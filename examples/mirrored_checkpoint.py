"""Two-branch checkpoint basin: mirrored save + fastest-replica restore.

Builds the dual-tier checkpoint DAG (host snapshot -> serialize staging
-> {local NVMe, remote object store}), shows the branch-aware plan the
planner derives for it (per-branch staging parameters under shared-tier
rate conservation), then saves a small state tree mirrored to both
replicas and restores from whichever branch is modeled faster — falling
back to the surviving replica when the primary is torn.

Usage:
    PYTHONPATH=src python examples/mirrored_checkpoint.py
"""

import shutil
import tempfile

import numpy as np

from repro.checkpoint.manager import CheckpointManager, verify_checkpoint
from repro.core.basin import MIB, mirrored_checkpoint_basin
from repro.core.planner import plan_transfer


def main() -> None:
    # -- the model: one source splitting to two storage sinks ------------
    basin = mirrored_checkpoint_basin()
    print("topology:")
    print(f"  roots={basin.roots()} split={basin.split_tiers()} "
          f"sinks={basin.sinks()}")
    for path, rate in basin.branch_rates().items():
        print(f"  {' -> '.join(path)}  @ {rate / 1e9:.2f} GB/s")

    # -- the plan: one branch per replica, weights from conservation -----
    plan = plan_transfer(basin, 8 * MIB, stages=("serialize",))
    print("\nplan:")
    print(plan.describe())

    # -- a mirrored save and a fastest-replica restore -------------------
    primary = tempfile.mkdtemp(prefix="ckpt-primary-")
    mirror = tempfile.mkdtemp(prefix="ckpt-mirror-")
    try:
        tree = {"w": np.arange(64, dtype=np.float32).reshape(8, 8),
                "step": np.asarray(7, dtype=np.int32)}
        mgr = CheckpointManager(primary, every_steps=1, mirror_root=mirror)
        mgr.maybe_save(1, tree, force=True)
        mgr.wait()
        print(f"\nsaved step 1: primary ok={verify_checkpoint(primary, 1)} "
              f"mirror ok={verify_checkpoint(mirror, 1)}")

        like = {"w": np.zeros((8, 8), np.float32),
                "step": np.zeros((), np.int32)}
        step, restored = mgr.restore_latest(like)
        print(f"restored step {step} from the faster replica: "
              f"match={np.allclose(np.asarray(restored['w']), tree['w'])}")

        # tear the primary: restore falls back to the mirror
        shutil.rmtree(primary)
        step, restored = mgr.restore_latest(like)
        print(f"primary torn -> restored step {step} from mirror: "
              f"match={np.allclose(np.asarray(restored['w']), tree['w'])}")
    finally:
        shutil.rmtree(primary, ignore_errors=True)
        shutil.rmtree(mirror, ignore_errors=True)


if __name__ == "__main__":
    main()
