"""Read a ``--telemetry-jsonl`` time series and print per-layer trends.

The training driver appends one cross-layer telemetry snapshot per flush
(``python -m repro.launch.train ... --telemetry-jsonl /tmp/telemetry.jsonl``).
Each line carries the cumulative per-layer aggregates; differencing
adjacent lines gives interval throughput, so this script shows how each
layer's rate and worst fidelity gap moved over the run — the drill-down
the atomic ``--telemetry-json`` point-in-time file cannot answer.

Usage:
    PYTHONPATH=src python examples/telemetry_timeseries.py /tmp/telemetry.jsonl
"""

import json
import sys


def load(path: str) -> list[dict]:
    rows = []
    with open(path) as f:
        for line in f:
            line = line.strip()
            if line:
                rows.append(json.loads(line))
    return rows


def spark(values: list[float]) -> str:
    """One-character-per-sample trend line."""
    bars = "▁▂▃▄▅▆▇█"
    hi = max(values) or 1.0
    return "".join(bars[min(len(bars) - 1,
                            int(v / hi * (len(bars) - 1)))] for v in values)


def main() -> None:
    if len(sys.argv) != 2:
        raise SystemExit(__doc__)
    rows = load(sys.argv[1])
    if len(rows) < 2:
        raise SystemExit("need at least two snapshots to show a trend "
                         f"(got {len(rows)})")
    layers = sorted({name for r in rows for name in r["layers"]})
    t0 = rows[0]["ts"]
    print(f"{len(rows)} snapshots over {rows[-1]['ts'] - t0:.1f}s")
    for name in layers:
        rates = []
        for prev, cur in zip(rows, rows[1:]):
            a = prev["layers"].get(name, {"bytes": 0, "elapsed_s": 0.0})
            b = cur["layers"].get(name)
            if b is None:
                continue
            d_bytes = b["bytes"] - a["bytes"]
            d_t = b["elapsed_s"] - a["elapsed_s"]
            rates.append(d_bytes / d_t / 1e6 if d_t > 0 else 0.0)
        if not rates:
            continue
        gap = rows[-1]["layers"][name].get("worst_fidelity_gap")
        gap_s = f"worst gap {gap:+.3f}" if gap is not None else "gap n/a"
        print(f"{name:>12}: {spark(rates)}  "
              f"{rates[0]:8.1f} -> {rates[-1]:8.1f} MB/s  ({gap_s})")


if __name__ == "__main__":
    main()
