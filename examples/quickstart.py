"""Quickstart: the whole framework in ~60 lines.

Builds the demo 100M-class config (reduced here so it runs in seconds on
CPU), trains a few steps through the burst-buffered input pipeline,
checkpoints, restores, and serves a few tokens — the full drainage-basin
data path end to end.

    PYTHONPATH=src python examples/quickstart.py
"""

import os
import sys
import tempfile

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.configs import get_smoke_config
from repro.data.pipeline import PipelineConfig, SyntheticTokenSource
from repro.launch.mesh import make_host_mesh
from repro.launch.serve import Server
from repro.launch.train import Trainer


def main() -> None:
    cfg = get_smoke_config("smollm-360m")
    mesh = make_host_mesh()

    with tempfile.TemporaryDirectory() as ckpt_dir:
        # --- train through the staged input path ---------------------------
        trainer = Trainer(cfg, mesh, ckpt_dir=ckpt_dir, ckpt_every=10,
                          lr=5e-3, total_steps=20)
        trainer.init_state()
        pc = PipelineConfig(global_batch=4, seq_len=128)
        source = SyntheticTokenSource(cfg, pc, n_batches=24)
        log = trainer.run(source, 20)
        print(f"[quickstart] trained 20 steps: loss "
              f"{log[0]['loss']:.3f} -> {log[-1]['loss']:.3f}")

        # --- restart from checkpoint (fault-tolerance path) ----------------
        t2 = Trainer(cfg, mesh, ckpt_dir=ckpt_dir, total_steps=20)
        t2.init_state(seed=123)
        assert t2.try_restore(), "restore failed"
        print(f"[quickstart] restored at step {t2.step_idx}")

        # --- serve: bulk prefill + streaming decode -------------------------
        server = Server(cfg, max_len=160)
        server.params = t2.params
        import numpy as np
        prompt = {"tokens": np.random.default_rng(0).integers(
            0, cfg.vocab, (2, 32), dtype=np.int32)}
        out = server.generate(prompt, n_tokens=16)
        print(f"[quickstart] generated {out.shape[1]} tokens/seq; "
              f"stream throughput "
              f"{server.last_report.throughput_bytes_per_s:.0f} B/s")
    print("[quickstart] OK")


if __name__ == "__main__":
    main()
