"""Fleet-scale basin arbitration, end to end: N tenants, one channel.

Walks the :class:`~repro.core.fleet.FleetArbiter` through the full
lifecycle on a simulated 100 Gb/s channel (virtual time, deterministic):

1. staggered arrivals — tenants of different QoS classes admit one by
   one and every grant is re-leveled under rate conservation;
2. admission control — a greedy min-rate ask that cannot fit is queued
   without touching a single live grant;
3. degradation — the channel is rebalanced onto a halved basin and the
   lowest class is shed below its floor (marked, not torn down), then
   recovers when the basin is restored;
4. live transfers — two tenants actually move bytes concurrently; a
   third admits mid-stream and the shrunken grants are pushed to the
   running stages as zero-drain plan revisions (watch the replan count);
5. departure — the first tenant finishes, auto-releases, and the
   survivors absorb its share at the next rebalance.

Usage:
    PYTHONPATH=src python examples/fleet_transfer.py
"""

import os
import sys
import threading

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "tests"))

from simbasin import SimHarness

from repro.core.basin import DrainageBasin, GBPS, Link, MIB, Tier, TierKind

L = 100 * GBPS                  # 12.5 GB/s line
ITEM = 1 * MIB
RTT = 0.005


def basin(line: float = L) -> DrainageBasin:
    return DrainageBasin(
        [Tier("src", TierKind.SOURCE, 2 * L),
         Tier("dst", TierKind.SINK, 2 * L)],
        [Link("src", "dst", line, rtt_s=RTT)])


def main() -> None:
    h = SimHarness()
    arb = h.arbiter(basin())

    # -- 1. staggered arrivals: every admit re-levels the whole fleet ----
    print("== staggered arrivals ==")
    handles = {}
    for name, qos, floor in (("ckpt", "priority", 0.4 * L),
                             ("shard", "bulk", 0.0),
                             ("scrub", "scavenger", 0.12 * L)):
        adm = handles[name] = arb.admit(name, ITEM, qos=qos,
                                        min_bytes_per_s=floor,
                                        stages=("move",))
        g = adm.granted_bytes_per_s / 1e6
        print(f"  + {name} ({qos}, floor {floor / 1e6:.0f} MB/s): "
              f"{adm.status}, granted {g:.0f} MB/s")
    print(arb.describe())

    # -- 2. admission control: an unfittable min-rate ask queues ---------
    print("\n== admission control ==")
    greedy = arb.admit("greedy", ITEM, qos="bulk", min_bytes_per_s=0.9 * L,
                       stages=("move",))
    print(f"  greedy (min 90% of line): {greedy.status} — {greedy.reason}")
    print(arb.describe())

    # -- 3. degradation: halve the channel, the bottom class is shed -----
    print("\n== channel degraded to half line ==")
    arb.rebalance(basin=basin(line=L / 2))
    print(arb.describe())
    print("\n== channel restored ==")
    arb.rebalance(basin=basin())
    arb.release("greedy")       # withdraw the queued ask for the demo
    print(arb.describe())

    # -- 4./5. live transfers with a mid-stream arrival ------------------
    print("\n== live transfers (scrub admits mid-stream) ==")
    link = h.link(bandwidth_bytes_per_s=L, rtt_s=RTT, wall_sync=10.0,
                  wall_pacing_s=0.0)
    go = threading.Event()
    sunk = [0]

    def sink_ckpt(_item):
        sunk[0] += 1
        if sunk[0] == 32:
            go.set()            # ckpt is mid-stream: bring in the scrub

    def tenant(adm, n_items, seed, sink=None):
        def run():
            src = h.source(h.tier(bandwidth_bytes_per_s=1000 * GBPS,
                                  wall_pacing_s=0.0, seed=seed), n_items,
                           ITEM)
            return h.mover().bulk_transfer(
                iter(src), sink if sink else (lambda _: None),
                transforms=[("move", h.service(link))], fleet=adm)
        return run

    def late_scrub():
        go.wait(timeout=120)
        adm = arb.admit("scrub2", ITEM, qos="scavenger", stages=("move",))
        print(f"  + scrub2 mid-stream: {adm.status}, granted "
              f"{adm.granted_bytes_per_s / 1e6:.0f} MB/s")
        return tenant(adm, 64, seed=9)()

    rep_ckpt, rep_shard, rep_scrub = h.run_concurrent(
        tenant(handles["ckpt"], 192, seed=1, sink=sink_ckpt),
        tenant(handles["shard"], 96, seed=2), late_scrub)
    for name, rep in (("ckpt", rep_ckpt), ("shard", rep_shard),
                      ("scrub2", rep_scrub)):
        print(f"  {name}: {rep.items} items at "
              f"{rep.throughput_bytes_per_s / 1e6:.0f} MB/s, "
              f"replans={rep.replans}, gap={rep.fidelity_gap:.3f}")
    print("\n== after the fleet drains (auto-release) ==")
    print(arb.describe())


if __name__ == "__main__":
    main()
