"""Batched serving example: bulk prefill + streaming decode with the
unified mover, over several request waves (the paper's two workload
classes composed, §2.2).

    PYTHONPATH=src python examples/serve_pipeline.py
"""

import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro.configs import get_smoke_config
from repro.launch.serve import Server


def main() -> None:
    cfg = get_smoke_config("gemma3-1b")   # local:global attention family
    server = Server(cfg, max_len=256)
    server.load()
    rng = np.random.default_rng(0)

    total_tokens = 0
    t0 = time.monotonic()
    for wave in range(3):
        batch = {"tokens": rng.integers(0, cfg.vocab, (4, 48),
                                        dtype=np.int32)}
        streamed: list[np.ndarray] = []
        out = server.generate(batch, n_tokens=24, sink=streamed.append)
        total_tokens += out.size
        rep = server.last_report
        print(f"[serve] wave {wave}: {out.shape} tokens; "
              f"streaming mode={rep.mode} items={rep.items} "
              f"stall(bottleneck)={rep.bottleneck_stage().name if rep.stage_reports else 'n/a'}")
    dt = time.monotonic() - t0
    print(f"[serve] {total_tokens} tokens in {dt:.2f}s "
          f"({total_tokens / dt:.1f} tok/s) — OK")


if __name__ == "__main__":
    main()
