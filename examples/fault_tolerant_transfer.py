"""Fault-tolerant transfers end to end: a flapping element is retried
away under its hop budget, the replanner re-prices it as
``fault-degraded``, a branch that dies outright is failed over without
losing an item, and a killed transfer resumes from its durable ledger
with a bit-identical stream checksum.

The paper's production framing (§2.1) is that a long transfer's real
question is whether it *completes* — this walkthrough exercises the
survive layer that answers it:

    PYTHONPATH=src python examples/fault_tolerant_transfer.py
"""

import hashlib
import os
import random
import sys
import tempfile
import threading

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.core.basin import (DrainageBasin, GBPS, Link, MIB, Tier,
                              TierKind)
from repro.core.mover import MoverConfig, UnifiedDataMover
from repro.core.planner import plan_transfer
from repro.core.resume import TransferLedger

N_ITEMS, ITEM = 48, 256 * 1024


def fanout_basin() -> DrainageBasin:
    return DrainageBasin(
        tiers=[
            Tier("src", TierKind.SOURCE, 40.0 * GBPS, latency_s=1e-5),
            Tier("staging", TierKind.BURST_BUFFER, 40.0 * GBPS,
                 latency_s=1e-5),
            Tier("path-a", TierKind.SINK, 10.0 * GBPS),
            Tier("path-b", TierKind.SINK, 10.0 * GBPS),
        ],
        links=[Link("src", "staging"),
               Link("staging", "path-a"),
               Link("staging", "path-b")])


def dataset():
    rng = random.Random(9)
    return [bytes([rng.randrange(256)]) * ITEM for _ in range(N_ITEMS)]


def xor_sha(items) -> str:
    acc = bytearray(32)
    for it in items:
        d = hashlib.sha256(it).digest()
        for i in range(32):
            acc[i] ^= d[i]
    return bytes(acc).hex()


def main() -> None:
    data = dataset()
    truth = xor_sha(data)

    # --- 1. a flapping element: retried away under the hop budget ----------
    plan = plan_transfer(fanout_basin(), ITEM, stages=("deliver",))
    print(f"[plan] every hop ships with a retry budget:")
    for line in plan.describe().splitlines():
        if "retry=" in line:
            print(f"       {line.strip()}")

    flaps = {"n": 0}

    def flaky(item):
        flaps["n"] += 1
        if flaps["n"] in (3, 7):        # two transient faults mid-stream
            raise IOError("element flapped")
        return item

    got = []
    mover = UnifiedDataMover(MoverConfig(checksum=True))
    linear = DrainageBasin(
        tiers=[Tier("src", TierKind.SOURCE, 40.0 * GBPS, latency_s=1e-5),
               Tier("staging", TierKind.BURST_BUFFER, 40.0 * GBPS,
                    latency_s=1e-5),
               Tier("dst", TierKind.SINK, 10.0 * GBPS)],
        links=[Link("src", "staging"), Link("staging", "dst")])
    rep = mover.bulk_transfer(iter(data), got.append,
                              transforms=[("deliver", flaky)],
                              plan=plan_transfer(linear, ITEM,
                                                 stages=("deliver",)))
    retries = sum(r.retries for r in rep.stage_reports)
    backoff = sum(r.retry_wait_s for r in rep.stage_reports)
    print(f"[retry] {rep.items}/{N_ITEMS} items delivered; "
          f"{retries} transient faults retried away "
          f"({backoff * 1e3:.1f} ms backoff), checksum "
          f"{'OK' if rep.checksum == truth else 'MISMATCH'}")

    # --- 2. a branch dies outright: failover, not failure ------------------
    deaths = {"n": 0}
    lock = threading.Lock()

    def dying_a(item):
        with lock:
            deaths["n"] += 1
            if deaths["n"] > 5:         # permanent death after 5 items
                raise IOError("path-a element died")
        return item

    got = []
    mover = UnifiedDataMover(MoverConfig(checksum=True))
    rep = mover.parallel_transfer(
        iter(data), got.append,
        transforms={"path-a": [("deliver", dying_a)],
                    "path-b": [("deliver", lambda x: x)]},
        mode="split", plan=plan_transfer(fanout_basin(), ITEM,
                                         stages=("deliver",)),
        checksum=True)
    diag = mover.last_plan.diagnosis
    print(f"[failover] path-a died mid-stream -> "
          f"{len(got)}/{N_ITEMS} items still delivered, checksum "
          f"{'OK' if rep.checksum == truth else 'MISMATCH'}")
    print(f"[failover] verdict: {diag.get('path-a')}")
    salvaged = [r.name for r in rep.stage_reports
                if r.name.startswith("salvage/")]
    if salvaged:
        print(f"[failover] stranded items re-moved through a survivor: "
              f"{', '.join(salvaged)}")

    # --- 3. the process is killed: resume from the durable ledger ----------
    with tempfile.TemporaryDirectory() as tmp:
        path = os.path.join(tmp, "transfer.ledger.jsonl")
        led = TransferLedger(path)
        count = {"n": 0}

        def power_cut_sink(item):
            if count["n"] >= 17:
                raise RuntimeError("power cut")
            count["n"] += 1

        try:
            UnifiedDataMover(MoverConfig(checksum=True)).bulk_transfer(
                iter(data), power_cut_sink, resume=led)
        except RuntimeError:
            pass
        led.close()
        print(f"[ledger] killed mid-transfer with "
              f"{TransferLedger(path).items_recorded}/{N_ITEMS} items "
              f"durably recorded in {os.path.basename(path)}")

        led2 = TransferLedger(path)
        moved = []
        rep = UnifiedDataMover(MoverConfig(checksum=True)).bulk_transfer(
            iter(data), moved.append, resume=led2)
        verdict = ("identical to an unbroken run"
                   if rep.checksum == truth else "MISMATCH")
        print(f"[resume] skipped {led2.skipped_items} verified items "
              f"({led2.skipped_bytes / MIB:.1f} MiB not re-moved), "
              f"moved the remaining {len(moved)}; stream checksum "
              f"{verdict}")
        led2.close()


if __name__ == "__main__":
    main()
