"""The zero-copy batched data plane, end to end: measure what the host
can actually hash, watch the per-item hot path collapse under
coordination cost, recover it with slab admission, and let the planner
move the integrity budget off the host when the hash rate — not the
pipe — is what pins delivery.

    PYTHONPATH=src python examples/zero_copy_transfer.py

Three acts:

1. **Per-item collapse.** The same stream, the same plan: forcing
   ``batch_items=1`` pays one upstream pull, one admission check, one
   buffer lock round-trip, and one digest lock per 8 KiB item — the
   §3.6 abstraction penalty, measured on real wall clock.
2. **Slab recovery.** ``batch_items="auto"`` moves ~1 MiB slabs of
   ``memoryview`` items (no per-item copy anywhere) through every one
   of those seams in one step each.
3. **Host-compute-bound.** With the measured SHA-256 rate in the plan,
   a recorded checksum-hop report pinned at that ceiling (the replay
   protocol of tests/test_replan_corpus.py) makes ``replan`` diagnose
   the digest placement itself — the remedy flips the checksum to the
   accelerator and leaves every estimate, worker count, and the planned
   rate standing.  Kernel parity for the accelerator digest is gated in
   ``benchmarks/kernel_bench.py`` (interpret-mode wall time on a CPU
   container is *not* TPU performance, so this act is a planning story).
"""

import hashlib
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.core.basin import DrainageBasin, GBPS, Tier, TierKind
from repro.core.mover import MoverConfig, UnifiedDataMover
from repro.core.planner import plan_transfer, replan
from repro.core.staging import StageReport, slab_views

ITEM = 8 * 1024
STREAM = 32 * 1024 * 1024


def _basin() -> DrainageBasin:
    # pipes far above what the host can coordinate per item, so wall
    # clock measures the data plane, not the modeled links
    return DrainageBasin([
        Tier("src", TierKind.SOURCE, 100.0 * GBPS, latency_s=1e-6),
        Tier("bb", TierKind.BURST_BUFFER, 200.0 * GBPS, latency_s=1e-6),
        Tier("sink", TierKind.SINK, 100.0 * GBPS, latency_s=1e-6),
    ])


def _run(data: bytes, plan, batch_items) -> tuple[float, str]:
    mover = UnifiedDataMover(MoverConfig(checksum=True), plan=plan)
    t0 = time.perf_counter()
    rep = mover.bulk_transfer(
        slab_views(data, ITEM), lambda _: None,
        transforms=[("pull", None), ("push", None)],
        checksum=True, batch_items=batch_items)
    dt = time.perf_counter() - t0
    assert rep.items == STREAM // ITEM
    return len(data) / dt, rep.checksum


def main() -> None:
    data = os.urandom(STREAM)

    # --- what can this host actually hash? ---------------------------------
    t0 = time.perf_counter()
    hashlib.sha256(data).digest()
    host_hash_bps = STREAM / (time.perf_counter() - t0)
    print(f"[host] measured SHA-256 rate: {host_hash_bps / 1e9:.2f} GB/s "
          f"(the integrity budget a host-placed digest charges)")

    # --- the plan: auto-sized slabs, digest charged to the host ------------
    plan = plan_transfer(_basin(), ITEM, stages=("pull", "push"),
                         checksum=True, batch_items="auto",
                         checksum_placement="host",
                         host_digest_bytes_per_s=host_hash_bps)
    print(f"[plan] {plan.describe()}")

    # --- act 1 + 2: per-item collapse, slab recovery -----------------------
    bps_item, sum_item = _run(data, plan, 1)
    bps_slab, sum_slab = _run(data, plan, None)
    assert sum_item == sum_slab, "the slab path must be bit-identical"
    print(f"[mover] per-item  {bps_item / 1e6:7.0f} MB/s   (batch_items=1, "
          f"the historical hot path)")
    print(f"[mover] batched   {bps_slab / 1e6:7.0f} MB/s   "
          f"(b={max(h.batch_items for h in plan.hops)}, "
          f"{bps_slab / bps_item:.1f}x, same checksum {sum_slab[:16]}…)")

    # --- act 3: the digest ceiling becomes the verdict ---------------------
    # A recorded report for the checksum hop, delivering AT the measured
    # hash ceiling with no queue/window stalls: nothing is starved,
    # nothing backpressures — the host's own hashing is the only thing
    # the delivered rate can be charged to.
    hop = plan.hops[plan.checksum_index]
    pinned = StageReport(name=hop.name, items=int(host_hash_bps * 2 // ITEM),
                         bytes=int(host_hash_bps * 1.9), elapsed_s=2.0,
                         active_s=2.0, stall_up_s=0.02, stall_down_s=0.02,
                         errors=0)
    revised = replan(plan, [pinned], damping=1.0)
    print(f"[replan] diagnosis: {revised.diagnosis}")
    print(f"[replan] {revised.describe()}")
    assert revised.checksum_placement == "accel"
    assert revised.planned_bytes_per_s == plan.planned_bytes_per_s
    print("[replan] remedy is placement, not estimates: the digest moves "
          "to the accelerator\n         (Pallas lattice kernel, parity-"
          "gated in benchmarks/kernel_bench.py);\n         tier estimates, "
          "workers, and the planned rate all stand.")


if __name__ == "__main__":
    main()
