"""Windowed WAN transfer: the paper's latency collapse, and its remedies.

Walks the §3.1/§3.2 story end to end on the paper's canonical path —
``paper_basin(link_gbps=100, rtt_ms=74)``, the Switzerland -> California
production link — in simulated (virtual) time:

1. plan the transfer under a default-sized host stream buffer
   (``max_window_bytes=16 MiB``): the planner sizes every RTT-governed
   hop's in-flight window, but the host clamp pins it ~70x below the
   link's bandwidth-delay product;
2. run it: delivery collapses to ~``window / RTT`` (a few hundred MB/s
   on a 100 Gbps link) with the wait accounted as *window stall* —
   distinct from queue stalls, because its remedy is different;
3. ``replan`` reads the evidence and issues a **window-bound** verdict:
   the tier estimates stand, the worker pool stays put, only the window
   (and the buffers feeding it) rise — to BDP with jitter headroom;
4. re-run on the revised plan: the same link now delivers the planned
   line rate.  The same remedy applies zero-drain to a live transfer via
   ``replan_every_items`` (see tests/test_windowed_transport.py).

Then the two §3.2 scenarios the window-bound verdict alone would
MISDIAGNOSE — the point of the adaptive transport:

5. a mid-transfer route change (74 ms -> 150 ms) produces the same
   surface symptom (window stall, pinned delivery), but the hop's own
   ACK spacing says the ROUND TRIP changed: the verdict is
   **rtt-revised** — the window is re-sized to the new BDP and the
   re-run recovers the line; "lift the clamp" would have fixed nothing;
6. deterministic loss makes every item pay a retransmit round trip the
   plan never modeled: the verdict is **loss-bound** — the window
   deepens by (1 + loss), the pool is staffed for the per-item
   retransmit RTT, and the promise drops honestly when even the full
   pool cannot reach the line.

Usage:
    PYTHONPATH=src:tests python examples/wan_transfer.py
"""

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "tests"))

from simbasin import SimHarness  # noqa: E402

from repro.core.basin import (DrainageBasin, GBPS, Link, MIB,  # noqa: E402
                              Tier, TierKind, paper_basin)
from repro.core.planner import plan_transfer, replan  # noqa: E402

ITEM = 8 * MIB
N_ITEMS = 96
RTT_S = 0.074
HOST_WINDOW = 16 * MIB          # the default-config stream buffer (§3.2)


def run_transfer(plan):
    """Execute the planned path in virtual time: fast feeder, the
    scripted 100 Gbps x 74 ms link, destination storage."""
    h = SimHarness()
    link = h.link(bandwidth_bytes_per_s=100 * GBPS, rtt_s=RTT_S)
    dst = h.tier(bandwidth_bytes_per_s=40 * GBPS, latency_s=2e-3, seed=7)
    src = h.source(h.tier(bandwidth_bytes_per_s=1000 * GBPS,
                          wall_pacing_s=0.0), N_ITEMS, ITEM)
    mover = h.mover(plan=plan)
    return mover.bulk_transfer(
        iter(src), lambda _: None,
        transforms=[("wan", h.service(link)), ("store", h.service(dst))])


def main() -> None:
    basin = paper_basin(link_gbps=100.0, rtt_ms=74.0, storage_jitter_ms=0.0)
    bdp = basin.link("burst-buffer-src", "wan").bdp_bytes()
    print(f"link BDP at 100 Gbps x 74 ms: {bdp / 1e6:.0f} MB "
          f"(host window: {HOST_WINDOW / 1e6:.0f} MB — "
          f"{bdp / HOST_WINDOW:.0f}x under)")

    # 1. the under-windowed plan: the promise is still the line rate —
    #    a misconfigured window must show up as a gap, not be hidden
    plan = plan_transfer(basin, ITEM, stages=("wan", "store"),
                         max_window_bytes=HOST_WINDOW)
    print("\nunder-windowed plan:")
    print(plan.describe())

    # 2. the collapse: delivery pins at ~window/RTT
    rep = run_transfer(plan)
    print(f"\ncollapsed delivery: {rep.throughput_bytes_per_s / 1e6:.0f} "
          f"MB/s  (window/RTT ceiling: "
          f"{HOST_WINDOW / RTT_S / 1e6:.0f} MB/s, planned: "
          f"{plan.planned_bytes_per_s / 1e6:.0f} MB/s, fidelity gap: "
          f"{rep.fidelity_gap:.2f})")
    wan = next(r for r in rep.stage_reports if r.name == "wan")
    print(f"evidence: wan stall_window={wan.stall_window_s:.1f}s vs "
          f"stall_up={wan.stall_up_s:.2f}s stall_down="
          f"{wan.stall_down_s:.2f}s")

    # 3. one replan: the window-bound verdict raises the window, nothing
    #    else — more workers would all park on the same ACK clock
    revised = replan(plan, rep.stage_reports, damping=1.0)
    print("\nrevised plan:")
    print(revised.describe())

    # 4. recovery: the same link at the planned rate
    rep2 = run_transfer(revised)
    print(f"\nrecovered delivery: {rep2.throughput_bytes_per_s / 1e6:.0f} "
          f"MB/s  ({rep2.throughput_bytes_per_s / rep.throughput_bytes_per_s:.1f}x "
          f"the collapsed run)")

    route_change_act()
    loss_act()


def _line_basin(rtt_ms=74.0, loss_rate=0.0):
    """A WAN path whose storage outruns the 100 Gbps link: the planned
    rate IS the line rate, so transport misbehaviour cannot hide behind
    a slow endpoint."""
    return DrainageBasin(
        tiers=[Tier("src", TierKind.SOURCE, 200 * GBPS, latency_s=1e-4),
               Tier("bb", TierKind.BURST_BUFFER, 200 * GBPS, latency_s=1e-5),
               Tier("dst", TierKind.SINK, 200 * GBPS, latency_s=1e-4)],
        links=[Link("src", "bb", 200 * GBPS),
               Link("bb", "dst", 100 * GBPS, rtt_s=rtt_ms / 1e3,
                    loss_rate=loss_rate)])


def run_line(plan, n_items=240, *, rtt_s=RTT_S, loss_every=0,
             shift_rtt_s=None):
    """Execute the plan against a scripted link — clock, link, feeder,
    and mover all share ONE simulation context."""
    h = SimHarness()
    link = h.link(bandwidth_bytes_per_s=100 * GBPS, rtt_s=rtt_s,
                  loss_every=loss_every)
    if shift_rtt_s is not None:
        link.shift_at(12, rtt_s=shift_rtt_s)
    src = h.source(h.tier(bandwidth_bytes_per_s=1000 * GBPS,
                          wall_pacing_s=0.0), n_items, 2 * ITEM)
    mover = h.mover(plan=plan)
    return mover.bulk_transfer(iter(src), lambda _: None,
                               transforms=[("move", h.service(link))])


def route_change_act() -> None:
    # 5. the misdiagnosis bait: mid-transfer the route changes and the
    #    round trip doubles.  The surface evidence — window stall,
    #    delivery pinned below the line — is EXACTLY what window-bound
    #    looks like, but no clamp was ever wrong, and lifting one would
    #    fix nothing.  The hop's observed ACK spacing names the real
    #    culprit: the window is sized for a round trip that no longer
    #    exists.
    print("\n--- route change: 74 ms -> 150 ms mid-transfer ---")
    plan = plan_transfer(_line_basin(), 2 * ITEM, stages=("move",))
    rep = run_line(plan, shift_rtt_s=0.150)
    move = rep.stage_reports[0]
    print(f"collapsed delivery: {rep.throughput_bytes_per_s / 1e6:.0f} MB/s "
          f"(planned {plan.planned_bytes_per_s / 1e6:.0f} MB/s); "
          f"window stall {move.stall_window_s:.1f}s — window-bound bait, "
          f"but observed rtt ~{move.rtt_estimate_s * 1e3:.0f} ms")
    revised = replan(plan, rep.stage_reports, damping=1.0)
    print(f"verdict: {revised.diagnosis['move']}")
    print(revised.describe())
    rep2 = run_line(revised, rtt_s=0.150)
    print(f"recovered delivery on the changed route: "
          f"{rep2.throughput_bytes_per_s / 1e6:.0f} MB/s "
          f"({rep2.throughput_bytes_per_s / rep.throughput_bytes_per_s:.1f}x)")


def loss_act() -> None:
    # 6. scripted loss: every item pays one retransmit round trip the
    #    plan never modeled.  The retransmit counter is first-hand
    #    channel telemetry: the verdict is loss-bound, the window
    #    deepens by (1 + loss), the pool is staffed for the per-item
    #    retransmit RTT, and the promise drops to what the staffed pool
    #    can actually push — honestly, not as a perpetual fidelity gap.
    print("\n--- deterministic loss: every item retransmits once ---")
    plan = plan_transfer(_line_basin(), 2 * ITEM, stages=("move",))
    rep = run_line(plan, n_items=96, loss_every=1)
    move = rep.stage_reports[0]
    print(f"collapsed delivery: {rep.throughput_bytes_per_s / 1e6:.0f} MB/s "
          f"(planned {plan.planned_bytes_per_s / 1e6:.0f} MB/s); "
          f"{move.retransmits}/{move.items} items retransmitted")
    revised = replan(plan, rep.stage_reports, damping=1.0)
    print(f"verdict: {revised.diagnosis['move']}")
    print(revised.describe())
    rep2 = run_line(revised, n_items=96, loss_every=1)
    print(f"recovered delivery through the same loss: "
          f"{rep2.throughput_bytes_per_s / 1e6:.0f} MB/s "
          f"({rep2.throughput_bytes_per_s / rep.throughput_bytes_per_s:.1f}x, "
          f"honest promise {revised.planned_bytes_per_s / 1e6:.0f} MB/s)")


if __name__ == "__main__":
    main()
