"""Windowed WAN transfer: the paper's latency collapse, and its remedy.

Walks the §3.1/§3.2 story end to end on the paper's canonical path —
``paper_basin(link_gbps=100, rtt_ms=74)``, the Switzerland -> California
production link — in simulated (virtual) time:

1. plan the transfer under a default-sized host stream buffer
   (``max_window_bytes=16 MiB``): the planner sizes every RTT-governed
   hop's in-flight window, but the host clamp pins it ~70x below the
   link's bandwidth-delay product;
2. run it: delivery collapses to ~``window / RTT`` (a few hundred MB/s
   on a 100 Gbps link) with the wait accounted as *window stall* —
   distinct from queue stalls, because its remedy is different;
3. ``replan`` reads the evidence and issues a **window-bound** verdict:
   the tier estimates stand, the worker pool stays put, only the window
   (and the buffers feeding it) rise — to BDP with jitter headroom;
4. re-run on the revised plan: the same link now delivers the planned
   line rate.  The same remedy applies zero-drain to a live transfer via
   ``replan_every_items`` (see tests/test_windowed_transport.py).

Usage:
    PYTHONPATH=src:tests python examples/wan_transfer.py
"""

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "tests"))

from simbasin import SimHarness  # noqa: E402

from repro.core.basin import GBPS, MIB, paper_basin  # noqa: E402
from repro.core.planner import plan_transfer, replan  # noqa: E402

ITEM = 8 * MIB
N_ITEMS = 96
RTT_S = 0.074
HOST_WINDOW = 16 * MIB          # the default-config stream buffer (§3.2)


def run_transfer(plan):
    """Execute the planned path in virtual time: fast feeder, the
    scripted 100 Gbps x 74 ms link, destination storage."""
    h = SimHarness()
    link = h.link(bandwidth_bytes_per_s=100 * GBPS, rtt_s=RTT_S)
    dst = h.tier(bandwidth_bytes_per_s=40 * GBPS, latency_s=2e-3, seed=7)
    src = h.source(h.tier(bandwidth_bytes_per_s=1000 * GBPS,
                          wall_pacing_s=0.0), N_ITEMS, ITEM)
    mover = h.mover(plan=plan)
    return mover.bulk_transfer(
        iter(src), lambda _: None,
        transforms=[("wan", h.service(link)), ("store", h.service(dst))])


def main() -> None:
    basin = paper_basin(link_gbps=100.0, rtt_ms=74.0, storage_jitter_ms=0.0)
    bdp = basin.link("burst-buffer-src", "wan").bdp_bytes()
    print(f"link BDP at 100 Gbps x 74 ms: {bdp / 1e6:.0f} MB "
          f"(host window: {HOST_WINDOW / 1e6:.0f} MB — "
          f"{bdp / HOST_WINDOW:.0f}x under)")

    # 1. the under-windowed plan: the promise is still the line rate —
    #    a misconfigured window must show up as a gap, not be hidden
    plan = plan_transfer(basin, ITEM, stages=("wan", "store"),
                         max_window_bytes=HOST_WINDOW)
    print("\nunder-windowed plan:")
    print(plan.describe())

    # 2. the collapse: delivery pins at ~window/RTT
    rep = run_transfer(plan)
    print(f"\ncollapsed delivery: {rep.throughput_bytes_per_s / 1e6:.0f} "
          f"MB/s  (window/RTT ceiling: "
          f"{HOST_WINDOW / RTT_S / 1e6:.0f} MB/s, planned: "
          f"{plan.planned_bytes_per_s / 1e6:.0f} MB/s, fidelity gap: "
          f"{rep.fidelity_gap:.2f})")
    wan = next(r for r in rep.stage_reports if r.name == "wan")
    print(f"evidence: wan stall_window={wan.stall_window_s:.1f}s vs "
          f"stall_up={wan.stall_up_s:.2f}s stall_down="
          f"{wan.stall_down_s:.2f}s")

    # 3. one replan: the window-bound verdict raises the window, nothing
    #    else — more workers would all park on the same ACK clock
    revised = replan(plan, rep.stage_reports, damping=1.0)
    print("\nrevised plan:")
    print(revised.describe())

    # 4. recovery: the same link at the planned rate
    rep2 = run_transfer(revised)
    print(f"\nrecovered delivery: {rep2.throughput_bytes_per_s / 1e6:.0f} "
          f"MB/s  ({rep2.throughput_bytes_per_s / rep.throughput_bytes_per_s:.1f}x "
          f"the collapsed run)")


if __name__ == "__main__":
    main()
