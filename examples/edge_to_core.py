"""The paper's own scenario, executable: move a dataset from an erratic
edge source to a core sink across a latency-bearing channel, staged
through burst buffers, with integrity on — then read the fidelity report
and the basin model's verdict side by side.

    PYTHONPATH=src python examples/edge_to_core.py
"""

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro.core.basin import GBPS, paper_basin, recommend_tier
from repro.core.mover import MoverConfig, UnifiedDataMover


def main() -> None:
    # --- plan: the basin model predicts the path ---------------------------
    basin = paper_basin(link_gbps=100.0, rtt_ms=74.0, storage_gbps=40.0)
    plan = basin.bottleneck()
    print(f"[basin] bottleneck: {plan.element} "
          f"({plan.achievable_bytes_per_s / GBPS:.1f} Gbps achievable, "
          f"fidelity gap {plan.fidelity_gap:.0%})")
    print(f"[basin] appliance tier: "
          f"{recommend_tier(plan.achievable_bytes_per_s).value}; "
          f"buffer >= {basin.buffer_bytes_required() / 2**20:.0f} MiB; "
          f"prefetch depth {basin.prefetch_depth(64 << 20)}")

    # --- execute: staged, checksummed bulk transfer across the "WAN" --------
    import time
    n_items, item = 32, 1 << 20
    rng = np.random.default_rng(0)
    dataset = [rng.integers(0, 255, item, dtype=np.uint8)
               for _ in range(n_items)]

    def wan_hop(chunk):
        time.sleep(0.01)                # per-item link latency
        return chunk

    received = []
    mover = UnifiedDataMover(MoverConfig(staging_capacity=8,
                                         staging_workers=4, checksum=True),
                             basin=basin)
    report = mover.bulk_transfer(iter(dataset), received.append,
                                 transforms=[("wan", wan_hop)])
    print(f"[mover] {report.items} items, "
          f"{report.bytes / 2**20:.0f} MiB in {report.elapsed_s:.2f}s "
          f"({report.throughput_bytes_per_s / 1e6:.0f} MB/s)")
    print(f"[mover] checksum {report.checksum[:16]}…; "
          f"bottleneck stage: {report.bottleneck_stage().name}")

    # --- compare against the unstaged single-stream path --------------------
    t0 = time.monotonic()
    for chunk in dataset:
        wan_hop(chunk)                  # every item pays the RTT serially
    direct_s = time.monotonic() - t0
    direct_bps = n_items * item / direct_s
    speedup = report.throughput_bytes_per_s / direct_bps
    print(f"[mover] staged vs single-stream: {speedup:.2f}x "
          f"(the co-design dividend) — OK")


if __name__ == "__main__":
    main()
