"""End-to-end training driver: the repro-100m config for a few hundred
steps with the complete production data path — staged input pipeline,
fault injection, async checksummed checkpoints, restart, metrics.

Full run (~100M params; give it time on CPU):
    PYTHONPATH=src python examples/train_e2e.py --steps 300

Reduced (CI-speed) run:
    PYTHONPATH=src python examples/train_e2e.py --smoke --steps 60
"""

import argparse
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.configs import get_config, get_smoke_config
from repro.data.pipeline import PipelineConfig, SyntheticTokenSource
from repro.launch.mesh import make_host_mesh
from repro.launch.train import Trainer


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=512)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_e2e_ckpt")
    ap.add_argument("--inject-failure-at", type=int, default=-1)
    ap.add_argument("--out", default="/tmp/repro_e2e_metrics.json")
    args = ap.parse_args()

    cfg = (get_smoke_config("repro-100m") if args.smoke
           else get_config("repro-100m"))
    if args.smoke:
        args.seq_len = min(args.seq_len, 128)
    mesh = make_host_mesh()
    trainer = Trainer(cfg, mesh, ckpt_dir=args.ckpt_dir, ckpt_every=50,
                      lr=3e-3, total_steps=args.steps)
    trainer.init_state()
    resumed = trainer.try_restore()
    if resumed:
        print(f"[e2e] resumed from step {trainer.step_idx}")

    pc = PipelineConfig(global_batch=args.global_batch, seq_len=args.seq_len)
    source = SyntheticTokenSource(cfg, pc, n_batches=args.steps + 16)
    log = trainer.run(source, args.steps,
                      inject_failure_at=args.inject_failure_at)

    losses = [r["loss"] for r in log]
    stalls = [r["input_stall_s"] for r in log]
    walls = [r["wall_s"] for r in log]
    summary = {
        "arch": cfg.name, "steps": len(log),
        "loss_first": losses[0], "loss_last": losses[-1],
        "mean_step_s": sum(walls) / len(walls),
        "total_input_stall_s": stalls[-1] if stalls else 0.0,
        "tokens_per_s": args.global_batch * args.seq_len
                        / (sum(walls) / len(walls)),
    }
    with open(args.out, "w") as f:
        json.dump({"summary": summary, "log": log}, f)
    print(f"[e2e] {json.dumps(summary, indent=1)}")
    assert losses[-1] < losses[0], "training did not improve loss"
    print("[e2e] OK — loss improved; metrics at", args.out)


if __name__ == "__main__":
    main()
