"""Stream or stage?  Walk the planner's path decision end to end.

§3.6's abstraction penalty runs both ways: always staging pays a copy
the direct path skips, always streaming pays a round trip per item the
windowed ledger hides.  This walkthrough forces the WRONG shape first,
reads the fidelity gap, then hands the choice to ``path="auto"`` and
watches a scripted mid-transfer route change trigger the
``path-revised`` verdict — the live transfer switches shape at a
revision boundary and recovers.

    PYTHONPATH=src python examples/stream_vs_stage.py
"""

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "tests"))

from simbasin import SimHarness

from repro.core.basin import DrainageBasin, Link, Tier, TierKind
from repro.core.planner import plan_transfer

ITEM = 256 << 10          # a 256 KiB object — small enough that the
#                           round trip matters, big enough to measure


def basin() -> DrainageBasin:
    """Fast endpoints, a slow burst buffer, a short-round-trip wire:
    the regime where the direct cut-through (no staging copy) wins."""
    return DrainageBasin(
        [Tier("src", TierKind.SOURCE, 8e9),
         Tier("bb", TierKind.BURST_BUFFER, 0.15e9, latency_s=50e-6),
         Tier("dst", TierKind.SINK, 8e9)],
        [Link("src", "bb", 5e9),
         Link("bb", "dst", 5e9, rtt_s=0.2e-3)])


def run(path: str, *, shift: bool = False, replan_every: int = 0,
        bypass: bool = True):
    """Execute one 96-item planned transfer in virtual time; with
    ``shift``, the wire's round trip is re-routed 0.2 ms -> 40 ms at
    the 24th item (the mid-transfer regime change).

    ``bypass`` is the direct shape's execution mapping: a direct plan
    runs cut-through, so its staging hop does not serve the burst
    buffer (that copy is what the bypass skips).  The shift scenario
    passes ``bypass=False`` so stay-vs-revise differ ONLY in what the
    planner does about the route change."""
    plan = plan_transfer(basin(), ITEM, stages=("stage", "move"),
                         path=path)
    h = SimHarness()
    bb = h.tier(bandwidth_bytes_per_s=0.15e9, wall_pacing_s=0.0)
    link = h.link(bandwidth_bytes_per_s=5e9, rtt_s=0.2e-3,
                  wall_pacing_s=0.0)
    if shift:
        link.shift_at(24, rtt_s=40e-3)
    if plan.path == "direct" and bypass:
        def stage_tf(item):
            return item
    else:
        stage_tf = h.service(bb)
    src = h.source(h.tier(bandwidth_bytes_per_s=8e9, wall_pacing_s=0.0),
                   96, ITEM)
    mover = h.mover(plan=plan)
    report = mover.bulk_transfer(
        iter(src), lambda _: None,
        transforms=[("stage", stage_tf), ("move", h.service(link))],
        replan_every_items=replan_every)
    return plan, report, mover.last_plan


def main() -> None:
    # --- 1. the planner prices every shape and shows its work ------------
    plan = plan_transfer(basin(), ITEM, stages=("stage", "move"),
                         path="auto")
    print("[plan] candidate scores (modeled end-to-end MB/s):")
    for name, score in sorted(plan.path_scores.items(),
                              key=lambda kv: -kv[1]):
        mark = " <- chosen" if name == plan.path else ""
        print(f"[plan]   {name:16s} {score / 1e6:8.1f}{mark}")
    print(plan.describe())

    # --- 2. force the WRONG shape and read the fidelity gap --------------
    _, staged_rep, _ = run("windowed-staged")
    _, direct_rep, _ = run("direct")
    print(f"[forced] windowed-staged: "
          f"{staged_rep.throughput_bytes_per_s / 1e6:7.1f} MB/s "
          f"(every byte pays the 150 MB/s staging copy)")
    print(f"[forced] direct:          "
          f"{direct_rep.throughput_bytes_per_s / 1e6:7.1f} MB/s "
          f"(cut-through skips it)")
    gap = (direct_rep.throughput_bytes_per_s
           / staged_rep.throughput_bytes_per_s)
    print(f"[forced] picking wrong here costs x{gap:.1f} — "
          f"the paper's abstraction penalty, both directions")

    # --- 3. the regime shifts mid-transfer: path-revised -----------------
    # a route change stretches the wire round trip 0.2 ms -> 40 ms at
    # item 24.  The direct shape is stop-and-wait: it now pays 40 ms
    # per 256 KiB item.  Stay the course vs revise online:
    _, stay_rep, stay_plan = run("direct", shift=True, bypass=False)
    _, auto_rep, auto_plan = run("auto", shift=True, replan_every=16,
                                 bypass=False)
    print(f"[shift] stay-the-course direct: "
          f"{stay_rep.throughput_bytes_per_s / 1e6:7.1f} MB/s")
    print(f"[shift] auto ({auto_rep.replans} replans): "
          f"{auto_rep.throughput_bytes_per_s / 1e6:7.1f} MB/s "
          f"final path={auto_plan.path}")
    print(f"[shift] verdict: {auto_plan.diagnosis.get('path')} "
          f"(+ {auto_plan.diagnosis.get('move')})")
    gain = (auto_rep.throughput_bytes_per_s
            / stay_rep.throughput_bytes_per_s)
    print(f"[shift] revising the path mid-stream recovered x{gain:.1f} "
          f"over riding the wrong shape to the end")


if __name__ == "__main__":
    main()
