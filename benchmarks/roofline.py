"""Roofline table — reads the dry-run artifacts (experiments/dryrun/) and
emits the three-term analysis per (arch x shape x mesh) cell.  This is
the §Roofline deliverable's machine-readable form; EXPERIMENTS.md renders
the same records."""

import json
import os

from .common import emit

DRYRUN_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments",
                          "dryrun")


def run() -> None:
    if not os.path.isdir(DRYRUN_DIR):
        emit("roofline/missing", 0.0, "run `python -m repro.launch.dryrun --all` first")
        return
    records = []
    for name in sorted(os.listdir(DRYRUN_DIR)):
        if not name.endswith(".json"):
            continue
        with open(os.path.join(DRYRUN_DIR, name)) as f:
            records.append(json.load(f))
    n_ok = n_skip = n_err = 0
    for r in records:
        cell = f"{r['arch']}/{r['shape']}/{r['mesh']}"
        if r["status"] == "skipped":
            n_skip += 1
            continue
        if r["status"] != "ok":
            n_err += 1
            emit(f"roofline/{cell}", 0.0, "ERROR " + r.get("error", "?")[:80])
            continue
        n_ok += 1
        rf = r["roofline"]
        step_us = rf["step_time_s"] * 1e6
        emit(f"roofline/{cell}", step_us,
             f"dom={rf['dominant']} compute={rf['t_compute']*1e3:.1f}ms "
             f"mem={rf['t_memory']*1e3:.1f}ms coll={rf['t_collective']*1e3:.1f}ms "
             f"frac={rf['roofline_fraction']:.3f} "
             f"useful={rf.get('useful_compute_fraction') or 0:.2f}")
    emit("roofline/summary", 0.0, f"{n_ok} ok, {n_skip} skipped, {n_err} errors")
