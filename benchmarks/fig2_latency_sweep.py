"""Fig. 2 — latency sweep: OOTB (single-stream, synchronous) vs tuned
(staged, concurrent) path.

The paper shows default host settings collapsing under link latency while
a co-designed host holds throughput flat.  The mechanism being measured
is concurrency: the tuned path keeps several transfers in flight so
per-item link latency overlaps; the OOTB path serializes every item with
the full RTT.  Here the 'WAN hop' is a transform stage that sleeps the
one-way latency per item: the staged configuration runs 4 concurrent
movers through it (zx's concurrency model), the direct configuration is
the synchronous copy loop.
"""

import time

from repro.core.mover import MoverConfig, UnifiedDataMover

from .common import emit, payload_stream

N_ITEMS = 24
ITEM = 1 << 20   # 1 MiB


def _wan(latency_s):
    def hop(item):
        time.sleep(latency_s)      # per-item link latency (tc-netem style)
        return item
    return hop


def run() -> None:
    for latency_ms in (0, 10, 50, 100):
        lat = latency_ms / 1e3
        mover = UnifiedDataMover(MoverConfig(staging_capacity=8,
                                             staging_workers=4,
                                             checksum=False))
        staged = mover.bulk_transfer(
            payload_stream(N_ITEMS, ITEM), lambda x: None,
            transforms=[("wan", _wan(lat))])
        # OOTB: one stream, each item pays the latency serially
        t0 = time.monotonic()
        n = 0
        for item in payload_stream(N_ITEMS, ITEM):
            _wan(lat)(item)
            n += 1
        direct_s = time.monotonic() - t0
        direct_bps = N_ITEMS * ITEM / direct_s if direct_s else 0.0
        ratio = staged.throughput_bytes_per_s / max(direct_bps, 1.0)
        emit(f"fig2/latency_{latency_ms}ms_staged",
             staged.elapsed_s / N_ITEMS * 1e6,
             f"{staged.throughput_bytes_per_s / 1e6:.1f} MB/s")
        emit(f"fig2/latency_{latency_ms}ms_direct",
             direct_s / N_ITEMS * 1e6,
             f"{direct_bps / 1e6:.1f} MB/s staged/direct={ratio:.2f}x")
