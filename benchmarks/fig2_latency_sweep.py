"""Fig. 2 — latency sweep: BDP-sized vs naive window across 0-100 ms RTT.

The paper shows default host settings collapsing under link latency while
a co-designed host holds throughput flat.  The governing mechanism
(§3.1/§3.2) is the transport window: a link admits only ``window``
unACKed bytes, so delivery is ``min(line_rate, window / RTT)`` — a window
sized to the bandwidth-delay product rides the line rate at any latency,
a default-sized window degrades in proportion to RTT.

This suite runs both configurations through the REAL windowed transport
path (``plan_transfer`` window sizing -> ``WindowedStage`` credit/ACK
clocking) on the simulated basin — virtual time, zero jitter, so every
number is a pure function of the script and the suite is CI-gateable:

* the BDP-sized path must deliver >= 90% of the planned line rate at
  every RTT (the paper's "flat" curve),
* the naive path must sit at its window ceiling (<= ~window/RTT) once
  the BDP exceeds the window, degrading ∝ RTT.

Rows carry structured ``window_bytes`` / ``rtt_ms`` / ``throughput_mb_s``
JSON fields so CI tracks the windowed-transport trajectory over time.
"""

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "tests"))

from simbasin import SimHarness  # noqa: E402

from repro.core.basin import DrainageBasin, GBPS, Link, MIB, Tier, \
    TierKind  # noqa: E402
from repro.core.planner import plan_transfer  # noqa: E402

from .common import emit

N_ITEMS = 48
ITEM = 4 * MIB
LINK_GBPS = 100.0
#: the "default host config" stream buffer (§3.2's silent throughput
#: killer): fine at metro RTTs, ~100x under BDP at 100 ms
NAIVE_WINDOW = 8 * MIB
RTTS_MS = (0, 10, 25, 50, 74, 100)

#: acceptance gates (deterministic in virtual time)
BDP_MIN_PLANNED_FRACTION = 0.9
NAIVE_CEILING_SLACK = 1.15


def _basin(rtt_ms: float) -> DrainageBasin:
    return DrainageBasin(
        tiers=[
            Tier("src", TierKind.SOURCE, 200.0 * GBPS, latency_s=1e-5),
            Tier("bb", TierKind.BURST_BUFFER, 200.0 * GBPS, latency_s=1e-5),
            Tier("dst", TierKind.SINK, 200.0 * GBPS, latency_s=1e-5),
        ],
        links=[
            Link("src", "bb", 200.0 * GBPS),
            Link("bb", "dst", LINK_GBPS * GBPS, rtt_s=rtt_ms / 1e3),
        ],
    )


def _run_one(rtt_ms: float, max_window_bytes):
    plan = plan_transfer(_basin(rtt_ms), ITEM, stages=("move",),
                         max_window_bytes=max_window_bytes)
    h = SimHarness()
    link = h.link(bandwidth_bytes_per_s=LINK_GBPS * GBPS,
                  rtt_s=rtt_ms / 1e3)
    src = h.source(h.tier(bandwidth_bytes_per_s=1000.0 * GBPS,
                          wall_pacing_s=0.0), N_ITEMS, ITEM)
    mover = h.mover(plan=plan)
    report = mover.bulk_transfer(iter(src), lambda _: None,
                                 transforms=[("move", h.service(link))])
    return plan, report


def run() -> None:
    failures = []
    for rtt_ms in RTTS_MS:
        bdp_plan, bdp = _run_one(rtt_ms, None)
        naive_plan, naive = _run_one(rtt_ms, NAIVE_WINDOW)
        planned = bdp_plan.planned_bytes_per_s
        win = bdp_plan.hops[0].window_bytes
        emit(f"fig2/rtt_{rtt_ms}ms_bdp_window",
             bdp.elapsed_s / N_ITEMS * 1e6,
             f"{bdp.throughput_bytes_per_s / 1e6:.1f}MB/s "
             f"win={win / 1e6:.0f}MB planned="
             f"{planned / 1e6:.0f}MB/s",
             window_bytes=win, rtt_ms=rtt_ms,
             throughput_mb_s=bdp.throughput_bytes_per_s / 1e6)
        naive_win = naive_plan.hops[0].window_bytes
        emit(f"fig2/rtt_{rtt_ms}ms_naive_window",
             naive.elapsed_s / N_ITEMS * 1e6,
             f"{naive.throughput_bytes_per_s / 1e6:.1f}MB/s "
             f"win={naive_win / 1e6:.0f}MB "
             f"bdp/naive={bdp.throughput_bytes_per_s / max(naive.throughput_bytes_per_s, 1.0):.1f}x",
             window_bytes=naive_win, rtt_ms=rtt_ms,
             throughput_mb_s=naive.throughput_bytes_per_s / 1e6)

        # gate 1: the BDP-sized window holds the planned rate, flat in RTT
        if bdp.throughput_bytes_per_s < BDP_MIN_PLANNED_FRACTION * planned:
            failures.append(
                f"rtt={rtt_ms}ms: BDP window delivered "
                f"{bdp.throughput_bytes_per_s / 1e6:.1f}MB/s < "
                f"{BDP_MIN_PLANNED_FRACTION:.0%} of planned "
                f"{planned / 1e6:.1f}MB/s")
        # gate 2: once BDP exceeds the naive window, delivery is pinned
        # at its ceiling (window/RTT) — degradation ∝ RTT
        rtt_s = rtt_ms / 1e3
        if rtt_s > 0 and LINK_GBPS * GBPS * rtt_s > NAIVE_WINDOW:
            ceiling = NAIVE_WINDOW / rtt_s
            if naive.throughput_bytes_per_s > ceiling * NAIVE_CEILING_SLACK:
                failures.append(
                    f"rtt={rtt_ms}ms: naive window delivered "
                    f"{naive.throughput_bytes_per_s / 1e6:.1f}MB/s above "
                    f"its window/RTT ceiling {ceiling / 1e6:.1f}MB/s")
    if failures:
        raise SystemExit("fig2 windowed-transport gate failed: "
                         + "; ".join(failures))

