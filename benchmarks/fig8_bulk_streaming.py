"""Figs. 8-9 — bulk vs streaming data rates under an arbitered channel.

The paper's testbed result: streaming transfers (data produced while
moving) reach data rates close to bulk transfers (data at rest) across
latencies, because the staged path overlaps production, staging, and
transit.  Reproduced here the way the testbed actually ran it — two
tenants on ONE channel at the same time — and in virtual time: a bulk
tenant and a streaming tenant admit to the same
:class:`~repro.core.fleet.FleetArbiter` under equal-weight QoS and share
a simulated contended link across the latency sweep.  Each tenant runs a
two-stage staged pipeline (produce -> move), so the streaming tenant's
per-item production cost rides a stage of its own and overlaps transit;
its achieved rate stays within a whisker of the bulk tenant's — the
Fig. 8/9 claim, now with conservation enforced on the wire.

Hard gates: at every latency the streaming tenant must reach >= 85% of
the bulk tenant's rate, and both tenants must meet their time-averaged
granted promises (fidelity gap < 0.15).
"""

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "tests"))

from simbasin import SimHarness  # noqa: E402

from repro.core.basin import DrainageBasin, GBPS, Link, MIB, Tier, \
    TierKind  # noqa: E402

from .common import emit

N, ITEM = 256, MIB // 4        # 64 MiB per tenant in 256 KiB items
LINK = 10 * GBPS                # the shared channel both tenants ride


def _basin(rtt_s: float) -> DrainageBasin:
    return DrainageBasin(
        [Tier("src", TierKind.SOURCE, 4 * LINK),
         Tier("buf", TierKind.BURST_BUFFER, 4 * LINK, latency_s=1e-5),
         Tier("dst", TierKind.SINK, 4 * LINK)],
        [Link("src", "buf", 4 * LINK),
         Link("buf", "dst", LINK, rtt_s=rtt_s)])


def _two_tenants(rtt_s: float):
    h = SimHarness()
    arb = h.arbiter(_basin(rtt_s))
    link = h.link(bandwidth_bytes_per_s=LINK, rtt_s=rtt_s,
                  wall_sync=10.0, wall_pacing_s=0.0)
    stages = ("produce", "move")
    adm_bulk = arb.admit("bulk", ITEM, qos="bulk", stages=stages)
    adm_stream = arb.admit("stream", ITEM, qos="bulk", stages=stages)
    assert adm_bulk.status == adm_stream.status == "admitted"

    def tenant(adm, produce, mode, seed):
        src = h.source(h.tier(bandwidth_bytes_per_s=1000 * GBPS,
                              wall_pacing_s=0.0, seed=seed), N, ITEM)
        run_fn = (h.mover().streaming_transfer if mode == "streaming"
                  else h.mover().bulk_transfer)

        def run():
            return run_fn(
                iter(src), lambda _: None,
                transforms=[("produce", h.service(produce)),
                            ("move", h.service(link))], fleet=adm)
        return run

    # bulk: data at rest, the produce stage is a fast local read.
    # streaming: each item pays real production (5 Gb/s + 0.1 ms/item,
    # ~1.7 GB/s raw — above the 625 MB/s grant, but only if the staged
    # overlap actually hides it behind transit)
    at_rest = h.tier(bandwidth_bytes_per_s=1000 * GBPS, wall_pacing_s=0.0)
    producing = h.tier(bandwidth_bytes_per_s=40 * GBPS, latency_s=1e-4,
                       seed=2, wall_pacing_s=0.0)
    return h.run_concurrent(tenant(adm_bulk, at_rest, "bulk", seed=1),
                            tenant(adm_stream, producing, "streaming",
                                   seed=2))


def run() -> None:
    for latency_ms in (10, 50, 100):
        bulk, stream = _two_tenants(latency_ms / 1e3)
        ratio = (stream.throughput_bytes_per_s
                 / max(bulk.throughput_bytes_per_s, 1e-9))
        emit(f"fig8/bulk_{latency_ms}ms", bulk.elapsed_s / N * 1e6,
             f"{bulk.throughput_bytes_per_s / 1e6:.1f} MB/s "
             f"gap={bulk.fidelity_gap:.3f}",
             fidelity_gap=bulk.fidelity_gap)
        emit(f"fig9/streaming_{latency_ms}ms", stream.elapsed_s / N * 1e6,
             f"{stream.throughput_bytes_per_s / 1e6:.1f} MB/s "
             f"({ratio:.2f}x bulk) gap={stream.fidelity_gap:.3f}",
             ratio_vs_bulk=ratio, fidelity_gap=stream.fidelity_gap)
        if ratio < 0.85:
            raise SystemExit(
                f"streaming fell to {ratio:.2f}x bulk at {latency_ms} ms "
                f"(gate: 0.85x) — the staged overlap failed to hide "
                f"production behind transit")
        for tag, rep in (("bulk", bulk), ("streaming", stream)):
            if abs(rep.fidelity_gap) > 0.15:
                raise SystemExit(
                    f"{tag} tenant missed its granted promise at "
                    f"{latency_ms} ms: gap {rep.fidelity_gap:.3f} "
                    f"(gate: |gap| < 0.15)")


if __name__ == "__main__":
    print("name,us_per_call,derived")
    run()
