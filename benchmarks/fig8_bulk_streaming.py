"""Figs. 8-9 — bulk vs streaming sweeps under simulated latency.

The paper's testbed result: streaming transfers (data produced while
moving) reach data rates close to bulk transfers (data at rest) across
latencies — because the staged path overlaps production, staging, and
transit.  Mirrored here with the unified mover's two modes.
"""

from repro.core.mover import MoverConfig, UnifiedDataMover

from .common import emit, payload_stream

N, ITEM = 16, 1 << 20


def run() -> None:
    for latency_ms in (10, 50, 100):
        lat = latency_ms / 1e3
        mover = UnifiedDataMover(MoverConfig(staging_capacity=8,
                                             staging_workers=4,
                                             checksum=False))
        bulk = mover.bulk_transfer(
            payload_stream(N, ITEM, latency_s=lat, jitter_every=4),
            lambda x: None)
        streaming = mover.streaming_transfer(
            payload_stream(N, ITEM, latency_s=lat, jitter_every=1),
            lambda x: None)
        emit(f"fig8/bulk_{latency_ms}ms", bulk.elapsed_s / N * 1e6,
             f"{bulk.throughput_bytes_per_s / 1e6:.1f} MB/s")
        emit(f"fig9/streaming_{latency_ms}ms", streaming.elapsed_s / N * 1e6,
             f"{streaming.throughput_bytes_per_s / 1e6:.1f} MB/s "
             f"({streaming.throughput_bytes_per_s / max(bulk.throughput_bytes_per_s, 1):.2f}x bulk)")
