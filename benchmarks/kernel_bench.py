"""Kernel microbench: interpret-mode wall time is NOT TPU performance —
what matters here is (a) oracle parity and (b) the analytic VMEM/roofline
characteristics emitted as `derived` (block sizes, ideal IO)."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention_bhsd
from repro.kernels.ssd_scan import ssd_scan_bhsd

from .common import emit, time_it


def run() -> None:
    k = jax.random.PRNGKey(0)
    B, H, S, hd = 1, 4, 512, 64
    q = jax.random.normal(k, (B, H, S, hd))
    kv = jax.random.normal(jax.random.fold_in(k, 1), (B, 2, S, hd))
    t, out = time_it(lambda: jax.block_until_ready(
        flash_attention_bhsd(q, kv, kv, causal=True, bq=128, bk=128,
                             interpret=True)))
    r = ref.attention_ref(q, kv, kv, causal=True)
    err = float(np.abs(np.asarray(out) - np.asarray(r)).max())
    flops = 4 * B * H * S * S * hd
    ideal_us = flops / 197e12 * 1e6
    emit("kernel/flash_attention_interp", t * 1e6,
         f"maxerr={err:.1e} tpu_ideal={ideal_us:.1f}us "
         f"vmem_per_step={(3*128*hd*2 + 2*128*128*4)/1024:.0f}KiB")

    Bs, Hs, Ss, P, N = 1, 4, 256, 16, 32
    x = jax.random.normal(k, (Bs, Hs, Ss, P))
    dt = jax.nn.softplus(jax.random.normal(jax.random.fold_in(k, 2),
                                           (Bs, Hs, Ss)))
    A = -jnp.exp(jax.random.normal(jax.random.fold_in(k, 3), (Hs,)) * 0.2)
    Bm = jax.random.normal(jax.random.fold_in(k, 4), (Bs, 1, Ss, N))
    Cm = jax.random.normal(jax.random.fold_in(k, 5), (Bs, 1, Ss, N))
    t, y = time_it(lambda: jax.block_until_ready(
        ssd_scan_bhsd(x, dt, A, Bm, Cm, chunk=64, interpret=True)))
    r = ref.ssd_scan_ref(x, dt, A, Bm, Cm, chunk=64)
    err = float(np.abs(np.asarray(y) - np.asarray(r)).max())
    emit("kernel/ssd_scan_interp", t * 1e6,
         f"maxerr={err:.1e} state_vmem={(P*N*4)/1024:.0f}KiB "
         f"chunk_flops={2*64*64*(N+P)}")

    # lattice digest: the accelerator-placed integrity kernel must be
    # BIT-EXACT against the jnp oracle (uint32 wraparound arithmetic is
    # deterministic on both paths — any mismatch is a kernel bug, not
    # float noise), since the oracle IS the CPU production digest path
    from repro.core.integrity import DIGEST_BLOCK, DIGEST_TILE
    from repro.kernels.digest import block_digest, digest_ref
    nb = 64 * DIGEST_TILE
    panels = jnp.asarray(
        np.random.default_rng(0).integers(0, 1 << 32, (nb, DIGEST_BLOCK),
                                          dtype=np.uint32))
    t, d = time_it(lambda: jax.block_until_ready(
        block_digest(panels, tile=DIGEST_TILE, interpret=True)))
    d_ref = np.asarray(digest_ref(panels))
    exact = bool((np.asarray(d) == d_ref).all())
    emit("kernel/digest_interp", t * 1e6,
         f"exact_parity={exact} blocks={nb} "
         f"bytes={nb * DIGEST_BLOCK * 4 // 1024}KiB")
    if not exact:
        raise SystemExit("kernel/digest_interp: pallas digest diverged "
                         "from the jnp oracle (must be bit-exact)")

    # wire compression roundtrip: the blockwise-int8 stage transform
    # must reconstruct within int8 quantization error
    from repro.core.integrity import compress_transform, decompress_transform
    xs = jax.random.normal(jax.random.fold_in(k, 6), (64, 256)) * 3.0
    comp, decomp = compress_transform(), decompress_transform()
    t, back = time_it(lambda: jax.block_until_ready(decomp(comp(xs))))
    scale = float(jnp.abs(xs).max())
    rerr = float(jnp.abs(back - xs).max()) / max(scale, 1e-9)
    emit("kernel/compress_roundtrip_interp", t * 1e6,
         f"rel_err={rerr:.1e} ratio=4x block=256")
    if rerr > 2.0 / 127.0:
        raise SystemExit(
            f"kernel/compress_roundtrip_interp: reconstruction error "
            f"{rerr:.2e} exceeds int8 quantization bound")
