"""Fault-recovery chaos suite: the survive layer's acceptance claims,
deterministic in virtual time.

Scripted chaos — a mid-transfer link outage ridden out by hop retries
AND one permanent branch death failed over mid-stream — must not cost
correctness or (much) speed:

  fault_recovery/chaos      outage + branch death; completes, stream
                            checksum verified against ground truth
  fault_recovery/naive      the restart-from-zero baseline: fail-hard
                            run to the death, then the whole stream
                            again over the survivor
  fault_recovery/resume     a killed bulk transfer resumed from its
                            durable ledger

Hard gates (exit nonzero):
  * the chaos run completes with the exact ground-truth checksum and a
    ``branch-dead`` verdict on the corpse;
  * failover beats the naive restart-from-zero baseline by >= 1.5x;
  * the ledger resume re-moves < 10% of the already-verified bytes.
"""

import dataclasses
import hashlib
import os
import sys
import tempfile

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "tests"))

from simbasin import SimHarness  # noqa: E402

from repro.core.basin import DrainageBasin, GBPS, Link, MIB, Tier, \
    TierKind  # noqa: E402
from repro.core.mover import MoverConfig, UnifiedDataMover  # noqa: E402
from repro.core.planner import plan_transfer  # noqa: E402
from repro.core.resume import TransferLedger  # noqa: E402

from .common import emit

N_ITEMS = 240
ITEM_BYTES = 1 * MIB
#: path-b's served-item index of its permanent death — late enough that
#: restarting from zero is clearly worse than carrying on, early enough
#: that the survivor still has real work left
DIE_AT = 90
#: path-a's link blacks out for this window of virtual time
OUTAGE_AT_S = 0.01
OUTAGE_S = 0.025
#: the chaos posture's backoff base: two retries' cumulative backoff
#: (>= base * (1 + 2) = 0.03 s) always clears the outage window, while
#: the corpse's final backoff tail stays small against the stream's
#: virtual work time
BACKOFF_S = 0.01


def _chaos_retry(plan):
    """Re-price the planned hops' backoff base for the scripted outage
    (the planner's default is sized for WAN-scale flaps)."""
    def swap(h):
        return dataclasses.replace(h, backoff_base_s=BACKOFF_S)
    plan.hops[:] = [swap(h) for h in plan.hops]
    plan.branches[:] = [
        dataclasses.replace(b, hops=tuple(swap(h) for h in b.hops))
        for b in plan.branches]
    return plan


def _tiers():
    return [
        Tier("src", TierKind.SOURCE, 40.0 * GBPS, latency_s=1e-5),
        Tier("staging", TierKind.BURST_BUFFER, 40.0 * GBPS, latency_s=1e-5),
        Tier("path-a", TierKind.SINK, 10.0 * GBPS),
        Tier("path-b", TierKind.SINK, 10.0 * GBPS),
    ]


def _fanout_basin() -> DrainageBasin:
    src, staging, a, b = _tiers()
    return DrainageBasin([src, staging, a, b],
                         [Link("src", "staging"), Link("staging", "path-a"),
                          Link("staging", "path-b")])


def _survivor_basin() -> DrainageBasin:
    """What a naive restart has left: the one surviving path."""
    src, staging, a, _ = _tiers()
    return DrainageBasin([src, staging, a])


def _payloads():
    # distinct payloads: identical items XOR their SHA-256s away in
    # pairs, which would blind the checksum to a lost pair
    return [bytes([i % 251 + 1]) * ITEM_BYTES for i in range(N_ITEMS)]


def _truth(payloads) -> str:
    acc = bytearray(32)
    for p in payloads:
        d = hashlib.sha256(p).digest()
        for i in range(32):
            acc[i] ^= d[i]
    return bytes(acc).hex()


def _chaos_scenario(h: SimHarness):
    """Scripted truth: path-a's link blacks out mid-stream (transient —
    retries ride it out), path-b's element dies permanently."""
    link_a = h.link(bandwidth_bytes_per_s=10.0 * GBPS, rtt_s=1e-4,
                    wall_pacing_s=0.0)
    link_a.outage(OUTAGE_AT_S, OUTAGE_S)
    tier_b = h.branch_tier("path-b", bandwidth_bytes_per_s=10.0 * GBPS,
                           wall_pacing_s=0.0)
    tier_b.fail_at(DIE_AT, permanent=True)
    return link_a, tier_b


def _run_chaos():
    h = SimHarness()
    link_a, tier_b = _chaos_scenario(h)
    plan = _chaos_retry(
        plan_transfer(_fanout_basin(), ITEM_BYTES, stages=("deliver",)))
    got = []
    mover = h.mover(plan=plan, checksum=True)
    rep = mover.parallel_transfer(
        iter(_payloads()), got.append,
        transforms={"path-a": [("deliver", h.service(link_a))],
                    "path-b": [("deliver", h.service(tier_b))]},
        mode="split", checksum=True)
    return rep, got, mover, link_a


def _run_naive():
    """Restart-from-zero: the fail-hard run costs its virtual time up to
    the death, then the whole stream moves again over the survivor."""
    h = SimHarness()
    _, tier_b = _chaos_scenario(h)
    tier_a = h.branch_tier("path-a", bandwidth_bytes_per_s=10.0 * GBPS,
                           wall_pacing_s=0.0)
    plan = _chaos_retry(
        plan_transfer(_fanout_basin(), ITEM_BYTES, stages=("deliver",)))
    try:
        h.mover(plan=plan).parallel_transfer(
            iter(_payloads()), lambda _: None,
            transforms={"path-a": [("deliver", h.service(tier_a))],
                        "path-b": [("deliver", h.service(tier_b))]},
            mode="split", drain_per_segment=True)     # the fail-hard path
        raise SystemExit("fault_recovery: the fail-hard baseline run was "
                         "expected to die on path-b's permanent fault")
    except RuntimeError:
        wasted_s = h.clock.now()

    h2 = SimHarness()
    tier_a2 = h2.branch_tier("path-a", bandwidth_bytes_per_s=10.0 * GBPS,
                             wall_pacing_s=0.0)
    plan2 = _chaos_retry(plan_transfer(_survivor_basin(), ITEM_BYTES,
                                        stages=("deliver",)))
    rep = h2.mover(plan=plan2).bulk_transfer(
        iter(_payloads()), lambda _: None,
        transforms=[("deliver", h2.service(tier_a2))])
    return wasted_s + rep.elapsed_s, wasted_s


def _run_resume():
    payloads = _payloads()
    with tempfile.TemporaryDirectory() as tmp:
        path = os.path.join(tmp, "ledger.jsonl")
        led = TransferLedger(path)
        count = {"n": 0}

        def power_cut(item):
            if count["n"] >= 160:
                raise RuntimeError("power cut")
            count["n"] += 1

        try:
            UnifiedDataMover(MoverConfig(checksum=True)).bulk_transfer(
                iter(payloads), power_cut, resume=led)
            raise SystemExit("fault_recovery: the first ledger run was "
                             "expected to be killed mid-stream")
        except RuntimeError:
            pass
        led.close()
        verified = TransferLedger(path).bytes_recorded

        led2 = TransferLedger(path)
        moved = []
        rep = UnifiedDataMover(MoverConfig(checksum=True)).bulk_transfer(
            iter(payloads), moved.append, resume=led2)
        led2.close()
        removed_verified = verified - led2.skipped_bytes
        return rep, verified, removed_verified, len(moved)


def run() -> None:
    payloads = _payloads()
    truth = _truth(payloads)

    rep, got, mover, link_a = _run_chaos()
    diag = mover.last_plan.diagnosis
    emit("fault_recovery/chaos", rep.elapsed_s * 1e6,
         f"{rep.throughput_bytes_per_s / 1e6:.1f}MB/s "
         f"items={len(got)}/{N_ITEMS} outage_faults={link_a.faults} "
         f"verdict={diag.get('path-b', '?')}")
    if (sorted(got) != sorted(payloads) or rep.checksum != truth
            or link_a.faults < 1
            or not diag.get("path-b", "").startswith("branch-dead")):
        raise SystemExit(
            f"fault_recovery: chaos run broke correctness — "
            f"items={len(got)}/{N_ITEMS} checksum_ok="
            f"{rep.checksum == truth} outage_faults={link_a.faults} "
            f"diagnosis={diag}")

    naive_s, wasted_s = _run_naive()
    speedup = naive_s / max(rep.elapsed_s, 1e-12)
    emit("fault_recovery/naive", naive_s * 1e6,
         f"restart-from-zero baseline (wasted {wasted_s:.2f}s) "
         f"x{speedup:.2f} slower than failover")
    if speedup < 1.5:
        raise SystemExit(
            f"fault_recovery: failover ({rep.elapsed_s:.3f}s) failed to "
            f"beat the naive restart baseline ({naive_s:.3f}s) by 1.5x "
            f"(got x{speedup:.2f})")

    rep2, verified, removed_verified, moved = _run_resume()
    frac = removed_verified / max(verified, 1)
    emit("fault_recovery/resume", rep2.elapsed_s * 1e6,
         f"verified={verified / MIB:.0f}MiB re-moved="
         f"{removed_verified / MIB:.1f}MiB ({frac:.1%}) "
         f"remainder={moved} items")
    if rep2.checksum != truth or frac >= 0.10:
        raise SystemExit(
            f"fault_recovery: ledger resume re-moved {frac:.1%} of the "
            f"already-verified bytes (gate < 10%) or broke the checksum "
            f"(ok={rep2.checksum == truth})")


if __name__ == "__main__":
    print("name,us_per_call,derived")
    run()
