"""Shared benchmark helpers: synthetic payloads, latency injection, CSV."""

from __future__ import annotations

import sys
import time
from typing import Any, Callable, Iterable, Iterator

import numpy as np


def payload_stream(n_items: int, item_bytes: int, *, latency_s: float = 0.0,
                   jitter_every: int = 1, seed: int = 0
                   ) -> Iterator[np.ndarray]:
    """Items of `item_bytes`, with optional per-item source latency
    (the tc-netem analogue: injected delay on the producing side)."""
    rng = np.random.default_rng(seed)
    base = rng.integers(0, 255, max(item_bytes, 1), dtype=np.uint8)
    for i in range(n_items):
        if latency_s and i % jitter_every == 0:
            time.sleep(latency_s)
        yield base


#: machine-readable result rows accumulated by ``emit`` — the harness
#: (benchmarks/run.py ``--json``) snapshots this per suite into
#: ``BENCH_<suite>.json`` so the perf trajectory is tracked over time
RESULTS: list[dict] = []


def emit(name: str, us_per_call: float, derived: str = "",
         **extra: Any) -> None:
    """CSV row: name,us_per_call,derived.  ``extra`` keyword fields ride
    along in the JSON result row only (structured throughput/speedup/
    replan-count numbers that would be lossy as a derived string)."""
    print(f"{name},{us_per_call:.2f},{derived}")
    sys.stdout.flush()
    row: dict[str, Any] = {"name": name, "us_per_call": us_per_call,
                           "derived": derived}
    row.update(extra)
    RESULTS.append(row)


def time_it(fn: Callable[[], Any], *, repeats: int = 3) -> tuple[float, Any]:
    best = float("inf")
    out = None
    for _ in range(repeats):
        t0 = time.monotonic()
        out = fn()
        best = min(best, time.monotonic() - t0)
    return best, out
