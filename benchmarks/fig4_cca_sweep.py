"""Fig. 4 — CCA/schedule sweep: flat throughput KiB -> GiB on the
windowed path.

The paper's figure shows end-to-end throughput insensitive to the
congestion-control/scheduling discipline once the host is co-designed
with the path: the governing resource is the transport window (sized to
the link's BDP), not the staging schedule.  Earlier revisions of this
suite measured wall-clock staging overhead on a host-local path, which
says nothing about the claim — the window never entered the picture.

This re-port runs the REAL windowed transport (``plan_transfer`` window
sizing -> ``WindowedStage`` credit/ACK clocking) over the scripted
100 Gbps x 74 ms link in virtual time.  Each point plans one item size
(64 KiB up to 1 GiB — the GiB points ride a constant-size payload proxy
so the sweep never allocates gigabyte buffers) and executes it under
three staging schedules styled after CCA temperaments: a shallow
conservative pool ("reno-like"), a mid-depth pool ("cubic-like"), and a
deep aggressive pool ("bbr-like").

Gates (deterministic in virtual time):

* every (size, schedule) point delivers >= 90% of the planned line rate
  — KiB items and GiB items alike (the coarse-admission window guard in
  the planner is what keeps the GiB end flat);
* across schedules at a fixed size, the throughput spread stays within
  10% — the schedule is immaterial, the window governs.

Rows carry structured ``item_bytes`` / ``schedule`` / ``throughput_mb_s``
/ ``retransmits`` JSON fields so CI tracks the sweep's trajectory.
"""

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "tests"))

from simbasin import SimHarness  # noqa: E402

from repro.core.basin import DrainageBasin, GBPS, GIB, Link, MIB, Tier, \
    TierKind  # noqa: E402
from repro.core.planner import plan_transfer  # noqa: E402

from .common import emit

KIB = 1024
LINK_GBPS = 100.0
RTT_S = 0.074

#: (item size, items to stream) — sized so every point moves enough
#: bytes that startup transients are noise, without wall-clock cost
SIZES = (
    (64 * KIB, 512),
    (4 * MIB, 128),
    (64 * MIB, 48),
    (1 * GIB, 12),
)

#: staging-schedule temperaments (capacity slots, worker pool) — the
#: knob the figure shows NOT to matter once the window is BDP-governed
SCHEDULES = (
    ("reno-like", 8, 2),
    ("cubic-like", 16, 4),
    ("bbr-like", 32, 8),
)

#: acceptance gates
MIN_PLANNED_FRACTION = 0.9
MAX_SCHEDULE_SPREAD = 0.10


class _Payload:
    """A constant-size stand-in for a staged item: the data plane sizes
    items via ``nbytes`` (then ``len``), so the GiB sweep points never
    touch gigabytes of host memory — only the virtual clock pays."""

    __slots__ = ("nbytes",)

    def __init__(self, nbytes: int) -> None:
        self.nbytes = nbytes

    def __len__(self) -> int:
        return self.nbytes


def _basin() -> DrainageBasin:
    return DrainageBasin(
        tiers=[
            Tier("src", TierKind.SOURCE, 200.0 * GBPS, latency_s=1e-5),
            Tier("bb", TierKind.BURST_BUFFER, 200.0 * GBPS, latency_s=1e-5),
            Tier("dst", TierKind.SINK, 200.0 * GBPS, latency_s=1e-5),
        ],
        links=[
            Link("src", "bb", 200.0 * GBPS),
            Link("bb", "dst", LINK_GBPS * GBPS, rtt_s=RTT_S),
        ],
    )


def _stream(feeder, n_items: int, item_bytes: int):
    for _ in range(n_items):
        feeder.serve(item_bytes)
        yield _Payload(item_bytes)


def _run_one(item_bytes: int, n_items: int, capacity: int, workers: int):
    plan = plan_transfer(_basin(), item_bytes, stages=("move",))
    h = SimHarness()
    link = h.link(bandwidth_bytes_per_s=LINK_GBPS * GBPS, rtt_s=RTT_S)
    feeder = h.tier(bandwidth_bytes_per_s=1000.0 * GBPS, wall_pacing_s=0.0)
    mover = h.mover(plan=plan)
    report = mover.bulk_transfer(
        _stream(feeder, n_items, item_bytes), lambda _: None,
        transforms=[("move", h.service(link))],
        capacity=capacity, workers=workers)
    move = report.stage_reports[0]
    return plan, report, move.retransmits


def run() -> None:
    failures = []
    for item_bytes, n_items in SIZES:
        size_label = (f"{item_bytes // MIB}MiB" if item_bytes >= MIB
                      else f"{item_bytes // KIB}KiB")
        points = {}
        for sched, capacity, workers in SCHEDULES:
            plan, report, retransmits = _run_one(
                item_bytes, n_items, capacity, workers)
            planned = plan.planned_bytes_per_s
            win = plan.hops[0].window_bytes
            points[sched] = report.throughput_bytes_per_s
            emit(f"fig4/{size_label}_{sched}",
                 report.elapsed_s / n_items * 1e6,
                 f"{report.throughput_bytes_per_s / 1e6:.0f}MB/s "
                 f"win={win / 1e6:.0f}MB planned={planned / 1e6:.0f}MB/s",
                 item_bytes=item_bytes, schedule=sched,
                 throughput_mb_s=report.throughput_bytes_per_s / 1e6,
                 retransmits=retransmits)
            # gate 1: flat against the plan — KiB and GiB alike
            if (report.throughput_bytes_per_s
                    < MIN_PLANNED_FRACTION * planned):
                failures.append(
                    f"{size_label}/{sched}: delivered "
                    f"{report.throughput_bytes_per_s / 1e6:.0f}MB/s < "
                    f"{MIN_PLANNED_FRACTION:.0%} of planned "
                    f"{planned / 1e6:.0f}MB/s")
        # gate 2: the schedule knob is immaterial at a fixed size
        spread = (max(points.values()) - min(points.values())) \
            / max(points.values())
        if spread > MAX_SCHEDULE_SPREAD:
            failures.append(
                f"{size_label}: schedule spread {spread:.1%} > "
                f"{MAX_SCHEDULE_SPREAD:.0%} ("
                + ", ".join(f"{s}={v / 1e6:.0f}MB/s"
                            for s, v in points.items()) + ")")
    if failures:
        raise SystemExit("fig4 schedule-insensitivity gate failed: "
                         + "; ".join(failures))
