"""Figs. 4-6 — transfer sweeps across item sizes x transport schedules.

The paper's finding: with a co-designed path, the CCA choice (BBR vs
CUBIC vs Reno) is immaterial — throughput is flat across file sizes from
KiB to TiB.  The ICI-era analogue of the 'transport algorithm' knob is
the staging schedule (worker count / buffer depth).  A balanced staged
path should show the same insensitivity: varying the schedule barely
moves throughput, while item size only matters at the tiny end
(per-item latency amortization, §3.4).
"""

from repro.core.mover import MoverConfig, UnifiedDataMover

from .common import emit, payload_stream

TOTAL = 24 << 20   # 24 MiB per sweep point
SCHEDULES = {"reno-like": (2, 1), "cubic-like": (4, 2), "bbr-like": (8, 4)}


def run() -> None:
    for size_kib in (1, 16, 256, 4096):
        item = size_kib << 10
        n = max(4, TOTAL // item)
        rates = {}
        for sched, (cap, workers) in SCHEDULES.items():
            mover = UnifiedDataMover(MoverConfig(staging_capacity=cap,
                                                 staging_workers=workers,
                                                 checksum=False))
            rep = mover.bulk_transfer(payload_stream(n, item, latency_s=2e-4),
                                      lambda x: None)
            rates[sched] = rep.throughput_bytes_per_s
            emit(f"fig4/item_{size_kib}KiB_{sched}",
                 rep.elapsed_s / n * 1e6,
                 f"{rep.throughput_bytes_per_s / 1e6:.1f} MB/s")
        spread = (max(rates.values()) - min(rates.values())) / max(rates.values())
        emit(f"fig4/item_{size_kib}KiB_schedule_spread", 0.0,
             f"{spread:.2%} (co-designed path is schedule-insensitive)")
