"""Fig. 10 — storage must exceed the target rate: end-to-end throughput
tracks min(source, path) and extra link bandwidth buys nothing once the
source is the bottleneck (paradigm §3.4)."""

import time

from repro.core.basin import DrainageBasin, GBPS, Tier, TierKind
from repro.core.mover import MoverConfig, UnifiedDataMover

from .common import emit, payload_stream

N, ITEM = 16, 1 << 20


def run() -> None:
    # analytic form (the paper figure): sweep storage bw against a fixed link
    for storage_gbps in (10, 40, 100, 200):
        basin = DrainageBasin([
            Tier("storage", TierKind.SOURCE, storage_gbps * GBPS),
            Tier("bb", TierKind.BURST_BUFFER, 200 * GBPS),
            Tier("link", TierKind.CHANNEL, 100 * GBPS),
        ])
        rep = basin.bottleneck()
        emit(f"fig10/storage_{storage_gbps}gbps_link_100gbps", 0.0,
             f"achieved={rep.achievable_bytes_per_s / GBPS:.0f} Gbps "
             f"bottleneck={rep.element}")

    # measured form: throttle the source, not the link
    for src_rate_mbps in (50, 200, 800):
        per_item = ITEM / (src_rate_mbps * 1e6 / 8)
        mover = UnifiedDataMover(MoverConfig(staging_capacity=8,
                                             staging_workers=2,
                                             checksum=False))
        rep = mover.bulk_transfer(
            payload_stream(N, ITEM, latency_s=per_item), lambda x: None)
        emit(f"fig10/measured_source_{src_rate_mbps}mbps",
             rep.elapsed_s / N * 1e6,
             f"{rep.throughput_bytes_per_s * 8 / 1e6:.0f} Mbps achieved "
             f"(source-bound)")
