"""Fig. 10 — storage must exceed the target rate: end-to-end throughput
tracks min(source, path) and extra link bandwidth buys nothing once the
source is the bottleneck (paradigm §3.4).

Both forms are deterministic: the analytic sweep is pure basin algebra,
and the measured form runs a *planned* transfer on the simulated-basin
harness — a throttled source tier feeding a fast link in virtual time,
so the achieved rate is a function of the script, not host load.  The
gate pins the paper's claim both ways: achieved tracks the analytic
``min(source, link)`` within tolerance, and doubling the link when the
source is the bottleneck buys nothing.
"""

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "tests"))

from simbasin import SimHarness  # noqa: E402

from repro.core.basin import DrainageBasin, GBPS, Tier, TierKind  # noqa: E402
from repro.core.planner import plan_transfer  # noqa: E402

from .common import emit

N, ITEM = 64, 1 << 20


def _measured(storage_gbps: float, link_gbps: float) -> float:
    """Planned transfer, virtual time: achieved bytes/s of a stream that
    is served by a ``storage_gbps`` source and moved over a
    ``link_gbps`` channel."""
    h = SimHarness()
    basin = DrainageBasin([
        Tier("storage", TierKind.SOURCE, storage_gbps * GBPS,
             latency_s=1e-5),
        Tier("bb", TierKind.BURST_BUFFER, 200 * GBPS, latency_s=1e-5),
        Tier("link", TierKind.SINK, link_gbps * GBPS),
    ])
    plan = plan_transfer(basin, ITEM, stages=("move",))
    src = h.source(h.tier(bandwidth_bytes_per_s=storage_gbps * GBPS,
                          wall_pacing_s=0.0), N, ITEM)
    link = h.tier(bandwidth_bytes_per_s=link_gbps * GBPS,
                  wall_pacing_s=0.0)
    rep = h.mover(plan=plan).bulk_transfer(
        iter(src), lambda _: None,
        transforms=[("move", h.service(link))])
    return rep.throughput_bytes_per_s


def run() -> None:
    # analytic form (the paper figure): sweep storage bw against a fixed link
    for storage_gbps in (10, 40, 100, 200):
        basin = DrainageBasin([
            Tier("storage", TierKind.SOURCE, storage_gbps * GBPS),
            Tier("bb", TierKind.BURST_BUFFER, 200 * GBPS),
            Tier("link", TierKind.CHANNEL, 100 * GBPS),
        ])
        rep = basin.bottleneck()
        emit(f"fig10/storage_{storage_gbps}gbps_link_100gbps", 0.0,
             f"achieved={rep.achievable_bytes_per_s / GBPS:.0f} Gbps "
             f"bottleneck={rep.element}")

    # measured form, virtual time: the planned path achieves min(source,
    # link) — gate each sweep point against the analytic roof
    achieved = {}
    for storage_gbps in (10, 40, 100):
        bps = _measured(storage_gbps, 100.0)
        achieved[storage_gbps] = bps
        roof = min(storage_gbps, 100.0) * GBPS
        emit(f"fig10/measured_storage_{storage_gbps}gbps",
             N * ITEM / bps * 1e6 / N,
             f"{bps * 8 / 1e9:.1f} Gbps achieved (roof "
             f"{roof * 8 / 1e9:.0f} Gbps)")
        if not (0.5 * roof <= bps <= 1.2 * roof):
            raise SystemExit(
                f"fig10: measured {bps:.3g} B/s strayed from the "
                f"min(source, link) roof {roof:.3g} B/s")

    # the paper's punchline: with a 10 Gbps source, doubling the link
    # from 100 to 200 Gbps buys nothing
    wider = _measured(10.0, 200.0)
    emit("fig10/measured_storage_10gbps_link_200gbps",
         N * ITEM / wider * 1e6 / N,
         f"{wider * 8 / 1e9:.1f} Gbps achieved (source-bound)")
    gain = wider / max(achieved[10], 1e-9)
    if gain > 1.15:
        raise SystemExit(
            f"fig10: doubling the link moved a source-bound transfer "
            f"by x{gain:.2f} — the storage-bound claim broke")


if __name__ == "__main__":
    print("name,us_per_call,derived")
    run()
