"""Fig. 11 — the zx-vs-aws-cli contrast: a co-designed staged path vs the
abstracted synchronous path, both with integrity on (the paper's transfer
carried full checksumming).  The staged path overlaps hash + staging +
delivery; the direct path serializes them — the 'cloud abstraction
penalty' (§3.6: 30-50%)."""

from repro.core.mover import MoverConfig, UnifiedDataMover

from .common import emit, payload_stream

N, ITEM = 24, 1 << 20


def run() -> None:
    mover = UnifiedDataMover(MoverConfig(staging_capacity=8,
                                         staging_workers=4, checksum=True))
    staged = mover.bulk_transfer(
        payload_stream(N, ITEM, latency_s=5e-3), lambda x: None)
    direct = mover.direct_transfer(
        payload_stream(N, ITEM, latency_s=5e-3), lambda x: None)
    assert staged.checksum == direct.checksum, "integrity mismatch"
    penalty = 1.0 - (direct.throughput_bytes_per_s
                     / staged.throughput_bytes_per_s)
    emit("fig11/staged_zx_like", staged.elapsed_s / N * 1e6,
         f"{staged.throughput_bytes_per_s / 1e6:.1f} MB/s (checksummed)")
    emit("fig11/direct_cli_like", direct.elapsed_s / N * 1e6,
         f"{direct.throughput_bytes_per_s / 1e6:.1f} MB/s "
         f"abstraction_penalty={penalty:.1%}")
