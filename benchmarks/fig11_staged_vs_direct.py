"""Fig. 11 re-ported — stream-vs-stage as a *planned* decision.

The seed form of this benchmark measured the staged-vs-direct contrast
wall-clock and left the choice to the caller.  This form closes the
loop on §3.6: ``plan_transfer(path="auto")`` prices every execution
shape (direct cut-through, staged streams, windowed-staged, compressed
wire) against the basin and the run EXECUTES the chosen shape on the
simulated-basin harness in virtual time — deterministic, a pure
function of the script.

Two hard gates:

* **sweep** — at every (basin regime, item size) point, the auto path
  achieves >= 0.95x the best forced path, and somewhere in the sweep
  the worst forced path loses by >= 1.5x (the decision is non-trivial:
  picking wrong costs integer factors, exactly what the paper measures);
* **regime shift** — a scripted mid-transfer route change (0.2 ms ->
  40 ms) flips a correct direct choice into a stop-and-wait crawl; the
  ``path-revised`` verdict switches the live transfer to
  windowed-staged at a revision boundary and the post-switch run beats
  the stay-the-course baseline >= 1.3x.

Execution mapping: a plan whose shape is ``direct`` runs cut-through —
its staging hop does NOT serve the burst-buffer tier (that copy is
what the bypass skips); a ``compressed`` plan serves the wire with
``item_bytes / ratio`` (the int8 transform's bytes actually crossing
the bottleneck link).  Every other shape stages through the buffer at
full wire bytes.
"""

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "tests"))

from simbasin import SimHarness  # noqa: E402

from repro.core.basin import DrainageBasin, Link, Tier, TierKind  # noqa: E402
from repro.core.planner import COMPRESS_WIRE_RATIO, plan_transfer  # noqa: E402

from .common import emit

KIB = 1 << 10
MIB = 1 << 20

#: modeled-vs-measured tolerance for the auto gate: the sim executes
#: the shapes it prices, so auto may only lose to a forced shape by
#: measurement noise, never by a mispriced model
AUTO_TOLERANCE = 0.95
WORST_LOSES_BY = 1.5


def slow_bb_basin() -> DrainageBasin:
    """Fast endpoints, slow staging tier, short wire — direct country."""
    return DrainageBasin(
        [Tier("src", TierKind.SOURCE, 8e9),
         Tier("bb", TierKind.BURST_BUFFER, 0.15e9, latency_s=50e-6),
         Tier("dst", TierKind.SINK, 8e9)],
        [Link("src", "bb", 5e9),
         Link("bb", "dst", 5e9, rtt_s=0.2e-3)])


def long_fat_basin() -> DrainageBasin:
    """Fast staging, long-round-trip wire — windowed country."""
    return DrainageBasin(
        [Tier("src", TierKind.SOURCE, 8e9),
         Tier("bb", TierKind.BURST_BUFFER, 6e9, latency_s=10e-6),
         Tier("dst", TierKind.SINK, 8e9)],
        [Link("src", "bb", 5e9),
         Link("bb", "dst", 12e9, rtt_s=20e-3)])


def wire_bound_basin() -> DrainageBasin:
    """Everything fast except the wire — compressed country."""
    return DrainageBasin(
        [Tier("src", TierKind.SOURCE, 8e9),
         Tier("bb", TierKind.BURST_BUFFER, 6e9, latency_s=10e-6),
         Tier("dst", TierKind.SINK, 8e9)],
        [Link("src", "bb", 5e9),
         Link("bb", "dst", 0.6e9, rtt_s=1e-3)])


def _measured(make_basin, item_bytes: int, path: str, *,
              compressible: bool = False, n_items: int = 16) -> tuple:
    """Plan with ``path`` and execute the planned shape in virtual
    time; returns (achieved bytes/s, executed path label)."""
    basin = make_basin()
    plan = plan_transfer(basin, item_bytes, stages=("stage", "move"),
                         path=path, compressible=compressible)
    h = SimHarness()
    bb_bw = next(t.bandwidth_bytes_per_s for t in basin.tiers
                 if t.kind is TierKind.BURST_BUFFER)
    bb = h.tier(bandwidth_bytes_per_s=bb_bw, wall_pacing_s=0.0)
    wire = next(l for l in basin.links if l.dst == "dst")
    link = h.link(bandwidth_bytes_per_s=wire.bandwidth_bytes_per_s,
                  rtt_s=wire.rtt_s, wall_pacing_s=0.0)

    if plan.path == "direct":
        # cut-through: the staging copy never happens
        def stage_tf(item):
            return item
    else:
        stage_tf = h.service(bb)
    ratio = COMPRESS_WIRE_RATIO if plan.path == "compressed" else 1.0

    def move_tf(item, _link=link, _ratio=ratio):
        _link.serve(max(1, int(len(item) / _ratio)))
        return item
    move_tf.channel = link

    src = h.source(h.tier(bandwidth_bytes_per_s=8e9, wall_pacing_s=0.0),
                   n_items, item_bytes)
    rep = h.mover(plan=plan).bulk_transfer(
        iter(src), lambda _: None,
        transforms=[("stage", stage_tf), ("move", move_tf)])
    return rep.throughput_bytes_per_s, plan.path


def _sweep() -> None:
    # small-item points move enough items to fill the window and
    # amortize the pipeline ramp — a 256 KiB point on a 240 MB-BDP
    # pipe measured over 16 items would be all transient
    points = [
        ("slow_bb_64k", slow_bb_basin, 64 * KIB, False, 256),
        ("slow_bb_64m", slow_bb_basin, 64 * MIB, False, 16),
        ("long_fat_256k", long_fat_basin, 256 * KIB, False, 256),
        ("wire_bound_4m", wire_bound_basin, 4 * MIB, True, 48),
    ]
    nontrivial = False
    for label, make_basin, item, compressible, n_items in points:
        forced = {}
        shapes = ["direct", "staged", "windowed-staged"]
        if compressible:
            shapes.append("compressed")
        for shape in shapes:
            bps, _ = _measured(make_basin, item, shape,
                               compressible=compressible,
                               n_items=n_items)
            forced[shape] = bps
            emit(f"fig11/{label}_{shape}", item / bps * 1e6,
                 f"{bps / 1e6:.1f} MB/s forced",
                 path=shape, item_bytes=item,
                 throughput_mb_s=round(bps / 1e6, 1))
        auto_bps, chosen = _measured(make_basin, item, "auto",
                                     compressible=compressible,
                                     n_items=n_items)
        best = max(forced.values())
        worst = min(forced.values())
        emit(f"fig11/{label}_auto", item / auto_bps * 1e6,
             f"{auto_bps / 1e6:.1f} MB/s auto->{chosen} "
             f"(best forced {best / 1e6:.1f}, worst {worst / 1e6:.1f})",
             path=chosen, item_bytes=item,
             throughput_mb_s=round(auto_bps / 1e6, 1))
        if auto_bps < AUTO_TOLERANCE * best:
            raise SystemExit(
                f"fig11: auto chose {chosen} at {label} and achieved "
                f"{auto_bps / 1e6:.1f} MB/s < {AUTO_TOLERANCE:.2f}x the "
                f"best forced path ({best / 1e6:.1f} MB/s)")
        if worst * WORST_LOSES_BY <= best:
            nontrivial = True
    if not nontrivial:
        raise SystemExit(
            "fig11: no sweep point separates the forced paths by "
            f">= {WORST_LOSES_BY}x — the decision the engine automates "
            "is trivial and the sweep no longer exercises it")

    # KiB->GiB endpoint, model-priced (a GiB item's staging residency
    # would dwarf the harness; the decision itself is the figure)
    plan = plan_transfer(slow_bb_basin(), 1 << 30,
                         stages=("stage", "move"), path="auto")
    emit("fig11/slow_bb_1g_model", 0.0,
         f"auto->{plan.path} " + " ".join(
             f"{k}={v / 1e6:.0f}MB/s"
             for k, v in sorted(plan.path_scores.items())),
         path=plan.path, item_bytes=1 << 30,
         throughput_mb_s=round(plan.path_scores[plan.path] / 1e6, 1))


def _regime_shift(policy: str, replan_every: int) -> tuple:
    """One 96-item transfer over the slow-bb basin whose wire round
    trip is re-routed 0.2 ms -> 40 ms at the 24th served item.  Both
    runs execute identical simulated services (staging copy included)
    so the only difference is what the planner does about the shift."""
    item = 256 * KIB
    plan = plan_transfer(slow_bb_basin(), item, stages=("stage", "move"),
                         path=policy)
    h = SimHarness()
    bb = h.tier(bandwidth_bytes_per_s=0.15e9, wall_pacing_s=0.0)
    link = h.link(bandwidth_bytes_per_s=5e9, rtt_s=0.2e-3,
                  wall_pacing_s=0.0)
    link.shift_at(24, rtt_s=40e-3)
    src = h.source(h.tier(bandwidth_bytes_per_s=8e9, wall_pacing_s=0.0),
                   96, item)
    mover = h.mover(plan=plan)
    rep = mover.bulk_transfer(
        iter(src), lambda _: None,
        transforms=[("stage", h.service(bb)), ("move", h.service(link))],
        replan_every_items=replan_every)
    return rep, mover.last_plan


def _shift_gate() -> None:
    stay, stay_plan = _regime_shift("direct", 0)
    auto, auto_plan = _regime_shift("auto", 16)
    emit("fig11/shift_stay_direct", stay.elapsed_s / stay.items * 1e6,
         f"{stay.throughput_bytes_per_s / 1e6:.1f} MB/s stop-and-wait "
         "rode the 40 ms route to the end",
         path=stay_plan.path, item_bytes=256 * KIB,
         throughput_mb_s=round(stay.throughput_bytes_per_s / 1e6, 1))
    emit("fig11/shift_auto_revised", auto.elapsed_s / auto.items * 1e6,
         f"{auto.throughput_bytes_per_s / 1e6:.1f} MB/s "
         f"path={auto_plan.path} replans={auto.replans} "
         f"verdict={auto_plan.diagnosis.get('path', '-')}",
         path=auto_plan.path, item_bytes=256 * KIB,
         throughput_mb_s=round(auto.throughput_bytes_per_s / 1e6, 1))
    if auto_plan.path != "windowed-staged" \
            or not auto_plan.diagnosis.get("path", "").startswith(
                "path-revised(direct->"):
        raise SystemExit(
            f"fig11: the scripted regime shift did not produce a "
            f"path-revised switch (final path {auto_plan.path!r}, "
            f"diagnosis {auto_plan.diagnosis})")
    gain = (auto.throughput_bytes_per_s
            / max(stay.throughput_bytes_per_s, 1e-9))
    if gain < 1.3:
        raise SystemExit(
            f"fig11: path-revised run beat stay-the-course by only "
            f"x{gain:.2f} (< 1.3) — the online switch stopped paying")


def run() -> None:
    _sweep()
    _shift_gate()


if __name__ == "__main__":
    print("name,us_per_call,derived")
    run()
