"""Zero-copy batched data plane vs the per-item hot path — wall clock.

The tentpole claim of the zero-copy refactor: the staging layer's cost
per moved byte was dominated by *per-item coordination* — one upstream
pull, one admission check, one buffer lock round-trip, one digest lock
acquisition for every 8 KiB item.  Batch admission moves whole slabs
through every one of those seams (``put_many``/``get_many``, one
``_admit`` per slab, one digest fold per slab), and ``slab_views`` feeds
the stream as ``memoryview`` slices of one contiguous buffer — no
per-item copy anywhere on the path.

Both rows move the SAME >= 256 MiB stream through the SAME plan with the
stream checksum enabled; the baseline forces ``batch_items=1`` (the
historical per-item path), the batched row defers to the plan's
auto-sized slabs.  This is real wall clock on the host — the relative
claim mirrors the paper's host-bottleneck argument, not TPU numbers.

Rows:
  staging_throughput/per-item   batch_items=1 against the batched plan
  staging_throughput/batched    the plan's auto slab size (~1 MiB slabs)

Exits nonzero if the batched path fails to sustain >= 2x the per-item
throughput, if the two stream checksums differ, or if either path drops
an item — the zero-copy plane must be faster AND bit-identical.
"""

from repro.core.basin import DrainageBasin, GBPS, Tier, TierKind
from repro.core.mover import MoverConfig, UnifiedDataMover
from repro.core.planner import plan_transfer
from repro.core.staging import slab_views

from .common import emit

STREAM_BYTES = 256 * 1024 * 1024
ITEM_BYTES = 8 * 1024
N_ITEMS = STREAM_BYTES // ITEM_BYTES
#: batched path must beat per-item by at least this factor (hard gate)
MIN_SPEEDUP = 2.0


def _basin() -> DrainageBasin:
    # fast in-host tiers: the modeled pipes are far above what the host
    # staging layer can coordinate per item, so the measured delta is
    # pure data-plane overhead (the quantity under test)
    return DrainageBasin([
        Tier("src", TierKind.SOURCE, 50.0 * GBPS, latency_s=1e-6),
        Tier("bb", TierKind.BURST_BUFFER, 100.0 * GBPS, latency_s=1e-6),
        Tier("sink", TierKind.SINK, 50.0 * GBPS, latency_s=1e-6),
    ])


def _stream(data: bytes):
    return slab_views(data, ITEM_BYTES)


def _run_one(data: bytes, plan, batch_items):
    mover = UnifiedDataMover(MoverConfig(checksum=True), plan=plan)
    report = mover.bulk_transfer(
        _stream(data), lambda _: None,
        transforms=[("pull", None), ("push", None)],
        checksum=True, batch_items=batch_items)
    return report


def run() -> None:
    # position-dependent payload: every item hashes differently, so the
    # XOR-folded stream checksum cannot trivially cancel to zero
    data = bytes(bytearray((i * 2654435761 >> 7) & 0xFF
                           for i in range(1 << 16))) * (STREAM_BYTES >> 16)
    plan = plan_transfer(_basin(), ITEM_BYTES, stages=("pull", "push"),
                         checksum=True, batch_items="auto")
    batch = max(h.batch_items for h in plan.hops)

    per_item = _run_one(data, plan, 1)
    batched = _run_one(data, plan, None)

    mbs_item = per_item.throughput_bytes_per_s / 1e6
    mbs_batch = batched.throughput_bytes_per_s / 1e6
    speedup = (batched.throughput_bytes_per_s
               / per_item.throughput_bytes_per_s
               if per_item.throughput_bytes_per_s > 0 else 0.0)

    emit("staging_throughput/per-item", per_item.elapsed_s * 1e6,
         f"{mbs_item:.0f}MB/s items={per_item.items}",
         throughput_mb_s=mbs_item, batch_items=1,
         items=per_item.items, checksum=per_item.checksum)
    emit("staging_throughput/batched", batched.elapsed_s * 1e6,
         f"{mbs_batch:.0f}MB/s items={batched.items} b={batch} "
         f"speedup={speedup:.2f}x",
         throughput_mb_s=mbs_batch, batch_items=batch,
         items=batched.items, speedup=speedup,
         checksum=batched.checksum)

    if per_item.items != N_ITEMS or batched.items != N_ITEMS:
        raise SystemExit(
            f"staging_throughput: item count mismatch "
            f"(per-item={per_item.items} batched={batched.items} "
            f"expected={N_ITEMS})")
    if per_item.checksum != batched.checksum:
        raise SystemExit(
            f"staging_throughput: stream checksum diverged "
            f"({per_item.checksum} != {batched.checksum})")
    if speedup < MIN_SPEEDUP:
        raise SystemExit(
            f"staging_throughput: batched speedup {speedup:.2f}x "
            f"< required {MIN_SPEEDUP}x")


if __name__ == "__main__":
    run()
