"""Zero-drain live plan swap vs drain-and-rebuild on a long stream.

The tentpole claim of the zero-drain refactor: online replanning used to
pay a teardown bubble at every ``replan_every_items`` boundary — the
buffer path fully drained and the stage pipeline was rebuilt from
scratch, so a long stream with frequent revisions repeatedly fell off
line rate exactly while the plan was being corrected (the self-inflicted
host-side stall class of arXiv:2308.10312).  The live-swap path keeps ONE
persistent pipeline and applies each revision in place (buffer resize,
worker grow/retire), so the boundary costs nothing.

Deterministic: both paths run on the simulated-basin harness
(tests/simbasin.py) — a virtual clock, a latency-prone store with a
scripted mid-stream regime shift, zero jitter — so the numbers are a
function of the script, not host load.

Rows:
  live_swap/drain-rebuild   drain_per_segment=True (the historical path)
  live_swap/live            zero-drain: plan deltas applied to the
                            running pipeline

`derived` carries achieved MB/s; the live row adds the speedup and both
paths' online revision counts.  Exits nonzero if the live path fails to
sustain >= 1.3x the drain-and-rebuild throughput (the acceptance claim).
"""

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "tests"))

from simbasin import SimHarness  # noqa: E402

from repro.core.basin import DrainageBasin, GBPS, Tier, TierKind  # noqa: E402
from repro.core.planner import plan_transfer  # noqa: E402

from .common import emit

N_ITEMS = 240
ITEM_BYTES = 256 * 1024
#: frequent revision boundaries — the drain path pays a pipeline
#: fill/drain bubble at every one of these
REPLAN_EVERY = 12
LATENCY_S = 2e-3                # latency-prone store (constant: no jitter,
#                                 so virtual elapsed is a pure function of
#                                 the script)
SHIFT_AT = 120                  # mid-stream regime shift: latency doubles
LATENCY_AFTER_S = 4e-3


def _modeled_basin() -> DrainageBasin:
    return DrainageBasin([
        Tier("store", TierKind.SOURCE, 10.0 * GBPS, latency_s=LATENCY_S),
        Tier("staging", TierKind.BURST_BUFFER, 100.0 * GBPS,
             latency_s=1e-5),
        Tier("sink", TierKind.SINK, 40.0 * GBPS, latency_s=1e-5),
    ])


def _run_one(drain_per_segment: bool):
    h = SimHarness()
    tier = h.tier(bandwidth_bytes_per_s=10.0 * GBPS, latency_s=LATENCY_S)
    tier.shift_at(SHIFT_AT, latency_s=LATENCY_AFTER_S)
    plan = plan_transfer(_modeled_basin(), ITEM_BYTES, stages=("fetch",))
    mover = h.mover(plan=plan)
    src = h.source(h.tier(bandwidth_bytes_per_s=1000.0 * GBPS,
                          wall_pacing_s=0.0), N_ITEMS, ITEM_BYTES)
    report = mover.bulk_transfer(
        iter(src), lambda _: None,
        transforms=[("fetch", h.service(tier))],
        replan_every_items=REPLAN_EVERY,
        drain_per_segment=drain_per_segment)
    return report


def run() -> None:
    drained = _run_one(True)
    emit("live_swap/drain-rebuild", drained.elapsed_s * 1e6,
         f"{drained.throughput_bytes_per_s / 1e6:.1f}MB/s "
         f"replans={drained.replans}",
         throughput_mb_s=drained.throughput_bytes_per_s / 1e6,
         replans=drained.replans)

    live = _run_one(False)
    speedup = (live.throughput_bytes_per_s
               / max(drained.throughput_bytes_per_s, 1e-9))
    emit("live_swap/live", live.elapsed_s * 1e6,
         f"{live.throughput_bytes_per_s / 1e6:.1f}MB/s "
         f"x{speedup:.2f}-vs-drain replans={live.replans}",
         throughput_mb_s=live.throughput_bytes_per_s / 1e6,
         speedup=speedup, replans=live.replans)

    if live.items != drained.items:
        raise SystemExit(
            f"zero-drain path delivered {live.items} items, "
            f"drain path {drained.items} — equivalence broken")
    if speedup < 1.3:
        raise SystemExit(
            f"live swap ({live.throughput_bytes_per_s:.0f} B/s) failed to "
            f"sustain 1.3x the drain-and-rebuild path "
            f"({drained.throughput_bytes_per_s:.0f} B/s): x{speedup:.2f}")


if __name__ == "__main__":
    print("name,us_per_call,derived")
    run()
