"""Benchmark harness — one module per paper table/figure.

Emits ``name,us_per_call,derived`` CSV rows.  Roofline terms come from the
dry-run artifacts (compile-time analysis, CPU container); host-path
numbers (staging/mover) are measured wall-clock and used for *relative*
claims mirroring the paper's figures.

``--json DIR`` additionally writes one machine-readable
``BENCH_<suite>.json`` per suite (rows incl. structured throughput/
speedup/replan-count fields, pass/fail status) so the perf trajectory is
tracked across commits; CI uploads these as artifacts.  ``--quick`` runs
only the fast deterministic suites (virtual-time / analytic — suitable
for the tier-1 loop).

    PYTHONPATH=src python -m benchmarks.run [--only fig2,roofline]
    PYTHONPATH=src python -m benchmarks.run --quick --json bench-json
"""

import argparse
import json
import os
import sys
import traceback

from . import (common, fault_recovery, fig2_latency_sweep, fig4_cca_sweep,
               fig8_bulk_streaming, fig10_storage_bound,
               fig11_staged_vs_direct, fleet_arbitration, global_tuning,
               kernel_bench, live_swap, multipath, online_replan,
               planned_vs_fixed, roofline, staging_throughput,
               table5_basin_volumes)

SUITES = {
    "table5": table5_basin_volumes,
    "fault_recovery": fault_recovery,
    "fig2": fig2_latency_sweep,
    "fig4": fig4_cca_sweep,
    "fig8": fig8_bulk_streaming,
    "fig10": fig10_storage_bound,
    "fig11": fig11_staged_vs_direct,
    "fleet_arbitration": fleet_arbitration,
    "global_tuning": global_tuning,
    "kernels": kernel_bench,
    "live_swap": live_swap,
    "multipath": multipath,
    "online_replan": online_replan,
    "planned_vs_fixed": planned_vs_fixed,
    "roofline": roofline,
    "staging_throughput": staging_throughput,
}

#: deterministic-in-virtual-time / analytic suites, fast enough for the
#: per-push CI loop (no wall-clock sleeps, no model compiles) — plus the
#: staging_throughput wall-clock gate, the zero-copy plane's acceptance
#: claim (a few seconds of pure host work, no compiles, no sleeps).
#: fig8 and fleet_arbitration run contended links in wall-synced virtual
#: time (a few wall seconds each) and hard-gate the PR 8 arbiter claims.
#: fig10 and fault_recovery run planned transfers in virtual time and
#: hard-gate the storage-bound roof and the PR 9 survive-layer claims
#: (chaos completion + checksum, failover vs restart, ledger resume).
#: fig11 executes planner-chosen paths in virtual time and hard-gates
#: the stream-vs-stage decision engine (auto >= 0.95x best forced at
#: every sweep point; the path-revised switch beats stay-the-course).
QUICK = ["table5", "fault_recovery", "fig2", "fig4", "fig8", "fig10",
         "fig11", "fleet_arbitration", "live_swap", "multipath",
         "staging_throughput"]


def _write_json(json_dir: str, name: str, rows: list, error: str) -> None:
    os.makedirs(json_dir, exist_ok=True)
    path = os.path.join(json_dir, f"BENCH_{name}.json")
    with open(path, "w") as f:
        json.dump({"suite": name, "ok": not error, "error": error or None,
                   "rows": rows}, f, indent=2)


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--only", default=None, help="comma-separated suite names")
    ap.add_argument("--quick", action="store_true",
                    help=f"run only the fast deterministic suites {QUICK}")
    ap.add_argument("--json", default=None, metavar="DIR",
                    help="write BENCH_<suite>.json result files into DIR")
    args = ap.parse_args()
    if args.only:
        names = args.only.split(",")
    elif args.quick:
        names = list(QUICK)
    else:
        names = list(SUITES)
    print("name,us_per_call,derived")
    failed = []
    for name in names:
        start = len(common.RESULTS)
        error = ""
        try:
            SUITES[name].run()
        except Exception as e:
            failed.append(name)
            error = f"{type(e).__name__}: {e}"
            print(f"{name}/ERROR,0.0,{error}")
            traceback.print_exc(file=sys.stderr)
        except SystemExit as e:
            # suites raise SystemExit on a failed acceptance gate — record
            # it as a failure but keep running the remaining suites
            failed.append(name)
            error = str(e)
            print(f"{name}/GATE-FAILED,0.0,{error}")
        if args.json is not None:
            _write_json(args.json, name, common.RESULTS[start:], error)
    if failed:
        raise SystemExit(f"benchmark suites failed: {failed}")


if __name__ == "__main__":
    main()
