"""Benchmark harness — one module per paper table/figure.

Emits ``name,us_per_call,derived`` CSV rows.  Roofline terms come from the
dry-run artifacts (compile-time analysis, CPU container); host-path
numbers (staging/mover) are measured wall-clock and used for *relative*
claims mirroring the paper's figures.

    PYTHONPATH=src python -m benchmarks.run [--only fig2,roofline]
"""

import argparse
import sys
import traceback

from . import (fig2_latency_sweep, fig4_cca_sweep, fig8_bulk_streaming,
               fig10_storage_bound, fig11_staged_vs_direct, global_tuning,
               kernel_bench, multipath, online_replan, planned_vs_fixed,
               roofline, table5_basin_volumes)

SUITES = {
    "table5": table5_basin_volumes,
    "fig2": fig2_latency_sweep,
    "fig4": fig4_cca_sweep,
    "fig8": fig8_bulk_streaming,
    "fig10": fig10_storage_bound,
    "fig11": fig11_staged_vs_direct,
    "global_tuning": global_tuning,
    "kernels": kernel_bench,
    "multipath": multipath,
    "online_replan": online_replan,
    "planned_vs_fixed": planned_vs_fixed,
    "roofline": roofline,
}


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--only", default=None, help="comma-separated suite names")
    args = ap.parse_args()
    names = args.only.split(",") if args.only else list(SUITES)
    print("name,us_per_call,derived")
    failed = []
    for name in names:
        try:
            SUITES[name].run()
        except Exception as e:
            failed.append(name)
            print(f"{name}/ERROR,0.0,{type(e).__name__}: {e}")
            traceback.print_exc(file=sys.stderr)
    if failed:
        raise SystemExit(f"benchmark suites failed: {failed}")


if __name__ == "__main__":
    main()
