"""Planned vs fixed-config staging on a jittery source (the tentpole claim).

The TransferPlan engine's promise: deriving capacity/workers from the
basin model beats one-size-fits-all constants when the source is erratic
— concurrency sized to amortize per-item latency (§3.1), buffer depth
sized to the jitter window (§2.1).  The scenario is the paper's erratic
production storage: each item costs a fixed fetch latency (tc-netem
style, injected in the fetch transform so concurrent workers can
overlap it — the storage, not the iterator, is the slow element).

Rows:
  planned_vs_fixed/direct        un-staged baseline (every fetch serializes)
  planned_vs_fixed/fixed         uniform MoverConfig defaults (cap=4, w=2)
  planned_vs_fixed/planned       basin-derived TransferPlan
  planned_vs_fixed/replanned     after one hypothesis->measure->revise cycle

`derived` carries achieved MB/s; the planned row also carries the
speedup over fixed.  Exits nonzero if planned < fixed (the acceptance
claim of the planner).
"""

import time

import numpy as np

from repro.core.basin import DrainageBasin, GBPS, Tier, TierKind
from repro.core.mover import MoverConfig, UnifiedDataMover
from repro.core.planner import plan_transfer, replan

from .common import emit

N_ITEMS = 48
ITEM_BYTES = 256 * 1024
FETCH_LATENCY_S = 2e-3


def _scenario_basin() -> DrainageBasin:
    """Erratic store -> host staging -> fast sink; the store's per-item
    latency/jitter matches the injected fetch cost."""
    return DrainageBasin([
        Tier("jittery-store", TierKind.SOURCE, 10.0 * GBPS,
             latency_s=FETCH_LATENCY_S, jitter_s=FETCH_LATENCY_S),
        Tier("staging", TierKind.BURST_BUFFER, 100.0 * GBPS,
             latency_s=10e-6),
        Tier("sink", TierKind.SINK, 40.0 * GBPS, latency_s=10e-6),
    ])


def _make_fetch():
    payload = np.random.default_rng(0).integers(
        0, 255, ITEM_BYTES, dtype=np.uint8)

    def fetch(_i: int) -> np.ndarray:
        time.sleep(FETCH_LATENCY_S)      # erratic storage service time
        return payload

    return fetch


def _throughput(report) -> float:
    return report.throughput_bytes_per_s


def run() -> None:
    fetch = _make_fetch()
    sink = []

    def go(mover, plan=None):
        sink.clear()
        return mover.bulk_transfer(iter(range(N_ITEMS)), sink.append,
                                   transforms=[("fetch", fetch)], plan=plan)

    # -- direct: every fetch serializes with delivery ------------------------
    direct = UnifiedDataMover(MoverConfig(checksum=False)).direct_transfer(
        (fetch(i) for i in range(N_ITEMS)), sink.append)
    emit("planned_vs_fixed/direct", direct.elapsed_s * 1e6,
         f"{_throughput(direct) / 1e6:.1f}MB/s")

    # -- fixed: the one-size-fits-all MoverConfig defaults -------------------
    fixed = go(UnifiedDataMover(MoverConfig(checksum=False)))
    emit("planned_vs_fixed/fixed", fixed.elapsed_s * 1e6,
         f"{_throughput(fixed) / 1e6:.1f}MB/s")

    # -- planned: capacity/workers derived from the basin model --------------
    basin = _scenario_basin()
    plan = plan_transfer(basin, ITEM_BYTES, stages=("fetch",))
    planned = go(UnifiedDataMover(MoverConfig(checksum=False), plan=plan),
                 plan)
    speedup = _throughput(planned) / max(_throughput(fixed), 1e-9)
    emit("planned_vs_fixed/planned", planned.elapsed_s * 1e6,
         f"{_throughput(planned) / 1e6:.1f}MB/s "
         f"x{speedup:.2f}-vs-fixed cap={plan.hops[0].capacity} "
         f"w={plan.hops[0].workers}")

    # -- replanned: one measure->revise cycle on the observed stalls ---------
    plan2 = replan(plan, planned.stage_reports)
    replanned = go(UnifiedDataMover(MoverConfig(checksum=False), plan=plan2),
                   plan2)
    gap = replanned.fidelity_gap
    emit("planned_vs_fixed/replanned", replanned.elapsed_s * 1e6,
         f"{_throughput(replanned) / 1e6:.1f}MB/s "
         f"gap={'n/a' if gap is None else f'{gap:+.3f}'}")

    if _throughput(planned) < _throughput(fixed):
        raise SystemExit(
            f"planned ({_throughput(planned):.0f} B/s) slower than fixed "
            f"({_throughput(fixed):.0f} B/s) on the jittery-source scenario")


if __name__ == "__main__":
    print("name,us_per_call,derived")
    run()
