"""DAG-planned multipath movement vs the linear planner under branch decay.

The tentpole claim of the DAG basin refactor: when the data path has two
branches and one of them degrades *mid-transfer*, a multipath plan
revised online (a) attributes the stall to the degraded branch alone
(its ``"<branch>/<hop>"`` diagnosis key), and (b) rebalances traffic
toward the healthy branch — sustaining far higher aggregate throughput
than a linear plan, which can only ride its one path down.

Deterministic: both scenarios run on the simulated-basin harness
(tests/simbasin.py) — a virtual clock and scripted per-branch regime
shifts, so the numbers are a function of the script, not host load.

Rows:
  multipath/linear    one path (the pre-DAG planner), branch A only
  multipath/dag       split over both branches, online replan rebalances

`derived` carries achieved MB/s; the dag row adds the speedup, the
replan count, and the final branch weights.  Exits nonzero if the DAG
plan fails to beat the linear one (the acceptance claim).
"""

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "tests"))

from simbasin import SimHarness  # noqa: E402

from repro.core.basin import DrainageBasin, GBPS, Link, MIB, Tier, \
    TierKind  # noqa: E402
from repro.core.planner import plan_transfer  # noqa: E402

from .common import emit

N_ITEMS = 360
ITEM_BYTES = 1 * MIB
# branch-A served-item index of the decay.  Aligned to A's segment
# boundary (equal-weight DRR deals A exactly REPLAN_EVERY/2 items per
# segment) so the post-shift segment's service samples are purely
# degraded — a mixed segment reads as dispersed (latency-like) and the
# replanner would answer with the wrong remedy first
SHIFT_AT = 90
DEGRADED_GBPS = 0.5             # branch A after the shift (was 10)
# segment length trades replan agility against measurement quality: a
# segment must carry enough virtual time that pipeline-startup ramp
# (~ms) stays well under the stall threshold on healthy branches
REPLAN_EVERY = 60


def _tiers():
    return [
        Tier("src", TierKind.SOURCE, 40.0 * GBPS, latency_s=1e-5),
        Tier("staging", TierKind.BURST_BUFFER, 40.0 * GBPS, latency_s=1e-5),
        Tier("path-a", TierKind.SINK, 10.0 * GBPS),
        Tier("path-b", TierKind.SINK, 10.0 * GBPS),
    ]


def _dag_basin() -> DrainageBasin:
    src, staging, a, b = _tiers()
    return DrainageBasin([src, staging, a, b],
                         [Link("src", "staging"), Link("staging", "path-a"),
                          Link("staging", "path-b")])


def _linear_basin() -> DrainageBasin:
    """What the pre-DAG planner could express: one path, branch A only."""
    src, staging, a, _ = _tiers()
    return DrainageBasin([src, staging, a])


def _scenario(harness: SimHarness):
    """Fresh scripted truth: branch A decays at its 60th item, B steady."""
    tier_a = harness.branch_tier("path-a",
                                 bandwidth_bytes_per_s=10.0 * GBPS)
    tier_a.shift_at(SHIFT_AT, bandwidth_bytes_per_s=DEGRADED_GBPS * GBPS)
    tier_b = harness.branch_tier("path-b",
                                 bandwidth_bytes_per_s=10.0 * GBPS)
    # the dispatcher is a single thread (no GIL fairness to enforce) and
    # must outpace branch consumption, or phantom upstream starvation
    # pollutes the attribution signal: pacing off, supply far above the
    # branch line rate so its serves barely advance the virtual clock
    src = harness.source(harness.tier(bandwidth_bytes_per_s=1000.0 * GBPS,
                                      wall_pacing_s=0.0),
                         N_ITEMS, ITEM_BYTES)
    return src, tier_a, tier_b


def _run_linear():
    h = SimHarness()
    src, tier_a, _ = _scenario(h)
    plan = plan_transfer(_linear_basin(), ITEM_BYTES, stages=("deliver",))
    mover = h.mover(plan=plan)
    report = mover.bulk_transfer(
        iter(src), lambda _: None,
        transforms=[("deliver", h.service(tier_a))],
        replan_every_items=REPLAN_EVERY)
    return report, mover


def _run_dag():
    h = SimHarness()
    src, tier_a, tier_b = _scenario(h)
    plan = plan_transfer(_dag_basin(), ITEM_BYTES, stages=("deliver",))
    mover = h.mover(plan=plan)
    report = mover.parallel_transfer(
        iter(src), lambda _: None,
        transforms={"path-a": [("deliver", h.service(tier_a))],
                    "path-b": [("deliver", h.service(tier_b))]},
        mode="split", replan_every_items=REPLAN_EVERY)
    return report, mover


def run() -> None:
    linear, _ = _run_linear()
    emit("multipath/linear", linear.elapsed_s * 1e6,
         f"{linear.throughput_bytes_per_s / 1e6:.1f}MB/s")

    dag, mover = _run_dag()
    speedup = (dag.throughput_bytes_per_s
               / max(linear.throughput_bytes_per_s, 1e-9))
    weights = " ".join(f"{b.branch_id}={b.weight:.2f}"
                       for b in mover.last_plan.branches)
    emit("multipath/dag", dag.elapsed_s * 1e6,
         f"{dag.throughput_bytes_per_s / 1e6:.1f}MB/s "
         f"x{speedup:.2f}-vs-linear replans={dag.replans} {weights}")

    # load-robust attribution gate: the degraded branch must carry a
    # verdict naming its own private tier, the healthy branch must never
    # be diagnosed bandwidth-bound (that would strip its traffic share),
    # and traffic must have rebalanced toward it.  The strict
    # one-branch-only claim is pinned deterministically by the replay
    # corpus (tests/data/stage_reports/multipath_branch_degrade.json).
    diag = mover.last_plan.diagnosis
    final = {b.branch_id: b.weight for b in mover.last_plan.branches}
    if ("path-a" not in diag.get("path-a/deliver", "")
            or "bandwidth-bound" in diag.get("path-b/deliver", "")
            or final["path-b"] <= final["path-a"]):
        raise SystemExit(
            f"per-branch attribution failed: diagnosis={diag} "
            f"weights={final}")
    if dag.throughput_bytes_per_s <= 1.2 * linear.throughput_bytes_per_s:
        raise SystemExit(
            f"DAG plan ({dag.throughput_bytes_per_s:.0f} B/s) failed to "
            f"clearly beat the linear plan "
            f"({linear.throughput_bytes_per_s:.0f} B/s) on the "
            f"branch-decay scenario")


if __name__ == "__main__":
    print("name,us_per_call,derived")
    run()
