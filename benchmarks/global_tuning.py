"""ESnet 2020 evaluation claim — 'a single setting for a wide range of
file sizes': ONE mover configuration across four orders of magnitude of
item size keeps the fidelity gap small at every point (vs per-size
retuning).  §2.3's operational-simplicity argument, measured."""

from repro.core.mover import MoverConfig, UnifiedDataMover

from .common import emit, payload_stream

TOTAL = 32 << 20


def run() -> None:
    mover = UnifiedDataMover(MoverConfig(staging_capacity=8,
                                         staging_workers=4, checksum=False))
    rates = {}
    for size_kib in (4, 64, 1024, 16384):
        item = size_kib << 10
        n = max(2, TOTAL // item)
        rep = mover.bulk_transfer(payload_stream(n, item, latency_s=1e-4),
                                  lambda x: None)
        rates[size_kib] = rep.throughput_bytes_per_s
        emit(f"global_tuning/item_{size_kib}KiB", rep.elapsed_s / n * 1e6,
             f"{rep.throughput_bytes_per_s / 1e6:.1f} MB/s")
    flat = min(rates.values()) / max(rates.values())
    emit("global_tuning/flatness", 0.0,
         f"min/max={flat:.2f} across 4096x item-size range, one config")
