"""Fleet-scale basin arbitration — N concurrent transfers at aggregate
line rate (the PR 8 tentpole claim).

Three deterministic virtual-time scenarios on one shared 100 Gb/s
channel (tests/simbasin.py in contended-link mode), each a hard gate:

1. **Weighted line rate** — four tenants across QoS classes
   (priority/bulk/scavenger/scavenger, weights 4/2/1/1) run under one
   :class:`~repro.core.fleet.FleetArbiter`.  The fleet must hold
   aggregate delivery >= 90% of the line while every class's achieved
   share lands within 10% of its weight share.  The SAME four transfers
   planned independently (each promised the whole line) all miss their
   fidelity gates — the misbehaviour the arbiter exists to fix.
2. **Admission control** — a fifth tenant whose min-rate ask cannot fit
   the live fleet is queued (or rejected with ``queue=False``) without
   perturbing a single live grant, and the ledger stays conserved.
3. **Live rebalance** — tenant A runs alone at the line; mid-stream,
   four scavengers admit and A's halved grant is pushed through the
   zero-drain applier (A observes >= 1 replan, no teardown).  On the
   same arrival schedule the arbitered fleet must complete A >= 1.3x
   faster than the static fleet (full-BDP windows, no arbiter) where
   the scavengers crowd A to an equal split.

Rows carry achieved MB/s, per-tenant shares, and the speedup; gates
raise SystemExit on failure (run.py records GATE-FAILED).
"""

import os
import sys
import threading

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "tests"))

from simbasin import SimHarness  # noqa: E402

from repro.core.basin import DrainageBasin, GBPS, Link, MIB, Tier, \
    TierKind  # noqa: E402
from repro.core.planner import plan_transfer  # noqa: E402

from .common import emit

L = 100 * GBPS                  # the shared channel's line rate
ITEM = 1 * MIB
RTT = 0.005
#: wall seconds per virtual second: enough that the wall-gate keeps the
#: contended link serving in virtual-arrival order (grant enforcement on
#: the wire), small enough that the suite stays a few wall seconds
WALL_SYNC = 10.0

#: (name, qos, weight, items) — sizes proportional to weights so the
#: tenants finish together and achieved shares are directly comparable
TENANTS = [
    ("ckpt", "priority", 4.0, 384),
    ("shard", "bulk", 2.0, 192),
    ("scrub1", "scavenger", 1.0, 96),
    ("scrub2", "scavenger", 1.0, 96),
]


def _basin() -> DrainageBasin:
    return DrainageBasin(
        [Tier("src", TierKind.SOURCE, 2 * L),
         Tier("dst", TierKind.SINK, 2 * L)],
        [Link("src", "dst", L, rtt_s=RTT)])


def _contended_link(h: SimHarness):
    return h.link(bandwidth_bytes_per_s=L, rtt_s=RTT,
                  wall_sync=WALL_SYNC, wall_pacing_s=0.0)


def _runner(h, link, n_items, seed, fleet=None, plan=None, sink=None):
    def run():
        src = h.source(h.tier(bandwidth_bytes_per_s=1000 * GBPS,
                              wall_pacing_s=0.0, seed=seed), n_items, ITEM)
        mover = h.mover(plan=None if fleet is not None else plan)
        return mover.bulk_transfer(
            iter(src), sink if sink is not None else (lambda _: None),
            transforms=[("move", h.service(link))], fleet=fleet)
    return run


# -- gate 1: weighted aggregate line rate vs independent plans ----------------


def _run_arbitered_fleet():
    h = SimHarness()
    arb = h.arbiter(_basin())
    link = _contended_link(h)
    adms = [arb.admit(name, ITEM, qos=qos, stages=("move",))
            for name, qos, _w, _n in TENANTS]
    for adm in adms:
        assert adm.status == "admitted", (adm.name, adm.reason)
    reps = h.run_concurrent(*[
        _runner(h, link, n, seed=i, fleet=adm)
        for i, (adm, (_, _, _, n)) in enumerate(zip(adms, TENANTS))])
    return reps


def _run_independent_fleet():
    """The pre-arbiter world: each tenant prices the basin as if it owned
    it — four promises of the full line on one link."""
    h = SimHarness()
    link = _contended_link(h)
    plan = plan_transfer(_basin(), ITEM, stages=("move",))
    reps = h.run_concurrent(*[
        _runner(h, link, n, seed=i, plan=plan)
        for i, (_, _, _, n) in enumerate(TENANTS)])
    return reps


def _gate_weighted_line_rate() -> None:
    reps = _run_arbitered_fleet()
    total_w = sum(w for _, _, w, _ in TENANTS)
    makespan = max(r.elapsed_s for r in reps)
    agg = sum(r.bytes for r in reps) / makespan
    emit("fleet/arbitered_aggregate", makespan * 1e6,
         f"{agg / 1e6:.0f}MB/s ({agg / L:.3f}x-line)",
         aggregate_bytes_per_s=agg, line_bytes_per_s=L)
    worst_dev = 0.0
    achieved_total = sum(r.bytes / r.elapsed_s for r in reps)
    for (name, qos, w, _n), rep in zip(TENANTS, reps):
        share = (rep.bytes / rep.elapsed_s) / achieved_total
        weight_share = w / total_w
        dev = abs(share / weight_share - 1.0)
        worst_dev = max(worst_dev, dev)
        emit(f"fleet/share_{name}", rep.elapsed_s * 1e6,
             f"{share:.3f} (weight {weight_share:.3f}, "
             f"dev {dev * 100:.1f}%) gap={rep.fidelity_gap:.3f}",
             share=share, weight_share=weight_share,
             fidelity_gap=rep.fidelity_gap)
    if agg < 0.9 * L:
        raise SystemExit(
            f"arbitered fleet aggregate {agg / 1e6:.0f} MB/s fell below "
            f"90% of the {L / 1e6:.0f} MB/s line")
    if worst_dev > 0.10:
        raise SystemExit(
            f"achieved shares drifted {worst_dev * 100:.1f}% from the "
            f"class weights (gate: 10%)")

    base = _run_independent_fleet()
    for (name, _, _, _n), rep in zip(TENANTS, base):
        emit(f"fleet/independent_{name}", rep.elapsed_s * 1e6,
             f"gap={rep.fidelity_gap:.3f}", fidelity_gap=rep.fidelity_gap)
    if not all(r.fidelity_gap > 0.1 for r in base):
        raise SystemExit(
            "independent plans unexpectedly met their promises on the "
            "contended channel — the scenario no longer shows the "
            "over-promise misbehaviour")


# -- gate 2: admission control keeps the ledger conserved ---------------------


def _gate_admission() -> None:
    arb = SimHarness().arbiter(_basin())
    for name, qos, _w, _n in TENANTS:
        assert arb.admit(name, ITEM, qos=qos,
                         stages=("move",)).status == "admitted"
    before = arb.grants()
    greedy = arb.admit("greedy", ITEM, qos="bulk",
                       min_bytes_per_s=0.3 * L, stages=("move",))
    refused = arb.admit("refused", ITEM, qos="bulk",
                        min_bytes_per_s=0.3 * L, queue=False,
                        stages=("move",))
    agg = sum(arb.grants().values())
    emit("fleet/admission", 0.0,
         f"greedy={greedy.status} refused={refused.status} "
         f"ledger={agg / 1e6:.0f}MB/s")
    if greedy.status != "queued" or refused.status != "rejected":
        raise SystemExit(
            f"admission control failed: greedy={greedy.status} "
            f"(want queued), refused={refused.status} (want rejected)")
    if arb.grants() != before:
        raise SystemExit("a failed admission perturbed the live grants")
    if agg > L * (1 + 1e-9):
        raise SystemExit(
            f"ledger oversubscribed: {agg / 1e6:.0f} MB/s granted on a "
            f"{L / 1e6:.0f} MB/s line")


# -- gate 3: live rebalance beats the static fleet ----------------------------

A_ITEMS = 640
SCAV_ITEMS = 256
ADMIT_AT = 128                  # A's sunk-item count when the peers land


def _run_rebalanced():
    h = SimHarness()
    arb = h.arbiter(_basin())
    link = _contended_link(h)
    adm_a = arb.admit("A", ITEM, qos="interactive", stages=("move",))
    go = threading.Event()
    sunk = [0]

    def sink_a(_item):
        sunk[0] += 1
        if sunk[0] == ADMIT_AT:
            go.set()

    def scavenger(i):
        def run():
            go.wait(timeout=120)
            adm = arb.admit(f"scav{i}", ITEM, qos="scavenger",
                            stages=("move",))
            assert adm.status == "admitted", adm.reason
            return _runner(h, link, SCAV_ITEMS, seed=10 + i, fleet=adm)()
        return run

    res = h.run_concurrent(
        _runner(h, link, A_ITEMS, seed=1, fleet=adm_a, sink=sink_a),
        *[scavenger(i) for i in range(4)])
    return res[0], res[1:]


def _run_static():
    """No arbiter: everyone carries a full-BDP window, and the
    scavengers crowd A toward an equal split of the link."""
    h = SimHarness()
    link = _contended_link(h)
    plan = plan_transfer(_basin(), ITEM, stages=("move",))
    go = threading.Event()
    sunk = [0]

    def sink_a(_item):
        sunk[0] += 1
        if sunk[0] == ADMIT_AT:
            go.set()

    def scavenger(i):
        def run():
            go.wait(timeout=120)
            return _runner(h, link, SCAV_ITEMS, seed=10 + i, plan=plan)()
        return run

    res = h.run_concurrent(
        _runner(h, link, A_ITEMS, seed=1, plan=plan, sink=sink_a),
        *[scavenger(i) for i in range(4)])
    return res[0], res[1:]


def _gate_rebalance() -> None:
    arb_a, _arb_peers = _run_rebalanced()
    static_a, _static_peers = _run_static()
    speedup = static_a.elapsed_s / arb_a.elapsed_s
    emit("fleet/rebalanced_A", arb_a.elapsed_s * 1e6,
         f"{arb_a.throughput_bytes_per_s / 1e6:.0f}MB/s "
         f"replans={arb_a.replans} x{speedup:.2f}-vs-static",
         speedup=speedup, replans=arb_a.replans)
    emit("fleet/static_A", static_a.elapsed_s * 1e6,
         f"{static_a.throughput_bytes_per_s / 1e6:.0f}MB/s")
    if arb_a.replans < 1:
        raise SystemExit(
            "the mid-stream rebalance never reached A's live stage "
            "(expected >= 1 zero-drain plan revision)")
    if speedup < 1.3:
        raise SystemExit(
            f"arbitered fleet only beat the static fleet x{speedup:.2f} "
            f"on the arrival schedule (gate: x1.3)")


def run() -> None:
    _gate_weighted_line_rate()
    _gate_admission()
    _gate_rebalance()


if __name__ == "__main__":
    print("name,us_per_call,derived")
    run()
