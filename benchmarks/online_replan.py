"""Online vs epoch-boundary replanning on a regime-shifting source.

The tentpole claim of the service-time-aware replanner: when a transfer's
bottleneck regime shifts *mid-transfer* (here, an erratic store whose
per-item latency jumps an order of magnitude partway through), a plan
revised online at buffer boundaries (``replan_every_items``) diagnoses the
shift from per-item service-time samples, answers latency with
concurrency, and recovers throughput inside the same ``bulk_transfer`` —
while the epoch-boundary-only path rides the degraded regime to the end.

Rows:
  online_replan/offline     one plan for the whole transfer (the old way)
  online_replan/online      replan_every_items: plan revised mid-transfer

`derived` carries achieved MB/s; the online row also carries the speedup,
the number of online revisions, and the final worker count.  Exits
nonzero if online fails to beat offline (the acceptance claim).
"""

import threading
import time

import numpy as np

from repro.core.basin import DrainageBasin, GBPS, Tier, TierKind
from repro.core.mover import MoverConfig, UnifiedDataMover
from repro.core.planner import plan_transfer

from .common import emit

N_ITEMS = 240
ITEM_BYTES = 256 * 1024
SHIFT_AT = 60                   # item index where the regime shifts
LATENCY_BEFORE_S = 0.5e-3       # smooth store
LATENCY_AFTER_S = 5e-3          # suddenly latency-bound (mean, jittered)
REPLAN_EVERY = 40


def _modeled_basin() -> DrainageBasin:
    """What the planner believes at transfer start: the smooth regime."""
    return DrainageBasin([
        Tier("store", TierKind.SOURCE, 10.0 * GBPS,
             latency_s=LATENCY_BEFORE_S),
        Tier("staging", TierKind.BURST_BUFFER, 100.0 * GBPS,
             latency_s=10e-6),
        Tier("sink", TierKind.SINK, 40.0 * GBPS, latency_s=10e-6),
    ])


def _make_fetch():
    """Item fetch with a scripted latency-regime shift.  The cost sits in
    the transform (the storage service time), so planned concurrency can
    overlap it — or fail to, when the plan predates the shift."""
    payload = np.random.default_rng(0).integers(
        0, 255, ITEM_BYTES, dtype=np.uint8)
    rng = np.random.default_rng(1)
    count = [0]
    lock = threading.Lock()

    def fetch(_i: int) -> np.ndarray:
        with lock:
            k = count[0]
            count[0] += 1
            jitter = rng.random()
        if k < SHIFT_AT:
            time.sleep(LATENCY_BEFORE_S)
        else:
            # erratic regime: mean LATENCY_AFTER_S, widely dispersed —
            # the high-variance signature of a latency-bound tier
            time.sleep(LATENCY_AFTER_S * (0.25 + 1.5 * jitter))
        return payload

    return fetch


def _run_one(replan_every_items: int):
    plan = plan_transfer(_modeled_basin(), ITEM_BYTES, stages=("fetch",))
    mover = UnifiedDataMover(MoverConfig(checksum=False), plan=plan)
    report = mover.bulk_transfer(
        iter(range(N_ITEMS)), lambda _: None,
        transforms=[("fetch", _make_fetch())],
        replan_every_items=replan_every_items)
    return report, mover


def run() -> None:
    offline, _ = _run_one(0)
    emit("online_replan/offline", offline.elapsed_s * 1e6,
         f"{offline.throughput_bytes_per_s / 1e6:.1f}MB/s")

    online, mover = _run_one(REPLAN_EVERY)
    speedup = (online.throughput_bytes_per_s
               / max(offline.throughput_bytes_per_s, 1e-9))
    final = mover.last_plan.hops[0]
    emit("online_replan/online", online.elapsed_s * 1e6,
         f"{online.throughput_bytes_per_s / 1e6:.1f}MB/s "
         f"x{speedup:.2f}-vs-offline replans={online.replans} "
         f"w={final.workers} cap={final.capacity}")

    # Wall-clock gate, load-tolerant: on a busy shared host the sleep-based
    # regimes compress and the speedup can flatten.  The deterministic
    # (virtual-clock) form of this acceptance claim lives in
    # tests/test_simbasin.py::test_online_replan_recovers_after_regime_shift;
    # here we only hard-fail on a clear regression.
    if online.throughput_bytes_per_s < 0.85 * offline.throughput_bytes_per_s:
        raise SystemExit(
            f"online replanning ({online.throughput_bytes_per_s:.0f} B/s) "
            f"clearly lost to the epoch-boundary path "
            f"({offline.throughput_bytes_per_s:.0f} B/s) on the "
            f"regime-shift scenario")


if __name__ == "__main__":
    print("name,us_per_call,derived")
    run()
