"""Table 5 — daily data volume achievable at common network speeds,
computed from the basin model (and the TPU-side equivalents)."""

from repro.core.basin import GBPS, daily_volume_bytes, paper_basin, recommend_tier

from .common import emit


def run() -> None:
    for gbps, note in [(1, "edge/5G"), (10, "hp-edge"), (100, "core-1PB/day")]:
        vol_tb = daily_volume_bytes(gbps * GBPS) / 1e12
        emit(f"table5/daily_volume_{gbps}gbps", 0.0,
             f"{vol_tb:.1f} TB/day tier={recommend_tier(gbps * GBPS).value}")
    # end-to-end: what the full paper basin actually sustains at 100G
    b = paper_basin(link_gbps=100.0, storage_gbps=40.0)
    rep = b.bottleneck()
    emit("table5/paper_basin_achievable", 0.0,
         f"{rep.achievable_bytes_per_s / GBPS:.1f} Gbps achieved "
         f"(bottleneck={rep.element} gap={rep.fidelity_gap:.2f})")
    b2 = paper_basin(link_gbps=100.0, storage_gbps=250.0)
    emit("table5/codesigned_basin_achievable", 0.0,
         f"{b2.bottleneck().achievable_bytes_per_s / GBPS:.1f} Gbps "
         f"(balanced storage: gap={b2.bottleneck().fidelity_gap:.2f})")
