"""Render EXPERIMENTS.md tables from experiments/dryrun/*.json."""

import json
import os
import sys

D = os.path.join(os.path.dirname(__file__), "dryrun")


def fmt_bytes(b):
    if b is None:
        return "-"
    return f"{b / 2**30:.2f}"


def load():
    recs = []
    for name in sorted(os.listdir(D)):
        if name.endswith(".json"):
            recs.append(json.load(open(os.path.join(D, name))))
    return recs


def dryrun_table(recs, mesh):
    rows = ["| arch | shape | status | compile s | arg+temp GiB/dev | "
            "HLO GFLOP/dev | coll GiB/dev | collectives |",
            "|---|---|---|---|---|---|---|---|"]
    for r in recs:
        if r["mesh"] != mesh:
            continue
        if r["status"] == "skipped":
            rows.append(f"| {r['arch']} | {r['shape']} | SKIP | - | - | - | - | "
                        f"{r['reason'][:40]}… |")
            continue
        rf = r["roofline"]
        ma = r["memory_analysis"]
        mem = (ma["argument_bytes"] + ma["temp_bytes"]) / 2**30
        coll = rf["collective_bytes_per_device"] / 2**30
        byt = ", ".join(f"{k}:{v/2**30:.1f}G"
                        for k, v in sorted(rf["collective_by_type"].items(),
                                           key=lambda kv: -kv[1])[:3])
        rows.append(
            f"| {r['arch']} | {r['shape']} | OK | {r['compile_s']} | "
            f"{mem:.2f} | {rf['flops_per_device']/1e9:.0f} | {coll:.2f} | {byt} |")
    return "\n".join(rows)


def roofline_table(recs, mesh="single"):
    rows = ["| arch | shape | t_compute ms | t_memory ms (raw) | t_coll ms | "
            "dominant | roofline | useful | move-it note |",
            "|---|---|---|---|---|---|---|---|---|"]
    for r in recs:
        if r["mesh"] != mesh or r["status"] != "ok":
            continue
        rf = r["roofline"]
        dom = rf["dominant"]
        note = {
            "compute": "at the envelope — kernel/MXU efficiency next",
            "memory": "fuse/kernelize the hot region; shard or shrink "
                      "resident activations",
            "collective": "reshard (less FSDP gather), overlap, or "
                          "compress the dominant collective",
        }[dom]
        uf = rf.get("useful_compute_fraction")
        rows.append(
            f"| {r['arch']} | {r['shape']} | {rf['t_compute']*1e3:.1f} | "
            f"{rf['t_memory']*1e3:.1f} ({rf['t_memory_raw']*1e3:.1f}) | "
            f"{rf['t_collective']*1e3:.1f} | {dom} | "
            f"{rf['roofline_fraction']:.3f} | "
            f"{uf if uf is None else round(uf, 2)} | {note} |")
    return "\n".join(rows)


if __name__ == "__main__":
    recs = load()
    which = sys.argv[1] if len(sys.argv) > 1 else "all"
    if which in ("all", "dryrun-single"):
        print("### Single-pod (16x16 = 256 chips)\n")
        print(dryrun_table(recs, "single"))
    if which in ("all", "dryrun-multi"):
        print("\n### Multi-pod (2x16x16 = 512 chips)\n")
        print(dryrun_table(recs, "multi"))
    if which in ("all", "roofline"):
        print("\n### Roofline (single-pod)\n")
        print(roofline_table(recs))
