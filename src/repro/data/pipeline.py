"""Training-input pipeline: the drainage basin's headwaters, executable.

The path is   dataset store -> host burst buffer -> device HBM   and it is
built with exactly the machinery the paper prescribes (DESIGN.md §2):

* the *source* (synthetic PRNG stream or a memory-mapped token file) plays
  the erratic production-storage role — it may stall arbitrarily
  (``jitter_s`` injects that for tests/benchmarks),
* a :class:`~repro.core.burst_buffer.BurstBuffer` per hop decouples source
  jitter from the deterministic device feed; depths come from the basin
  model (``DrainageBasin.prefetch_depth``),
* **bulk** mode iterates a finite dataset (epochs); **streaming** mode is
  an endless stream consumed while "produced" — the two paper workload
  classes,
* the consumer never sees the source: it drains the last buffer, so
  transfer cadence emerges from buffer state (decentralized coordination,
  paper §2.2).
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.basin import DrainageBasin, tpu_input_basin
from repro.core.staging import Stage, StagePipeline
from repro.models.config import ModelConfig


@dataclasses.dataclass
class PipelineConfig:
    global_batch: int
    seq_len: int
    mode: str = "streaming"          # bulk | streaming
    staging_capacity: Optional[int] = None   # None -> from basin model
    staging_workers: int = 1    # >1 absorbs more jitter but may reorder
    host_index: int = 0
    host_count: int = 1
    seed: int = 0


class SyntheticTokenSource:
    """Deterministic PRNG token stream (per-host shard of the global batch).

    ``jitter_s`` emulates erratic production storage for latency/jitter
    experiments (paper Fig. 2 analogue)."""

    def __init__(self, cfg: ModelConfig, pc: PipelineConfig, *,
                 n_batches: Optional[int] = None, jitter_s: float = 0.0,
                 jitter_every: int = 3):
        self.cfg = cfg
        self.pc = pc
        self.n_batches = n_batches
        self.jitter_s = jitter_s
        self.jitter_every = jitter_every
        assert pc.global_batch % pc.host_count == 0
        self.batch_per_host = pc.global_batch // pc.host_count

    def __iter__(self) -> Iterator[dict[str, np.ndarray]]:
        rng = np.random.default_rng(self.pc.seed + 7919 * self.pc.host_index)
        i = 0
        while self.n_batches is None or i < self.n_batches:
            if self.jitter_s and i % self.jitter_every == 0:
                time.sleep(self.jitter_s)        # erratic source stall
            yield self._make(rng, i)
            i += 1

    def _make(self, rng: np.random.Generator, i: int) -> dict[str, np.ndarray]:
        cfg, pc = self.cfg, self.pc
        B, S = self.batch_per_host, pc.seq_len
        if cfg.family == "encdec":
            tokens = rng.integers(0, cfg.vocab, (B, S), dtype=np.int32)
            return {"frames": rng.standard_normal((B, S, cfg.d_model)
                                                  ).astype(np.float32),
                    "tokens": tokens,
                    "labels": np.roll(tokens, -1, axis=1)}
        s_text = S - cfg.frontend_len if cfg.frontend else S
        tokens = rng.integers(0, cfg.vocab, (B, s_text), dtype=np.int32)
        batch = {"tokens": tokens, "labels": np.roll(tokens, -1, axis=1)}
        if cfg.frontend:
            batch["extra_embeds"] = rng.standard_normal(
                (B, cfg.frontend_len, cfg.d_model)).astype(np.float32)
        return batch


class FileTokenSource:
    """Memory-mapped flat token file (.bin of uint16/uint32) — the 'data at
    rest' bulk source.  Windows of seq_len+1 give (tokens, labels)."""

    def __init__(self, path: str, cfg: ModelConfig, pc: PipelineConfig,
                 dtype=np.uint16):
        self.data = np.memmap(path, dtype=dtype, mode="r")
        self.cfg, self.pc = cfg, pc
        self.batch_per_host = pc.global_batch // pc.host_count
        span = pc.seq_len + 1
        self.n_windows = (len(self.data) - 1) // pc.seq_len
        self.n_batches = self.n_windows // (self.batch_per_host * pc.host_count)

    def __iter__(self) -> Iterator[dict[str, np.ndarray]]:
        B, S = self.batch_per_host, self.pc.seq_len
        stride = B * self.pc.host_count
        for i in range(self.n_batches):
            rows = []
            for b in range(B):
                w = (i * stride + self.pc.host_index * B + b) * S
                rows.append(np.asarray(self.data[w:w + S + 1], np.int32))
            arr = np.stack(rows)
            yield {"tokens": arr[:, :-1], "labels": arr[:, 1:]}


def make_batch_sharding(mesh, batch_axes: tuple[str, ...]):
    """NamedSharding putting the batch dim over the data axes."""
    from jax.sharding import NamedSharding, PartitionSpec as P

    def shard_for(x: Any):
        spec = P(batch_axes, *([None] * (np.ndim(x) - 1)))
        return NamedSharding(mesh, spec)

    return shard_for


class InputPipeline:
    """source -> [decode stage] -> [staging buffer] -> device feed."""

    def __init__(self, source: Any, *, basin: Optional[DrainageBasin] = None,
                 pc: Optional[PipelineConfig] = None, mesh=None,
                 batch_axes: tuple[str, ...] = ("data",),
                 to_device: bool = True):
        self.source = source
        self.basin = basin or tpu_input_basin()
        self.pc = pc or getattr(source, "pc", PipelineConfig(1, 128))
        self.mesh = mesh
        self.batch_axes = batch_axes
        self.to_device = to_device
        item_bytes = self._estimate_item_bytes()
        cap = self.pc.staging_capacity or self.basin.prefetch_depth(item_bytes)
        cap = max(2, min(cap, 16))
        self._stages = [
            Stage("decode", capacity=cap, workers=self.pc.staging_workers,
                  transform=self._decode),
            Stage("stage", capacity=cap, workers=1,
                  transform=self._place),
        ]
        self._pipeline: Optional[StagePipeline] = None

    def _estimate_item_bytes(self) -> int:
        pc = self.pc
        return int(pc.global_batch / max(1, pc.host_count) * pc.seq_len * 4 * 2)

    def _decode(self, item: dict) -> dict:
        out = {}
        for k, v in item.items():
            if v.dtype == np.float32 and k in ("frames", "extra_embeds"):
                out[k] = v.astype(jnp.bfloat16)
            else:
                out[k] = v
        return out

    def _place(self, item: dict) -> dict:
        if not self.to_device:
            return item
        if self.mesh is not None:
            shard_for = make_batch_sharding(self.mesh, self.batch_axes)
            return {k: jax.device_put(v, shard_for(v)) for k, v in item.items()}
        return {k: jnp.asarray(v) for k, v in item.items()}

    def __iter__(self) -> Iterator[dict]:
        self._pipeline = StagePipeline(iter(self.source), self._stages)
        return iter(self._pipeline)

    def reports(self):
        return self._pipeline.reports() if self._pipeline else []

    def consumer_stall_s(self) -> float:
        """Total time the training step waited on input — the pipeline's
        fidelity-gap contribution (0 when the basin is balanced)."""
        if not self._pipeline:
            return 0.0
        return self._pipeline.output.stats.consumer_stall_s
