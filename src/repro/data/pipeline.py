"""Training-input pipeline: the drainage basin's headwaters, executable.

The path is   dataset store -> host burst buffer -> device HBM   and it is
built with exactly the machinery the paper prescribes (DESIGN.md §2):

* the *source* (synthetic PRNG stream or a memory-mapped token file) plays
  the erratic production-storage role — it may stall arbitrarily
  (``jitter_s`` injects that for tests/benchmarks),
* a :class:`~repro.core.burst_buffer.BurstBuffer` per hop decouples source
  jitter from the deterministic device feed; depths come from the basin
  model (``DrainageBasin.prefetch_depth``),
* **bulk** mode iterates a finite dataset (epochs); **streaming** mode is
  an endless stream consumed while "produced" — the two paper workload
  classes,
* the consumer never sees the source: it drains the last buffer, so
  transfer cadence emerges from buffer state (decentralized coordination,
  paper §2.2).
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.basin import DrainageBasin, sharded_input_basin, \
    tpu_input_basin
from repro.core.mover import TransferReport
from repro.core.planner import TransferPlan, plan_transfer, replan
from repro.core.staging import (ParallelBranchPipeline, Stage, StagePipeline,
                                StageReport, delta_reports, merge_reports)
from repro.core.telemetry import TelemetryRegistry, get_registry
from repro.models.config import ModelConfig


@dataclasses.dataclass
class PipelineConfig:
    global_batch: int
    seq_len: int
    mode: str = "streaming"          # bulk | streaming
    staging_capacity: Optional[int] = None   # None -> from the TransferPlan
    staging_workers: Optional[int] = None    # None -> from the TransferPlan;
    # explicit >1 opts into jitter absorption at the cost of batch order
    host_index: int = 0
    host_count: int = 1
    seed: int = 0
    #: > 0: revise the transfer plan online, every N delivered batches, at
    #: a buffer boundary inside the running stream (0 = only when the
    #: caller invokes replan() between iterations)
    replan_every_items: int = 0


class SyntheticTokenSource:
    """Deterministic PRNG token stream (per-host shard of the global batch).

    ``jitter_s`` emulates erratic production storage for latency/jitter
    experiments (paper Fig. 2 analogue)."""

    def __init__(self, cfg: ModelConfig, pc: PipelineConfig, *,
                 n_batches: Optional[int] = None, jitter_s: float = 0.0,
                 jitter_every: int = 3):
        self.cfg = cfg
        self.pc = pc
        self.n_batches = n_batches
        self.jitter_s = jitter_s
        self.jitter_every = jitter_every
        assert pc.global_batch % pc.host_count == 0
        self.batch_per_host = pc.global_batch // pc.host_count

    def __iter__(self) -> Iterator[dict[str, np.ndarray]]:
        rng = np.random.default_rng(self.pc.seed + 7919 * self.pc.host_index)
        i = 0
        while self.n_batches is None or i < self.n_batches:
            if self.jitter_s and i % self.jitter_every == 0:
                time.sleep(self.jitter_s)        # erratic source stall
            yield self._make(rng, i)
            i += 1

    def _make(self, rng: np.random.Generator, i: int) -> dict[str, np.ndarray]:
        cfg, pc = self.cfg, self.pc
        B, S = self.batch_per_host, pc.seq_len
        if cfg.family == "encdec":
            tokens = rng.integers(0, cfg.vocab, (B, S), dtype=np.int32)
            return {"frames": rng.standard_normal((B, S, cfg.d_model)
                                                  ).astype(np.float32),
                    "tokens": tokens,
                    "labels": np.roll(tokens, -1, axis=1)}
        s_text = S - cfg.frontend_len if cfg.frontend else S
        tokens = rng.integers(0, cfg.vocab, (B, s_text), dtype=np.int32)
        batch = {"tokens": tokens, "labels": np.roll(tokens, -1, axis=1)}
        if cfg.frontend:
            batch["extra_embeds"] = rng.standard_normal(
                (B, cfg.frontend_len, cfg.d_model)).astype(np.float32)
        return batch


class FileTokenSource:
    """Memory-mapped flat token file (.bin of uint16/uint32) — the 'data at
    rest' bulk source.  Windows of seq_len+1 give (tokens, labels)."""

    def __init__(self, path: str, cfg: ModelConfig, pc: PipelineConfig,
                 dtype=np.uint16):
        self.data = np.memmap(path, dtype=dtype, mode="r")
        self.cfg, self.pc = cfg, pc
        self.batch_per_host = pc.global_batch // pc.host_count
        span = pc.seq_len + 1
        self.n_windows = (len(self.data) - 1) // pc.seq_len
        self.n_batches = self.n_windows // (self.batch_per_host * pc.host_count)

    def __iter__(self) -> Iterator[dict[str, np.ndarray]]:
        B, S = self.batch_per_host, self.pc.seq_len
        stride = B * self.pc.host_count
        for i in range(self.n_batches):
            rows = []
            for b in range(B):
                w = (i * stride + self.pc.host_index * B + b) * S
                rows.append(np.asarray(self.data[w:w + S + 1], np.int32))
            arr = np.stack(rows)
            yield {"tokens": arr[:, :-1], "labels": arr[:, 1:]}


def make_batch_sharding(mesh, batch_axes: tuple[str, ...]):
    """NamedSharding putting the batch dim over the data axes."""
    from jax.sharding import NamedSharding, PartitionSpec as P

    def shard_for(x: Any):
        spec = P(batch_axes, *([None] * (np.ndim(x) - 1)))
        return NamedSharding(mesh, spec)

    return shard_for


class InputPipeline:
    """source -> [decode stage] -> [staging buffer] -> device feed.

    Staging depth and concurrency per hop come from a
    :class:`~repro.core.planner.TransferPlan` derived from the basin model
    and the estimated batch size — the planning discipline applied, not
    hand-tuned constants.  Batch order must survive the path (training
    determinism), so the plan is ``ordered`` unless the caller explicitly
    sets ``pc.staging_workers > 1``.  Explicit ``pc.staging_capacity`` /
    ``pc.staging_workers`` remain per-workload overrides.

    Replanning is **online and zero-drain**: with
    ``replan_every_items > 0`` (argument or ``pc.replan_every_items``)
    ONE persistent pipeline serves the whole stream, and every that many
    delivered batches the plan is revised from that window's observed
    stalls and applied to the *running* stages in place (buffer resize,
    worker grow/retire) — no staged batch is dropped, batch order is
    preserved, and the device feed never rides a teardown bubble.  A
    mid-epoch regime shift in the dataset store is answered mid-epoch,
    not at the next epoch.  ``replan()`` remains callable between
    iterations for epoch-cadence revision.

    **Shard fan-in**: pass a *list* of sources and the pipeline plans the
    N-shard -> host merge topology
    (:func:`~repro.core.basin.sharded_input_basin`): one planned pull
    branch per shard, all merging into the shared decode/place path via a
    :class:`~repro.core.staging.ParallelBranchPipeline`.  Per-shard stage
    reports come back tagged ``"shard-k/pull"``, so ``replan()`` revises
    each shard branch independently (one slow shard is attributed, not
    averaged over the fleet).  Batch order is preserved *within* a shard;
    interleaving across shards follows delivery order.  Online segmented
    replanning (``replan_every_items``) applies to the merged decode/place
    tail, with the shard plan revising at the same cadence; the basin (or
    a custom one) must plan exactly one branch per shard source.
    """

    def __init__(self, source: Any, *, basin: Optional[DrainageBasin] = None,
                 pc: Optional[PipelineConfig] = None, mesh=None,
                 batch_axes: tuple[str, ...] = ("data",),
                 to_device: bool = True,
                 plan: Optional[TransferPlan] = None,
                 telemetry: Optional[TelemetryRegistry] = None,
                 replan_every_items: Optional[int] = None):
        self.sources: Optional[list[Any]] = None
        if isinstance(source, (list, tuple)):
            if len(source) > 1:
                self.sources = list(source)
            else:
                source = source[0]
        self.source = source
        if self.sources is not None:
            self.basin = basin or (plan.basin if plan is not None
                                   else sharded_input_basin(len(self.sources)))
        else:
            self.basin = basin or (plan.basin if plan is not None
                                   else tpu_input_basin())
        self.pc = pc or getattr(self.sources[0] if self.sources else source,
                                "pc", PipelineConfig(1, 128))
        self.mesh = mesh
        self.batch_axes = batch_axes
        self.to_device = to_device
        self.telemetry = telemetry if telemetry is not None else get_registry()
        self.replan_every_items = int(
            replan_every_items if replan_every_items is not None
            else getattr(self.pc, "replan_every_items", 0) or 0)
        self.item_bytes = self._estimate_item_bytes()
        ordered = not (self.pc.staging_workers and self.pc.staging_workers > 1)
        #: fan-in only: the multipath plan for the per-shard pull branches
        self.shard_plan: Optional[TransferPlan] = None
        if self.sources is not None:
            self.shard_plan = plan_transfer(
                self.basin, self.item_bytes, stages=("pull",),
                ordered=ordered, path="auto")
            if len(self.shard_plan.branches) != len(self.sources):
                raise ValueError(
                    f"fan-in basin plans {len(self.shard_plan.branches)} "
                    f"branches but {len(self.sources)} shard sources were "
                    "given; pass a basin with one root->sink path per "
                    "shard (e.g. sharded_input_basin(n_shards))")
            # the shared tail (merge tier onward) runs as one linear
            # decode/place pipeline fed by the merged shard branches.
            # The tail starts at the MERGE tier — the first tier common
            # to all root->sink paths — not at branch 0's second tier: a
            # custom fan-in basin may give each shard a private chain
            # deeper than one tier, and slicing ``tiers[1:]`` would plan
            # the shared tail over another branch's private tiers
            tail_basin = self._fanin_tail_basin()
            self.plan = plan or plan_transfer(
                tail_basin, self.item_bytes, stages=("decode", "stage"),
                ordered=ordered, path="auto")
            self._clamp_tail_promise()
        else:
            self.plan = plan or plan_transfer(
                self.basin, self.item_bytes, stages=("decode", "stage"),
                ordered=ordered, path="auto")
        self._shard_pbp: Optional[ParallelBranchPipeline] = None
        #: per-stage totals already consumed by a shard-plan revision
        #: (see _fresh_shard_reports)
        self._shard_seen: dict[str, StageReport] = {}
        #: tail-stage totals already consumed by a live-swap revision
        #: (see _fresh_tail_reports)
        self._tail_seen: dict[str, StageReport] = {}
        self._pipeline: Optional[StagePipeline] = None
        self._t_start: Optional[float] = None
        self._recorded = False
        # the plan whose staging parameters the running pipeline
        # currently carries; replan() revises self.plan, which
        # _apply_plan_live() then applies to the running stages
        self._active_plan = self.plan
        self._delivered = 0

    def _fanin_tail_basin(self) -> DrainageBasin:
        """The linear sub-basin the merged decode/place tail runs over:
        from the merge tier (the first tier every root->sink path
        shares) to the sink.  Built via ``path_basin`` so explicit tail
        links survive — a provisioned bandwidth or an ``rtt_s`` on a
        merge->sink link must reach the tail plan (it is what makes a
        tail hop windowed).  A merge tier that IS the sink leaves no
        chain to plan; the tail then keeps one upstream tier of path 0
        so the basin still models a pull->deliver hop."""
        paths = self.basin.paths()
        common = set(paths[0])
        for p in paths[1:]:
            common &= set(p)
        if not common:
            raise ValueError(
                "fan-in basin has no tier shared by every shard path; "
                "shard branches must merge before the sink")
        first = paths[0]
        merge_idx = next(i for i, name in enumerate(first)
                         if name in common)
        lo = min(merge_idx, len(first) - 2)     # a basin needs >= 2 tiers
        return self.basin.path_basin(first[lo:])

    def _build_stages(self) -> list[Stage]:
        decode_hop = self.plan.hop_for(0, "decode")
        place_hop = self.plan.hop_for(1, "stage")
        cap0 = self.pc.staging_capacity or decode_hop.capacity
        cap1 = self.pc.staging_capacity or place_hop.capacity
        wrk0 = self.pc.staging_workers or decode_hop.workers
        return [
            Stage("decode", capacity=cap0, workers=wrk0,
                  transform=self._decode),
            # device placement stays single-worker: jax.device_put ordering
            Stage("stage", capacity=cap1, workers=1,
                  transform=self._place),
        ]

    def _estimate_item_bytes(self) -> int:
        pc = self.pc
        return int(pc.global_batch / max(1, pc.host_count) * pc.seq_len * 4 * 2)

    def _decode(self, item: dict) -> dict:
        out = {}
        for k, v in item.items():
            if v.dtype == np.float32 and k in ("frames", "extra_embeds"):
                out[k] = v.astype(jnp.bfloat16)
            else:
                out[k] = v
        return out

    def _place(self, item: dict) -> dict:
        if not self.to_device:
            return item
        if self.mesh is not None:
            shard_for = make_batch_sharding(self.mesh, self.batch_axes)
            return {k: jax.device_put(v, shard_for(v)) for k, v in item.items()}
        return {k: jnp.asarray(v) for k, v in item.items()}

    def __iter__(self) -> Iterator[dict]:
        # fresh stages per iteration so the current plan takes effect
        # (and re-iteration after replan() works); _pipeline resets NOW so
        # telemetry queried before the first batch never sees a previous
        # run's stage reports
        self._active_plan = self.plan
        self._pipeline = None
        self._shard_pbp = None
        self._shard_seen = {}
        self._tail_seen = {}
        self._delivered = 0
        self._t_start = time.monotonic()
        self._recorded = False

        if self.sources is not None:
            return self._run_fanin()

        def run() -> Iterator[dict]:
            yield from self._run_segments(iter(self.source))
            self.record_telemetry()

        return run()

    def _run_segments(self, source_it: Iterator[Any]) -> Iterator[dict]:
        """The zero-drain online-replanning protocol, shared by the
        linear and fan-in paths: ONE persistent pipeline serves the whole
        stream; every ``replan_every_items`` delivered batches is an
        accounting-only checkpoint — the window's stall evidence revises
        the plan, and the revision is applied to the *running* stages in
        place (``Stage.resize``), so no staged batch drains and the
        device feed never rides a rebuild bubble."""
        self._pipeline = StagePipeline(source_it, self._build_stages())
        chunk = self.replan_every_items
        boundary = chunk
        for item in self._pipeline:
            self._delivered += 1
            yield item
            if chunk and self._delivered >= boundary:
                boundary += chunk
                self.replan(_fresh_only=True)
                self._apply_plan_live()

    def _apply_plan_live(self) -> None:
        """Apply the revised plan to the running pipeline — the
        zero-drain swap.  Tail stages re-size against the revised tail
        hops (explicit ``pc`` overrides still win, and device placement
        stays single-worker for ordering); fan-in shard pull stages
        re-size against their revised branch hops."""
        if self._pipeline is not None:
            decode_hop = self.plan.hop_for(0, "decode")
            place_hop = self.plan.hop_for(1, "stage")
            for st in self._pipeline.stages:
                if st.name == "decode":
                    st.resize(
                        capacity=self.pc.staging_capacity
                        or decode_hop.capacity,
                        workers=self.pc.staging_workers or decode_hop.workers)
                elif st.name == "stage":
                    st.resize(capacity=self.pc.staging_capacity
                              or place_hop.capacity, workers=1)
        if self._shard_pbp is not None and self.shard_plan is not None:
            for bid, pipe in self._shard_pbp.branches:
                try:
                    b = self.shard_plan.branch(bid)
                except KeyError:
                    continue
                for i, st in enumerate(pipe.stages):
                    hop = b.hop_for(i, st.name)
                    st.resize(capacity=hop.capacity, workers=hop.workers)
        self._active_plan = self.plan

    def _clamp_tail_promise(self) -> None:
        """Fan-in only: the tail plan alone promises the merge-to-device
        rate, but delivery is bounded by the shard branches' conserved
        aggregate — the fidelity gap must measure against the slower of
        the two or it reads ~1.0 even when every tier performs as
        modeled."""
        if self.shard_plan is not None:
            self.plan.planned_bytes_per_s = min(
                self.plan.planned_bytes_per_s,
                self.shard_plan.planned_bytes_per_s)

    def _run_fanin(self) -> Iterator[dict]:
        """One planned pull branch per shard source, merged into the
        shared decode/place tail — the executable N-shard fan-in.

        Online replanning (``replan_every_items``) applies to the merged
        tail zero-drain: the shard branch pipelines AND the decode/place
        stages run continuously, and each revision window re-sizes both
        in place.  The shard plan revises at the same cadence from the
        windowed ``shard-k/pull`` report deltas."""
        branches = []
        for b, src in zip(self.shard_plan.branches, self.sources):
            hop = b.hops[0]
            branches.append((b.branch_id, StagePipeline(
                iter(src),
                [Stage(hop.name, capacity=hop.capacity,
                       workers=hop.workers)])))
        self._shard_pbp = ParallelBranchPipeline(branches)
        merged = (item for _bid, item in self._shard_pbp)
        yield from self._run_segments(merged)
        self._shard_pbp.join()
        self.record_telemetry()

    def reports(self) -> list[StageReport]:
        """Per-stage reports of the current iteration's (persistent)
        pipeline; in fan-in mode the per-shard pull reports (tagged
        ``shard-k/pull``) ride along."""
        live = self._pipeline.reports() if self._pipeline else []
        shard = self._shard_pbp.reports() if self._shard_pbp else []
        return merge_reports([shard, live])

    def record_telemetry(self) -> Optional[TransferReport]:
        """Record the stream's progress so far (for consumers that stop
        before the source exhausts — e.g. a bounded training run).  At
        most one report per iteration of the pipeline."""
        if not self._pipeline or not self._t_start or self._recorded:
            return None
        self._recorded = True
        report = TransferReport(
            mode=self.pc.mode, items=self._delivered,
            bytes=int(self._delivered * self.item_bytes),
            elapsed_s=time.monotonic() - self._t_start,
            stage_reports=self.reports(),
            planned_bytes_per_s=self._active_plan.planned_bytes_per_s)
        self.telemetry.record("input", report)
        return report

    def replan(self, *, damping: float = 0.5,
               _fresh_only: bool = False) -> TransferPlan:
        """Fold observed stall ratios back into the plan (the paper's
        hypothesis -> change -> measure cycle).  Called automatically at
        segment boundaries when ``replan_every_items`` is set; callable
        manually between iterations.  The revised plan takes effect on
        the next segment (online) or iteration (manual).

        With online replanning active, each checkpoint revision consumes
        its window's report deltas, and a manual call between iterations
        sees only the final (not-yet-consumed) window — consumed
        evidence is never re-applied.  A manual call *mid*-window still
        overlaps the upcoming checkpoint fold; keep manual calls between
        iterations.

        In fan-in mode the per-shard branch plan revises too, from the
        ``shard-k/pull``-tagged reports: a single slow shard gets its own
        verdict and loses traffic share, instead of dragging the whole
        shard fleet's estimate down."""
        if _fresh_only or self.replan_every_items:
            reps = self._fresh_tail_reports()
        else:
            reps = self.reports()
        if reps:
            tail = [r for r in reps if "/" not in r.name]
            if tail:
                self.plan = replan(self.plan, tail, damping=damping)
        if self.shard_plan is not None and self._shard_pbp is not None:
            shard_reps = self._fresh_shard_reports()
            if shard_reps:
                self.shard_plan = replan(self.shard_plan, shard_reps,
                                         damping=damping)
        self._clamp_tail_promise()
        return self.plan

    def _fresh_tail_reports(self) -> list[StageReport]:
        """Tail-stage reports covering only the window since the last
        revision (:func:`repro.core.staging.delta_reports` over the
        persistent pipeline's cumulative counters); reservoirs start
        fresh once consumed, so a long-gone regime's samples never keep
        steering later diagnoses."""
        if not self._pipeline:
            return []
        cur = self._pipeline.reports()
        fresh = delta_reports(cur, list(self._tail_seen.values()))
        self._tail_seen = {r.name: r for r in cur}
        for stage in self._pipeline.stages:
            stage.reset_service_reservoirs()
        return fresh

    def _fresh_shard_reports(self) -> list[StageReport]:
        """Shard-branch reports covering only the window since the last
        revision — same protocol as the tail: re-feeding consumed stall
        seconds through ``replan`` at every boundary would re-apply
        evidence and defeat damping, and a consumed window's reservoir
        samples must not keep polluting later diagnoses."""
        cur = self._shard_pbp.reports()
        fresh = delta_reports(cur, list(self._shard_seen.values()))
        self._shard_seen = {r.name: r for r in cur}
        for _, pipe in self._shard_pbp.branches:
            for stage in pipe.stages:
                stage.reset_service_reservoirs()
        return fresh

    def fidelity_gap(self) -> Optional[float]:
        """Live achieved-vs-planned gap of the staging path (<0 means the
        path is beating the plan's promise)."""
        if not self._pipeline or not self._t_start:
            return None
        elapsed = time.monotonic() - self._t_start
        if elapsed <= 0:
            return None
        achieved = self._delivered * self.item_bytes / elapsed
        return 1.0 - achieved / self._active_plan.planned_bytes_per_s

    def consumer_stall_s(self) -> float:
        """Total time the training step waited on input — the pipeline's
        fidelity-gap contribution (0 when the basin is balanced).  The
        zero-drain pipeline persists for the whole iteration, so its
        output buffer's cumulative stall is the whole story."""
        return (self._pipeline.output.stats.consumer_stall_s
                if self._pipeline else 0.0)
