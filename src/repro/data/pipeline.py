"""Training-input pipeline: the drainage basin's headwaters, executable.

The path is   dataset store -> host burst buffer -> device HBM   and it is
built with exactly the machinery the paper prescribes (DESIGN.md §2):

* the *source* (synthetic PRNG stream or a memory-mapped token file) plays
  the erratic production-storage role — it may stall arbitrarily
  (``jitter_s`` injects that for tests/benchmarks),
* a :class:`~repro.core.burst_buffer.BurstBuffer` per hop decouples source
  jitter from the deterministic device feed; depths come from the basin
  model (``DrainageBasin.prefetch_depth``),
* **bulk** mode iterates a finite dataset (epochs); **streaming** mode is
  an endless stream consumed while "produced" — the two paper workload
  classes,
* the consumer never sees the source: it drains the last buffer, so
  transfer cadence emerges from buffer state (decentralized coordination,
  paper §2.2).
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.basin import DrainageBasin, tpu_input_basin
from repro.core.mover import TransferReport
from repro.core.planner import TransferPlan, plan_transfer, replan
from repro.core.staging import (Stage, StagePipeline, StageReport,
                                iter_segments, merge_reports)
from repro.core.telemetry import TelemetryRegistry, get_registry
from repro.models.config import ModelConfig


@dataclasses.dataclass
class PipelineConfig:
    global_batch: int
    seq_len: int
    mode: str = "streaming"          # bulk | streaming
    staging_capacity: Optional[int] = None   # None -> from the TransferPlan
    staging_workers: Optional[int] = None    # None -> from the TransferPlan;
    # explicit >1 opts into jitter absorption at the cost of batch order
    host_index: int = 0
    host_count: int = 1
    seed: int = 0
    #: > 0: revise the transfer plan online, every N delivered batches, at
    #: a buffer boundary inside the running stream (0 = only when the
    #: caller invokes replan() between iterations)
    replan_every_items: int = 0


class SyntheticTokenSource:
    """Deterministic PRNG token stream (per-host shard of the global batch).

    ``jitter_s`` emulates erratic production storage for latency/jitter
    experiments (paper Fig. 2 analogue)."""

    def __init__(self, cfg: ModelConfig, pc: PipelineConfig, *,
                 n_batches: Optional[int] = None, jitter_s: float = 0.0,
                 jitter_every: int = 3):
        self.cfg = cfg
        self.pc = pc
        self.n_batches = n_batches
        self.jitter_s = jitter_s
        self.jitter_every = jitter_every
        assert pc.global_batch % pc.host_count == 0
        self.batch_per_host = pc.global_batch // pc.host_count

    def __iter__(self) -> Iterator[dict[str, np.ndarray]]:
        rng = np.random.default_rng(self.pc.seed + 7919 * self.pc.host_index)
        i = 0
        while self.n_batches is None or i < self.n_batches:
            if self.jitter_s and i % self.jitter_every == 0:
                time.sleep(self.jitter_s)        # erratic source stall
            yield self._make(rng, i)
            i += 1

    def _make(self, rng: np.random.Generator, i: int) -> dict[str, np.ndarray]:
        cfg, pc = self.cfg, self.pc
        B, S = self.batch_per_host, pc.seq_len
        if cfg.family == "encdec":
            tokens = rng.integers(0, cfg.vocab, (B, S), dtype=np.int32)
            return {"frames": rng.standard_normal((B, S, cfg.d_model)
                                                  ).astype(np.float32),
                    "tokens": tokens,
                    "labels": np.roll(tokens, -1, axis=1)}
        s_text = S - cfg.frontend_len if cfg.frontend else S
        tokens = rng.integers(0, cfg.vocab, (B, s_text), dtype=np.int32)
        batch = {"tokens": tokens, "labels": np.roll(tokens, -1, axis=1)}
        if cfg.frontend:
            batch["extra_embeds"] = rng.standard_normal(
                (B, cfg.frontend_len, cfg.d_model)).astype(np.float32)
        return batch


class FileTokenSource:
    """Memory-mapped flat token file (.bin of uint16/uint32) — the 'data at
    rest' bulk source.  Windows of seq_len+1 give (tokens, labels)."""

    def __init__(self, path: str, cfg: ModelConfig, pc: PipelineConfig,
                 dtype=np.uint16):
        self.data = np.memmap(path, dtype=dtype, mode="r")
        self.cfg, self.pc = cfg, pc
        self.batch_per_host = pc.global_batch // pc.host_count
        span = pc.seq_len + 1
        self.n_windows = (len(self.data) - 1) // pc.seq_len
        self.n_batches = self.n_windows // (self.batch_per_host * pc.host_count)

    def __iter__(self) -> Iterator[dict[str, np.ndarray]]:
        B, S = self.batch_per_host, self.pc.seq_len
        stride = B * self.pc.host_count
        for i in range(self.n_batches):
            rows = []
            for b in range(B):
                w = (i * stride + self.pc.host_index * B + b) * S
                rows.append(np.asarray(self.data[w:w + S + 1], np.int32))
            arr = np.stack(rows)
            yield {"tokens": arr[:, :-1], "labels": arr[:, 1:]}


def make_batch_sharding(mesh, batch_axes: tuple[str, ...]):
    """NamedSharding putting the batch dim over the data axes."""
    from jax.sharding import NamedSharding, PartitionSpec as P

    def shard_for(x: Any):
        spec = P(batch_axes, *([None] * (np.ndim(x) - 1)))
        return NamedSharding(mesh, spec)

    return shard_for


class InputPipeline:
    """source -> [decode stage] -> [staging buffer] -> device feed.

    Staging depth and concurrency per hop come from a
    :class:`~repro.core.planner.TransferPlan` derived from the basin model
    and the estimated batch size — the planning discipline applied, not
    hand-tuned constants.  Batch order must survive the path (training
    determinism), so the plan is ``ordered`` unless the caller explicitly
    sets ``pc.staging_workers > 1``.  Explicit ``pc.staging_capacity`` /
    ``pc.staging_workers`` remain per-workload overrides.

    Replanning is **online**: with ``replan_every_items > 0`` (argument or
    ``pc.replan_every_items``) the stream runs in segments of that many
    batches and the plan is revised from observed stalls at each segment
    boundary — a buffer boundary, so no staged batch is dropped and batch
    order is preserved.  A mid-epoch regime shift in the dataset store is
    answered mid-epoch, not at the next epoch.  ``replan()`` remains
    callable between iterations for epoch-cadence revision.
    """

    def __init__(self, source: Any, *, basin: Optional[DrainageBasin] = None,
                 pc: Optional[PipelineConfig] = None, mesh=None,
                 batch_axes: tuple[str, ...] = ("data",),
                 to_device: bool = True,
                 plan: Optional[TransferPlan] = None,
                 telemetry: Optional[TelemetryRegistry] = None,
                 replan_every_items: Optional[int] = None):
        self.source = source
        self.basin = basin or (plan.basin if plan is not None
                               else tpu_input_basin())
        self.pc = pc or getattr(source, "pc", PipelineConfig(1, 128))
        self.mesh = mesh
        self.batch_axes = batch_axes
        self.to_device = to_device
        self.telemetry = telemetry if telemetry is not None else get_registry()
        self.replan_every_items = int(
            replan_every_items if replan_every_items is not None
            else getattr(self.pc, "replan_every_items", 0) or 0)
        self.item_bytes = self._estimate_item_bytes()
        ordered = not (self.pc.staging_workers and self.pc.staging_workers > 1)
        self.plan = plan or plan_transfer(
            self.basin, self.item_bytes, stages=("decode", "stage"),
            ordered=ordered)
        self._pipeline: Optional[StagePipeline] = None
        self._t_start: Optional[float] = None
        self._recorded = False
        # the plan whose staging parameters the running pipeline was
        # built with; replan() revises self.plan for the NEXT segment /
        # iteration, so live metrics must keep measuring against this one
        self._active_plan = self.plan
        # reports of segments whose pipelines already drained (online
        # replanning runs one pipeline per segment); the live pipeline's
        # reports are merged in on demand
        self._prior_reports: list[StageReport] = []
        self._prior_consumer_stall_s = 0.0
        self._delivered = 0

    def _build_stages(self) -> list[Stage]:
        decode_hop = self.plan.hop_for(0, "decode")
        place_hop = self.plan.hop_for(1, "stage")
        cap0 = self.pc.staging_capacity or decode_hop.capacity
        cap1 = self.pc.staging_capacity or place_hop.capacity
        wrk0 = self.pc.staging_workers or decode_hop.workers
        return [
            Stage("decode", capacity=cap0, workers=wrk0,
                  transform=self._decode),
            # device placement stays single-worker: jax.device_put ordering
            Stage("stage", capacity=cap1, workers=1,
                  transform=self._place),
        ]

    def _estimate_item_bytes(self) -> int:
        pc = self.pc
        return int(pc.global_batch / max(1, pc.host_count) * pc.seq_len * 4 * 2)

    def _decode(self, item: dict) -> dict:
        out = {}
        for k, v in item.items():
            if v.dtype == np.float32 and k in ("frames", "extra_embeds"):
                out[k] = v.astype(jnp.bfloat16)
            else:
                out[k] = v
        return out

    def _place(self, item: dict) -> dict:
        if not self.to_device:
            return item
        if self.mesh is not None:
            shard_for = make_batch_sharding(self.mesh, self.batch_axes)
            return {k: jax.device_put(v, shard_for(v)) for k, v in item.items()}
        return {k: jnp.asarray(v) for k, v in item.items()}

    def __iter__(self) -> Iterator[dict]:
        # fresh stages per iteration so the current plan takes effect
        # (and re-iteration after replan() works); _pipeline resets NOW so
        # telemetry queried before the first batch never sees a previous
        # run's stage reports
        self._active_plan = self.plan
        self._pipeline = None
        self._prior_reports = []
        self._prior_consumer_stall_s = 0.0
        self._delivered = 0
        self._t_start = time.monotonic()
        self._recorded = False

        def run() -> Iterator[dict]:
            for segment in iter_segments(iter(self.source),
                                         self.replan_every_items):
                if self._pipeline is not None:
                    # segment boundary == buffer boundary: every staged
                    # batch was delivered, so the plan can swap without
                    # loss; fold the drained segment's stalls into the
                    # next plan before building it
                    self.replan(_fresh_only=True)
                    self._prior_reports = merge_reports(
                        [self._prior_reports, self._pipeline.reports()])
                    self._prior_consumer_stall_s += \
                        self._pipeline.output.stats.consumer_stall_s
                self._pipeline = StagePipeline(segment, self._build_stages())
                for item in self._pipeline:
                    self._delivered += 1
                    yield item
            self.record_telemetry()

        return run()

    def reports(self) -> list[StageReport]:
        """Per-stage reports merged over every segment run so far."""
        live = self._pipeline.reports() if self._pipeline else []
        return merge_reports([self._prior_reports, live])

    def record_telemetry(self) -> Optional[TransferReport]:
        """Record the stream's progress so far (for consumers that stop
        before the source exhausts — e.g. a bounded training run).  At
        most one report per iteration of the pipeline."""
        if not self._pipeline or not self._t_start or self._recorded:
            return None
        self._recorded = True
        report = TransferReport(
            mode=self.pc.mode, items=self._delivered,
            bytes=int(self._delivered * self.item_bytes),
            elapsed_s=time.monotonic() - self._t_start,
            stage_reports=self.reports(),
            planned_bytes_per_s=self._active_plan.planned_bytes_per_s)
        self.telemetry.record("input", report)
        return report

    def replan(self, *, damping: float = 0.5,
               _fresh_only: bool = False) -> TransferPlan:
        """Fold observed stall ratios back into the plan (the paper's
        hypothesis -> change -> measure cycle).  Called automatically at
        segment boundaries when ``replan_every_items`` is set; callable
        manually between iterations.  The revised plan takes effect on
        the next segment (online) or iteration (manual).

        With online replanning active, each boundary revision consumes
        its segment's reports, and a manual call between iterations sees
        only the final segment (the one no boundary folded) — already-
        consumed segments are not re-applied.  A manual call *mid*-
        segment still overlaps the upcoming boundary fold; keep manual
        calls between iterations."""
        if _fresh_only or self.replan_every_items:
            reps = self._pipeline.reports() if self._pipeline else []
        else:
            reps = self.reports()
        if reps:
            self.plan = replan(self.plan, reps, damping=damping)
        return self.plan

    def fidelity_gap(self) -> Optional[float]:
        """Live achieved-vs-planned gap of the staging path (<0 means the
        path is beating the plan's promise)."""
        if not self._pipeline or not self._t_start:
            return None
        elapsed = time.monotonic() - self._t_start
        if elapsed <= 0:
            return None
        achieved = self._delivered * self.item_bytes / elapsed
        return 1.0 - achieved / self._active_plan.planned_bytes_per_s

    def consumer_stall_s(self) -> float:
        """Total time the training step waited on input — the pipeline's
        fidelity-gap contribution (0 when the basin is balanced)."""
        live = (self._pipeline.output.stats.consumer_stall_s
                if self._pipeline else 0.0)
        return self._prior_consumer_stall_s + live
