from .pipeline import (FileTokenSource, InputPipeline, PipelineConfig,
                       SyntheticTokenSource, make_batch_sharding)

__all__ = ["FileTokenSource", "InputPipeline", "PipelineConfig",
           "SyntheticTokenSource", "make_batch_sharding"]
