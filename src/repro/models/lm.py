"""Decoder-only language models: dense / MoE / SSM / hybrid / VLM.

Every homogeneous layer stack runs as ``jax.lax.scan`` over stacked layer
parameters, so compile time (and the dry-run matrix) is O(1) in depth.
Heterogeneity is handled without breaking the scan:

* per-layer attention windows (gemma3's 5:1 local:global) ride through the
  scan as an ``int32`` xs array feeding the mask,
* zamba2's shared attention block (one set of weights applied every
  ``attn_every`` layers) splits the Mamba stack into segments, scanning
  each segment and applying the shared block between segments,
* decode caches travel through the scan as xs/ys (sliced per layer on the
  way in, restacked on the way out), keeping serve_step compile-time flat.

Remat policy (cfg.remat): 'full' checkpoints each layer body (only layer
boundaries persist for backward), 'dots' saves matmul outputs, 'none'
stores everything.
"""

from __future__ import annotations

import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp

from . import ssm as ssm_lib
from .attention import (attention, cache_positions_full, cache_positions_ring,
                        cache_update_full, cache_update_ring)
from .blocks import (ShardCtx, dense_layer_apply, init_dense_layer,
                     init_mamba_layer, init_moe_layer, moe_layer_apply,
                     stack_layers)
from .common import (apply_rope, cross_entropy_loss, dense_init, embed_init,
                     rms_norm)
from .config import ModelConfig


def _remat(fn, mode: str):
    if mode == "none":
        return fn
    if mode == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    return jax.checkpoint(fn)


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------


def init_lm(cfg: ModelConfig, key: jax.Array) -> dict:
    cfg.validate()
    keys = jax.random.split(key, 8)
    D, V = cfg.d_model, cfg.vocab
    params: dict[str, Any] = {"embed": embed_init(keys[0], (V, D))}
    kind = {"dense": "attn", "vlm": "attn", "moe": "moe",
            "ssm": "mamba", "hybrid": "mamba"}[cfg.family]
    params["layers"] = stack_layers(keys[1], cfg, cfg.n_layers, kind)
    if cfg.family == "hybrid":
        shared = init_dense_layer(keys[2], cfg)
        params["shared_attn"] = shared
    if cfg.family in ("ssm", "hybrid"):
        # mamba layers need a pre-norm scale
        params["layers"]["ln"] = jnp.zeros((cfg.n_layers, D), jnp.float32)
    if cfg.frontend:
        params["projector"] = {
            "w1": dense_init(keys[3], (D, D), D),
            "w2": dense_init(keys[4], (D, D), D),
        }
    params["final_norm"] = jnp.zeros((D,), jnp.float32)
    if not cfg.tie_embeddings:
        params["lm_head"] = dense_init(keys[5], (D, V), D)
    return params


# ---------------------------------------------------------------------------
# Forward (train / prefill)
# ---------------------------------------------------------------------------


def _embed_inputs(params: dict, cfg: ModelConfig, tokens: jax.Array,
                  ctx: ShardCtx, extra_embeds: Optional[jax.Array]) -> jax.Array:
    x = params["embed"][tokens]
    if cfg.frontend:
        assert extra_embeds is not None, "frontend arch needs stub embeddings"
        fe = extra_embeds.astype(x.dtype)
        h = jnp.einsum("bnd,de->bne", fe, params["projector"]["w1"])
        h = jax.nn.gelu(h.astype(jnp.float32)).astype(x.dtype)
        fe = jnp.einsum("bnd,de->bne", h, params["projector"]["w2"])
        x = jnp.concatenate([fe, x], axis=1)
    return ctx.shard_act(x)


def _logits(params: dict, cfg: ModelConfig, x: jax.Array) -> jax.Array:
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    return jnp.einsum("bsd,dv->bsv", x, head)


def _mamba_layer_apply(x, lp, cfg, ctx):
    h = rms_norm(x, lp["ln"], cfg.norm_eps)
    y = ssm_lib.mamba_block_train(h, lp, cfg, impl=ctx.impl,
                                  shard_heads=ctx.shard_heads)
    return ctx.shard_act(x + y)


def _scan_stack(x, layers, cfg, ctx, positions, windows, body_kind,
                n_layers=None):
    """Scan a homogeneous stack.  Returns (x, lb_sum, z_sum)."""

    def dense_body(carry, xs):
        h, lb, z = carry
        lp, w = xs
        h = dense_layer_apply(h, lp, cfg, ctx, positions=positions, window=w)
        return (h, lb, z), None

    def moe_body(carry, xs):
        h, lb, z = carry
        lp, w = xs
        h, lbi, zi = moe_layer_apply(h, lp, cfg, ctx, positions=positions,
                                     window=w)
        return (h, lb + lbi, z + zi), None

    def mamba_body(carry, xs):
        h, lb, z = carry
        lp, w = xs
        h = _mamba_layer_apply(h, lp, cfg, ctx)
        return (h, lb, z), None

    body = {"attn": dense_body, "moe": moe_body, "mamba": mamba_body}[body_kind]
    body = _remat(body, cfg.remat)
    zero = jnp.zeros((), jnp.float32)
    (x, lb, z), _ = jax.lax.scan(body, (x, zero, zero), (layers, windows))
    return x, lb, z


def forward_lm(params: dict, cfg: ModelConfig, tokens: jax.Array,
               ctx: ShardCtx, *, extra_embeds: Optional[jax.Array] = None
               ) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Full-sequence forward.  Returns (logits, lb_loss, z_loss)."""
    x = _embed_inputs(params, cfg, tokens, ctx, extra_embeds)
    S = x.shape[1]
    positions = jnp.arange(S, dtype=jnp.int32)
    windows = jnp.asarray(cfg.layer_windows(), jnp.int32)

    if cfg.family in ("dense", "vlm"):
        x, lb, z = _scan_stack(x, params["layers"], cfg, ctx, positions,
                               windows, "attn")
    elif cfg.family == "moe":
        x, lb, z = _scan_stack(x, params["layers"], cfg, ctx, positions,
                               windows, "moe")
    elif cfg.family == "ssm":
        x, lb, z = _scan_stack(x, params["layers"], cfg, ctx, positions,
                               windows, "mamba")
    elif cfg.family == "hybrid":
        x, lb, z = _hybrid_forward(params, cfg, x, ctx, positions, windows)
    else:
        raise ValueError(cfg.family)
    return _logits(params, cfg, x), lb, z


def _segment_bounds(n_layers: int, every: int) -> list[tuple[int, int]]:
    bounds, start = [], 0
    while start < n_layers:
        bounds.append((start, min(start + every, n_layers)))
        start += every
    return bounds


def _slice_layers(layers: dict, lo: int, hi: int) -> dict:
    return jax.tree.map(lambda a: jax.lax.slice_in_dim(a, lo, hi, axis=0),
                        layers)


def _hybrid_forward(params, cfg, x, ctx, positions, windows):
    """Zamba2 pattern: Mamba segments with a shared attention block between
    (same weights at every application site)."""
    zero = jnp.zeros((), jnp.float32)
    lb = z = zero
    shared_window = cfg.window  # 0 (full) normally; ring window for long ctx
    for lo, hi in _segment_bounds(cfg.n_layers, cfg.attn_every or cfg.n_layers):
        seg = _slice_layers(params["layers"], lo, hi)
        x, lbi, zi = _scan_stack(x, seg, cfg, ctx, positions,
                                 windows[lo:hi], "mamba")
        lb, z = lb + lbi, z + zi
        if hi < cfg.n_layers or hi == cfg.n_layers:
            x = dense_layer_apply(x, params["shared_attn"], cfg, ctx,
                                  positions=positions, window=shared_window)
    return x, lb, z


# ---------------------------------------------------------------------------
# Prefill (serving: forward + cache population)
# ---------------------------------------------------------------------------


def _ring_pack(k_full: jax.Array, window: int) -> jax.Array:
    """Arrange the last `window` steps of (B, S, ...) into ring-slot order."""
    S = k_full.shape[1]
    if S <= window:
        pad = [(0, 0)] * k_full.ndim
        pad[1] = (0, window - S)
        return jnp.pad(k_full, pad)
    j = jnp.arange(window)
    p = (S - 1) - jnp.mod((S - 1) - j, window)
    return jnp.take(k_full, p, axis=1)


def prefill_lm(params: dict, cfg: ModelConfig, tokens: jax.Array,
               ctx: ShardCtx, max_len: int,
               extra_embeds: Optional[jax.Array] = None
               ) -> tuple[jax.Array, dict]:
    """Run the prompt through the stack, returning (last-token logits,
    populated decode cache).  This is the serving 'bulk' phase: the cache
    is staged once, decode then streams against it."""
    x = _embed_inputs(params, cfg, tokens, ctx, extra_embeds)
    B, S, _ = x.shape
    positions = jnp.arange(S, dtype=jnp.int32)
    windows = jnp.asarray(cfg.layer_windows(), jnp.int32)
    cache = init_lm_cache(cfg, B, max_len, ctx)
    ring = cache_kind(cfg) == "ring"
    s_cache = _attn_cache_len(cfg, max_len)

    if cfg.family in ("dense", "vlm", "moe"):
        is_moe = cfg.family == "moe"

        def body(carry, xs):
            h = carry
            lp, w = xs
            hn = rms_norm(h, lp["ln1"], cfg.norm_eps)
            from .blocks import self_attention_block
            attn_out, k_new, v_new = self_attention_block(
                hn, lp["attn"], cfg, ctx, q_pos=positions, k_pos=positions,
                causal=True, window=w)
            h = ctx.shard_act(h + attn_out)
            h2 = rms_norm(h, lp["ln2"], cfg.norm_eps)
            from . import ffn as ffn_lib
            if is_moe:
                moe_p = lp["moe"]
                impl = ctx.choose_moe(cfg)
                if impl == "ep":
                    y, _, _ = ffn_lib.moe_ep(h2, moe_p["router"],
                                             moe_p["w_gate"], moe_p["w_up"],
                                             moe_p["w_down"], cfg=cfg,
                                             mesh=ctx.mesh,
                                             batch_axes=ctx.batch_axes,
                                             model_axis=ctx.model_axis)
                elif impl == "tp":
                    y, _, _ = ffn_lib.moe_tp(h2, moe_p["router"],
                                             moe_p["w_gate"], moe_p["w_up"],
                                             moe_p["w_down"], cfg=cfg,
                                             mesh=ctx.mesh,
                                             batch_axes=ctx.batch_axes,
                                             model_axis=ctx.model_axis)
                else:
                    y, _, _ = ffn_lib.moe_ref(h2, moe_p["router"],
                                              moe_p["w_gate"], moe_p["w_up"],
                                              moe_p["w_down"], cfg=cfg)
            else:
                y = ffn_lib.swiglu(h2, lp["mlp"]["w_gate"], lp["mlp"]["w_up"],
                                   lp["mlp"]["w_down"])
            h = ctx.shard_act(h + y)
            if ring:
                k_c = _ring_pack(k_new, s_cache)
                v_c = _ring_pack(v_new, s_cache)
            else:
                pad = [(0, 0)] * 4
                pad[1] = (0, max_len - S)
                k_c = jnp.pad(k_new, pad)
                v_c = jnp.pad(v_new, pad)
            return h, (k_c.astype(jnp.bfloat16), v_c.astype(jnp.bfloat16))

        body = _remat(body, cfg.remat)
        x, (k_all, v_all) = jax.lax.scan(body, x, (params["layers"], windows))
        cache["k"], cache["v"] = k_all, v_all

    elif cfg.family == "ssm":
        def body(h, xs):
            lp, w = xs
            hn = rms_norm(h, lp["ln"], cfg.norm_eps)
            y, st = ssm_lib.mamba_block_train(
                hn, lp, cfg, impl=ctx.impl, shard_heads=ctx.shard_heads,
                return_state=True)
            return ctx.shard_act(h + y), (st.conv, st.ssm)

        body = _remat(body, cfg.remat)
        x, (conv_all, ssm_all) = jax.lax.scan(body, x,
                                              (params["layers"], windows))
        cache["mamba"] = ssm_lib.MambaState(conv=conv_all, ssm=ssm_all)

    elif cfg.family == "hybrid":
        x, cache = _hybrid_prefill(params, cfg, x, ctx, positions, windows,
                                   cache, s_cache)
    else:
        raise ValueError(cfg.family)

    cache["pos"] = jnp.asarray(S, jnp.int32)
    logits = _logits(params, cfg, x[:, -1:, :])
    return logits, cache


def _hybrid_prefill(params, cfg, x, ctx, positions, windows, cache, s_cache):
    from .blocks import self_attention_block
    from . import ffn as ffn_lib
    S = x.shape[1]
    conv_out, ssm_out, k_sites, v_sites = [], [], [], []

    def seg_body(h, xs):
        lp, w = xs
        hn = rms_norm(h, lp["ln"], cfg.norm_eps)
        y, st = ssm_lib.mamba_block_train(
            hn, lp, cfg, impl=ctx.impl, shard_heads=ctx.shard_heads,
            return_state=True)
        return ctx.shard_act(h + y), (st.conv, st.ssm)

    seg_body = _remat(seg_body, cfg.remat)
    for lo, hi in _segment_bounds(cfg.n_layers, cfg.attn_every or cfg.n_layers):
        seg = _slice_layers(params["layers"], lo, hi)
        x, (conv_n, ssm_n) = jax.lax.scan(seg_body, x, (seg, windows[lo:hi]))
        conv_out.append(conv_n)
        ssm_out.append(ssm_n)
        sp = params["shared_attn"]
        hn = rms_norm(x, sp["ln1"], cfg.norm_eps)
        attn_out, k_new, v_new = self_attention_block(
            hn, sp["attn"], cfg, ctx, q_pos=positions, k_pos=positions,
            causal=True, window=cfg.window)
        x = ctx.shard_act(x + attn_out)
        h2 = rms_norm(x, sp["ln2"], cfg.norm_eps)
        x = ctx.shard_act(x + ffn_lib.swiglu(h2, sp["mlp"]["w_gate"],
                                             sp["mlp"]["w_up"],
                                             sp["mlp"]["w_down"]))
        if cfg.window > 0:
            k_sites.append(_ring_pack(k_new, s_cache).astype(jnp.bfloat16))
            v_sites.append(_ring_pack(v_new, s_cache).astype(jnp.bfloat16))
        else:
            pad = [(0, 0)] * 4
            pad[1] = (0, cache["shared_k"].shape[2] - S)
            k_sites.append(jnp.pad(k_new, pad).astype(jnp.bfloat16))
            v_sites.append(jnp.pad(v_new, pad).astype(jnp.bfloat16))

    cache["mamba"] = ssm_lib.MambaState(conv=jnp.concatenate(conv_out, 0),
                                        ssm=jnp.concatenate(ssm_out, 0))
    cache["shared_k"] = jnp.stack(k_sites)
    cache["shared_v"] = jnp.stack(v_sites)
    return x, cache


# ---------------------------------------------------------------------------
# Loss
# ---------------------------------------------------------------------------


def lm_loss(params: dict, cfg: ModelConfig, batch: dict, ctx: ShardCtx
            ) -> tuple[jax.Array, dict]:
    logits, lb, z = forward_lm(params, cfg, batch["tokens"], ctx,
                               extra_embeds=batch.get("extra_embeds"))
    labels = batch["labels"]
    if cfg.frontend:
        # frontend positions carry no labels: score only the token tail
        logits = logits[:, -labels.shape[1]:]
    ce = cross_entropy_loss(logits, labels, batch.get("loss_mask"))
    aux = {"ce": ce, "load_balance": lb, "router_z": z}
    total = ce
    if cfg.moe:
        total = total + cfg.moe.load_balance_coef * lb + cfg.moe.router_z_coef * z
    return total, aux


# ---------------------------------------------------------------------------
# Decode (serve_step)
# ---------------------------------------------------------------------------


def cache_kind(cfg: ModelConfig) -> str:
    """'ring' when every attention layer is windowed (mixtral SWA);
    'full' otherwise (per-layer windows still masked inside a full cache)."""
    if cfg.window > 0 and cfg.global_every == 0:
        return "ring"
    return "full"


def _attn_cache_len(cfg: ModelConfig, max_len: int) -> int:
    return min(cfg.window, max_len) if cache_kind(cfg) == "ring" else max_len


def init_lm_cache(cfg: ModelConfig, batch: int, max_len: int,
                  ctx: Optional[ShardCtx] = None) -> dict:
    """Decode cache pytree.  Shapes are static; `pos` tracks the clock."""
    ctx = ctx or ShardCtx()
    cache: dict[str, Any] = {"pos": jnp.zeros((), jnp.int32)}
    L = cfg.n_layers
    if cfg.family in ("dense", "vlm", "moe"):
        s = _attn_cache_len(cfg, max_len)
        kv = jnp.zeros((L, batch, s, cfg.n_kv_heads, cfg.hd), jnp.bfloat16)
        cache["k"] = ctx.shard_kv_cache(kv, seq_axis=2)
        cache["v"] = ctx.shard_kv_cache(kv, seq_axis=2)
    elif cfg.family in ("ssm", "hybrid"):
        st = ssm_lib.init_mamba_state(cfg, batch)
        cache["mamba"] = ssm_lib.MambaState(
            conv=jnp.zeros((L,) + st.conv.shape, st.conv.dtype),
            ssm=jnp.zeros((L,) + st.ssm.shape, st.ssm.dtype),
        )
        if cfg.family == "hybrid":
            n_sites = len(_segment_bounds(cfg.n_layers,
                                          cfg.attn_every or cfg.n_layers))
            s = min(cfg.window, max_len) if cfg.window > 0 else max_len
            kv = jnp.zeros((n_sites, batch, s, cfg.n_kv_heads, cfg.hd),
                           jnp.bfloat16)
            cache["shared_k"] = ctx.shard_kv_cache(kv, seq_axis=2)
            cache["shared_v"] = ctx.shard_kv_cache(kv, seq_axis=2)
    return cache


def _decode_attn_block(x, lp, cfg, ctx, k_cache, v_cache, pos, window,
                       ring_len: int):
    """One decode step through one attention layer against its cache.
    Returns (x_out, k_cache', v_cache')."""
    from .blocks import self_attention_block  # local to avoid cycle at import

    h = rms_norm(x, lp["ln1"], cfg.norm_eps)
    B = x.shape[0]
    q_pos = jnp.broadcast_to(pos, (1,)).astype(jnp.int32)
    q = jnp.einsum("bsd,dq->bsq", h, lp["attn"]["wq"]).reshape(
        B, 1, cfg.n_heads, cfg.hd)
    k = jnp.einsum("bsd,dk->bsk", h, lp["attn"]["wk"]).reshape(
        B, 1, cfg.n_kv_heads, cfg.hd)
    v = jnp.einsum("bsd,dk->bsk", h, lp["attn"]["wv"]).reshape(
        B, 1, cfg.n_kv_heads, cfg.hd)
    q = apply_rope(q, q_pos, cfg.rope_theta)
    k = apply_rope(k, q_pos, cfg.rope_theta)
    s_cache = k_cache.shape[1]
    if ring_len > 0:
        k_cache, v_cache = cache_update_ring(k_cache, v_cache, k, v, pos,
                                             ring_len)
        k_pos = cache_positions_ring(ring_len, pos)
    else:
        k_cache, v_cache = cache_update_full(k_cache, v_cache, k, v, pos)
        k_pos = cache_positions_full(s_cache, pos)
    out = attention(q, k_cache, v_cache, q_pos=q_pos, k_pos=k_pos,
                    causal=True, window=window, impl="ref")
    out = out.reshape(B, 1, cfg.q_dim)
    x = x + jnp.einsum("bsq,qd->bsd", out, lp["attn"]["wo"])
    return x, k_cache, v_cache


def lm_decode_step(params: dict, cfg: ModelConfig, cache: dict,
                   tokens: jax.Array, ctx: ShardCtx
                   ) -> tuple[jax.Array, dict]:
    """One new token per sequence.  tokens: (B, 1).  Returns (logits, cache')."""
    from . import ffn as ffn_lib

    pos = cache["pos"]
    x = ctx.shard_act(params["embed"][tokens])
    new_cache = dict(cache)
    ring = cfg.window if cache_kind(cfg) == "ring" else 0
    windows = jnp.asarray(cfg.layer_windows(), jnp.int32)

    if cfg.family in ("dense", "vlm", "moe"):
        is_moe = cfg.family == "moe"

        def body(h, xs):
            lp, k_l, v_l, w = xs
            h, k_l, v_l = _decode_attn_block(h, lp, cfg, ctx, k_l, v_l, pos,
                                             w, ring)
            h2 = rms_norm(h, lp["ln2"], cfg.norm_eps)
            if is_moe:
                moe = lp["moe"]
                impl = ctx.choose_moe(cfg)
                if impl == "ep":
                    y, _, _ = ffn_lib.moe_ep(h2, moe["router"], moe["w_gate"],
                                             moe["w_up"], moe["w_down"],
                                             cfg=cfg, mesh=ctx.mesh,
                                             batch_axes=ctx.batch_axes,
                                             model_axis=ctx.model_axis)
                elif impl == "tp":
                    y, _, _ = ffn_lib.moe_tp(h2, moe["router"], moe["w_gate"],
                                             moe["w_up"], moe["w_down"],
                                             cfg=cfg, mesh=ctx.mesh,
                                             batch_axes=ctx.batch_axes,
                                             model_axis=ctx.model_axis)
                else:
                    y, _, _ = ffn_lib.moe_ref(h2, moe["router"], moe["w_gate"],
                                              moe["w_up"], moe["w_down"],
                                              cfg=cfg)
            else:
                y = ffn_lib.swiglu(h2, lp["mlp"]["w_gate"], lp["mlp"]["w_up"],
                                   lp["mlp"]["w_down"])
            return h + y, (k_l, v_l)

        x, (k_new, v_new) = jax.lax.scan(
            body, x, (params["layers"], cache["k"], cache["v"], windows))
        new_cache["k"], new_cache["v"] = k_new, v_new

    elif cfg.family == "ssm":
        def body(h, xs):
            lp, conv_l, ssm_l = xs
            hn = rms_norm(h, lp["ln"], cfg.norm_eps)
            y, st = ssm_lib.mamba_block_decode(
                hn, lp, cfg, ssm_lib.MambaState(conv=conv_l, ssm=ssm_l))
            return h + y, (st.conv, st.ssm)

        x, (conv_new, ssm_new) = jax.lax.scan(
            body, x, (params["layers"], cache["mamba"].conv,
                      cache["mamba"].ssm))
        new_cache["mamba"] = ssm_lib.MambaState(conv=conv_new, ssm=ssm_new)

    elif cfg.family == "hybrid":
        x, new_cache = _hybrid_decode(params, cfg, cache, x, ctx, pos)

    else:
        raise ValueError(cfg.family)

    logits = _logits(params, cfg, x)
    new_cache["pos"] = pos + 1
    return logits, new_cache


def _hybrid_decode(params, cfg, cache, x, ctx, pos):
    from . import ffn as ffn_lib

    new_cache = dict(cache)
    bounds = _segment_bounds(cfg.n_layers, cfg.attn_every or cfg.n_layers)
    ring = cfg.window if cfg.window > 0 else 0
    conv_all, ssm_all = cache["mamba"].conv, cache["mamba"].ssm
    conv_out, ssm_out = [], []
    k_sites, v_sites = [], []

    def seg_body(h, xs):
        lp, conv_l, ssm_l = xs
        hn = rms_norm(h, lp["ln"], cfg.norm_eps)
        y, st = ssm_lib.mamba_block_decode(
            hn, lp, cfg, ssm_lib.MambaState(conv=conv_l, ssm=ssm_l))
        return h + y, (st.conv, st.ssm)

    for i, (lo, hi) in enumerate(bounds):
        seg = _slice_layers(params["layers"], lo, hi)
        conv_seg = jax.lax.slice_in_dim(conv_all, lo, hi, axis=0)
        ssm_seg = jax.lax.slice_in_dim(ssm_all, lo, hi, axis=0)
        x, (conv_n, ssm_n) = jax.lax.scan(seg_body, x, (seg, conv_seg, ssm_seg))
        conv_out.append(conv_n)
        ssm_out.append(ssm_n)
        # shared attention block at the segment boundary
        sp = params["shared_attn"]
        k_l = cache["shared_k"][i]
        v_l = cache["shared_v"][i]
        x, k_l, v_l = _decode_attn_block(x, sp, cfg, ctx, k_l, v_l, pos,
                                         cfg.window, ring)
        h2 = rms_norm(x, sp["ln2"], cfg.norm_eps)
        x = x + ffn_lib.swiglu(h2, sp["mlp"]["w_gate"], sp["mlp"]["w_up"],
                               sp["mlp"]["w_down"])
        k_sites.append(k_l)
        v_sites.append(v_l)

    new_cache["mamba"] = ssm_lib.MambaState(
        conv=jnp.concatenate(conv_out, axis=0),
        ssm=jnp.concatenate(ssm_out, axis=0))
    new_cache["shared_k"] = jnp.stack(k_sites)
    new_cache["shared_v"] = jnp.stack(v_sites)
    return x, new_cache
