"""Shared model primitives: norms, RoPE, initializers, dtype policy."""

from __future__ import annotations

import jax
import jax.numpy as jnp

PARAM_DTYPE = jnp.bfloat16
COMPUTE_DTYPE = jnp.bfloat16


def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-5) -> jax.Array:
    """RMSNorm with f32 statistics (numerics policy: reductions in f32)."""
    dtype = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps)
    return (y * (1.0 + scale.astype(jnp.float32))).astype(dtype)


def rope_freqs(hd: int, theta: float) -> jax.Array:
    """Inverse frequencies for rotary embeddings (half-split convention)."""
    return 1.0 / (theta ** (jnp.arange(0, hd, 2, dtype=jnp.float32) / hd))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """Rotary position embedding.

    x: (..., S, H, hd); positions: broadcastable to (..., S) absolute ids.
    Half-split (LLaMA) convention: rotate [x1, x2] halves.
    """
    hd = x.shape[-1]
    inv = rope_freqs(hd, theta)                       # (hd/2,)
    ang = positions.astype(jnp.float32)[..., None] * inv  # (..., S, hd/2)
    cos = jnp.cos(ang)[..., None, :]                  # (..., S, 1, hd/2)
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def dense_init(key: jax.Array, shape: tuple[int, ...], in_dim: int,
               dtype=PARAM_DTYPE) -> jax.Array:
    """Truncated-normal fan-in init (std = 1/sqrt(in_dim))."""
    std = in_dim ** -0.5
    return (jax.random.truncated_normal(key, -3.0, 3.0, shape, jnp.float32)
            * std).astype(dtype)


def embed_init(key: jax.Array, shape: tuple[int, ...], dtype=PARAM_DTYPE) -> jax.Array:
    return (jax.random.truncated_normal(key, -3.0, 3.0, shape, jnp.float32)).astype(dtype)


def split_keys(key: jax.Array, n: int) -> list[jax.Array]:
    return list(jax.random.split(key, n))


def cross_entropy_loss(logits: jax.Array, labels: jax.Array,
                       mask: jax.Array | None = None,
                       z_loss_coef: float = 1e-4) -> jax.Array:
    """Token-mean CE in f32 with optional z-loss (logit drift control)."""
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    ce = lse - gold
    if z_loss_coef:
        ce = ce + z_loss_coef * jnp.square(lse)
    if mask is not None:
        mask = mask.astype(jnp.float32)
        return jnp.sum(ce * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.mean(ce)
