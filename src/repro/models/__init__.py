"""Model zoo: pure-JAX scan-over-layers implementations of the assigned
architectures (dense GQA / MoE / Mamba2-SSD / hybrid / enc-dec / VLM)."""

from .api import SHAPES, ModelApi, ShapeSpec, build
from .blocks import ShardCtx
from .config import ModelConfig, MoEConfig, SSMConfig, smoke_variant

__all__ = [
    "SHAPES", "ModelApi", "ShapeSpec", "build", "ShardCtx",
    "ModelConfig", "MoEConfig", "SSMConfig", "smoke_variant",
]
