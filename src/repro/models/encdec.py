"""Encoder-decoder backbone (seamless-m4t style, audio frontend stubbed).

The speech encoder consumes precomputed frame embeddings (the assignment
stubs the modality frontend); the text decoder attends causally to itself
and bidirectionally to the encoder output.  Both stacks scan over stacked
layer params.  At serve time the encoder output's K/V projections are
precomputed once per request ("bulk" staging of the cross-attention
operands — see DESIGN.md section 2) and decode steps only touch the self
cache.
"""

from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp

from . import ffn as ffn_lib
from .attention import (attention, cache_positions_full, cache_update_full)
from .blocks import ShardCtx, init_attn_params, init_mlp_params
from .common import apply_rope, cross_entropy_loss, dense_init, embed_init, rms_norm
from .config import ModelConfig
from .lm import _remat


def init_encdec(cfg: ModelConfig, key: jax.Array) -> dict:
    cfg.validate()
    keys = jax.random.split(key, 6)
    D, V = cfg.d_model, cfg.vocab

    def enc_layer(k):
        ka, km = jax.random.split(k)
        return {"attn": init_attn_params(ka, cfg),
                "mlp": init_mlp_params(km, cfg),
                "ln1": jnp.zeros((D,), jnp.float32),
                "ln2": jnp.zeros((D,), jnp.float32)}

    def dec_layer(k):
        ka, kc, km = jax.random.split(k, 3)
        return {"attn": init_attn_params(ka, cfg),
                "cross": init_attn_params(kc, cfg),
                "mlp": init_mlp_params(km, cfg),
                "ln1": jnp.zeros((D,), jnp.float32),
                "ln2": jnp.zeros((D,), jnp.float32),
                "ln3": jnp.zeros((D,), jnp.float32)}

    enc = [enc_layer(k) for k in jax.random.split(keys[0], cfg.enc_layers)]
    dec = [dec_layer(k) for k in jax.random.split(keys[1], cfg.n_layers)]
    return {
        "embed": embed_init(keys[2], (V, D)),
        "enc_layers": jax.tree.map(lambda *xs: jnp.stack(xs), *enc),
        "dec_layers": jax.tree.map(lambda *xs: jnp.stack(xs), *dec),
        "enc_norm": jnp.zeros((D,), jnp.float32),
        "final_norm": jnp.zeros((D,), jnp.float32),
        "lm_head": dense_init(keys[3], (D, V), D),
        "frame_proj": dense_init(keys[4], (D, D), D),  # frontend stub adapter
    }


def _proj_qkv(h, p, cfg, ctx, positions, rope=True):
    B, S, _ = h.shape
    q = jnp.einsum("bsd,dq->bsq", h, p["wq"]).reshape(B, S, cfg.n_heads, cfg.hd)
    k = jnp.einsum("bsd,dk->bsk", h, p["wk"]).reshape(B, S, cfg.n_kv_heads, cfg.hd)
    v = jnp.einsum("bsd,dk->bsk", h, p["wv"]).reshape(B, S, cfg.n_kv_heads, cfg.hd)
    if rope:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    return ctx.shard_heads(q), ctx.shard_heads(k), ctx.shard_heads(v)


def encode(params: dict, cfg: ModelConfig, frames: jax.Array, ctx: ShardCtx
           ) -> jax.Array:
    """frames: (B, S_enc, D) stub embeddings -> encoder states."""
    x = ctx.shard_act(jnp.einsum("bsd,de->bse",
                                 frames.astype(jnp.bfloat16),
                                 params["frame_proj"]))
    S = x.shape[1]
    positions = jnp.arange(S, dtype=jnp.int32)

    def body(h, lp):
        hn = rms_norm(h, lp["ln1"], cfg.norm_eps)
        q, k, v = _proj_qkv(hn, lp["attn"], cfg, ctx, positions)
        out = attention(q, k, v, q_pos=positions, k_pos=positions,
                        causal=False, impl=ctx.impl)
        B = h.shape[0]
        h = ctx.shard_act(
            h + jnp.einsum("bsq,qd->bsd", out.reshape(B, S, cfg.q_dim),
                           lp["attn"]["wo"]))
        h2 = rms_norm(h, lp["ln2"], cfg.norm_eps)
        h = ctx.shard_act(h + ffn_lib.swiglu(h2, lp["mlp"]["w_gate"],
                                             lp["mlp"]["w_up"],
                                             lp["mlp"]["w_down"]))
        return h, None

    body = _remat(body, cfg.remat)
    x, _ = jax.lax.scan(body, x, params["enc_layers"])
    return rms_norm(x, params["enc_norm"], cfg.norm_eps)


def _decoder_stack(params, cfg, x, enc_out, ctx):
    S = x.shape[1]
    S_enc = enc_out.shape[1]
    positions = jnp.arange(S, dtype=jnp.int32)
    enc_positions = jnp.arange(S_enc, dtype=jnp.int32)

    def body(h, lp):
        B = h.shape[0]
        hn = rms_norm(h, lp["ln1"], cfg.norm_eps)
        q, k, v = _proj_qkv(hn, lp["attn"], cfg, ctx, positions)
        out = attention(q, k, v, q_pos=positions, k_pos=positions,
                        causal=True, impl=ctx.impl)
        h = ctx.shard_act(
            h + jnp.einsum("bsq,qd->bsd", out.reshape(B, S, cfg.q_dim),
                           lp["attn"]["wo"]))
        # cross attention (no rope; encoder memory is position-agnostic here)
        hc = rms_norm(h, lp["ln2"], cfg.norm_eps)
        qc = jnp.einsum("bsd,dq->bsq", hc, lp["cross"]["wq"]).reshape(
            B, S, cfg.n_heads, cfg.hd)
        kc = jnp.einsum("bsd,dk->bsk", enc_out, lp["cross"]["wk"]).reshape(
            B, S_enc, cfg.n_kv_heads, cfg.hd)
        vc = jnp.einsum("bsd,dk->bsk", enc_out, lp["cross"]["wv"]).reshape(
            B, S_enc, cfg.n_kv_heads, cfg.hd)
        out = attention(ctx.shard_heads(qc), ctx.shard_heads(kc),
                        ctx.shard_heads(vc), q_pos=positions,
                        k_pos=enc_positions, causal=False, impl=ctx.impl)
        h = ctx.shard_act(
            h + jnp.einsum("bsq,qd->bsd", out.reshape(B, S, cfg.q_dim),
                           lp["cross"]["wo"]))
        h2 = rms_norm(h, lp["ln3"], cfg.norm_eps)
        h = ctx.shard_act(h + ffn_lib.swiglu(h2, lp["mlp"]["w_gate"],
                                             lp["mlp"]["w_up"],
                                             lp["mlp"]["w_down"]))
        return h, None

    body = _remat(body, cfg.remat)
    x, _ = jax.lax.scan(body, x, params["dec_layers"])
    return x


def forward_encdec(params: dict, cfg: ModelConfig, frames: jax.Array,
                   dec_tokens: jax.Array, ctx: ShardCtx) -> jax.Array:
    enc_out = encode(params, cfg, frames, ctx)
    x = ctx.shard_act(params["embed"][dec_tokens])
    x = _decoder_stack(params, cfg, x, enc_out, ctx)
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    return jnp.einsum("bsd,dv->bsv", x, params["lm_head"])


def encdec_loss(params: dict, cfg: ModelConfig, batch: dict, ctx: ShardCtx
                ) -> tuple[jax.Array, dict]:
    logits = forward_encdec(params, cfg, batch["frames"], batch["tokens"], ctx)
    ce = cross_entropy_loss(logits, batch["labels"], batch.get("loss_mask"))
    return ce, {"ce": ce}


# ---------------------------------------------------------------------------
# Serving
# ---------------------------------------------------------------------------


def cross_kv(params: dict, cfg: ModelConfig, enc_out: jax.Array, ctx: ShardCtx
             ) -> tuple[jax.Array, jax.Array]:
    """Precompute every decoder layer's cross K/V from encoder states —
    bulk-staged once per request.  Returns (L, B, S_enc, Hkv, hd) x 2."""
    B, S_enc, _ = enc_out.shape
    kc = jnp.einsum("bsd,ldk->lbsk", enc_out, params["dec_layers"]["cross"]["wk"])
    vc = jnp.einsum("bsd,ldk->lbsk", enc_out, params["dec_layers"]["cross"]["wv"])
    shape = (cfg.n_layers, B, S_enc, cfg.n_kv_heads, cfg.hd)
    kc = kc.reshape(shape).astype(jnp.bfloat16)
    vc = vc.reshape(shape).astype(jnp.bfloat16)
    if ctx.mesh is not None:
        kc = jax.tree.map(lambda a: ctx.shard_kv_cache(a, seq_axis=2), kc)
        vc = jax.tree.map(lambda a: ctx.shard_kv_cache(a, seq_axis=2), vc)
    return kc, vc


def init_encdec_cache(cfg: ModelConfig, batch: int, max_len: int,
                      enc_len: int, ctx: Optional[ShardCtx] = None) -> dict:
    ctx = ctx or ShardCtx()
    L = cfg.n_layers
    kv = jnp.zeros((L, batch, max_len, cfg.n_kv_heads, cfg.hd), jnp.bfloat16)
    ckv = jnp.zeros((L, batch, enc_len, cfg.n_kv_heads, cfg.hd), jnp.bfloat16)
    return {
        "pos": jnp.zeros((), jnp.int32),
        "k": ctx.shard_kv_cache(kv, seq_axis=2),
        "v": ctx.shard_kv_cache(kv, seq_axis=2),
        "cross_k": ctx.shard_kv_cache(ckv, seq_axis=2),
        "cross_v": ctx.shard_kv_cache(ckv, seq_axis=2),
    }


def encdec_decode_step(params: dict, cfg: ModelConfig, cache: dict,
                       tokens: jax.Array, ctx: ShardCtx
                       ) -> tuple[jax.Array, dict]:
    """One decoder token against (self cache, precomputed cross K/V)."""
    pos = cache["pos"]
    x = ctx.shard_act(params["embed"][tokens])
    B = x.shape[0]
    q_pos = jnp.broadcast_to(pos, (1,)).astype(jnp.int32)
    s_self = cache["k"].shape[2]
    s_enc = cache["cross_k"].shape[2]
    enc_positions = jnp.arange(s_enc, dtype=jnp.int32)

    def body(h, xs):
        lp, k_l, v_l, ck_l, cv_l = xs
        hn = rms_norm(h, lp["ln1"], cfg.norm_eps)
        q = jnp.einsum("bsd,dq->bsq", hn, lp["attn"]["wq"]).reshape(
            B, 1, cfg.n_heads, cfg.hd)
        k = jnp.einsum("bsd,dk->bsk", hn, lp["attn"]["wk"]).reshape(
            B, 1, cfg.n_kv_heads, cfg.hd)
        v = jnp.einsum("bsd,dk->bsk", hn, lp["attn"]["wv"]).reshape(
            B, 1, cfg.n_kv_heads, cfg.hd)
        q = apply_rope(q, q_pos, cfg.rope_theta)
        k = apply_rope(k, q_pos, cfg.rope_theta)
        k_l, v_l = cache_update_full(k_l, v_l, k, v, pos)
        k_pos = cache_positions_full(s_self, pos)
        out = attention(q, k_l, v_l, q_pos=q_pos, k_pos=k_pos, causal=True)
        h = h + jnp.einsum("bsq,qd->bsd", out.reshape(B, 1, cfg.q_dim),
                           lp["attn"]["wo"])
        hc = rms_norm(h, lp["ln2"], cfg.norm_eps)
        qc = jnp.einsum("bsd,dq->bsq", hc, lp["cross"]["wq"]).reshape(
            B, 1, cfg.n_heads, cfg.hd)
        out = attention(qc, ck_l, cv_l, q_pos=q_pos, k_pos=enc_positions,
                        causal=False)
        h = h + jnp.einsum("bsq,qd->bsd", out.reshape(B, 1, cfg.q_dim),
                           lp["cross"]["wo"])
        h2 = rms_norm(h, lp["ln3"], cfg.norm_eps)
        h = h + ffn_lib.swiglu(h2, lp["mlp"]["w_gate"], lp["mlp"]["w_up"],
                               lp["mlp"]["w_down"])
        return h, (k_l, v_l)

    x, (k_new, v_new) = jax.lax.scan(
        body, x, (params["dec_layers"], cache["k"], cache["v"],
                  cache["cross_k"], cache["cross_v"]))
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = jnp.einsum("bsd,dv->bsv", x, params["lm_head"])
    new_cache = dict(cache)
    new_cache["k"], new_cache["v"] = k_new, v_new
    new_cache["pos"] = pos + 1
    return logits, new_cache
