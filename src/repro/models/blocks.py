"""Transformer building blocks + the sharding context threaded through models.

``ShardCtx`` is how model code stays mesh-agnostic: layers call
``ctx.shard_act`` / ``ctx.shard_heads`` at the tensor boundaries where a
sharding constraint matters, and the context decides (from the mesh and
divisibility) what constraint, if any, to apply.  On a mesh-less CPU run
everything is the identity.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from . import ffn as ffn_lib
from .attention import attention
from .common import apply_rope, dense_init, rms_norm
from .config import ModelConfig


@dataclasses.dataclass(frozen=True)
class ShardCtx:
    """Mesh context for activation sharding + manual-collective blocks."""

    mesh: Optional[Mesh] = None
    batch_axes: tuple[str, ...] = ("data",)
    model_axis: str = "model"
    impl: str = "ref"              # attention/ssd kernel impl: ref | pallas
    moe_impl: str = "auto"         # auto | ep | tp | ref
    seq_parallel: bool = False     # Megatron-SP: layer-boundary activations
    #                                (and remat residuals) shard their seq
    #                                dim over the model axis

    def _constrain(self, x: jax.Array, spec: P) -> jax.Array:
        if self.mesh is None:
            return x
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(self.mesh, spec))

    def _model_size(self) -> int:
        return self.mesh.shape[self.model_axis] if self.mesh is not None else 1

    def shard_act(self, x: jax.Array) -> jax.Array:
        """(B, S, D) activations: batch over the data axes; with
        seq_parallel the sequence additionally shards over the model axis
        (residuals and remat-saved layer inputs then cost 1/model_size of
        HBM — required to fit the 123B/141B archs' 88/56-layer stacks)."""
        m = self._model_size()
        if (self.seq_parallel and x.ndim >= 3 and m > 1
                and x.shape[1] % m == 0 and x.shape[1] > 1):
            spec = P(self.batch_axes, self.model_axis,
                     *([None] * (x.ndim - 2)))
            return self._constrain(x, spec)
        spec = P(self.batch_axes, *([None] * (x.ndim - 1)))
        return self._constrain(x, spec)

    def heads_shardable(self, h: int) -> bool:
        m = self._model_size()
        return m > 1 and h % m == 0

    def seq_parallel_attn(self, h: int, s: int) -> bool:
        """Sequence-parallel fallback: when heads don't divide the model
        axis (smollm: 15H, gemma3: 4H), shard the *query sequence* over it
        instead — otherwise attention compute replicates model_size-fold
        (measured: 16x redundant FLOPs on the 16x16 mesh)."""
        m = self._model_size()
        return (not self.heads_shardable(h)) and m > 1 and s > 1 and s % m == 0

    def shard_heads(self, x: jax.Array, role: str = "q") -> jax.Array:
        """(B, S, H, hd).  Heads over model when divisible; else the query
        sequence shards over model (role='q') and K/V stay replicated
        across it (role='kv')."""
        if self.mesh is None:
            return x
        h, s = x.shape[2], x.shape[1]
        if self.heads_shardable(h):
            return self._constrain(
                x, P(self.batch_axes, None, self.model_axis, None))
        if role == "q" and self.seq_parallel_attn(h, s):
            return self._constrain(
                x, P(self.batch_axes, self.model_axis, None, None))
        return self._constrain(x, P(self.batch_axes, None, None, None))

    def shard_kv_cache(self, x: jax.Array, *, seq_axis: int = 1) -> jax.Array:
        """(B, S, Hkv, hd) cache: batch over data axes when divisible;
        heads over model when divisible, otherwise the *sequence* takes the
        model axis (flash-decode partials combine via psum); with batch
        also unshardable (long_500k) the sequence takes the data axes."""
        if self.mesh is None:
            return x
        b, s, h = x.shape[0], x.shape[seq_axis], x.shape[2]
        dp = 1
        for a in self.batch_axes:
            dp *= self.mesh.shape[a]
        m = self._model_size()
        head_spec = self.model_axis if (m > 1 and h % m == 0) else None
        b_spec = self.batch_axes if (b % dp == 0 and b >= dp) else None
        if head_spec is None and m > 1 and s % m == 0:
            s_spec = self.model_axis
        elif b_spec is None and s % dp == 0:
            s_spec = self.batch_axes
        else:
            s_spec = None
        return self._constrain(x, P(b_spec, s_spec, head_spec, None))

    def choose_moe(self, cfg: ModelConfig) -> str:
        if self.moe_impl != "auto":
            return self.moe_impl
        if self.mesh is None:
            return "ref"
        return ffn_lib.choose_moe_impl(cfg, self.mesh, self.model_axis)


# ---------------------------------------------------------------------------
# Parameter builders
# ---------------------------------------------------------------------------


def init_attn_params(key: jax.Array, cfg: ModelConfig) -> dict:
    D, Q, KV = cfg.d_model, cfg.q_dim, cfg.kv_dim
    k1, k2, k3, k4 = jax.random.split(key, 4)
    return {
        "wq": dense_init(k1, (D, Q), D),
        "wk": dense_init(k2, (D, KV), D),
        "wv": dense_init(k3, (D, KV), D),
        "wo": dense_init(k4, (Q, D), Q),
    }


def init_mlp_params(key: jax.Array, cfg: ModelConfig, d_ff: int | None = None) -> dict:
    D = cfg.d_model
    F = d_ff or cfg.d_ff
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "w_gate": dense_init(k1, (D, F), D),
        "w_up": dense_init(k2, (D, F), D),
        "w_down": dense_init(k3, (F, D), F),
    }


def init_moe_params(key: jax.Array, cfg: ModelConfig) -> dict:
    D, E, F = cfg.d_model, cfg.moe.n_experts, cfg.moe.d_ff_expert
    k1, k2, k3, k4 = jax.random.split(key, 4)
    return {
        "router": dense_init(k1, (D, E), D, dtype=jnp.float32),
        "w_gate": dense_init(k2, (E, D, F), D),
        "w_up": dense_init(k3, (E, D, F), D),
        "w_down": dense_init(k4, (E, F, D), F),
    }


def init_dense_layer(key: jax.Array, cfg: ModelConfig) -> dict:
    ka, km = jax.random.split(key)
    D = cfg.d_model
    return {
        "attn": init_attn_params(ka, cfg),
        "mlp": init_mlp_params(km, cfg),
        "ln1": jnp.zeros((D,), jnp.float32),
        "ln2": jnp.zeros((D,), jnp.float32),
    }


def init_moe_layer(key: jax.Array, cfg: ModelConfig) -> dict:
    ka, km = jax.random.split(key)
    D = cfg.d_model
    return {
        "attn": init_attn_params(ka, cfg),
        "moe": init_moe_params(km, cfg),
        "ln1": jnp.zeros((D,), jnp.float32),
        "ln2": jnp.zeros((D,), jnp.float32),
    }


def init_mamba_layer(key: jax.Array, cfg: ModelConfig) -> dict:
    s = cfg.ssm
    D = cfg.d_model
    k1, k2, k3 = jax.random.split(key, 3)
    H = cfg.ssm_heads
    return {
        "in_proj": dense_init(k1, (D, cfg.in_proj_dim), D),
        "conv_w": dense_init(k2, (s.conv_width, cfg.conv_dim), s.conv_width),
        "conv_b": jnp.zeros((cfg.conv_dim,), jnp.float32),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, H, dtype=jnp.float32)),
        "D": jnp.ones((H,), jnp.float32),
        "dt_bias": jnp.log(jnp.expm1(
            jnp.exp(jax.random.uniform(k3, (H,), jnp.float32,
                                       jnp.log(1e-3), jnp.log(1e-1))))),
        "norm_w": jnp.ones((cfg.d_inner,), jnp.float32),
        "out_proj": dense_init(jax.random.fold_in(k1, 7), (cfg.d_inner, D),
                               cfg.d_inner),
    }


def stack_layers(key: jax.Array, cfg: ModelConfig, n: int, kind: str) -> dict:
    """Stacked per-layer params (leading L axis) for lax.scan."""
    init = {"attn": init_dense_layer, "moe": init_moe_layer,
            "mamba": init_mamba_layer}[kind]
    keys = jax.random.split(key, n)
    layers = [init(k, cfg) for k in keys]
    return jax.tree.map(lambda *xs: jnp.stack(xs), *layers)


# ---------------------------------------------------------------------------
# Layer application
# ---------------------------------------------------------------------------


def self_attention_block(
    x: jax.Array, p: dict, cfg: ModelConfig, ctx: ShardCtx, *,
    q_pos: jax.Array, k_pos: jax.Array,
    k_cached: jax.Array | None = None, v_cached: jax.Array | None = None,
    causal: bool = True, window: int | jax.Array = 0,
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """QKV projections + RoPE + attention.  Returns (out, k_new, v_new)
    where k_new/v_new are this step's keys/values (pre-cache, post-RoPE)."""
    B, S, D = x.shape
    q = jnp.einsum("bsd,dq->bsq", x, p["wq"]).reshape(B, S, cfg.n_heads, cfg.hd)
    k = jnp.einsum("bsd,dk->bsk", x, p["wk"]).reshape(B, S, cfg.n_kv_heads, cfg.hd)
    v = jnp.einsum("bsd,dk->bsk", x, p["wv"]).reshape(B, S, cfg.n_kv_heads, cfg.hd)
    q = apply_rope(q, q_pos, cfg.rope_theta)
    k = apply_rope(k, q_pos, cfg.rope_theta)   # new keys carry current positions
    q = ctx.shard_heads(q, role="q")
    # GQA sharding repair: when Hq shards over the model axis but Hkv does
    # not (kv=8 on a 16-wide axis), the (Hkv, G) grouping reshape breaks
    # the head sharding of the score tensor and GSPMD falls back to full
    # rematerialization (measured: ~1 TiB/dev score all-gathers, §Perf M2).
    # Materializing the KV head repeat costs ~MBs and keeps every
    # attention tensor cleanly model-sharded.  (The Pallas kernel does GQA
    # without the repeat on real TPU — this is the GSPMD-graph trade.)
    if (ctx.heads_shardable(cfg.n_heads)
            and not ctx.heads_shardable(cfg.n_kv_heads)
            and cfg.n_heads != cfg.n_kv_heads):
        rep = cfg.n_heads // cfg.n_kv_heads
        k = jnp.repeat(k, rep, axis=2)
        v = jnp.repeat(v, rep, axis=2)
        k = ctx.shard_heads(k, role="q")
        v = ctx.shard_heads(v, role="q")
    else:
        k = ctx.shard_heads(k, role="kv")
        v = ctx.shard_heads(v, role="kv")
    if k_cached is not None:
        k_all, v_all = k_cached, v_cached
    else:
        k_all, v_all = k, v
    # query chunking is a memory fallback for *unsharded* attention only:
    # with heads (or the query sequence) sharded over the model axis the
    # score workspace is already bounded, and the chunk scan's extra
    # sharding transitions trigger involuntary full rematerialization in
    # GSPMD (measured: 4.2 TiB/dev of score all-gathers on
    # mistral-large train_4k — EXPERIMENTS.md §Perf iteration M1)
    q_chunk = 0 if (ctx.heads_shardable(cfg.n_heads)
                    or ctx.seq_parallel_attn(cfg.n_heads, S)) else None
    out = attention(q, k_all, v_all, q_pos=q_pos, k_pos=k_pos,
                    causal=causal, window=window, impl=ctx.impl,
                    q_chunk=q_chunk)
    out = out.reshape(B, S, cfg.q_dim)
    return jnp.einsum("bsq,qd->bsd", out, p["wo"]), k, v


def dense_layer_apply(
    x: jax.Array, p: dict, cfg: ModelConfig, ctx: ShardCtx, *,
    positions: jax.Array, window: int | jax.Array = 0, causal: bool = True,
) -> jax.Array:
    """Full pre-norm transformer layer (train/prefill path, no cache)."""
    h = rms_norm(x, p["ln1"], cfg.norm_eps)
    attn_out, _, _ = self_attention_block(
        h, p["attn"], cfg, ctx, q_pos=positions, k_pos=positions,
        causal=causal, window=window)
    x = ctx.shard_act(x + attn_out)
    h2 = rms_norm(x, p["ln2"], cfg.norm_eps)
    mlp_out = ffn_lib.swiglu(h2, p["mlp"]["w_gate"], p["mlp"]["w_up"],
                             p["mlp"]["w_down"])
    return ctx.shard_act(x + mlp_out)


def moe_layer_apply(
    x: jax.Array, p: dict, cfg: ModelConfig, ctx: ShardCtx, *,
    positions: jax.Array, window: int | jax.Array = 0, causal: bool = True,
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """MoE transformer layer; returns (x, lb_loss, z_loss)."""
    h = rms_norm(x, p["ln1"], cfg.norm_eps)
    attn_out, _, _ = self_attention_block(
        h, p["attn"], cfg, ctx, q_pos=positions, k_pos=positions,
        causal=causal, window=window)
    x = ctx.shard_act(x + attn_out)
    h2 = rms_norm(x, p["ln2"], cfg.norm_eps)
    moe = p["moe"]
    impl = ctx.choose_moe(cfg)
    if impl == "ep":
        y, lb, z = ffn_lib.moe_ep(h2, moe["router"], moe["w_gate"],
                                  moe["w_up"], moe["w_down"], cfg=cfg,
                                  mesh=ctx.mesh, batch_axes=ctx.batch_axes,
                                  model_axis=ctx.model_axis)
    elif impl == "tp":
        y, lb, z = ffn_lib.moe_tp(h2, moe["router"], moe["w_gate"],
                                  moe["w_up"], moe["w_down"], cfg=cfg,
                                  mesh=ctx.mesh, batch_axes=ctx.batch_axes,
                                  model_axis=ctx.model_axis)
    else:
        y, lb, z = ffn_lib.moe_ref(h2, moe["router"], moe["w_gate"],
                                   moe["w_up"], moe["w_down"], cfg=cfg)
    return ctx.shard_act(x + y), lb, z
