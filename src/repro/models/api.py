"""Unified model API — one handle per architecture for the launcher, the
dry-run, the trainer, the server, tests, and benchmarks.

``ModelApi`` exposes exactly the entry points the rest of the framework
needs, dispatched per family:

    init(key)                          -> params
    loss(params, batch, ctx)           -> (scalar, aux dict)
    prefill(params, batch, ctx, max)   -> (last logits, cache)
    decode_step(params, cache, tok, ctx) -> (logits, cache')
    init_cache(batch, max_len, ctx)    -> cache pytree
    train_input_specs / decode_input_specs -> ShapeDtypeStruct pytrees
    model_flops(shape)                 -> useful-FLOPs convention (6*N*D / 2*N*D)
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any, Optional

import jax
import jax.numpy as jnp

from . import encdec as encdec_lib
from . import lm as lm_lib
from .blocks import ShardCtx
from .config import ModelConfig


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    """One assigned input-shape cell."""

    name: str
    seq_len: int
    global_batch: int
    kind: str                  # train | prefill | decode

    @property
    def tokens(self) -> int:
        return self.seq_len * self.global_batch


# the four assigned shape cells (identical across all ten archs)
SHAPES: dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}


@dataclasses.dataclass(frozen=True)
class ModelApi:
    cfg: ModelConfig

    # -- lifecycle -----------------------------------------------------------

    def init(self, key: jax.Array) -> dict:
        if self.cfg.family == "encdec":
            return encdec_lib.init_encdec(self.cfg, key)
        return lm_lib.init_lm(self.cfg, key)

    # -- training ------------------------------------------------------------

    def loss(self, params: dict, batch: dict, ctx: ShardCtx
             ) -> tuple[jax.Array, dict]:
        if self.cfg.family == "encdec":
            return encdec_lib.encdec_loss(params, self.cfg, batch, ctx)
        return lm_lib.lm_loss(params, self.cfg, batch, ctx)

    # -- serving -------------------------------------------------------------

    def prefill(self, params: dict, batch: dict, ctx: ShardCtx,
                max_len: int) -> tuple[jax.Array, dict]:
        if self.cfg.family == "encdec":
            enc_out = encdec_lib.encode(params, self.cfg, batch["frames"], ctx)
            ck, cv = encdec_lib.cross_kv(params, self.cfg, enc_out, ctx)
            cache = encdec_lib.init_encdec_cache(
                self.cfg, enc_out.shape[0], max_len, enc_out.shape[1], ctx)
            cache["cross_k"], cache["cross_v"] = ck, cv
            logits, cache = encdec_lib.encdec_decode_step(
                params, self.cfg, cache, batch["tokens"][:, :1], ctx)
            return logits, cache
        return lm_lib.prefill_lm(params, self.cfg, batch["tokens"], ctx,
                                 max_len,
                                 extra_embeds=batch.get("extra_embeds"))

    def init_cache(self, batch: int, max_len: int, ctx: ShardCtx,
                   enc_len: Optional[int] = None) -> dict:
        if self.cfg.family == "encdec":
            return encdec_lib.init_encdec_cache(
                self.cfg, batch, max_len, enc_len or max_len, ctx)
        return lm_lib.init_lm_cache(self.cfg, batch, max_len, ctx)

    def decode_step(self, params: dict, cache: dict, tokens: jax.Array,
                    ctx: ShardCtx) -> tuple[jax.Array, dict]:
        if self.cfg.family == "encdec":
            return encdec_lib.encdec_decode_step(params, self.cfg, cache,
                                                 tokens, ctx)
        return lm_lib.lm_decode_step(params, self.cfg, cache, tokens, ctx)

    # -- abstract input specs (dry-run: no allocation) -------------------------

    def train_input_specs(self, shape: ShapeSpec) -> dict:
        B, S = shape.global_batch, shape.seq_len
        cfg = self.cfg
        i32 = jnp.int32
        if cfg.family == "encdec":
            return {
                "frames": jax.ShapeDtypeStruct((B, S, cfg.d_model), jnp.bfloat16),
                "tokens": jax.ShapeDtypeStruct((B, S), i32),
                "labels": jax.ShapeDtypeStruct((B, S), i32),
            }
        if cfg.frontend:
            s_text = S - cfg.frontend_len
            return {
                "tokens": jax.ShapeDtypeStruct((B, s_text), i32),
                "labels": jax.ShapeDtypeStruct((B, s_text), i32),
                "extra_embeds": jax.ShapeDtypeStruct(
                    (B, cfg.frontend_len, cfg.d_model), jnp.bfloat16),
            }
        return {
            "tokens": jax.ShapeDtypeStruct((B, S), i32),
            "labels": jax.ShapeDtypeStruct((B, S), i32),
        }

    def decode_input_specs(self, shape: ShapeSpec, ctx: ShardCtx
                           ) -> tuple[dict, jax.ShapeDtypeStruct]:
        """(cache specs, token specs) for serve_step lowering."""
        B, S = shape.global_batch, shape.seq_len
        enc_len = min(S, 8192) if self.cfg.family == "encdec" else None
        cache = jax.eval_shape(
            lambda: self.init_cache(B, S, ctx, enc_len=enc_len))
        tokens = jax.ShapeDtypeStruct((B, 1), jnp.int32)
        return cache, tokens

    # -- accounting ------------------------------------------------------------

    def model_flops(self, shape: ShapeSpec) -> float:
        """Useful-FLOPs convention: 6*N*D train, 2*N_active*D inference
        (decode: D = one token per sequence)."""
        n = self.cfg.active_param_count()
        if shape.kind == "train":
            return 6.0 * n * shape.tokens
        if shape.kind == "prefill":
            return 2.0 * n * shape.tokens
        return 2.0 * n * shape.global_batch  # decode: one token / sequence

    def flash_ideal_io_bytes(self, shape: ShapeSpec) -> float:
        """Global ideal HBM IO of the kernel-fusable regions (attention /
        SSD cores): what the Pallas kernels move instead of the unfused
        oracle graphs.  Convention: fwd reads q,k,v + writes o; backward
        re-reads q,k,v,o and writes dq,dk,dv (~3x fwd IO); decode reads
        the cache once per step.
        """
        cfg = self.cfg
        B = shape.global_batch
        S = shape.seq_len
        bpe = 2.0  # bf16
        passes = 3.0 if shape.kind == "train" else 1.0

        def attn_call_bytes(s_q: float, s_kv: float) -> float:
            q_o = 2.0 * B * s_q * cfg.q_dim * bpe
            kv = 2.0 * B * s_kv * cfg.kv_dim * bpe
            return q_o + kv

        n_attn, n_ssd = 0, 0
        if cfg.family in ("dense", "vlm", "moe"):
            n_attn = cfg.n_layers
        elif cfg.family == "ssm":
            n_ssd = cfg.n_layers
        elif cfg.family == "hybrid":
            n_ssd = cfg.n_layers
            n_attn = max(1, cfg.n_layers // max(cfg.attn_every, 1))
        elif cfg.family == "encdec":
            n_attn = cfg.enc_layers + 2 * cfg.n_layers  # self + cross

        if shape.kind == "decode":
            s_kv = min(cfg.window, S) if (cfg.window and cfg.global_every == 0) else S
            attn = n_attn * attn_call_bytes(1, s_kv)
            s_ssm = cfg.ssm
            ssd = n_ssd * (2.0 * B * cfg.d_inner * bpe
                           + 2.0 * B * self.cfg.ssm_heads
                           * (s_ssm.head_dim * s_ssm.d_state) * 4.0) if n_ssd else 0.0
            return attn + ssd
        attn = passes * n_attn * attn_call_bytes(S, S)
        ssd = 0.0
        if n_ssd:
            s_ssm = cfg.ssm
            per_layer = (2.0 * B * S * cfg.d_inner * bpe          # x in, y out
                         + 2.0 * B * S * 2 * s_ssm.n_groups * s_ssm.d_state * bpe)
            ssd = passes * n_ssd * per_layer
        return attn + ssd

    def applicable(self, shape: ShapeSpec) -> tuple[bool, str]:
        """Assignment rules: long_500k only for sub-quadratic attention."""
        cfg = self.cfg
        if shape.name == "long_500k":
            sub_quadratic = (cfg.family in ("ssm", "hybrid")
                             or (cfg.window > 0))
            if not sub_quadratic:
                return False, ("pure full-attention arch — long_500k skipped "
                               "(see DESIGN.md section 5)")
        return True, ""


def build(cfg: ModelConfig) -> ModelApi:
    cfg.validate()
    return ModelApi(cfg)
