"""Attention: GQA with causal / sliding-window / local:global masking.

One implementation covers every assigned pattern:

* full causal (phi3, smollm, mistral-large, qwen3, llava, seamless-dec),
* sliding window (mixtral, window=4096),
* 5:1 local:global interleave (gemma3 — per-layer window passed as data
  through the layer scan, so the stacked-layer scan stays homogeneous),
* bidirectional (seamless encoder), cross-attention (seamless decoder),
* single-query decode against a (possibly ring) KV cache.

Positions are explicit everywhere: a KV slot with position < 0 is invalid
(empty ring-buffer slot).  Window masking is relative: key valid iff
``q_pos - window < k_pos <= q_pos`` (window == 0 means unbounded), which
makes ring-buffer caches correct without any index shuffling.

``impl="pallas"`` routes the train/prefill path through the Pallas flash
kernel (kernels/flash_attention.py); ``"ref"`` is the pure-jnp oracle the
kernel is validated against.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def _build_mask(
    q_pos: jax.Array,        # (B?, Sq) or (Sq,)
    k_pos: jax.Array,        # (B?, Sk) or (Sk,)
    *,
    causal: bool,
    window: int | jax.Array = 0,
) -> jax.Array:
    """Boolean keep-mask broadcastable to (..., Sq, Sk)."""
    qp = q_pos[..., :, None].astype(jnp.int32)
    kp = k_pos[..., None, :].astype(jnp.int32)
    keep = kp >= 0
    if causal:
        keep = jnp.logical_and(keep, kp <= qp)
    # window as traced scalar supports per-layer windows through scan
    w = jnp.asarray(window, jnp.int32)
    keep = jnp.logical_and(keep, jnp.where(w > 0, kp > qp - w, True))
    return keep


# score tensors above this many elements trigger query-chunked evaluation
# (bounds the live (Sq x Sk) softmax workspace — the pure-jnp analogue of
# flash attention's tiling; the Pallas kernel does this in VMEM natively)
ATTN_CHUNK_ELEMS = 1 << 22


def _attn_core(q, k, v, *, q_pos, k_pos, causal, window) -> jax.Array:
    # named_scope tags every op in here as belonging to a region a fused
    # flash-attention kernel replaces on TPU: core/fidelity.py separates
    # these bytes so the roofline can report raw vs. kernel-fused memory
    # traffic (the Pallas kernel in kernels/flash_attention.py is the
    # fused implementation; this is its oracle).
    with jax.named_scope("flashable_attention"):
        B, Sq, Hq, hd = q.shape
        _, Sk, Hkv, _ = k.shape
        assert Hq % Hkv == 0, (Hq, Hkv)
        G = Hq // Hkv
        qg = q.reshape(B, Sq, Hkv, G, hd)
        scale = hd ** -0.5
        # mixed-precision dot: bf16 operands, f32 accumulation — native on
        # the TPU MXU (avoids materializing f32 casts of the K cache)
        scores = jnp.einsum("bqhgd,bkhd->bhgqk", qg, k,
                            preferred_element_type=jnp.float32) * scale
        mask = _build_mask(q_pos, k_pos, causal=causal, window=window)
        # mask broadcast: (.., Sq, Sk) -> (B?, 1, 1, Sq, Sk)
        while mask.ndim < scores.ndim:
            mask = mask[..., None, :, :] if mask.ndim >= 2 else mask
        scores = jnp.where(mask, scores, NEG_INF)
        probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
        out = jnp.einsum("bhgqk,bkhd->bqhgd", probs, v)
        return out.reshape(B, Sq, Hq, hd)


def attention(
    q: jax.Array,            # (B, Sq, Hq, hd)
    k: jax.Array,            # (B, Sk, Hkv, hd)
    v: jax.Array,            # (B, Sk, Hkv, hd)
    *,
    q_pos: jax.Array,        # (Sq,) or (B, Sq)
    k_pos: jax.Array,        # (Sk,) or (B, Sk)
    causal: bool = True,
    window: int | jax.Array = 0,
    impl: str = "ref",
    q_chunk: int | None = None,
) -> jax.Array:
    """Grouped-query attention; returns (B, Sq, Hq, hd).

    q_chunk: None = auto (chunk when the score workspace is large),
    0 = never chunk (caller bounds memory another way, e.g. sequence-
    parallel sharding), >0 = explicit chunk length.
    """
    if impl == "pallas":
        out = _try_pallas(q, k, v, q_pos=q_pos, k_pos=k_pos, causal=causal,
                          window=window)
        if out is not None:
            return out
    B, Sq, Hq, hd = q.shape
    Sk = k.shape[1]
    if (q_chunk == 0 or Sq * Sk <= ATTN_CHUNK_ELEMS or q_pos.ndim != 1):
        return _attn_core(q, k, v, q_pos=q_pos, k_pos=k_pos, causal=causal,
                          window=window)
    # query-chunked evaluation: scan over Sq blocks; the body is
    # checkpointed so backward recomputes each block's scores instead of
    # saving the full (Sq, Sk) probability tensor.
    if q_chunk is None:
        q_chunk = max(128, ATTN_CHUNK_ELEMS // Sk)
    while Sq % q_chunk:
        q_chunk //= 2
    nq = Sq // q_chunk
    qc = jnp.moveaxis(q.reshape(B, nq, q_chunk, Hq, hd), 1, 0)
    qpc = q_pos.reshape(nq, q_chunk)

    @jax.checkpoint
    def body(_, inp):
        qi, qpi = inp
        return None, _attn_core(qi, k, v, q_pos=qpi, k_pos=k_pos,
                                causal=causal, window=window)

    _, outs = jax.lax.scan(body, None, (qc, qpc))
    return jnp.moveaxis(outs, 0, 1).reshape(B, Sq, Hq, hd)


def _try_pallas(q, k, v, *, q_pos, k_pos, causal, window) -> Optional[jax.Array]:
    """Route to the Pallas flash kernel when the shape regime fits it
    (train/prefill: Sq == Sk, static positions)."""
    if q.shape[1] != k.shape[1] or q.shape[1] < 128:
        return None
    try:
        from repro.kernels import ops as kops
    except Exception:
        return None
    try:
        return kops.flash_attention(q, k, v, causal=causal,
                                    window=int(window) if not isinstance(window, jax.Array) else 0)
    except (NotImplementedError, ValueError):
        return None


# ---------------------------------------------------------------------------
# KV caches
# ---------------------------------------------------------------------------


def cache_update_full(k_cache: jax.Array, v_cache: jax.Array,
                      k_new: jax.Array, v_new: jax.Array,
                      pos: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Write step-`pos` K/V into a full-length cache (B, S_max, Hkv, hd)."""
    k_cache = jax.lax.dynamic_update_slice_in_dim(
        k_cache, k_new.astype(k_cache.dtype), pos, axis=1)
    v_cache = jax.lax.dynamic_update_slice_in_dim(
        v_cache, v_new.astype(v_cache.dtype), pos, axis=1)
    return k_cache, v_cache


def cache_positions_full(s_max: int, pos: jax.Array) -> jax.Array:
    """Absolute positions of full-cache slots; > pos slots invalid (-1)."""
    idx = jnp.arange(s_max, dtype=jnp.int32)
    return jnp.where(idx <= pos, idx, -1)


def cache_update_ring(k_cache: jax.Array, v_cache: jax.Array,
                      k_new: jax.Array, v_new: jax.Array,
                      pos: jax.Array, window: int) -> tuple[jax.Array, jax.Array]:
    """Write into a ring cache of length `window` at slot pos % window."""
    slot = jnp.mod(pos, window)
    k_cache = jax.lax.dynamic_update_slice_in_dim(
        k_cache, k_new.astype(k_cache.dtype), slot, axis=1)
    v_cache = jax.lax.dynamic_update_slice_in_dim(
        v_cache, v_new.astype(v_cache.dtype), slot, axis=1)
    return k_cache, v_cache


def cache_positions_ring(window: int, pos: jax.Array) -> jax.Array:
    """Absolute position held by each ring slot after writing step `pos`.

    Slot j holds the largest p <= pos with p === j (mod window); slots that
    would be negative are invalid (-1).
    """
    j = jnp.arange(window, dtype=jnp.int32)
    p = pos - jnp.mod(pos - j, window)
    return jnp.where(p >= 0, p, -1)
