"""Feed-forward blocks: SwiGLU MLP and Mixture-of-Experts.

Two production MoE data paths (chosen per arch by expert-count/mesh
divisibility — DESIGN.md section 5):

* :func:`moe_ep` — **expert parallelism** via ``shard_map``: tokens are
  sequence-split across the model axis, dispatched into per-expert
  capacity buffers by a sort-based router, exchanged with
  ``all_to_all`` over the model axis, computed on the owning shard, and
  all_to_all'd back.  This is the DeepSpeed-MoE/Tutel pattern; the
  collective volume it generates is a first-class flow of the drainage
  basin (an aggregation "tributary" converging on expert shards).
  Used when ``n_experts %% model_axis == 0`` (qwen3: 128 experts).

* :func:`moe_tp` — **tensor parallelism inside experts**: tokens are
  all-gathered across the model axis, every shard routes identically and
  computes all experts against its ``d_ff`` slice, and outputs return via
  ``psum_scatter``.  Megatron-style; used when the expert count does not
  divide the model axis (mixtral: 8 experts on a 16-wide axis).

:func:`moe_ref` is the dense no-drop oracle used by tests: with a
generous capacity factor the sparse paths must match it.
"""

from __future__ import annotations

import math
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.parallel.compat import shard_map

from .config import ModelConfig


def swiglu(x: jax.Array, w_gate: jax.Array, w_up: jax.Array,
           w_down: jax.Array) -> jax.Array:
    """SwiGLU MLP: (x W_g) SiLU * (x W_u) -> W_d."""
    g = jnp.einsum("...d,df->...f", x, w_gate)
    u = jnp.einsum("...d,df->...f", x, w_up)
    h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
    return jnp.einsum("...f,fd->...d", h, w_down)


# ---------------------------------------------------------------------------
# Routing (shared by every MoE path)
# ---------------------------------------------------------------------------


def route(x: jax.Array, w_router: jax.Array, top_k: int
          ) -> tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """Top-k routing.  x: (T, D) -> (gates (T,k), experts (T,k) i32,
    probs (T,E) f32, logits f32)."""
    logits = jnp.einsum("td,de->te", x.astype(jnp.float32),
                        w_router.astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_idx = jax.lax.top_k(probs, top_k)
    gate_vals = gate_vals / jnp.maximum(
        jnp.sum(gate_vals, axis=-1, keepdims=True), 1e-9)
    return gate_vals, expert_idx, probs, logits


def aux_losses(probs: jax.Array, expert_idx: jax.Array, n_experts: int,
               logits: jax.Array) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Local (tokens-per-expert, prob-mass, z-loss) sums.  Callers must
    reduce count and mass SEPARATELY before multiplying: the global
    load-balance term is count_global x mass_global, and a per-shard
    sum of products is a different (biased) estimator."""
    one_hot = jax.nn.one_hot(expert_idx, n_experts, dtype=jnp.float32)  # (T,k,E)
    tokens_per_expert = one_hot.sum(axis=(0, 1))        # (E,)
    prob_mass = probs.sum(axis=0)                       # (E,)
    z_num = jnp.sum(jnp.square(jax.nn.logsumexp(logits, axis=-1)))
    return tokens_per_expert, prob_mass, z_num


def _local_dispatch(x: jax.Array, expert_idx: jax.Array, gates: jax.Array,
                    n_experts: int, capacity: int
                    ) -> tuple[jax.Array, jax.Array, jax.Array, jax.Array, jax.Array]:
    """Sort-based capacity dispatch of local tokens into (E, C, D) buffers.

    Returns (buffer, sorted_experts, sorted_token_ids, sorted_positions,
    keep_mask) — the latter four drive the inverse combine.
    """
    t, d = x.shape
    k = expert_idx.shape[-1]
    e_flat = expert_idx.reshape(t * k)
    tok_flat = jnp.repeat(jnp.arange(t, dtype=jnp.int32), k)
    order = jnp.argsort(e_flat, stable=True)
    se = e_flat[order]
    st = tok_flat[order]
    counts = jnp.bincount(se, length=n_experts)
    starts = jnp.cumsum(counts) - counts
    pos = jnp.arange(t * k, dtype=jnp.int32) - starts[se].astype(jnp.int32)
    keep = pos < capacity
    safe_pos = jnp.where(keep, pos, 0)
    buf = jnp.zeros((n_experts, capacity, d), x.dtype)
    contrib = jnp.where(keep[:, None], x[st], jnp.zeros_like(x[st]))
    buf = buf.at[se, safe_pos].add(contrib)
    return buf, se, st, safe_pos, keep


def _local_combine(y: jax.Array, se: jax.Array, st: jax.Array,
                   pos: jax.Array, keep: jax.Array, gates: jax.Array,
                   order_gates: jax.Array, t: int) -> jax.Array:
    """Inverse of :func:`_local_dispatch` with gate weighting."""
    gathered = y[se, pos]                       # (t*k, D)
    weighted = gathered * (order_gates * keep)[:, None].astype(y.dtype)
    out = jnp.zeros((t, y.shape[-1]), y.dtype)
    return out.at[st].add(weighted)


def _capacity(tokens: int, top_k: int, n_experts: int, cf: float) -> int:
    return max(1, math.ceil(tokens * top_k * cf / n_experts))


# ---------------------------------------------------------------------------
# Expert-parallel path (shard_map + all_to_all)
# ---------------------------------------------------------------------------


def _token_axes(total_tokens: int, mesh: Mesh,
                batch_axes: tuple[str, ...], model_axis: str
                ) -> tuple[str, ...]:
    """Widest axis tuple that evenly divides the token count.  Decode
    shapes (a handful of tokens) degrade gracefully: tokens replicate over
    the axes they cannot split across (redundant-but-correct dispatch)."""
    full = batch_axes + (model_axis,)
    def prod(axes):
        out = 1
        for a in axes:
            out *= mesh.shape[a]
        return out
    if total_tokens % prod(full) == 0 and total_tokens >= prod(full):
        return full
    if total_tokens % prod(batch_axes) == 0 and total_tokens >= prod(batch_axes):
        return batch_axes
    return ()


def moe_ep(
    x: jax.Array,                 # (B, S, D)
    w_router: jax.Array,          # (D, E)
    w_gate: jax.Array,            # (E, D, F)
    w_up: jax.Array,              # (E, D, F)
    w_down: jax.Array,            # (E, F, D)
    *,
    cfg: ModelConfig,
    mesh: Mesh,
    batch_axes: tuple[str, ...],
    model_axis: str = "model",
    fsdp_axis: Optional[str] = "data",
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Expert-parallel MoE layer.  Returns (y, lb_loss, z_loss)."""
    moe = cfg.moe
    B, S, D = x.shape
    ep = mesh.shape[model_axis]
    assert moe.n_experts % ep == 0, (moe.n_experts, ep)
    tok_axes = _token_axes(B * S, mesh, batch_axes, model_axis)
    tok_shards = 1
    for a in tok_axes:
        tok_shards *= mesh.shape[a]
    t_local = max(1, B * S // tok_shards)
    cap = _capacity(t_local, moe.top_k, moe.n_experts, moe.capacity_factor)
    total_tokens = float(B * S)

    fsdp = fsdp_axis if (fsdp_axis and mesh.shape.get(fsdp_axis, 1) > 1) else None

    def local(xl, wr, wg, wu, wd):
        # xl: (t_local, D) — tokens split over tok_axes (replicated on the
        # rest: decode shapes dispatch redundantly but correctly)
        if fsdp:
            wg = jax.lax.all_gather(wg, fsdp, axis=1, tiled=True)
            wu = jax.lax.all_gather(wu, fsdp, axis=1, tiled=True)
            wd = jax.lax.all_gather(wd, fsdp, axis=2, tiled=True)
        gates, eidx, probs, logits = route(xl, wr, moe.top_k)
        buf, se, st, pos, keep = _local_dispatch(
            xl, eidx, gates, moe.n_experts, cap)
        order_gates = gates.reshape(-1)[jnp.argsort(eidx.reshape(-1), stable=True)]
        # exchange: (E, C, D) -> (E/ep, C*ep, D) on the expert's owner
        recv = jax.lax.all_to_all(buf, model_axis, split_axis=0,
                                  concat_axis=1, tiled=True)
        g = jnp.einsum("ecd,edf->ecf", recv, wg)
        u = jnp.einsum("ecd,edf->ecf", recv, wu)
        h = jax.nn.silu(g.astype(jnp.float32)).astype(recv.dtype) * u
        yl = jnp.einsum("ecf,efd->ecd", h, wd)
        back = jax.lax.all_to_all(yl, model_axis, split_axis=1,
                                  concat_axis=0, tiled=True)
        out = _local_combine(back, se, st, pos, keep, gates, order_gates,
                             xl.shape[0])
        # aux losses: reduce count/mass over the token-split axes, then
        # combine (global estimator — see aux_losses docstring)
        counts, mass, z_num = aux_losses(probs, eidx, moe.n_experts, logits)
        if tok_axes:
            counts = jax.lax.psum(counts, tok_axes)
            mass = jax.lax.psum(mass, tok_axes)
            z_num = jax.lax.psum(z_num, tok_axes)
        lb = moe.n_experts * jnp.sum(counts * mass) / (
            total_tokens * total_tokens * moe.top_k)
        z = z_num / total_tokens
        return out, lb, z

    tok_spec = P(tok_axes if tok_axes else None, None)
    gate_up_spec = P(model_axis, fsdp, None)
    down_spec = P(model_axis, None, fsdp)
    y, lb, z = shard_map(
        local, mesh=mesh,
        in_specs=(tok_spec, P(None, None), gate_up_spec, gate_up_spec, down_spec),
        out_specs=(tok_spec, P(), P()),
        check_vma=False,
    )(x.reshape(B * S, D), w_router, w_gate, w_up, w_down)
    return y.reshape(B, S, D), lb, z


# ---------------------------------------------------------------------------
# Tensor-parallel-experts path (all_gather + psum_scatter)
# ---------------------------------------------------------------------------


def moe_tp(
    x: jax.Array,                 # (B, S, D)
    w_router: jax.Array,          # (D, E)
    w_gate: jax.Array,            # (E, D, F)  — F sharded over model
    w_up: jax.Array,
    w_down: jax.Array,            # (E, F, D)
    *,
    cfg: ModelConfig,
    mesh: Mesh,
    batch_axes: tuple[str, ...],
    model_axis: str = "model",
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """TP-inside-experts MoE (expert count need not divide the mesh)."""
    moe = cfg.moe
    B, S, D = x.shape
    m = mesh.shape[model_axis]
    tok_axes = _token_axes(B * S, mesh, batch_axes, model_axis)
    seq_split = model_axis in tok_axes
    tok_shards = 1
    for a in tok_axes:
        tok_shards *= mesh.shape[a]
    t_local = max(1, B * S // tok_shards)
    t_row = t_local * m if seq_split else t_local
    cap = _capacity(t_row, moe.top_k, moe.n_experts, moe.capacity_factor)
    total_tokens = float(B * S)
    row_axes = tuple(a for a in tok_axes if a != model_axis)

    def local(xl, wr, wg, wu, wd):
        # gather this data-row's tokens across the model axis (when split)
        xr = (jax.lax.all_gather(xl, model_axis, axis=0, tiled=True)
              if seq_split else xl)                    # (t_row, D)
        gates, eidx, probs, logits = route(xr, wr, moe.top_k)
        buf, se, st, pos, keep = _local_dispatch(xr, eidx, gates,
                                                 moe.n_experts, cap)
        order_gates = gates.reshape(-1)[jnp.argsort(eidx.reshape(-1), stable=True)]
        g = jnp.einsum("ecd,edf->ecf", buf, wg)      # F sliced over model
        u = jnp.einsum("ecd,edf->ecf", buf, wu)
        h = jax.nn.silu(g.astype(jnp.float32)).astype(buf.dtype) * u
        y_part = jnp.einsum("ecf,efd->ecd", h, wd)   # partial over F slice
        out_row = _local_combine(y_part, se, st, pos, keep, gates,
                                 order_gates, t_row)
        if seq_split:
            out = jax.lax.psum_scatter(out_row, model_axis,
                                       scatter_dimension=0, tiled=True)
        else:
            out = jax.lax.psum(out_row, model_axis)
        counts, mass, z_num = aux_losses(probs, eidx, moe.n_experts, logits)
        if row_axes:
            counts = jax.lax.psum(counts, row_axes)
            mass = jax.lax.psum(mass, row_axes)
            z_num = jax.lax.psum(z_num, row_axes)
        lb = moe.n_experts * jnp.sum(counts * mass) / (
            total_tokens * total_tokens * moe.top_k)
        z = z_num / total_tokens
        return out, lb, z

    tok_spec = P(tok_axes if tok_axes else None, None)
    y, lb, z = shard_map(
        local, mesh=mesh,
        in_specs=(tok_spec, P(None, None),
                  P(None, None, model_axis), P(None, None, model_axis),
                  P(None, model_axis, None)),
        out_specs=(tok_spec, P(), P()),
        check_vma=False,
    )(x.reshape(B * S, D), w_router, w_gate, w_up, w_down)
    return y.reshape(B, S, D), lb, z


# ---------------------------------------------------------------------------
# Dense oracle (tests / tiny shapes only)
# ---------------------------------------------------------------------------


def moe_ref(
    x: jax.Array, w_router: jax.Array, w_gate: jax.Array, w_up: jax.Array,
    w_down: jax.Array, *, cfg: ModelConfig,
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """No-drop dense-compute MoE: every expert on every token, masked.
    O(T*E*F) — the correctness oracle for the sparse paths."""
    moe = cfg.moe
    B, S, D = x.shape
    xt = x.reshape(B * S, D)
    gates, eidx, probs, logits = route(xt, w_router, moe.top_k)
    g = jnp.einsum("td,edf->tef", xt, w_gate)
    u = jnp.einsum("td,edf->tef", xt, w_up)
    h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
    y_all = jnp.einsum("tef,efd->ted", h, w_down)           # (T, E, D)
    mask = jax.nn.one_hot(eidx, moe.n_experts, dtype=jnp.float32)  # (T,k,E)
    w = (mask * gates[..., None]).sum(axis=1)               # (T, E)
    y = jnp.einsum("ted,te->td", y_all.astype(jnp.float32), w).astype(x.dtype)
    counts, mass, z_num = aux_losses(probs, eidx, moe.n_experts, logits)
    total = float(B * S)
    lb = moe.n_experts * jnp.sum(counts * mass) / (total * total * moe.top_k)
    z = z_num / total
    return y.reshape(B, S, D), lb, z


def choose_moe_impl(cfg: ModelConfig, mesh: Mesh, model_axis: str = "model") -> str:
    """EP when experts divide the model axis, else TP-inside-experts."""
    m = mesh.shape.get(model_axis, 1)
    if cfg.moe and cfg.moe.n_experts % m == 0:
        return "ep"
    return "tp"
