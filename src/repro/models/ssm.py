"""Mamba2 / SSD (state-space duality) blocks [arXiv:2405.21060].

The SSD layer computes, per head h with scalar decay ``a_t = exp(dt_t A)``:

    state_t = a_t * state_{t-1} + dt_t * B_t x_t^T        (N x P state)
    y_t     = C_t . state_t + D * x_t

Training uses the chunked SSD algorithm: the sequence splits into chunks
of length Q; within a chunk the dual quadratic (attention-like) form is
used, and a single inter-chunk recurrence over ``S/Q`` steps carries the
state — O(S Q) work, sub-quadratic in S, and TPU-friendly (the intra-chunk
form is batched matmuls on the MXU).  ``repro.kernels.ssd_scan`` holds the
Pallas kernel for the intra-chunk core; this module is the pure-jnp
reference implementation the kernel is validated against (the model layer
can route through either).

Decode is O(1) in sequence length: one multiply-accumulate against the
(H, P, N) state — this is why the ssm/hybrid archs run the ``long_500k``
cell that pure-attention archs skip.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from .config import ModelConfig


class MambaState(NamedTuple):
    conv: jax.Array         # (B, conv_width-1, conv_dim) rolling conv input
    ssm: jax.Array          # (B, H, P, N) recurrent state (f32)


def _split_proj(cfg: ModelConfig, zxbcdt: jax.Array):
    s = cfg.ssm
    d_in, H = cfg.d_inner, cfg.ssm_heads
    gn = s.n_groups * s.d_state
    z, x, B, C, dt = jnp.split(
        zxbcdt, [d_in, 2 * d_in, 2 * d_in + gn, 2 * d_in + 2 * gn], axis=-1)
    return z, x, B, C, dt


def _dt_activation(dt: jax.Array, dt_bias: jax.Array) -> jax.Array:
    return jax.nn.softplus(dt.astype(jnp.float32) + dt_bias.astype(jnp.float32))


def _gated_norm(y: jax.Array, z: jax.Array, w: jax.Array, eps: float) -> jax.Array:
    """Mamba2's gated RMSNorm: norm(y * silu(z)) * w."""
    y32 = y.astype(jnp.float32) * jax.nn.silu(z.astype(jnp.float32))
    var = jnp.mean(jnp.square(y32), axis=-1, keepdims=True)
    return (y32 * jax.lax.rsqrt(var + eps) * w.astype(jnp.float32)).astype(y.dtype)


# ---------------------------------------------------------------------------
# Chunked SSD (training / prefill)
# ---------------------------------------------------------------------------


def ssd_chunked(
    x: jax.Array,       # (B, S, H, P)
    dt: jax.Array,      # (B, S, H) — post-softplus, f32
    A: jax.Array,       # (H,) negative, f32
    Bm: jax.Array,      # (B, S, G, N)
    Cm: jax.Array,      # (B, S, G, N)
    chunk: int,
    *,
    initial_state: jax.Array | None = None,   # (B, H, P, N)
) -> tuple[jax.Array, jax.Array]:
    """Chunked SSD scan.  Returns (y (B,S,H,P), final_state (B,H,P,N))."""
    B_, S, H, Pd = x.shape
    G, N = Bm.shape[-2], Bm.shape[-1]
    assert S % chunk == 0, (S, chunk)
    nc = S // chunk
    rep = H // G

    xc = x.reshape(B_, nc, chunk, H, Pd)
    dtc = dt.reshape(B_, nc, chunk, H)
    Bc = Bm.reshape(B_, nc, chunk, G, N)
    Cc = Cm.reshape(B_, nc, chunk, G, N)

    dA = dtc * A[None, None, None, :]                     # (B,nc,Q,H) negatives
    cum = jnp.cumsum(dA, axis=2)                          # inclusive cumsum
    total = cum[:, :, -1, :]                              # (B,nc,H)

    # intra-chunk (dual quadratic form): L[i,j] = exp(cum_i - cum_j) * dt_j, j<=i
    # (named_scope: the Pallas ssd_scan kernel fuses this region — the
    # roofline engine separates its bytes; see core/fidelity.py)
    with jax.named_scope("flashable_ssd"):
        li = cum[:, :, :, None, :] - cum[:, :, None, :, :]    # (B,nc,Q,Q,H)
        mask = jnp.tril(jnp.ones((chunk, chunk), bool))
        L = jnp.where(mask[None, None, :, :, None], jnp.exp(li), 0.0)
        L = L * dtc[:, :, None, :, :]                         # x dt_j
        # scores_ij = C_i . B_j (group-shared across rep heads)
        CB = jnp.einsum("bnigx,bnjgx->bnijg", Cc.astype(jnp.float32),
                        Bc.astype(jnp.float32))               # (B,nc,Q,Q,G)
        CB = jnp.repeat(CB, rep, axis=-1)                     # (B,nc,Q,Q,H)
        W = CB * L                                            # (B,nc,Q,Q,H)
        y_intra = jnp.einsum("bnijh,bnjhp->bnihp", W, xc.astype(jnp.float32))

    # chunk states: S_c = sum_j exp(total - cum_j) dt_j B_j x_j^T
    decay_to_end = jnp.exp(total[:, :, None, :] - cum)    # (B,nc,Q,H)
    wdt = decay_to_end * dtc                              # (B,nc,Q,H)
    Bh = jnp.repeat(Bc, rep, axis=-2)                     # (B,nc,Q,H,N)
    Sc = jnp.einsum("bcqh,bcqhn,bcqhp->bchpn",
                    wdt, Bh.astype(jnp.float32), xc.astype(jnp.float32))

    # inter-chunk recurrence over nc (sequential scan over chunk states)
    chunk_decay = jnp.exp(total)                          # (B,nc,H)
    init = (initial_state.astype(jnp.float32) if initial_state is not None
            else jnp.zeros((B_, H, Pd, N), jnp.float32))

    def step(state, inp):
        dec, s_c = inp                                    # (B,H), (B,H,P,N)
        new = state * dec[:, :, None, None] + s_c
        return new, state                                 # emit state *entering* chunk

    final, entering = jax.lax.scan(
        step, init,
        (jnp.moveaxis(chunk_decay, 1, 0), jnp.moveaxis(Sc, 1, 0)))
    entering = jnp.moveaxis(entering, 0, 1)               # (B,nc,H,P,N)

    # inter-chunk contribution: y_i += C_i exp(cum_i) . state_entering
    Ch = jnp.repeat(Cc, rep, axis=-2)                     # (B,nc,Q,H,N)
    decay_in = jnp.exp(cum)                               # (B,nc,Q,H)
    y_inter = jnp.einsum("bcqhn,bchpn,bcqh->bcqhp",
                         Ch.astype(jnp.float32), entering, decay_in)

    y = (y_intra + y_inter).reshape(B_, S, H, Pd)
    return y.astype(x.dtype), final


def ssd_decode_step(
    x: jax.Array,       # (B, H, P)
    dt: jax.Array,      # (B, H) f32 (post-softplus)
    A: jax.Array,       # (H,)
    Bm: jax.Array,      # (B, G, N)
    Cm: jax.Array,      # (B, G, N)
    state: jax.Array,   # (B, H, P, N) f32
) -> tuple[jax.Array, jax.Array]:
    """One-token SSD update (O(1) in sequence length)."""
    H = x.shape[1]
    G = Bm.shape[1]
    rep = H // G
    dec = jnp.exp(dt * A[None, :])                        # (B,H)
    Bh = jnp.repeat(Bm, rep, axis=1).astype(jnp.float32)  # (B,H,N)
    Ch = jnp.repeat(Cm, rep, axis=1).astype(jnp.float32)
    upd = dt[:, :, None, None] * jnp.einsum(
        "bhn,bhp->bhpn", Bh, x.astype(jnp.float32))
    new_state = state * dec[:, :, None, None] + upd
    y = jnp.einsum("bhpn,bhn->bhp", new_state, Ch)
    return y.astype(x.dtype), new_state


# ---------------------------------------------------------------------------
# Full Mamba2 block (projections + conv + SSD + gate)
# ---------------------------------------------------------------------------


def mamba_block_train(x: jax.Array, p: dict, cfg: ModelConfig,
                      *, impl: str = "ref", shard_heads=None,
                      return_state: bool = False):
    """(B, S, D) -> (B, S, D)  [or (y, MambaState) with return_state]."""
    s = cfg.ssm
    Bsz, S, D = x.shape
    H, Pd, N, G = cfg.ssm_heads, s.head_dim, s.d_state, s.n_groups
    zxbcdt = jnp.einsum("bsd,de->bse", x, p["in_proj"])
    z, xin, Bm, Cm, dt = _split_proj(cfg, zxbcdt)

    # causal depthwise conv over (x, B, C)
    xbc_raw = jnp.concatenate([xin, Bm, Cm], axis=-1)     # (B,S,conv_dim)
    xbc = xbc_raw
    pad = jnp.pad(xbc, ((0, 0), (s.conv_width - 1, 0), (0, 0)))
    windows = jnp.stack(
        [pad[:, i:i + S] for i in range(s.conv_width)], axis=2)  # (B,S,W,C)
    xbc = jax.nn.silu(
        (jnp.einsum("bswc,wc->bsc", windows.astype(jnp.float32),
                    p["conv_w"].astype(jnp.float32))
         + p["conv_b"].astype(jnp.float32))).astype(x.dtype)
    xin, Bm, Cm = jnp.split(xbc, [cfg.d_inner, cfg.d_inner + G * N], axis=-1)

    xh = xin.reshape(Bsz, S, H, Pd)
    if shard_heads is not None:
        xh = shard_heads(xh)
    Bg = Bm.reshape(Bsz, S, G, N)
    Cg = Cm.reshape(Bsz, S, G, N)
    dtf = _dt_activation(dt, p["dt_bias"])                   # (B,S,H) f32
    A = -jnp.exp(p["A_log"].astype(jnp.float32))

    y = _try_pallas_ssd(xh, dtf, A, Bg, Cg, s.chunk) if (
        impl == "pallas" and not return_state) else None
    final_state = None
    if y is None:
        y, final_state = ssd_chunked(xh, dtf, A, Bg, Cg, s.chunk)
    y = y + xh * p["D"].astype(x.dtype)[None, None, :, None]
    y = y.reshape(Bsz, S, cfg.d_inner)
    y = _gated_norm(y, z, p["norm_w"], cfg.norm_eps)
    out = jnp.einsum("bse,ed->bsd", y, p["out_proj"])
    if return_state:
        conv_state = xbc_raw[:, S - (s.conv_width - 1):, :].astype(jnp.bfloat16)
        return out, MambaState(conv=conv_state, ssm=final_state)
    return out


def mamba_block_decode(x: jax.Array, p: dict, cfg: ModelConfig,
                       state: MambaState) -> tuple[jax.Array, MambaState]:
    """(B, 1, D) one-token step with rolling conv + SSM state."""
    s = cfg.ssm
    Bsz = x.shape[0]
    H, Pd, N, G = cfg.ssm_heads, s.head_dim, s.d_state, s.n_groups
    zxbcdt = jnp.einsum("bsd,de->bse", x, p["in_proj"])[:, 0]
    z, xin, Bm, Cm, dt = _split_proj(cfg, zxbcdt)

    xbc_new = jnp.concatenate([xin, Bm, Cm], axis=-1)     # (B, conv_dim)
    conv_in = jnp.concatenate([state.conv, xbc_new[:, None, :]], axis=1)
    xbc = jax.nn.silu(
        (jnp.einsum("bwc,wc->bc", conv_in.astype(jnp.float32),
                    p["conv_w"].astype(jnp.float32))
         + p["conv_b"].astype(jnp.float32))).astype(x.dtype)
    new_conv = conv_in[:, 1:, :]

    xin, Bm, Cm = jnp.split(xbc, [cfg.d_inner, cfg.d_inner + G * N], axis=-1)
    xh = xin.reshape(Bsz, H, Pd)
    Bg = Bm.reshape(Bsz, G, N)
    Cg = Cm.reshape(Bsz, G, N)
    dtf = _dt_activation(dt, p["dt_bias"])                   # (B,H)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    y, new_ssm = ssd_decode_step(xh, dtf, A, Bg, Cg, state.ssm)
    y = y + xh * p["D"].astype(x.dtype)[None, :, None]
    y = y.reshape(Bsz, cfg.d_inner)
    y = _gated_norm(y, z, p["norm_w"], cfg.norm_eps)
    out = jnp.einsum("be,ed->bd", y, p["out_proj"])[:, None, :]
    return out, MambaState(conv=new_conv, ssm=new_ssm)


def init_mamba_state(cfg: ModelConfig, batch: int) -> MambaState:
    s = cfg.ssm
    return MambaState(
        conv=jnp.zeros((batch, s.conv_width - 1, cfg.conv_dim), jnp.bfloat16),
        ssm=jnp.zeros((batch, cfg.ssm_heads, s.head_dim, s.d_state), jnp.float32),
    )


def _try_pallas_ssd(xh, dtf, A, Bg, Cg, chunk):
    try:
        from repro.kernels import ops as kops
        return kops.ssd_scan(xh, dtf, A, Bg, Cg, chunk=chunk)
    except Exception:
        return None
