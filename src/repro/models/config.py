"""Model configuration — one dataclass covering every assigned family.

Families: ``dense`` (GQA transformer), ``moe`` (sparse FFN), ``ssm``
(Mamba2/SSD), ``hybrid`` (Mamba2 + shared attention block, Zamba2-style),
``encdec`` (encoder-decoder, Seamless-style), ``vlm`` (dense backbone +
patch-embedding frontend stub).

Per the assignment, [vlm]/[audio] entries specify the transformer backbone
only; the modality frontend is a stub whose precomputed embeddings arrive
via ``input_specs()``.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Optional, Sequence


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_ff_expert: int               # per-expert hidden width
    capacity_factor: float = 1.25
    router_z_coef: float = 1e-3
    load_balance_coef: float = 1e-2


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    d_state: int = 128
    head_dim: int = 64             # Mamba2 P
    expand: int = 2                # d_inner = expand * d_model
    n_groups: int = 1
    conv_width: int = 4
    chunk: int = 256               # SSD chunk length


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                    # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: Optional[int] = None             # default d_model // n_heads
    # attention pattern
    window: int = 0                            # 0 = full attention (SWA if > 0)
    global_every: int = 0                      # >0: every k-th layer is global (rest windowed)
    # family extensions
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    attn_every: int = 0                        # hybrid: shared attn block every k layers
    enc_layers: int = 0                        # encdec: encoder depth
    frontend: Optional[str] = None             # None | "patch" | "frames"
    frontend_len: int = 576                    # stub embedding length
    # numerics / misc
    rope_theta: float = 10000.0
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    remat: str = "full"                        # none | dots | full
    max_seq_len: int = 131072
    source: str = ""                           # provenance note

    # ------------------------------------------------------------------
    @property
    def hd(self) -> int:
        return self.head_dim if self.head_dim is not None else self.d_model // self.n_heads

    @property
    def q_dim(self) -> int:
        return self.n_heads * self.hd

    @property
    def kv_dim(self) -> int:
        return self.n_kv_heads * self.hd

    @property
    def d_inner(self) -> int:
        s = self.ssm or SSMConfig()
        return s.expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        s = self.ssm or SSMConfig()
        return self.d_inner // s.head_dim

    @property
    def conv_dim(self) -> int:
        s = self.ssm or SSMConfig()
        return self.d_inner + 2 * s.n_groups * s.d_state

    @property
    def in_proj_dim(self) -> int:
        # Mamba2 fused in-projection: z, x, B, C, dt
        s = self.ssm or SSMConfig()
        return 2 * self.d_inner + 2 * s.n_groups * s.d_state + self.ssm_heads

    def layer_kinds(self) -> list[str]:
        """Per-layer block kind: 'attn' | 'moe' | 'mamba'."""
        if self.family in ("dense", "vlm"):
            return ["attn"] * self.n_layers
        if self.family == "moe":
            return ["moe"] * self.n_layers
        if self.family == "ssm":
            return ["mamba"] * self.n_layers
        if self.family == "hybrid":
            return ["mamba"] * self.n_layers  # shared attn woven in separately
        if self.family == "encdec":
            return ["attn"] * self.n_layers
        raise ValueError(self.family)

    def layer_windows(self) -> list[int]:
        """Per-layer attention window (0 = full).  Implements the paper
        configs' SWA / local:global interleavings."""
        if self.global_every > 0:
            # gemma3 pattern: (global_every-1) local layers then 1 global
            return [0 if (i + 1) % self.global_every == 0 else self.window
                    for i in range(self.n_layers)]
        return [self.window] * self.n_layers

    # -- parameter accounting (used by codesign + roofline useful-FLOPs) ----
    def param_count(self) -> int:
        D, V = self.d_model, self.vocab
        emb = V * D * (1 if self.tie_embeddings else 2)
        per_attn = D * self.q_dim + 2 * D * self.kv_dim + self.q_dim * D
        per_mlp = 3 * D * self.d_ff
        per_moe = (self.moe.n_experts * 3 * D * self.moe.d_ff_expert
                   + D * self.moe.n_experts) if self.moe else 0
        per_mamba = (D * self.in_proj_dim + self.conv_dim * (self.ssm.conv_width if self.ssm else 4)
                     + 3 * self.ssm_heads + self.d_inner + self.d_inner * D) if self.family in ("ssm", "hybrid") else 0
        total = emb
        if self.family in ("dense", "vlm"):
            total += self.n_layers * (per_attn + per_mlp + 2 * D)
        elif self.family == "moe":
            total += self.n_layers * (per_attn + per_moe + 2 * D)
        elif self.family == "ssm":
            total += self.n_layers * (per_mamba + 2 * D)
        elif self.family == "hybrid":
            total += self.n_layers * (per_mamba + 2 * D)
            total += per_attn + per_mlp + 2 * D  # one shared block
        elif self.family == "encdec":
            # encoder self-attn+mlp, decoder self+cross+mlp
            total += self.enc_layers * (per_attn + per_mlp + 2 * D)
            total += self.n_layers * (2 * per_attn + per_mlp + 3 * D)
        if self.frontend:
            total += 2 * D * D  # projector MLP
        return int(total)

    def active_param_count(self) -> int:
        """Per-token active params (MoE activates top_k of n_experts)."""
        if not self.moe:
            return self.param_count()
        D = self.d_model
        dense_like = self.param_count() - self.n_layers * self.moe.n_experts * 3 * D * self.moe.d_ff_expert
        active_moe = self.n_layers * self.moe.top_k * 3 * D * self.moe.d_ff_expert
        return int(dense_like + active_moe)

    def validate(self) -> None:
        assert self.family in ("dense", "moe", "ssm", "hybrid", "encdec", "vlm"), self.family
        if self.family not in ("ssm", "hybrid"):
            assert self.n_heads >= 1 and self.n_kv_heads >= 1
            assert self.n_heads % self.n_kv_heads == 0, "GQA group must divide"
        if self.family == "moe":
            assert self.moe is not None
        if self.family in ("ssm", "hybrid"):
            assert self.ssm is not None
            assert self.d_inner % (self.ssm.head_dim) == 0
        if self.family == "encdec":
            assert self.enc_layers > 0


def smoke_variant(cfg: ModelConfig, **overrides) -> ModelConfig:
    """A reduced same-family config for CPU smoke tests: small depth/width,
    few experts, tiny vocab — per the assignment's smoke-test rule."""
    d_model = 64
    n_heads = max(2, min(4, cfg.n_heads))
    n_kv = max(1, n_heads // max(1, cfg.n_heads // max(cfg.n_kv_heads, 1)))
    if n_heads % n_kv:
        n_kv = 1
    small: dict = dict(
        name=cfg.name + "-smoke",
        n_layers=min(4, cfg.n_layers) if cfg.family != "hybrid" else 4,
        d_model=d_model,
        n_heads=n_heads,
        n_kv_heads=n_kv,
        head_dim=16,
        d_ff=128,
        vocab=256,
        max_seq_len=512,
        window=min(cfg.window, 32) if cfg.window else 0,
        global_every=cfg.global_every if cfg.global_every else 0,
        frontend_len=8 if cfg.frontend else cfg.frontend_len,
        remat="none",
    )
    if cfg.moe:
        small["moe"] = MoEConfig(n_experts=4, top_k=min(2, cfg.moe.top_k),
                                 d_ff_expert=64, capacity_factor=2.0)
    if cfg.ssm:
        small["ssm"] = SSMConfig(d_state=16, head_dim=16, expand=2,
                                 n_groups=1, conv_width=4, chunk=16)
    if cfg.family == "hybrid":
        small["attn_every"] = 2
    if cfg.family == "encdec":
        small["enc_layers"] = 2
        small["n_layers"] = 2
    small.update(overrides)
    return dataclasses.replace(cfg, **small)
