"""AdamW with fp32 master weights over bf16 compute params.

Mixed-precision convention (production standard): the *model* params are
bf16 (what matmuls consume); the optimizer holds an fp32 master copy plus
fp32 first/second moments.  The update runs in fp32 and re-casts.  The
optimizer state therefore shards exactly like the params (the sharding
rules in parallel/sharding.py apply leaf-wise to the whole state tree).
"""

from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jax.Array          # () i32
    master: Any              # fp32 copy of params
    m: Any                   # fp32 first moment
    v: Any                   # fp32 second moment


def adamw_init(params: Any) -> AdamWState:
    f32 = lambda p: p.astype(jnp.float32)
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return AdamWState(
        step=jnp.zeros((), jnp.int32),
        master=jax.tree.map(f32, params),
        m=jax.tree.map(zeros, params),
        v=jax.tree.map(zeros, params),
    )


def clip_by_global_norm(grads: Any, max_norm: float) -> tuple[Any, jax.Array]:
    sq = sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
             for g in jax.tree.leaves(grads))
    norm = jnp.sqrt(sq)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale), grads), norm


def adamw_update(
    grads: Any,
    state: AdamWState,
    params: Any,
    *,
    lr: jax.Array | float,
    b1: float = 0.9,
    b2: float = 0.95,
    eps: float = 1e-8,
    weight_decay: float = 0.1,
    max_grad_norm: float = 1.0,
) -> tuple[Any, AdamWState, dict]:
    """One AdamW step.  Returns (new bf16 params, new state, metrics)."""
    grads, gnorm = clip_by_global_norm(grads, max_grad_norm)
    step = state.step + 1
    t = step.astype(jnp.float32)
    bc1 = 1.0 - b1 ** t
    bc2 = 1.0 - b2 ** t

    def upd(g, m, v, w):
        m = b1 * m + (1.0 - b1) * g
        v = b2 * v + (1.0 - b2) * jnp.square(g)
        mhat = m / bc1
        vhat = v / bc2
        w = w - lr * (mhat / (jnp.sqrt(vhat) + eps) + weight_decay * w)
        return m, v, w

    flat_g, tdef = jax.tree.flatten(grads)
    flat_m = jax.tree.leaves(state.m)
    flat_v = jax.tree.leaves(state.v)
    flat_w = jax.tree.leaves(state.master)
    new_m, new_v, new_w = [], [], []
    for g, m, v, w in zip(flat_g, flat_m, flat_v, flat_w):
        m2, v2, w2 = upd(g, m, v, w)
        new_m.append(m2)
        new_v.append(v2)
        new_w.append(w2)
    master = jax.tree.unflatten(tdef, new_w)
    new_state = AdamWState(step=step, master=master,
                           m=jax.tree.unflatten(tdef, new_m),
                           v=jax.tree.unflatten(tdef, new_v))
    cast = jax.tree.map(lambda w, p: w.astype(p.dtype), master, params)
    return cast, new_state, {"grad_norm": gnorm, "lr": jnp.asarray(lr)}


def warmup_cosine(step: jax.Array, *, peak_lr: float, warmup: int,
                  total: int, floor: float = 0.1) -> jax.Array:
    """Linear warmup then cosine decay to floor*peak."""
    t = step.astype(jnp.float32)
    warm = peak_lr * t / jnp.maximum(1.0, float(warmup))
    prog = jnp.clip((t - warmup) / jnp.maximum(1.0, float(total - warmup)),
                    0.0, 1.0)
    cos = peak_lr * (floor + (1.0 - floor) * 0.5 * (1.0 + jnp.cos(jnp.pi * prog)))
    return jnp.where(t < warmup, warm, cos)
