from .adamw import (AdamWState, adamw_init, adamw_update, clip_by_global_norm,
                    warmup_cosine)
from .compression import (CompressionState, compress_decompress,
                          error_feedback_init, error_feedback_step,
                          quantize_int8_blockwise, dequantize_int8_blockwise)

__all__ = [
    "AdamWState", "adamw_init", "adamw_update", "clip_by_global_norm",
    "warmup_cosine",
    "CompressionState", "compress_decompress", "error_feedback_init",
    "error_feedback_step", "quantize_int8_blockwise",
    "dequantize_int8_blockwise",
]
