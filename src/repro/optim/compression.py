"""Gradient compression: block-wise int8 quantization with error feedback.

The paper budgets compute for integrity/encryption *inside* the staged
data path (section 3.4); the training-time analogue is spending a little
compute to quantize gradients so the cross-pod (DCN-class) collective
moves 4x fewer bytes.  Error feedback (1-bit-Adam style) keeps the
quantization residual local and re-injects it next step, preserving
convergence.

``repro.kernels.quantize`` is the Pallas kernel for the blockwise
quantize; this module is the jnp reference and the error-feedback state
machinery.  ``repro.parallel.collectives.compressed_psum`` performs the
actual reduced-precision exchange.
"""

from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


def _pad_to_block(x: jax.Array, block: int) -> tuple[jax.Array, int]:
    flat = x.reshape(-1)
    pad = (-flat.size) % block
    if pad:
        flat = jnp.pad(flat, (0, pad))
    return flat, pad


def quantize_int8_blockwise(x: jax.Array, block: int = 256
                            ) -> tuple[jax.Array, jax.Array]:
    """x (any shape) -> (int8 values (nblocks, block), f32 scales (nblocks,)).

    Symmetric per-block scaling: scale = max|x| / 127.
    """
    flat, _ = _pad_to_block(x.astype(jnp.float32), block)
    blocks = flat.reshape(-1, block)
    scale = jnp.max(jnp.abs(blocks), axis=1) / 127.0
    safe = jnp.where(scale > 0, scale, 1.0)
    q = jnp.clip(jnp.round(blocks / safe[:, None]), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8_blockwise(q: jax.Array, scale: jax.Array,
                              shape: tuple[int, ...]) -> jax.Array:
    flat = (q.astype(jnp.float32) * scale[:, None]).reshape(-1)
    n = 1
    for d in shape:
        n *= d
    return flat[:n].reshape(shape)


def compress_decompress(x: jax.Array, block: int = 256) -> jax.Array:
    """Round-trip (the local-arithmetic part of a compressed collective)."""
    q, s = quantize_int8_blockwise(x, block)
    return dequantize_int8_blockwise(q, s, x.shape).astype(x.dtype)


class CompressionState(NamedTuple):
    """Per-parameter error-feedback residuals (fp32)."""

    residual: Any


def error_feedback_init(params: Any) -> CompressionState:
    return CompressionState(residual=jax.tree.map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params))


def error_feedback_step(grads: Any, state: CompressionState, block: int = 256
                        ) -> tuple[Any, CompressionState]:
    """Compress (g + residual); carry the quantization error to next step.

    Returns (decompressed gradients as seen by the receiving side, new
    state).  The communication itself happens in
    parallel/collectives.compressed_psum; composing that with this
    function is exact because quantization is deterministic.
    """

    def leaf(g, r):
        corrected = g.astype(jnp.float32) + r
        sent = compress_decompress(corrected, block)
        return sent.astype(jnp.float32), corrected - sent.astype(jnp.float32)

    flat_g, tdef = jax.tree.flatten(grads)
    flat_r = jax.tree.leaves(state.residual)
    outs = [leaf(g, r) for g, r in zip(flat_g, flat_r)]
    sent = jax.tree.unflatten(tdef, [o[0] for o in outs])
    resid = jax.tree.unflatten(tdef, [o[1] for o in outs])
    return sent, CompressionState(residual=resid)
