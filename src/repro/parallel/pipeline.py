"""Pipeline parallelism: GPipe-style microbatch pipeline over an axis.

``pipeline_forward`` runs a layer-stack forward as a collective_permute
rotation inside ``shard_map``: each device along ``stage_axis`` owns a
contiguous slab of layers; microbatches enter at stage 0 and activations
hop stage-to-stage with ``collective_permute`` (the paper's peer-to-peer,
buffer-state-coordinated transfer — no global scheduler, each stage
simply services whatever sits in its inbound slot).

Steady-state utilization is ``m / (m + s - 1)`` for m microbatches and s
stages; the schedule loop below is exactly that bubble.  Used as the PP
option for the deepest assigned arch (mistral-large-123b) where the pod
axis becomes the stage axis — see EXPERIMENTS.md §Perf.
"""

from __future__ import annotations

from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from .compat import pvary, shard_map


def pipeline_forward(
    layer_fn: Callable[[Any, jax.Array], jax.Array],
    stacked_params: Any,           # leaves with leading dim n_layers
    x: jax.Array,                  # (n_micro, micro_batch, ...) microbatched input
    *,
    mesh: Mesh,
    stage_axis: str = "pod",
    layers_per_stage: int,
) -> jax.Array:
    """Forward x through all stages.  Returns (n_micro, micro_batch, ...).

    ``layer_fn(stage_params, h) -> h`` applies one stage's slab (typically
    an inner lax.scan over ``layers_per_stage`` layers).
    """
    n_stages = mesh.shape[stage_axis]
    n_micro = x.shape[0]
    perm_fwd = [(i, (i + 1) % n_stages) for i in range(n_stages)]

    def staged(params_local, x_local):
        # params_local: this stage's slab (layers_per_stage, ...)
        # x_local: full microbatch stream, present on stage 0
        stage_id = jax.lax.axis_index(stage_axis)
        mb_shape = x_local.shape[1:]
        # carries must be marked device-varying over the stage axis up
        # front (ppermute outputs are varying; fori_loop carries need
        # matching types)
        buf = pvary(jnp.zeros(mb_shape, x_local.dtype), stage_axis)
        outs = pvary(jnp.zeros_like(x_local), stage_axis)

        def tick(t, carry):
            buf, outs = carry
            # stage 0 injects microbatch t (if any remain)
            inject = jnp.where(t < n_micro,
                               x_local[jnp.minimum(t, n_micro - 1)],
                               jnp.zeros(mb_shape, x_local.dtype))
            h = jnp.where(stage_id == 0, inject, buf)
            h = layer_fn(params_local, h)
            # last stage banks the finished microbatch (entered at t-s+1);
            # select-based update (lax.cond branches would need matching
            # varying-manual-axes types inside shard_map)
            done_idx = t - (n_stages - 1)
            valid = jnp.logical_and(done_idx >= 0, done_idx < n_micro)
            idx = jnp.clip(done_idx, 0, n_micro - 1)
            cur = jax.lax.dynamic_index_in_dim(outs, idx, 0, keepdims=False)
            new = jnp.where(valid, h.astype(outs.dtype), cur)
            outs = jax.lax.dynamic_update_index_in_dim(outs, new, idx, 0)
            # rotate activations one stage forward
            buf = jax.lax.ppermute(h, stage_axis, perm_fwd)
            return buf, outs

        buf, outs = jax.lax.fori_loop(0, n_micro + n_stages - 1, tick,
                                      (buf, outs))
        # result lives on the last stage; broadcast so out_specs can be
        # stage-replicated (callers usually reduce immediately anyway)
        outs = jax.lax.psum(
            jnp.where(stage_id == n_stages - 1, outs, jnp.zeros_like(outs)),
            stage_axis)
        return outs

    # stage axis shards the layer dim of every stacked leaf
    param_spec = jax.tree.map(lambda _: P(stage_axis), stacked_params)
    return shard_map(
        staged, mesh=mesh,
        in_specs=(param_spec, P(*( [None] * x.ndim ))),
        out_specs=P(*([None] * x.ndim)),
    )(stacked_params, x)
