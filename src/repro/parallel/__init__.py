from .sharding import (batch_axes_of, batch_specs, param_shardings,
                       param_specs, state_shardings)
from .collectives import compressed_psum, hierarchical_psum
from .pipeline import pipeline_forward

__all__ = ["batch_axes_of", "batch_specs", "param_shardings", "param_specs",
           "state_shardings", "compressed_psum", "hierarchical_psum",
           "pipeline_forward"]
