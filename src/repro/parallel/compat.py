"""Version-portable jax API surface used by the manual-collective paths."""

from __future__ import annotations

import jax


def pvary(x, axis_names):
    """``jax.lax.pvary`` where it exists; older jax has no varying-axes
    typing inside shard_map, so the marker is a no-op there."""
    if hasattr(jax.lax, "pvary"):
        return jax.lax.pvary(x, axis_names)
    return x


def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool = True):
    """``jax.shard_map`` where available, else the experimental spelling
    (which names the replication check ``check_rep``)."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=check_vma)
    from jax.experimental.shard_map import shard_map as _shard_map
    return _shard_map(f, mesh=mesh, in_specs=in_specs,
                      out_specs=out_specs, check_rep=check_vma)
