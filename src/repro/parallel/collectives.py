"""Collective schedules: compressed and hierarchical gradient exchange.

The paper's §3.2 finding transposed to ICI/DCN: the *transport algorithm*
(CCA there, collective schedule here) matters less than path balance —
but when a path **is** collective-bound (the cross-pod DCN hop), reducing
bytes on the wire is the lever.  Two tools:

* :func:`compressed_psum` — int8 block-quantized all-reduce: a
  reduce-scatter-shaped ``all_to_all`` of int8 chunks, local fp32
  reduction, then an int8 ``all_gather`` of results.  Wire bytes are
  ~ ``(2 (g-1)/g) * 1 B/elem`` vs ``(2 (g-1)/g) * 2 B/elem`` for a bf16
  ring all-reduce — a 2x (4x vs fp32) cut on the dominant term.
  Deterministic, so it composes exactly with error feedback
  (optim/compression.py).

* :func:`hierarchical_psum` — reduce-scatter intra-pod (cheap ICI),
  exchange only shards across pods (expensive DCN), all-gather intra-pod.
  Cross-pod traffic drops by the pod size (16x here).

Both run inside ``shard_map`` (manual-collective regions embedded in the
auto-sharded program, like the MoE paths).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.optim.compression import (dequantize_int8_blockwise,
                                     quantize_int8_blockwise)



def _axis_size(axis_name) -> int:
    """jax.lax.axis_size where available; psum(1) is the portable spelling."""
    if hasattr(jax.lax, "axis_size"):
        return jax.lax.axis_size(axis_name)
    return jax.lax.psum(1, axis_name)


def compressed_psum(x: jax.Array, axis_name: str, *, block: int = 256
                    ) -> jax.Array:
    """int8-wire psum over ``axis_name`` (call inside shard_map).

    Algorithm (g = axis size):
      1. quantize local tensor blockwise -> (q int8, scales f32)
      2. all_to_all chunk exchange: device i receives chunk i of every
         peer's q (reduce-scatter data movement, int8 on the wire)
      3. local fp32 dequant + sum of the g received chunks
      4. re-quantize the reduced chunk; all_gather int8 + scales
      5. dequant -> full reduced tensor
    """
    g = _axis_size(axis_name)
    if g == 1:
        return x
    orig_shape, orig_dtype = x.shape, x.dtype
    q, s = quantize_int8_blockwise(x, block)          # (nb, block), (nb,)
    nb = q.shape[0]
    pad_blocks = (-nb) % g
    if pad_blocks:
        q = jnp.pad(q, ((0, pad_blocks), (0, 0)))
        s = jnp.pad(s, (0, pad_blocks))
    nb_p = q.shape[0]
    # 2. exchange: split blocks axis into g chunks, one per peer
    q_recv = jax.lax.all_to_all(q.reshape(g, nb_p // g, block), axis_name,
                                split_axis=0, concat_axis=0, tiled=False)
    s_recv = jax.lax.all_to_all(s.reshape(g, nb_p // g), axis_name,
                                split_axis=0, concat_axis=0, tiled=False)
    # q_recv: (g, nb_p/g, block) — peer-major chunks of my shard
    chunk = (q_recv.astype(jnp.float32) * s_recv[..., None]).sum(axis=0)
    # 4. requantize the reduced shard and gather all shards
    qr, sr = quantize_int8_blockwise(chunk, block)
    q_all = jax.lax.all_gather(qr, axis_name, axis=0, tiled=True)
    s_all = jax.lax.all_gather(sr, axis_name, axis=0, tiled=True)
    flat = (q_all.astype(jnp.float32) * s_all[:, None]).reshape(-1)
    n = 1
    for d in orig_shape:
        n *= d
    return flat[:n].reshape(orig_shape).astype(orig_dtype)


def hierarchical_psum(x: jax.Array, *, intra_axis: str, inter_axis: str,
                      compress_inter: bool = False, block: int = 256
                      ) -> jax.Array:
    """Two-level all-reduce (call inside shard_map).

    reduce-scatter over ``intra_axis`` (ICI), psum the shard over
    ``inter_axis`` (DCN; optionally int8-compressed), all-gather back over
    ``intra_axis``.
    """
    g = _axis_size(intra_axis)
    flat = x.reshape(-1)
    pad = (-flat.size) % g
    if pad:
        flat = jnp.pad(flat, (0, pad))
    shard = jax.lax.psum_scatter(flat.reshape(g, -1), intra_axis,
                                 scatter_dimension=0, tiled=False)
    if compress_inter:
        shard = compressed_psum(shard, inter_axis, block=block)
    else:
        shard = jax.lax.psum(shard, inter_axis)
    full = jax.lax.all_gather(shard, intra_axis, axis=0, tiled=False)
    out = full.reshape(-1)[: x.size].reshape(x.shape)
    return out.astype(x.dtype)
