"""Sharding rules: parameter-tree paths -> PartitionSpecs.

MaxText-style logical rules resolved against the production mesh
(DESIGN.md §4):

* batch over the data axes ``("pod", "data")`` / ``("data",)``,
* attention heads / FFN hidden / experts / vocab over ``"model"`` (TP/EP),
* the *other* weight dim additionally over ``"data"`` (FSDP / ZeRO-3) when
  ``fsdp=True`` — mandatory for the 123B/141B archs,
* every rule checks divisibility and silently drops an axis that does not
  divide (predictable memory: no GSPMD padding surprises).

Optimizer state shards exactly like the parameters (leaf-wise reuse).
"""

from __future__ import annotations

import math
from typing import Any, Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models.config import ModelConfig


def batch_axes_of(mesh: Mesh) -> tuple[str, ...]:
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def _axis_size(mesh: Mesh, axis) -> int:
    if axis is None:
        return 1
    if isinstance(axis, tuple):
        return math.prod(mesh.shape[a] for a in axis)
    return mesh.shape[axis]


def _fit(mesh: Mesh, shape: tuple[int, ...], want: tuple) -> P:
    """Drop axes that don't divide their dim."""
    out = []
    for dim, axis in zip(shape, want):
        if axis is None:
            out.append(None)
            continue
        size = _axis_size(mesh, axis)
        out.append(axis if (size > 1 and dim % size == 0) else None)
    return P(*out)


def _spec_for(path: str, shape: tuple[int, ...], cfg: ModelConfig,
              mesh: Mesh, *, fsdp: bool, ep: bool) -> P:
    """Rule table keyed on the trailing parameter name."""
    d = "data" if fsdp else None
    name = path.split("/")[-1]
    parent = path.split("/")[-2] if "/" in path else ""
    nd = len(shape)

    def tail(*axes):
        """Right-align axes against shape (stacked-L leading dims -> None)."""
        want = [None] * (nd - len(axes)) + list(axes)
        return _fit(mesh, shape, tuple(want))

    if name == "embed":
        return tail("model", d)
    if name == "lm_head":
        return tail(d, "model")
    if name in ("wq", "wk", "wv"):
        return tail(d, "model")
    if name == "wo":
        return tail("model", d)
    if parent == "moe" or (parent in ("", "moe") and name == "router"):
        if name == "router":
            return tail(d, None)
        if name in ("w_gate", "w_up"):
            return tail("model", d, None) if ep else tail(None, d, "model")
        if name == "w_down":
            return tail("model", None, d) if ep else tail(None, "model", d)
    if name in ("w_gate", "w_up"):
        return tail(d, "model")
    if name == "w_down":
        return tail("model", d)
    if name == "in_proj":
        return tail(d, "model")
    if name == "out_proj":
        return tail("model", d)
    if name == "conv_w":
        return tail(None, "model")
    if name in ("conv_b", "A_log", "D", "dt_bias", "norm_w"):
        return tail("model")
    if name in ("w1",):       # projector
        return tail(d, "model")
    if name in ("w2",):
        return tail("model", d)
    if name == "frame_proj":
        return tail(d, "model")
    # norms / scalars / step counters
    return P(*([None] * nd))


def _leaf_path(path) -> str:
    return "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                    for p in path)


def param_specs(tree: Any, cfg: ModelConfig, mesh: Mesh, *,
                fsdp: bool = True) -> Any:
    """PartitionSpec tree matching ``tree`` (params or any state whose
    leaves mirror param shapes, e.g. Adam moments)."""
    ep = bool(cfg.moe and cfg.moe.n_experts % mesh.shape["model"] == 0)
    leaves, treedef = jax.tree_util.tree_flatten_with_path(tree)
    specs = [_spec_for(_leaf_path(p), tuple(v.shape), cfg, mesh,
                       fsdp=fsdp, ep=ep)
             for p, v in leaves]
    return jax.tree_util.tree_unflatten(treedef, specs)


def param_shardings(tree: Any, cfg: ModelConfig, mesh: Mesh, *,
                    fsdp: bool = True) -> Any:
    return jax.tree.map(lambda s: NamedSharding(mesh, s),
                        param_specs(tree, cfg, mesh, fsdp=fsdp))


def state_shardings(state_tree: Any, cfg: ModelConfig, mesh: Mesh, *,
                    fsdp: bool = True) -> Any:
    """Shardings for AdamWState-like containers.  The optimizer's
    master/m/v subtrees mirror the param tree, so their leaf paths end in
    the same names and the path-keyed rules apply directly; scalars (the
    step counter) fall through to replicated."""
    return param_shardings(state_tree, cfg, mesh, fsdp=fsdp)


def batch_specs(batch: Any, mesh: Mesh) -> Any:
    """Batch pytree: leading dim over the data axes."""
    axes = batch_axes_of(mesh)

    def spec(v):
        nd = getattr(v, "ndim", None) or len(v.shape)
        return NamedSharding(mesh, P(axes, *([None] * (nd - 1))))

    return jax.tree.map(spec, batch)
