"""Production mesh construction.

A function (not a module-level constant) so importing this module never
touches jax device state — the dry-run must set
``xla_force_host_platform_device_count`` *before* first jax init.

Single pod: 256 chips as (16, 16) = ("data", "model").
Multi-pod:  2 pods x 256 chips as (2, 16, 16) = ("pod", "data", "model");
the "pod" axis is the DCN-class boundary the hierarchical collectives
(parallel/collectives.py) treat differently from ICI.
"""

from __future__ import annotations

import jax


def _make_mesh(shape: tuple[int, ...], axes: tuple[str, ...]) -> jax.sharding.Mesh:
    # jax.sharding.AxisType landed after some jax versions in this image;
    # Auto is the default when the kwarg is omitted.
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is not None:
        return jax.make_mesh(shape, axes,
                             axis_types=(axis_type.Auto,) * len(axes))
    return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _make_mesh(shape, axes)


def make_host_mesh(shape: tuple[int, ...] = None,
                   axes: tuple[str, ...] = None) -> jax.sharding.Mesh:
    """Small mesh over whatever devices exist (tests / CPU examples)."""
    n = len(jax.devices())
    if shape is None:
        shape, axes = (1, n), ("data", "model")
    return _make_mesh(shape, axes)
