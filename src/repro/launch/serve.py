"""Serving driver: batched prefill + streaming decode through the mover.

The serving path is the paper's two workload classes composed:

* **bulk** — prefill: the prompt batch moves through the stack once and
  the KV cache is staged (the "data at rest" transfer),
* **streaming** — decode: tokens are produced step by step and move to
  the client sink *while being generated*, staged through a burst buffer
  so a slow client never stalls the accelerator (the low-jitter
  decoupling of §2.1),
* **fan-out** — pass ``generate`` a list of client sinks and the token
  stream replicates down one planned branch per client
  (:func:`~repro.core.basin.decode_fanout_basin` + the mover's parallel
  mirror mode): per-branch stage reports let ``replan`` pin a stall on
  the one slow client instead of degrading every stream.  Deliveries run
  through a **per-client drainer pool** (one small buffer + drainer
  thread per client), so one blocking client write no longer serializes
  its siblings at the merge buffer — a transient client stall is
  absorbed by that client's own staging depth while the other streams
  keep flowing.

Usage (CPU smoke):
  python -m repro.launch.serve --arch repro-100m --smoke --batch 4 \
      --prompt-len 64 --gen 32
"""

from __future__ import annotations

import argparse
import time
from typing import Any, Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, get_smoke_config
from repro.core.basin import decode_fanout_basin, decode_stream_basin
from repro.core.codesign import CodesignPlan
from repro.core.mover import MoverConfig, UnifiedDataMover
from repro.core.planner import plan_transfer
from repro.core.telemetry import TelemetryRegistry, get_registry
from repro.launch import steps as steps_lib
from repro.launch.mesh import make_host_mesh
from repro.models.api import ShapeSpec, build
from repro.models.blocks import ShardCtx

#: floor for the fed-back client drain-rate estimate — one stalled client
#: must not collapse the next request's plan to a zero-rate basin
MIN_CLIENT_GBPS = 1e-3

#: how many recent serve transfers the drain-rate estimate averages over
DRAIN_RATE_WINDOW = 4

#: a stream counts as client-limited evidence only when its staging hop
#: spent at least this fraction of the transfer backpressured by the sink
CLIENT_LIMITED_STALL = 0.1


def observed_client_gbps(registry: TelemetryRegistry) -> Optional[float]:
    """Client drain rate (Gbps) observed by recent decode streams.

    Only streams the client actually *limited* count as evidence: a
    stream's end-to-end rate is ``min(decode rate, client drain rate)``,
    so a transfer paced by decode compute (no downstream backpressure in
    its stage reports) says nothing about the client — feeding it back
    would ratchet the client-tier estimate down to the producer's rate
    with no way to recover.  Fan-out (mirror) transfers count bytes once
    per client delivery, so their aggregate rate is divided by the branch
    count to recover a per-client estimate.  Returns ``None`` when no
    client-limited stream has been recorded (the modeled default
    applies)."""
    rates = []
    for r in registry.reports("serve"):
        if r.elapsed_s <= 0 or r.bytes <= 0:
            continue
        if not any(s.stall_down_s >= CLIENT_LIMITED_STALL * r.elapsed_s
                   for s in r.stage_reports):
            continue                     # producer-paced: no client evidence
        n_clients = len({s.name.split("/")[0] for s in r.stage_reports
                         if "/" in s.name}) or 1
        rates.append(r.throughput_bytes_per_s / n_clients)
    if not rates:
        return None
    window = rates[-DRAIN_RATE_WINDOW:]
    return max(MIN_CLIENT_GBPS, (sum(window) / len(window)) * 8.0 / 1e9)


class Server:
    """Holds params + compiled prefill/decode; streams tokens out through
    a burst buffer."""

    def __init__(self, cfg, mesh=None, *, max_len: int = 512,
                 plan: Optional[CodesignPlan] = None,
                 telemetry: Optional[TelemetryRegistry] = None,
                 replan_every_tokens: int = 0):
        self.cfg = cfg
        self.api = build(cfg)
        self.mesh = mesh
        self.max_len = max_len
        self.plan = plan or CodesignPlan(sharding="tp", seq_parallel=False)
        self.telemetry = telemetry if telemetry is not None else get_registry()
        self.replan_every_tokens = replan_every_tokens
        self.ctx = (steps_lib.make_ctx(self.api, mesh, self.plan)
                    if mesh is not None else ShardCtx())
        self.params = None
        self._prefill = jax.jit(
            lambda p, b: self.api.prefill(p, b, self.ctx, max_len=max_len))
        self._decode = jax.jit(
            lambda p, c, t: self.api.decode_step(p, c, t, self.ctx))

    def load(self, seed: int = 0) -> None:
        self.params = self.api.init(jax.random.PRNGKey(seed))

    def stream_basin(self):
        """The decode-stream basin, its client tier re-estimated from the
        drain rate previous requests actually observed (telemetry feedback
        between requests — ROADMAP item 2)."""
        drain = observed_client_gbps(self.telemetry)
        if drain is None:
            return decode_stream_basin()
        return decode_stream_basin(client_gbps=drain)

    def fanout_basin(self, n_clients: int):
        """The decode fan-out basin for ``n_clients`` concurrent streams,
        its per-client tier re-estimated from observed drain rates."""
        drain = observed_client_gbps(self.telemetry)
        if drain is None:
            return decode_fanout_basin(n_clients)
        return decode_fanout_basin(n_clients, client_gbps=drain)

    def generate(self, batch: dict, n_tokens: int,
                 sink=None) -> np.ndarray:
        """Greedy-decode ``n_tokens``; each step's tokens stream to ``sink``
        through the unified mover (streaming transfer).  Staging depth
        comes from the decode-stream basin plan — sized so an erratic
        client never stalls the accelerator; the plan is ``ordered``
        because the token stream must arrive in decode order.  The basin's
        client tier is re-estimated from observed drain rates between
        requests, and with ``replan_every_tokens`` set the plan also
        revises online inside one long generation.

        ``sink`` may be a *list* of callables — concurrent client streams.
        The token stream then replicates down one planned branch per
        client (decode fan-out, mover parallel mirror mode): every client
        receives every token, each branch carries its own staging depth,
        and the per-branch stage reports attribute a stall to the one
        slow client.  Deliveries drain through the mover's per-client
        drainer pool, so one client blocking on a write stalls only its
        own stream while its siblings keep receiving."""
        logits, cache = self._prefill(self.params, batch)
        tok = jnp.argmax(logits[:, -1], axis=-1, keepdims=True).astype(jnp.int32)
        out = [np.asarray(tok)]
        n_batch = int(tok.shape[0])

        def produce() -> Iterator[np.ndarray]:
            nonlocal tok, cache
            for _ in range(n_tokens - 1):
                logits_i, cache = self._decode(self.params, cache, tok)
                tok = jnp.argmax(logits_i[:, -1], axis=-1,
                                 keepdims=True).astype(jnp.int32)
                yield np.asarray(tok)

        sinks = list(sink) if isinstance(sink, (list, tuple)) else None
        collected: list[np.ndarray] = []
        if sinks and len(sinks) > 1:
            plan = plan_transfer(self.fanout_basin(len(sinks)),
                                 item_bytes=max(1, n_batch * 4),
                                 stages=("token-stream",), ordered=True,
                                 path="auto")
            mover = UnifiedDataMover(MoverConfig(checksum=False), plan=plan,
                                     telemetry=self.telemetry, layer="serve")
            # branch order follows basin link order == client order
            sink_map = {b.branch_id: s
                        for b, s in zip(plan.branches, sinks)}
            first = plan.branches[0].branch_id
            first_sink = sink_map[first]

            def tee(item):
                collected.append(item)
                first_sink(item)

            sink_map[first] = tee
            report = mover.parallel_transfer(
                produce(), sink_map, plan=plan, mode="mirror",
                replan_every_items=self.replan_every_tokens,
                drainer_pool=True)
        else:
            one_sink = sinks[0] if sinks else sink
            plan = plan_transfer(self.stream_basin(),
                                 item_bytes=max(1, n_batch * 4),
                                 stages=("token-stream",), ordered=True,
                                 path="auto")
            mover = UnifiedDataMover(MoverConfig(checksum=False), plan=plan,
                                     telemetry=self.telemetry, layer="serve")
            report = mover.streaming_transfer(
                produce(), one_sink or collected.append, plan=plan,
                replan_every_items=self.replan_every_tokens)
        out.extend(collected)
        self.last_report = report
        return np.concatenate(out, axis=1)


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="repro-100m")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=32)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    server = Server(cfg, max_len=args.prompt_len + args.gen + 1)
    server.load()
    rng = np.random.default_rng(0)
    batch = {"tokens": rng.integers(0, cfg.vocab,
                                    (args.batch, args.prompt_len),
                                    dtype=np.int32)}
    if cfg.family == "encdec":
        batch["frames"] = rng.standard_normal(
            (args.batch, args.prompt_len, cfg.d_model)).astype(np.float32)
    if cfg.frontend:
        batch["extra_embeds"] = rng.standard_normal(
            (args.batch, cfg.frontend_len, cfg.d_model)).astype(np.float32)

    t0 = time.monotonic()
    tokens = server.generate(batch, args.gen)
    dt = time.monotonic() - t0
    tps = args.batch * args.gen / dt
    print(f"[serve] generated {tokens.shape} in {dt:.2f}s ({tps:.1f} tok/s)")
    print(f"[serve] stream fidelity: throughput="
          f"{server.last_report.throughput_bytes_per_s:.0f} B/s "
          f"bottleneck={server.last_report.bottleneck_stage().name if server.last_report.stage_reports else 'n/a'}")


if __name__ == "__main__":
    main()
