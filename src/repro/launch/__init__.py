"""Launch layer: production mesh, dry-run, trainer, server."""
