"""Training driver: the full co-designed data path, end to end.

    dataset -> burst-buffered input pipeline -> pjit train_step
            -> async checksummed checkpoints -> restart recovery

Fault tolerance (DESIGN.md §7):
* periodic async checkpoints (manifest-atomic, SHA-256 per shard),
* automatic restart discovery (newest complete manifest),
* step-failure recovery: a failing step restores the last checkpoint and
  resumes (``--inject-failure`` exercises this in tests/examples),
* elastic restore: checkpoints re-shard onto whatever mesh the restarted
  job has.

Usage (CPU example — full meshes need the dry-run, not execution):
  python -m repro.launch.train --arch repro-100m --steps 50 \
      --global-batch 8 --seq-len 256 --ckpt-dir /tmp/ckpt
"""

from __future__ import annotations

import argparse
import time
from typing import Any, Optional

import jax
import numpy as np

from repro.checkpoint.manager import CheckpointManager
from repro.configs import get_config, get_smoke_config
from repro.core.basin import tpu_input_basin
from repro.core.codesign import CodesignPlan
from repro.core.telemetry import get_registry
from repro.data.pipeline import InputPipeline, PipelineConfig, SyntheticTokenSource
from repro.launch import steps as steps_lib
from repro.launch.mesh import make_host_mesh
from repro.models.api import build
from repro.optim.adamw import adamw_init
from repro.parallel.sharding import batch_axes_of


class Trainer:
    """Owns the step function, state, pipeline, and recovery logic."""

    def __init__(self, cfg, mesh, *, plan: Optional[CodesignPlan] = None,
                 ckpt_dir: Optional[str] = None, ckpt_every: int = 50,
                 lr: float = 3e-4, total_steps: int = 1000):
        self.cfg = cfg
        self.api = build(cfg)
        self.mesh = mesh
        self.plan = plan or CodesignPlan(sharding="fsdp_tp", microbatches=1,
                                         remat=cfg.remat,
                                         seq_parallel=False)
        # warmup must fit inside the run: the default 100-step warmup never
        # reaches peak lr on short runs (smoke tests, examples)
        warmup = max(1, min(100, total_steps // 5))
        (self.train_step, self.p_shard, self.s_shard,
         self.ctx) = steps_lib.make_train_step(
            self.api, mesh, self.plan, lr_peak=lr, warmup=warmup,
            total_steps=total_steps)
        self.ckpt = (CheckpointManager(ckpt_dir, every_steps=ckpt_every)
                     if ckpt_dir else None)
        self.params = None
        self.opt_state = None
        self.step_idx = 0
        self.metrics_log: list[dict] = []

    # -- state ---------------------------------------------------------------

    def init_state(self, seed: int = 0) -> None:
        key = jax.random.PRNGKey(seed)
        params = jax.jit(self.api.init, out_shardings=self.p_shard)(key)
        opt = jax.jit(adamw_init, out_shardings=self.s_shard)(params)
        self.params, self.opt_state = params, opt

    def try_restore(self) -> bool:
        """Resume from the newest complete checkpoint, re-sharded onto the
        current mesh (elastic)."""
        if self.ckpt is None:
            return False
        like = {"params": self.params, "opt": self.opt_state}
        shardings = {"params": self.p_shard, "opt": self.s_shard}
        step, state = self.ckpt.restore_latest(like, shardings=shardings)
        if step is None:
            return False
        self.params, self.opt_state = state["params"], state["opt"]
        self.step_idx = step
        return True

    # -- loop ----------------------------------------------------------------

    def run(self, source, n_steps: int, *, inject_failure_at: int = -1,
            replan_every: int = 0, telemetry_json: Optional[str] = None,
            telemetry_every: int = 10,
            telemetry_jsonl: Optional[str] = None) -> list[dict]:
        """Train ``n_steps``.  ``replan_every > 0`` folds observed input
        stall ratios and service-time samples back into the transfer plan
        *online*, every that many batches, at a buffer boundary inside the
        running stream (one batch = one item, so the step cadence and the
        item cadence coincide) — no staged batch is dropped and the
        revision takes effect mid-run, not at the next epoch.  Logged
        fidelity gaps always measure against the plan the stream started
        with.  ``telemetry_json`` dumps the cross-layer
        :class:`~repro.core.telemetry.TelemetryRegistry` to that path every
        ``telemetry_every`` steps (atomic rename — safe to poll);
        ``telemetry_jsonl`` additionally *appends* one snapshot line per
        flush to that path — a time series the trend example
        (``examples/telemetry_timeseries.py``) reads back."""
        pc = getattr(source, "pc", None)
        pipeline = InputPipeline(
            source, basin=tpu_input_basin(), pc=pc, mesh=self.mesh,
            batch_axes=batch_axes_of(self.mesh),
            # None defers to pc.replan_every_items; an unset flag must not
            # silently disable a cadence the PipelineConfig asked for
            replan_every_items=replan_every if replan_every else None)
        it = iter(pipeline)
        done = 0
        while done < n_steps:
            batch = next(it, None)
            if batch is None:
                break
            try:
                if self.step_idx == inject_failure_at:
                    inject_failure_at = -1          # fail exactly once
                    raise RuntimeError("injected node failure")
                t0 = time.monotonic()
                self.params, self.opt_state, metrics = self.train_step(
                    self.params, self.opt_state, batch)
                loss = float(metrics["loss"])
                dt = time.monotonic() - t0
            except RuntimeError as e:
                if "injected" not in str(e):
                    raise
                # node-failure path: restore + resume (paper: the data path
                # must survive erratic components)
                restored = self.try_restore()
                if not restored:
                    self.init_state()
                continue
            self.step_idx += 1
            done += 1
            rec = {"step": self.step_idx, "loss": loss, "wall_s": dt,
                   "input_stall_s": pipeline.consumer_stall_s(),
                   "input_fidelity_gap": pipeline.fidelity_gap()}
            self.metrics_log.append(rec)
            if done % max(1, telemetry_every) == 0:
                if telemetry_json:
                    get_registry().dump_json(telemetry_json)
                if telemetry_jsonl:
                    get_registry().append_jsonl(telemetry_jsonl)
            if self.ckpt is not None:
                self.ckpt.maybe_save(self.step_idx, {
                    "params": self.params, "opt": self.opt_state})
        pipeline.record_telemetry()
        if telemetry_json:
            get_registry().dump_json(telemetry_json)
        if telemetry_jsonl:
            get_registry().append_jsonl(telemetry_jsonl)
        if self.ckpt is not None:
            self.ckpt.wait()
            self.ckpt.maybe_save(self.step_idx, {
                "params": self.params, "opt": self.opt_state}, force=True)
            self.ckpt.wait()
        return self.metrics_log


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="repro-100m")
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced smoke config")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--global-batch", type=int, default=4)
    ap.add_argument("--seq-len", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--inject-failure-at", type=int, default=-1)
    ap.add_argument("--replan-every", type=int, default=0,
                    help="revise the transfer plan online from observed "
                         "stalls and service-time samples every N batches, "
                         "at a buffer boundary inside the running stream "
                         "(0 = off)")
    ap.add_argument("--telemetry-json", default=None, metavar="PATH",
                    help="periodically dump the cross-layer telemetry "
                         "registry to PATH as JSON (atomic rename; for "
                         "dashboards)")
    ap.add_argument("--telemetry-every", type=int, default=10,
                    help="step cadence of --telemetry-json/-jsonl dumps")
    ap.add_argument("--telemetry-jsonl", default=None, metavar="PATH",
                    help="append one telemetry snapshot per flush to PATH "
                         "as a JSONL time series (see "
                         "examples/telemetry_timeseries.py)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    mesh = make_host_mesh()
    trainer = Trainer(cfg, mesh, ckpt_dir=args.ckpt_dir,
                      ckpt_every=args.ckpt_every, lr=args.lr,
                      total_steps=args.steps)
    trainer.init_state(args.seed)
    if trainer.try_restore():
        print(f"[train] resumed from step {trainer.step_idx}")

    pc = PipelineConfig(global_batch=args.global_batch, seq_len=args.seq_len,
                        seed=args.seed)
    source = SyntheticTokenSource(cfg, pc, n_batches=args.steps + 8)
    log = trainer.run(source, args.steps,
                      inject_failure_at=args.inject_failure_at,
                      replan_every=args.replan_every,
                      telemetry_json=args.telemetry_json,
                      telemetry_every=args.telemetry_every,
                      telemetry_jsonl=args.telemetry_jsonl)
    for rec in log[-5:]:
        gap = rec.get("input_fidelity_gap")
        gap_s = f" gap {gap:+.3f}" if gap is not None else ""
        print(f"[train] step {rec['step']:5d} loss {rec['loss']:.4f} "
              f"wall {rec['wall_s']*1e3:.1f} ms "
              f"stall {rec['input_stall_s']:.3f}s{gap_s}")
    losses = [r["loss"] for r in log]
    if len(losses) >= 10:
        print(f"[train] loss {losses[0]:.4f} -> {losses[-1]:.4f} "
              f"({'improved' if losses[-1] < losses[0] else 'NOT improved'})")
    print("[train] transfer telemetry (all layers):")
    for line in get_registry().format_summary().splitlines():
        print(f"[train]   {line}")


if __name__ == "__main__":
    main()
