import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: prove the distribution config is coherent without
hardware (the paper's §3.3 argument — an emulated environment with high
predictive fidelity replaces the dedicated testbed).

For every (architecture x input shape x mesh) cell this driver:

  1. builds the production mesh ((16,16) single-pod / (2,16,16) multi-pod
     over 512 emulated host devices),
  2. lowers + compiles the exact production step (train_step for train
     shapes incl. the full AdamW update; prefill/serve_step for inference
     shapes) from ShapeDtypeStruct inputs — no allocation,
  3. records memory_analysis() (fits-in-HBM proof), cost_analysis(), and
     the roofline terms extracted from the optimized HLO
     (core/fidelity.py: per-device FLOPs / bytes / collective bytes with
     while-loop trip counts multiplied through),
  4. writes one JSON per cell under experiments/dryrun/ — EXPERIMENTS.md
     §Dry-run/§Roofline tables are generated from these artifacts.

Usage:
  python -m repro.launch.dryrun --arch phi3-mini-3.8b --shape train_4k
  python -m repro.launch.dryrun --all --mesh single
  python -m repro.launch.dryrun --arch mixtral-8x22b --shape decode_32k --mesh multi
"""

import argparse
import dataclasses
import json
import time
import traceback
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.configs import ASSIGNED_ARCHS, get_config
from repro.core.codesign import CodesignPlan
from repro.core.fidelity import analyze_hlo_text, roofline
from repro.launch.mesh import make_production_mesh
from repro.launch import steps as steps_lib
from repro.models.api import SHAPES, ModelApi, ShapeSpec, build
from repro.parallel.sharding import batch_axes_of

OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "experiments", "dryrun")


def default_plan(api: ModelApi, multi_pod: bool,
                 shape_name: str = "train_4k") -> CodesignPlan:
    """Global tuning default (codesign §2.3): FSDP x TP x SP everywhere —
    one configuration family from 360M to 141B; the analytic basin model
    picks the microbatch count so the plan fits HBM (the co-design loop,
    automated)."""
    from repro.core.codesign import predict, workload_from_config
    shape = SHAPES.get(shape_name, SHAPES["train_4k"])
    work = workload_from_config(api.cfg, shape.global_batch, shape.seq_len)
    pods = 2 if multi_pod else 1
    for mb in (1, 2, 4, 8):
        plan = CodesignPlan(sharding="fsdp_tp", microbatches=mb,
                            remat=api.cfg.remat, seq_parallel=True)
        pred = predict(work, plan, n_chips=256 * pods, dp=16, tp=16, pods=pods)
        if pred.fits:
            return plan
    return CodesignPlan(sharding="fsdp_tp", microbatches=8,
                        remat=api.cfg.remat, seq_parallel=True)


def _abstract(tree: Any, shardings: Any) -> Any:
    return jax.tree.map(
        lambda v, s: jax.ShapeDtypeStruct(v.shape, v.dtype, sharding=s),
        tree, shardings)


def run_cell(arch: str, shape_name: str, mesh_kind: str,
             plan: Optional[CodesignPlan] = None,
             out_dir: str = OUT_DIR, verbose: bool = True) -> dict:
    """Lower + compile one cell; return (and persist) its record."""
    t_start = time.time()
    shape = SHAPES[shape_name]
    cfg = get_config(arch)
    api = build(cfg)
    multi_pod = mesh_kind == "multi"

    ok, why = api.applicable(shape)
    record: dict[str, Any] = {
        "arch": arch, "shape": shape_name, "mesh": mesh_kind,
        "family": cfg.family, "params": cfg.param_count(),
        "active_params": cfg.active_param_count(),
    }
    if not ok:
        record.update(status="skipped", reason=why)
        _persist(record, out_dir)
        if verbose:
            print(f"[dryrun] SKIP {arch} x {shape_name} x {mesh_kind}: {why}")
        return record

    mesh = make_production_mesh(multi_pod=multi_pod)
    plan = plan or default_plan(api, multi_pod, shape_name)
    record["plan"] = plan.describe()

    try:
        with jax.set_mesh(mesh):
            if shape.kind == "train":
                lowered = _lower_train(api, mesh, plan, shape)
            elif shape.kind == "prefill":
                lowered = _lower_prefill(api, mesh, plan, shape)
            else:
                lowered = _lower_serve(api, mesh, plan, shape)
            t_low = time.time()
            compiled = lowered.compile()
            t_comp = time.time()

        ma = compiled.memory_analysis()
        ca = compiled.cost_analysis() or {}
        hlo = compiled.as_text()
        cost = analyze_hlo_text(hlo)
        rep = roofline(
            cost, label=f"{arch}/{shape_name}/{mesh_kind}",
            n_devices=mesh.size,
            model_flops=api.model_flops(shape),
            flash_ideal_bytes_global=api.flash_ideal_io_bytes(shape),
            memory_per_device_bytes=(ma.argument_size_in_bytes
                                     + ma.temp_size_in_bytes))
        record["flops_by_op"] = dict(sorted(
            cost.flops_by_op.items(), key=lambda kv: -kv[1])[:12])
        record.update(
            status="ok",
            lower_s=round(t_low - t_start, 2),
            compile_s=round(t_comp - t_low, 2),
            memory_analysis={
                "argument_bytes": ma.argument_size_in_bytes,
                "output_bytes": ma.output_size_in_bytes,
                "temp_bytes": ma.temp_size_in_bytes,
                "alias_bytes": ma.alias_size_in_bytes,
            },
            cost_analysis={"flops": ca.get("flops"),
                           "bytes": ca.get("bytes accessed")},
            roofline=rep.to_json(),
            hlo_bytes=len(hlo),
        )
        if verbose:
            print(f"[dryrun] OK   {arch} x {shape_name} x {mesh_kind} "
                  f"compile={record['compile_s']}s "
                  f"mem/dev={(ma.argument_size_in_bytes + ma.temp_size_in_bytes)/2**30:.2f}GiB")
            print(f"         {rep.summary()}")
    except Exception as e:  # a failing cell is a bug; keep the evidence
        record.update(status="error", error=f"{type(e).__name__}: {e}",
                      traceback=traceback.format_exc()[-4000:])
        if verbose:
            print(f"[dryrun] FAIL {arch} x {shape_name} x {mesh_kind}: {e}")
    _persist(record, out_dir)
    return record


def _lower_train(api, mesh, plan, shape):
    step, p_shard, s_shard, ctx = steps_lib.make_train_step(api, mesh, plan)
    p_abs = steps_lib.abstract_params(api)
    params = _abstract(p_abs, p_shard)
    from repro.optim.adamw import adamw_init
    s_abs = jax.eval_shape(adamw_init, p_abs)
    opt = _abstract(s_abs, s_shard)
    batch_abs = api.train_input_specs(shape)
    batch = _abstract(batch_abs, steps_lib._batch_shardings(api, mesh))
    return step.lower(params, opt, batch)


def _lower_prefill(api, mesh, plan, shape):
    step, ctx = steps_lib.make_prefill_step(api, mesh, plan, shape)
    p_abs = steps_lib.abstract_params(api)
    from repro.parallel.sharding import param_shardings
    fsdp = plan.sharding in ("fsdp", "fsdp_tp")
    p_shard = param_shardings(p_abs, api.cfg, mesh, fsdp=fsdp)
    params = _abstract(p_abs, p_shard)
    batch_abs = api.train_input_specs(shape)
    batch = {k: v for k, v in batch_abs.items() if k != "labels"}
    batch["labels"] = batch_abs["labels"]  # prefill reuses train batch shape
    batch = _abstract(batch, steps_lib._batch_shardings(api, mesh))
    return step.lower(params, batch)


def _lower_serve(api, mesh, plan, shape):
    step, cache_shard, ctx = steps_lib.make_serve_step(api, mesh, plan, shape)
    p_abs = steps_lib.abstract_params(api)
    from repro.parallel.sharding import param_shardings
    fsdp = plan.sharding in ("fsdp", "fsdp_tp")
    p_shard = param_shardings(p_abs, api.cfg, mesh, fsdp=fsdp)
    params = _abstract(p_abs, p_shard)
    cache_abs, tok_abs = api.decode_input_specs(shape, ctx)
    cache = _abstract(cache_abs, cache_shard)
    from jax.sharding import NamedSharding, PartitionSpec as P
    axes = batch_axes_of(mesh)
    dp = 1
    for a in axes:
        dp *= mesh.shape[a]
    tok_spec = P(axes, None) if shape.global_batch % dp == 0 else P(None, None)
    tokens = jax.ShapeDtypeStruct(tok_abs.shape, tok_abs.dtype,
                                  sharding=NamedSharding(mesh, tok_spec))
    return step.lower(params, cache, tokens)


def _persist(record: dict, out_dir: str) -> None:
    os.makedirs(out_dir, exist_ok=True)
    name = f"{record['arch']}__{record['shape']}__{record['mesh']}.json"
    with open(os.path.join(out_dir, name), "w") as f:
        json.dump(record, f, indent=1)


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", choices=list(ASSIGNED_ARCHS) + ["repro-100m"],
                    help="one architecture (default: all)")
    ap.add_argument("--shape", choices=list(SHAPES), help="one shape")
    ap.add_argument("--mesh", choices=["single", "multi", "both"],
                    default="both")
    ap.add_argument("--all", action="store_true",
                    help="run the full assigned matrix")
    ap.add_argument("--out", default=OUT_DIR)
    args = ap.parse_args()

    archs = [args.arch] if args.arch else list(ASSIGNED_ARCHS)
    shapes = [args.shape] if args.shape else list(SHAPES)
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]

    results = []
    for arch in archs:
        for shape in shapes:
            for mesh_kind in meshes:
                results.append(run_cell(arch, shape, mesh_kind,
                                        out_dir=args.out))
    n_ok = sum(r["status"] == "ok" for r in results)
    n_skip = sum(r["status"] == "skipped" for r in results)
    n_err = sum(r["status"] == "error" for r in results)
    print(f"\n[dryrun] done: {n_ok} ok, {n_skip} skipped, {n_err} errors "
          f"of {len(results)} cells")
    if n_err:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
