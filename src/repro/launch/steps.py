"""Step factories: build the jitted train/prefill/serve steps for an
(arch x mesh x plan) combination, with shardings and donation wired.

These are shared by the trainer, the server, and the dry-run — the
dry-run lowers exactly what production would execute.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.codesign import CodesignPlan
from repro.models.api import ModelApi, ShapeSpec
from repro.models.blocks import ShardCtx
from repro.optim.adamw import AdamWState, adamw_init, adamw_update, warmup_cosine
from repro.parallel.sharding import batch_axes_of, param_shardings


@dataclasses.dataclass(frozen=True)
class StepBundle:
    """Everything the driver needs for one configuration."""

    api: ModelApi
    mesh: Mesh
    ctx: ShardCtx
    plan: CodesignPlan
    param_sharding: Any            # tree of NamedSharding
    state_sharding: Any            # for AdamWState
    train_step: Any                # jitted (params, opt, batch) -> ...
    serve_step: Optional[Any] = None
    prefill_step: Optional[Any] = None


def make_ctx(api: ModelApi, mesh: Optional[Mesh], plan: CodesignPlan,
             impl: str = "ref") -> ShardCtx:
    axes = batch_axes_of(mesh) if mesh is not None else ("data",)
    return ShardCtx(mesh=mesh, batch_axes=axes, model_axis="model", impl=impl,
                    seq_parallel=plan.seq_parallel)


def abstract_params(api: ModelApi) -> Any:
    return jax.eval_shape(lambda: api.init(jax.random.PRNGKey(0)))


def make_train_step(api: ModelApi, mesh: Mesh, plan: CodesignPlan,
                    *, lr_peak: float = 3e-4, warmup: int = 100,
                    total_steps: int = 10000, impl: str = "ref"):
    """Returns (jitted train_step, param_shardings, state_shardings, ctx).

    train_step(params, opt_state, batch) -> (params', opt_state', metrics)
    — full forward+backward+AdamW update (what the dry-run compiles).
    """
    cfg = api.cfg
    ctx = make_ctx(api, mesh, plan, impl)
    fsdp = plan.sharding in ("fsdp", "fsdp_tp")

    p_abs = abstract_params(api)
    p_shard = param_shardings(p_abs, cfg, mesh, fsdp=fsdp)
    s_abs = jax.eval_shape(adamw_init, p_abs)
    s_shard = param_shardings(s_abs, cfg, mesh, fsdp=fsdp)

    def loss_fn(params, batch):
        loss, aux = api.loss(params, batch, ctx)
        return loss, aux

    def step(params, opt_state, batch):
        if plan.microbatches > 1:
            grads, (loss, aux) = _accumulated_grads(
                loss_fn, params, batch, plan.microbatches)
        else:
            (loss, aux), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params, batch)
        # step counter is pre-increment: schedule on step+1 so the very
        # first update trains at a nonzero warmup rate
        lr = warmup_cosine(opt_state.step + 1, peak_lr=lr_peak,
                           warmup=warmup, total=total_steps)
        params, opt_state, om = adamw_update(grads, opt_state, params, lr=lr)
        metrics = {"loss": loss, **{k: v for k, v in aux.items()}, **om}
        return params, opt_state, metrics

    batch_shard = _batch_shardings(api, mesh)
    jitted = jax.jit(
        step,
        in_shardings=(p_shard, s_shard, batch_shard),
        out_shardings=(p_shard, s_shard, None),
        donate_argnums=(0, 1),
    )
    return jitted, p_shard, s_shard, ctx


def _accumulated_grads(loss_fn, params, batch, n_micro: int):
    """Gradient accumulation over microbatches (lax.scan over splits)."""

    def split(v):
        b = v.shape[0]
        return v.reshape(n_micro, b // n_micro, *v.shape[1:])

    micro = jax.tree.map(split, batch)
    zero_g = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)

    def body(carry, mb):
        acc, loss_sum = carry
        (loss, aux), g = jax.value_and_grad(loss_fn, has_aux=True)(params, mb)
        acc = jax.tree.map(lambda a, x: a + x.astype(jnp.float32), acc, g)
        return (acc, loss_sum + loss), aux

    (acc, loss_sum), auxs = jax.lax.scan(body, (zero_g, 0.0), micro)
    grads = jax.tree.map(lambda a: a / n_micro, acc)
    aux = jax.tree.map(lambda a: a[-1], auxs)
    return grads, (loss_sum / n_micro, aux)


def _batch_shardings(api: ModelApi, mesh: Mesh) -> Any:
    axes = batch_axes_of(mesh)
    spec = api.train_input_specs(
        ShapeSpec("probe", 8, 8, "train"))   # structure only

    def shard(v):
        return NamedSharding(mesh, P(axes, *([None] * (len(v.shape) - 1))))

    return jax.tree.map(shard, spec)


def make_serve_step(api: ModelApi, mesh: Mesh, plan: CodesignPlan,
                    shape: ShapeSpec, *, impl: str = "ref"):
    """Returns (jitted serve_step, cache_shardings, ctx).

    serve_step(params, cache, tokens) -> (logits, cache') — one decode
    token against a seq_len-deep cache (what decode_* / long_* lower).
    """
    cfg = api.cfg
    ctx = make_ctx(api, mesh, plan, impl)
    fsdp = plan.sharding in ("fsdp", "fsdp_tp")
    p_abs = abstract_params(api)
    p_shard = param_shardings(p_abs, cfg, mesh, fsdp=fsdp)

    cache_abs, _ = api.decode_input_specs(shape, ctx)
    cache_shard = cache_shardings(cache_abs, mesh)

    def step(params, cache, tokens):
        return api.decode_step(params, cache, tokens, ctx)

    tok_shard = NamedSharding(
        mesh, P(batch_axes_of(mesh), None)
        if shape.global_batch % _dp(mesh) == 0 else P(None, None))
    jitted = jax.jit(step,
                     in_shardings=(p_shard, cache_shard, tok_shard),
                     out_shardings=(None, cache_shard),
                     donate_argnums=(1,))
    return jitted, cache_shard, ctx


def make_prefill_step(api: ModelApi, mesh: Mesh, plan: CodesignPlan,
                      shape: ShapeSpec, *, impl: str = "ref"):
    """prefill_step(params, batch) -> (last logits, populated cache)."""
    ctx = make_ctx(api, mesh, plan, impl)
    fsdp = plan.sharding in ("fsdp", "fsdp_tp")
    p_abs = abstract_params(api)
    p_shard = param_shardings(p_abs, api.cfg, mesh, fsdp=fsdp)
    batch_shard = _batch_shardings(api, mesh)

    def step(params, batch):
        return api.prefill(params, batch, ctx, max_len=shape.seq_len)

    jitted = jax.jit(step, in_shardings=(p_shard, batch_shard))
    return jitted, ctx


def _dp(mesh: Mesh) -> int:
    out = 1
    for a in batch_axes_of(mesh):
        out *= mesh.shape[a]
    return out


def cache_shardings(cache_abs: Any, mesh: Mesh) -> Any:
    """Decode-cache shardings by leaf kind.

    KV-like leaves (L, B, S, H, hd): batch over the data axes when it
    divides, else the *sequence* shards over data (long-context batch=1);
    heads over model when divisible.  Mamba states (L, B, ...): batch over
    data, feature dims over model when divisible.  Scalars replicated.
    """
    axes = batch_axes_of(mesh)
    dp = _dp(mesh)
    m = mesh.shape["model"]

    def leaf(path, v) -> NamedSharding:
        nd = len(v.shape)
        if nd == 0:
            return NamedSharding(mesh, P())
        name = str(getattr(path[-1], "key", getattr(path[-1], "name", "")))
        if nd == 5:          # (L, B, S, H, hd) attention caches
            L, B, S, H, _ = v.shape
            b_ax = axes if (B % dp == 0 and B >= dp) else None
            h_ax = "model" if H % m == 0 else None
            # when heads can't shard, the sequence takes the model axis
            # (flash-decode partials combine via psum); with batch also
            # unshardable the sequence takes the data axes instead
            if h_ax is None and S % m == 0:
                s_ax = "model"
            elif b_ax is None and S % dp == 0:
                s_ax = axes
            else:
                s_ax = None
            return NamedSharding(mesh, P(None, b_ax, s_ax, h_ax, None))
        if nd == 4 and name in ("conv", ""):   # (L, B, W, C) conv state
            L, B, W, C = v.shape
            b_ax = axes if (B % dp == 0 and B >= dp) else None
            c_ax = "model" if C % m == 0 else None
            return NamedSharding(mesh, P(None, b_ax, None, c_ax))
        if nd == 5 or nd == 4:
            pass
        if nd >= 3:          # (L, B, H, P, N) ssm state and friends
            B = v.shape[1]
            b_ax = axes if (B % dp == 0 and B >= dp) else None
            spec = [None, b_ax] + [None] * (nd - 2)
            if nd >= 3 and v.shape[2] % m == 0:
                spec[2] = "model"
            return NamedSharding(mesh, P(*spec))
        return NamedSharding(mesh, P(*([None] * nd)))

    leaves, treedef = jax.tree_util.tree_flatten_with_path(cache_abs)
    return jax.tree_util.tree_unflatten(
        treedef, [leaf(p, v) for p, v in leaves])
