"""Resumable transfer ledger — survive a killed transfer (§2.1's
"routine operation" promise, extended past the process boundary).

The adaptive loop handles *degradation* (slow tiers, lossy links,
shrunken grants) online, but a killed process used to mean restarting
the whole stream from byte zero — exactly the failure mode the
production trials behind the paper identify as what decides whether a
long transfer completes at all.  :class:`TransferLedger` closes that
gap: every delivered item's completion is recorded **durably** (an
append-only JSONL file, flushed and fsynced per batch) together with
its host SHA-256 identity, and ``bulk_transfer(resume=ledger)`` then

* **skips** every item the ledger already verified — the source wrapper
  claims matching identities and never stages them again,
* **folds** each skipped item's recorded digest into the live
  :class:`~repro.core.integrity.StreamDigest`, so the resumed run's
  stream checksum is bit-identical to an unbroken run's (the
  item-exactness proof rides the checksum, not trust),
* **records** every newly delivered item, so a second kill resumes from
  the union — after N interruptions the ledger holds each item exactly
  once and a final resume moves nothing.

Identity is the item's *content* (SHA-256 over
:func:`~repro.core.integrity.as_bytes`), kept as a **multiset**: a
stream that legitimately carries equal items needs one completion per
occurrence, and deliveries arrive out of order (concurrent staging
workers), so positional bookkeeping would be wrong by design.  Claims
during a resume pass are in-memory only — the durable file is never
rewritten, so a crash *during* resume loses no record.

The ledger records host SHA-256 identities; a resumed transfer
therefore requires ``checksum_placement="host"`` (the accel lattice
fingerprint is a different format by design — see
:meth:`StreamDigest.absorb_digest`).
"""

from __future__ import annotations

import hashlib
import json
import os
import threading
from typing import Any, Callable, Iterable, Iterator, Optional

from .integrity import StreamDigest, as_bytes

__all__ = ["TransferLedger"]


class TransferLedger:
    """Durable per-item completion record for resumable transfers.

    ``path=None`` keeps the ledger in memory (property tests, or a
    caller that persists it elsewhere); with a path, existing records
    load on open and new records append — a torn final line from a
    mid-write kill is skipped on load, never fatal.  Thread-safe: the
    mover's concurrent sink workers record through one lock.
    """

    def __init__(self, path: Optional[str] = None):
        self._lock = threading.Lock()
        self._counts: dict[str, int] = {}
        self._bytes: dict[str, int] = {}
        self._path = path
        self._fh = None
        #: per-resume-pass accounting (reset by :meth:`skip_verified`)
        self.skipped_items = 0
        self.skipped_bytes = 0
        if path is not None:
            if os.path.exists(path):
                with open(path, "r", encoding="utf-8") as fh:
                    for line in fh:
                        line = line.strip()
                        if not line:
                            continue
                        try:
                            rec = json.loads(line)
                            sha = rec["sha"]
                            nb = int(rec.get("bytes", 0))
                        except (ValueError, KeyError, TypeError):
                            # torn tail line from a mid-write kill: the
                            # item it described was never acknowledged,
                            # so dropping it is the safe direction
                            continue
                        self._counts[sha] = self._counts.get(sha, 0) + 1
                        self._bytes[sha] = nb
            self._fh = open(path, "a", encoding="utf-8")

    # -- identity -------------------------------------------------------------

    @staticmethod
    def item_key(item: Any) -> str:
        """Content identity: hex SHA-256 over the item's stable byte
        view — the same per-item digest the host stream checksum XORs,
        which is what lets a skipped item's record fold into the live
        digest."""
        return hashlib.sha256(as_bytes(item)).hexdigest()

    # -- recording ------------------------------------------------------------

    def record(self, item: Any) -> str:
        """Durably record one delivered item; returns its identity."""
        key = self.item_key(item)
        nb = len(as_bytes(item))
        with self._lock:
            self._counts[key] = self._counts.get(key, 0) + 1
            self._bytes[key] = nb
            if self._fh is not None:
                self._fh.write(json.dumps({"sha": key, "bytes": nb}) + "\n")
                self._fh.flush()
                try:
                    os.fsync(self._fh.fileno())
                except OSError:  # pragma: no cover - exotic filesystems
                    pass
        return key

    def counts(self) -> dict[str, int]:
        """Snapshot of the verified multiset (identity -> occurrences)."""
        with self._lock:
            return dict(self._counts)

    @property
    def items_recorded(self) -> int:
        with self._lock:
            return sum(self._counts.values())

    @property
    def bytes_recorded(self) -> int:
        with self._lock:
            return sum(self._bytes[k] * n for k, n in self._counts.items())

    def close(self) -> None:
        with self._lock:
            if self._fh is not None:
                self._fh.close()
                self._fh = None

    def __enter__(self) -> "TransferLedger":
        return self

    def __exit__(self, *exc: Any) -> None:
        self.close()

    # -- the resume seam (consumed by UnifiedDataMover._run) ------------------

    def skip_verified(self, source: Iterable[Any],
                      digest: Optional[StreamDigest] = None
                      ) -> Iterator[Any]:
        """Wrap a source: ledger-verified items are claimed (in memory,
        against a snapshot — the durable file never rewrites) and
        skipped, their recorded digests folded into ``digest``; only
        unverified items yield through to be staged."""
        pending = self.counts()
        self.skipped_items = 0
        self.skipped_bytes = 0

        def gen() -> Iterator[Any]:
            for item in source:
                key = self.item_key(item)
                if pending.get(key, 0) > 0:
                    pending[key] -= 1
                    if digest is not None:
                        digest.absorb_digest(key)
                    self.skipped_items += 1
                    self.skipped_bytes += len(as_bytes(item))
                    continue
                yield item

        return gen()

    def recording_sink(self, sink: Callable[[Any], None]
                       ) -> Callable[[Any], None]:
        """Wrap a sink: each successful delivery records durably, so a
        kill between deliveries loses at most the in-flight items."""

        def wrapped(item: Any) -> None:
            sink(item)
            self.record(item)

        return wrapped
