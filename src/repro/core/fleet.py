"""Fleet-scale basin arbitration — N concurrent transfers, one basin.

The paper's Drainage Basin Pattern says sustainable throughput is a
property of the *shared* end-to-end system, not of any one flow — yet
:func:`~repro.core.planner.plan_transfer` prices every transfer as if it
owned the basin.  K concurrent transfers (checkpoint saves, input
shards, decode streams) each promised the line rate collectively
over-promise the same host/NIC/storage tiers, and all K miss their
fidelity gates — not because anything degraded, but because the model
could not even *express* two transfers sharing a tier.

:class:`FleetArbiter` is the registry that can.  It owns one
:class:`~repro.core.basin.DrainageBasin` and allocates tier rates across
all live transfers under cross-*plan* rate conservation — the same
fixed-point discipline :meth:`~repro.core.basin.DrainageBasin.branch_rates`
applies across the branches of ONE plan, lifted across plans:

* **weighted QoS classes** — each member belongs to a class with a
  weight; on every oversubscribed tier/link the residual (above the
  admitted floors) is water-filled proportionally to weight, capped at
  each member's own path capability.
* **admission control** — a transfer whose ``min_bytes_per_s`` ask
  cannot fit the current fleet is queued (promoted highest-weight-first
  as peers release) or rejected outright; the live fleet's grants are
  never disturbed by a failed admission.
* **load shedding** — when even the admitted floors oversubscribe an
  element (a tier lost bandwidth under the fleet's feet), floors are
  honored in descending class weight: the lowest class's floor is cut
  first and the member is marked *shed*.
* **live rebalancing** — every membership change re-derives each live
  member's :class:`~repro.core.planner.TransferPlan` under its new
  grant (``rate_cap_bytes_per_s``) and pushes the
  :func:`~repro.core.planner.plan_delta` to the running transfer through
  its bound applier.  The zero-drain ``Stage.resize``/window-revision
  path (PRs 4-7) makes each rebalance free of teardown bubbles: windows
  and pools re-size in place, mid-stream.

The enforcement mechanism is the window: a capped plan's windowed hops
carry ``grant x RTT`` of credit instead of the link's full BDP, so K
members on one work-conserving channel each self-pace to exactly their
grant — conservation holds on the wire, not just in the ledger.

Usage (see ``examples/fleet_transfer.py`` for the full walkthrough)::

    arb = FleetArbiter(basin, telemetry=registry)
    adm = arb.admit("ckpt", item_bytes, qos="interactive",
                    stages=("move",))
    if adm.status == "admitted":
        mover.bulk_transfer(src, sink, fleet=adm)   # auto-releases

"HTCondor data movement at 100 Gbps" (PAPERS.md) is the production
shape: aggregate line rate assembled from many coordinated streams,
none of which owns the link.
"""

from __future__ import annotations

import dataclasses
import threading
import time
from typing import Callable, Mapping, Optional, Sequence

from .basin import DrainageBasin
from .planner import TransferPlan, plan_delta, plan_transfer

#: default QoS classes (name -> weight).  Residual bandwidth on every
#: oversubscribed element is shared proportionally to weight; floors are
#: honored — and shed — in descending weight order.
DEFAULT_CLASSES: Mapping[str, float] = {
    "interactive": 8.0,
    "priority": 4.0,
    "bulk": 2.0,
    "scavenger": 1.0,
}

#: relative tolerance for rate comparisons (grants, floors, conservation)
_REL_TOL = 1e-9

#: bandwidth a dead basin element is derated to (bytes/s): effectively
#: zero, but nonzero so every rate fixed point stays finite — members
#: crossing the corpse re-price to ~nothing and survivors absorb the
#: share on the next allocation instead of waiting on a hung grant
DEAD_ELEMENT_BYTES_PER_S = 1.0

#: observed throughput across a derated element above which a
#: post-derate probe reads as recovery (bytes/s) — far enough above the
#: 1 B/s obituary that retry trickle can never fake a resurrection
RECOVERY_PROBE_BYTES_PER_S = 1e3


@dataclasses.dataclass
class _Member:
    """One live (or queued) transfer's arbitration state."""

    name: str
    qos: str
    weight: float
    seq: int                            # admission order (FIFO tiebreak)
    item_bytes: float
    min_bytes_per_s: float
    path: Optional[tuple]               # pinned root->sink path, or None
    plan_kwargs: dict
    sub: DrainageBasin                  # the basin the member's plan sees
    crosses_tiers: frozenset[str]
    crosses_links: frozenset[tuple[str, str]]
    demand: float                       # the path's own raw capability
    granted: float = 0.0
    shed: bool = False
    plan: Optional[TransferPlan] = None
    on_revision: Optional[Callable[[TransferPlan, object], None]] = None
    apply_fn: Optional[Callable[[TransferPlan, object], None]] = None
    #: step function of the grant over time: [(t, bytes/s), ...] — the
    #: basis of the time-averaged promise a finished transfer is judged
    #: against (the grant moved mid-stream; the fidelity gate must too)
    grant_log: list = dataclasses.field(default_factory=list)


class Admission:
    """Handle returned by :meth:`FleetArbiter.admit`.

    ``status`` is ``"admitted"`` (a plan is live under a grant),
    ``"queued"`` (the min-rate ask does not fit yet; the handle mutates
    to ``"admitted"`` when a release makes room), or ``"rejected"``
    (``queue=False``, or the ask exceeds the path's own capability).
    The mover accepts the handle via ``fleet=`` — it binds a zero-drain
    applier for mid-stream rebalances and releases the grant on
    completion."""

    def __init__(self, arbiter: "FleetArbiter", member: _Member,
                 status: str, reason: str = "") -> None:
        self._arbiter = arbiter
        self._member = member
        self.status = status
        self.reason = reason

    @property
    def name(self) -> str:
        return self._member.name

    @property
    def qos(self) -> str:
        return self._member.qos

    @property
    def plan(self) -> Optional[TransferPlan]:
        """The member's current plan under its grant (None until admitted)."""
        return self._member.plan

    @property
    def granted_bytes_per_s(self) -> float:
        return self._member.granted

    @property
    def shed(self) -> bool:
        return self._member.shed

    def bind(self, apply_fn: Callable[[TransferPlan, object], None]) -> None:
        """Register the live applier rebalances are pushed through; it is
        invoked once immediately so a revision that landed between plan
        pickup and bind is never lost."""
        self._arbiter._bind(self._member, apply_fn)

    def unbind(self) -> None:
        self._arbiter._bind(self._member, None)

    def release(self) -> None:
        """Free the grant; survivors absorb the share, the queue promotes."""
        self._arbiter.release(self.name)

    def mean_granted(self, t0: float, t1: float) -> float:
        """Time-averaged grant over ``[t0, t1]`` — the honest promise for
        a transfer whose share moved while it ran."""
        return self._arbiter._mean_granted(self._member, t0, t1)

    def element_died(self, tier_name: str) -> None:
        """Failover hook: the mover reports that a branch of this
        member's transfer died for good on ``tier_name`` (retry budget
        exhausted).  Delegates to :meth:`FleetArbiter.element_died` —
        the tier derates and the whole fleet re-levels, so the member's
        grant re-prices to its surviving branches instead of hanging on
        a promise the corpse can no longer keep."""
        self._arbiter.element_died(tier_name)

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return (f"Admission({self._member.name!r}, {self.status}, "
                f"granted={self._member.granted / 1e6:.1f} MB/s)")


class FleetArbiter:
    """Cross-plan rate conservation over one shared basin.

    ``classes`` maps QoS class name -> weight (default
    :data:`DEFAULT_CLASSES`); ``clock`` stamps the grant history (pass
    the simbasin virtual clock in tests so time-averaged promises are
    deterministic); ``telemetry`` receives a fleet stats row
    (:meth:`stats`) on every rebalance via
    :meth:`~repro.core.telemetry.TelemetryRegistry.record_fleet`."""

    def __init__(self, basin: DrainageBasin, *,
                 classes: Optional[Mapping[str, float]] = None,
                 clock: Optional[Callable[[], float]] = None,
                 telemetry=None) -> None:
        self.basin = basin
        self.classes = dict(DEFAULT_CLASSES if classes is None else classes)
        for qos, w in self.classes.items():
            if w <= 0:
                raise ValueError(f"class {qos!r} weight must be > 0, got {w}")
        self._clock = clock if clock is not None else time.monotonic
        self.telemetry = telemetry
        self._lock = threading.RLock()
        self._members: dict[str, _Member] = {}
        self._queue: list[tuple[_Member, Admission]] = []
        self._seq = 0
        #: pre-derate bandwidth estimates of dead elements, keyed by tier
        #: name — what :meth:`element_recovered` restores
        self._derated: dict[str, float] = {}

    # -- membership --------------------------------------------------------

    def admit(self, name: str, item_bytes: float, *,
              qos: str = "bulk", min_bytes_per_s: float = 0.0,
              queue: bool = True, path: Optional[Sequence[str]] = None,
              on_revision: Optional[Callable] = None,
              **plan_kwargs) -> Admission:
        """Ask the fleet for a share of the basin.

        ``path`` restricts the member to one root->sink tier path of a
        branching basin (default: the whole basin — on a linear basin the
        only path, on a DAG a multipath plan charged conservatively
        against every element it might cross).  ``min_bytes_per_s`` is
        the admission floor: a fleet that cannot grant it queues the ask
        (``queue=True``, promoted highest-weight-first on release) or
        rejects it — the live fleet's grants are untouched either way.
        Remaining keyword arguments (``stages``, ``checksum``,
        ``ordered``, ``batch_items``, ...) pass through to
        :func:`~repro.core.planner.plan_transfer` on every grant.

        ``path`` is overloaded the way the planner reads it: a
        *sequence of tier names* restricts the member's route (above),
        while a plain *string* (``"auto"`` or a forced execution shape)
        is the planner's path policy and passes through to
        ``plan_transfer`` — a granted member planning ``path="auto"``
        re-prices its shape candidates against every re-grant, so the
        stream-vs-stage choice tracks the member's share of the basin,
        not the raw line."""
        if isinstance(path, str):
            # execution-shape policy, not a tier route: the planner's
            # path argument (validated there), re-priced on every grant
            plan_kwargs["path"] = path
            path = None
        if item_bytes <= 0:
            raise ValueError("item_bytes must be > 0")
        if min_bytes_per_s < 0:
            raise ValueError("min_bytes_per_s must be >= 0")
        if qos not in self.classes:
            raise ValueError(
                f"unknown QoS class {qos!r}; have {sorted(self.classes)}")
        with self._lock:
            if name in self._members or any(
                    m.name == name for m, _ in self._queue):
                raise ValueError(f"fleet member {name!r} already exists")
            member = self._make_member(name, item_bytes, qos,
                                       min_bytes_per_s, path, on_revision,
                                       plan_kwargs)
            if min_bytes_per_s > member.demand * (1.0 + _REL_TOL):
                return Admission(
                    self, member, "rejected",
                    reason=(f"min {min_bytes_per_s / 1e6:.1f} MB/s exceeds "
                            f"the path's own capability "
                            f"{member.demand / 1e6:.1f} MB/s"))
            trial = self._allocate(list(self._members.values()) + [member],
                                   no_floor=frozenset((name,)))
            if trial[name] < min_bytes_per_s * (1.0 - _REL_TOL):
                reason = (f"granting min {min_bytes_per_s / 1e6:.1f} MB/s "
                          f"would break conservation (fit: "
                          f"{trial[name] / 1e6:.1f} MB/s)")
                if queue:
                    adm = Admission(self, member, "queued", reason=reason)
                    self._queue.append((member, adm))
                    return adm
                return Admission(self, member, "rejected", reason=reason)
            self._members[name] = member
            adm = Admission(self, member, "admitted")
            self._apply_grants(trial)
            self._publish()
            return adm

    def release(self, name: str) -> None:
        """Remove a member; survivors absorb its share (never losing any
        of their own — allocation is release-monotone) and queued asks
        are promoted in descending class weight."""
        with self._lock:
            member = self._members.pop(name, None)
            if member is None:
                # releasing a queued/rejected ask just withdraws it
                self._queue = [(m, a) for m, a in self._queue
                               if m.name != name]
                return
            member.grant_log.append((self._clock(), 0.0))
            member.granted = 0.0
            self._apply_grants(self._allocate(list(self._members.values())))
            self._promote_queue()
            self._publish()

    def rebalance(self, basin: Optional[DrainageBasin] = None) -> None:
        """Re-run allocation across the live fleet — with ``basin``
        given, against a REVISED basin (same tier topology, new
        capacity/latency estimates: a tier lost bandwidth under the
        fleet's feet, typically surfaced by a member's replan verdict).

        This is where **load shedding** becomes reachable: admission
        control guarantees the admitted floors fit the basin they were
        admitted against, so on a static basin no floor is ever cut —
        but a capacity loss can leave the floors oversubscribed, and
        then the lowest class's floor is the one cut first (the member
        stays live at its reduced share, marked ``shed``)."""
        with self._lock:
            if basin is not None:
                if ({t.name for t in basin.tiers}
                        != {t.name for t in self.basin.tiers}):
                    raise ValueError(
                        "revised basin must keep the tier topology")
                self.basin = basin
                for m in self._members.values():
                    self._rederive(m)
                for m, _adm in self._queue:
                    self._rederive(m)
            self._apply_grants(
                self._allocate(list(self._members.values())),
                force=basin is not None)
            self._promote_queue()
            self._publish()

    def element_died(self, tier_name: str) -> None:
        """A basin element died under the fleet's feet (a live member's
        branch exhausted its retry budget against it).  The tier is
        derated to :data:`DEAD_ELEMENT_BYTES_PER_S` — same topology, so
        every member's sub-basin re-derives cleanly — and the fleet
        re-levels: survivors absorb the share, members whose floor no
        longer fits are shed in class order.  Unknown tiers no-op (the
        corpse may be a branch-private tier outside this basin)."""
        with self._lock:
            if all(t.name != tier_name for t in self.basin.tiers):
                return
            already = {t.name: t.bandwidth_bytes_per_s
                       for t in self.basin.tiers}
            if already[tier_name] <= DEAD_ELEMENT_BYTES_PER_S:
                return          # idempotent: the obituary already landed
            # keep the pre-derate estimate so a returned element can be
            # re-admitted at its known capability, not a guess
            self._derated[tier_name] = already[tier_name]
            tiers = [dataclasses.replace(
                         t, bandwidth_bytes_per_s=DEAD_ELEMENT_BYTES_PER_S)
                     if t.name == tier_name else t
                     for t in self.basin.tiers]
            self.rebalance(basin=self.basin.replace_tiers(tiers))

    def element_recovered(self, tier_name: str,
                          bandwidth_bytes_per_s: Optional[float] = None
                          ) -> None:
        """A derated element returned to service: restore its pre-derate
        bandwidth estimate (or an explicit revised one) and re-level the
        fleet — survivors give back the absorbed share, shed floors
        re-fit, and queued asks are promoted against the recovered
        capacity.  The exact inverse of :meth:`element_died`; no-ops for
        tiers that are not currently derated."""
        with self._lock:
            stored = self._derated.pop(tier_name, None)
            bw = bandwidth_bytes_per_s if bandwidth_bytes_per_s else stored
            if bw is None or bw <= DEAD_ELEMENT_BYTES_PER_S:
                return
            by_name = {t.name: t for t in self.basin.tiers}
            tier = by_name.get(tier_name)
            if tier is None or tier.bandwidth_bytes_per_s > \
                    DEAD_ELEMENT_BYTES_PER_S:
                return          # unknown, or never actually derated
            tiers = [dataclasses.replace(t, bandwidth_bytes_per_s=bw)
                     if t.name == tier_name else t
                     for t in self.basin.tiers]
            self.rebalance(basin=self.basin.replace_tiers(tiers))

    def probe_element(self, tier_name: str,
                      observed_bytes_per_s: float) -> bool:
        """Recovery *detection*: a member that kept (or resumed) pushing
        traffic across a derated tier reports what it actually observed
        through it.  A clean post-derate probe — observed throughput far
        above the 1 B/s obituary — is the evidence the element returned;
        the arbiter re-admits it at the stored pre-derate estimate
        (clamped to the observation when the element came back weaker)
        and re-levels.  Returns True when the probe triggered
        re-admission."""
        with self._lock:
            by_name = {t.name: t for t in self.basin.tiers}
            tier = by_name.get(tier_name)
            if tier is None or tier.bandwidth_bytes_per_s > \
                    DEAD_ELEMENT_BYTES_PER_S:
                return False
            if observed_bytes_per_s <= RECOVERY_PROBE_BYTES_PER_S:
                return False    # still (near-)dead: obituary stands
            stored = self._derated.get(tier_name)
            bw = observed_bytes_per_s if stored is None \
                else min(stored, observed_bytes_per_s)
            self.element_recovered(tier_name, bw)
            return True

    def _make_member(self, name, item_bytes, qos, min_bytes_per_s, path,
                     on_revision, plan_kwargs) -> _Member:
        seq = self._seq
        self._seq += 1
        if path is not None:
            path = tuple(path)
            if path not in self.basin.paths():
                raise ValueError(f"{path!r} is not a root->sink path "
                                 f"of the basin")
        member = _Member(name=name, qos=qos, weight=self.classes[qos],
                         seq=seq, item_bytes=float(item_bytes),
                         min_bytes_per_s=float(min_bytes_per_s),
                         path=path, plan_kwargs=dict(plan_kwargs),
                         sub=self.basin, crosses_tiers=frozenset(),
                         crosses_links=frozenset(), demand=0.0,
                         on_revision=on_revision)
        self._rederive(member)
        return member

    def _rederive(self, m: _Member) -> None:
        """(Re)compute a member's sub-basin, crossing sets and raw
        demand against the arbiter's CURRENT basin."""
        if m.path is not None:
            m.sub = self.basin.path_basin(m.path)
            m.crosses_tiers = frozenset(m.path)
            m.crosses_links = frozenset(zip(m.path, m.path[1:]))
            m.demand = min(
                min(t.bandwidth_bytes_per_s for t in m.sub.tiers),
                min(l.bandwidth_bytes_per_s for l in m.sub.links))
        else:
            m.sub = self.basin
            # a whole-basin member is charged conservatively against
            # every element it may cross — exact per-branch accounting
            # belongs to branch_rates inside its own plan
            m.crosses_tiers = frozenset(t.name for t in self.basin.tiers)
            m.crosses_links = frozenset((l.src, l.dst)
                                        for l in self.basin.links)
            m.demand = self.basin.achievable_throughput()

    # -- allocation --------------------------------------------------------

    def _elements(self, members: Sequence[_Member]
                  ) -> list[tuple[float, list[_Member]]]:
        """(capacity, crossing members) per basin element — the
        conservation constraints, mirroring branch_rates' shared-element
        collection across branches."""
        els: list[tuple[float, list[_Member]]] = []
        for t in self.basin.tiers:
            ms = [m for m in members if t.name in m.crosses_tiers]
            if ms:
                els.append((t.bandwidth_bytes_per_s, ms))
        for l in self.basin.links:
            ms = [m for m in members if (l.src, l.dst) in m.crosses_links]
            if ms:
                els.append((l.bandwidth_bytes_per_s, ms))
        return els

    def _allocate(self, members: Sequence[_Member],
                  no_floor: frozenset[str] = frozenset()
                  ) -> dict[str, float]:
        """Fixed point of per-element weighted water-filling.

        Seed every member at its own demand, then repeatedly re-fill each
        oversubscribed element: admitted floors first (descending class
        weight — shedding order), the residual proportional to weight,
        capped at each member's running rate.  Rates only ever decrease,
        so the iteration converges — and removing a member can only
        weaken constraints, which is what makes release monotone.

        ``no_floor`` names members whose floor is NOT honored — the
        admission trial runs the candidate floorless, so its min-rate ask
        must fit its *fair share* rather than being self-fulfilling
        (a floor only binds once admission has validated it)."""
        rates = {m.name: m.demand for m in members}
        if not members:
            return rates
        floors = {m.name: (0.0 if m.name in no_floor
                           else min(m.min_bytes_per_s, m.demand))
                  for m in members}
        els = self._elements(members)
        for _ in range(max(1, 4 * len(members) * max(1, len(els)))):
            changed = False
            for cap, ms in els:
                load = sum(rates[m.name] for m in ms)
                if load <= cap * (1.0 + 1e-12):
                    continue
                alloc = self._fill(cap, ms, rates, floors)
                for m in ms:
                    if alloc[m.name] < rates[m.name] * (1.0 - _REL_TOL):
                        rates[m.name] = alloc[m.name]
                        changed = True
            if not changed:
                break
        return rates

    @staticmethod
    def _fill(cap: float, ms: Sequence[_Member], rates: Mapping[str, float],
              floors: Mapping[str, float]) -> dict[str, float]:
        """One element's weighted water-fill under floors and rate caps:
        every member gets ``clamp(level * weight, floor, rate)`` at the
        common water level that exactly spends the capacity.

        Floors are *reserved* in descending class weight first, so when
        the floors alone oversubscribe the element the lowest class's
        floor is the one cut (load shedding — detected afterwards as
        granted < min).  A floor below the member's fair share never
        inflates it: the clamp only binds from below when the share
        would dip under the floor."""
        order = sorted(ms, key=lambda m: (-m.weight, m.seq))
        left = cap
        floor_grant: dict[str, float] = {}
        for m in order:
            f = min(floors[m.name], rates[m.name], max(0.0, left))
            floor_grant[m.name] = f
            left -= f
        # water level by iterated pinning: members whose weighted share
        # violates a bound are pinned at it and the level recomputes over
        # the rest — terminates, each pass pins at least one member
        pinned: dict[str, float] = {}
        alloc: dict[str, float] = {}
        while True:
            free = [m for m in order if m.name not in pinned]
            if not free:
                break
            budget = cap - sum(pinned.values())
            total_w = sum(m.weight for m in free)
            level = max(0.0, budget) / total_w
            moved = False
            for m in free:
                share = level * m.weight
                if share < floor_grant[m.name] * (1.0 - _REL_TOL):
                    pinned[m.name] = floor_grant[m.name]
                    moved = True
                elif share > rates[m.name] * (1.0 + _REL_TOL):
                    pinned[m.name] = rates[m.name]
                    moved = True
            if not moved:
                for m in free:
                    alloc[m.name] = level * m.weight
                break
        alloc.update(pinned)
        return alloc

    def _apply_grants(self, rates: Mapping[str, float],
                      force: bool = False) -> None:
        """Re-derive and push every member's plan under its new grant
        (``force``: rebuild even at an unchanged grant — the sub-basin
        the plan prices moved under it)."""
        now = self._clock()
        for m in self._members.values():
            granted = rates.get(m.name, 0.0)
            m.shed = (m.min_bytes_per_s > 0
                      and granted < m.min_bytes_per_s * (1.0 - 1e-6))
            if (not force and m.plan is not None
                    and abs(granted - m.granted)
                    <= _REL_TOL * max(1.0, m.granted)):
                continue
            old = m.plan
            new = plan_transfer(m.sub, m.item_bytes,
                                rate_cap_bytes_per_s=max(granted, 1e-9),
                                **m.plan_kwargs)
            m.plan = new
            m.granted = granted
            m.grant_log.append((now, granted))
            delta = plan_delta(old, new) if old is not None else None
            if old is not None:
                if m.apply_fn is not None:
                    m.apply_fn(new, delta)
                if m.on_revision is not None:
                    m.on_revision(new, delta)

    def _promote_queue(self) -> None:
        """Admit queued asks that now fit, highest class weight first."""
        self._queue.sort(key=lambda ma: (-ma[0].weight, ma[0].seq))
        promoted = True
        while promoted:
            promoted = False
            for i, (m, adm) in enumerate(self._queue):
                trial = self._allocate(
                    list(self._members.values()) + [m],
                    no_floor=frozenset((m.name,)))
                if trial[m.name] >= m.min_bytes_per_s * (1.0 - _REL_TOL):
                    del self._queue[i]
                    self._members[m.name] = m
                    adm.status = "admitted"
                    adm.reason = ""
                    self._apply_grants(trial)
                    promoted = True
                    break

    # -- live binding ------------------------------------------------------

    def _bind(self, member: _Member, apply_fn: Optional[Callable]) -> None:
        with self._lock:
            member.apply_fn = apply_fn
            if apply_fn is not None and member.plan is not None:
                # sync call: a rebalance that landed between the mover's
                # plan pickup and this bind must not be lost — the mover's
                # applier diffs against what it actually built, so a
                # no-op sync is harmless
                apply_fn(member.plan, None)

    def _mean_granted(self, member: _Member, t0: float, t1: float) -> float:
        with self._lock:
            if t1 <= t0:
                return member.granted
            log = member.grant_log
            total = 0.0
            for i, (t, rate) in enumerate(log):
                t_next = log[i + 1][0] if i + 1 < len(log) else max(t1, t)
                a, b = max(t, t0), min(t_next, t1)
                if b > a:
                    total += rate * (b - a)
            return total / (t1 - t0)

    # -- observability -----------------------------------------------------

    def grants(self) -> dict[str, float]:
        """name -> granted bytes/s for every live member."""
        with self._lock:
            return {m.name: m.granted for m in self._members.values()}

    def weighted_fairness(self) -> float:
        """Jain's fairness index over weight-normalized grants
        (``granted / weight``): 1.0 = every class holds exactly its
        weighted share, 1/n = one member holds everything."""
        with self._lock:
            xs = [m.granted / m.weight for m in self._members.values()]
        xs = [x for x in xs if x > 0]
        if not xs:
            return 1.0
        return sum(xs) ** 2 / (len(xs) * sum(x * x for x in xs))

    def stats(self) -> dict:
        """The fleet row telemetry records on every rebalance."""
        with self._lock:
            classes: dict[str, dict] = {}
            for m in self._members.values():
                row = classes.setdefault(
                    m.qos, {"weight": m.weight, "members": 0,
                            "granted_bytes_per_s": 0.0})
                row["members"] += 1
                row["granted_bytes_per_s"] += m.granted
            return {
                "live": len(self._members),
                "queued": len(self._queue),
                "shed": sorted(m.name for m in self._members.values()
                               if m.shed),
                "aggregate_granted_bytes_per_s":
                    sum(m.granted for m in self._members.values()),
                "fairness_index": self.weighted_fairness(),
                "classes": classes,
            }

    def describe(self) -> str:
        """Operator surface: one line per member plus the fleet totals —
        the fleet-level analogue of ``TransferPlan.describe()``."""
        with self._lock:
            s = self.stats()
            lines = [f"FleetArbiter({s['live']} live, {s['queued']} queued, "
                     f"aggregate={s['aggregate_granted_bytes_per_s'] / 1e6:.1f}"
                     f" MB/s, fairness={s['fairness_index']:.3f}"]
            for m in sorted(self._members.values(),
                            key=lambda m: (-m.weight, m.seq)):
                shed = "  SHED" if m.shed else ""
                floor = (f" min={m.min_bytes_per_s / 1e6:.1f} MB/s"
                         if m.min_bytes_per_s > 0 else "")
                lines.append(f"  {m.name} [{m.qos} w={m.weight:g}] "
                             f"granted={m.granted / 1e6:.1f} MB/s"
                             f"{floor}{shed}")
            for m, _adm in self._queue:
                lines.append(f"  {m.name} [{m.qos} w={m.weight:g}] QUEUED "
                             f"min={m.min_bytes_per_s / 1e6:.1f} MB/s")
            return "\n".join(lines) + ")"

    def _publish(self) -> None:
        if self.telemetry is not None:
            self.telemetry.record_fleet(self.stats())
