"""TransferPlan engine — the basin model turned into staging parameters.

The paper's planning discipline (§2.3 "global tuning", §3.4 weakest-link
analysis) is that predictable line-rate movement comes from matching
buffer depth, concurrency, and integrity budget to *every* tier of the
path — not from per-workload hand tuning.  :mod:`repro.core.basin` is the
analytic model; this module is the bridge that turns a
:class:`~repro.core.basin.DrainageBasin` plus an item-size estimate into
the concrete knobs every data-moving layer needs:

* **capacity** — burst-buffer slots per hop (Little's law over the
  jitter window, double-buffered),
* **workers** — concurrent staging workers per hop (concurrency as the
  latency antidote, §3.1: enough in-flight pulls that per-item latency
  and jitter amortize away and the hop sustains the path's line rate),
* **checksum placement** — the integrity budget (§3.4) rides the hop
  with the most bandwidth headroom, so hashing overlaps transit instead
  of stretching the critical path.

Every consumer — the training-input pipeline, the checkpoint engine, the
decode token stream — builds its basin, asks :func:`plan_transfer` for a
:class:`TransferPlan`, and hands that plan to the
:class:`~repro.core.mover.UnifiedDataMover` / stage constructors.  No
layer carries hard-coded staging constants.

Adaptive re-planning (the paper's hypothesis -> change -> measure cycle,
made mechanical): observed :class:`~repro.core.staging.StageReport` stall
ratios feed back into the tier bandwidth estimates via :func:`replan`,
which returns a revised plan.  A hop that mostly *starved* (stall
upstream) reveals the upstream tier is slower than modeled; a hop that
mostly *backpressured* (stall downstream) reveals the downstream tier is.

Worked example
--------------

>>> from repro.core.basin import DrainageBasin, Tier, TierKind, GBPS
>>> basin = DrainageBasin([
...     Tier("src", TierKind.SOURCE, 10 * GBPS, latency_s=5e-3,
...          jitter_s=20e-3),                      # erratic headwaters
...     Tier("buf", TierKind.BURST_BUFFER, 100 * GBPS, latency_s=10e-6),
...     Tier("dst", TierKind.SINK, 40 * GBPS, latency_s=1e-3),
... ])
>>> plan = plan_transfer(basin, item_bytes=4 * 1024 ** 2,
...                      stages=["decode", "stage"], checksum=True)
>>> [h.workers for h in plan.hops]      # erratic source hop needs concurrency
[8, 1]
>>> [h.capacity for h in plan.hops]     # deep buffer absorbs the jitter
[12, 2]
>>> plan.checksum_index                 # hashing rides the slack hop
1
>>> plan.planned_bytes_per_s <= basin.achievable_throughput()
True

After running the transfer, feed the observed stage reports back:

>>> revised = replan(plan, stage_reports)           # doctest: +SKIP
>>> revised.hops[0].workers                         # doctest: +SKIP
8

and use ``revised`` for the next transfer — measure, adjust, repeat.

Regime diagnosis (latency-bound vs bandwidth-bound)
---------------------------------------------------

A stall ratio alone cannot say *why* a hop waited — and the two causes
demand opposite remedies (the paper's "raw bandwidth = capability"
fallacy, and the regime separation of arXiv:2308.10312).  The per-item
service-time reservoirs in :class:`~repro.core.staging.StageReport`
(``service_up_s`` / ``service_down_s``) disambiguate:

* **latency-bound** — service times are widely dispersed (stochastic
  per-item latency + jitter dominates).  Remedy: revise the tier's
  ``latency_s``/``jitter_s`` estimates upward so the next plan raises
  ``workers`` (concurrency amortizes latency, §3.1) and deepens the
  buffer.  Bandwidth estimates are left alone.
* **bandwidth-bound** — service times are tight around a constant (the
  pipe is saturated; every item takes ~``item_bytes/true_bw``).  Remedy:
  pull the tier's ``bandwidth_gbps`` estimate toward the observed rate
  and accept the lower line rate.  More workers would not help.

Worked example: the same 70 % stall ratio on the source hop, opposite
service signatures::

    # high-variance samples (5 ms +- 4 ms) -> latency-bound
    >>> lat = replan(plan, [report_jittery])        # doctest: +SKIP
    >>> lat.hops[0].workers                         # doctest: +SKIP
    8                                               # was 2: workers UP
    >>> lat.describe()                              # doctest: +SKIP
    'TransferPlan(move[cap=24 w=8 src->dst]; planned=1250.0 MB/s,
     checksum@None; diag[move=latency-bound(src)])'

    # tight samples (21 ms +- 0.1 ms) -> saturated bandwidth
    >>> bw = replan(plan, [report_saturated])       # doctest: +SKIP
    >>> bw.basin.tiers[0].bandwidth_bytes_per_s     # doctest: +SKIP
    5.0e7                                           # was 1.25e9: rate DOWN
    >>> bw.describe()                               # doctest: +SKIP
    'TransferPlan(move[cap=4 w=1 src->dst]; planned=50.0 MB/s,
     checksum@None; diag[move=bandwidth-bound(src)])'

Without service samples (an empty reservoir) replan falls back to the
bandwidth remedy — the conservative pre-diagnosis behaviour.  A hop that
never stalled but still underdelivered against its planned rate (busy on
its own pull + transform service) is diagnosed from its samples too — the
busy-hop rule, exercised by ``benchmarks/online_replan.py``.

Online replanning: the mover's ``replan_every_items`` runs a transfer in
segments and feeds each segment's reports through :func:`replan` at the
buffer boundary, so a mid-transfer regime shift is answered mid-transfer
(see ``UnifiedDataMover.bulk_transfer``).
"""

from __future__ import annotations

import dataclasses
import math
from typing import Optional, Sequence

from .basin import DrainageBasin, Link, Tier
from .staging import StageReport

#: ceiling on per-hop concurrency (a planning guard, not a tuning knob:
#: past this the GIL/thread overhead of the host path dominates)
MAX_WORKERS = 8
#: ceiling on per-hop buffer slots (bounds host memory for tiny items)
MAX_CAPACITY = 64


@dataclasses.dataclass(frozen=True)
class HopPlan:
    """Staging parameters for one hop (one :class:`~repro.core.staging.Stage`)."""

    name: str
    capacity: int               # burst-buffer slots
    workers: int                # concurrent staging workers
    up_tier: str                # tier the hop pulls from
    down_tier: str              # tier the hop delivers toward
    rate_bytes_per_s: float     # what this hop can sustain as planned


@dataclasses.dataclass
class TransferPlan:
    """A fully derived data path: per-hop parameters plus the promise
    (``planned_bytes_per_s``) the fidelity gap is measured against."""

    hops: list[HopPlan]
    item_bytes: float
    planned_bytes_per_s: float
    checksum_index: Optional[int]       # hop index carrying the digest, or None
    basin: DrainageBasin
    ordered: bool
    #: hop name -> regime verdict (e.g. ``"latency-bound(src)"``) set by
    #: :func:`replan` on the revised plan; empty on a fresh derivation
    diagnosis: dict[str, str] = dataclasses.field(default_factory=dict)

    @property
    def stages(self) -> list[str]:
        return [h.name for h in self.hops]

    def hop_for(self, index: int, name: str | None = None) -> HopPlan:
        """Hop by stage name when it matches, else by position (extra
        stages beyond the planned hops inherit the last hop's params)."""
        if name is not None:
            for h in self.hops:
                if h.name == name:
                    return h
        return self.hops[min(index, len(self.hops) - 1)]

    @property
    def total_buffer_items(self) -> int:
        return sum(h.capacity for h in self.hops)

    def describe(self) -> str:
        hops = ", ".join(
            f"{h.name}[cap={h.capacity} w={h.workers} "
            f"{h.up_tier}->{h.down_tier}]" for h in self.hops)
        diag = ""
        if self.diagnosis:
            diag = "; diag[" + ", ".join(
                f"{name}={verdict}"
                for name, verdict in sorted(self.diagnosis.items())) + "]"
        return (f"TransferPlan({hops}; planned="
                f"{self.planned_bytes_per_s / 1e6:.1f} MB/s, "
                f"checksum@{self.checksum_index}{diag})")


def _segment(tiers: Sequence[Tier], n_stages: int, j: int
             ) -> tuple[int, int]:
    """Tier-index span [lo, hi] that stage ``j`` of ``n_stages`` covers.

    Stages partition the basin path evenly; each hop pulls from its
    segment's first tier and delivers toward its last."""
    T = len(tiers)
    lo = j * (T - 1) // n_stages
    hi = (j + 1) * (T - 1) // n_stages
    hi = max(hi, lo + 1)
    return lo, min(hi, T - 1)


def _segment_rtt(basin: DrainageBasin, lo: int, hi: int) -> float:
    names = {t.name for t in basin.tiers[lo:hi + 1]}
    rtts = [l.rtt_s for l in basin.links
            if l.src in names and l.dst in names]
    return max(rtts, default=0.0)


def _raw_line_rate(basin: DrainageBasin) -> float:
    """Line rate ignoring per-item latency: min raw bandwidth over every
    tier and link.  Concurrency (workers) is how a hop reaches it despite
    latency — the paper's §3.1 latency insensitivity."""
    rates = [t.bandwidth_bytes_per_s for t in basin.tiers]
    rates.extend(l.bandwidth_bytes_per_s for l in basin.links)
    return min(rates)


def _worker_rate(up: Tier, down: Tier, item_bytes: float) -> float:
    """Sustained rate of ONE staging worker doing pull -> transform ->
    push: upstream service time (with latency + jitter) plus downstream
    delivery, serialized within the worker."""
    t = (item_bytes / up.bandwidth_bytes_per_s + up.latency_s + up.jitter_s
         + item_bytes / down.bandwidth_bytes_per_s + down.latency_s)
    return item_bytes / t


def plan_transfer(
    basin: DrainageBasin,
    item_bytes: float,
    *,
    stages: Sequence[str] = ("stage",),
    checksum: bool = False,
    ordered: bool = False,
    max_workers: int = MAX_WORKERS,
    max_capacity: int = MAX_CAPACITY,
) -> TransferPlan:
    """Derive per-hop staging parameters from the basin model.

    ``stages`` names the hops the consumer will run (one
    :class:`~repro.core.staging.Stage` each); the basin path is split
    evenly across them.  ``ordered=True`` pins every hop to one worker —
    required when item order must survive the transfer (training batches,
    decode token streams); buffer depth still comes from the model, so
    jitter absorption is preserved.
    """
    if item_bytes <= 0:
        raise ValueError("item_bytes must be > 0")
    if not stages:
        raise ValueError("need at least one stage name")
    tiers = basin.tiers
    n = len(stages)
    target = _raw_line_rate(basin)

    hops: list[HopPlan] = []
    headroom: list[float] = []          # uncapped sustainable rate per hop
    for j, name in enumerate(stages):
        lo, hi = _segment(tiers, n, j)
        up, down = tiers[lo], tiers[hi]
        rate_1 = _worker_rate(up, down, item_bytes)
        if ordered:
            workers = 1
        else:
            workers = max(1, min(max_workers, math.ceil(target / rate_1)))
        # Little's law over the stochastic window, double-buffered
        window_s = up.jitter_s + down.jitter_s + _segment_rtt(basin, lo, hi)
        need_items = math.ceil(target * window_s / item_bytes)
        capacity = max(2, workers + 1, 2 * need_items)
        capacity = min(capacity, max_capacity)
        # the segment's burst capacity is a hard ceiling: never plan more
        # staged items than the smallest tier on the hop can actually hold
        cap_bytes = min(t.capacity_bytes for t in tiers[lo:hi + 1])
        if math.isfinite(cap_bytes):
            capacity = min(capacity, max(1, int(cap_bytes // item_bytes)))
            # a buffer shallower than the pool serializes the extra
            # workers; shrink the pool so the promised rate stays honest
            workers = min(workers, max(1, capacity - 1))
        headroom.append(workers * rate_1)
        hop_rate = min(workers * rate_1, target)
        hops.append(HopPlan(name=name, capacity=capacity, workers=workers,
                            up_tier=up.name, down_tier=down.name,
                            rate_bytes_per_s=hop_rate))

    planned = min(min(h.rate_bytes_per_s for h in hops),
                  basin.achievable_throughput())
    checksum_index = None
    if checksum:
        # integrity rides the hop with the most headroom over the plan
        checksum_index = max(range(len(hops)), key=lambda i: headroom[i])
    return TransferPlan(hops=hops, item_bytes=float(item_bytes),
                        planned_bytes_per_s=planned,
                        checksum_index=checksum_index, basin=basin,
                        ordered=ordered)


# ---------------------------------------------------------------------------
# Adaptive re-planning: hypothesis -> change -> measure, made mechanical
# ---------------------------------------------------------------------------

#: a hop is considered stalled when this fraction of its worker-time was
#: spent waiting (below it, the measurement is noise)
STALL_THRESHOLD = 0.1

#: minimum service-time samples before a regime diagnosis is attempted
#: (fewer and the dispersion statistic is noise)
MIN_DIAGNOSIS_SAMPLES = 8

#: service-sample dispersion — (p90 - p10) / median — above which a
#: stalled side reads as latency/jitter-bound; at or below it the side is
#: a steadily saturated pipe (bandwidth-bound).  A stochastic per-item
#: latency spreads the samples; a saturated pipe serves every item in
#: ~item_bytes/true_bw with near-zero spread.
LATENCY_DISPERSION = 0.75


def _percentiles(sorted_samples: Sequence[float]
                 ) -> tuple[float, float, float]:
    """(p10, median, p90) of an already-sorted sample list."""
    n = len(sorted_samples)
    return (sorted_samples[int(0.1 * (n - 1))],
            sorted_samples[n // 2],
            sorted_samples[int(0.9 * (n - 1))])


def diagnose_service(samples: Sequence[float]) -> Optional[str]:
    """Classify a stalled side's regime from its per-item service times.

    Returns ``"latency"`` (high-dispersion samples: stochastic per-item
    latency dominates — more concurrency is the remedy), ``"bandwidth"``
    (tight samples: the pipe is steadily saturated — accept the lower
    rate), or ``None`` when there are too few samples to say.
    """
    if len(samples) < MIN_DIAGNOSIS_SAMPLES:
        return None
    s = sorted(samples)
    p10, med, p90 = _percentiles(s)
    if med <= 0:
        return None
    return "latency" if (p90 - p10) / med > LATENCY_DISPERSION else "bandwidth"


def replan(plan: TransferPlan, reports: Sequence[StageReport], *,
           damping: float = 0.5) -> TransferPlan:
    """Revise a plan from observed stall ratios and service-time samples.

    For each hop, the stall accounting of its :class:`StageReport` says
    which side actually limited it (``stall_up_s`` dominant: the upstream
    tier; ``stall_down_s`` dominant: the downstream tier).  The limiting
    side's per-item service-time reservoir then says *why* — and the two
    regimes get opposite remedies:

    * **latency-bound** (dispersed samples): revise the tier's
      ``latency_s``/``jitter_s`` estimates from the sample distribution;
      the rebuilt plan raises ``workers`` / deepens the buffer while the
      bandwidth estimate (and so the planned line rate) stands,
    * **bandwidth-bound** (tight samples) — or no samples at all: pull
      the tier's bandwidth estimate toward the hop's observed throughput
      and accept the reduced line rate.

    ``damping`` blends old estimate and observation (1.0 = trust the
    measurement outright).  Returns a new :class:`TransferPlan` built on
    the re-estimated basin, its per-hop verdicts in
    :attr:`TransferPlan.diagnosis` (surfaced by ``describe()``); the
    original plan is untouched.
    """
    if not 0.0 < damping <= 1.0:
        raise ValueError("damping must be in (0, 1]")
    est = {t.name: t.bandwidth_bytes_per_s for t in plan.basin.tiers}
    lat_est = {t.name: t.latency_s for t in plan.basin.tiers}
    jit_est = {t.name: t.jitter_s for t in plan.basin.tiers}
    # carry the most recent verdict per hop forward: a chain of online
    # replans keeps showing what was learned even after the remedy quiets
    # the stall (describe() is the operator surface)
    diagnosis: dict[str, str] = dict(plan.diagnosis)
    by_name = {r.name: r for r in reports}
    for hop in plan.hops:
        rep = by_name.get(hop.name)
        if rep is None or rep.elapsed_s <= 0:
            continue
        observed = rep.throughput_bytes_per_s
        if observed <= 0:
            continue
        worker_time = rep.elapsed_s * hop.workers
        r_up = rep.stall_up_s / worker_time
        r_down = rep.stall_down_s / worker_time
        if max(r_up, r_down) >= STALL_THRESHOLD:
            # the side we mostly waited on is the side that limited us
            up_limited = r_up >= r_down
        elif (len(rep.service_up_s) >= MIN_DIAGNOSIS_SAMPLES
              and observed < hop.rate_bytes_per_s * (1.0 - STALL_THRESHOLD)):
            # the busy-hop case: no waiting on either side, yet the hop
            # underdelivered against its own planned rate — its per-item
            # acquisition service (pull + transform, the modeled upstream
            # tier) is slower than planned; the samples say which regime
            up_limited = True
        else:
            continue
        tier_name = hop.up_tier if up_limited else hop.down_tier
        samples = rep.service_up_s if up_limited else rep.service_down_s
        regime = diagnose_service(samples)
        if regime == "latency":
            # the pipe is fine; per-item setup cost is what we waited on.
            # median service over the modeled transmit time is the latency
            # estimate, the p10-p90 spread the jitter window.
            s = sorted(samples)
            p10, med, p90 = _percentiles(s)
            transmit = plan.item_bytes / est[tier_name]
            lat_est[tier_name] = ((1.0 - damping) * lat_est[tier_name]
                                  + damping * max(0.0, med - transmit))
            jit_est[tier_name] = ((1.0 - damping) * jit_est[tier_name]
                                  + damping * max(0.0, p90 - p10))
            diagnosis[hop.name] = f"latency-bound({tier_name})"
        else:
            # saturated (or undiagnosable): the limiting side's *effective*
            # delivery rate was the hop's observed throughput
            est[tier_name] = ((1.0 - damping) * est[tier_name]
                              + damping * observed)
            if regime == "bandwidth":
                diagnosis[hop.name] = f"bandwidth-bound({tier_name})"

    new_tiers = [dataclasses.replace(t, bandwidth_bytes_per_s=est[t.name],
                                     latency_s=lat_est[t.name],
                                     jitter_s=jit_est[t.name])
                 for t in plan.basin.tiers]
    # explicit links are physical (bandwidth + rtt) and survive; implicit
    # ones were derived from the old tier estimates and must re-derive,
    # otherwise an upward revision stays clamped at the stale link rate
    links = plan.basin.links if plan.basin.explicit_links else None
    new_basin = DrainageBasin(new_tiers, links)
    revised = plan_transfer(
        new_basin, plan.item_bytes, stages=plan.stages,
        checksum=plan.checksum_index is not None, ordered=plan.ordered)
    revised.diagnosis = diagnosis
    return revised
