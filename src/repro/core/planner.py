"""TransferPlan engine — the basin model turned into staging parameters.

The paper's planning discipline (§2.3 "global tuning", §3.4 weakest-link
analysis) is that predictable line-rate movement comes from matching
buffer depth, concurrency, and integrity budget to *every* tier of the
path — not from per-workload hand tuning.  :mod:`repro.core.basin` is the
analytic model; this module is the bridge that turns a
:class:`~repro.core.basin.DrainageBasin` plus an item-size estimate into
the concrete knobs every data-moving layer needs:

* **capacity** — burst-buffer slots per hop (Little's law over the
  jitter window, double-buffered),
* **workers** — concurrent staging workers per hop (concurrency as the
  latency antidote, §3.1: enough in-flight pulls that per-item latency
  and jitter amortize away and the hop sustains the path's line rate),
* **checksum placement** — the integrity budget (§3.4) rides the hop
  with the most bandwidth headroom, so hashing overlaps transit instead
  of stretching the critical path.

Every consumer — the training-input pipeline, the checkpoint engine, the
decode token stream — builds its basin, asks :func:`plan_transfer` for a
:class:`TransferPlan`, and hands that plan to the
:class:`~repro.core.mover.UnifiedDataMover` / stage constructors.  No
layer carries hard-coded staging constants.

DAG basins and per-branch plans
-------------------------------

A branching basin (N dataset shards fanning in to one host, a checkpoint
mirrored to two storage tiers, a decode stream fanning out to many
clients) plans per **branch**: :func:`plan_transfer` enumerates the
basin's root->sink paths, allocates each a rate under shared-tier rate
conservation (:meth:`~repro.core.basin.DrainageBasin.branch_rates` —
branch rates through a shared tier sum to no more than its effective
rate), and derives an independent hop list per branch sized to that
branch's allocated share.  The result is one :class:`TransferPlan` whose
:attr:`~TransferPlan.branches` lists a :class:`BranchPlan` per path; its
``planned_bytes_per_s`` is the *aggregate* over branches, and its
``weight`` per branch is the share of traffic the parallel mover routes
down that branch (``UnifiedDataMover.parallel_transfer``).  On a linear
basin there is exactly one branch mirroring ``plan.hops`` — the
pre-refactor behaviour, bit for bit.

Adaptive re-planning (the paper's hypothesis -> change -> measure cycle,
made mechanical): observed :class:`~repro.core.staging.StageReport` stall
ratios feed back into the tier bandwidth estimates via :func:`replan`,
which returns a revised plan.  A hop that mostly *starved* (stall
upstream) reveals the upstream tier is slower than modeled; a hop that
mostly *backpressured* (stall downstream) reveals the downstream tier is.

Per-branch attribution: parallel-branch reports arrive tagged
``"<branch>/<stage>"``, and :func:`replan` attributes each branch's
evidence to that branch alone.  Two rules keep one slow branch from
uniformly degrading the whole plan:

* **private-tier attribution** — a branch hop that is *busy* (no stalls,
  yet underdelivering) spent its time in its own pull+transform service,
  i.e. in the branch-private channel; the verdict lands on the branch's
  private tier, never on a tier shared with healthy siblings.
* **corroboration** — a branch's stall evidence may implicate a shared
  tier only when every sibling branch crossing that tier shows evidence
  against it too.  A lone branch starving upstream of a split node is a
  routing shadow (traffic was sent elsewhere), not proof the shared tier
  degraded.

The revised plan re-allocates branch rates from the updated estimates,
so traffic rebalances toward healthy branches (their weights grow) while
the degraded branch's verdict is preserved in
:attr:`TransferPlan.diagnosis` under its ``"<branch>/<hop>"`` key.

Regime diagnosis (latency-bound vs bandwidth-bound)
---------------------------------------------------

A stall ratio alone cannot say *why* a hop waited — and the two causes
demand opposite remedies (the paper's "raw bandwidth = capability"
fallacy, and the regime separation of arXiv:2308.10312).  The per-item
service-time reservoirs in :class:`~repro.core.staging.StageReport`
(``service_up_s`` / ``service_down_s``) disambiguate:

* **latency-bound** — service times are widely dispersed (stochastic
  per-item latency + jitter dominates).  Remedy: revise the tier's
  ``latency_s``/``jitter_s`` estimates upward so the next plan raises
  ``workers`` (concurrency amortizes latency, §3.1) and deepens the
  buffer.  Bandwidth estimates are left alone.
* **bandwidth-bound** — service times are tight around a constant (the
  pipe is saturated; every item takes ~``item_bytes/true_bw``).  Remedy:
  pull the tier's ``bandwidth_gbps`` estimate toward the observed rate
  and accept the lower line rate.  More workers would not help.

Without service samples (an empty reservoir) replan falls back to the
bandwidth remedy — the conservative pre-diagnosis behaviour.  A hop that
never stalled but still underdelivered against its planned rate (busy on
its own pull + transform service) is diagnosed from its samples too — the
busy-hop rule, exercised by ``benchmarks/online_replan.py``.

Online replanning: the mover's ``replan_every_items`` runs a transfer in
segments and feeds each segment's reports through :func:`replan` at the
buffer boundary, so a mid-transfer regime shift is answered mid-transfer
(see ``UnifiedDataMover.bulk_transfer`` / ``parallel_transfer``).
"""

from __future__ import annotations

import collections.abc
import dataclasses
import math
from typing import Mapping, Optional, Sequence, Union

from .basin import DrainageBasin, Tier, TierKind
from .staging import StageReport

#: ceiling on per-hop concurrency (a planning guard, not a tuning knob:
#: past this the GIL/thread overhead of the host path dominates)
MAX_WORKERS = 8
#: ceiling on per-hop buffer slots (bounds host memory for tiny items)
MAX_CAPACITY = 64
#: window sizing margin over the path BDP (§3.2): ACK compression and
#: cross-traffic jitter make a window cut exactly at BDP oscillate below
#: line rate, so the plan leaves this much slack
WINDOW_HEADROOM = 1.25
#: slab sizing target for ``batch_items="auto"``: enough items per slab
#: that the per-slab lock/admission round-trip amortizes to noise, small
#: enough that a slab never monopolizes a hop's burst buffer
SLAB_TARGET_BYTES = 1 << 20
#: default modeled host digest throughput (SHA-256 on one core, bytes/s)
#: — the §3.4 integrity budget when the checksum runs on the host CPU.
#: Callers with a measured rate pass ``host_digest_bytes_per_s``.
HOST_DIGEST_BYTES_PER_S = 1.6e9
#: default modeled accelerator digest throughput: a batched Pallas digest
#: kernel streams at HBM-class bandwidth, far above any host path — the
#: placement that takes integrity off the critical path entirely
ACCEL_DIGEST_BYTES_PER_S = 64e9
#: a busy checksum hop is **host-compute-bound** only when its delivered
#: rate sits at the digest ceiling — within this factor of the modeled
#: ``digest_bytes_per_s`` (the §3.4 signature: throughput pinned by the
#: integrity budget, not by any tier or by transport credit)
DIGEST_PIN_SLACK = 1.5
#: minimum observed-ACK samples before the live RTT estimate is trusted
#: to revise ``HopPlan.rtt_s`` (fewer and one stray ACK skews the mean)
MIN_RTT_SAMPLES = 8
#: relative deviation of the observed RTT estimate from the planned
#: ``rtt_s`` beyond which the plan's RTT is revised (an **rtt-revised**
#: verdict).  Below it the estimate is jitter, not a route change.
RTT_REVISION_TOLERANCE = 0.2
#: observed retransmit fraction (retransmits / items) at or above which a
#: window-stalled hop reads as **loss-bound** — §3.2's deterministic-loss
#: regime, whose remedy deepens the window by (1 + loss) and lowers the
#: promise honestly wherever a clamp keeps the window shallow
LOSS_RATE_THRESHOLD = 0.05
#: execution shapes the path decision engine prices (§3.6's stream-vs-
#: stage question made a planned quantity).  ``direct`` is the cut-
#: through stream: one worker, no burst-buffer depth, stop-and-wait on
#: any latency-bearing link — it bypasses the staging copy entirely.
#: ``staged`` is N synchronous streams through the burst buffer (each
#: worker pays the round trip per item).  ``windowed-staged`` is the
#: historical full shape: staged concurrency plus BDP-sized transport
#: credit.  ``compressed`` is windowed-staged with the int8 wire
#: transform: :data:`COMPRESS_WIRE_RATIO` fewer bytes cross every link,
#: paid for at :data:`COMPRESS_BYTES_PER_S` of quantize compute.
PATH_CHOICES = ("direct", "staged", "windowed-staged", "compressed")
#: wire-byte reduction of the compressed path: float32 payloads quantize
#: to int8 (+ per-block scales) via ``integrity.compress_transform`` —
#: 4x fewer bytes on every link the plan prices
COMPRESS_WIRE_RATIO = 4.0
#: modeled quantize/dequantize service rate (bytes of UNCOMPRESSED
#: payload per second) — the compute charge the compressed-wire path
#: pays for its wire relief; it only wins when a link is the priced
#: bottleneck by more than this ceiling allows
COMPRESS_BYTES_PER_S = 8e9
#: online path-revision hysteresis: a replan abandons the executing path
#: only when the re-scored challenger beats the incumbent's re-scored
#: rate by this factor.  A live shape switch re-parameterizes a running
#: pipeline; near-ties must not flap it every revision boundary.
PATH_REVISION_MARGIN = 1.2
#: ceiling on a fault-priced retry budget: past this, a flapping element
#: needs failover (branch death / fleet re-admission), not more patience
MAX_RETRY_BUDGET = 8
#: default transient-failure posture (budget, backoff base) for an
#: element with no observed faults in the telemetry priors
DEFAULT_RETRY_BUDGET = 2
DEFAULT_BACKOFF_BASE_S = 0.05


@dataclasses.dataclass(frozen=True)
class HopPlan:
    """Staging parameters for one hop (one :class:`~repro.core.staging.Stage`)."""

    name: str
    capacity: int               # burst-buffer slots
    workers: int                # concurrent staging workers
    up_tier: str                # tier the hop pulls from
    down_tier: str              # tier the hop delivers toward
    rate_bytes_per_s: float     # what this hop can sustain as planned
    #: in-flight byte cap for an RTT-governed CHANNEL hop (0 = the hop is
    #: queue-clocked).  Sized from the segment link's BDP with
    #: :data:`WINDOW_HEADROOM`, clamped to the segment's burst capacity
    #: and to an explicit ``max_window_bytes`` (the host's socket-buffer
    #: limit, §3.2's silent throughput killer)
    window_bytes: float = 0.0
    #: round-trip time of the hop's windowed link (the ACK clock)
    rtt_s: float = 0.0
    #: ``"src->dst"`` of the link whose BDP governs the window (the name
    #: a window-bound verdict points at); "" on queue-clocked hops
    window_link: str = ""
    #: modeled retransmit fraction of the windowed link (§3.2): the
    #: window is deepened by (1 + loss_rate) so retransmit round trips
    #: do not drain the pipe, and a clamped window's promise drops by
    #: the same factor.  Revised by a **loss-bound** verdict.
    loss_rate: float = 0.0
    #: live RTT estimate from observed ACK spacing (0 = none yet); set by
    #: :func:`replan` when an **rtt-revised** verdict re-times the hop,
    #: and surfaced by ``describe()`` as ``rtt-est=`` next to ``rtt=``
    rtt_estimate_s: float = 0.0
    #: slab size: items the hop's workers pull/admit/stage per loop
    #: (``Stage.batch_items``).  1 = the per-item path.
    batch_items: int = 1
    #: modeled digest service rate charged to this hop (bytes/s); > 0
    #: only on the hop carrying the stream checksum.  Host placement
    #: charges the host SHA rate (and can pin the hop — the
    #: host-compute-bound verdict); accelerator placement charges the
    #: Pallas kernel's rate, far above line rate.
    digest_bytes_per_s: float = 0.0
    #: transient-failure retry budget: how many times one item's
    #: pull+transform may be re-attempted (exponential backoff from
    #: ``backoff_base_s``, seeded jitter) before the failure is final
    #: and the branch is declared dead.  0 = fail fast.
    retry_budget: int = 2
    #: base of the exponential backoff between retries (seconds);
    #: attempt k waits ``backoff_base_s * 2**k * (1 + jitter)``
    backoff_base_s: float = 0.05


def _hop_lookup(hops: Sequence[HopPlan], index: int,
                name: str | None) -> HopPlan:
    if name is not None:
        for h in hops:
            if h.name == name:
                return h
    return hops[min(index, len(hops) - 1)]


@dataclasses.dataclass
class BranchPlan:
    """One root->sink branch of a (possibly branching) plan."""

    branch_id: str                  # stable id ("nvme", "shard-0", ...)
    path: tuple[str, ...]           # tier names, root -> sink
    hops: list[HopPlan]
    rate_bytes_per_s: float         # the branch's planned sustained rate
    weight: float                   # share of traffic routed this way
    #: tiers on this path no other branch crosses — where branch-local
    #: evidence is attributed (see module docstring)
    private_tiers: tuple[str, ...] = ()

    def hop_for(self, index: int, name: str | None = None) -> HopPlan:
        """Hop by stage name when it matches, else by position."""
        return _hop_lookup(self.hops, index, name)


@dataclasses.dataclass
class TransferPlan:
    """A fully derived data path: per-hop parameters plus the promise
    (``planned_bytes_per_s``) the fidelity gap is measured against.

    ``branches`` always holds one :class:`BranchPlan` per root->sink path;
    on a linear basin the single branch mirrors ``hops`` exactly.  On a
    branching basin ``hops`` is the primary (highest-rate) branch's hop
    list — kept for single-pipeline consumers — and
    ``planned_bytes_per_s`` is the aggregate over branches."""

    hops: list[HopPlan]
    item_bytes: float
    planned_bytes_per_s: float
    checksum_index: Optional[int]       # hop index carrying the digest, or None
    basin: DrainageBasin
    ordered: bool
    #: hop name (or ``"<branch>/<hop>"``) -> regime verdict set by
    #: :func:`replan` on the revised plan; empty on a fresh derivation
    diagnosis: dict[str, str] = dataclasses.field(default_factory=dict)
    branches: list[BranchPlan] = dataclasses.field(default_factory=list)
    #: branching plans hash at the split node instead of riding one hop
    checksum_at_split: bool = False
    #: host limit the windowed hops were clamped under (None = BDP-sized;
    #: a mapping clamps per branch id).  A window-bound verdict's remedy
    #: is raising this — for the diagnosed branch only (see :func:`replan`)
    max_window_bytes: WindowClamp = None
    #: where the stream digest runs: ``"host"`` (SHA on the staging CPU,
    #: charged at ``host_digest_bytes_per_s``) or ``"accel"`` (batched
    #: Pallas digest, charged at ``accel_digest_bytes_per_s``).  A
    #: host-compute-bound verdict's remedy is flipping this to "accel".
    checksum_placement: str = "host"
    #: the ``batch_items`` policy the plan was derived under (None, int,
    #: or "auto") — carried so :func:`replan` re-derives with it
    batch_policy: Optional[object] = None
    #: arbiter-granted rate share (bytes/s) the plan was sized under, or
    #: None when the transfer owns the basin.  A capped plan's promise is
    #: the GRANT, its windows are sized from ``grant x RTT`` (so a
    #: windowed hop self-paces to its share), and :func:`replan` treats
    #: share-shaped stalls on a hop that still delivers its grant as the
    #: arbiter at work — never as a degraded tier (the fleet analogue of
    #: the §3.2 misdiagnosis family).  Carried through re-derivations.
    rate_cap_bytes_per_s: Optional[float] = None
    host_digest_bytes_per_s: float = HOST_DIGEST_BYTES_PER_S
    accel_digest_bytes_per_s: float = ACCEL_DIGEST_BYTES_PER_S
    #: the execution shape the hops are parameterized for (one of
    #: :data:`PATH_CHOICES`).  Legacy derivations (no ``path=`` given)
    #: label what they built — ``"windowed-staged"`` when any hop carries
    #: transport credit, ``"staged"`` otherwise — without pricing
    #: candidates.
    path: str = "windowed-staged"
    #: the caller's path request, carried through re-derivations: None
    #: (legacy, no decision engine), ``"auto"`` (replan may re-choose —
    #: the **path-revised** verdict), or a forced member of
    #: :data:`PATH_CHOICES` (pinned; replan re-prices but never switches)
    path_policy: Optional[str] = None
    #: candidate shape -> modeled end-to-end bytes/s over the item-size
    #: distribution; empty on legacy derivations.  ``describe()`` prints
    #: it so an operator can see what the chosen path beat.
    path_scores: dict[str, float] = dataclasses.field(default_factory=dict)
    #: normalized item-size histogram ``((bytes, weight), ...)`` the
    #: candidates were priced over (None = priced at ``item_bytes``).  A
    #: small-file storm prices its per-item latency honestly instead of
    #: hiding behind the mean.
    item_bytes_dist: Optional[tuple] = None
    #: the compressed-wire candidate is only enumerable when the caller
    #: vouches the payload survives the int8 transform
    compressible: bool = False
    #: element name ("src->dst" link or tier) -> observed transient-fault
    #: rate (retries per item), the telemetry prior retry budgets are
    #: priced from; None = every element keeps the cheap default posture
    fault_priors: Optional[Mapping[str, float]] = None

    @property
    def stages(self) -> list[str]:
        return [h.name for h in self.hops]

    @property
    def is_multipath(self) -> bool:
        return len(self.branches) > 1

    def branch(self, branch_id: str) -> BranchPlan:
        for b in self.branches:
            if b.branch_id == branch_id:
                return b
        raise KeyError(f"no branch {branch_id!r}")

    def hop_for(self, index: int, name: str | None = None) -> HopPlan:
        """Hop by stage name when it matches, else by position (extra
        stages beyond the planned hops inherit the last hop's params)."""
        return _hop_lookup(self.hops, index, name)

    @property
    def total_buffer_items(self) -> int:
        hops = [h for b in self.branches for h in b.hops] or self.hops
        return sum(h.capacity for h in hops)

    def _fmt_path(self) -> str:
        """Chosen execution shape + per-candidate scores; "" on legacy
        plans so their describe() stays byte-identical."""
        if not self.path_scores:
            return ""
        scores = ",".join(f"{name}={rate / 1e6:.1f}"
                          for name, rate in sorted(self.path_scores.items()))
        return f" path={self.path} scores[{scores}]MB/s"

    @staticmethod
    def _fmt_hop(h: HopPlan) -> str:
        win = ""
        if h.window_bytes > 0:
            loss = f" loss={h.loss_rate:.0%}" if h.loss_rate > 0 else ""
            est = (f" rtt-est={h.rtt_estimate_s * 1e3:.0f}ms"
                   if h.rtt_estimate_s > 0 else "")
            win = (f" win={h.window_bytes / 1e6:.1f}MB"
                   f" rtt={h.rtt_s * 1e3:.0f}ms{est}{loss}")
        # slab size surfaces only when the hop is actually batched, so a
        # per-item plan's describe() stays byte-identical to the old form
        batch = f" b={h.batch_items}" if h.batch_items > 1 else ""
        retry = f" retry={h.retry_budget}" if h.retry_budget > 0 else ""
        return (f"{h.name}[cap={h.capacity} w={h.workers}{batch}{win}{retry} "
                f"{h.up_tier}->{h.down_tier}]")

    def describe(self) -> str:
        """Operator surface: one line for a linear plan (unchanged from
        the pre-DAG format; windowed hops add their ``win``/``rtt``,
        batched hops their slab size ``b=``, and a carried checksum its
        placement — ``checksum@1:host`` vs ``checksum@1:accel``), a
        per-branch topology summary otherwise."""
        if not self.is_multipath:
            diag = ""
            if self.diagnosis:
                diag = "; diag[" + ", ".join(
                    f"{name}={verdict}"
                    for name, verdict in sorted(self.diagnosis.items())) + "]"
            hops = ", ".join(self._fmt_hop(h) for h in self.hops)
            place = (f":{self.checksum_placement}"
                     if self.checksum_index is not None else "")
            cap = ""
            if self.rate_cap_bytes_per_s is not None:
                cap = (f" arbiter-capped@"
                       f"{self.rate_cap_bytes_per_s / 1e6:.1f} MB/s")
            return (f"TransferPlan({hops}; planned="
                    f"{self.planned_bytes_per_s / 1e6:.1f} MB/s{cap}"
                    f"{self._fmt_path()}, "
                    f"checksum@{self.checksum_index}{place}{diag})")
        split = (f"split:{self.checksum_placement}"
                 if self.checksum_at_split else "None")
        cap = ""
        if self.rate_cap_bytes_per_s is not None:
            cap = (f" arbiter-capped@"
                   f"{self.rate_cap_bytes_per_s / 1e6:.1f} MB/s")
        lines = [f"TransferPlan({len(self.branches)} branches, planned="
                 f"{self.planned_bytes_per_s / 1e6:.1f} MB/s aggregate{cap}"
                 f"{self._fmt_path()}, "
                 f"checksum@{split}"]
        shown = set()
        for b in self.branches:
            hops = ", ".join(self._fmt_hop(h) for h in b.hops)
            keys = [f"{b.branch_id}/{h.name}" for h in b.hops]
            verdicts = [f"{k.split('/', 1)[1]}={self.diagnosis[k]}"
                        for k in keys if k in self.diagnosis]
            shown.update(k for k in keys if k in self.diagnosis)
            tail = f"  !{'; '.join(verdicts)}" if verdicts else ""
            # a failed-over branch carries its obituary under its bare id
            dead = ""
            if self.diagnosis.get(b.branch_id, "").startswith("branch-dead"):
                shown.add(b.branch_id)
                dead = " dead"
            lines.append(f"  {b.branch_id}{dead} w={b.weight:.2f} "
                         f"@{b.rate_bytes_per_s / 1e6:.1f} MB/s: {hops}{tail}")
        # verdicts carried over from branches no longer in the plan
        stray = {k: v for k, v in self.diagnosis.items() if k not in shown}
        diag = ""
        if stray:
            diag = "; diag[" + ", ".join(
                f"{k}={v}" for k, v in sorted(stray.items())) + "]"
        return "\n".join(lines) + f"{diag})"


@dataclasses.dataclass(frozen=True)
class HopRevision:
    """Revised staging parameters for one live hop."""

    name: str
    capacity: int
    workers: int
    window_bytes: float = 0.0
    batch_items: int = 1
    #: revised ACK-clock round trip (0 = the hop is queue-clocked).  An
    #: rtt-revised plan must re-time the RUNNING WindowedStage even when
    #: every other parameter (including a clamped window) is unchanged —
    #: a stale ACK clock mis-paces admission and mis-reads the next
    #: revision window's evidence.
    rtt_s: float = 0.0
    #: revised transient-fault posture (fault-prior pricing): the running
    #: stage adopts the new budget/backoff without a drain, so a hop that
    #: just proved it flaps gets its deeper budget before the next fault
    retry_budget: int = DEFAULT_RETRY_BUDGET
    backoff_base_s: float = DEFAULT_BACKOFF_BASE_S


@dataclasses.dataclass
class PlanDelta:
    """What actually changed between two plans over the same topology —
    the unit of **zero-drain** replanning.

    A revised :class:`TransferPlan` is a full re-derivation; a running
    pipeline does not need to be torn down to adopt it, only to apply the
    difference: per-hop capacity/worker revisions (resized in place via
    ``Stage.resize``) and per-branch traffic-weight shifts (swapped into
    the live dispatcher).  Falsy when the revision changed nothing —
    the mover's ``replans`` counter counts truthy deltas only."""

    #: linear-path hop name -> revised params (changed hops only)
    hops: dict[str, HopRevision] = dataclasses.field(default_factory=dict)
    #: branch id -> hop name -> revised params (changed hops only)
    branch_hops: dict[str, dict[str, HopRevision]] = \
        dataclasses.field(default_factory=dict)
    #: branch id -> new traffic weight (branches whose share shifted)
    weights: dict[str, float] = dataclasses.field(default_factory=dict)
    #: the revised plan's execution shape when it differs from the old
    #: plan's (a **path-revised** switch): the mover rebuilds the pipeline
    #: shape — via the same per-hop resizes, since every shape is a
    #: parameterization of the same stage chain — while buffers, ledger,
    #: fleet grant, and the stream digest carry over.  None = same shape.
    path: Optional[str] = None

    def __bool__(self) -> bool:
        return bool(self.hops or self.branch_hops or self.weights
                    or self.path)


def plan_delta(old: TransferPlan, new: TransferPlan) -> PlanDelta:
    """The applicable difference between two same-topology plans.

    Hops match by name (a replan preserves stage names and order); a
    weight counts as shifted beyond round-off at 3 decimals — the same
    signature the drain-path revision counter used, so the two execution
    modes count replans identically."""
    delta = PlanDelta()

    def changed_hop(h: HopPlan, prev: HopPlan | None) -> bool:
        # rtt_s is part of the live-applicable surface: an rtt-revised
        # plan whose (clamped) window came out numerically identical must
        # still produce a truthy delta, or the running WindowedStage
        # keeps a stale ACK clock through the revision.  The retry
        # posture rides the same surface: a fault-priced budget must
        # reach the running stage before the element's next flap.
        return prev is None or (
            (h.capacity, h.workers, h.window_bytes, h.batch_items, h.rtt_s,
             h.retry_budget, h.backoff_base_s)
            != (prev.capacity, prev.workers, prev.window_bytes,
                prev.batch_items, prev.rtt_s,
                prev.retry_budget, prev.backoff_base_s))

    def revision(h: HopPlan) -> HopRevision:
        return HopRevision(h.name, h.capacity, h.workers, h.window_bytes,
                           h.batch_items, h.rtt_s,
                           retry_budget=h.retry_budget,
                           backoff_base_s=h.backoff_base_s)

    if new.path != old.path:
        delta.path = new.path
    old_hops = {h.name: h for h in old.hops}
    for h in new.hops:
        if changed_hop(h, old_hops.get(h.name)):
            delta.hops[h.name] = revision(h)
    old_branches = {b.branch_id: b for b in old.branches}
    for b in new.branches:
        prev = old_branches.get(b.branch_id)
        if prev is not None and round(b.weight, 3) != round(prev.weight, 3):
            delta.weights[b.branch_id] = b.weight
        prev_hops = {h.name: h for h in prev.hops} if prev is not None else {}
        changed = {}
        for h in b.hops:
            if changed_hop(h, prev_hops.get(h.name)):
                changed[h.name] = revision(h)
        if changed:
            delta.branch_hops[b.branch_id] = changed
    return delta


def _segment(tiers: Sequence[Tier], n_stages: int, j: int
             ) -> tuple[int, int]:
    """Tier-index span [lo, hi] that stage ``j`` of ``n_stages`` covers.

    Stages partition the basin path evenly; each hop pulls from its
    segment's first tier and delivers toward its last."""
    T = len(tiers)
    lo = j * (T - 1) // n_stages
    hi = (j + 1) * (T - 1) // n_stages
    hi = max(hi, lo + 1)
    return lo, min(hi, T - 1)


def _segment_rtt(basin: DrainageBasin, lo: int, hi: int) -> float:
    names = {t.name for t in basin.tiers[lo:hi + 1]}
    rtts = [l.rtt_s for l in basin.links
            if l.src in names and l.dst in names]
    return max(rtts, default=0.0)


def _segment_window(basin: DrainageBasin, lo: int, hi: int
                    ) -> tuple[float, float, str, float]:
    """(rtt_s, bdp_bytes, "src->dst", loss_rate) of the highest-BDP
    windowed link inside the tier span — the link whose ACK clock governs
    this hop.  (0, 0, "", 0) when the segment crosses no latency-bearing
    link (a queue-clocked hop)."""
    names = {t.name for t in basin.tiers[lo:hi + 1]}
    best = (0.0, 0.0, "", 0.0)
    for l in basin.links:
        if l.src in names and l.dst in names and l.rtt_s > 0:
            if l.bdp_bytes() > best[1]:
                best = (l.rtt_s, l.bdp_bytes(), f"{l.src}->{l.dst}",
                        l.loss_rate)
    return best


def _raw_line_rate(basin: DrainageBasin) -> float:
    """Line rate ignoring per-item latency: min raw bandwidth over every
    tier and link.  Concurrency (workers) is how a hop reaches it despite
    latency — the paper's §3.1 latency insensitivity."""
    rates = [t.bandwidth_bytes_per_s for t in basin.tiers]
    rates.extend(l.bandwidth_bytes_per_s for l in basin.links)
    return min(rates)


def _worker_rate(up: Tier, down: Tier, item_bytes: float,
                 batch_items: int = 1,
                 extra_latency_s: float = 0.0) -> float:
    """Sustained rate of ONE staging worker doing pull -> transform ->
    push: upstream service time (with latency + jitter) plus downstream
    delivery, serialized within the worker.

    A batched worker pays the per-operation latency/jitter once per
    *slab* of ``batch_items`` — the analytic form of the zero-copy data
    plane's amortization (one lock round-trip, one admission check per
    slab); the per-byte transmit cost is unchanged.  ``batch_items=1``
    is the historical per-item figure exactly.

    ``extra_latency_s`` is charged per *item*, never amortized by the
    slab: it models the expected retransmit round trips on a lossy
    windowed hop (``loss_rate * rtt_s``), which each item pays
    independently — concurrency across workers, not batching within
    one, is what rides those round trips out."""
    b = max(1, int(batch_items))
    t = (item_bytes / up.bandwidth_bytes_per_s
         + (up.latency_s + up.jitter_s) / b
         + item_bytes / down.bandwidth_bytes_per_s + down.latency_s / b
         + extra_latency_s)
    return item_bytes / t


def _resolve_batch(batch_items: Optional[object],
                   item_bytes: float) -> int:
    """The slab-size policy -> a concrete per-hop starting point.

    ``None``/1 keeps the per-item path; ``"auto"`` targets
    :data:`SLAB_TARGET_BYTES` per slab (further clamped per hop by window
    and capacity); an explicit int is taken as given (same clamps)."""
    if batch_items is None:
        return 1
    if batch_items == "auto":
        return max(1, int(SLAB_TARGET_BYTES // item_bytes))
    b = int(batch_items)
    if b < 1:
        raise ValueError(f"batch_items must be >= 1, got {batch_items!r}")
    return b


def _plan_path(
    basin: DrainageBasin,
    item_bytes: float,
    stages: Sequence[str],
    ordered: bool,
    max_workers: int,
    max_capacity: int,
    target: float | None = None,
    max_window_bytes: float | None = None,
    batch_items: int = 1,
    rate_cap: float | None = None,
    shape: str = "windowed-staged",
    wire_ratio: float = 1.0,
) -> tuple[list[HopPlan], list[float], float]:
    """Per-hop parameters for one *linear* path.  ``target`` overrides the
    rate the hops are sized against (a branch's allocated share); default
    is the path's own raw line rate.  ``max_window_bytes`` caps every
    windowed hop's in-flight window (the host buffer limit).
    ``batch_items`` is the resolved slab-size starting point (see
    :func:`_resolve_batch`); each hop clamps it to its own window and
    burst capacity.  ``rate_cap`` is an arbiter grant: windows size from
    ``grant x RTT`` instead of the link's full BDP, so a capped windowed
    hop self-paces to its share on a link it does not own — uncapped
    plans keep the historical BDP sizing bit for bit.

    ``shape`` parameterizes the same stage chain into one of
    :data:`PATH_CHOICES`: ``"windowed-staged"`` (and ``"compressed"``,
    which additionally scales every link's wire bytes by ``wire_ratio``)
    keep the historical derivation; ``"staged"`` runs N synchronous
    streams — each worker pays the full round trip per item, the window
    holds exactly one item per worker; ``"direct"`` is the cut-through
    stream — one worker, one buffer slot, stop-and-wait credit of a
    single item on any latency-bearing link.  Because shapes differ only
    in hop parameters, a live path switch is applied with the same
    zero-drain resizes as any other revision."""
    tiers = basin.tiers
    n = len(stages)
    if target is None:
        target = _raw_line_rate(basin)
    if rate_cap is not None:
        target = min(target, rate_cap)
    sync_rtt = shape in ("direct", "staged")

    hops: list[HopPlan] = []
    headroom: list[float] = []          # uncapped sustainable rate per hop
    for j, name in enumerate(stages):
        lo, hi = _segment(tiers, n, j)
        up, down = tiers[lo], tiers[hi]
        # the segment's burst capacity is a hard ceiling: never plan more
        # staged items than the smallest tier on the hop can actually hold
        cap_bytes = min(t.capacity_bytes for t in tiers[lo:hi + 1])
        # RTT-governed hop: the in-flight window is sized from the link's
        # BDP with jitter headroom (§3.1/§3.2), clamped to the segment's
        # burst capacity and the host's window limit.  The two clamps
        # mean different things: a *burst-capacity* clamp is a physical
        # model fact (the hop cannot keep more in flight than the
        # staging tier holds), so the hop's promise honestly drops to
        # window/RTT; a *host* (``max_window_bytes``) clamp is a fixable
        # misconfiguration, so the promise stays the line rate and the
        # shortfall surfaces as a fidelity gap + window-bound verdict —
        # whose remedy (lifting the clamp) then actually works.
        rtt, bdp, win_link, loss = _segment_window(basin, lo, hi)
        win = 0.0
        hop_cap = target
        if rtt > 0 and bdp > 0:
            # a lossy link pays one extra RTT per retransmitted item
            # (§3.2): riding those round trips out without draining the
            # pipe needs (1 + loss) windows of bytes in flight — and a
            # window clamped below that only ever delivers
            # ``win / (rtt * (1 + loss))``, so the burst-capacity clamp
            # drops the hop's promise by the same factor (honesty), while
            # a host clamp keeps the promise and surfaces as window-bound
            # an arbiter-capped plan keeps only its granted share of the
            # pipe in flight: window credit IS the enforcement mechanism
            # (K capped peers on one work-conserving link each converge
            # to exactly their grant — the credit clocks, not goodwill).
            # A binding grant carries NO jitter headroom: headroom exists
            # to absorb estimate error on a link the plan owns, but on a
            # shared link it would overshoot the grant — and K overshoots
            # sum to a standing queue whose delay lands unevenly (big
            # windows burst hardest), skewing every class off its share.
            # wire bytes per item: the compressed shape moves the int8
            # form across the link, so window credit (which meters the
            # WIRE) is sized and charged in compressed bytes while hop
            # rates stay in delivered (uncompressed) bytes
            wire_item = item_bytes / wire_ratio
            capped = rate_cap is not None and (target / wire_ratio) * rtt < bdp
            if capped:
                bdp = (target / wire_ratio) * rtt
            slack = 1.0 if capped else WINDOW_HEADROOM
            bdp_eff = bdp * (1.0 + loss)
            if shape == "direct":
                # stop-and-wait: exactly one item's wire bytes in flight;
                # every item pays the round trip (charged in rate_1 below)
                win = wire_item
            else:
                win = bdp_eff * slack
                # coarse admission units (§3.4): the window admits whole
                # items, so once one item is a sizable fraction of the BDP
                # a BDP-sized window degenerates toward stop-and-wait — it
                # cannot hold the item in transmission AND its unACKed
                # predecessors.  Size for both, and throughput stays flat
                # from KiB items to GiB items (the fig4 claim).
                if wire_item * 4 > bdp_eff:
                    win = (bdp_eff + wire_item) * slack
            if math.isfinite(cap_bytes) and cap_bytes < win:
                win = cap_bytes
                hop_cap = min(hop_cap,
                              wire_ratio * win / (rtt * (1.0 + loss)))
            if max_window_bytes is not None:
                win = min(win, float(max_window_bytes))
        # slab size: ordered transfers pin to per-item (a slab reorders
        # nothing, but per-item keeps the stream's pacing exact); a
        # windowed hop never slabs more than one window's worth, or a
        # single admission could park the whole pool on the ACK clock
        b = 1 if ordered else batch_items
        if b > 1 and win > 0:
            b = max(1, min(b, int(win // item_bytes)))
        # a lossy hop's workers each carry the expected retransmit
        # round trip per item; the pool is staffed for it, and when even
        # ``max_workers`` cannot reach the line, the hop's promise drops
        # with the staffed pool — honestly, not as a fidelity gap.  The
        # synchronous shapes (direct, staged) pay the FULL round trip
        # per item — that is what makes them lose on a long fat link and
        # what makes their model honest when they win anyway.
        extra = rtt * (1.0 + loss) if (sync_rtt and rtt > 0) else loss * rtt
        rate_1 = _worker_rate(up, down, item_bytes, batch_items=b,
                              extra_latency_s=extra)
        if ordered or shape == "direct":
            workers = 1
        else:
            workers = max(1, min(max_workers, math.ceil(target / rate_1)))
        # Little's law over the stochastic window, double-buffered
        window_s = up.jitter_s + down.jitter_s + _segment_rtt(basin, lo, hi)
        need_items = math.ceil(target * window_s / item_bytes)
        capacity = max(2, workers + 1, 2 * need_items)
        if b > 1:
            # double-buffered slabs: one slab staged while the next fills
            capacity = max(capacity, 2 * b)
        capacity = min(capacity, max_capacity)
        if math.isfinite(cap_bytes):
            capacity = min(capacity, max(1, int(cap_bytes // item_bytes)))
            # a buffer shallower than the pool serializes the extra
            # workers; shrink the pool so the promised rate stays honest
            workers = min(workers, max(1, capacity - 1))
        if b > 1:
            # whatever clamped capacity also clamps the slab (a slab must
            # fit the buffer twice over, or put_many serializes in waves)
            b = max(1, min(b, capacity // 2))
        if shape == "direct":
            # cut-through: no burst-buffer depth, no pool, no slabs —
            # the item passes straight through, which is exactly where
            # the shape's win (no staging copy) and its loss (no
            # concurrency to amortize latency) both come from
            workers, capacity, b = 1, 1, 1
        elif shape == "staged" and win > 0:
            # N synchronous streams: window credit of one item per
            # worker, so each stream is stop-and-wait on its own round
            # trip while the pool overlaps them — transport credit never
            # exceeds what the synchronous semantics can use
            win = workers * (item_bytes / wire_ratio)
            if math.isfinite(cap_bytes):
                win = min(win, cap_bytes)
            if max_window_bytes is not None:
                win = min(win, float(max_window_bytes))
        headroom.append(workers * rate_1)
        hop_rate = min(workers * rate_1, hop_cap)
        hops.append(HopPlan(name=name, capacity=capacity, workers=workers,
                            up_tier=up.name, down_tier=down.name,
                            rate_bytes_per_s=hop_rate,
                            window_bytes=win, rtt_s=rtt,
                            window_link=win_link if win > 0 else "",
                            loss_rate=loss if win > 0 else 0.0,
                            batch_items=b))

    achievable = basin.achievable_throughput()
    if wire_ratio > 1.0:
        # the compressed wire carries ratio-fewer bytes per delivered
        # byte: links stop binding until their boosted rate does, and the
        # quantize kernel's service rate becomes the new ceiling
        rates = [t.bandwidth_bytes_per_s for t in tiers]
        rates.extend((l.bandwidth_bytes_per_s or math.inf) * wire_ratio
                     for l in basin.links)
        achievable = min(min(rates), COMPRESS_BYTES_PER_S)
    planned = min(min(h.rate_bytes_per_s for h in hops), achievable)
    return hops, headroom, planned


#: a window clamp is either one host limit for the whole plan (float) or
#: a per-branch mapping ``branch_id -> bytes`` (two WAN branches behind
#: different host configs); ``None``/missing branch = BDP-sized
WindowClamp = Optional[Union[float, Mapping[str, float]]]


def _branch_window_clamp(max_window_bytes: WindowClamp,
                         branch_id: str) -> Optional[float]:
    """Resolve the window clamp that applies to one branch."""
    if max_window_bytes is None:
        return None
    if isinstance(max_window_bytes, collections.abc.Mapping):
        v = max_window_bytes.get(branch_id)
        return float(v) if v is not None else None
    return float(max_window_bytes)


def _branch_ids(paths: Sequence[tuple[str, ...]]) -> list[str]:
    """Shortest distinguishing name per path: the sink when sinks differ
    (fan-out), the root when roots differ (fan-in), else the full path."""
    sinks = [p[-1] for p in paths]
    if len(set(sinks)) == len(paths):
        return sinks
    roots = [p[0] for p in paths]
    if len(set(roots)) == len(paths):
        return roots
    return ["->".join(p) for p in paths]


# ---------------------------------------------------------------------------
# Path decision engine: §3.6's stream-vs-stage question, priced per basin
# ---------------------------------------------------------------------------


def _resolve_dist(item_bytes_dist, item_bytes: float
                  ) -> tuple[tuple[float, float], ...]:
    """Normalize an item-size histogram to ``((bytes, weight), ...)``.

    Accepts a mapping ``bytes -> weight`` or a sequence of pairs; None
    degenerates to a single bucket at ``item_bytes``.  Weights are
    relative (they need not sum to 1)."""
    if item_bytes_dist is None:
        return ((float(item_bytes), 1.0),)
    if isinstance(item_bytes_dist, collections.abc.Mapping):
        pairs = list(item_bytes_dist.items())
    else:
        pairs = [tuple(p) for p in item_bytes_dist]
    out = []
    for b, w in pairs:
        b, w = float(b), float(w)
        if b <= 0 or w <= 0:
            raise ValueError(
                f"item_bytes_dist buckets must be positive, got ({b}, {w})")
        out.append((b, w))
    if not out:
        raise ValueError("item_bytes_dist must not be empty")
    return tuple(out)


def _retry_posture(fault_rate: float) -> tuple[int, float]:
    """(retry_budget, backoff_base_s) priced from an element's observed
    transient-fault rate (retries per item — the inverse of its MTBF in
    items).  A fault-free element keeps the cheap default; a flapping one
    gets budget in proportion to how often it flaps (more faults per item
    -> more attempts funded before the failure is final) and a shorter
    backoff base (frequent transient blips clear fast; the budget, not
    long waits, carries the persistence risk)."""
    if fault_rate <= 0:
        return DEFAULT_RETRY_BUDGET, DEFAULT_BACKOFF_BASE_S
    budget = min(MAX_RETRY_BUDGET,
                 DEFAULT_RETRY_BUDGET + math.ceil(fault_rate / 0.05))
    backoff = max(0.01,
                  DEFAULT_BACKOFF_BASE_S * (1.0 - min(0.8, 10.0 * fault_rate)))
    return budget, backoff


def _stamp_retry_budgets(hops: list[HopPlan],
                         priors: Mapping[str, float]) -> None:
    """Re-price each hop's fault posture from the telemetry priors, in
    place (hop lists are shared between ``plan.hops`` and the primary
    branch — mutating preserves that identity)."""
    for i, h in enumerate(hops):
        f = priors.get(h.window_link or h.up_tier,
                       priors.get(h.up_tier, 0.0))
        budget, backoff = _retry_posture(f)
        if (budget, backoff) != (h.retry_budget, h.backoff_base_s):
            hops[i] = dataclasses.replace(h, retry_budget=budget,
                                          backoff_base_s=backoff)


def _shape_rate(basin: DrainageBasin, shape: str, item_bytes: float, *,
                checksum: bool, digest_rate: float, ordered: bool,
                max_workers: int, max_window_bytes: Optional[float],
                rate_cap: Optional[float],
                target: Optional[float] = None) -> float:
    """Modeled end-to-end bytes/s of one execution shape over one linear
    path at one item size — the pricing model behind ``path="auto"``.

    The four shapes price §3.6's trade directly:

    * ``direct`` — serialized cut-through: every non-staging element's
      transmit + latency is paid per item, in sequence, plus the full
      round trip of every link (stop-and-wait) and the serial digest when
      integrity is on.  Interior BURST_BUFFER tiers are *bypassed* — the
      direct stream never pays the staging copy, which is exactly how it
      wins on a path whose staging tier is the priced bottleneck.
    * ``staged`` — concurrent synchronous streams through the burst
      buffer: the pool amortizes per-item latency, but each item still
      carries its links' full round trips.
    * ``windowed-staged`` — staged plus BDP-sized transport credit: round
      trips amortize into the window; each windowed link instead ceilings
      at ``window / RTT``.
    * ``compressed`` — windowed-staged with every link's wire bytes
      scaled by :data:`COMPRESS_WIRE_RATIO`, the whole path ceilinged at
      :data:`COMPRESS_BYTES_PER_S` of quantize compute.
    """
    tiers = basin.tiers
    links = basin.links
    wire_ratio = COMPRESS_WIRE_RATIO if shape == "compressed" else 1.0

    if shape == "direct":
        t = 0.0
        for i, tier in enumerate(tiers):
            if (0 < i < len(tiers) - 1
                    and tier.kind is TierKind.BURST_BUFFER):
                continue
            t += (item_bytes / tier.bandwidth_bytes_per_s
                  + tier.latency_s + tier.jitter_s)
        for link in links:
            if link.bandwidth_bytes_per_s:
                t += item_bytes / link.bandwidth_bytes_per_s
            t += link.rtt_s * (1.0 + link.loss_rate)
        if checksum:
            t += item_bytes / digest_rate
        rate = item_bytes / t
    else:
        rates = [tier.bandwidth_bytes_per_s for tier in tiers]
        rates.extend((link.bandwidth_bytes_per_s or math.inf) * wire_ratio
                     for link in links)
        line = min(rates)
        if shape == "compressed":
            line = min(line, COMPRESS_BYTES_PER_S)
        lat_total = sum(tier.latency_s + tier.jitter_s for tier in tiers)
        if shape == "staged":
            per_item = lat_total + sum(l.rtt_s * (1.0 + l.loss_rate)
                                       for l in links)
        else:
            per_item = lat_total + sum(l.rtt_s * l.loss_rate for l in links)
        workers = 1 if ordered else max_workers
        worker_rate = item_bytes / (item_bytes / line + per_item)
        rate = min(line, workers * worker_rate)
        if shape in ("windowed-staged", "compressed"):
            cap_bytes = min(tier.capacity_bytes for tier in tiers)
            for link in links:
                if link.rtt_s <= 0:
                    continue
                bdp_eff = link.bdp_bytes() * (1.0 + link.loss_rate)
                wire_item = item_bytes / wire_ratio
                win = bdp_eff * WINDOW_HEADROOM
                if wire_item * 4 > bdp_eff:
                    win = (bdp_eff + wire_item) * WINDOW_HEADROOM
                if math.isfinite(cap_bytes):
                    win = min(win, cap_bytes)
                if max_window_bytes is not None:
                    win = min(win, float(max_window_bytes))
                rate = min(rate, wire_ratio * win
                           / (link.rtt_s * (1.0 + link.loss_rate)))
        if checksum:
            # the staged digest overlaps transit but still ceilings the
            # pipeline — §3.4's integrity budget, shape-priced
            rate = min(rate, digest_rate)
    if rate_cap is not None:
        rate = min(rate, rate_cap)
    if target is not None:
        rate = min(rate, target)
    return rate


def _score_paths(basin: DrainageBasin,
                 dist: Sequence[tuple[float, float]], *,
                 checksum: bool, digest_rate: float, ordered: bool,
                 max_workers: int, max_window_bytes: WindowClamp,
                 rate_cap: Optional[float],
                 compressible: bool) -> dict[str, float]:
    """Candidate shape -> modeled end-to-end bytes/s over the item-size
    distribution (byte-weighted harmonic mean: the rate at which the MIX
    moves, so a small-file storm's per-item latency prices honestly
    instead of hiding behind the mean size).  Branching basins score each
    root->sink path at its conservation-allocated share and sum."""
    candidates = [c for c in PATH_CHOICES
                  if compressible or c != "compressed"]
    if basin.is_linear:
        paths = [tuple(t.name for t in basin.tiers)]
        subs = {paths[0]: basin}
        targets: dict = {paths[0]: None}
        ids = [paths[0][-1]]
    else:
        paths = basin.paths()
        subs = {p: basin.path_basin(p) for p in paths}
        targets = basin.branch_rates()
        ids = _branch_ids(paths)
    scores: dict[str, float] = {}
    for cand in candidates:
        total = 0.0
        for bid, p in zip(ids, paths):
            clamp = _branch_window_clamp(max_window_bytes, bid)
            total_bytes = sum(b * w for b, w in dist)
            total_time = sum(
                b * w / _shape_rate(subs[p], cand, b, checksum=checksum,
                                    digest_rate=digest_rate,
                                    ordered=ordered,
                                    max_workers=max_workers,
                                    max_window_bytes=clamp,
                                    rate_cap=rate_cap,
                                    target=targets[p])
                for b, w in dist)
            total += total_bytes / total_time
        scores[cand] = total
    return scores


#: deterministic tie-break for equal scores: the historical full shape
#: first, then the cheaper shapes — a tie must never flip behaviour away
#: from what an un-priced plan would have built
_PATH_PREFERENCE = {"windowed-staged": 0, "staged": 1, "compressed": 2,
                    "direct": 3}


def _choose_path(scores: Mapping[str, float], *,
                 incumbent: Optional[str] = None,
                 margin: float = 1.0) -> str:
    """Highest-scoring candidate; with an ``incumbent`` (online
    revision), the challenger must win by ``margin`` or the running shape
    stands — a live rebuild is not free, and near-ties would flap."""
    best = max(scores, key=lambda k: (scores[k], -_PATH_PREFERENCE[k]))
    if (incumbent is not None and incumbent in scores
            and scores[best] <= scores[incumbent] * margin):
        return incumbent
    return best


def plan_transfer(
    basin: DrainageBasin,
    item_bytes: float,
    *,
    stages: Sequence[str] = ("stage",),
    checksum: bool = False,
    ordered: bool = False,
    max_workers: int = MAX_WORKERS,
    max_capacity: int = MAX_CAPACITY,
    max_window_bytes: WindowClamp = None,
    batch_items: Optional[object] = None,
    checksum_placement: str = "host",
    host_digest_bytes_per_s: float = HOST_DIGEST_BYTES_PER_S,
    accel_digest_bytes_per_s: float = ACCEL_DIGEST_BYTES_PER_S,
    rate_cap_bytes_per_s: Optional[float] = None,
    path: Optional[str] = None,
    item_bytes_dist: Optional[object] = None,
    compressible: bool = False,
    fault_priors: Optional[Mapping[str, float]] = None,
) -> TransferPlan:
    """Derive per-hop staging parameters from the basin model.

    ``stages`` names the hops the consumer will run (one
    :class:`~repro.core.staging.Stage` each); each root->sink path is
    split evenly across them.  ``ordered=True`` pins every hop to one
    worker — required when item order must survive the transfer (training
    batches, decode token streams); buffer depth still comes from the
    model, so jitter absorption is preserved.

    Hops whose segment crosses a latency-bearing link are **windowed**:
    ``HopPlan.window_bytes`` is sized from the link's BDP (with
    :data:`WINDOW_HEADROOM`) and executed by a
    :class:`~repro.core.staging.WindowedStage`.  ``max_window_bytes``
    models the host's socket/stream-buffer limit (§3.2): a clamp below
    BDP pins delivery at ``window / RTT`` — the plan keeps promising the
    line rate so the shortfall surfaces as a window-bound verdict.  A
    mapping ``branch_id -> bytes`` clamps per branch (two WAN branches
    behind differently configured hosts plan — and get diagnosed —
    independently); on a linear basin the branch id is the sink tier's
    name.  A lossy link (``Link.loss_rate > 0``) plans a window deepened
    by ``(1 + loss_rate)`` so retransmit round trips don't drain the
    pipe, and any burst-capacity clamp drops the hop's promise by the
    same factor.

    On a branching basin the returned plan carries one
    :class:`BranchPlan` per root->sink path, each sized against its
    conservation-allocated rate share; ``planned_bytes_per_s`` is the
    aggregate and ``weight`` the traffic share per branch.

    ``batch_items`` selects the zero-copy slab path: ``None`` (default)
    keeps every hop per-item, ``"auto"`` sizes slabs toward
    :data:`SLAB_TARGET_BYTES`, an int pins the slab.  Ordered transfers
    stay per-item regardless.  ``checksum_placement`` charges the stream
    digest (§3.4's integrity budget) to the right compute resource:
    ``"host"`` models the staging CPU's hash rate
    (``host_digest_bytes_per_s``) on the checksum hop — which can pin it,
    the **host-compute-bound** misconfiguration of "Demystifying the
    Performance of Data Transfers" — while ``"accel"`` charges the
    batched Pallas digest kernel's rate (``accel_digest_bytes_per_s``),
    taking integrity off the host's critical path.

    ``rate_cap_bytes_per_s`` is an arbiter grant (see
    :mod:`repro.core.fleet`): every hop is sized against
    ``min(line rate, grant)``, windowed hops get ``grant x RTT`` windows
    (the credit clock enforces the share on a link the transfer does not
    own), the promise becomes the grant, and :func:`replan` will not read
    share-shaped stalls on a hop still delivering its grant as a degraded
    tier.  ``None`` (default) plans as the basin's sole occupant.

    ``path`` engages the decision engine (§3.6): ``"auto"`` prices every
    candidate shape in :data:`PATH_CHOICES` over the basin, integrity
    placement, and item-size distribution, and parameterizes the hops for
    the winner (recorded as :attr:`TransferPlan.path`, candidates in
    :attr:`TransferPlan.path_scores`; :func:`replan` may later flip it —
    the **path-revised** verdict); a concrete shape name forces it;
    ``None`` (default) keeps the historical derivation bit for bit.
    ``item_bytes_dist`` is an optional histogram (mapping or pairs of
    ``bytes -> weight``) the candidates are priced over — a small-file
    storm prices per-item latency honestly instead of at the mean.
    ``compressible=True`` vouches the payload survives the int8 wire
    transform, making the compressed candidate enumerable.
    ``fault_priors`` (element -> observed transient-fault rate) prices
    each hop's ``retry_budget``/``backoff_base_s``; absent elements keep
    the cheap default posture.
    """
    if item_bytes <= 0:
        raise ValueError("item_bytes must be > 0")
    if rate_cap_bytes_per_s is not None and rate_cap_bytes_per_s <= 0:
        raise ValueError("rate_cap_bytes_per_s must be > 0 or None")
    if not stages:
        raise ValueError("need at least one stage name")
    if checksum_placement not in ("host", "accel"):
        raise ValueError(
            f"checksum_placement must be 'host' or 'accel', "
            f"got {checksum_placement!r}")
    batch = _resolve_batch(batch_items, item_bytes)
    digest_rate = (host_digest_bytes_per_s if checksum_placement == "host"
                   else accel_digest_bytes_per_s)

    # -- path decision (§3.6): price the candidate shapes, pick one ----------
    compressible = bool(compressible) or path == "compressed"
    dist = _resolve_dist(item_bytes_dist, item_bytes)
    path_scores: dict[str, float] = {}
    if path is not None:
        if path != "auto" and path not in PATH_CHOICES:
            raise ValueError(f"path must be 'auto' or one of {PATH_CHOICES},"
                             f" got {path!r}")
        path_scores = _score_paths(
            basin, dist, checksum=checksum, digest_rate=digest_rate,
            ordered=ordered, max_workers=max_workers,
            max_window_bytes=max_window_bytes,
            rate_cap=rate_cap_bytes_per_s, compressible=compressible)
        shape = _choose_path(path_scores) if path == "auto" else path
    else:
        shape = "windowed-staged"
    wire_ratio = COMPRESS_WIRE_RATIO if shape == "compressed" else 1.0

    def _label(all_hops: Sequence[HopPlan]) -> str:
        # legacy derivations label what they built without pricing it
        if path is not None:
            return shape
        return ("windowed-staged"
                if any(h.window_bytes > 0 for h in all_hops) else "staged")

    def _compressed_target(sub: DrainageBasin,
                           base: Optional[float]) -> Optional[float]:
        # the compressed shape's line rate: links carry the int8 form
        # (wire bytes / ratio), the whole path ceilings at the quantize
        # kernel's service rate
        if shape != "compressed":
            return base
        rates = [t.bandwidth_bytes_per_s for t in sub.tiers]
        rates.extend((l.bandwidth_bytes_per_s or math.inf) * wire_ratio
                     for l in sub.links)
        boosted = min(min(rates), COMPRESS_BYTES_PER_S)
        return boosted if base is None else min(base, boosted)

    if basin.is_linear:
        hops, headroom, planned = _plan_path(
            basin, item_bytes, stages, ordered, max_workers, max_capacity,
            target=_compressed_target(basin, None),
            max_window_bytes=_branch_window_clamp(
                max_window_bytes, basin.tiers[-1].name),
            batch_items=batch, rate_cap=rate_cap_bytes_per_s,
            shape=shape, wire_ratio=wire_ratio)
        if fault_priors:
            _stamp_retry_budgets(hops, fault_priors)
        if rate_cap_bytes_per_s is not None:
            planned = min(planned, rate_cap_bytes_per_s)
        checksum_index = None
        if checksum:
            # integrity rides the hop with the most headroom over the plan
            checksum_index = max(range(len(hops)), key=lambda i: headroom[i])
            # ... and that hop is charged the digest service rate of the
            # placement, so replan can tell "the hash pinned the hop"
            # (host-compute-bound) apart from a slow tier
            hops[checksum_index] = dataclasses.replace(
                hops[checksum_index], digest_bytes_per_s=digest_rate)
        tier_path = tuple(t.name for t in basin.tiers)
        branch = BranchPlan(branch_id=tier_path[-1], path=tier_path,
                            hops=hops,
                            rate_bytes_per_s=planned, weight=1.0,
                            private_tiers=tier_path)
        return TransferPlan(hops=hops, item_bytes=float(item_bytes),
                            planned_bytes_per_s=planned,
                            checksum_index=checksum_index, basin=basin,
                            ordered=ordered, branches=[branch],
                            max_window_bytes=max_window_bytes,
                            checksum_placement=checksum_placement,
                            batch_policy=batch_items,
                            rate_cap_bytes_per_s=rate_cap_bytes_per_s,
                            host_digest_bytes_per_s=host_digest_bytes_per_s,
                            accel_digest_bytes_per_s=accel_digest_bytes_per_s,
                            path=_label(hops), path_policy=path,
                            path_scores=path_scores,
                            item_bytes_dist=(dist if item_bytes_dist
                                             is not None else None),
                            compressible=compressible,
                            fault_priors=(dict(fault_priors)
                                          if fault_priors else None))

    # -- branching basin: one plan per root->sink path -----------------------
    paths = basin.paths()
    rates = basin.branch_rates()
    # an arbiter grant below the aggregate scales every branch's share
    # proportionally — conservation INSIDE the plan is branch_rates' job,
    # conservation ACROSS plans is the grant's
    cap_scale = 1.0
    if rate_cap_bytes_per_s is not None:
        agg = sum(rates.values())
        if agg > rate_cap_bytes_per_s > 0:
            cap_scale = rate_cap_bytes_per_s / agg
    ids = _branch_ids(paths)
    crossing = {t.name: sum(1 for p in paths if t.name in p)
                for t in basin.tiers}
    branches: list[BranchPlan] = []
    for bid, tier_path in zip(ids, paths):
        sub = basin.path_basin(tier_path)
        hops, _, planned = _plan_path(
            sub, item_bytes, stages, ordered, max_workers, max_capacity,
            target=_compressed_target(sub, rates[tier_path] * cap_scale),
            max_window_bytes=_branch_window_clamp(max_window_bytes, bid),
            batch_items=batch,
            rate_cap=None if rate_cap_bytes_per_s is None
            else rates[tier_path] * cap_scale,
            shape=shape, wire_ratio=wire_ratio)
        if fault_priors:
            _stamp_retry_budgets(hops, fault_priors)
        branches.append(BranchPlan(
            branch_id=bid, path=tier_path, hops=hops,
            rate_bytes_per_s=planned, weight=0.0,
            private_tiers=tuple(n for n in tier_path if crossing[n] == 1)))
    aggregate = sum(b.rate_bytes_per_s for b in branches)
    for b in branches:
        b.weight = (b.rate_bytes_per_s / aggregate) if aggregate > 0 \
            else 1.0 / len(branches)
    primary = max(branches, key=lambda b: b.rate_bytes_per_s)
    return TransferPlan(hops=primary.hops, item_bytes=float(item_bytes),
                        planned_bytes_per_s=aggregate,
                        checksum_index=None, basin=basin,
                        ordered=ordered, branches=branches,
                        checksum_at_split=bool(checksum),
                        max_window_bytes=max_window_bytes,
                        checksum_placement=checksum_placement,
                        batch_policy=batch_items,
                        rate_cap_bytes_per_s=rate_cap_bytes_per_s,
                        host_digest_bytes_per_s=host_digest_bytes_per_s,
                        accel_digest_bytes_per_s=accel_digest_bytes_per_s,
                        path=_label([h for b in branches for h in b.hops]),
                        path_policy=path, path_scores=path_scores,
                        item_bytes_dist=(dist if item_bytes_dist
                                         is not None else None),
                        compressible=compressible,
                        fault_priors=(dict(fault_priors)
                                      if fault_priors else None))


# ---------------------------------------------------------------------------
# Adaptive re-planning: hypothesis -> change -> measure, made mechanical
# ---------------------------------------------------------------------------

#: a hop is considered stalled when this fraction of its worker-time was
#: spent waiting (below it, the measurement is noise)
STALL_THRESHOLD = 0.1

#: minimum service-time samples before a regime diagnosis is attempted
#: (fewer and the dispersion statistic is noise)
MIN_DIAGNOSIS_SAMPLES = 8

#: intake-ratio severity required for the sample-free ``culprit-slow``
#: verdict: the flagged branch must be moving at no more than half the
#: fastest sibling's pace (or backpressuring the split node at least
#: half the window).  Milder flags still shift weight and estimates, but
#: persistent diagnosis text demands more than scheduling-phase noise.
CULPRIT_SEVERITY = 0.5

#: service-sample dispersion — (p90 - p10) / median — above which a
#: stalled side reads as latency/jitter-bound; at or below it the side is
#: a steadily saturated pipe (bandwidth-bound).  A stochastic per-item
#: latency spreads the samples; a saturated pipe serves every item in
#: ~item_bytes/true_bw with near-zero spread.
LATENCY_DISPERSION = 0.75

#: a window-stalled hop is **window-bound** only when its delivered rate
#: actually sits at the window ceiling — within this factor of
#: ``window / RTT`` (§3.2's signature: throughput pinned by credit, not
#: by the pipe).  A hop that window-stalls yet delivers far above the
#: ceiling is mid-transition noise, not a pinned link.
WINDOW_PIN_SLACK = 1.5


def _percentiles(sorted_samples: Sequence[float]
                 ) -> tuple[float, float, float]:
    """(p10, median, p90) of an already-sorted sample list."""
    n = len(sorted_samples)
    return (sorted_samples[int(0.1 * (n - 1))],
            sorted_samples[n // 2],
            sorted_samples[int(0.9 * (n - 1))])


def diagnose_service(samples: Sequence[float], *,
                     workers: int = 1) -> Optional[str]:
    """Classify a stalled side's regime from its per-item service times.

    Returns ``"latency"`` (high-dispersion samples: stochastic per-item
    latency dominates — more concurrency is the remedy), ``"bandwidth"``
    (tight samples: the pipe is steadily saturated — accept the lower
    rate), or ``None`` when there are too few samples to say.

    ``workers`` widens the dispersion threshold for samples taken by a
    pool sharing one pipe: N workers on a saturated pipe see per-item
    completions spread across ``[1x .. Nx]`` the transmit time (queueing
    phase, not stochastic latency), so what counts as "dispersed" must
    scale with the pool size.
    """
    if len(samples) < MIN_DIAGNOSIS_SAMPLES:
        return None
    s = sorted(samples)
    p10, med, p90 = _percentiles(s)
    if med <= 0:
        return None
    threshold = LATENCY_DISPERSION + 0.5 * (max(1, workers) - 1)
    return "latency" if (p90 - p10) / med > threshold else "bandwidth"


@dataclasses.dataclass
class _Evidence:
    """One branch-hop's observed limitation, before attribution."""

    branch: BranchPlan
    hop: HopPlan
    report: StageReport
    up_limited: bool
    busy: bool                  # the busy-hop rule fired (no stalls)
    candidate_tier: str         # tier the raw stall accounting implicates
    #: samples were taken by a worker pool sharing one saturated pipe
    #: (dispatcher-fed culprit branch) — regime diagnosis must widen its
    #: dispersion threshold by the pool size
    pipe_shared: bool = False
    #: the hop was pinned at ~window/RTT with window-stall evidence — a
    #: transport-credit limitation, not a tier-estimate error
    window: bool = False
    #: observed ACK round trip deviating from the planned ``rtt_s`` (0 =
    #: no deviation): a route change, not a window misconfiguration — the
    #: remedy is revising the link's RTT (and re-sizing the window to the
    #: new BDP), never raising a clamp that was correct
    rtt_revised: float = 0.0
    #: observed retransmit fraction when it deviates from the modeled
    #: ``HopPlan.loss_rate`` (None = consistent with the model); drives
    #: the loss-bound verdict and silent loss decay
    loss: Optional[float] = None
    #: the checksum hop was pinned at ~its modeled digest rate with no
    #: stall on any side — the integrity budget (§3.4) is the limiter,
    #: not any tier; the remedy is offloading the digest, not touching
    #: estimates or workers
    compute: bool = False
    #: fraction of the hop's worker-time spent in retry backoff (> 0 =
    #: the hop paid its retry budget against a faulting element).  The
    #: retry counter is the stage's own first-hand telemetry; letting
    #: the backoff-inflated service samples reach the dispersion test
    #: would misread a flapping link as latency-bound, so this verdict
    #: is collected BEFORE the stall classifiers.
    faulted: float = 0.0


def _collect_evidence(plan: TransferPlan,
                      reports: Sequence[StageReport],
                      culprits: frozenset[str],
                      has_intake: bool) -> list[_Evidence]:
    """Per-branch-hop limitation evidence.

    Two regimes.  With split-node intake data (``has_intake`` — the
    parallel mover), per-worker stall accounting is phase noise across
    competing branch pipelines; evidence reduces to the two robust
    signals: a branch the split node singled out (``culprits``) that also
    underdelivers over its *active* window is busy on its own channel —
    everything else is a shadow of the culprit and carries no evidence.
    Without intake data (a linear plan, or fan-in branches that own their
    sources), the stall/busy classification is first-hand, as pre-DAG."""
    by_name = {r.name: r for r in reports}
    multipath = plan.is_multipath
    out: list[_Evidence] = []
    for branch in plan.branches:
        for hop in branch.hops:
            key = f"{branch.branch_id}/{hop.name}" if multipath else hop.name
            rep = by_name.get(key)
            if rep is None and multipath:
                rep = by_name.get(hop.name)
            if rep is None or rep.elapsed_s <= 0:
                continue
            if rep.throughput_bytes_per_s <= 0:
                continue
            # rate over the stage's *active* window: a branch that
            # finished its share early and idled behind a slow sibling
            # must not read that tail as underdelivery
            active = rep.active_s if rep.active_s > 0 else rep.elapsed_s
            active_rate = rep.bytes / active if active > 0 else 0.0
            underdelivered = (active_rate
                              < hop.rate_bytes_per_s
                              * (1.0 - STALL_THRESHOLD))
            # RTT-revision check FIRST — before window-bound can fire.
            # The observed ACK spacing is the hop's own first-hand
            # telemetry: when it deviates from the planned rtt_s, the
            # ROUTE changed, and every downstream symptom (window stall,
            # pinned delivery) is a consequence of sizing the window for
            # the wrong round trip.  Diagnosing window-bound here would
            # prescribe the wrong remedy (lift a clamp that was never
            # wrong) — §3.2's misdiagnosis family, done right.
            if (hop.window_bytes > 0 and hop.rtt_s > 0
                    and rep.acks >= MIN_RTT_SAMPLES):
                rtt_obs = rep.rtt_estimate_s
                if (rtt_obs > 0 and abs(rtt_obs - hop.rtt_s)
                        > RTT_REVISION_TOLERANCE * hop.rtt_s):
                    out.append(_Evidence(branch=branch, hop=hop, report=rep,
                                         up_limited=True, busy=False,
                                         candidate_tier=hop.up_tier,
                                         rtt_revised=rtt_obs))
                    continue
            # loss check, second: a hop paying retransmit round trips
            # beyond what the plan modeled is loss-bound.  The
            # retransmit counter is the channel's own first-hand
            # telemetry, so no stall-ledger corroboration is required:
            # depending on pool depth the unmodeled round trips surface
            # either as window stalls (deep pipes) or as serialized
            # service time inside each worker (shallow pools), and
            # demanding one signature would let the other collapse into
            # a bandwidth-bound misdiagnosis — the §3.2 family again.
            # Either way the remedy is the same: size the window AND the
            # pool for the observed loss regime, not for any host clamp.
            loss_obs = (rep.retransmits / rep.items
                        if rep.items > 0 else 0.0)
            worker_time = rep.elapsed_s * hop.workers
            if (hop.window_bytes > 0 and hop.rtt_s > 0
                    and rep.items >= MIN_DIAGNOSIS_SAMPLES
                    and loss_obs >= LOSS_RATE_THRESHOLD
                    and loss_obs > hop.loss_rate * 1.2
                    and underdelivered):
                out.append(_Evidence(branch=branch, hop=hop, report=rep,
                                     up_limited=True, busy=False,
                                     candidate_tier=hop.up_tier,
                                     loss=loss_obs))
                continue
            # silent loss decay: a hop modeled lossy that stopped losing
            # revises the estimate back down (shallower window next
            # derivation) — quietly, no verdict string
            if (hop.window_bytes > 0 and hop.loss_rate > 0
                    and rep.items >= MIN_DIAGNOSIS_SAMPLES
                    and loss_obs < hop.loss_rate * 0.5):
                out.append(_Evidence(branch=branch, hop=hop, report=rep,
                                     up_limited=True, busy=False,
                                     candidate_tier=hop.up_tier,
                                     loss=loss_obs))
                continue
            # window-bound check next, in BOTH regimes: the ACK ledger is
            # the stage's own first-hand accounting (never phase noise
            # across competing branches), and a credit-pinned hop must not
            # fall through to the busy-hop rule — per-worker time parked
            # on the window is neither a stall side nor a slow service
            if (hop.window_bytes > 0 and hop.rtt_s > 0 and worker_time > 0
                    and rep.stall_window_s / worker_time >= STALL_THRESHOLD
                    and underdelivered
                    and active_rate <= WINDOW_PIN_SLACK
                    * hop.window_bytes / hop.rtt_s):
                out.append(_Evidence(branch=branch, hop=hop, report=rep,
                                     up_limited=True, busy=False,
                                     candidate_tier=hop.up_tier,
                                     window=True))
                continue
            # host-compute-bound check, second (also first-hand, also in
            # both regimes): the checksum hop, stalled on NO side yet
            # delivering at its modeled digest ceiling, is pinned by the
            # integrity budget — but only when the model itself puts that
            # ceiling below the hop's promise (a host-placed digest on a
            # fast path; an accelerator-placed digest's ceiling sits far
            # above line rate and can never bind)
            r_up = rep.stall_up_s / worker_time if worker_time > 0 else 0.0
            r_down = (rep.stall_down_s / worker_time
                      if worker_time > 0 else 0.0)
            r_win = (rep.stall_window_s / worker_time
                     if worker_time > 0 else 0.0)
            if (hop.digest_bytes_per_s > 0 and underdelivered
                    and hop.digest_bytes_per_s
                    < hop.rate_bytes_per_s * (1.0 - STALL_THRESHOLD)
                    and max(r_up, r_down, r_win) < STALL_THRESHOLD
                    and active_rate
                    <= DIGEST_PIN_SLACK * hop.digest_bytes_per_s):
                out.append(_Evidence(branch=branch, hop=hop, report=rep,
                                     up_limited=True, busy=True,
                                     candidate_tier=hop.up_tier,
                                     compute=True))
                continue
            # fault-degraded check, BEFORE the stall classifiers: a hop
            # that paid real worker-time in retry backoff (its own retry
            # counter — first-hand, never phase noise) and underdelivered
            # is limited by the faulting element, not by any estimate.
            # The backoff intervals inflate the per-item service samples,
            # so falling through would misdiagnose a flapping link as
            # latency-bound and prescribe MORE workers into the fault —
            # the §3.2 misdiagnosis family, robustness edition.  Remedy:
            # lower the hop's promise honestly and re-level traffic.
            retry_frac = (rep.retry_wait_s / worker_time
                          if worker_time > 0 else 0.0)
            if (rep.retries > 0 and underdelivered
                    and retry_frac >= STALL_THRESHOLD):
                out.append(_Evidence(branch=branch, hop=hop, report=rep,
                                     up_limited=True, busy=False,
                                     candidate_tier=hop.up_tier,
                                     faulted=retry_frac))
                continue
            if has_intake and multipath:
                if branch.branch_id not in culprits or not underdelivered:
                    continue
                out.append(_Evidence(branch=branch, hop=hop, report=rep,
                                     up_limited=True, busy=True,
                                     candidate_tier=hop.up_tier,
                                     pipe_shared=True))
                continue
            # arbiter-capped gate: a fleet member that is DELIVERING its
            # granted share necessarily waits whenever peers occupy the
            # rest of the pipe — those stalls are the arbiter at work,
            # not a degraded tier, and letting them fall through to the
            # stall ledger would misdiagnose every well-behaved tenant
            # as bandwidth-bound (the §3.2 misdiagnosis family, fleet
            # edition).  A capped hop below its grant is still evidence.
            if plan.rate_cap_bytes_per_s is not None and not underdelivered:
                continue
            busy = False
            if max(r_up, r_down) >= STALL_THRESHOLD:
                # the side we mostly waited on is the side that limited us
                up_limited = r_up >= r_down
            elif (len(rep.service_up_s) >= MIN_DIAGNOSIS_SAMPLES
                  and underdelivered):
                # the busy-hop case: no waiting on either side, yet the hop
                # underdelivered against its own planned rate — its per-item
                # acquisition service (pull + transform, the modeled upstream
                # tier) is slower than planned; the samples say which regime
                up_limited = True
                busy = True
            else:
                continue
            out.append(_Evidence(
                branch=branch, hop=hop, report=rep, up_limited=up_limited,
                busy=busy,
                candidate_tier=hop.up_tier if up_limited else hop.down_tier))
    return out


def _intake_culprits(plan: TransferPlan,
                     intake_ratio: Optional[Mapping[str, float]]
                     ) -> frozenset[str]:
    """Branches the split node's backpressure singles out as slow.

    The parallel mover measures, per branch, the fraction of the segment
    its dispatcher spent blocked pushing into that branch's intake queue
    (§2.2: coordination through buffer state).  A backpressure ratio both
    above the stall threshold and well above the *least* backpressured
    sibling marks a culprit: the branch is draining its share slower than
    the split node can supply it.  When every branch backpressures alike
    (a healthy, well-fed fan-out) nobody is flagged — the relative test
    is what separates "this branch is slow" from "supply outruns all"."""
    if not intake_ratio or not plan.is_multipath:
        return frozenset()
    vals = [intake_ratio.get(b.branch_id, 0.0) for b in plan.branches]
    floor = min(vals)
    return frozenset(
        b.branch_id for b in plan.branches
        if intake_ratio.get(b.branch_id, 0.0) >= STALL_THRESHOLD
        and intake_ratio.get(b.branch_id, 0.0) > 2.0 * floor)


def _attributed_tier(ev: _Evidence, evidence: Sequence[_Evidence],
                     plan: TransferPlan,
                     culprits: frozenset[str],
                     has_intake: bool) -> Optional[str]:
    """Resolve one piece of evidence to the tier it actually indicts.

    Linear plans: the raw candidate, as always.  Branching plans apply
    the private-tier and corroboration rules (module docstring): evidence
    from a culprit branch (split-node backpressure singled it out) or
    busy evidence lands on the branch's private tier; stall evidence
    against a shared tier needs every sibling branch crossing that tier
    to concur, else it is a routing shadow and is dropped.  When split-
    node backpressure data exists (``has_intake``) it overrides the
    noisier per-worker accounting: with culprits flagged, only their
    evidence counts; with none flagged, busy evidence is discarded
    (underdelivery without intake asymmetry indicts the shared supply,
    never one branch)."""
    if not plan.is_multipath:
        return ev.candidate_tier
    private = (ev.branch.private_tiers[-1] if ev.branch.private_tiers
               else ev.candidate_tier)
    if has_intake:
        # evidence was pre-filtered to culprit branches that underdeliver
        # over their active window (_collect_evidence): the defect is in
        # the branch's own channel, i.e. its deepest private tier
        return private
    # no intake data (each branch owns a real source — the fan-in case):
    # per-worker accounting is first-hand evidence
    if ev.busy:
        # time went into this branch's own pull+transform — its private
        # channel.  Deepest private tier = the branch-specific element.
        return private
    tier = ev.candidate_tier
    if tier in ev.branch.private_tiers:
        return tier
    return tier if _corroborated(ev, evidence, plan, tier, culprits) else None


def _corroborated(ev: _Evidence, evidence: Sequence[_Evidence],
                  plan: TransferPlan, tier: str,
                  culprits: frozenset[str]) -> bool:
    """Shared-tier evidence holds only when every sibling branch crossing
    the tier implicates it too; a lone branch starving upstream of a
    split node is a routing shadow."""
    siblings = [b for b in plan.branches
                if b.branch_id != ev.branch.branch_id and tier in b.path]
    for sib in siblings:
        if not any(e.branch.branch_id == sib.branch_id
                   and _raw_or_private(e, culprits) == tier
                   for e in evidence):
            return False
    return True


def _raw_or_private(ev: _Evidence, culprits: frozenset[str]) -> str:
    """The tier a sibling's evidence points at, for corroboration checks."""
    if ((ev.busy or ev.branch.branch_id in culprits)
            and ev.branch.private_tiers):
        return ev.branch.private_tiers[-1]
    return ev.candidate_tier


def replan(plan: TransferPlan, reports: Sequence[StageReport], *,
           damping: float = 0.5,
           intake_ratio: Optional[Mapping[str, float]] = None
           ) -> TransferPlan:
    """Revise a plan from observed stall ratios and service-time samples.

    For each hop, the stall accounting of its :class:`StageReport` says
    which side actually limited it (``stall_up_s`` dominant: the upstream
    tier; ``stall_down_s`` dominant: the downstream tier).  The limiting
    side's per-item service-time reservoir then says *why* — and the two
    regimes get opposite remedies:

    * **latency-bound** (dispersed samples): revise the tier's
      ``latency_s``/``jitter_s`` estimates from the sample distribution;
      the rebuilt plan raises ``workers`` / deepens the buffer while the
      bandwidth estimate (and so the planned line rate) stands,
    * **bandwidth-bound** (tight samples) — or no samples at all: pull
      the tier's bandwidth estimate toward the hop's observed throughput
      and accept the reduced line rate.

    A third verdict sits above the regime split: **window-bound**.  A
    windowed hop whose delivered rate is pinned at ~``window/RTT`` with
    dominant ``stall_window_s`` is limited by transport credit, not by
    any tier — the estimates stand, and the remedy is raising the window
    (the rebuilt plan drops the ``max_window_bytes`` clamp back to
    BDP-with-headroom, and the buffers feeding the hop re-derive), never
    adding workers: a worker pool sharing an exhausted window all parks
    on the same ACK clock (§3.2).

    A fourth verdict, **host-compute-bound**, covers the §3.4 integrity
    budget: a checksum hop stalled on no side yet pinned at its modeled
    host digest rate is limited by the hash, not by any tier.  Estimates
    and workers stand; the rebuilt plan flips ``checksum_placement`` to
    ``"accel"`` so the digest rides the Pallas kernel instead of the
    staging CPU (applies from the next transfer / rebuilt pipeline — a
    stream's digest backend never switches mid-stream).

    Two channel verdicts sit above window-bound (§3.2's misdiagnosis
    family): **rtt-revised** — the hop's observed ACK spacing deviates
    from the planned ``rtt_s`` (a route change), so the link's RTT is
    revised and the rebuilt plan re-sizes the window to the new BDP; any
    window stall was a symptom of the wrong clock, and no clamp is
    lifted.  **loss-bound** — the hop paid retransmit round trips beyond
    the modeled ``loss_rate``, so the link's loss estimate is revised and
    the rebuilt plan deepens the window by ``(1 + loss)`` (and lowers any
    capacity-clamped promise honestly).  A hop modeled lossy that stopped
    losing decays the estimate back down, quietly.  Window-bound remains
    the verdict only when the ACK clock agrees with the plan and loss is
    at its modeled level — then the clamp really is the lie, and on a
    per-branch clamp only the diagnosed branch's clamp is lifted.

    A robustness verdict, **fault-degraded**, sits before the stall
    classifiers: a hop that spent at least the stall threshold of its
    worker-time in retry backoff (``StageReport.retries`` /
    ``retry_wait_s`` — the stage's own retry ledger, first-hand) and
    underdelivered is limited by a *flapping* element, not a mis-modeled
    one.  The backoff intervals inflate the per-item service samples, so
    without this ordering a flapping link would read as latency-bound
    and the remedy would pour workers into the fault.  Instead the
    faulting side's estimate is pulled toward the observed effective
    rate (backoff included) — the promise drops honestly and a branching
    plan re-levels traffic toward healthy siblings.

    On a branching plan, reports tagged ``"<branch>/<stage>"`` attribute
    per branch (private-tier + corroboration rules, module docstring),
    and the rebuilt plan re-allocates branch rates from the revised
    estimates — traffic rebalances toward healthy branches instead of
    the whole plan degrading uniformly.  ``intake_ratio`` (branch id ->
    fraction of the segment the split node spent backpressured against
    that branch's intake, supplied by the parallel mover) sharpens the
    attribution: a branch the split node singles out is a culprit and
    its evidence lands on its private tier whatever the raw stall side
    says (see :func:`_intake_culprits`).

    ``damping`` blends old estimate and observation (1.0 = trust the
    measurement outright).  Returns a new :class:`TransferPlan` built on
    the re-estimated basin, its per-hop verdicts in
    :attr:`TransferPlan.diagnosis` (surfaced by ``describe()``); the
    original plan is untouched.
    """
    if not 0.0 < damping <= 1.0:
        raise ValueError("damping must be in (0, 1]")
    est = {t.name: t.bandwidth_bytes_per_s for t in plan.basin.tiers}
    lat_est = {t.name: t.latency_s for t in plan.basin.tiers}
    jit_est = {t.name: t.jitter_s for t in plan.basin.tiers}
    # carry the most recent verdict per hop forward: a chain of online
    # replans keeps showing what was learned even after the remedy quiets
    # the stall (describe() is the operator surface)
    diagnosis: dict[str, str] = dict(plan.diagnosis)
    culprits = _intake_culprits(plan, intake_ratio)
    evidence = _collect_evidence(plan, reports, culprits,
                                 intake_ratio is not None)
    multipath = plan.is_multipath
    # -- window-bound pre-pass: transport-credit evidence never touches the
    # tier estimates — the pipe and its model are fine, the in-flight cap
    # is the lie.  The remedy is raising the window (and the buffers that
    # feed it, which the rebuilt plan re-derives), NOT adding workers:
    # N workers sharing an exhausted window all park on the same ACK clock.
    raise_window = False
    raise_branches: set[str] = set()
    # -- host-compute pre-pass, the same shape: a checksum hop pinned at
    # its modeled digest rate indicts the integrity budget's *placement*,
    # not any tier estimate.  The remedy is offloading the digest to the
    # accelerator (the rebuilt plan flips checksum_placement, lifting the
    # hop's digest ceiling to the Pallas kernel's rate); estimates stand
    # and workers do not rise — N workers sharing one host hash pipeline
    # all queue on the same core.
    offload_digest = False
    # -- channel pre-pass: RTT and loss evidence revise the LINK model
    # ("src->dst" -> field overrides applied by replace_tiers), never the
    # tier estimates — the pipe's bandwidth is fine; its round trip or
    # its loss regime changed.  The rebuilt plan re-sizes windows from
    # the revised BDP/(1+loss); for loss it ALSO staffs the pool for the
    # retransmit round trip each item now carries, and when even the
    # full pool cannot reach the line, the hop's promise drops with it —
    # honestly, instead of surviving as a perpetual fidelity gap.
    link_rtt_rev: dict[str, float] = {}
    link_loss_rev: dict[str, float] = {}
    obs_rtt: dict[str, float] = {}
    for ev in list(evidence):
        key = (f"{ev.branch.branch_id}/{ev.hop.name}" if multipath
               else ev.hop.name)
        link = (ev.hop.window_link
                or f"{ev.hop.up_tier}->{ev.hop.down_tier}")
        if ev.rtt_revised > 0:
            evidence.remove(ev)
            link_rtt_rev[link] = ((1.0 - damping) * ev.hop.rtt_s
                                  + damping * ev.rtt_revised)
            obs_rtt[link] = ev.rtt_revised
            diagnosis[key] = f"rtt-revised({link})"
        elif ev.loss is not None:
            evidence.remove(ev)
            link_loss_rev[link] = ((1.0 - damping) * ev.hop.loss_rate
                                   + damping * ev.loss)
            if ev.loss >= LOSS_RATE_THRESHOLD:
                diagnosis[key] = f"loss-bound({link})"
            # else: silent decay — the estimate shrinks, no verdict
        elif ev.window:
            evidence.remove(ev)
            raise_window = True
            raise_branches.add(ev.branch.branch_id)
            diagnosis[key] = f"window-bound({link})"
        elif ev.compute:
            evidence.remove(ev)
            offload_digest = True
            diagnosis[key] = f"host-compute-bound({ev.hop.up_tier}:digest)"
        elif ev.faulted > 0:
            # fault-degraded: the element is flapping, not mis-modeled —
            # but the retries cost real delivered bytes, so the honest
            # remedy is pulling the faulting side's estimate toward the
            # observed effective rate (backoff time included).  On a
            # branching plan the retry counter is the branch's own
            # first-hand telemetry, so the derate lands on its private
            # tier — the rebuilt plan re-levels traffic toward healthy
            # siblings instead of degrading the whole fan-out.
            evidence.remove(ev)
            tier_name = ev.hop.up_tier
            if (multipath and ev.branch.private_tiers
                    and tier_name not in ev.branch.private_tiers):
                tier_name = ev.branch.private_tiers[-1]
            rep_f = ev.report
            active = rep_f.active_s if rep_f.active_s > 0 \
                else rep_f.elapsed_s
            observed = rep_f.bytes / active if active > 0 else 0.0
            if observed > 0:
                est[tier_name] = ((1.0 - damping) * est[tier_name]
                                  + damping * observed)
            element = ev.hop.window_link or tier_name
            diagnosis[key] = f"fault-degraded({element})"
    resolved = []
    for ev in evidence:
        tier_name = _attributed_tier(ev, evidence, plan, culprits,
                                     intake_ratio is not None)
        if tier_name is not None:
            resolved.append((ev, tier_name))
    # one application per tier: corroborated shared-tier evidence arrives
    # once per branch, but each branch only saw its own traffic share —
    # the tier's effective rate is the SUM over corroborating branches,
    # applied once (N damped per-share updates would collapse a healthy
    # shared tier's estimate to ~1/N of reality)
    grouped: dict[str, list[_Evidence]] = {}
    order: list[str] = []
    for ev, tier_name in resolved:
        if multipath and tier_name not in ev.branch.private_tiers:
            key = tier_name
        else:
            key = f"{ev.branch.branch_id}\x00{ev.hop.name}\x00{tier_name}"
        if key not in grouped:
            grouped[key] = []
            order.append(key)
        grouped[key].append(ev)

    def _active_rate(e: _Evidence) -> float:
        rep = e.report
        active = rep.active_s if rep.active_s > 0 else rep.elapsed_s
        return rep.bytes / active

    for key in order:
        evs = grouped[key]
        tier_name = key if "\x00" not in key else key.split("\x00")[2]
        # one contribution per distinct report: with untagged reports (a
        # multipath plan driven through a single pipeline) the lookup
        # fallback hands every branch the SAME report, and summing or
        # pooling it once per branch would inflate the estimate N-fold
        uniq = list({id(e.report): e for e in evs}.values())
        samples = [s for e in uniq
                   for s in (e.report.service_up_s if e.up_limited
                             else e.report.service_down_s)]
        pool = max((e.hop.workers for e in evs if e.pipe_shared),
                   default=1)
        regime = diagnose_service(samples, workers=pool)
        diag_keys = [(f"{e.branch.branch_id}/{e.hop.name}" if multipath
                      else e.hop.name) for e in evs]
        if regime == "latency":
            # the pipe is fine; per-item setup cost is what we waited on.
            # median service over the modeled transmit time is the latency
            # estimate, the p10-p90 spread the jitter window.
            s = sorted(samples)
            p10, med, p90 = _percentiles(s)
            transmit = plan.item_bytes / est[tier_name]
            lat_est[tier_name] = ((1.0 - damping) * lat_est[tier_name]
                                  + damping * max(0.0, med - transmit))
            jit_est[tier_name] = ((1.0 - damping) * jit_est[tier_name]
                                  + damping * max(0.0, p90 - p10))
            for k in diag_keys:
                diagnosis[k] = f"latency-bound({tier_name})"
        else:
            # saturated (or undiagnosable): the limiting side's *effective*
            # delivery rate was the observed throughput — summed over the
            # corroborating branches' distinct reports for a shared tier,
            # and over the active window, so a parallel branch's idle
            # tail (waiting for a slower sibling) does not deflate it
            observed = sum(_active_rate(e) for e in uniq)
            est[tier_name] = ((1.0 - damping) * est[tier_name]
                              + damping * observed)
            if regime == "bandwidth":
                for k in diag_keys:
                    diagnosis[k] = f"bandwidth-bound({tier_name})"
            elif any(
                e.pipe_shared
                and (intake_ratio or {}).get(e.branch.branch_id, 0.0)
                >= CULPRIT_SEVERITY
                for e in evs
            ):
                # sample-free culprit verdict: a steal/deal-route culprit
                # that moved fewer than MIN_DIAGNOSIS_SAMPLES items in the
                # revision window still had its estimate pulled down and
                # its weight shifted — describe() must show WHY the branch
                # lost traffic, even before the reservoir fills.  Gated on
                # a SEVERE intake signal: verdicts persist across replans,
                # so a mild phase-noise flag on a healthy fan-out must not
                # permanently taint the plan's diagnosis surface.
                for k in diag_keys:
                    diagnosis[k] = f"culprit-slow({tier_name})"

    # -- fault priors: each hop's retry ledger updates its element's
    # observed transient-fault rate (retries per item — the telemetry
    # prior the next derivation prices retry budgets from).  A hop that
    # went quiet decays its element's prior back toward the cheap
    # default posture instead of holding the deep budget forever.
    fault_priors: dict[str, float] = dict(plan.fault_priors or {})
    by_name = {r.name: r for r in reports}
    for branch in plan.branches:
        for hop in branch.hops:
            rkey = (f"{branch.branch_id}/{hop.name}" if multipath
                    else hop.name)
            rep = by_name.get(rkey)
            if rep is None and multipath:
                rep = by_name.get(hop.name)
            if rep is None or rep.items < MIN_DIAGNOSIS_SAMPLES:
                continue
            element = hop.window_link or hop.up_tier
            f_obs = rep.retries / rep.items
            if f_obs > 0:
                fault_priors[element] = ((1.0 - damping)
                                         * fault_priors.get(element, 0.0)
                                         + damping * f_obs)
            elif element in fault_priors:
                decayed = fault_priors[element] * (1.0 - damping)
                if decayed < 1e-3:
                    del fault_priors[element]
                else:
                    fault_priors[element] = decayed

    new_tiers = [dataclasses.replace(t, bandwidth_bytes_per_s=est[t.name],
                                     latency_s=lat_est[t.name],
                                     jitter_s=jit_est[t.name])
                 for t in plan.basin.tiers]
    # derived links re-derive from the revised tiers, explicit (physical)
    # links survive — replace_tiers encodes that distinction.  Channel
    # verdicts ride along as link-field overrides: a route change revises
    # the PATH an explicit link takes, so rtt/loss revisions apply even
    # to physically provisioned links.
    overrides: dict[str, dict] = {}
    for link_name, v in link_rtt_rev.items():
        overrides.setdefault(link_name, {})["rtt_s"] = v
    for link_name, v in link_loss_rev.items():
        overrides.setdefault(link_name, {})["loss_rate"] = max(0.0, v)
    new_basin = plan.basin.replace_tiers(new_tiers,
                                         link_overrides=overrides or None)
    # a window-bound verdict lifts the host clamp — for the diagnosed
    # branch only, when the clamp is per-branch: the rebuilt plan's
    # windows go back to BDP-with-headroom (and the live-swap path grows
    # the running windows without a drain).  rtt-revised / loss-bound do
    # NOT lift clamps: their windows re-size from the revised link model.
    clamp = plan.max_window_bytes
    if raise_window and clamp is not None:
        if isinstance(clamp, collections.abc.Mapping):
            clamp = {k: v for k, v in clamp.items()
                     if k not in raise_branches} or None
        else:
            clamp = None
    # -- path carry / revision: a forced path stays forced through every
    # re-derivation; an "auto" plan re-prices its candidates against the
    # REVISED basin (rtt/loss overrides, derated estimates — the very
    # evidence that contradicts the executing shape's model) and switches
    # only when a challenger clears PATH_REVISION_MARGIN over the
    # incumbent's re-scored rate — the **path-revised** verdict.
    checksum_on = plan.checksum_index is not None or plan.checksum_at_split
    revised_placement = ("accel" if offload_digest
                         else plan.checksum_placement)
    path_arg = plan.path_policy
    if plan.path_policy == "auto":
        digest_rate = (plan.host_digest_bytes_per_s
                       if revised_placement == "host"
                       else plan.accel_digest_bytes_per_s)
        scores = _score_paths(
            new_basin, plan.item_bytes_dist or ((plan.item_bytes, 1.0),),
            checksum=checksum_on, digest_rate=digest_rate,
            ordered=plan.ordered, max_workers=MAX_WORKERS,
            max_window_bytes=clamp, rate_cap=plan.rate_cap_bytes_per_s,
            compressible=plan.compressible)
        path_arg = _choose_path(scores, incumbent=plan.path,
                                margin=PATH_REVISION_MARGIN)
    revised = plan_transfer(
        new_basin, plan.item_bytes, stages=plan.stages,
        checksum=checksum_on,
        ordered=plan.ordered,
        max_window_bytes=clamp,
        batch_items=plan.batch_policy,
        # a host-compute-bound verdict's remedy: the rebuilt plan carries
        # the digest on the accelerator, so the checksum hop's ceiling
        # lifts from the host hash rate to the Pallas kernel's
        checksum_placement=revised_placement,
        host_digest_bytes_per_s=plan.host_digest_bytes_per_s,
        accel_digest_bytes_per_s=plan.accel_digest_bytes_per_s,
        # the arbiter grant survives re-derivation: a revision must never
        # silently promote a fleet member back to sole-occupant sizing
        rate_cap_bytes_per_s=plan.rate_cap_bytes_per_s,
        path=path_arg,
        item_bytes_dist=plan.item_bytes_dist,
        compressible=plan.compressible,
        fault_priors=fault_priors or None)
    if plan.path_policy == "auto":
        # the re-derivation ran with the resolved choice pinned; the plan
        # stays an "auto" plan so the NEXT revision may re-choose too
        revised.path_policy = "auto"
        if revised.path != plan.path:
            diagnosis["path"] = (
                f"path-revised({plan.path}->{revised.path})")
    if obs_rtt:
        # stamp the raw observed estimate on the re-timed hops (the
        # operator surface: describe() shows rtt-est= next to the damped
        # rtt= the plan now runs under).  Hop lists may be shared between
        # plan.hops and the primary branch — dedupe by list identity.
        hop_lists = {id(revised.hops): revised.hops}
        for b in revised.branches:
            hop_lists.setdefault(id(b.hops), b.hops)
        for lst in hop_lists.values():
            for i, h in enumerate(lst):
                if h.window_link in obs_rtt:
                    lst[i] = dataclasses.replace(
                        h, rtt_estimate_s=obs_rtt[h.window_link])
    revised.diagnosis = diagnosis
    return revised
