"""TransferPlan engine — the basin model turned into staging parameters.

The paper's planning discipline (§2.3 "global tuning", §3.4 weakest-link
analysis) is that predictable line-rate movement comes from matching
buffer depth, concurrency, and integrity budget to *every* tier of the
path — not from per-workload hand tuning.  :mod:`repro.core.basin` is the
analytic model; this module is the bridge that turns a
:class:`~repro.core.basin.DrainageBasin` plus an item-size estimate into
the concrete knobs every data-moving layer needs:

* **capacity** — burst-buffer slots per hop (Little's law over the
  jitter window, double-buffered),
* **workers** — concurrent staging workers per hop (concurrency as the
  latency antidote, §3.1: enough in-flight pulls that per-item latency
  and jitter amortize away and the hop sustains the path's line rate),
* **checksum placement** — the integrity budget (§3.4) rides the hop
  with the most bandwidth headroom, so hashing overlaps transit instead
  of stretching the critical path.

Every consumer — the training-input pipeline, the checkpoint engine, the
decode token stream — builds its basin, asks :func:`plan_transfer` for a
:class:`TransferPlan`, and hands that plan to the
:class:`~repro.core.mover.UnifiedDataMover` / stage constructors.  No
layer carries hard-coded staging constants.

Adaptive re-planning (the paper's hypothesis -> change -> measure cycle,
made mechanical): observed :class:`~repro.core.staging.StageReport` stall
ratios feed back into the tier bandwidth estimates via :func:`replan`,
which returns a revised plan.  A hop that mostly *starved* (stall
upstream) reveals the upstream tier is slower than modeled; a hop that
mostly *backpressured* (stall downstream) reveals the downstream tier is.

Worked example
--------------

>>> from repro.core.basin import DrainageBasin, Tier, TierKind, GBPS
>>> basin = DrainageBasin([
...     Tier("src", TierKind.SOURCE, 10 * GBPS, latency_s=5e-3,
...          jitter_s=20e-3),                      # erratic headwaters
...     Tier("buf", TierKind.BURST_BUFFER, 100 * GBPS, latency_s=10e-6),
...     Tier("dst", TierKind.SINK, 40 * GBPS, latency_s=1e-3),
... ])
>>> plan = plan_transfer(basin, item_bytes=4 * 1024 ** 2,
...                      stages=["decode", "stage"], checksum=True)
>>> [h.workers for h in plan.hops]      # erratic source hop needs concurrency
[8, 1]
>>> [h.capacity for h in plan.hops]     # deep buffer absorbs the jitter
[12, 2]
>>> plan.checksum_index                 # hashing rides the slack hop
1
>>> plan.planned_bytes_per_s <= basin.achievable_throughput()
True

After running the transfer, feed the observed stage reports back:

>>> revised = replan(plan, stage_reports)           # doctest: +SKIP
>>> revised.hops[0].workers                         # doctest: +SKIP
8

and use ``revised`` for the next transfer — measure, adjust, repeat.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Optional, Sequence

from .basin import DrainageBasin, Link, Tier
from .staging import StageReport

#: ceiling on per-hop concurrency (a planning guard, not a tuning knob:
#: past this the GIL/thread overhead of the host path dominates)
MAX_WORKERS = 8
#: ceiling on per-hop buffer slots (bounds host memory for tiny items)
MAX_CAPACITY = 64


@dataclasses.dataclass(frozen=True)
class HopPlan:
    """Staging parameters for one hop (one :class:`~repro.core.staging.Stage`)."""

    name: str
    capacity: int               # burst-buffer slots
    workers: int                # concurrent staging workers
    up_tier: str                # tier the hop pulls from
    down_tier: str              # tier the hop delivers toward
    rate_bytes_per_s: float     # what this hop can sustain as planned


@dataclasses.dataclass
class TransferPlan:
    """A fully derived data path: per-hop parameters plus the promise
    (``planned_bytes_per_s``) the fidelity gap is measured against."""

    hops: list[HopPlan]
    item_bytes: float
    planned_bytes_per_s: float
    checksum_index: Optional[int]       # hop index carrying the digest, or None
    basin: DrainageBasin
    ordered: bool

    @property
    def stages(self) -> list[str]:
        return [h.name for h in self.hops]

    def hop_for(self, index: int, name: str | None = None) -> HopPlan:
        """Hop by stage name when it matches, else by position (extra
        stages beyond the planned hops inherit the last hop's params)."""
        if name is not None:
            for h in self.hops:
                if h.name == name:
                    return h
        return self.hops[min(index, len(self.hops) - 1)]

    @property
    def total_buffer_items(self) -> int:
        return sum(h.capacity for h in self.hops)

    def describe(self) -> str:
        hops = ", ".join(
            f"{h.name}[cap={h.capacity} w={h.workers} "
            f"{h.up_tier}->{h.down_tier}]" for h in self.hops)
        return (f"TransferPlan({hops}; planned="
                f"{self.planned_bytes_per_s / 1e6:.1f} MB/s, "
                f"checksum@{self.checksum_index})")


def _segment(tiers: Sequence[Tier], n_stages: int, j: int
             ) -> tuple[int, int]:
    """Tier-index span [lo, hi] that stage ``j`` of ``n_stages`` covers.

    Stages partition the basin path evenly; each hop pulls from its
    segment's first tier and delivers toward its last."""
    T = len(tiers)
    lo = j * (T - 1) // n_stages
    hi = (j + 1) * (T - 1) // n_stages
    hi = max(hi, lo + 1)
    return lo, min(hi, T - 1)


def _segment_rtt(basin: DrainageBasin, lo: int, hi: int) -> float:
    names = {t.name for t in basin.tiers[lo:hi + 1]}
    rtts = [l.rtt_s for l in basin.links
            if l.src in names and l.dst in names]
    return max(rtts, default=0.0)


def _raw_line_rate(basin: DrainageBasin) -> float:
    """Line rate ignoring per-item latency: min raw bandwidth over every
    tier and link.  Concurrency (workers) is how a hop reaches it despite
    latency — the paper's §3.1 latency insensitivity."""
    rates = [t.bandwidth_bytes_per_s for t in basin.tiers]
    rates.extend(l.bandwidth_bytes_per_s for l in basin.links)
    return min(rates)


def _worker_rate(up: Tier, down: Tier, item_bytes: float) -> float:
    """Sustained rate of ONE staging worker doing pull -> transform ->
    push: upstream service time (with latency + jitter) plus downstream
    delivery, serialized within the worker."""
    t = (item_bytes / up.bandwidth_bytes_per_s + up.latency_s + up.jitter_s
         + item_bytes / down.bandwidth_bytes_per_s + down.latency_s)
    return item_bytes / t


def plan_transfer(
    basin: DrainageBasin,
    item_bytes: float,
    *,
    stages: Sequence[str] = ("stage",),
    checksum: bool = False,
    ordered: bool = False,
    max_workers: int = MAX_WORKERS,
    max_capacity: int = MAX_CAPACITY,
) -> TransferPlan:
    """Derive per-hop staging parameters from the basin model.

    ``stages`` names the hops the consumer will run (one
    :class:`~repro.core.staging.Stage` each); the basin path is split
    evenly across them.  ``ordered=True`` pins every hop to one worker —
    required when item order must survive the transfer (training batches,
    decode token streams); buffer depth still comes from the model, so
    jitter absorption is preserved.
    """
    if item_bytes <= 0:
        raise ValueError("item_bytes must be > 0")
    if not stages:
        raise ValueError("need at least one stage name")
    tiers = basin.tiers
    n = len(stages)
    target = _raw_line_rate(basin)

    hops: list[HopPlan] = []
    headroom: list[float] = []          # uncapped sustainable rate per hop
    for j, name in enumerate(stages):
        lo, hi = _segment(tiers, n, j)
        up, down = tiers[lo], tiers[hi]
        rate_1 = _worker_rate(up, down, item_bytes)
        if ordered:
            workers = 1
        else:
            workers = max(1, min(max_workers, math.ceil(target / rate_1)))
        headroom.append(workers * rate_1)
        hop_rate = min(workers * rate_1, target)
        # Little's law over the stochastic window, double-buffered
        window_s = up.jitter_s + down.jitter_s + _segment_rtt(basin, lo, hi)
        need_items = math.ceil(target * window_s / item_bytes)
        capacity = max(2, workers + 1, 2 * need_items)
        capacity = min(capacity, max_capacity)
        hops.append(HopPlan(name=name, capacity=capacity, workers=workers,
                            up_tier=up.name, down_tier=down.name,
                            rate_bytes_per_s=hop_rate))

    planned = min(min(h.rate_bytes_per_s for h in hops),
                  basin.achievable_throughput())
    checksum_index = None
    if checksum:
        # integrity rides the hop with the most headroom over the plan
        checksum_index = max(range(len(hops)), key=lambda i: headroom[i])
    return TransferPlan(hops=hops, item_bytes=float(item_bytes),
                        planned_bytes_per_s=planned,
                        checksum_index=checksum_index, basin=basin,
                        ordered=ordered)


# ---------------------------------------------------------------------------
# Adaptive re-planning: hypothesis -> change -> measure, made mechanical
# ---------------------------------------------------------------------------

#: a hop is considered stalled when this fraction of its worker-time was
#: spent waiting (below it, the measurement is noise)
STALL_THRESHOLD = 0.1


def replan(plan: TransferPlan, reports: Sequence[StageReport], *,
           damping: float = 0.5) -> TransferPlan:
    """Revise a plan from observed stall ratios.

    For each hop, the stall accounting of its :class:`StageReport` says
    which side actually limited it:

    * ``stall_up_s`` dominant  -> the upstream tier delivered slower than
      modeled; pull its bandwidth estimate toward the observed rate
      (next plan raises this hop's concurrency / deepens the buffer in
      front of it),
    * ``stall_down_s`` dominant -> the downstream tier absorbed slower
      than modeled; pull its estimate down likewise.

    ``damping`` blends old estimate and observation (1.0 = trust the
    measurement outright).  Returns a new :class:`TransferPlan` built on
    the re-estimated basin; the original is untouched.
    """
    if not 0.0 < damping <= 1.0:
        raise ValueError("damping must be in (0, 1]")
    est = {t.name: t.bandwidth_bytes_per_s for t in plan.basin.tiers}
    by_name = {r.name: r for r in reports}
    for hop in plan.hops:
        rep = by_name.get(hop.name)
        if rep is None or rep.elapsed_s <= 0:
            continue
        observed = rep.throughput_bytes_per_s
        if observed <= 0:
            continue
        worker_time = rep.elapsed_s * hop.workers
        r_up = rep.stall_up_s / worker_time
        r_down = rep.stall_down_s / worker_time
        if max(r_up, r_down) < STALL_THRESHOLD:
            continue
        # the side we mostly waited on is the side that limited us: its
        # *effective* delivery rate was the hop's observed throughput
        tier_name = hop.up_tier if r_up >= r_down else hop.down_tier
        est[tier_name] = (1.0 - damping) * est[tier_name] + damping * observed

    new_tiers = [dataclasses.replace(t, bandwidth_bytes_per_s=est[t.name])
                 for t in plan.basin.tiers]
    # explicit links are physical (bandwidth + rtt) and survive; implicit
    # ones were derived from the old tier estimates and must re-derive,
    # otherwise an upward revision stays clamped at the stale link rate
    links = plan.basin.links if plan.basin.explicit_links else None
    new_basin = DrainageBasin(new_tiers, links)
    return plan_transfer(
        new_basin, plan.item_bytes, stages=plan.stages,
        checksum=plan.checksum_index is not None, ordered=plan.ordered)
