"""Cross-layer transfer telemetry — the fidelity gap as an always-on signal.

The paper's headline observable (§1) is the *fidelity gap*: provisioned
capacity vs. what the application actually achieves.  Every planned
transfer in this framework already computes it per transfer
(:class:`~repro.core.mover.TransferReport`); this module aggregates those
reports **across layers** — input pipeline, checkpoint engine, decode
stream — so one registry answers "where does the whole system leak
bandwidth", which is exactly the weakest-link question of §3.4.

Layers record under a stable name (``"input"``, ``"checkpoint"``,
``"serve"``); the training driver surfaces :meth:`TelemetryRegistry.summary`
in its step logs and the benchmark harness reads the same registry for
planned-vs-fixed comparisons.  A process-global default registry keeps
wiring trivial; tests construct their own.
"""

from __future__ import annotations

import collections
import dataclasses
import json
import os
import threading
import time
from typing import TYPE_CHECKING, Optional

if TYPE_CHECKING:          # circular-import guard (mover imports telemetry)
    from .mover import TransferReport


@dataclasses.dataclass
class LayerSummary:
    """Aggregate view of one layer's recorded transfers."""

    layer: str
    transfers: int = 0
    items: int = 0
    bytes: int = 0
    elapsed_s: float = 0.0
    worst_fidelity_gap: Optional[float] = None
    #: fault posture: transient faults retried away inside this layer's
    #: transfers, and the worker-time those backoffs consumed — a layer
    #: can meet its fidelity gate while quietly burning retry budget,
    #: and this is where that cost stays visible
    retries: int = 0
    retry_wait_s: float = 0.0

    @property
    def throughput_bytes_per_s(self) -> float:
        return self.bytes / self.elapsed_s if self.elapsed_s > 0 else 0.0


class TelemetryRegistry:
    """Thread-safe collector of :class:`TransferReport`\\ s by layer.

    Reports fold into per-layer running aggregates at record time (O(1)
    memory per layer — a long-lived server or multi-day training run
    never grows it); only the most recent ``keep_recent`` raw reports
    are retained for inspection."""

    def __init__(self, keep_recent: int = 256) -> None:
        self._lock = threading.Lock()
        self._aggregates: dict[str, LayerSummary] = {}
        self._recent: collections.deque[tuple[str, "TransferReport"]] = \
            collections.deque(maxlen=keep_recent)
        # latest fleet-arbitration snapshot (FleetArbiter.stats()):
        # aggregate granted rate + per-class weighted-fairness view of a
        # multi-tenant basin; None until an arbiter records one
        self._fleet: Optional[dict] = None

    def record_fleet(self, stats: dict) -> None:
        """Record the latest fleet arbitration snapshot (pushed by
        :class:`~repro.core.fleet.FleetArbiter` on every rebalance); it
        rides :meth:`to_json` / :meth:`append_jsonl` so JSONL trends
        cover multi-tenant runs."""
        with self._lock:
            self._fleet = dict(stats)

    def record(self, layer: str, report: "TransferReport") -> None:
        with self._lock:
            self._recent.append((layer, report))
            s = self._aggregates.setdefault(layer, LayerSummary(layer=layer))
            s.transfers += 1
            s.items += report.items
            s.bytes += report.bytes
            s.elapsed_s += report.elapsed_s
            for r in report.stage_reports:
                s.retries += r.retries
                s.retry_wait_s += r.retry_wait_s
            gap = report.fidelity_gap
            if gap is not None:
                if s.worst_fidelity_gap is None or gap > s.worst_fidelity_gap:
                    s.worst_fidelity_gap = gap

    def reports(self, layer: str | None = None) -> list["TransferReport"]:
        """The retained recent raw reports (newest last)."""
        with self._lock:
            return [r for l, r in self._recent
                    if layer is None or l == layer]

    def layers(self) -> list[str]:
        with self._lock:
            return list(self._aggregates)

    def summary(self) -> dict[str, LayerSummary]:
        """Per-layer aggregation of everything recorded so far."""
        with self._lock:
            return {layer: dataclasses.replace(s)
                    for layer, s in self._aggregates.items()}

    def worst_fidelity_gap(self) -> Optional[float]:
        """The system-wide weakest link: max gap over every layer, or
        ``None`` when no planned transfer has been recorded yet."""
        gaps = [s.worst_fidelity_gap for s in self.summary().values()
                if s.worst_fidelity_gap is not None]
        return max(gaps) if gaps else None

    def format_summary(self) -> str:
        lines = []
        for name, s in sorted(self.summary().items()):
            gap = ("n/a" if s.worst_fidelity_gap is None
                   else f"{s.worst_fidelity_gap:.3f}")
            faults = (f", {s.retries} retries "
                      f"({s.retry_wait_s:.2f}s backoff)"
                      if s.retries else "")
            lines.append(
                f"{name:>10}: {s.transfers} transfers, {s.items} items, "
                f"{s.throughput_bytes_per_s / 1e6:.1f} MB/s, "
                f"worst gap {gap}{faults}")
        with self._lock:
            fleet = self._fleet
        if fleet is not None:
            lines.append(
                f"{'fleet':>10}: {fleet.get('live', 0)} live, "
                f"{fleet.get('queued', 0)} queued, "
                f"{fleet.get('aggregate_granted_bytes_per_s', 0.0) / 1e6:.1f}"
                f" MB/s granted, "
                f"fairness {fleet.get('fairness_index', 1.0):.3f}")
        return "\n".join(lines) or "(no transfers recorded)"

    # -- serialization (the dashboard surface) --------------------------------

    def to_json(self, *, indent: Optional[int] = None) -> str:
        """Serialize the per-layer aggregates as JSON.

        The payload carries everything a dashboard needs — counters,
        elapsed, worst fidelity gap, and the derived throughput per layer.
        The recent raw-report ring is process-local detail and is not
        serialized; :meth:`from_json` restores the aggregates exactly."""
        with self._lock:
            layers = {
                name: {**dataclasses.asdict(s),
                       "throughput_bytes_per_s": s.throughput_bytes_per_s}
                for name, s in self._aggregates.items()
            }
            fleet = self._fleet
        gaps = [d["worst_fidelity_gap"] for d in layers.values()
                if d["worst_fidelity_gap"] is not None]
        payload = {"version": 1, "layers": layers,
                   "worst_fidelity_gap": max(gaps) if gaps else None}
        if fleet is not None:
            payload["fleet"] = fleet
        return json.dumps(payload, indent=indent, sort_keys=True)

    @classmethod
    def from_json(cls, text: str) -> "TelemetryRegistry":
        """Rebuild a registry (aggregates only) from :meth:`to_json` output."""
        data = json.loads(text)
        reg = cls()
        for name, d in data.get("layers", {}).items():
            reg._aggregates[name] = LayerSummary(
                layer=d.get("layer", name),
                transfers=int(d["transfers"]),
                items=int(d["items"]),
                bytes=int(d["bytes"]),
                elapsed_s=float(d["elapsed_s"]),
                worst_fidelity_gap=d.get("worst_fidelity_gap"),
                retries=int(d.get("retries", 0)),
                retry_wait_s=float(d.get("retry_wait_s", 0.0)))
        reg._fleet = data.get("fleet")
        return reg

    def dump_json(self, path: str, *, indent: Optional[int] = 2) -> None:
        """Atomically write :meth:`to_json` to ``path`` (tmp + rename), so
        a dashboard polling the file never reads a half-written dump."""
        payload = self.to_json(indent=indent)
        tmp = f"{path}.tmp"
        with open(tmp, "w") as f:
            f.write(payload)
        os.replace(tmp, path)

    def append_jsonl(self, path: str, *,
                     timestamp: Optional[float] = None) -> None:
        """Append one snapshot line to a JSONL time series.

        Where :meth:`dump_json` overwrites a point-in-time file, this
        keeps the history: one compact JSON object per flush, stamped
        with wall time, so a dashboard (or
        ``examples/telemetry_timeseries.py``) can plot per-layer rate
        trends over a run.  Aggregates are cumulative-from-start; the
        consumer differences adjacent lines for interval rates."""
        snapshot = json.loads(self.to_json())
        snapshot["ts"] = time.time() if timestamp is None else timestamp
        with open(path, "a") as f:
            f.write(json.dumps(snapshot, sort_keys=True) + "\n")

    def clear(self) -> None:
        with self._lock:
            self._aggregates.clear()
            self._recent.clear()
            self._fleet = None


_global = TelemetryRegistry()


def get_registry() -> TelemetryRegistry:
    """The process-global registry the production layers record into."""
    return _global
