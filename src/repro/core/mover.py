"""Unified data mover — one engine for every tier (the paper's zx analogue).

Paper Table 1 / section 2.1: a *single*, concurrent, scale-out data mover
manages the complete placement workflow "from source storage through
transit to destination storage", supporting bulk and streaming transfers,
with integrity built in, at every basin tier.

:class:`UnifiedDataMover` is that engine for this framework.  The same
object moves

* dataset batches        host storage  -> host burst buffer -> device feed,
* checkpoint shards      device        -> host burst buffer -> storage,
* decode token streams   device        -> host burst buffer -> client sink,

in either **bulk** mode (the dataset fully exists before the transfer
starts) or **streaming** mode (the source is still producing — transfer
overlaps production).  Integrity checksums (the paper's encryption/
checksumming budget, section 3.4) are computed *inside the staged path* so
they overlap transit instead of serializing with it.

Branching basins run through :meth:`UnifiedDataMover.parallel_transfer`:
one stage pipeline per branch of a multipath
:class:`~repro.core.planner.TransferPlan`, fed by a dispatcher that either
**splits** the stream across branches (weighted by the plan's per-branch
traffic shares — the fan-out/fan-in case) or **mirrors** every item down
every branch (the replication case: a dual-tier checkpoint, a decode
fan-out to many clients).  Branch reports come back tagged
``"<branch>/<stage>"`` so online replanning attributes a mid-transfer
stall to the one degraded branch and rebalances traffic toward the
healthy ones.

Every transfer returns a :class:`TransferReport` carrying achieved
throughput and the fidelity gap against the planned basin — making the
paper's headline metric a first-class, always-on observable.
"""

from __future__ import annotations

import dataclasses
import hashlib
import threading
import time
from typing import Any, Callable, Iterable, Iterator, Mapping, Optional, \
    Sequence

from .basin import DrainageBasin
from .burst_buffer import BufferClosed, BurstBuffer
from .planner import BranchPlan, TransferPlan, replan as _replan
from .staging import ParallelBranchPipeline, Stage, StagePipeline, \
    StageReport, _default_sizeof, iter_segments, merge_reports
from .telemetry import TelemetryRegistry


@dataclasses.dataclass
class TransferReport:
    """Outcome of one end-to-end transfer."""

    mode: str                       # "bulk" | "streaming"
    items: int
    bytes: int
    elapsed_s: float
    stage_reports: list[StageReport]
    checksum: Optional[str] = None  # hex digest over the item stream
    planned_bytes_per_s: Optional[float] = None
    #: online plan revisions applied mid-transfer (``replan_every_items``)
    replans: int = 0

    @property
    def throughput_bytes_per_s(self) -> float:
        return self.bytes / self.elapsed_s if self.elapsed_s > 0 else 0.0

    @property
    def fidelity_gap(self) -> Optional[float]:
        """1 - achieved/planned (paper section 1).  None without a plan."""
        if not self.planned_bytes_per_s:
            return None
        return 1.0 - self.throughput_bytes_per_s / self.planned_bytes_per_s

    def bottleneck_stage(self) -> Optional[StageReport]:
        if not self.stage_reports:
            return None
        return min(self.stage_reports,
                   key=lambda r: r.throughput_bytes_per_s or float("inf"))


class _StreamDigest:
    """Order-independent integrity over an item stream: XOR of per-item
    SHA-256 digests (commutative + associative), shared by the staged,
    parallel-branch, and direct paths so their checksums stay comparable.
    Thread-safe; a ``None``-mode instance is a no-op."""

    def __init__(self, enabled: bool):
        self._acc = bytearray(32) if enabled else None
        self._lock = threading.Lock()

    def add(self, item: Any) -> Any:
        if self._acc is not None:
            d = hashlib.sha256(_as_bytes(item)).digest()
            with self._lock:
                for i in range(32):
                    self._acc[i] ^= d[i]
        return item

    def hexdigest(self) -> Optional[str]:
        return bytes(self._acc).hex() if self._acc is not None else None


@dataclasses.dataclass
class MoverConfig:
    """Global tuning (paper section 2.3): one configuration effective across
    item sizes spanning orders of magnitude.  Per-transfer overrides are
    accepted by the transfer methods (the paper's hierarchical tuning)."""

    staging_capacity: int = 4       # slots per burst buffer
    staging_workers: int = 2        # concurrent movers per hop
    checksum: bool = True           # integrity over the item stream
    name: str = "zx-jax"


class UnifiedDataMover:
    """Moves item streams through a staged, buffered, instrumented path.

    Staging parameters come from (in precedence order) a
    :class:`~repro.core.planner.TransferPlan` — per-hop capacity/workers
    derived from the basin model — then per-call overrides, then the
    uniform :class:`MoverConfig` defaults.  With ``telemetry`` set, every
    :class:`TransferReport` is recorded there under ``layer``.
    """

    def __init__(self, config: MoverConfig | None = None,
                 basin: DrainageBasin | None = None,
                 plan: TransferPlan | None = None,
                 telemetry: TelemetryRegistry | None = None,
                 layer: str | None = None,
                 clock: Callable[[], float] | None = None):
        self.config = config or MoverConfig()
        self.plan = plan
        self.basin = basin or (plan.basin if plan is not None else None)
        self.telemetry = telemetry
        self.layer = layer or self.config.name
        # injectable for the deterministic simulated-basin test harness
        self._clock = clock or time.monotonic
        #: the plan the most recent transfer ended on (== its starting
        #: plan unless online replanning revised it mid-transfer)
        self.last_plan: TransferPlan | None = plan

    # -- internal ------------------------------------------------------------

    def _stage_params(
        self,
        transforms: Sequence[tuple[str, Any]],
        plan: Optional[TransferPlan],
        capacity: Optional[int],
        workers: Optional[int],
    ) -> list[tuple[int, int]]:
        """(capacity, workers) per stage: plan-derived per hop, or uniform."""
        n = max(1, len(transforms))
        if plan is not None:
            names = [name for name, _ in transforms] or ["stage"]
            hops = [plan.hop_for(i, name) for i, name in enumerate(names)]
            return [(capacity or h.capacity, workers or h.workers)
                    for h in hops]
        cap = capacity or self.config.staging_capacity
        wrk = workers or self.config.staging_workers
        return [(cap, wrk)] * n

    def _build_pipeline(
        self,
        source: Iterable[Any],
        transforms: Sequence[tuple[str, Callable[[Any], Any]]],
        params: Sequence[tuple[int, int]],
        plan: Optional[TransferPlan] = None,
    ) -> StagePipeline:
        default_name = plan.hops[0].name if plan is not None else "stage"
        stages = [
            Stage(name, capacity=cap, workers=wrk, transform=fn,
                  clock=self._clock)
            for (name, fn), (cap, wrk) in zip(transforms, params)
        ] or [Stage(default_name, capacity=params[0][0], workers=params[0][1],
                    clock=self._clock)]
        return StagePipeline(source, stages)

    def _record(self, report: TransferReport) -> TransferReport:
        if self.telemetry is not None:
            self.telemetry.record(self.layer, report)
        return report

    def _run(
        self,
        mode: str,
        source: Iterable[Any],
        sink: Callable[[Any], None],
        transforms: Sequence[tuple[str, Callable[[Any], Any]]],
        capacity: Optional[int],
        workers: Optional[int],
        checksum: Optional[bool],
        plan: Optional[TransferPlan],
        replan_every_items: int = 0,
        replan_damping: float = 0.5,
    ) -> TransferReport:
        own_plan = plan is None
        plan = plan if plan is not None else self.plan
        do_sum = self.config.checksum if checksum is None else checksum

        # order-independent integrity: concurrent staging workers may
        # deliver items out of order (see _StreamDigest)
        digest = _StreamDigest(do_sum)

        all_transforms = list(transforms)
        if do_sum:
            # checksum rides inside the staged path — overlapped, not
            # serial.  With a plan it rides the hop with the most
            # bandwidth headroom (planner.checksum_index); otherwise it
            # trails the path.
            at = len(all_transforms)
            if plan is not None and plan.checksum_index is not None:
                at = min(plan.checksum_index, at)
            all_transforms.insert(at, ("checksum", digest.add))

        # online replanning needs a plan to revise; without one the
        # transfer runs as a single segment
        chunk = replan_every_items if plan is not None else 0
        active = plan
        merged: list[StageReport] = []      # folded incrementally: bounded
        last_reports: list[StageReport] = []
        replans = 0
        items = 0
        nbytes = 0
        t0 = self._clock()
        for segment in iter_segments(iter(source), chunk):
            if last_reports:
                # buffer boundary: the previous segment fully drained, so
                # the plan can swap without dropping staged items
                # (hypothesis -> change -> measure, mid-transfer)
                revised = _replan(active, last_reports,
                                  damping=replan_damping)
                if ([(h.capacity, h.workers) for h in revised.hops]
                        != [(h.capacity, h.workers) for h in active.hops]):
                    replans += 1
                active = revised
            params = self._stage_params(all_transforms, active, capacity,
                                        workers)
            pipeline = self._build_pipeline(segment, all_transforms, params,
                                            active)
            pipeline.start()
            for item in pipeline.output.drain():
                sink(item)
                items += 1
                nbytes += _default_sizeof(item)
            pipeline.join()
            last_reports = pipeline.reports()
            merged = merge_reports([merged, last_reports])
        elapsed = self._clock() - t0
        self.last_plan = active
        if own_plan and self.plan is not None:
            # the mover owns the plan: online revisions persist to the
            # next transfer (the checkpoint engine replans across saves)
            self.plan = active

        if plan is not None:
            planned = plan.planned_bytes_per_s
        else:
            planned = self.basin.achievable_throughput() if self.basin else None
        return self._record(TransferReport(
            mode=mode,
            items=items,
            bytes=nbytes,
            elapsed_s=elapsed,
            stage_reports=merged,
            checksum=digest.hexdigest(),
            planned_bytes_per_s=planned,
            replans=replans,
        ))

    # -- public API -----------------------------------------------------------

    def bulk_transfer(
        self,
        source: Iterable[Any],
        sink: Callable[[Any], None],
        *,
        transforms: Sequence[tuple[str, Callable[[Any], Any]]] = (),
        capacity: Optional[int] = None,
        workers: Optional[int] = None,
        checksum: Optional[bool] = None,
        plan: Optional[TransferPlan] = None,
        replan_every_items: int = 0,
        replan_damping: float = 0.5,
    ) -> TransferReport:
        """Move a dataset at rest (paper section 2.2, *Bulk Transfer*).

        ``replan_every_items > 0`` makes the transfer *self-revising*: the
        path runs in segments of that many items, and at each segment
        boundary (a buffer boundary — every staged item delivered) the
        observed stall ratios and service-time samples feed
        :func:`~repro.core.planner.replan`, whose revised plan drives the
        next segment.  A mid-transfer regime shift is answered mid-transfer
        instead of at the next pipeline construction."""
        return self._run("bulk", source, sink, transforms, capacity, workers,
                         checksum, plan, replan_every_items, replan_damping)

    def streaming_transfer(
        self,
        source: Iterable[Any],
        sink: Callable[[Any], None],
        *,
        transforms: Sequence[tuple[str, Callable[[Any], Any]]] = (),
        capacity: Optional[int] = None,
        workers: Optional[int] = None,
        checksum: Optional[bool] = None,
        plan: Optional[TransferPlan] = None,
        replan_every_items: int = 0,
        replan_damping: float = 0.5,
    ) -> TransferReport:
        """Move a still-growing stream (paper section 2.2, *Streaming
        Transfer*): the source iterator may block while data is produced;
        staging overlaps production with transit, which is exactly what the
        buffer path provides.  Identical machinery, different source
        contract — the unified-mover property.  ``replan_every_items``
        revises the plan online at buffer boundaries, as in
        :meth:`bulk_transfer`."""
        return self._run("streaming", source, sink, transforms, capacity,
                         workers, checksum, plan, replan_every_items,
                         replan_damping)

    # -- parallel-branch path (DAG plans) --------------------------------------

    def _branch_pipelines(
        self,
        plan: TransferPlan,
        transforms: Sequence[tuple[str, Callable[[Any], Any]]]
        | Mapping[str, Sequence[tuple[str, Callable[[Any], Any]]]],
        capacity: Optional[int],
        workers: Optional[int],
    ) -> tuple[dict[str, BurstBuffer], ParallelBranchPipeline]:
        """Per-branch input queue + stage chain from a multipath plan."""
        queues: dict[str, BurstBuffer] = {}
        branches: list[tuple[str, StagePipeline]] = []
        for b in plan.branches:
            tf = (transforms.get(b.branch_id, ())
                  if isinstance(transforms, Mapping) else transforms)
            named = list(tf) or [(b.hops[0].name, None)]
            stages = []
            for i, (name, fn) in enumerate(named):
                hop = b.hop_for(i, name)
                stages.append(Stage(
                    name, capacity=capacity or hop.capacity,
                    workers=workers or hop.workers, transform=fn,
                    clock=self._clock))
            q = BurstBuffer(b.hops[0].capacity,
                            name=f"{b.branch_id}.inq", clock=self._clock)
            queues[b.branch_id] = q
            branches.append((b.branch_id, StagePipeline(q.drain(), stages)))
        return queues, ParallelBranchPipeline(branches, clock=self._clock,
                                              upstreams=queues)

    @staticmethod
    def _dispatch(segment: Iterator[Any], queues: dict[str, BurstBuffer],
                  branch_plans: Sequence[BranchPlan], mode: str,
                  on_item: Callable[[Any], Any]) -> Callable[[], None]:
        """The split/merge node, executable: pulls the source and routes.

        ``split``: weighted deficit round-robin over the plan's branch
        weights — deterministic routing, so a simulated run is a pure
        function of the script.  ``mirror``: every item goes down every
        branch (replication), pacing at the slowest branch's intake.
        """
        weights = {b.branch_id: max(b.weight, 0.0) for b in branch_plans}
        if sum(weights.values()) <= 0:
            weights = {bid: 1.0 for bid in weights}
        deficits = {bid: 0.0 for bid in weights}
        order = [b.branch_id for b in branch_plans]

        def run() -> None:
            try:
                for item in segment:
                    on_item(item)
                    if mode == "mirror":
                        for bid in order:
                            queues[bid].put(item)
                        continue
                    for bid in order:
                        deficits[bid] += weights[bid]
                    pick = max(order, key=lambda bid: deficits[bid])
                    deficits[pick] -= 1.0
                    queues[pick].put(item)
            except BufferClosed:
                pass
            finally:
                for q in queues.values():
                    q.close()

        return run

    def parallel_transfer(
        self,
        source: Iterable[Any],
        sink: Callable[[Any], None] | Mapping[str, Callable[[Any], None]],
        *,
        plan: Optional[TransferPlan] = None,
        mode: str = "split",
        transforms: Sequence[tuple[str, Callable[[Any], Any]]]
        | Mapping[str, Sequence[tuple[str, Callable[[Any], Any]]]] = (),
        capacity: Optional[int] = None,
        workers: Optional[int] = None,
        checksum: Optional[bool] = None,
        replan_every_items: int = 0,
        replan_damping: float = 0.5,
    ) -> TransferReport:
        """Move a stream down every branch of a multipath plan at once.

        One stage pipeline per :class:`~repro.core.planner.BranchPlan`; a
        dispatcher thread plays the split node.  ``mode="split"`` routes
        each item down exactly one branch (weighted by the plan's branch
        traffic shares — aggregate throughput is the sum over branches);
        ``mode="mirror"`` replicates every item down every branch (the
        dual-tier checkpoint / decode fan-out case — the dispatcher paces
        at the slowest branch, which is the point: a mirror is only as
        durable as its slowest copy).

        ``transforms`` applies to every branch, or a mapping
        ``branch_id -> transforms`` gives each branch its own chain (a
        mirrored save writes different directories per branch).  ``sink``
        likewise: one callable for all deliveries, or per-branch.
        Integrity (``checksum``) hashes each *source* item once at the
        split node, overlapping branch transit.

        ``replan_every_items > 0`` revises the plan at segment boundaries
        from branch-tagged reports: a degraded branch gets its verdict in
        ``plan.diagnosis["<branch>/<hop>"]`` and loses traffic share to
        healthy branches (split mode) on the next segment.  Items/bytes
        in the returned report count *deliveries* (mirror mode moves each
        item once per branch)."""
        if mode not in ("split", "mirror"):
            raise ValueError(f"unknown parallel mode {mode!r}")
        own_plan = plan is None
        plan = plan if plan is not None else self.plan
        if plan is None or not plan.branches:
            raise ValueError("parallel_transfer needs a branch-aware plan")
        do_sum = self.config.checksum if checksum is None else checksum
        digest = _StreamDigest(do_sum)

        def sink_for(bid: str) -> Callable[[Any], None]:
            if isinstance(sink, Mapping):
                return sink[bid]
            return sink

        chunk = replan_every_items
        active = plan
        merged: list[StageReport] = []
        last_reports: list[StageReport] = []
        last_intake: dict[str, float] = {}
        replans = 0
        items = 0
        nbytes = 0
        t0 = self._clock()
        for segment in iter_segments(iter(source), chunk):
            if last_reports:
                revised = _replan(active, last_reports,
                                  damping=replan_damping,
                                  intake_ratio=last_intake)
                if (self._branch_params(revised)
                        != self._branch_params(active)):
                    replans += 1
                active = revised
            queues, pbp = self._branch_pipelines(active, transforms,
                                                 capacity, workers)
            dispatch = threading.Thread(
                target=self._dispatch(segment, queues, active.branches,
                                      mode, digest.add),
                name="branch-dispatch", daemon=True)
            t_seg0 = self._clock()
            pbp.start()
            dispatch.start()
            for bid, item in pbp.output.drain():
                sink_for(bid)(item)
                items += 1
                nbytes += _default_sizeof(item)
            dispatch.join()
            pbp.join()
            t_seg = self._clock() - t_seg0
            # the split node's per-branch backpressure: the attribution
            # signal replan uses to single out a slow branch (§2.2)
            last_intake = {
                bid: (q.stats.producer_stall_s / t_seg if t_seg > 0 else 0.0)
                for bid, q in queues.items()}
            last_reports = pbp.reports()
            merged = merge_reports([merged, last_reports])
        elapsed = self._clock() - t0
        self.last_plan = active
        if own_plan and self.plan is not None:
            self.plan = active
        if mode == "mirror":
            # replication paces at the slowest branch: every branch moves
            # every item, so the honest promise is n x the weakest rate,
            # not the split-mode aggregate
            rates = [b.rate_bytes_per_s for b in plan.branches]
            planned = len(rates) * min(rates)
        else:
            planned = plan.planned_bytes_per_s
        return self._record(TransferReport(
            mode=f"parallel-{mode}",
            items=items,
            bytes=nbytes,
            elapsed_s=elapsed,
            stage_reports=merged,
            checksum=digest.hexdigest(),
            planned_bytes_per_s=planned,
            replans=replans,
        ))

    @staticmethod
    def _branch_params(plan: TransferPlan) -> list[tuple]:
        """The revision signature: staging params + routing weights."""
        return [(b.branch_id, round(b.weight, 3),
                 tuple((h.capacity, h.workers) for h in b.hops))
                for b in plan.branches]

    # -- direct (un-staged) path, for comparison -------------------------------

    def direct_transfer(
        self,
        source: Iterable[Any],
        sink: Callable[[Any], None],
        *,
        checksum: Optional[bool] = None,
    ) -> TransferReport:
        """Synchronous, un-staged copy loop — the 'aws-cli' style baseline of
        Fig. 11: every hop serializes with every other hop.  Used by
        benchmarks to quantify the staged-vs-direct fidelity delta."""
        do_sum = self.config.checksum if checksum is None else checksum
        digest = _StreamDigest(do_sum)
        items = 0
        nbytes = 0
        t0 = self._clock()
        for item in source:
            digest.add(item)                  # serial hash: the baseline
            sink(item)
            items += 1
            nbytes += _default_sizeof(item)
        elapsed = self._clock() - t0
        planned = self.basin.achievable_throughput() if self.basin else None
        return self._record(TransferReport(
            mode="direct",
            items=items,
            bytes=nbytes,
            elapsed_s=elapsed,
            stage_reports=[],
            checksum=digest.hexdigest(),
            planned_bytes_per_s=planned,
        ))


def _as_bytes(item: Any) -> bytes:
    """Stable byte view of an item for integrity hashing."""
    if isinstance(item, (bytes, bytearray)):
        return bytes(item)
    if isinstance(item, memoryview):
        return item.tobytes()
    tobytes = getattr(item, "tobytes", None)
    if tobytes is not None:
        return tobytes()
    if isinstance(item, (tuple, list)):
        return b"".join(_as_bytes(e) for e in item)
    if isinstance(item, dict):
        return b"".join(_as_bytes(item[k]) for k in sorted(item))
    return repr(item).encode()
