"""Unified data mover — one engine for every tier (the paper's zx analogue).

Paper Table 1 / section 2.1: a *single*, concurrent, scale-out data mover
manages the complete placement workflow "from source storage through
transit to destination storage", supporting bulk and streaming transfers,
with integrity built in, at every basin tier.

:class:`UnifiedDataMover` is that engine for this framework.  The same
object moves

* dataset batches        host storage  -> host burst buffer -> device feed,
* checkpoint shards      device        -> host burst buffer -> storage,
* decode token streams   device        -> host burst buffer -> client sink,

in either **bulk** mode (the dataset fully exists before the transfer
starts) or **streaming** mode (the source is still producing — transfer
overlaps production).  Integrity checksums (the paper's encryption/
checksumming budget, section 3.4) are computed *inside the staged path* so
they overlap transit instead of serializing with it.

Every transfer returns a :class:`TransferReport` carrying achieved
throughput and the fidelity gap against the planned basin — making the
paper's headline metric a first-class, always-on observable.
"""

from __future__ import annotations

import dataclasses
import hashlib
import threading
import time
from typing import Any, Callable, Iterable, Iterator, Optional, Sequence

from .basin import DrainageBasin
from .planner import TransferPlan, replan as _replan
from .staging import Stage, StagePipeline, StageReport, _default_sizeof, \
    iter_segments, merge_reports
from .telemetry import TelemetryRegistry


@dataclasses.dataclass
class TransferReport:
    """Outcome of one end-to-end transfer."""

    mode: str                       # "bulk" | "streaming"
    items: int
    bytes: int
    elapsed_s: float
    stage_reports: list[StageReport]
    checksum: Optional[str] = None  # hex digest over the item stream
    planned_bytes_per_s: Optional[float] = None
    #: online plan revisions applied mid-transfer (``replan_every_items``)
    replans: int = 0

    @property
    def throughput_bytes_per_s(self) -> float:
        return self.bytes / self.elapsed_s if self.elapsed_s > 0 else 0.0

    @property
    def fidelity_gap(self) -> Optional[float]:
        """1 - achieved/planned (paper section 1).  None without a plan."""
        if not self.planned_bytes_per_s:
            return None
        return 1.0 - self.throughput_bytes_per_s / self.planned_bytes_per_s

    def bottleneck_stage(self) -> Optional[StageReport]:
        if not self.stage_reports:
            return None
        return min(self.stage_reports,
                   key=lambda r: r.throughput_bytes_per_s or float("inf"))


@dataclasses.dataclass
class MoverConfig:
    """Global tuning (paper section 2.3): one configuration effective across
    item sizes spanning orders of magnitude.  Per-transfer overrides are
    accepted by the transfer methods (the paper's hierarchical tuning)."""

    staging_capacity: int = 4       # slots per burst buffer
    staging_workers: int = 2        # concurrent movers per hop
    checksum: bool = True           # integrity over the item stream
    name: str = "zx-jax"


class UnifiedDataMover:
    """Moves item streams through a staged, buffered, instrumented path.

    Staging parameters come from (in precedence order) a
    :class:`~repro.core.planner.TransferPlan` — per-hop capacity/workers
    derived from the basin model — then per-call overrides, then the
    uniform :class:`MoverConfig` defaults.  With ``telemetry`` set, every
    :class:`TransferReport` is recorded there under ``layer``.
    """

    def __init__(self, config: MoverConfig | None = None,
                 basin: DrainageBasin | None = None,
                 plan: TransferPlan | None = None,
                 telemetry: TelemetryRegistry | None = None,
                 layer: str | None = None,
                 clock: Callable[[], float] | None = None):
        self.config = config or MoverConfig()
        self.plan = plan
        self.basin = basin or (plan.basin if plan is not None else None)
        self.telemetry = telemetry
        self.layer = layer or self.config.name
        # injectable for the deterministic simulated-basin test harness
        self._clock = clock or time.monotonic
        #: the plan the most recent transfer ended on (== its starting
        #: plan unless online replanning revised it mid-transfer)
        self.last_plan: TransferPlan | None = plan

    # -- internal ------------------------------------------------------------

    def _stage_params(
        self,
        transforms: Sequence[tuple[str, Any]],
        plan: Optional[TransferPlan],
        capacity: Optional[int],
        workers: Optional[int],
    ) -> list[tuple[int, int]]:
        """(capacity, workers) per stage: plan-derived per hop, or uniform."""
        n = max(1, len(transforms))
        if plan is not None:
            names = [name for name, _ in transforms] or ["stage"]
            hops = [plan.hop_for(i, name) for i, name in enumerate(names)]
            return [(capacity or h.capacity, workers or h.workers)
                    for h in hops]
        cap = capacity or self.config.staging_capacity
        wrk = workers or self.config.staging_workers
        return [(cap, wrk)] * n

    def _build_pipeline(
        self,
        source: Iterable[Any],
        transforms: Sequence[tuple[str, Callable[[Any], Any]]],
        params: Sequence[tuple[int, int]],
        plan: Optional[TransferPlan] = None,
    ) -> StagePipeline:
        default_name = plan.hops[0].name if plan is not None else "stage"
        stages = [
            Stage(name, capacity=cap, workers=wrk, transform=fn,
                  clock=self._clock)
            for (name, fn), (cap, wrk) in zip(transforms, params)
        ] or [Stage(default_name, capacity=params[0][0], workers=params[0][1],
                    clock=self._clock)]
        return StagePipeline(source, stages)

    def _record(self, report: TransferReport) -> TransferReport:
        if self.telemetry is not None:
            self.telemetry.record(self.layer, report)
        return report

    def _run(
        self,
        mode: str,
        source: Iterable[Any],
        sink: Callable[[Any], None],
        transforms: Sequence[tuple[str, Callable[[Any], Any]]],
        capacity: Optional[int],
        workers: Optional[int],
        checksum: Optional[bool],
        plan: Optional[TransferPlan],
        replan_every_items: int = 0,
        replan_damping: float = 0.5,
    ) -> TransferReport:
        own_plan = plan is None
        plan = plan if plan is not None else self.plan
        do_sum = self.config.checksum if checksum is None else checksum

        # order-independent integrity: concurrent staging workers may
        # deliver items out of order, so the stream digest is the XOR of
        # per-item SHA-256 digests (commutative + associative).
        digest_acc = bytearray(32) if do_sum else None
        hash_lock = threading.Lock()

        def maybe_hash(item: Any) -> Any:
            if digest_acc is not None:
                d = hashlib.sha256(_as_bytes(item)).digest()
                with hash_lock:
                    for i in range(32):
                        digest_acc[i] ^= d[i]
            return item

        all_transforms = list(transforms)
        if do_sum:
            # checksum rides inside the staged path — overlapped, not
            # serial.  With a plan it rides the hop with the most
            # bandwidth headroom (planner.checksum_index); otherwise it
            # trails the path.
            at = len(all_transforms)
            if plan is not None and plan.checksum_index is not None:
                at = min(plan.checksum_index, at)
            all_transforms.insert(at, ("checksum", maybe_hash))

        # online replanning needs a plan to revise; without one the
        # transfer runs as a single segment
        chunk = replan_every_items if plan is not None else 0
        active = plan
        merged: list[StageReport] = []      # folded incrementally: bounded
        last_reports: list[StageReport] = []
        replans = 0
        items = 0
        nbytes = 0
        t0 = self._clock()
        for segment in iter_segments(iter(source), chunk):
            if last_reports:
                # buffer boundary: the previous segment fully drained, so
                # the plan can swap without dropping staged items
                # (hypothesis -> change -> measure, mid-transfer)
                revised = _replan(active, last_reports,
                                  damping=replan_damping)
                if ([(h.capacity, h.workers) for h in revised.hops]
                        != [(h.capacity, h.workers) for h in active.hops]):
                    replans += 1
                active = revised
            params = self._stage_params(all_transforms, active, capacity,
                                        workers)
            pipeline = self._build_pipeline(segment, all_transforms, params,
                                            active)
            pipeline.start()
            for item in pipeline.output.drain():
                sink(item)
                items += 1
                nbytes += _default_sizeof(item)
            pipeline.join()
            last_reports = pipeline.reports()
            merged = merge_reports([merged, last_reports])
        elapsed = self._clock() - t0
        self.last_plan = active
        if own_plan and self.plan is not None:
            # the mover owns the plan: online revisions persist to the
            # next transfer (the checkpoint engine replans across saves)
            self.plan = active

        if plan is not None:
            planned = plan.planned_bytes_per_s
        else:
            planned = self.basin.achievable_throughput() if self.basin else None
        return self._record(TransferReport(
            mode=mode,
            items=items,
            bytes=nbytes,
            elapsed_s=elapsed,
            stage_reports=merged,
            checksum=bytes(digest_acc).hex() if digest_acc is not None else None,
            planned_bytes_per_s=planned,
            replans=replans,
        ))

    # -- public API -----------------------------------------------------------

    def bulk_transfer(
        self,
        source: Iterable[Any],
        sink: Callable[[Any], None],
        *,
        transforms: Sequence[tuple[str, Callable[[Any], Any]]] = (),
        capacity: Optional[int] = None,
        workers: Optional[int] = None,
        checksum: Optional[bool] = None,
        plan: Optional[TransferPlan] = None,
        replan_every_items: int = 0,
        replan_damping: float = 0.5,
    ) -> TransferReport:
        """Move a dataset at rest (paper section 2.2, *Bulk Transfer*).

        ``replan_every_items > 0`` makes the transfer *self-revising*: the
        path runs in segments of that many items, and at each segment
        boundary (a buffer boundary — every staged item delivered) the
        observed stall ratios and service-time samples feed
        :func:`~repro.core.planner.replan`, whose revised plan drives the
        next segment.  A mid-transfer regime shift is answered mid-transfer
        instead of at the next pipeline construction."""
        return self._run("bulk", source, sink, transforms, capacity, workers,
                         checksum, plan, replan_every_items, replan_damping)

    def streaming_transfer(
        self,
        source: Iterable[Any],
        sink: Callable[[Any], None],
        *,
        transforms: Sequence[tuple[str, Callable[[Any], Any]]] = (),
        capacity: Optional[int] = None,
        workers: Optional[int] = None,
        checksum: Optional[bool] = None,
        plan: Optional[TransferPlan] = None,
        replan_every_items: int = 0,
        replan_damping: float = 0.5,
    ) -> TransferReport:
        """Move a still-growing stream (paper section 2.2, *Streaming
        Transfer*): the source iterator may block while data is produced;
        staging overlaps production with transit, which is exactly what the
        buffer path provides.  Identical machinery, different source
        contract — the unified-mover property.  ``replan_every_items``
        revises the plan online at buffer boundaries, as in
        :meth:`bulk_transfer`."""
        return self._run("streaming", source, sink, transforms, capacity,
                         workers, checksum, plan, replan_every_items,
                         replan_damping)

    # -- direct (un-staged) path, for comparison -------------------------------

    def direct_transfer(
        self,
        source: Iterable[Any],
        sink: Callable[[Any], None],
        *,
        checksum: Optional[bool] = None,
    ) -> TransferReport:
        """Synchronous, un-staged copy loop — the 'aws-cli' style baseline of
        Fig. 11: every hop serializes with every other hop.  Used by
        benchmarks to quantify the staged-vs-direct fidelity delta."""
        do_sum = self.config.checksum if checksum is None else checksum
        digest_acc = bytearray(32) if do_sum else None
        items = 0
        nbytes = 0
        t0 = self._clock()
        for item in source:
            if digest_acc is not None:
                d = hashlib.sha256(_as_bytes(item)).digest()  # serial hash
                for i in range(32):
                    digest_acc[i] ^= d[i]
            sink(item)
            items += 1
            nbytes += _default_sizeof(item)
        elapsed = self._clock() - t0
        planned = self.basin.achievable_throughput() if self.basin else None
        return self._record(TransferReport(
            mode="direct",
            items=items,
            bytes=nbytes,
            elapsed_s=elapsed,
            stage_reports=[],
            checksum=bytes(digest_acc).hex() if digest_acc is not None else None,
            planned_bytes_per_s=planned,
        ))


def _as_bytes(item: Any) -> bytes:
    """Stable byte view of an item for integrity hashing."""
    if isinstance(item, (bytes, bytearray)):
        return bytes(item)
    if isinstance(item, memoryview):
        return item.tobytes()
    tobytes = getattr(item, "tobytes", None)
    if tobytes is not None:
        return tobytes()
    if isinstance(item, (tuple, list)):
        return b"".join(_as_bytes(e) for e in item)
    if isinstance(item, dict):
        return b"".join(_as_bytes(item[k]) for k in sorted(item))
    return repr(item).encode()
