"""Unified data mover — one engine for every tier (the paper's zx analogue).

Paper Table 1 / section 2.1: a *single*, concurrent, scale-out data mover
manages the complete placement workflow "from source storage through
transit to destination storage", supporting bulk and streaming transfers,
with integrity built in, at every basin tier.

:class:`UnifiedDataMover` is that engine for this framework.  The same
object moves

* dataset batches        host storage  -> host burst buffer -> device feed,
* checkpoint shards      device        -> host burst buffer -> storage,
* decode token streams   device        -> host burst buffer -> client sink,

in either **bulk** mode (the dataset fully exists before the transfer
starts) or **streaming** mode (the source is still producing — transfer
overlaps production).  Integrity checksums (the paper's encryption/
checksumming budget, section 3.4) are computed *inside the staged path* so
they overlap transit instead of serializing with it.

Branching basins run through :meth:`UnifiedDataMover.parallel_transfer`:
one stage pipeline per branch of a multipath
:class:`~repro.core.planner.TransferPlan`, fed by a dispatcher that either
**splits** the stream across branches (weighted by the plan's per-branch
traffic shares — the fan-out/fan-in case) or **mirrors** every item down
every branch (the replication case: a dual-tier checkpoint, a decode
fan-out to many clients).  Branch reports come back tagged
``"<branch>/<stage>"`` so online replanning attributes a mid-transfer
stall to the one degraded branch and rebalances traffic toward the
healthy ones.

Every transfer returns a :class:`TransferReport` carrying achieved
throughput and the fidelity gap against the planned basin — making the
paper's headline metric a first-class, always-on observable.

Zero-drain replanning (the default hot path)
--------------------------------------------

Online replanning (``replan_every_items``) used to buy adaptivity with a
teardown bubble: every boundary drained the buffer path and rebuilt the
stage pipeline from scratch, so a long stream repeatedly fell off line
rate exactly when the plan was being corrected — the class of host-side
self-inflicted stall arXiv:2308.10312 identifies as a dominant cause of
sub-provisioned throughput.  The hot path is now **zero-drain**: one
persistent pipeline per transfer, kept alive across revision boundaries.
A revision is computed from the boundary *window*'s evidence
(:func:`~repro.core.staging.delta_reports` over the running stages'
cumulative counters) and applied as a
:func:`~repro.core.planner.plan_delta` to the live pipeline — buffers
resize in place, worker pools grow/retire against the live queues, and
the split dispatcher swaps branch weights without stopping — so the data
path sustains the paper's deterministic supply *through* the correction.
Segment boundaries are demoted to accounting-only checkpoints; the
stream-wide checksum and merged :class:`StageReport` observables are
identical to the drain-per-segment path (equivalence-tested), which
remains available as ``drain_per_segment=True`` for comparison
(``benchmarks/live_swap.py`` measures the removed bubble).

Split-mode dispatch additionally offers ``route="steal"``: a pull-based
work-stealing route where every branch pulls from one shared intake, so
a transiently slow branch stops accumulating queued items *within* a
segment instead of waiting for the next weight rebalance (at the cost of
scripted routing determinism).  Replanning under stealing attributes per
branch from **pull rates** at the shared intake (bytes per busy
worker-second — see :meth:`UnifiedDataMover._steal_intake`), since a
shared queue backpressures nobody in particular.  Fan-out deliveries can
run through a per-client drainer pool (``drainer_pool=True``) so one
blocking client write no longer serializes its siblings at the merge
buffer.

Windowed (RTT-governed) hops
----------------------------

A plan hop whose segment crosses a latency-bearing link carries a
``window_bytes``/``rtt_s`` pair, and every execution path — bulk,
streaming, and both parallel modes — builds that hop as a
:class:`~repro.core.staging.WindowedStage` (the single
:meth:`UnifiedDataMover._make_stage` seam): in-flight bytes are capped
at the window and credit returns one RTT after transmission, so an
under-windowed CHANNEL delivers ``window / RTT`` however much bandwidth
is provisioned — the paper's §3.1/§3.2 collapse, executable.  A
window-bound verdict's remedy applies **zero-drain**: the live swap
grows the running stage's window (``Stage.resize(window_bytes=...)``)
and credit-blocked workers wake into the new credit immediately.
"""

from __future__ import annotations

import dataclasses
import threading
import time
import traceback
from typing import Any, Callable, Iterable, Iterator, Mapping, Optional, \
    Sequence

from .basin import DrainageBasin
from .burst_buffer import BufferClosed, BurstBuffer
# the integrity seam moved to core.integrity (host vs accelerator digest
# placement); re-exported under the historical names for importers
from .integrity import StreamDigest as _StreamDigest, as_bytes as _as_bytes
from .planner import BranchPlan, HopPlan, STALL_THRESHOLD, TransferPlan, \
    plan_delta, replan as _replan
from .staging import ParallelBranchPipeline, SERVICE_RESERVOIR, Stage, \
    StagePipeline, StageReport, WindowedStage, _default_sizeof, \
    delta_reports, iter_segments, merge_reports
from .telemetry import TelemetryRegistry

__all__ = ["MIRROR_BATCH", "MoverConfig", "TransferReport",
           "UnifiedDataMover", "_StreamDigest", "_as_bytes"]

#: items replicated per ``put_many`` batch by the mirror-mode dispatcher
#: (one lock round-trip per branch queue per batch instead of per item)
MIRROR_BATCH = 8

#: a live-window intake flag only holds when the flagged branch is also
#: at least this much slower per byte (busy time) than the fastest
#: branch — see UnifiedDataMover._validated_intake
BUSY_CULPRIT_RATIO = 1.5


@dataclasses.dataclass
class TransferReport:
    """Outcome of one end-to-end transfer."""

    mode: str                       # "bulk" | "streaming"
    items: int
    bytes: int
    elapsed_s: float
    stage_reports: list[StageReport]
    checksum: Optional[str] = None  # hex digest over the item stream
    planned_bytes_per_s: Optional[float] = None
    #: online plan revisions applied mid-transfer (``replan_every_items``)
    replans: int = 0
    #: execution shape the transfer finished on (``TransferPlan.path``) —
    #: differs from the initial choice when a ``path-revised`` verdict
    #: switched shapes mid-stream
    path: Optional[str] = None

    @property
    def throughput_bytes_per_s(self) -> float:
        return self.bytes / self.elapsed_s if self.elapsed_s > 0 else 0.0

    @property
    def fidelity_gap(self) -> Optional[float]:
        """1 - achieved/planned (paper section 1).  None without a plan."""
        if not self.planned_bytes_per_s:
            return None
        return 1.0 - self.throughput_bytes_per_s / self.planned_bytes_per_s

    def bottleneck_stage(self) -> Optional[StageReport]:
        if not self.stage_reports:
            return None
        return min(self.stage_reports,
                   key=lambda r: r.throughput_bytes_per_s or float("inf"))


def _drain_batched(buf: BurstBuffer,
                   batch: int = MIRROR_BATCH) -> Iterator[Any]:
    """Drain a buffer via ``get_many``: one lock round-trip per batch of
    *already-staged* items.  Unlike put-side batching this adds no
    latency — ``get_many`` returns immediately with at least one item —
    it only stops the hot merge-drain loop paying one lock acquisition
    per item."""
    batch = max(1, batch)
    while True:
        try:
            out = buf.get_many(batch)
        except BufferClosed:
            return
        yield from out


class _DrainerPool:
    """Per-client drainer pool for fan-out deliveries.

    The merge buffer of a parallel-branch transfer drains in one loop; a
    delivery callable that blocks (one slow client write) would therefore
    serialize every sibling behind it.  The pool gives each branch/client
    its own small burst buffer plus one drainer thread, so a blocking
    write stalls only its own client's queue while siblings keep
    receiving — the buffer-decoupling story of §2.1 applied to the last
    hop.  A client whose sink raises is retired: its error is kept for
    :meth:`close` and later deliveries to it are dropped (reported via
    the ``False`` return of :meth:`submit`) instead of failing siblings
    mid-stream."""

    def __init__(self, sinks: Mapping[str, Callable[[Any], None]],
                 capacities: Mapping[str, int],
                 clock: Callable[[], float]):
        self._bufs: dict[str, BurstBuffer] = {}
        self._threads: list[threading.Thread] = []
        self._errors: dict[str, str] = {}
        self._lock = threading.Lock()
        for bid, fn in sinks.items():
            buf: BurstBuffer = BurstBuffer(max(1, capacities.get(bid, 8)),
                                           name=f"{bid}.deliver", clock=clock)
            self._bufs[bid] = buf
            t = threading.Thread(target=self._drain, args=(bid, buf, fn),
                                 name=f"deliver-{bid}", daemon=True)
            self._threads.append(t)
            t.start()

    def _drain(self, bid: str, buf: BurstBuffer,
               fn: Callable[[Any], None]) -> None:
        try:
            for item in buf.drain():
                fn(item)
        except Exception:
            with self._lock:
                self._errors[bid] = traceback.format_exc()
            buf.close()      # unblock a submitter; later deliveries drop

    def submit(self, bid: str, item: Any) -> bool:
        """Queue one delivery; False when the client already failed."""
        try:
            self._bufs[bid].put(item)
            return True
        except BufferClosed:
            return False

    def close(self) -> None:
        """End-of-stream: drain every queue, join drainers, surface the
        first client failure (siblings completed their own streams)."""
        for buf in self._bufs.values():
            buf.close()
        for t in self._threads:
            t.join()
        if self._errors:
            bid, tb = sorted(self._errors.items())[0]
            raise RuntimeError(f"client sink {bid!r} failed:\n{tb}")


@dataclasses.dataclass
class MoverConfig:
    """Global tuning (paper section 2.3): one configuration effective across
    item sizes spanning orders of magnitude.  Per-transfer overrides are
    accepted by the transfer methods (the paper's hierarchical tuning)."""

    staging_capacity: int = 4       # slots per burst buffer
    staging_workers: int = 2        # concurrent movers per hop
    checksum: bool = True           # integrity over the item stream
    name: str = "zx-jax"


class UnifiedDataMover:
    """Moves item streams through a staged, buffered, instrumented path.

    Staging parameters come from (in precedence order) a
    :class:`~repro.core.planner.TransferPlan` — per-hop capacity/workers
    derived from the basin model — then per-call overrides, then the
    uniform :class:`MoverConfig` defaults.  With ``telemetry`` set, every
    :class:`TransferReport` is recorded there under ``layer``.
    """

    def __init__(self, config: MoverConfig | None = None,
                 basin: DrainageBasin | None = None,
                 plan: TransferPlan | None = None,
                 telemetry: TelemetryRegistry | None = None,
                 layer: str | None = None,
                 clock: Callable[[], float] | None = None):
        self.config = config or MoverConfig()
        self.plan = plan
        self.basin = basin or (plan.basin if plan is not None else None)
        self.telemetry = telemetry
        self.layer = layer or self.config.name
        # injectable for the deterministic simulated-basin test harness
        self._clock = clock or time.monotonic
        #: the plan the most recent transfer ended on (== its starting
        #: plan unless online replanning revised it mid-transfer)
        self.last_plan: TransferPlan | None = plan

    # -- internal ------------------------------------------------------------

    def _stage_params(
        self,
        transforms: Sequence[tuple[str, Any]],
        plan: Optional[TransferPlan],
        capacity: Optional[int],
        workers: Optional[int],
    ) -> list[tuple[int, int, Optional[HopPlan]]]:
        """(capacity, workers, hop) per stage: plan-derived per hop, or
        uniform with no hop (and so no transport window)."""
        n = max(1, len(transforms))
        if plan is not None:
            names = [name for name, _ in transforms] or ["stage"]
            hops = [plan.hop_for(i, name) for i, name in enumerate(names)]
            return [(capacity or h.capacity, workers or h.workers, h)
                    for h in hops]
        cap = capacity or self.config.staging_capacity
        wrk = workers or self.config.staging_workers
        return [(cap, wrk, None)] * n

    def _make_stage(self, name: str, capacity: int, workers: int,
                    transform: Optional[Callable[[Any], Any]],
                    hop: Optional[HopPlan],
                    batch_items: Optional[int] = None) -> Stage:
        """One staging hop — a :class:`~repro.core.staging.WindowedStage`
        when the plan marks the segment RTT-governed (a CHANNEL hop whose
        in-flight bytes are capped at the plan's ``window_bytes``), a
        queue-clocked :class:`~repro.core.staging.Stage` otherwise.  This
        is the single seam every execution path builds hops through, so
        windowed transport — and the zero-copy slab size
        (``batch_items``, a per-call override or the plan hop's) — rides
        bulk, streaming, and both parallel paths alike."""
        batch = self._hop_batch(hop, batch_items)
        # the plan staffs the hop's fault posture too: transient faults
        # retry with exponential backoff inside the stage (charged to
        # StageReport.retries/retry_wait_s — the fault-degraded verdict's
        # evidence); an unplanned stage keeps the historical fail-fast
        retry = dict(retry_budget=hop.retry_budget,
                     backoff_base_s=hop.backoff_base_s) \
            if hop is not None else {}
        if hop is not None and hop.window_bytes > 0 and hop.rtt_s > 0:
            return WindowedStage(name, capacity=capacity, workers=workers,
                                 transform=transform, clock=self._clock,
                                 window_bytes=hop.window_bytes,
                                 rtt_s=hop.rtt_s, batch_items=batch,
                                 **retry)
        return Stage(name, capacity=capacity, workers=workers,
                     transform=transform, clock=self._clock,
                     batch_items=batch, **retry)

    @staticmethod
    def _hop_window(hop: Optional[HopPlan]) -> Optional[float]:
        """The resize argument carrying a hop's revised window (None when
        the hop is queue-clocked — base stages ignore it)."""
        if hop is not None and hop.window_bytes > 0:
            return hop.window_bytes
        return None

    @staticmethod
    def _hop_rtt(hop: Optional[HopPlan]) -> Optional[float]:
        """The resize argument carrying a hop's revised round trip (None
        when the hop is queue-clocked — base stages ignore it).  An
        rtt-revised verdict's remedy rides the same zero-drain swap as a
        window raise: the running WindowedStage re-clocks its ACK ledger
        to the revised RTT without dropping a staged item."""
        if hop is not None and hop.window_bytes > 0 and hop.rtt_s > 0:
            return hop.rtt_s
        return None

    @staticmethod
    def _hop_batch(hop: Optional[HopPlan],
                   batch_items: Optional[int] = None) -> int:
        """Effective slab size for a hop: the per-call override wins
        (the benchmark's per-item baseline forces 1 against a batched
        plan), else the plan hop's ``batch_items``, else per-item."""
        if batch_items is not None:
            return max(1, int(batch_items))
        return hop.batch_items if hop is not None else 1

    @staticmethod
    def _hop_retry(hop: Optional[HopPlan]) -> dict:
        """Resize kwargs carrying a hop's revised fault posture — a
        fault-degraded element's re-priced ``retry_budget`` /
        ``backoff_base_s`` apply to the running stage at the same
        zero-drain boundary as a window raise.  Empty for unplanned hops
        (those keep their construction-time posture)."""
        if hop is None:
            return {}
        return {"retry_budget": hop.retry_budget,
                "backoff_base_s": hop.backoff_base_s}

    def _deal_batch(self, plan: TransferPlan,
                    batch_items: Optional[int] = None) -> int:
        """Split-node slab size: the smallest first-hop batch across
        branches (every branch intake must absorb a dealt slab without
        overrunning its queue).  Ordered plans stay per-item — holding
        tokens to fill a slab would trade delivery latency for lock
        traffic, the same rule mirror batching follows."""
        if plan.ordered or not plan.branches:
            return 1
        return max(1, min(self._hop_batch(b.hops[0], batch_items)
                          for b in plan.branches))

    def _build_pipeline(
        self,
        source: Iterable[Any],
        transforms: Sequence[tuple[str, Callable[[Any], Any]]],
        params: Sequence[tuple[int, int, Optional[HopPlan]]],
        plan: Optional[TransferPlan] = None,
        batch_items: Optional[int] = None,
    ) -> StagePipeline:
        default_name = plan.hops[0].name if plan is not None else "stage"
        stages = [
            self._make_stage(name, cap, wrk, fn, hop, batch_items)
            for (name, fn), (cap, wrk, hop) in zip(transforms, params)
        ] or [self._make_stage(default_name, params[0][0], params[0][1],
                               None, params[0][2], batch_items)]
        return StagePipeline(source, stages)

    @staticmethod
    def _fold_checksum_report(plan: Optional[TransferPlan],
                              reports: Sequence[StageReport]
                              ) -> list[StageReport]:
        """Fold the executed checksum stage's report into its charged
        hop's report before ``replan`` sees the window.

        The digest runs as its own pipeline stage while the *plan*
        charges its budget to the hop at ``checksum_index``
        (``digest_bytes_per_s``) — so the live "checksum" report matched
        no hop name and the host-compute-bound verdict could only ever
        fire on recorded/replayed reports, never on a run.  Merging the
        pair makes the live path speak the plan's accounting language:
        items/bytes are the hop's, the time base is the slower of the
        two (they overlap in the pipeline), the stalls on the buffer
        *between* the pair are dropped (internal coupling of the merged
        stages, not channel evidence) while both outer stall sides
        survive, and the transport ledger (window stalls, retransmits,
        ACK spacing) sums."""
        out = list(reports)
        if plan is None or plan.checksum_index is None or not plan.hops:
            return out
        hop = plan.hops[min(plan.checksum_index, len(plan.hops) - 1)]
        if hop.name == "checksum":
            return out
        i_sum = next((i for i, r in enumerate(out)
                      if r.name == "checksum"), None)
        i_hop = next((i for i, r in enumerate(out)
                      if r.name == hop.name), None)
        if i_sum is None or i_hop is None:
            return out
        sum_rep, hop_rep = out[i_sum], out[i_hop]
        first, second = ((sum_rep, hop_rep) if i_sum < i_hop
                         else (hop_rep, sum_rep))
        out[i_hop] = dataclasses.replace(
            hop_rep,
            elapsed_s=max(hop_rep.elapsed_s, sum_rep.elapsed_s),
            active_s=max(hop_rep.active_s, sum_rep.active_s),
            stall_up_s=first.stall_up_s,
            stall_down_s=second.stall_down_s,
            stall_window_s=hop_rep.stall_window_s + sum_rep.stall_window_s,
            errors=hop_rep.errors + sum_rep.errors,
            retransmits=hop_rep.retransmits + sum_rep.retransmits,
            rtt_sum_s=hop_rep.rtt_sum_s + sum_rep.rtt_sum_s,
            acks=hop_rep.acks + sum_rep.acks,
            service_up_s=(list(first.service_up_s)
                          + list(second.service_up_s))[-SERVICE_RESERVOIR:],
            service_down_s=(list(first.service_down_s)
                            + list(second.service_down_s)
                            )[-SERVICE_RESERVOIR:],
        )
        del out[i_sum]
        return out

    def _record(self, report: TransferReport) -> TransferReport:
        if self.telemetry is not None:
            self.telemetry.record(self.layer, report)
        return report

    def _run_live(
        self,
        source: Iterable[Any],
        sink: Callable[[Any], None],
        all_transforms: Sequence[tuple[str, Callable[[Any], Any]]],
        capacity: Optional[int],
        workers: Optional[int],
        plan: Optional[TransferPlan],
        chunk: int,
        damping: float,
        batch_items: Optional[int] = None,
        fleet=None,
    ) -> tuple[int, int, list[StageReport], int, Optional[TransferPlan]]:
        """The zero-drain hot path: ONE persistent pipeline for the whole
        transfer.  Revision boundaries are accounting-only checkpoints —
        the window's evidence (cumulative-counter deltas) feeds ``replan``
        and the resulting :class:`~repro.core.planner.PlanDelta` is
        applied to the running stages in place (buffer resize, worker
        spawn/retire), so no staged item drains and the supply never
        falls off line rate while the plan is being corrected.

        With a ``fleet`` admission bound, the arbiter pushes re-granted
        plans through the same in-place resize path as peers arrive and
        finish — each rebalance counts as a replan, and the pipeline is
        never torn down for one."""
        active = plan
        params = self._stage_params(all_transforms, active, capacity,
                                    workers)
        pipeline = self._build_pipeline(iter(source), all_transforms,
                                        params, active, batch_items)
        pipeline.start()
        rebalances = [0]
        applied = [active]
        if fleet is not None:
            fleet_lock = threading.Lock()

            def _fleet_apply(new_plan, _delta) -> None:
                # diff against what this pipeline actually runs (not the
                # arbiter's idea of the previous plan): the bind-time
                # sync call then degrades to a no-op when nothing moved
                # between plan pickup and bind
                with fleet_lock:
                    d = plan_delta(applied[0], new_plan)
                    applied[0] = new_plan
                    if not d:
                        return
                    rebalances[0] += 1
                    new_params = self._stage_params(all_transforms,
                                                    new_plan, capacity,
                                                    workers)
                    for st, (cap, wrk, hop) in zip(pipeline.stages,
                                                   new_params):
                        st.resize(capacity=cap, workers=wrk,
                                  window_bytes=self._hop_window(hop),
                                  rtt_s=self._hop_rtt(hop),
                                  batch_items=self._hop_batch(hop,
                                                              batch_items),
                                  **self._hop_retry(hop))

            fleet.bind(_fleet_apply)
        items = 0
        nbytes = 0
        replans = 0
        prev_cum: list[StageReport] = []
        boundary = chunk
        # a batched last hop stages whole slabs: drain them the same way
        # (one get_many lock round-trip per slab) instead of re-serializing
        # the sink loop to one lock acquisition per item
        out_batch = self._hop_batch(params[-1][2], batch_items)
        out_iter = (pipeline.output.drain() if out_batch <= 1
                    else _drain_batched(pipeline.output, out_batch))
        for item in out_iter:
            sink(item)
            items += 1
            nbytes += _default_sizeof(item)
            if chunk and items >= boundary:
                boundary += chunk
                cum = pipeline.reports()
                window = delta_reports(cum, prev_cum)
                prev_cum = cum
                for st in pipeline.stages:
                    # windows must not re-diagnose a consumed regime
                    st.reset_service_reservoirs()
                if not window:
                    continue
                revised = _replan(
                    active, self._fold_checksum_report(active, window),
                    damping=damping)
                delta = plan_delta(active, revised)
                active = revised
                if delta:
                    replans += 1
                    new_params = self._stage_params(all_transforms, active,
                                                    capacity, workers)
                    for st, (cap, wrk, hop) in zip(pipeline.stages,
                                                   new_params):
                        st.resize(capacity=cap, workers=wrk,
                                  window_bytes=self._hop_window(hop),
                                  rtt_s=self._hop_rtt(hop),
                                  batch_items=self._hop_batch(hop,
                                                              batch_items),
                                  **self._hop_retry(hop))
        if fleet is not None:
            fleet.unbind()
            active = applied[0]
            replans += rebalances[0]
        pipeline.join()
        return items, nbytes, pipeline.reports(), replans, active

    def _run_segmented(
        self,
        source: Iterable[Any],
        sink: Callable[[Any], None],
        all_transforms: Sequence[tuple[str, Callable[[Any], Any]]],
        capacity: Optional[int],
        workers: Optional[int],
        plan: Optional[TransferPlan],
        chunk: int,
        damping: float,
        batch_items: Optional[int] = None,
    ) -> tuple[int, int, list[StageReport], int, Optional[TransferPlan]]:
        """The historical drain-per-segment path: tear the pipeline down
        at every boundary and rebuild it on the revised plan.  Kept as an
        explicit fallback (``drain_per_segment=True``) — it is the
        baseline the zero-drain path is equivalence-tested and benchmarked
        against (``benchmarks/live_swap.py``)."""
        active = plan
        merged: list[StageReport] = []      # folded incrementally: bounded
        last_reports: list[StageReport] = []
        replans = 0
        items = 0
        nbytes = 0
        for segment in iter_segments(iter(source), chunk):
            if last_reports:
                # buffer boundary: the previous segment fully drained, so
                # the plan can swap without dropping staged items
                # (hypothesis -> change -> measure, mid-transfer)
                revised = _replan(
                    active, self._fold_checksum_report(active, last_reports),
                    damping=damping)
                # same revision signature as the live path (plan_delta),
                # so the two execution modes count replans identically
                if plan_delta(active, revised):
                    replans += 1
                active = revised
            params = self._stage_params(all_transforms, active, capacity,
                                        workers)
            pipeline = self._build_pipeline(segment, all_transforms, params,
                                            active, batch_items)
            pipeline.start()
            out_batch = self._hop_batch(params[-1][2], batch_items)
            out_iter = (pipeline.output.drain() if out_batch <= 1
                        else _drain_batched(pipeline.output, out_batch))
            for item in out_iter:
                sink(item)
                items += 1
                nbytes += _default_sizeof(item)
            pipeline.join()
            last_reports = pipeline.reports()
            merged = merge_reports([merged, last_reports])
        return items, nbytes, merged, replans, active

    def _run(
        self,
        mode: str,
        source: Iterable[Any],
        sink: Callable[[Any], None],
        transforms: Sequence[tuple[str, Callable[[Any], Any]]],
        capacity: Optional[int],
        workers: Optional[int],
        checksum: Optional[bool],
        plan: Optional[TransferPlan],
        replan_every_items: int = 0,
        replan_damping: float = 0.5,
        drain_per_segment: bool = False,
        batch_items: Optional[int] = None,
        fleet=None,
        resume=None,
    ) -> TransferReport:
        if fleet is not None:
            if replan_every_items:
                raise ValueError(
                    "a fleet-managed transfer delegates plan revision to "
                    "the arbiter; replan_every_items must be 0")
            if fleet.status != "admitted":
                raise ValueError(
                    f"fleet admission {fleet.name!r} is {fleet.status}"
                    f"{': ' + fleet.reason if fleet.reason else ''}")
            if plan is None:
                plan = fleet.plan
        own_plan = plan is None
        plan = plan if plan is not None else self.plan
        do_sum = self.config.checksum if checksum is None else checksum

        # order-independent integrity: concurrent staging workers may
        # deliver items out of order (see _StreamDigest).  The plan
        # decides where the digest computes (host SHA-256 vs the
        # accelerator lattice kernel) — the §3.4 compute-budget placement.
        placement = plan.checksum_placement if plan is not None else "host"
        digest = _StreamDigest(do_sum, placement=placement)

        if resume is not None:
            # resumable ledger (core.resume): items the ledger already
            # verified are claimed and skipped at the source — their
            # recorded digests fold into the live checksum so a resumed
            # run's stream checksum is bit-identical to an unbroken
            # one's — and every new delivery records durably through the
            # wrapped sink
            if do_sum and placement != "host":
                raise ValueError(
                    "a resumable transfer verifies through the host "
                    "checksum; plan checksum_placement='host'")
            source = resume.skip_verified(source, digest)
            sink = resume.recording_sink(sink)

        all_transforms = list(transforms)
        if do_sum:
            # checksum rides inside the staged path — overlapped, not
            # serial.  With a plan it rides the hop with the most
            # bandwidth headroom (planner.checksum_index); otherwise it
            # trails the path.  The digest object itself is the transform
            # (callable per item, `.many` per slab) so a batched hop
            # folds a whole slab under one lock acquisition.
            at = len(all_transforms)
            if plan is not None and plan.checksum_index is not None:
                at = min(plan.checksum_index, at)
            all_transforms.insert(at, ("checksum", digest))

        # online replanning needs a plan to revise; without one the
        # transfer runs as a single segment
        chunk = replan_every_items if plan is not None else 0
        t0 = self._clock()
        try:
            if drain_per_segment and chunk:
                items, nbytes, merged, replans, active = self._run_segmented(
                    source, sink, all_transforms, capacity, workers, plan,
                    chunk, replan_damping, batch_items)
            else:
                items, nbytes, merged, replans, active = self._run_live(
                    source, sink, all_transforms, capacity, workers, plan,
                    chunk, replan_damping, batch_items, fleet)
            elapsed = self._clock() - t0
        finally:
            # one admission, one transfer: completion (or failure) frees
            # the grant so survivors absorb the share immediately
            if fleet is not None:
                fleet.release()
        self.last_plan = active
        if own_plan and self.plan is not None:
            # the mover owns the plan: online revisions persist to the
            # next transfer (the checkpoint engine replans across saves)
            self.plan = active

        if fleet is not None:
            # the grant moved while the transfer ran (peers arrived and
            # finished); the honest promise is its time average — the
            # fleet analogue of planned_bytes_per_s
            planned = fleet.mean_granted(t0, t0 + elapsed)
        elif plan is not None:
            planned = plan.planned_bytes_per_s
        else:
            planned = self.basin.achievable_throughput() if self.basin else None
        return self._record(TransferReport(
            mode=mode,
            items=items,
            bytes=nbytes,
            elapsed_s=elapsed,
            stage_reports=merged,
            checksum=digest.hexdigest(),
            planned_bytes_per_s=planned,
            replans=replans,
            path=active.path if active is not None else None,
        ))

    # -- public API -----------------------------------------------------------

    def bulk_transfer(
        self,
        source: Iterable[Any],
        sink: Callable[[Any], None],
        *,
        transforms: Sequence[tuple[str, Callable[[Any], Any]]] = (),
        capacity: Optional[int] = None,
        workers: Optional[int] = None,
        checksum: Optional[bool] = None,
        plan: Optional[TransferPlan] = None,
        replan_every_items: int = 0,
        replan_damping: float = 0.5,
        drain_per_segment: bool = False,
        batch_items: Optional[int] = None,
        fleet=None,
        resume=None,
    ) -> TransferReport:
        """Move a dataset at rest (paper section 2.2, *Bulk Transfer*).

        ``resume`` takes a :class:`~repro.core.resume.TransferLedger`:
        items the ledger already verified (recorded by a previous,
        possibly killed, run) are skipped at the source with their
        digests folded into the stream checksum — a resumed run's
        checksum is bit-identical to an unbroken one's — and every new
        delivery records durably, so after N interruptions the ledger
        holds each item exactly once.  Requires the host checksum
        placement when ``checksum`` is on.

        ``fleet`` registers the transfer with a
        :class:`~repro.core.fleet.FleetArbiter`: pass the ``"admitted"``
        :class:`~repro.core.fleet.Admission` handle and the transfer runs
        under the arbiter's granted plan (``plan`` defaults to it),
        absorbs mid-stream re-grants zero-drain as peers arrive/finish
        (each counts in ``replans``), measures its fidelity gap against
        the time-averaged grant, and releases its share on completion.
        The arbiter owns revision, so ``replan_every_items`` must stay 0;
        use the same clock for mover and arbiter (the simbasin virtual
        clock in tests) so the time-averaged promise is coherent.

        ``replan_every_items > 0`` makes the transfer *self-revising*: the
        observed stall ratios and service-time samples of each revision
        window feed :func:`~repro.core.planner.replan`, and the revised
        plan is applied **zero-drain** to the one persistent pipeline
        (buffers resize in place, worker pools grow/retire live) — a
        mid-transfer regime shift is answered mid-transfer with no
        teardown bubble.  ``drain_per_segment=True`` selects the
        historical segment-drain-and-rebuild path instead (the
        equivalence/benchmark baseline).

        ``batch_items`` overrides the slab size on every hop (1 forces
        the per-item path against a batched plan — the benchmark
        baseline; None defers to the plan's per-hop ``batch_items``)."""
        return self._run("bulk", source, sink, transforms, capacity, workers,
                         checksum, plan, replan_every_items, replan_damping,
                         drain_per_segment, batch_items, fleet, resume)

    def streaming_transfer(
        self,
        source: Iterable[Any],
        sink: Callable[[Any], None],
        *,
        transforms: Sequence[tuple[str, Callable[[Any], Any]]] = (),
        capacity: Optional[int] = None,
        workers: Optional[int] = None,
        checksum: Optional[bool] = None,
        plan: Optional[TransferPlan] = None,
        replan_every_items: int = 0,
        replan_damping: float = 0.5,
        drain_per_segment: bool = False,
        batch_items: Optional[int] = None,
        fleet=None,
    ) -> TransferReport:
        """Move a still-growing stream (paper section 2.2, *Streaming
        Transfer*): the source iterator may block while data is produced;
        staging overlaps production with transit, which is exactly what the
        buffer path provides.  Identical machinery, different source
        contract — the unified-mover property.  ``replan_every_items``
        revises the plan online, applied zero-drain to the persistent
        pipeline as in :meth:`bulk_transfer`; ``batch_items`` overrides
        the per-hop slab size and ``fleet`` registers with an arbiter as
        in :meth:`bulk_transfer`."""
        return self._run("streaming", source, sink, transforms, capacity,
                         workers, checksum, plan, replan_every_items,
                         replan_damping, drain_per_segment, batch_items,
                         fleet)

    # -- parallel-branch path (DAG plans) --------------------------------------

    def _branch_pipelines(
        self,
        plan: TransferPlan,
        transforms: Sequence[tuple[str, Callable[[Any], Any]]]
        | Mapping[str, Sequence[tuple[str, Callable[[Any], Any]]]],
        capacity: Optional[int],
        workers: Optional[int],
        route: str = "deal",
        batch_items: Optional[int] = None,
    ) -> tuple[dict[str, BurstBuffer], ParallelBranchPipeline]:
        """Per-branch input queue + stage chain from a multipath plan.

        ``route="steal"`` wires every branch to ONE shared intake queue
        (sized to the branches' aggregate first-hop capacity): branches
        pull items as they free up instead of being dealt a share, so a
        transiently slow branch self-throttles within the segment.  Each
        intake queue is handed to its :class:`StagePipeline` as a
        BurstBuffer (not a drain iterator), so a batched first hop pulls
        true slabs — one ``get_many`` lock round-trip per slab."""
        queues: dict[str, BurstBuffer] = {}
        branches: list[tuple[str, StagePipeline]] = []
        shared: Optional[BurstBuffer] = None
        if route == "steal":
            agg = sum(b.hops[0].capacity for b in plan.branches)
            shared = BurstBuffer(capacity or max(1, agg),
                                 name="steal.inq", clock=self._clock)
        for b in plan.branches:
            tf = (transforms.get(b.branch_id, ())
                  if isinstance(transforms, Mapping) else transforms)
            named = list(tf) or [(b.hops[0].name, None)]
            stages = []
            for i, (name, fn) in enumerate(named):
                hop = b.hop_for(i, name)
                stages.append(self._make_stage(
                    name, capacity or hop.capacity,
                    workers or hop.workers, fn, hop, batch_items))
            if shared is not None:
                q = shared
            else:
                q = BurstBuffer(b.hops[0].capacity,
                                name=f"{b.branch_id}.inq", clock=self._clock)
            queues[b.branch_id] = q
            branches.append((b.branch_id, StagePipeline(q, stages)))
        pbp = ParallelBranchPipeline(
            branches, clock=self._clock,
            upstreams=None if shared is not None else queues,
            shared_upstream=shared)
        return queues, pbp

    def _salvage_pass(
        self,
        branch: BranchPlan,
        leftovers: list,
        deliver: Callable[[Any], bool],
        transforms,
        capacity: Optional[int],
        workers: Optional[int],
        batch_items: Optional[int],
    ) -> tuple[int, int, list[StageReport]]:
        """Re-move a dead branch's claimed-but-undelivered items down ONE
        surviving branch.

        Failover's last mile: items a dead branch pulled from its feed
        but never delivered (in-hand when the fault struck, or parked in
        its inter-stage buffers) are re-staged through a fresh copy of a
        survivor's hop chain and delivered under the survivor's id.  The
        stream digest is NOT part of these stages — in parallel mode it
        folds once at the split node, and every salvaged item was hashed
        there before it was ever dealt, so re-moving never re-counts."""
        tf = (transforms.get(branch.branch_id, ())
              if isinstance(transforms, Mapping) else transforms)
        named = list(tf) or [(branch.hops[0].name, None)]
        stages = []
        for i, (name, fn) in enumerate(named):
            hop = branch.hop_for(i, name)
            stages.append(self._make_stage(
                name, capacity or hop.capacity,
                workers or hop.workers, fn, hop, batch_items))
        pipe = StagePipeline(iter(leftovers), stages)
        pipe.start()
        items = 0
        nbytes = 0
        for item in pipe.output.drain():
            if deliver(item):
                items += 1
                nbytes += _default_sizeof(item)
        pipe.join()
        return items, nbytes, [
            dataclasses.replace(r, name=f"salvage/{r.name}")
            for r in pipe.reports()]

    @staticmethod
    def _dispatch(segment: Iterator[Any], queues: dict[str, BurstBuffer],
                  weights: dict[str, float], order: Sequence[str],
                  mode: str, on_item: Callable[[Any], Any],
                  route: str = "deal",
                  mirror_batch: int = MIRROR_BATCH,
                  err_out: Optional[list[str]] = None,
                  deal_batch: int = 1
                  ) -> Callable[[], None]:
        """The split/merge node, executable: pulls the source and routes.

        ``split`` + ``route="deal"``: weighted deficit round-robin over
        ``weights`` — deterministic routing, so a simulated run is a pure
        function of the script.  ``weights`` is read live per item: a
        zero-drain plan revision swaps new branch shares into the dict and
        the running dispatcher re-deals from the next item on.  ``split``
        + ``route="steal"``: every item goes to the shared intake queue;
        branches pull as they free up (self-balancing, not scripted).
        ``mirror``: every item goes down every branch (replication),
        batched ``mirror_batch`` deep — one ``put_many`` lock round-trip
        per branch per batch — pacing at the slowest branch's intake.
        The caller passes ``mirror_batch=1`` for ordered (latency-
        sensitive) streams, where holding tokens to fill a batch would
        trade delivery latency for lock traffic.

        ``deal_batch > 1`` routes split-mode traffic in whole slabs: the
        digest folds the slab in one lock acquisition (``on_item.many``
        when present), a dealt slab goes to ONE branch with its deficit
        debited by the slab size (long-run shares unchanged), and the
        steal intake takes one ``put_many`` per slab — the split node's
        share of the zero-copy batch admission.  ``deal_batch=1`` is the
        historical per-item dispatch, byte for byte.
        """
        deficits = {bid: 0.0 for bid in order}
        on_many = getattr(on_item, "many", None)
        # branches whose intake is still open: a put that raises
        # BufferClosed mid-stream means that branch DIED (its pipeline
        # aborted and closed its feed) — the dispatcher fails the branch
        # over instead of aborting the whole transfer, re-routing every
        # future item through the survivors via the same live-weights
        # seam a zero-drain revision uses
        live = list(order)

        def fold(batch: list[Any]) -> None:
            if on_many is not None:
                on_many(batch)
            else:
                for it in batch:
                    on_item(it)

        def drop(bid: str) -> None:
            live.remove(bid)
            weights[bid] = 0.0      # the zero-drain weight swap, forced

        def deal(batch: list[Any]) -> bool:
            """Route one slab/item to the highest-deficit live branch,
            failing over on a closed intake; False = no branch left."""
            n = float(len(batch))
            for bid in live:
                deficits[bid] += weights[bid] * n
            while live:
                # weights is read live: a zero-drain revision swaps new
                # (pre-normalized) shares in without stopping us
                pick = max(live, key=lambda bid: deficits[bid])
                try:
                    if len(batch) == 1 and deal_batch <= 1:
                        queues[pick].put(batch[0])
                    else:
                        queues[pick].put_many(batch)
                    deficits[pick] -= n
                    return True
                except BufferClosed:
                    drop(pick)
            return False

        def replicate(batch: list[Any]) -> bool:
            """Mirror one batch down every live replica; a dead replica
            is dropped (the mirror promise re-prices to the survivors).
            False = every replica is gone."""
            fold(batch)             # each source item hashed once
            for bid in list(live):
                try:
                    queues[bid].put_many(batch)
                except BufferClosed:
                    drop(bid)
            return bool(live)

        def run() -> None:
            try:
                if mode == "mirror":
                    batch: list[Any] = []
                    for item in segment:
                        batch.append(item)
                        if len(batch) >= mirror_batch:
                            if not replicate(batch):
                                return
                            batch = []
                    if batch:
                        replicate(batch)
                    return
                if route == "steal":
                    # ONE shared intake: it only closes when the LAST
                    # branch died (ParallelBranchPipeline's contract), so
                    # a lone death needs no dispatcher action — survivors
                    # keep pulling and the dead branch's stranded items
                    # re-enter the same queue
                    shared = queues[order[0]]
                    if deal_batch > 1:
                        for wave in iter_segments(segment, deal_batch):
                            batch = list(wave)
                            fold(batch)
                            shared.put_many(batch)
                    else:
                        for item in segment:
                            on_item(item)
                            shared.put(item)
                    return
                if deal_batch > 1:
                    for wave in iter_segments(segment, deal_batch):
                        batch = list(wave)
                        fold(batch)
                        if not deal(batch):
                            return
                    return
                for item in segment:
                    on_item(item)
                    if not deal([item]):
                        return
            except BufferClosed:
                pass
            except Exception:
                # a raising SOURCE must fail the transfer, not silently
                # truncate it: record for the caller to re-raise after
                # the branches drain (parity with the staged path, where
                # a source error surfaces through the stage join)
                if err_out is not None:
                    err_out.append(traceback.format_exc())
            finally:
                for q in queues.values():
                    q.close()

        return run

    @staticmethod
    def _validated_intake(plan: TransferPlan,
                          window: Sequence[StageReport],
                          intake: dict[str, float],
                          workers_by_report: Mapping[str, int]
                          ) -> dict[str, float]:
        """Corroborate a live window's intake backpressure before replan
        sees it.

        The intake ratio measures where the dispatcher's *blocked time*
        landed — exact over a drained segment, but phase-noisy while the
        pipeline keeps running: a window that straddles a regime
        transition can charge a healthy branch with the frontier advance
        a degraded sibling caused (and its routing shadow makes that same
        healthy branch read as underdelivering, so the spurious flag
        turns into a spurious verdict).  A true culprit is also *slower
        per byte* on its own channel, and the window reports measure that
        directly — busy time (``elapsed*workers`` minus both stall sides)
        per byte, a per-item service quantity the scheduling phase cannot
        inflate.  ``workers_by_report`` maps a tagged report name to the
        worker count its stage *actually ran* this window — plan values
        would be wrong under an explicit ``workers`` override or right
        after a revision resized the pool.  Any flag-capable ratio whose
        branch is not clearly the slowest (``BUSY_CULPRIT_RATIO`` over
        the fastest) is zeroed, so the culprit rule only ever fires on
        corroborated backpressure."""
        busy_per_byte: dict[str, float] = {}
        for branch in plan.branches:
            busy = 0.0
            nbytes = 0
            for r in window:
                if "/" not in r.name:
                    continue
                bid = r.name.split("/", 1)[0]
                if bid != branch.branch_id:
                    continue
                wrk = workers_by_report.get(r.name, 1)
                busy += max(0.0, r.elapsed_s * wrk - r.stall_up_s
                            - r.stall_down_s - r.stall_window_s)
                nbytes += r.bytes
            if nbytes > 0 and busy > 0:
                busy_per_byte[branch.branch_id] = busy / nbytes
        if len(busy_per_byte) < 2:
            return intake
        fastest = min(busy_per_byte.values())
        out = dict(intake)
        for bid, ratio in intake.items():
            # a branch with NO byte evidence this window (too slow to
            # complete a single item) cannot be exonerated — infinite
            # busy-per-byte keeps its flag
            if (ratio >= STALL_THRESHOLD
                    and busy_per_byte.get(bid, float("inf"))
                    < BUSY_CULPRIT_RATIO * fastest):
                out[bid] = 0.0
        return out

    @staticmethod
    def _steal_intake(plan: TransferPlan,
                      window: Sequence[StageReport],
                      workers_by_report: Mapping[str, int]
                      ) -> dict[str, float]:
        """Per-branch attribution signal under work-stealing dispatch.

        A shared intake has no per-branch backpressure to measure (every
        branch pulls the same queue), so ``replan`` used to run
        evidence-free on the steal route.  What stealing *does* make
        observable is each branch's **pull rate at the shared intake** —
        bytes moved per busy worker-second this window (busy = elapsed x
        workers minus every stall side, the same quantity
        :meth:`_validated_intake` corroborates with, which the scheduling
        phase cannot inflate).  A branch pulling clearly slower than the
        fastest sibling is draining its own channel slower — exactly why
        it steals less.  The rate deficit maps onto the intake-ratio
        scale ``replan`` already consumes (0 = keeps pace with the
        fastest, -> 1 = pulls almost nothing), so the existing culprit
        rule (``_intake_culprits``: >= STALL_THRESHOLD and well above the
        floor) applies unchanged.  A branch with no completed item this
        window contributes nothing — it can be neither flagged nor
        exonerated without byte evidence."""
        rates: dict[str, float] = {}
        for branch in plan.branches:
            busy = 0.0
            nbytes = 0
            for r in window:
                if "/" not in r.name:
                    continue
                bid = r.name.split("/", 1)[0]
                if bid != branch.branch_id:
                    continue
                wrk = workers_by_report.get(r.name, 1)
                busy += max(0.0, r.elapsed_s * wrk - r.stall_up_s
                            - r.stall_down_s - r.stall_window_s)
                nbytes += r.bytes
            if busy > 0 and nbytes > 0:
                rates[branch.branch_id] = nbytes / busy
        if len(rates) < 2:
            return {}
        fastest = max(rates.values())
        if fastest <= 0:
            return {}
        return {bid: max(0.0, 1.0 - rate / fastest)
                for bid, rate in rates.items()}

    @staticmethod
    def _normalized_weights(branches: Sequence[BranchPlan]
                            ) -> dict[str, float]:
        """Traffic shares the dispatcher deals by (uniform fallback when
        a degenerate plan zeroes every weight)."""
        w = {b.branch_id: max(b.weight, 0.0) for b in branches}
        if sum(w.values()) <= 0:
            w = {bid: 1.0 for bid in w}
        return w

    def _parallel_live(
        self,
        source: Iterable[Any],
        deliver: Callable[[str, Any], bool],
        plan: TransferPlan,
        mode: str,
        route: str,
        transforms,
        capacity: Optional[int],
        workers: Optional[int],
        chunk: int,
        damping: float,
        digest: _StreamDigest,
        batch_items: Optional[int] = None,
        fleet=None,
    ) -> tuple[int, int, list[StageReport], int, TransferPlan]:
        """Zero-drain parallel path: queues, branch stages, and the
        dispatcher live for the whole transfer.  Revision checkpoints
        compute the window's branch-tagged evidence + split-node intake
        ratios, and apply the resulting plan delta to the running
        machinery — weights swap into the live dispatcher, stages and
        queues resize in place.  A bound ``fleet`` admission pushes
        arbiter re-grants through the same in-place machinery."""
        active = plan
        queues, pbp = self._branch_pipelines(active, transforms, capacity,
                                             workers, route, batch_items)
        weights = self._normalized_weights(active.branches)
        order = [b.branch_id for b in active.branches]
        # ordered plans are the latency-sensitive streams (decode token
        # fan-out): deliver per item instead of holding a batch
        mirror_batch = 1 if plan.ordered else MIRROR_BATCH
        deal_batch = self._deal_batch(active, batch_items)
        source_err: list[str] = []
        dispatch = threading.Thread(
            target=self._dispatch(iter(source), queues, weights, order,
                                  mode, digest, route, mirror_batch,
                                  source_err, deal_batch),
            name="branch-dispatch", daemon=True)
        pbp.start()
        dispatch.start()
        rebalances = [0]
        applied = [active]
        if fleet is not None:
            fleet_lock = threading.Lock()

            def _fleet_apply(new_plan, _delta) -> None:
                with fleet_lock:
                    d = plan_delta(applied[0], new_plan)
                    applied[0] = new_plan
                    if not d:
                        return
                    rebalances[0] += 1
                    for bid2, pipe in pbp.branches:
                        b = new_plan.branch(bid2)
                        for i, st in enumerate(pipe.stages):
                            hop = b.hop_for(i, st.name)
                            st.resize(capacity=capacity or hop.capacity,
                                      workers=workers or hop.workers,
                                      window_bytes=self._hop_window(hop),
                                      rtt_s=self._hop_rtt(hop),
                                      batch_items=self._hop_batch(
                                          hop, batch_items),
                                      **self._hop_retry(hop))
                    if route == "steal":
                        agg = sum(b.hops[0].capacity
                                  for b in new_plan.branches)
                        queues[order[0]].resize(capacity or max(1, agg))
                    else:
                        for b in new_plan.branches:
                            queues[b.branch_id].resize(b.hops[0].capacity)
                    weights.update(
                        self._normalized_weights(new_plan.branches))

            fleet.bind(_fleet_apply)
        # -- branch failover bookkeeping --------------------------------
        # the dispatcher already *routes around* a dead branch the moment
        # its intake closes (see _dispatch); what remains here is the
        # accounting side: zero the corpse's weight so replanning never
        # hands it traffic back, write its obituary into the plan
        # diagnosis (describe() shows the branch as `dead`), and — under
        # a fleet — tell the arbiter the branch's basin element died so
        # the member's grant re-levels instead of hanging
        dead_handled: set[str] = set()
        obituaries: dict[str, str] = {}

        def _absorb_deaths(force: bool = False) -> None:
            # cheap per-delivery guard; the authoritative set is re-read
            # under the pipeline's lock only when the hint fires
            if not force and len(pbp._dead) == len(dead_handled):
                return
            for bid2 in pbp.dead_branches():
                if bid2 in dead_handled:
                    continue
                dead_handled.add(bid2)
                weights[bid2] = 0.0
                err = pbp.branch_error(bid2)
                obituaries[bid2] = (f"branch-dead({err})" if err
                                    else "branch-dead")
                if fleet is not None:
                    b2 = active.branch(bid2)
                    if b2.private_tiers:
                        fleet.element_died(b2.private_tiers[-1])
            if obituaries:
                active.diagnosis.update(obituaries)

        items = 0
        nbytes = 0
        seen = 0            # attempted deliveries: the boundary clock —
        #                     a retired drainer-pool client must not
        #                     stretch every later revision window
        replans = 0
        prev_cum: list[StageReport] = []
        prev_stall = {bid: 0.0 for bid in queues}
        t_prev = self._clock()
        # a boundary is chunk *source* items; mirror counts deliveries
        # once per branch
        step = chunk * (len(order) if mode == "mirror" else 1)
        boundary = step
        for bid, item in _drain_batched(pbp.output):
            seen += 1
            _absorb_deaths()
            if deliver(bid, item):
                items += 1
                nbytes += _default_sizeof(item)
            if step and seen >= boundary:
                boundary += step
                t_now = self._clock()
                t_win = t_now - t_prev
                t_prev = t_now
                cum = pbp.reports()
                window = delta_reports(cum, prev_cum)
                prev_cum = cum
                for _bid, pipe in pbp.branches:
                    for st in pipe.stages:
                        st.reset_service_reservoirs()
                intake: dict[str, float] = {}
                if route != "steal":
                    for qbid, q in queues.items():
                        stall = q.stats.producer_stall_s
                        intake[qbid] = ((stall - prev_stall[qbid]) / t_win
                                        if t_win > 0 else 0.0)
                        prev_stall[qbid] = stall
                if not window:
                    continue
                stage_workers = {
                    f"{bid2}/{st.name}": st.workers
                    for bid2, pipe in pbp.branches
                    for st in pipe.stages}
                if route == "steal":
                    # pull-based routing self-balances within the window
                    # and a shared intake has no per-branch backpressure;
                    # the per-branch PULL RATES at that intake are the
                    # attribution signal replan consumes instead
                    intake = self._steal_intake(active, window,
                                                stage_workers)
                elif intake:
                    intake = self._validated_intake(active, window, intake,
                                                    stage_workers)
                revised = _replan(active, window, damping=damping,
                                  intake_ratio=intake)
                delta = plan_delta(active, revised)
                active = revised
                if obituaries:
                    # replan rebuilt the diagnosis; obituaries persist
                    active.diagnosis.update(obituaries)
                if delta:
                    replans += 1
                    for bid2, pipe in pbp.branches:
                        b = active.branch(bid2)
                        for i, st in enumerate(pipe.stages):
                            hop = b.hop_for(i, st.name)
                            st.resize(capacity=capacity or hop.capacity,
                                      workers=workers or hop.workers,
                                      window_bytes=self._hop_window(hop),
                                      rtt_s=self._hop_rtt(hop),
                                      batch_items=self._hop_batch(
                                          hop, batch_items),
                                      **self._hop_retry(hop))
                    if route == "steal":
                        agg = sum(b.hops[0].capacity
                                  for b in active.branches)
                        queues[order[0]].resize(capacity or max(1, agg))
                    else:
                        for b in active.branches:
                            queues[b.branch_id].resize(b.hops[0].capacity)
                    weights.update(self._normalized_weights(active.branches))
        if fleet is not None:
            fleet.unbind()
            active = applied[0]
            replans += rebalances[0]
        dispatch.join()
        if dead_handled or pbp.dead_branches():
            # failover form: survivors' completion is the success
            # criterion; join() would re-raise the corpses' errors
            pbp.wait()
        else:
            pbp.join()
        _absorb_deaths(force=True)
        merged = pbp.reports()
        if dead_handled:
            survivors = [b for b in order if b not in dead_handled]
            if not survivors:
                raise RuntimeError(
                    "every branch died: "
                    + "; ".join(obituaries[b]
                                for b in sorted(dead_handled)))
            # the corpses' debris: items they claimed but never
            # delivered (stranded mid-pipeline) plus — on the deal
            # route — items still parked in their private intake
            # queues.  Mirror mode skips re-moving: every survivor
            # already carries its own full copy of the stream.
            leftovers: list = []
            for bid2 in sorted(dead_handled):
                leftovers.extend(pbp.take_stranded(bid2))
                if route != "steal" and mode == "split":
                    try:
                        while True:
                            leftovers.extend(
                                queues[bid2].get_many(1 << 10))
                    except BufferClosed:
                        pass
            if leftovers and mode == "split":
                sbid = survivors[0]
                s_items, s_bytes, s_reports = self._salvage_pass(
                    active.branch(sbid), leftovers,
                    lambda it: deliver(sbid, it),
                    transforms, capacity, workers, batch_items)
                items += s_items
                nbytes += s_bytes
                merged = merged + s_reports
        if source_err:
            raise RuntimeError(f"transfer source failed:\n{source_err[0]}")
        return items, nbytes, merged, replans, active

    def _parallel_segmented(
        self,
        source: Iterable[Any],
        deliver: Callable[[str, Any], bool],
        plan: TransferPlan,
        mode: str,
        route: str,
        transforms,
        capacity: Optional[int],
        workers: Optional[int],
        chunk: int,
        damping: float,
        digest: _StreamDigest,
        batch_items: Optional[int] = None,
    ) -> tuple[int, int, list[StageReport], int, TransferPlan]:
        """Historical drain-per-segment parallel path (explicit
        ``drain_per_segment=True``): full teardown + rebuild at every
        boundary — the baseline the zero-drain path is measured against."""
        active = plan
        merged: list[StageReport] = []
        last_reports: list[StageReport] = []
        last_intake: dict[str, float] = {}
        replans = 0
        items = 0
        nbytes = 0
        for segment in iter_segments(iter(source), chunk):
            if last_reports:
                revised = _replan(active, last_reports,
                                  damping=damping,
                                  intake_ratio=last_intake)
                if plan_delta(active, revised):
                    replans += 1
                active = revised
            queues, pbp = self._branch_pipelines(active, transforms,
                                                 capacity, workers, route,
                                                 batch_items)
            weights = self._normalized_weights(active.branches)
            order = [b.branch_id for b in active.branches]
            source_err: list[str] = []
            dispatch = threading.Thread(
                target=self._dispatch(segment, queues, weights, order,
                                      mode, digest, route,
                                      1 if plan.ordered else MIRROR_BATCH,
                                      source_err,
                                      self._deal_batch(active, batch_items)),
                name="branch-dispatch", daemon=True)
            t_seg0 = self._clock()
            pbp.start()
            dispatch.start()
            for bid, item in _drain_batched(pbp.output):
                if deliver(bid, item):
                    items += 1
                    nbytes += _default_sizeof(item)
            dispatch.join()
            pbp.join()
            if source_err:
                raise RuntimeError(
                    f"transfer source failed:\n{source_err[0]}")
            t_seg = self._clock() - t_seg0
            last_reports = pbp.reports()
            # the split node's per-branch backpressure: the attribution
            # signal replan uses to single out a slow branch (§2.2); the
            # steal route derives it from per-branch pull rates instead
            # (a shared intake backpressures nobody in particular)
            if route == "steal":
                stage_workers = {
                    f"{bid}/{st.name}": st.workers
                    for bid, pipe in pbp.branches
                    for st in pipe.stages}
                last_intake = self._steal_intake(active, last_reports,
                                                 stage_workers)
            else:
                last_intake = {
                    bid: (q.stats.producer_stall_s / t_seg
                          if t_seg > 0 else 0.0)
                    for bid, q in queues.items()}
            merged = merge_reports([merged, last_reports])
        return items, nbytes, merged, replans, active

    def parallel_transfer(
        self,
        source: Iterable[Any],
        sink: Callable[[Any], None] | Mapping[str, Callable[[Any], None]],
        *,
        plan: Optional[TransferPlan] = None,
        mode: str = "split",
        route: str = "deal",
        transforms: Sequence[tuple[str, Callable[[Any], Any]]]
        | Mapping[str, Sequence[tuple[str, Callable[[Any], Any]]]] = (),
        capacity: Optional[int] = None,
        workers: Optional[int] = None,
        checksum: Optional[bool] = None,
        replan_every_items: int = 0,
        replan_damping: float = 0.5,
        drain_per_segment: bool = False,
        drainer_pool: bool = False,
        batch_items: Optional[int] = None,
        fleet=None,
    ) -> TransferReport:
        """Move a stream down every branch of a multipath plan at once.

        ``fleet`` registers the transfer with a
        :class:`~repro.core.fleet.FleetArbiter` exactly as in
        :meth:`bulk_transfer`: the admitted plan is the default ``plan``,
        arbiter re-grants resize branches/queues/weights in place
        mid-stream, the promise is the time-averaged grant, and the
        share is released on completion (``replan_every_items`` must
        stay 0 — the arbiter owns revision).

        One stage pipeline per :class:`~repro.core.planner.BranchPlan`; a
        dispatcher thread plays the split node.  ``mode="split"`` routes
        each item down exactly one branch (weighted by the plan's branch
        traffic shares — aggregate throughput is the sum over branches);
        ``mode="mirror"`` replicates every item down every branch (the
        dual-tier checkpoint / decode fan-out case — the dispatcher paces
        at the slowest branch, which is the point: a mirror is only as
        durable as its slowest copy).

        ``route`` picks the split-mode routing discipline:
        ``"deal"`` (default) is the deterministic weighted-deficit
        round-robin over the plan's branch weights; ``"steal"`` is
        pull-based work stealing — every branch pulls one shared intake
        queue, so a transiently slow branch stops accumulating queued
        items *within* a segment instead of waiting for the next weight
        rebalance, at the cost of scripted routing determinism.

        ``transforms`` applies to every branch, or a mapping
        ``branch_id -> transforms`` gives each branch its own chain (a
        mirrored save writes different directories per branch).  ``sink``
        likewise: one callable for all deliveries, or per-branch.
        Integrity (``checksum``) hashes each *source* item once at the
        split node, overlapping branch transit.

        ``replan_every_items > 0`` revises the plan online from
        branch-tagged window reports: a degraded branch gets its verdict
        in ``plan.diagnosis["<branch>/<hop>"]`` and loses traffic share
        to healthy branches (split mode).  The revision applies
        **zero-drain** — weights swap into the live dispatcher, stages
        and queues resize in place (``drain_per_segment=True`` restores
        the historical teardown-per-segment behaviour).

        ``drainer_pool=True`` routes deliveries through a per-branch
        drainer pool (one small buffer + drainer thread per branch), so
        one blocking client write no longer serializes its siblings at
        the merge buffer; a single shared ``sink`` callable must then be
        thread-safe.  Items/bytes in the returned report count
        *deliveries* (mirror mode moves each item once per branch).

        ``batch_items`` overrides the per-hop slab size on every branch
        (1 forces the per-item path; None defers to the plan)."""
        if mode not in ("split", "mirror"):
            raise ValueError(f"unknown parallel mode {mode!r}")
        if route not in ("deal", "steal"):
            raise ValueError(f"unknown split route {route!r}")
        if route == "steal" and mode != "split":
            raise ValueError("route='steal' requires mode='split'")
        if fleet is not None:
            if replan_every_items:
                raise ValueError(
                    "a fleet-managed transfer delegates plan revision to "
                    "the arbiter; replan_every_items must be 0")
            if fleet.status != "admitted":
                raise ValueError(
                    f"fleet admission {fleet.name!r} is {fleet.status}"
                    f"{': ' + fleet.reason if fleet.reason else ''}")
            if plan is None:
                plan = fleet.plan
        own_plan = plan is None
        plan = plan if plan is not None else self.plan
        if plan is None or not plan.branches:
            raise ValueError("parallel_transfer needs a branch-aware plan")
        do_sum = self.config.checksum if checksum is None else checksum
        digest = _StreamDigest(do_sum, placement=plan.checksum_placement)

        def sink_for(bid: str) -> Callable[[Any], None]:
            if isinstance(sink, Mapping):
                return sink[bid]
            return sink

        pool: Optional[_DrainerPool] = None
        if drainer_pool:
            pool = _DrainerPool(
                {b.branch_id: sink_for(b.branch_id) for b in plan.branches},
                {b.branch_id: capacity or b.hops[-1].capacity
                 for b in plan.branches},
                self._clock)

        def deliver(bid: str, item: Any) -> bool:
            if pool is not None:
                return pool.submit(bid, item)
            sink_for(bid)(item)
            return True

        chunk = replan_every_items
        t0 = self._clock()
        try:
            # the live (zero-drain) machinery is the default — it is
            # also what branch failover rides (the dispatcher re-routes
            # around a dead branch and the tail sweep salvages its
            # debris; the segmented baseline keeps the historical
            # fail-hard contract).  A fleet admission always takes the
            # live path: re-grants need persistent machinery to resize.
            if drain_per_segment and fleet is None:
                items, nbytes, merged, replans, active = \
                    self._parallel_segmented(
                        source, deliver, plan, mode, route, transforms,
                        capacity, workers, chunk, replan_damping, digest,
                        batch_items)
            else:
                items, nbytes, merged, replans, active = \
                    self._parallel_live(
                        source, deliver, plan, mode, route, transforms,
                        capacity, workers, chunk, replan_damping, digest,
                        batch_items, fleet)
        except BaseException:
            # the primary failure wins: drain the pool for cleanup but do
            # not let a retired client's error replace the real traceback
            if fleet is not None:
                fleet.release()
            if pool is not None:
                try:
                    pool.close()
                except RuntimeError:
                    pass
            raise
        if pool is not None:
            pool.close()
        elapsed = self._clock() - t0
        if fleet is not None:
            fleet.release()
        self.last_plan = active
        if own_plan and self.plan is not None:
            self.plan = active
        if fleet is not None:
            planned = fleet.mean_granted(t0, t0 + elapsed)
        elif mode == "mirror":
            # replication paces at the slowest branch: every branch moves
            # every item, so the honest promise is n x the weakest rate,
            # not the split-mode aggregate.  A replica that DIED
            # mid-stream leaves the promise to the survivors — the
            # mirror re-prices to n_live x the weakest LIVE rate
            dead = {b for b, v in active.diagnosis.items()
                    if v.startswith("branch-dead")}
            rates = [b.rate_bytes_per_s for b in plan.branches
                     if b.branch_id not in dead]
            planned = len(rates) * min(rates) if rates else 0.0
        else:
            planned = plan.planned_bytes_per_s
        return self._record(TransferReport(
            mode=f"parallel-{mode}",
            items=items,
            bytes=nbytes,
            elapsed_s=elapsed,
            stage_reports=merged,
            checksum=digest.hexdigest(),
            planned_bytes_per_s=planned,
            replans=replans,
            path=active.path if active is not None else None,
        ))

    # -- direct (un-staged) path, for comparison -------------------------------

    def direct_transfer(
        self,
        source: Iterable[Any],
        sink: Callable[[Any], None],
        *,
        checksum: Optional[bool] = None,
    ) -> TransferReport:
        """Synchronous, un-staged copy loop — the 'aws-cli' style baseline of
        Fig. 11: every hop serializes with every other hop.  Used by
        benchmarks to quantify the staged-vs-direct fidelity delta."""
        do_sum = self.config.checksum if checksum is None else checksum
        digest = _StreamDigest(do_sum)
        items = 0
        nbytes = 0
        t0 = self._clock()
        for item in source:
            digest.add(item)                  # serial hash: the baseline
            sink(item)
            items += 1
            nbytes += _default_sizeof(item)
        elapsed = self._clock() - t0
        planned = self.basin.achievable_throughput() if self.basin else None
        return self._record(TransferReport(
            mode="direct",
            items=items,
            bytes=nbytes,
            elapsed_s=elapsed,
            stage_reports=[],
            checksum=digest.hexdigest(),
            planned_bytes_per_s=planned,
            path="direct",
        ))
