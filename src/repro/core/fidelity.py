"""Fidelity gap / roofline engine — the paper's headline metric, quantified.

Paper section 1 defines the *fidelity gap*: the discrepancy between
theoretical link capacity and actual application-level throughput.  For a
compiled TPU step the same three-way decomposition applies (DESIGN.md
section 6):

    t_compute    = FLOPs            / peak FLOP/s          (the MXU "link")
    t_memory     = HBM bytes        / HBM bandwidth        (the HBM "link")
    t_collective = collective bytes / ICI link bandwidth   (the ICI "link")

The dominant term is the bottleneck tier of the on-chip drainage basin;
the ratio of useful model FLOPs to compiled FLOPs is the fidelity of the
compute path itself (catching remat/redundancy waste).

``jax``'s ``compiled.cost_analysis()`` reports *per-device* numbers and
counts ``while`` bodies **once** (verified empirically — see DESIGN.md),
which under-counts scan-over-layers models by a factor of ``n_layers``.
This module therefore walks the optimized HLO text directly:

* per-computation symbol tables give every operand shape,
* ``dot`` FLOPs   = 2 x |out| x contracted-dims (from the lhs shape),
* bytes accessed  = operand+output bytes of every materializing top-level
  op (fusion internals are free — fusion boundaries approximate HBM
  traffic, the TPU accounting convention),
* ``while`` ops carry ``backend_config known_trip_count`` — costs inside
  the body are multiplied through, recursively,
* collective ops (incl. ``-start`` async forms) are tallied separately
  with their replica-group sizes.

Everything is pure text parsing: no device execution, usable on the
CPU-only dry-run container against the 512-device emulated mesh.
"""

from __future__ import annotations

import dataclasses
import json
import math
import re
from collections import Counter, defaultdict
from typing import Any, Optional

# ---------------------------------------------------------------------------
# Hardware model (TPU v5e, per task spec)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class HardwareSpec:
    name: str = "tpu-v5e"
    peak_flops: float = 197e12       # bf16 FLOP/s per chip
    hbm_bandwidth: float = 819e9     # bytes/s per chip
    ici_bandwidth: float = 50e9      # bytes/s per ICI link (~spec)
    hbm_bytes: float = 16 * 1024**3  # capacity per chip


TPU_V5E = HardwareSpec()


# ---------------------------------------------------------------------------
# HLO text parsing
# ---------------------------------------------------------------------------

_DTYPE_BYTES = {
    "pred": 1, "s4": 0.5, "u4": 0.5, "s8": 1, "u8": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16,
    "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3b11fnuz": 1, "f8e4m3": 1,
    "token": 0, "opaque": 0,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")

_COLLECTIVES = {
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute", "collective-broadcast", "ragged-all-to-all",
}

# top-level opcodes that materialize HBM traffic (fusion internals are free)
_MATERIALIZING = _COLLECTIVES | {
    "fusion", "dot", "convolution", "custom-call", "copy", "reduce", "sort",
    "gather", "scatter", "dynamic-slice", "dynamic-update-slice", "broadcast",
    "iota", "transpose", "concatenate", "slice", "pad", "reverse", "rng",
    "reduce-window", "select-and-scatter", "cholesky", "triangular-solve",
    "convert", "select", "compare", "add", "multiply", "subtract", "divide",
    "exponential", "tanh", "log", "rsqrt", "sqrt", "power", "maximum",
    "minimum", "negate", "abs", "clamp", "floor", "ceil", "sign",
}


def _leaf_shapes(shape_str: str) -> list[tuple[str, tuple[int, ...]]]:
    """All array leaves of a (possibly tuple) HLO shape string."""
    out = []
    for m in _SHAPE_RE.finditer(shape_str):
        dtype, dims = m.group(1), m.group(2)
        if dtype not in _DTYPE_BYTES:
            continue
        shape = tuple(int(d) for d in dims.split(",") if d) if dims else ()
        out.append((dtype, shape))
    return out


def _shape_bytes(shape_str: str) -> int:
    total = 0.0
    for dtype, shape in _leaf_shapes(shape_str):
        total += _DTYPE_BYTES[dtype] * math.prod(shape) if shape else _DTYPE_BYTES[dtype]
    return int(total)


@dataclasses.dataclass
class _Instr:
    name: str
    shape_str: str
    opcode: str
    operands: list[str]
    line: str

    def attr(self, pattern: str) -> Optional[str]:
        m = re.search(pattern, self.line)
        return m.group(1) if m else None


@dataclasses.dataclass
class _Computation:
    name: str
    instrs: list[_Instr]
    symbols: dict[str, str]  # instr name -> shape string


_COMP_HEADER_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-~]+)\s*\(.*\)\s*->.*\{")
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w\.\-~]+)\s*=\s*((?:\([^)]*\))|(?:\S+))\s+([\w\-]+)\("
)


def parse_hlo_module(text: str) -> tuple[dict[str, _Computation], Optional[str], int]:
    """Parse optimized HLO text into computations.

    Returns (computations, entry_name, num_partitions).
    """
    num_partitions = 1
    m = re.search(r"num_partitions=(\d+)", text)
    if m:
        num_partitions = int(m.group(1))

    comps: dict[str, _Computation] = {}
    entry: Optional[str] = None
    current: Optional[_Computation] = None
    for raw in text.splitlines():
        line = raw.rstrip()
        if current is None:
            hm = _COMP_HEADER_RE.match(line)
            if hm:
                name = hm.group(1)
                current = _Computation(name=name, instrs=[], symbols={})
                if line.startswith("ENTRY"):
                    entry = name
            continue
        if line == "}":
            comps[current.name] = current
            current = None
            continue
        im = _INSTR_RE.match(line)
        if not im:
            continue
        name, shape_str, opcode = im.group(1), im.group(2), im.group(3)
        # operand names: %refs inside the first balanced paren group after opcode
        paren_start = line.find(opcode + "(") + len(opcode)
        depth, end = 0, len(line)
        for i in range(paren_start, len(line)):
            if line[i] == "(":
                depth += 1
            elif line[i] == ")":
                depth -= 1
                if depth == 0:
                    end = i
                    break
        operand_region = line[paren_start:end + 1]
        operands = re.findall(r"%([\w\.\-~]+)", operand_region)
        instr = _Instr(name=name, shape_str=shape_str, opcode=opcode,
                       operands=operands, line=line)
        current.instrs.append(instr)
        current.symbols[name] = shape_str
    return comps, entry, num_partitions


def _dot_flops(instr: _Instr, symbols: dict[str, str]) -> float:
    out_elems = sum(math.prod(s) if s else 1 for _, s in _leaf_shapes(instr.shape_str))
    cdims_m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", instr.line)
    if not cdims_m or not instr.operands:
        return 2.0 * out_elems  # degenerate
    lhs_shape_str = symbols.get(instr.operands[0], "")
    leaves = _leaf_shapes(lhs_shape_str)
    if not leaves:
        return 2.0 * out_elems
    lhs_shape = leaves[0][1]
    k = 1
    for d in cdims_m.group(1).split(","):
        if d and int(d) < len(lhs_shape):
            k *= lhs_shape[int(d)]
    return 2.0 * out_elems * k


def _group_size(instr: _Instr, num_partitions: int) -> int:
    m = re.search(r"replica_groups=\[([\d,]+)\]<=", instr.line)
    if m:
        dims = [int(x) for x in m.group(1).split(",")]
        return dims[-1] if dims else num_partitions
    m = re.search(r"replica_groups=\{\{([\d,]+)\}", instr.line)
    if m:
        return len(m.group(1).split(","))
    return num_partitions


@dataclasses.dataclass
class HloCost:
    """Per-device cost totals extracted from one compiled SPMD module."""

    flops: float = 0.0
    bytes_accessed: float = 0.0
    collective_bytes: float = 0.0                     # sum of operand bytes (spec formula)
    collective_link_bytes: float = 0.0                # ring-model per-device link traffic
    collective_by_type: dict[str, float] = dataclasses.field(default_factory=dict)
    collective_count: dict[str, int] = dataclasses.field(default_factory=dict)
    flops_by_op: dict[str, float] = dataclasses.field(default_factory=dict)
    flashable_bytes: float = 0.0      # bytes inside kernel-fusable regions
    flashable_flops: float = 0.0
    bytes_by_op: dict[str, float] = dataclasses.field(default_factory=dict)
    num_partitions: int = 1
    unknown_trip_counts: int = 0

    def merge_scaled(self, other: "HloCost", mult: float) -> None:
        self.flops += other.flops * mult
        self.bytes_accessed += other.bytes_accessed * mult
        self.collective_bytes += other.collective_bytes * mult
        self.collective_link_bytes += other.collective_link_bytes * mult
        for k, v in other.collective_by_type.items():
            self.collective_by_type[k] = self.collective_by_type.get(k, 0.0) + v * mult
        for k, v in other.collective_count.items():
            self.collective_count[k] = self.collective_count.get(k, 0) + int(v * mult)
        for k, v in other.flops_by_op.items():
            self.flops_by_op[k] = self.flops_by_op.get(k, 0.0) + v * mult
        self.flashable_bytes += other.flashable_bytes * mult
        self.flashable_flops += other.flashable_flops * mult
        for k, v in other.bytes_by_op.items():
            self.bytes_by_op[k] = self.bytes_by_op.get(k, 0.0) + v * mult
        self.unknown_trip_counts += other.unknown_trip_counts


# ring-model per-device link bytes factor for `n`-way collective on `b` operand bytes
def _link_bytes(opcode: str, operand_bytes: float, output_bytes: float, g: int) -> float:
    if g <= 1:
        return 0.0
    frac = (g - 1) / g
    if opcode == "all-reduce":
        return 2.0 * operand_bytes * frac          # reduce-scatter + all-gather ring
    if opcode == "all-gather":
        return output_bytes * frac                 # each device receives (g-1)/g of out
    if opcode == "reduce-scatter":
        return operand_bytes * frac
    if opcode in ("all-to-all", "ragged-all-to-all"):
        return operand_bytes * frac
    if opcode == "collective-permute":
        return operand_bytes
    if opcode == "collective-broadcast":
        return output_bytes
    return operand_bytes


def _fusion_flops(comp: _Computation, comps: dict[str, _Computation]) -> float:
    """FLOPs of dots living inside a fusion body (bytes are free inside)."""
    total = 0.0
    for ins in comp.instrs:
        if ins.opcode == "dot":
            total += _dot_flops(ins, comp.symbols)
        elif ins.opcode == "fusion":
            called = ins.attr(r"calls=%([\w\.\-~]+)")
            if called and called in comps:
                total += _fusion_flops(comps[called], comps)
    return total


def _op_label(instr: _Instr) -> str:
    m = re.search(r'op_name="([^"]+)"', instr.line)
    if not m:
        return instr.opcode
    parts = m.group(1).split("/")
    return "/".join(parts[:3]) if parts else instr.opcode


def _walk(comp: _Computation, comps: dict[str, _Computation],
          num_partitions: int, cost: HloCost, mult: float) -> None:
    for ins in comp.instrs:
        op = ins.opcode
        base = op[:-6] if op.endswith("-start") else op
        out_bytes = _shape_bytes(ins.shape_str)
        opnd_bytes = sum(_shape_bytes(comp.symbols.get(o, "")) for o in ins.operands)
        flashable = "flashable" in ins.line

        if base in _COLLECTIVES:
            g = _group_size(ins, num_partitions)
            cost.collective_bytes += opnd_bytes * mult
            cost.collective_link_bytes += _link_bytes(base, opnd_bytes, out_bytes, g) * mult
            cost.collective_by_type[base] = (
                cost.collective_by_type.get(base, 0.0) + opnd_bytes * mult)
            cost.collective_count[base] = cost.collective_count.get(base, 0) + max(1, int(mult))
            cost.bytes_accessed += (opnd_bytes + out_bytes) * mult
            continue
        if op.endswith("-done"):
            continue
        if op == "while":
            tc = ins.attr(r'known_trip_count[^}]*?"n":"(\d+)"')
            if tc is None:
                cost.unknown_trip_counts += 1
                trip = 1.0
            else:
                trip = float(tc)
            body = ins.attr(r"body=%([\w\.\-~]+)")
            cond = ins.attr(r"condition=%([\w\.\-~]+)")
            if body and body in comps:
                _walk(comps[body], comps, num_partitions, cost, mult * trip)
            if cond and cond in comps:
                _walk(comps[cond], comps, num_partitions, cost, mult * trip)
            continue
        if op == "dynamic-update-slice":
            # XLA executes dus in place (input/output aliasing): traffic is
            # the update read + written, not the whole buffer copied.
            first = _shape_bytes(comp.symbols.get(ins.operands[0], "")) \
                if ins.operands else 0
            upd = max(opnd_bytes - first, 0)
            cost.bytes_accessed += 2 * upd * mult
            lblb = _op_label(ins)
            cost.bytes_by_op[lblb] = cost.bytes_by_op.get(lblb, 0.0) + 2 * upd * mult
            if flashable:
                cost.flashable_bytes += 2 * upd * mult
            continue
        if op == "conditional":
            for branch in re.findall(r"%([\w\.\-~]+)", ins.line.split("branch_computations", 1)[-1]) \
                    if "branch_computations" in ins.line else []:
                if branch in comps:
                    _walk(comps[branch], comps, num_partitions, cost, mult)
            continue
        if op == "call" or op == "async-start":
            called = ins.attr(r"(?:to_apply|calls|called_computation)=%([\w\.\-~]+)")
            if called and called in comps:
                _walk(comps[called], comps, num_partitions, cost, mult)
            continue
        if op == "fusion":
            called = ins.attr(r"calls=%([\w\.\-~]+)")
            f = _fusion_flops(comps[called], comps) if called and called in comps else 0.0
            if f:
                cost.flops += f * mult
                lbl = _op_label(ins)
                cost.flops_by_op[lbl] = cost.flops_by_op.get(lbl, 0.0) + f * mult
                if flashable:
                    cost.flashable_flops += f * mult
            cost.bytes_accessed += (opnd_bytes + out_bytes) * mult
            lblb = _op_label(ins)
            cost.bytes_by_op[lblb] = cost.bytes_by_op.get(lblb, 0.0) + (opnd_bytes + out_bytes) * mult
            if flashable:
                cost.flashable_bytes += (opnd_bytes + out_bytes) * mult
            continue
        if op == "dot":
            f = _dot_flops(ins, comp.symbols)
            cost.flops += f * mult
            lbl = _op_label(ins)
            cost.flops_by_op[lbl] = cost.flops_by_op.get(lbl, 0.0) + f * mult
            cost.bytes_accessed += (opnd_bytes + out_bytes) * mult
            cost.bytes_by_op[lbl] = cost.bytes_by_op.get(lbl, 0.0) + (opnd_bytes + out_bytes) * mult
            if flashable:
                cost.flashable_flops += f * mult
                cost.flashable_bytes += (opnd_bytes + out_bytes) * mult
            continue
        if op in _MATERIALIZING:
            cost.bytes_accessed += (opnd_bytes + out_bytes) * mult
            lblb = _op_label(ins)
            cost.bytes_by_op[lblb] = cost.bytes_by_op.get(lblb, 0.0) + (opnd_bytes + out_bytes) * mult
            if flashable:
                cost.flashable_bytes += (opnd_bytes + out_bytes) * mult


def analyze_hlo_text(text: str) -> HloCost:
    """Walk one compiled SPMD module; return per-device cost totals."""
    comps, entry, num_partitions = parse_hlo_module(text)
    cost = HloCost(num_partitions=num_partitions)
    if entry and entry in comps:
        _walk(comps[entry], comps, num_partitions, cost, 1.0)
    return cost


# ---------------------------------------------------------------------------
# Roofline report
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class RooflineReport:
    """Three-term roofline for one (arch x shape x mesh) cell."""

    label: str
    n_devices: int
    flops_per_device: float
    bytes_per_device: float
    collective_bytes_per_device: float       # spec formula (operand-bytes sum)
    collective_link_bytes_per_device: float  # ring model
    t_compute: float
    t_memory: float                          # flash-adjusted (headline)
    t_collective: float
    t_memory_raw: float = 0.0                # unfused-HLO memory term
    flashable_bytes_per_device: float = 0.0
    flash_ideal_bytes_per_device: float = 0.0
    model_flops: Optional[float] = None      # 6*N*D global useful FLOPs
    hw: HardwareSpec = TPU_V5E
    collective_by_type: dict[str, float] = dataclasses.field(default_factory=dict)
    memory_per_device_bytes: Optional[float] = None  # from memory_analysis()
    unknown_trip_counts: int = 0
    extras: dict[str, Any] = dataclasses.field(default_factory=dict)

    @property
    def dominant(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def step_time_s(self) -> float:
        """Roofline step time under perfect overlap = max of the terms."""
        return max(self.t_compute, self.t_memory, self.t_collective)

    @property
    def roofline_fraction(self) -> float:
        """How close the step is to being compute-bound at peak: 1.0 means
        the MXU term dominates (no fidelity gap on the chip's fast path)."""
        return self.t_compute / self.step_time_s if self.step_time_s > 0 else 0.0

    @property
    def useful_compute_fraction(self) -> Optional[float]:
        """MODEL_FLOPS / HLO_FLOPs (global) — catches remat/redundant work."""
        if self.model_flops is None:
            return None
        total = self.flops_per_device * self.n_devices
        return self.model_flops / total if total > 0 else None

    @property
    def fidelity_gap(self) -> float:
        """Paper section 1 gap for the step: 1 - achieved/peak on the
        dominant resource (i.e. how much of the provisioned roofline the
        non-dominant resources waste is 0 by definition; the gap is in the
        compute term's distance to the envelope)."""
        return 1.0 - self.roofline_fraction

    def to_json(self) -> dict[str, Any]:
        d = dataclasses.asdict(self)
        d.pop("hw")
        d["hw_name"] = self.hw.name
        d["dominant"] = self.dominant
        d["step_time_s"] = self.step_time_s
        d["roofline_fraction"] = self.roofline_fraction
        d["useful_compute_fraction"] = self.useful_compute_fraction
        return d

    def summary(self) -> str:
        mf = (f" useful={self.useful_compute_fraction:.2f}"
              if self.useful_compute_fraction is not None else "")
        return (
            f"{self.label}: compute {self.t_compute*1e3:.2f} ms | "
            f"memory {self.t_memory*1e3:.2f} ms | "
            f"collective {self.t_collective*1e3:.2f} ms | "
            f"dominant={self.dominant} roofline={self.roofline_fraction:.2f}{mf}"
        )


def roofline(
    cost: HloCost,
    *,
    label: str = "",
    n_devices: Optional[int] = None,
    model_flops: Optional[float] = None,
    memory_per_device_bytes: Optional[float] = None,
    flash_ideal_bytes_global: Optional[float] = None,
    hw: HardwareSpec = TPU_V5E,
) -> RooflineReport:
    """Build the three-term roofline from per-device HLO costs.

    ``collective term`` uses the spec's formula: summed collective operand
    bytes (per device, i.e. global/chips) over per-chip link bandwidth.

    ``flash_ideal_bytes_global``: if given, the memory term substitutes
    the kernel-fusable regions' raw HLO traffic with the fused kernel's
    ideal IO (q/k/v/o only) — the TPU-real number once the Pallas
    flash-attention / SSD kernels replace the unfused oracle graphs.  The
    raw term is kept alongside (t_memory_raw).
    """
    n = n_devices or cost.num_partitions
    t_compute = cost.flops / hw.peak_flops
    t_memory_raw = cost.bytes_accessed / hw.hbm_bandwidth
    if flash_ideal_bytes_global is not None:
        ideal_dev = flash_ideal_bytes_global / n
        adj_bytes = max(cost.bytes_accessed - cost.flashable_bytes, 0.0) + ideal_dev
        t_memory = adj_bytes / hw.hbm_bandwidth
        flash_dev = ideal_dev
    else:
        t_memory = t_memory_raw
        flash_dev = 0.0
    t_collective = cost.collective_bytes / hw.ici_bandwidth
    return RooflineReport(
        label=label,
        n_devices=n,
        flops_per_device=cost.flops,
        bytes_per_device=cost.bytes_accessed,
        collective_bytes_per_device=cost.collective_bytes,
        collective_link_bytes_per_device=cost.collective_link_bytes,
        t_compute=t_compute,
        t_memory=t_memory,
        t_collective=t_collective,
        t_memory_raw=t_memory_raw,
        flashable_bytes_per_device=cost.flashable_bytes,
        flash_ideal_bytes_per_device=flash_dev,
        model_flops=model_flops,
        hw=hw,
        collective_by_type=dict(cost.collective_by_type),
        memory_per_device_bytes=memory_per_device_bytes,
        unknown_trip_counts=cost.unknown_trip_counts,
    )


def model_flops_dense(n_params: float, n_tokens: float, *, backward: bool = True) -> float:
    """6*N*D (train) or 2*N*D (inference) useful-FLOPs convention."""
    return (6.0 if backward else 2.0) * n_params * n_tokens
