"""Core: the paper's contribution as composable modules.

* :mod:`repro.core.basin` — Drainage Basin Pattern (analytic path model)
* :mod:`repro.core.burst_buffer` — low-jitter staging buffer
* :mod:`repro.core.staging` — staging workers / pipelines
* :mod:`repro.core.mover` — unified bulk/streaming data mover
* :mod:`repro.core.planner` — TransferPlan engine: basin -> staging parameters
* :mod:`repro.core.fleet` — cross-plan rate arbitration over one shared basin
* :mod:`repro.core.telemetry` — cross-layer TransferReport registry
* :mod:`repro.core.fidelity` — fidelity-gap / roofline engine over compiled HLO
* :mod:`repro.core.codesign` — co-design plan enumeration + analytic ranking
"""

from .basin import (
    ApplianceTier,
    BottleneckReport,
    DrainageBasin,
    Link,
    Tier,
    TierKind,
    checkpoint_basin,
    daily_volume_bytes,
    decode_stream_basin,
    paper_basin,
    recommend_tier,
    tpu_input_basin,
    GBPS,
    MIB,
    GIB,
    TIB,
)
from .burst_buffer import BufferClosed, BufferStats, BurstBuffer
from .codesign import (
    CodesignPlan,
    PlanPrediction,
    WorkloadSpec,
    enumerate_plans,
    predict,
    rank_plans,
    workload_from_config,
)
from .fidelity import (
    HardwareSpec,
    HloCost,
    RooflineReport,
    TPU_V5E,
    analyze_hlo_text,
    model_flops_dense,
    roofline,
)
from .fleet import DEFAULT_CLASSES, Admission, FleetArbiter
from .mover import MoverConfig, TransferReport, UnifiedDataMover
from .planner import (HopPlan, HopRevision, PlanDelta, TransferPlan,
                      plan_delta, plan_transfer, replan)
from .staging import Stage, StagePipeline, StageReport
from .telemetry import LayerSummary, TelemetryRegistry, get_registry

__all__ = [
    "ApplianceTier", "BottleneckReport", "DrainageBasin", "Link", "Tier",
    "TierKind", "checkpoint_basin", "daily_volume_bytes",
    "decode_stream_basin", "paper_basin", "recommend_tier",
    "tpu_input_basin", "GBPS", "MIB", "GIB", "TIB",
    "BufferClosed", "BufferStats", "BurstBuffer",
    "CodesignPlan", "PlanPrediction", "WorkloadSpec", "enumerate_plans",
    "predict", "rank_plans", "workload_from_config",
    "HardwareSpec", "HloCost", "RooflineReport", "TPU_V5E",
    "analyze_hlo_text", "model_flops_dense", "roofline",
    "DEFAULT_CLASSES", "Admission", "FleetArbiter",
    "MoverConfig", "TransferReport", "UnifiedDataMover",
    "HopPlan", "HopRevision", "PlanDelta", "TransferPlan", "plan_delta",
    "plan_transfer", "replan",
    "LayerSummary", "TelemetryRegistry", "get_registry",
    "Stage", "StagePipeline", "StageReport",
]
