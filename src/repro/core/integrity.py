"""Stream integrity and wire compression — the §3.4 compute budget,
placeable on host or accelerator.

The paper's §3.4 point is that integrity/encryption are *budgeted
compute inside the data path*, and "Demystifying the Performance of Data
Transfers" shows what happens when that budget lands on the wrong
resource: a host-side hash pins an otherwise line-rate hop at the CPU's
hash throughput.  This module is the placement seam:

* :class:`StreamDigest` with ``placement="host"`` is the historical
  order-independent stream checksum — XOR of per-item SHA-256 digests,
  bit-identical (format and value) with every prior release.
* ``placement="accel"`` computes per-item fingerprints with the batched
  lattice-digest kernel (:mod:`repro.kernels.digest`): item bytes are
  viewed as uint32 words, reduced blockwise on the accelerator, and
  folded into a 64-bit fingerprint whose XOR over the stream is the
  checksum.  On CPU the jit-compiled jnp oracle runs the math at XLA
  speed (the stand-in for the compiled Pallas kernel on TPU); the
  interpret-mode Pallas kernel is gated bit-exact against it in
  ``benchmarks/kernel_bench.py``.

Both placements are order-independent (concurrent staging workers
deliver out of order) and batch-aware: :meth:`StreamDigest.add_many`
folds a whole slab under one lock acquisition, and the object itself is
a batch-capable stage transform (``__call__`` per item, ``.many`` per
slab) — the hook :meth:`repro.core.staging.Stage._step_batch` looks for.

The two placements produce *different* checksum formats on purpose (64
hex chars vs ``u32:`` + 16): a host digest and an accel digest are not
comparable, so equivalence gates always compare like with like.

Wire compression rides the same seam: :func:`compress_transform` /
:func:`decompress_transform` wrap the blockwise-int8 Pallas kernel
(:mod:`repro.kernels.quantize`; jnp oracle
:mod:`repro.optim.compression`) as batch-capable stage transforms for
float-array item streams (gradient/checkpoint shards) — 4x fewer bytes
on the wire for one budgeted accelerator pass.

jax imports are lazy: a host-placement digest (the default everywhere)
never touches jax, so the core data plane stays importable and fast on
machines without the accelerator stack.
"""

from __future__ import annotations

import hashlib
import threading
from typing import Any, Callable, Iterable, Optional, Sequence

#: uint32 words per digest block (matches the quantize kernel's panel
#: width: 1 KiB of payload per block row)
DIGEST_BLOCK = 256
#: panel rows per pallas grid step
DIGEST_TILE = 8


def as_bytes(item: Any) -> bytes:
    """Stable byte view of an item for integrity hashing."""
    if isinstance(item, (bytes, bytearray)):
        return bytes(item)
    if isinstance(item, memoryview):
        return item.tobytes()
    tobytes = getattr(item, "tobytes", None)
    if tobytes is not None:
        return tobytes()
    if isinstance(item, (tuple, list)):
        return b"".join(as_bytes(e) for e in item)
    if isinstance(item, dict):
        return b"".join(as_bytes(item[k]) for k in sorted(item))
    return repr(item).encode()


def _item_words(data: bytes):
    """Item bytes -> zero-padded uint32 words (little-endian), plus the
    real block count the digest fold keeps."""
    import numpy as np
    n = len(data)
    blocks = max(1, -(-n // (4 * DIGEST_BLOCK)))
    padded = data + b"\0" * (blocks * 4 * DIGEST_BLOCK - n)
    return np.frombuffer(padded, dtype="<u4").reshape(-1, DIGEST_BLOCK), \
        blocks


class StreamDigest:
    """Order-independent integrity over an item stream.

    ``placement="host"``: XOR of per-item SHA-256 digests (commutative +
    associative), shared by the staged, parallel-branch, and direct
    paths so their checksums stay comparable.  ``placement="accel"``:
    XOR of per-item 64-bit lattice fingerprints computed by the batched
    digest kernel (``backend="ref"`` = jit-compiled jnp oracle, the
    CPU stand-in for the compiled kernel; ``backend="pallas"`` = the
    interpret-mode Pallas kernel, used by parity tests).

    Thread-safe; a disabled instance is a no-op.  Usable directly as a
    stage transform: calling it (or :meth:`add`) folds one item and
    returns it; :meth:`many` folds a slab under one lock acquisition and
    returns it — the batch hook the slab worker loop discovers."""

    def __init__(self, enabled: bool, placement: str = "host",
                 backend: str = "ref"):
        if placement not in ("host", "accel"):
            raise ValueError(
                f"placement must be 'host' or 'accel', got {placement!r}")
        if backend not in ("ref", "pallas"):
            raise ValueError(
                f"backend must be 'ref' or 'pallas', got {backend!r}")
        self.placement = placement
        self._backend = backend
        self._enabled = bool(enabled)
        self._acc = 0 if enabled else None
        self._lock = threading.Lock()
        self._kernel: Optional[Callable[[Any], Any]] = None

    # -- accel fingerprinting -------------------------------------------------

    def _block_digests(self, panels):
        if self._kernel is None:
            # lazy: the host placement never pays the jax import
            if self._backend == "pallas":
                from ..kernels.digest import block_digest

                def kernel(p):
                    import numpy as np
                    nb = p.shape[0]
                    pad = (-nb) % DIGEST_TILE
                    if pad:
                        p = np.concatenate(
                            [p, np.zeros((pad, DIGEST_BLOCK), "<u4")])
                    return block_digest(p, tile=DIGEST_TILE,
                                        interpret=True)[:nb]
                self._kernel = kernel
            else:
                from ..kernels.digest import digest_ref
                self._kernel = digest_ref
        return self._kernel(panels)

    def _fingerprint(self, item: Any) -> int:
        import numpy as np
        data = as_bytes(item)
        panels, blocks = _item_words(data)
        d = np.asarray(self._block_digests(panels)[:blocks],
                       dtype=np.uint64)
        mix = (len(data) * 0x9E3779B1) & 0xFFFFFFFF
        hi = int(np.bitwise_xor.reduce(d)) ^ mix
        lo = (int(np.sum(d)) + mix) & 0xFFFFFFFF
        return (hi << 32) | lo

    def _fold_host(self, items: Sequence[Any]) -> int:
        acc = 0
        for it in items:
            acc ^= int.from_bytes(hashlib.sha256(as_bytes(it)).digest(),
                                  "little")
        return acc

    def _fold(self, items: Sequence[Any]) -> int:
        if self.placement == "host":
            return self._fold_host(items)
        acc = 0
        for it in items:
            acc ^= self._fingerprint(it)
        return acc

    # -- stream API -----------------------------------------------------------

    def add(self, item: Any) -> Any:
        if self._acc is not None:
            fold = self._fold((item,))
            with self._lock:
                self._acc ^= fold
        return item

    def add_many(self, items: Sequence[Any]) -> Sequence[Any]:
        """Fold a whole slab: the hashes compute outside the lock and
        the accumulator takes ONE acquisition — the batch-admitted
        counterpart of per-item ``add``, bit-identical in result
        (XOR is order-independent and associative)."""
        if self._acc is not None and items:
            fold = self._fold(items)
            with self._lock:
                self._acc ^= fold
        return items

    # stage-transform protocol: per-item call + the `.many` batch hook
    __call__ = add
    many = add_many

    def absorb_digest(self, item_sha256_hex: str) -> None:
        """Fold a previously recorded per-item SHA-256 into the stream
        accumulator *without the item* — the resume path's stand-in for
        re-hashing a ledger-verified item that is being skipped, so a
        resumed transfer's stream checksum stays bit-identical to an
        unbroken run's.  Host placement only: the resumable ledger
        records host SHA-256 identities (the accel lattice fingerprint
        is a different format by design)."""
        if self._acc is None:
            return
        if self.placement != "host":
            raise ValueError(
                "resume digests fold into the host placement only; "
                "plan the resumed transfer with checksum_placement='host'")
        fold = int.from_bytes(bytes.fromhex(item_sha256_hex), "little")
        with self._lock:
            self._acc ^= fold

    def hexdigest(self) -> Optional[str]:
        if self._acc is None:
            return None
        if self.placement == "host":
            # bit-identical to the historical byte-array accumulator
            return self._acc.to_bytes(32, "little").hex()
        return f"u32:{self._acc:016x}"


# -- wire compression (float-array item streams) -----------------------------


class _BatchTransform:
    """A per-item callable carrying a ``.many`` slab hook."""

    def __init__(self, one: Callable[[Any], Any],
                 many: Callable[[Sequence[Any]], Iterable[Any]]):
        self._one = one
        self.many = many

    def __call__(self, item: Any) -> Any:
        return self._one(item)


def compress_transform(block: int = 256, *,
                       interpret: bool = True) -> _BatchTransform:
    """Stage transform: float array item -> ``(q int8, scales, shape)``
    via the blockwise-int8 Pallas kernel — the budgeted accelerator pass
    that puts 4x fewer bytes on the wire (oracle:
    :func:`repro.optim.compression.quantize_int8_blockwise`, parity
    gated in ``benchmarks/kernel_bench.py``)."""
    from ..kernels.quantize import quantize_int8

    def one(x):
        q, s = quantize_int8(x, block=block, interpret=interpret)
        return q, s, tuple(x.shape)

    return _BatchTransform(one, lambda items: [one(x) for x in items])


def decompress_transform(block: int = 256, *,
                         interpret: bool = True) -> _BatchTransform:
    """Inverse stage transform: ``(q, scales, shape)`` -> float array."""
    from ..kernels.quantize import dequantize_int8

    def one(t):
        q, s, shape = t
        return dequantize_int8(q, s, shape, interpret=interpret)

    return _BatchTransform(one, lambda items: [one(t) for t in items])
