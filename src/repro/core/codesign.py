"""Co-design planner — automated napkin math over the whole path.

The paper's engineering loop (sections 2.3, 3.4) is: understand every tier
of the path, predict where it chokes, and pick *one global configuration*
that balances the tiers — instead of per-workload manual tuning.  This
module automates that loop for a training/serving step:

1. enumerate candidate plans (sharding layout x microbatching x remat
   policy x gradient compression x collective schedule),
2. predict each plan's three roofline terms analytically from the model
   config, the mesh, and the hardware spec (napkin math, no compile),
3. rank by predicted step time and return the ranking.

The dry-run (`launch/dryrun.py`) then *measures* the chosen plan's terms
from the compiled HLO; §Perf iterations compare prediction vs.
measurement — the hypothesis -> change -> measure cycle with the
hypothesis generated mechanically.
"""

from __future__ import annotations

import dataclasses
import itertools
import math
from typing import Any, Optional, Sequence

from .fidelity import HardwareSpec, TPU_V5E


@dataclasses.dataclass(frozen=True)
class CodesignPlan:
    """One global configuration (the paper's 'single setting')."""

    sharding: str = "fsdp_tp"        # dp | tp | fsdp | fsdp_tp
    microbatches: int = 1            # gradient-accumulation splits
    remat: str = "full"              # none | dots | full
    compress_grads: bool = False     # int8 cross-pod gradient sync
    collective_schedule: str = "flat"  # flat | hierarchical
    seq_parallel: bool = True        # Megatron-SP activation sharding

    def describe(self) -> str:
        return (f"sharding={self.sharding} ubatch={self.microbatches} "
                f"remat={self.remat} compress={self.compress_grads} "
                f"sched={self.collective_schedule} sp={self.seq_parallel}")


@dataclasses.dataclass
class PlanPrediction:
    plan: CodesignPlan
    t_compute: float
    t_memory: float
    t_collective: float
    hbm_bytes_needed: float
    fits: bool

    @property
    def step_time_s(self) -> float:
        return max(self.t_compute, self.t_memory, self.t_collective)

    @property
    def dominant(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)


@dataclasses.dataclass(frozen=True)
class WorkloadSpec:
    """What one step must move and compute (derived from a ModelConfig)."""

    n_params: float                  # total parameters
    n_active_params: float           # != n_params for MoE
    tokens_per_step: float           # global_batch x seq
    d_model: int
    n_layers: int
    seq_len: int
    global_batch: int
    bytes_per_param: float = 2.0     # bf16 weights


def predict(
    work: WorkloadSpec,
    plan: CodesignPlan,
    *,
    n_chips: int,
    dp: int,
    tp: int,
    pods: int = 1,
    hw: HardwareSpec = TPU_V5E,
) -> PlanPrediction:
    """Analytic three-term prediction for one plan.

    Deliberately first-order — the same fidelity as the paper's
    provisioning arithmetic (Table 5): good enough to rank plans and to
    predict the dominant term, cross-checked later against compiled HLO.
    """
    P, Pa = work.n_params, work.n_active_params
    T = work.tokens_per_step
    remat_factor = {"none": 6.0, "dots": 7.0, "full": 8.0}[plan.remat]

    # --- compute: fwd+bwd matmul flops (remat adds a recompute fwd pass)
    flops_global = remat_factor * Pa * T
    t_compute = flops_global / (n_chips * hw.peak_flops)

    # --- memory: weights traffic (each layer read fwd+bwd(+remat fwd)) +
    # activations written fwd / read bwd
    passes = 3.0 if plan.remat != "none" else 2.0
    act_bytes = 2.0 * T * work.d_model * work.n_layers * 2.0 / n_chips  # write+read
    if plan.remat == "full":
        act_bytes *= 0.25  # only layer-boundary activations persist
    resident_act = T * work.d_model * 2.0 * work.n_layers / (dp * pods)
    if plan.seq_parallel:
        resident_act /= tp
    weight_traffic = passes * P * work.bytes_per_param / min(n_chips, dp * tp)
    t_memory = (act_bytes + weight_traffic * plan.microbatches) / hw.hbm_bandwidth

    # --- collective: grad sync over dp (+pods), fsdp all-gathers over dp
    grad_bytes = P * (1.0 if plan.compress_grads else work.bytes_per_param)
    coll = 0.0
    if dp > 1 or pods > 1:
        g = dp * pods
        sync = 2.0 * grad_bytes / tp * (g - 1) / g  # ring all-reduce per chip
        if plan.collective_schedule == "hierarchical" and pods > 1:
            # reduce-scatter intra-pod + small cross-pod exchange + gather
            sync = grad_bytes / tp * ((dp - 1) / dp + 2.0 * (pods - 1) / pods / dp
                                      + (dp - 1) / dp)
        coll += sync
    if plan.sharding in ("fsdp", "fsdp_tp") and dp > 1:
        # params all-gathered across dp each pass (fwd, bwd, remat-fwd)
        coll += passes * (P * work.bytes_per_param / tp) * (dp - 1) / dp \
            * plan.microbatches
    if plan.sharding in ("tp", "fsdp_tp") and tp > 1:
        # activation all-reduces: 2 per layer fwd (+2 bwd) of B x S x D
        per_layer = work.seq_len * work.global_batch * work.d_model * 2.0 / (dp * pods)
        coll += 2.0 * passes * work.n_layers * per_layer * (tp - 1) / tp
    t_collective = coll / hw.ici_bandwidth

    # --- does it fit?  params(+grads+adam m,v master fp32) + activations
    opt_bytes = P * (2.0 + 4.0 + 4.0 + 4.0)  # bf16 w + fp32 master/m/v
    shard = {"dp": 1.0, "tp": tp, "fsdp": dp, "fsdp_tp": dp * tp}[plan.sharding]
    resident = opt_bytes / shard + resident_act / max(plan.microbatches, 1)
    fits = resident <= hw.hbm_bytes * 0.9

    return PlanPrediction(
        plan=plan, t_compute=t_compute, t_memory=t_memory,
        t_collective=t_collective, hbm_bytes_needed=resident, fits=fits,
    )


def enumerate_plans(
    *,
    microbatch_options: Sequence[int] = (1, 2, 4, 8),
    shardings: Sequence[str] = ("dp", "fsdp", "fsdp_tp", "tp"),
    remats: Sequence[str] = ("none", "dots", "full"),
    multi_pod: bool = False,
) -> list[CodesignPlan]:
    plans = []
    for s, m, r in itertools.product(shardings, microbatch_options, remats):
        plans.append(CodesignPlan(sharding=s, microbatches=m, remat=r))
        if multi_pod:
            plans.append(CodesignPlan(sharding=s, microbatches=m, remat=r,
                                      compress_grads=True,
                                      collective_schedule="hierarchical"))
    return plans


def rank_plans(
    work: WorkloadSpec,
    *,
    n_chips: int,
    dp: int,
    tp: int,
    pods: int = 1,
    hw: HardwareSpec = TPU_V5E,
    plans: Optional[Sequence[CodesignPlan]] = None,
) -> list[PlanPrediction]:
    """Rank candidate plans by predicted step time; non-fitting plans last.

    The head of the list is the 'global tuning' default (paper section 2.3);
    callers may override per task — the paper's hierarchical tuning."""
    plans = list(plans) if plans is not None else enumerate_plans(multi_pod=pods > 1)
    preds = [predict(work, p, n_chips=n_chips, dp=dp, tp=tp, pods=pods, hw=hw)
             for p in plans]
    preds.sort(key=lambda pr: (not pr.fits, pr.step_time_s))
    return preds


def workload_from_config(cfg: Any, global_batch: int, seq_len: int) -> WorkloadSpec:
    """Build a WorkloadSpec from a repro ModelConfig (duck-typed)."""
    n_params = float(cfg.param_count())
    n_active = float(getattr(cfg, "active_param_count", cfg.param_count)())
    return WorkloadSpec(
        n_params=n_params,
        n_active_params=n_active,
        tokens_per_step=float(global_batch) * seq_len,
        d_model=cfg.d_model,
        n_layers=cfg.n_layers,
        seq_len=seq_len,
        global_batch=global_batch,
    )
