"""Drainage Basin Pattern — the paper's conceptual model, made executable.

The paper (Fig. 1) models the full data-movement spectrum as a drainage
basin: *headwaters* (edge sources, 1-10 Gbps, erratic), *tributaries*
(aggregation points), and the *main channel* (core, >= 100 Gbps,
deterministic).  Matching the appliance tier (Mini / Mini+ / Core) to the
basin position - network position x burst-buffer capacity x compute - is
the paper's planning discipline.

This module is the executable form of that model.  A :class:`DrainageBasin`
is an ordered chain of :class:`Tier` nodes joined by :class:`Link` edges.
From it we derive, analytically:

* the end-to-end *achievable throughput* (min over the path - the paper's
  "a chain is only as strong as its weakest link", section 3.4),
* the *fidelity gap* of any link (section 1: theoretical capacity vs.
  application throughput),
* burst-buffer sizing via Little's law (buffer >= bandwidth x jitter
  window - section 2.1's "low-jitter interface"),
* the appliance tier recommendation (Fig. 3).

Inside a TPU installation the same pattern recurs (DESIGN.md section 2):
dataset store -> host RAM staging -> HBM -> ICI/DCN.  The training data
pipeline, the checkpoint engine and the co-design planner all size their
buffers and schedules from this model.
"""

from __future__ import annotations

import dataclasses
import enum
import math
from typing import Iterable, Sequence

# ---------------------------------------------------------------------------
# Units
# ---------------------------------------------------------------------------

GBPS = 1e9 / 8.0        # bytes/s per Gbit/s
MIB = 1024 ** 2
GIB = 1024 ** 3
TIB = 1024 ** 4


class TierKind(enum.Enum):
    """Role of a node in the basin."""

    SOURCE = "source"            # production storage / instrument / dataset store
    BURST_BUFFER = "burst_buffer"  # staging layer (NVMe in the paper; host RAM here)
    CHANNEL = "channel"          # a network hop (WAN in the paper; ICI/DCN/PCIe here)
    SINK = "sink"                # destination storage / device HBM


class ApplianceTier(enum.Enum):
    """Fig. 3 appliance spectrum."""

    MINI = "mini"          # edge, 1-10 Gbps
    MINI_PLUS = "mini+"    # aggregation, 10-100 Gbps
    CORE = "core"          # core, >= 100 Gbps


@dataclasses.dataclass(frozen=True)
class Tier:
    """One node in the drainage basin.

    ``bandwidth_bytes_per_s`` is the *sustained* rate the tier can absorb or
    emit.  ``jitter_s`` is the width of the stochastic service-time window
    (the paper's "erratic production storage"); deterministic tiers have
    ~zero jitter.  ``latency_s`` is per-operation setup latency.
    """

    name: str
    kind: TierKind
    bandwidth_bytes_per_s: float
    latency_s: float = 0.0
    jitter_s: float = 0.0
    capacity_bytes: float = math.inf

    def __post_init__(self) -> None:
        if self.bandwidth_bytes_per_s <= 0:
            raise ValueError(f"tier {self.name!r}: bandwidth must be > 0")
        if self.latency_s < 0 or self.jitter_s < 0:
            raise ValueError(f"tier {self.name!r}: latency/jitter must be >= 0")

    def effective_bandwidth(self, item_bytes: float) -> float:
        """Bandwidth observed when moving items of ``item_bytes``.

        Per-item latency amortizes over the item size - this is the paper's
        small-file penalty (section 3.4: "per-file overheads ... disrupt
        effective pipelining").
        """
        if item_bytes <= 0:
            raise ValueError("item_bytes must be > 0")
        t = item_bytes / self.bandwidth_bytes_per_s + self.latency_s
        return item_bytes / t


@dataclasses.dataclass(frozen=True)
class Link:
    """Directed edge between two tiers (a hop on the data path)."""

    src: str
    dst: str
    bandwidth_bytes_per_s: float
    rtt_s: float = 0.0

    def bdp_bytes(self) -> float:
        """Bandwidth-delay product (section 3.1) - the in-flight window
        required to keep the link full."""
        return self.bandwidth_bytes_per_s * self.rtt_s


@dataclasses.dataclass
class BottleneckReport:
    """Where the basin chokes and by how much."""

    element: str                 # tier or link name
    kind: str                    # "tier" | "link"
    bandwidth_bytes_per_s: float
    achievable_bytes_per_s: float
    theoretical_bytes_per_s: float  # fastest element on the path

    @property
    def fidelity_gap(self) -> float:
        """Paper section 1: 1 - achieved / theoretical-capacity.  0 = perfect."""
        if self.theoretical_bytes_per_s <= 0:
            return 0.0
        return 1.0 - self.achievable_bytes_per_s / self.theoretical_bytes_per_s


class DrainageBasin:
    """An ordered data path: SOURCE -> [BURST_BUFFER|CHANNEL]* -> SINK."""

    def __init__(self, tiers: Sequence[Tier], links: Sequence[Link] | None = None):
        if len(tiers) < 2:
            raise ValueError("a basin needs at least a source and a sink")
        names = [t.name for t in tiers]
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate tier names: {names}")
        self.tiers = list(tiers)
        self._by_name = {t.name: t for t in tiers}
        # implicit links derive from tier bandwidths, so a rebuild with
        # revised tiers must re-derive them (planner.replan relies on this)
        self.explicit_links = links is not None
        if links is None:
            # implicit infinite-bandwidth adjacency; bandwidth limited by tiers
            links = [
                Link(a.name, b.name, min(a.bandwidth_bytes_per_s, b.bandwidth_bytes_per_s))
                for a, b in zip(tiers, tiers[1:])
            ]
        for l in links:
            if l.src not in self._by_name or l.dst not in self._by_name:
                raise ValueError(f"link {l.src}->{l.dst} references unknown tier")
        self.links = list(links)

    # -- analysis ----------------------------------------------------------

    def path_elements(self) -> Iterable[tuple[str, str, float]]:
        for t in self.tiers:
            yield (t.name, "tier", t.bandwidth_bytes_per_s)
        for l in self.links:
            yield (f"{l.src}->{l.dst}", "link", l.bandwidth_bytes_per_s)

    def achievable_throughput(self, item_bytes: float | None = None) -> float:
        """Sustained end-to-end rate = min over every tier and link.

        With ``item_bytes`` given, tier latencies amortize per item
        (small-item regimes choke on latency, not bandwidth).
        """
        rates = []
        for t in self.tiers:
            rates.append(
                t.effective_bandwidth(item_bytes) if item_bytes else t.bandwidth_bytes_per_s
            )
        rates.extend(l.bandwidth_bytes_per_s for l in self.links)
        return min(rates)

    def bottleneck(self, item_bytes: float | None = None) -> BottleneckReport:
        best_name, best_kind, best_bw = None, None, math.inf
        theoretical = 0.0
        for t in self.tiers:
            bw = t.effective_bandwidth(item_bytes) if item_bytes else t.bandwidth_bytes_per_s
            theoretical = max(theoretical, t.bandwidth_bytes_per_s)
            if bw < best_bw:
                best_name, best_kind, best_bw = t.name, "tier", bw
        for l in self.links:
            theoretical = max(theoretical, l.bandwidth_bytes_per_s)
            if l.bandwidth_bytes_per_s < best_bw:
                best_name, best_kind, best_bw = f"{l.src}->{l.dst}", "link", l.bandwidth_bytes_per_s
        return BottleneckReport(
            element=best_name,
            kind=best_kind,
            bandwidth_bytes_per_s=best_bw,
            achievable_bytes_per_s=best_bw,
            theoretical_bytes_per_s=theoretical,
        )

    def fidelity_gap(self, achieved_bytes_per_s: float, against: str | None = None) -> float:
        """Measured-vs-provisioned gap for the whole basin or one element."""
        if against is None:
            capacity = max(bw for _, _, bw in self.path_elements())
        else:
            matches = [bw for n, _, bw in self.path_elements() if n == against]
            if not matches:
                raise KeyError(f"no element named {against!r}")
            capacity = matches[0]
        return 1.0 - achieved_bytes_per_s / capacity

    def transfer_time_s(self, total_bytes: float, item_bytes: float | None = None) -> float:
        return total_bytes / self.achievable_throughput(item_bytes)

    # -- planning ----------------------------------------------------------

    def buffer_bytes_required(self, link_name: str | None = None) -> float:
        """Little's-law burst-buffer sizing (section 2.1).

        The staging buffer in front of a channel must hold at least
        ``channel_bandwidth x (source jitter window + channel RTT)`` so the
        deterministic sink never starves while the stochastic source stalls.
        """
        channel_bw = self.achievable_throughput()
        jitter = max((t.jitter_s for t in self.tiers), default=0.0)
        rtt = max((l.rtt_s for l in self.links), default=0.0)
        return channel_bw * (jitter + rtt) * 2.0  # x2: double buffering

    def prefetch_depth(self, item_bytes: float) -> int:
        """Number of in-flight items to keep the channel full (>= 2)."""
        need = self.buffer_bytes_required()
        return max(2, math.ceil(need / max(item_bytes, 1.0)))


def recommend_tier(target_bytes_per_s: float) -> ApplianceTier:
    """Fig. 3: match the appliance tier to the basin position."""
    gbps = target_bytes_per_s / GBPS
    if gbps < 10.0:
        return ApplianceTier.MINI
    if gbps < 100.0:
        return ApplianceTier.MINI_PLUS
    return ApplianceTier.CORE


def daily_volume_bytes(rate_bytes_per_s: float) -> float:
    """Table 5: daily data volume achievable at a sustained rate."""
    return rate_bytes_per_s * 86400.0


# ---------------------------------------------------------------------------
# Pre-built basins
# ---------------------------------------------------------------------------

def paper_basin(link_gbps: float = 100.0, rtt_ms: float = 74.0,
                storage_gbps: float = 40.0, storage_jitter_ms: float = 50.0) -> DrainageBasin:
    """The paper's canonical path: production storage -> burst buffer ->
    WAN -> burst buffer -> production storage (defaults: the Switzerland ->
    California 100 Gbps production link, ~74 ms latency, section 3.3)."""
    bb_bw = 2.0 * link_gbps * GBPS  # NVMe staging provisioned above line rate
    return DrainageBasin(
        tiers=[
            Tier("prod-storage-src", TierKind.SOURCE, storage_gbps * GBPS,
                 latency_s=2e-3, jitter_s=storage_jitter_ms / 1e3),
            Tier("burst-buffer-src", TierKind.BURST_BUFFER, bb_bw, latency_s=50e-6),
            Tier("wan", TierKind.CHANNEL, link_gbps * GBPS, latency_s=rtt_ms / 2e3),
            Tier("burst-buffer-dst", TierKind.BURST_BUFFER, bb_bw, latency_s=50e-6),
            Tier("prod-storage-dst", TierKind.SINK, storage_gbps * GBPS,
                 latency_s=2e-3, jitter_s=storage_jitter_ms / 1e3),
        ],
        links=[
            Link("prod-storage-src", "burst-buffer-src", storage_gbps * GBPS),
            Link("burst-buffer-src", "wan", link_gbps * GBPS, rtt_s=rtt_ms / 1e3),
            Link("wan", "burst-buffer-dst", link_gbps * GBPS, rtt_s=rtt_ms / 1e3),
            Link("burst-buffer-dst", "prod-storage-dst", storage_gbps * GBPS),
        ],
    )


def tpu_input_basin(*, dataset_gbps: float = 8.0, dataset_jitter_ms: float = 20.0,
                    host_staging_gbps: float = 200.0, pcie_gbps: float = 128.0,
                    hbm_gbps: float = 819.0 * 8.0) -> DrainageBasin:
    """The training-input path on one host: dataset store -> host RAM burst
    buffer -> PCIe -> device HBM (DESIGN.md section 2 mapping)."""
    return DrainageBasin(
        tiers=[
            Tier("dataset-store", TierKind.SOURCE, dataset_gbps * GBPS,
                 latency_s=5e-3, jitter_s=dataset_jitter_ms / 1e3),
            Tier("host-burst-buffer", TierKind.BURST_BUFFER, host_staging_gbps * GBPS,
                 latency_s=10e-6),
            Tier("pcie", TierKind.CHANNEL, pcie_gbps * GBPS, latency_s=20e-6),
            Tier("hbm", TierKind.SINK, hbm_gbps * GBPS, latency_s=1e-6),
        ]
    )


def checkpoint_basin(*, host_gbps: float = 200.0, nvme_gbps: float = 16.0,
                     nvme_latency_ms: float = 0.2,
                     nvme_jitter_ms: float = 2.0) -> DrainageBasin:
    """The checkpoint-save path: host RAM snapshot -> serialize/hash
    staging -> NVMe/production storage.  The device->host snapshot happens
    before the staged transfer starts, so the basin begins at host RAM;
    the erratic element is the filesystem (allocation, page-cache
    writeback), modeled as sink jitter."""
    return DrainageBasin(
        tiers=[
            Tier("host-snapshot", TierKind.SOURCE, host_gbps * GBPS,
                 latency_s=10e-6),
            Tier("serialize-staging", TierKind.BURST_BUFFER,
                 host_gbps * GBPS, latency_s=10e-6),
            Tier("nvme", TierKind.SINK, nvme_gbps * GBPS,
                 latency_s=nvme_latency_ms / 1e3,
                 jitter_s=nvme_jitter_ms / 1e3),
        ]
    )


def decode_stream_basin(*, decode_step_ms: float = 2.0,
                        host_gbps: float = 200.0,
                        client_gbps: float = 1.0,
                        client_jitter_ms: float = 5.0) -> DrainageBasin:
    """The serving decode path: accelerator token producer -> host staging
    buffer -> client sink.  The producer's per-step latency is the decode
    step itself; the erratic element is the client (network scheduling,
    slow readers), which the staging buffer must decouple from the
    accelerator so a stalling consumer never idles the chip (§2.1)."""
    return DrainageBasin(
        tiers=[
            Tier("decode-producer", TierKind.SOURCE, host_gbps * GBPS,
                 latency_s=decode_step_ms / 1e3),
            Tier("token-staging", TierKind.BURST_BUFFER, host_gbps * GBPS,
                 latency_s=10e-6),
            Tier("client", TierKind.SINK, client_gbps * GBPS,
                 latency_s=1e-3, jitter_s=client_jitter_ms / 1e3),
        ]
    )
