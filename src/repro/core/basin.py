"""Drainage Basin Pattern — the paper's conceptual model, made executable.

The paper (Fig. 1) models the full data-movement spectrum as a drainage
basin: *headwaters* (edge sources, 1-10 Gbps, erratic), *tributaries*
(aggregation points), and the *main channel* (core, >= 100 Gbps,
deterministic).  Matching the appliance tier (Mini / Mini+ / Core) to the
basin position - network position x burst-buffer capacity x compute - is
the paper's planning discipline.

This module is the executable form of that model.  A :class:`DrainageBasin`
is a **DAG** of :class:`Tier` nodes joined by :class:`Link` edges.  Real
deployments are rarely one straight channel: datasets fan out N shards ->
M hosts (multiple roots merging at a staging tier), checkpoints mirror to
two storage tiers (one source splitting to two sinks), and decode streams
fan out to many clients.  A tier with several outgoing links is a *split*
(fan-out) node; several incoming links make a *merge* (fan-in) node; both
are detected from the link structure rather than declared.

The historical linear constructor is preserved as the degenerate
single-path case: ``DrainageBasin(tiers)`` with no links still means the
ordered chain ``tiers[0] -> tiers[1] -> ...``, and every analysis method
behaves exactly as it always has on such basins (``is_linear`` is true).
A :class:`Link` whose ``bandwidth_bytes_per_s`` is ``None`` is *derived*:
its capacity is taken from its endpoint tiers and re-derived whenever the
tier estimates are revised (``replace_tiers``), which is how the adaptive
replanner avoids clamping an upward revision at a stale link rate.

From the model we derive, analytically:

* the end-to-end *achievable throughput* (min over a linear path - the
  paper's "a chain is only as strong as its weakest link", section 3.4 -
  or, on a DAG, the sum of per-branch rates under shared-tier rate
  conservation: branch rates through a shared tier must sum to no more
  than its effective rate, see :meth:`DrainageBasin.branch_rates`),
* the *fidelity gap* of any link (section 1: theoretical capacity vs.
  application throughput),
* burst-buffer sizing via Little's law (buffer >= bandwidth x jitter
  window - section 2.1's "low-jitter interface"),
* the appliance tier recommendation (Fig. 3).

Inside a TPU installation the same pattern recurs (DESIGN.md section 2):
dataset store -> host RAM staging -> HBM -> ICI/DCN.  The training data
pipeline, the checkpoint engine and the co-design planner all size their
buffers and schedules from this model.
"""

from __future__ import annotations

import dataclasses
import enum
import math
from typing import Iterable, Sequence

# ---------------------------------------------------------------------------
# Units
# ---------------------------------------------------------------------------

GBPS = 1e9 / 8.0        # bytes/s per Gbit/s
MIB = 1024 ** 2
GIB = 1024 ** 3
TIB = 1024 ** 4


class TierKind(enum.Enum):
    """Role of a node in the basin."""

    SOURCE = "source"            # production storage / instrument / dataset store
    BURST_BUFFER = "burst_buffer"  # staging layer (NVMe in the paper; host RAM here)
    CHANNEL = "channel"          # a network hop (WAN in the paper; ICI/DCN/PCIe here)
    SINK = "sink"                # destination storage / device HBM


class ApplianceTier(enum.Enum):
    """Fig. 3 appliance spectrum."""

    MINI = "mini"          # edge, 1-10 Gbps
    MINI_PLUS = "mini+"    # aggregation, 10-100 Gbps
    CORE = "core"          # core, >= 100 Gbps


@dataclasses.dataclass(frozen=True)
class Tier:
    """One node in the drainage basin.

    ``bandwidth_bytes_per_s`` is the *sustained* rate the tier can absorb or
    emit.  ``jitter_s`` is the width of the stochastic service-time window
    (the paper's "erratic production storage"); deterministic tiers have
    ~zero jitter.  ``latency_s`` is per-operation setup latency.
    """

    name: str
    kind: TierKind
    bandwidth_bytes_per_s: float
    latency_s: float = 0.0
    jitter_s: float = 0.0
    capacity_bytes: float = math.inf

    def __post_init__(self) -> None:
        if self.bandwidth_bytes_per_s <= 0:
            raise ValueError(f"tier {self.name!r}: bandwidth must be > 0")
        if self.latency_s < 0 or self.jitter_s < 0:
            raise ValueError(f"tier {self.name!r}: latency/jitter must be >= 0")

    def effective_bandwidth(self, item_bytes: float) -> float:
        """Bandwidth observed when moving items of ``item_bytes``.

        Per-item latency amortizes over the item size - this is the paper's
        small-file penalty (section 3.4: "per-file overheads ... disrupt
        effective pipelining").
        """
        if item_bytes <= 0:
            raise ValueError("item_bytes must be > 0")
        t = item_bytes / self.bandwidth_bytes_per_s + self.latency_s
        return item_bytes / t


@dataclasses.dataclass(frozen=True)
class Link:
    """Directed edge between two tiers (a hop on the data path).

    ``bandwidth_bytes_per_s=None`` marks a *derived* link: its capacity is
    the min of its endpoint tiers, resolved by the basin at construction
    and re-resolved whenever tier estimates are revised
    (:meth:`DrainageBasin.replace_tiers`).  Give a concrete bandwidth only
    for physically provisioned links (a WAN circuit, a PCIe lane count).
    """

    src: str
    dst: str
    bandwidth_bytes_per_s: float | None = None
    rtt_s: float = 0.0
    #: expected retransmit fraction (retransmits / items) on this hop —
    #: §3.2's deterministic loss.  A lossy link needs a window deepened
    #: by (1 + loss_rate) to keep the pipe full while retransmit RTTs
    #: are being paid, and its honest promise drops accordingly when a
    #: clamp keeps the window shallow.
    loss_rate: float = 0.0

    def bdp_bytes(self) -> float:
        """Bandwidth-delay product (section 3.1) - the in-flight window
        required to keep the link full."""
        return (self.bandwidth_bytes_per_s or 0.0) * self.rtt_s


@dataclasses.dataclass
class BottleneckReport:
    """Where the basin chokes and by how much."""

    element: str                 # tier or link name
    kind: str                    # "tier" | "link"
    bandwidth_bytes_per_s: float
    achievable_bytes_per_s: float
    theoretical_bytes_per_s: float  # fastest element on the path

    @property
    def fidelity_gap(self) -> float:
        """Paper section 1: 1 - achieved / theoretical-capacity.  0 = perfect."""
        if self.theoretical_bytes_per_s <= 0:
            return 0.0
        return 1.0 - self.achievable_bytes_per_s / self.theoretical_bytes_per_s


#: combinatorial guard: a basin with more root->sink paths than this is a
#: modeling error, not a plannable topology
MAX_PATHS = 64


class DrainageBasin:
    """A DAG data path: SOURCE(s) -> [BURST_BUFFER|CHANNEL]* -> SINK(s).

    ``DrainageBasin(tiers)`` (no links) is the degenerate linear case: the
    ordered chain the model started life as, with every method behaving
    exactly as before the DAG refactor.  With explicit ``links`` the graph
    may branch: multiple roots merging (N dataset shards -> one host),
    one source splitting to multiple sinks (a mirrored checkpoint, a
    decode fan-out).  Split/merge nodes are detected from link degrees
    (:meth:`split_tiers` / :meth:`merge_tiers`); root->sink paths are
    enumerated by :meth:`paths` and each is addressable as a linear
    sub-basin via :meth:`path_basin`.
    """

    def __init__(self, tiers: Sequence[Tier], links: Sequence[Link] | None = None):
        if len(tiers) < 2:
            raise ValueError("a basin needs at least a source and a sink")
        names = [t.name for t in tiers]
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate tier names: {names}")
        self.tiers = list(tiers)
        self._by_name = {t.name: t for t in tiers}
        # implicit links derive from tier bandwidths, so a rebuild with
        # revised tiers must re-derive them (planner.replan relies on this)
        self.explicit_links = links is not None
        if links is None:
            links = [Link(a.name, b.name) for a, b in zip(tiers, tiers[1:])]
        # a None bandwidth is a *derived* link (min of its endpoints);
        # remember which so replace_tiers() can re-derive after revision
        self._derived_links = {(l.src, l.dst) for l in links
                               if l.bandwidth_bytes_per_s is None}
        resolved = []
        for l in links:
            if l.src not in self._by_name or l.dst not in self._by_name:
                raise ValueError(f"link {l.src}->{l.dst} references unknown tier")
            if l.bandwidth_bytes_per_s is None:
                l = dataclasses.replace(
                    l, bandwidth_bytes_per_s=min(
                        self._by_name[l.src].bandwidth_bytes_per_s,
                        self._by_name[l.dst].bandwidth_bytes_per_s))
            resolved.append(l)
        self.links = resolved
        self._out: dict[str, list[str]] = {n: [] for n in names}
        self._in: dict[str, list[str]] = {n: [] for n in names}
        for l in self.links:
            self._out[l.src].append(l.dst)
            self._in[l.dst].append(l.src)
        self._validate_dag()
        self._paths = self._enumerate_paths()

    # -- topology ----------------------------------------------------------

    def _validate_dag(self) -> None:
        indeg = {n: len(self._in[n]) for n in self._by_name}
        ready = [n for n in (t.name for t in self.tiers) if indeg[n] == 0]
        seen = 0
        queue = list(ready)
        while queue:
            n = queue.pop(0)
            seen += 1
            for m in self._out[n]:
                indeg[m] -= 1
                if indeg[m] == 0:
                    queue.append(m)
        if seen != len(self.tiers):
            cyclic = sorted(n for n, d in indeg.items() if d > 0)
            raise ValueError(f"basin links contain a cycle through {cyclic}")
        for t in self.tiers:
            if not self._in[t.name] and not self._out[t.name]:
                raise ValueError(f"tier {t.name!r} is disconnected")

    def _enumerate_paths(self) -> list[tuple[str, ...]]:
        """Every root->sink tier-name path, in deterministic (tier-order,
        then link-order) traversal order."""
        paths: list[tuple[str, ...]] = []

        def walk(node: str, acc: list[str]) -> None:
            acc.append(node)
            nexts = self._out[node]
            if not nexts:
                paths.append(tuple(acc))
                if len(paths) > MAX_PATHS:
                    raise ValueError(
                        f"basin enumerates more than {MAX_PATHS} root->sink "
                        "paths; simplify the topology")
            for m in nexts:
                walk(m, acc)
            acc.pop()

        for root in self.roots():
            walk(root, [])
        return paths

    def roots(self) -> list[str]:
        """Tier names with no incoming link (the headwaters)."""
        return [t.name for t in self.tiers if not self._in[t.name]]

    def sinks(self) -> list[str]:
        """Tier names with no outgoing link (the basin mouths)."""
        return [t.name for t in self.tiers if not self._out[t.name]]

    def split_tiers(self) -> list[str]:
        """Fan-out nodes: tiers with more than one outgoing link."""
        return [t.name for t in self.tiers if len(self._out[t.name]) > 1]

    def merge_tiers(self) -> list[str]:
        """Fan-in nodes: tiers with more than one incoming link."""
        return [t.name for t in self.tiers if len(self._in[t.name]) > 1]

    @property
    def is_linear(self) -> bool:
        """True when the basin is one root->sink chain covering every tier
        — the degenerate case all pre-DAG call sites construct."""
        return len(self._paths) == 1 and len(self._paths[0]) == len(self.tiers)

    def paths(self) -> list[tuple[str, ...]]:
        """All root->sink tier-name paths (one per branch)."""
        return list(self._paths)

    def tier(self, name: str) -> Tier:
        return self._by_name[name]

    def link(self, src: str, dst: str) -> Link:
        for l in self.links:
            if l.src == src and l.dst == dst:
                return l
        raise KeyError(f"no link {src}->{dst}")

    def path_basin(self, path: Sequence[str]) -> "DrainageBasin":
        """A linear sub-basin over one root->sink path.  Explicit link
        bandwidths/rtts along the path survive; derived links stay derived
        so the sub-basin re-derives them from its (shared) tier objects."""
        tiers = [self._by_name[n] for n in path]
        links = []
        for a, b in zip(path, path[1:]):
            l = self.link(a, b)
            if (a, b) in self._derived_links:
                l = dataclasses.replace(l, bandwidth_bytes_per_s=None)
            links.append(l)
        return DrainageBasin(tiers, links)

    def replace_tiers(self, new_tiers: Sequence[Tier],
                      link_overrides: "dict[str, dict] | None" = None
                      ) -> "DrainageBasin":
        """Rebuild with revised tier estimates, same topology.  Derived
        links re-derive from the new tiers (an upward bandwidth revision
        must not stay clamped at a stale link rate); explicit links are
        physical and survive unchanged.

        ``link_overrides`` maps ``"src->dst"`` to link-field revisions
        (``rtt_s``, ``loss_rate``) learned from observed telemetry — a
        route change revises the *path* the physical link takes, so the
        override applies even to explicit links."""
        if not self.explicit_links and not link_overrides:
            return DrainageBasin(new_tiers)
        links = [dataclasses.replace(l, bandwidth_bytes_per_s=None)
                 if (l.src, l.dst) in self._derived_links else l
                 for l in self.links]
        if link_overrides:
            links = [dataclasses.replace(
                         l, **link_overrides[f"{l.src}->{l.dst}"])
                     if f"{l.src}->{l.dst}" in link_overrides else l
                     for l in links]
        return DrainageBasin(new_tiers, links)

    # -- analysis ----------------------------------------------------------

    def path_elements(self) -> Iterable[tuple[str, str, float]]:
        for t in self.tiers:
            yield (t.name, "tier", t.bandwidth_bytes_per_s)
        for l in self.links:
            yield (f"{l.src}->{l.dst}", "link", l.bandwidth_bytes_per_s)

    def achievable_throughput(self, item_bytes: float | None = None) -> float:
        """Sustained end-to-end rate.

        Linear basin: min over every tier and link (the weakest link).
        Branching basin: the sum of per-branch rates under shared-tier
        rate conservation (:meth:`branch_rates`) — aggregate throughput is
        governed by the slowest *branch allocation*, not the provisioned
        link (arXiv:2308.10312's multi-flow regime).

        With ``item_bytes`` given, tier latencies amortize per item
        (small-item regimes choke on latency, not bandwidth).
        """
        if not self.is_linear:
            return sum(self.branch_rates(item_bytes).values())
        rates = []
        for t in self.tiers:
            rates.append(
                t.effective_bandwidth(item_bytes) if item_bytes else t.bandwidth_bytes_per_s
            )
        rates.extend(l.bandwidth_bytes_per_s for l in self.links)
        return min(rates)

    def branch_rates(self, item_bytes: float | None = None
                     ) -> dict[tuple[str, ...], float]:
        """Per-branch sustainable rate for every root->sink path.

        Each branch starts at its own weakest element, then rates are
        proportionally scaled down wherever branches sharing a tier or
        link would jointly exceed its capacity (rate conservation: branch
        rates through a shared element must sum to <= its effective
        rate).  Deterministic fixed-point iteration; on a linear basin the
        single branch equals :meth:`achievable_throughput`.
        """
        def tier_rate(name: str) -> float:
            t = self._by_name[name]
            return (t.effective_bandwidth(item_bytes) if item_bytes
                    else t.bandwidth_bytes_per_s)

        link_bw = {(l.src, l.dst): l.bandwidth_bytes_per_s for l in self.links}
        rates: dict[tuple[str, ...], float] = {}
        for p in self._paths:
            caps = [tier_rate(n) for n in p]
            caps.extend(link_bw[(a, b)] for a, b in zip(p, p[1:]))
            rates[p] = min(caps)
        # shared elements: (capacity, member paths)
        shared: list[tuple[float, list[tuple[str, ...]]]] = []
        for t in self.tiers:
            members = [p for p in self._paths if t.name in p]
            if len(members) > 1:
                shared.append((tier_rate(t.name), members))
        for (a, b), bw in link_bw.items():
            members = [p for p in self._paths
                       if any(x == a and y == b
                              for x, y in zip(p, p[1:]))]
            if len(members) > 1:
                shared.append((bw, members))
        for _ in range(max(1, 4 * len(self._paths))):
            changed = False
            for cap, members in shared:
                load = sum(rates[p] for p in members)
                if load > cap * (1.0 + 1e-12):
                    scale = cap / load
                    for p in members:
                        rates[p] *= scale
                    changed = True
            if not changed:
                break
        return rates

    def bottleneck(self, item_bytes: float | None = None) -> BottleneckReport:
        best_name, best_kind, best_bw = None, None, math.inf
        theoretical = 0.0
        for t in self.tiers:
            bw = t.effective_bandwidth(item_bytes) if item_bytes else t.bandwidth_bytes_per_s
            theoretical = max(theoretical, t.bandwidth_bytes_per_s)
            if bw < best_bw:
                best_name, best_kind, best_bw = t.name, "tier", bw
        for l in self.links:
            theoretical = max(theoretical, l.bandwidth_bytes_per_s)
            if l.bandwidth_bytes_per_s < best_bw:
                best_name, best_kind, best_bw = f"{l.src}->{l.dst}", "link", l.bandwidth_bytes_per_s
        return BottleneckReport(
            element=best_name,
            kind=best_kind,
            bandwidth_bytes_per_s=best_bw,
            achievable_bytes_per_s=best_bw,
            theoretical_bytes_per_s=theoretical,
        )

    def fidelity_gap(self, achieved_bytes_per_s: float, against: str | None = None) -> float:
        """Measured-vs-provisioned gap for the whole basin or one element."""
        if against is None:
            capacity = max(bw for _, _, bw in self.path_elements())
        else:
            matches = [bw for n, _, bw in self.path_elements() if n == against]
            if not matches:
                raise KeyError(f"no element named {against!r}")
            capacity = matches[0]
        return 1.0 - achieved_bytes_per_s / capacity

    def transfer_time_s(self, total_bytes: float, item_bytes: float | None = None) -> float:
        return total_bytes / self.achievable_throughput(item_bytes)

    # -- planning ----------------------------------------------------------

    def buffer_bytes_required(self, link_name: str | None = None) -> float:
        """Little's-law burst-buffer sizing (section 2.1).

        The staging buffer in front of a channel must hold at least
        ``channel_bandwidth x (source jitter window + channel RTT)`` so the
        deterministic sink never starves while the stochastic source stalls.
        """
        channel_bw = self.achievable_throughput()
        jitter = max((t.jitter_s for t in self.tiers), default=0.0)
        rtt = max((l.rtt_s for l in self.links), default=0.0)
        return channel_bw * (jitter + rtt) * 2.0  # x2: double buffering

    def prefetch_depth(self, item_bytes: float) -> int:
        """Number of in-flight items to keep the channel full (>= 2)."""
        need = self.buffer_bytes_required()
        return max(2, math.ceil(need / max(item_bytes, 1.0)))


def recommend_tier(target_bytes_per_s: float) -> ApplianceTier:
    """Fig. 3: match the appliance tier to the basin position."""
    gbps = target_bytes_per_s / GBPS
    if gbps < 10.0:
        return ApplianceTier.MINI
    if gbps < 100.0:
        return ApplianceTier.MINI_PLUS
    return ApplianceTier.CORE


def daily_volume_bytes(rate_bytes_per_s: float) -> float:
    """Table 5: daily data volume achievable at a sustained rate."""
    return rate_bytes_per_s * 86400.0


# ---------------------------------------------------------------------------
# Pre-built basins
# ---------------------------------------------------------------------------

def paper_basin(link_gbps: float = 100.0, rtt_ms: float = 74.0,
                storage_gbps: float = 40.0, storage_jitter_ms: float = 50.0) -> DrainageBasin:
    """The paper's canonical path: production storage -> burst buffer ->
    WAN -> burst buffer -> production storage (defaults: the Switzerland ->
    California 100 Gbps production link, ~74 ms latency, section 3.3)."""
    bb_bw = 2.0 * link_gbps * GBPS  # NVMe staging provisioned above line rate
    return DrainageBasin(
        tiers=[
            Tier("prod-storage-src", TierKind.SOURCE, storage_gbps * GBPS,
                 latency_s=2e-3, jitter_s=storage_jitter_ms / 1e3),
            Tier("burst-buffer-src", TierKind.BURST_BUFFER, bb_bw, latency_s=50e-6),
            Tier("wan", TierKind.CHANNEL, link_gbps * GBPS, latency_s=rtt_ms / 2e3),
            Tier("burst-buffer-dst", TierKind.BURST_BUFFER, bb_bw, latency_s=50e-6),
            Tier("prod-storage-dst", TierKind.SINK, storage_gbps * GBPS,
                 latency_s=2e-3, jitter_s=storage_jitter_ms / 1e3),
        ],
        links=[
            Link("prod-storage-src", "burst-buffer-src", storage_gbps * GBPS),
            Link("burst-buffer-src", "wan", link_gbps * GBPS, rtt_s=rtt_ms / 1e3),
            Link("wan", "burst-buffer-dst", link_gbps * GBPS, rtt_s=rtt_ms / 1e3),
            Link("burst-buffer-dst", "prod-storage-dst", storage_gbps * GBPS),
        ],
    )


def tpu_input_basin(*, dataset_gbps: float = 8.0, dataset_jitter_ms: float = 20.0,
                    host_staging_gbps: float = 200.0, pcie_gbps: float = 128.0,
                    hbm_gbps: float = 819.0 * 8.0) -> DrainageBasin:
    """The training-input path on one host: dataset store -> host RAM burst
    buffer -> PCIe -> device HBM (DESIGN.md section 2 mapping)."""
    return DrainageBasin(
        tiers=[
            Tier("dataset-store", TierKind.SOURCE, dataset_gbps * GBPS,
                 latency_s=5e-3, jitter_s=dataset_jitter_ms / 1e3),
            Tier("host-burst-buffer", TierKind.BURST_BUFFER, host_staging_gbps * GBPS,
                 latency_s=10e-6),
            Tier("pcie", TierKind.CHANNEL, pcie_gbps * GBPS, latency_s=20e-6),
            Tier("hbm", TierKind.SINK, hbm_gbps * GBPS, latency_s=1e-6),
        ]
    )


def checkpoint_basin(*, host_gbps: float = 200.0, nvme_gbps: float = 16.0,
                     nvme_latency_ms: float = 0.2,
                     nvme_jitter_ms: float = 2.0) -> DrainageBasin:
    """The checkpoint-save path: host RAM snapshot -> serialize/hash
    staging -> NVMe/production storage.  The device->host snapshot happens
    before the staged transfer starts, so the basin begins at host RAM;
    the erratic element is the filesystem (allocation, page-cache
    writeback), modeled as sink jitter."""
    return DrainageBasin(
        tiers=[
            Tier("host-snapshot", TierKind.SOURCE, host_gbps * GBPS,
                 latency_s=10e-6),
            Tier("serialize-staging", TierKind.BURST_BUFFER,
                 host_gbps * GBPS, latency_s=10e-6),
            Tier("nvme", TierKind.SINK, nvme_gbps * GBPS,
                 latency_s=nvme_latency_ms / 1e3,
                 jitter_s=nvme_jitter_ms / 1e3),
        ]
    )


def decode_stream_basin(*, decode_step_ms: float = 2.0,
                        host_gbps: float = 200.0,
                        client_gbps: float = 1.0,
                        client_jitter_ms: float = 5.0) -> DrainageBasin:
    """The serving decode path: accelerator token producer -> host staging
    buffer -> client sink.  The producer's per-step latency is the decode
    step itself; the erratic element is the client (network scheduling,
    slow readers), which the staging buffer must decouple from the
    accelerator so a stalling consumer never idles the chip (§2.1)."""
    return DrainageBasin(
        tiers=[
            Tier("decode-producer", TierKind.SOURCE, host_gbps * GBPS,
                 latency_s=decode_step_ms / 1e3),
            Tier("token-staging", TierKind.BURST_BUFFER, host_gbps * GBPS,
                 latency_s=10e-6),
            Tier("client", TierKind.SINK, client_gbps * GBPS,
                 latency_s=1e-3, jitter_s=client_jitter_ms / 1e3),
        ]
    )


# ---------------------------------------------------------------------------
# Pre-built branching (DAG) basins
# ---------------------------------------------------------------------------

def sharded_input_basin(n_shards: int = 2, *, shard_gbps: float = 4.0,
                        shard_jitter_ms: float = 20.0,
                        host_staging_gbps: float = 200.0,
                        pcie_gbps: float = 128.0,
                        hbm_gbps: float = 819.0 * 8.0) -> DrainageBasin:
    """The fan-in training-input path: N dataset shards -> one host burst
    buffer (merge node) -> PCIe -> device HBM.  Aggregate ingest is the
    sum of shard-branch rates, conserved at the shared host tier."""
    if n_shards < 1:
        raise ValueError("need at least one shard")
    shard_tiers = [
        Tier(f"shard-{i}", TierKind.SOURCE, shard_gbps * GBPS,
             latency_s=5e-3, jitter_s=shard_jitter_ms / 1e3)
        for i in range(n_shards)
    ]
    tail = [
        Tier("host-burst-buffer", TierKind.BURST_BUFFER,
             host_staging_gbps * GBPS, latency_s=10e-6),
        Tier("pcie", TierKind.CHANNEL, pcie_gbps * GBPS, latency_s=20e-6),
        Tier("hbm", TierKind.SINK, hbm_gbps * GBPS, latency_s=1e-6),
    ]
    links = [Link(t.name, "host-burst-buffer") for t in shard_tiers]
    links += [Link("host-burst-buffer", "pcie"), Link("pcie", "hbm")]
    return DrainageBasin(shard_tiers + tail, links)


def mirrored_checkpoint_basin(*, host_gbps: float = 200.0,
                              nvme_gbps: float = 16.0,
                              nvme_latency_ms: float = 0.2,
                              nvme_jitter_ms: float = 2.0,
                              object_gbps: float = 5.0,
                              object_latency_ms: float = 20.0,
                              object_jitter_ms: float = 15.0) -> DrainageBasin:
    """The dual-tier checkpoint-save path: host snapshot -> serialize
    staging (split node) -> {local NVMe, remote object store}.  Every
    shard is replicated down both branches (a mirror, not a split of
    traffic); restore picks whichever branch is modeled/measured faster."""
    staging = Tier("serialize-staging", TierKind.BURST_BUFFER,
                   host_gbps * GBPS, latency_s=10e-6)
    return DrainageBasin(
        tiers=[
            Tier("host-snapshot", TierKind.SOURCE, host_gbps * GBPS,
                 latency_s=10e-6),
            staging,
            Tier("nvme", TierKind.SINK, nvme_gbps * GBPS,
                 latency_s=nvme_latency_ms / 1e3,
                 jitter_s=nvme_jitter_ms / 1e3),
            Tier("object-store", TierKind.SINK, object_gbps * GBPS,
                 latency_s=object_latency_ms / 1e3,
                 jitter_s=object_jitter_ms / 1e3),
        ],
        links=[
            Link("host-snapshot", "serialize-staging"),
            Link("serialize-staging", "nvme"),
            Link("serialize-staging", "object-store"),
        ],
    )


def decode_fanout_basin(n_clients: int = 2, *, decode_step_ms: float = 2.0,
                        host_gbps: float = 200.0,
                        client_gbps: float = 1.0,
                        client_jitter_ms: float = 5.0) -> DrainageBasin:
    """The serving decode fan-out: one accelerator token producer -> host
    staging buffer (split node) -> N concurrent client sinks.  Each client
    receives the full stream (replication); the staging tier decouples the
    slowest client from the accelerator (§2.1), and per-branch plans let
    ``replan`` attribute a stall to the one slow client instead of
    degrading every stream."""
    if n_clients < 1:
        raise ValueError("need at least one client")
    clients = [
        Tier(f"client-{i}", TierKind.SINK, client_gbps * GBPS,
             latency_s=1e-3, jitter_s=client_jitter_ms / 1e3)
        for i in range(n_clients)
    ]
    tiers = [
        Tier("decode-producer", TierKind.SOURCE, host_gbps * GBPS,
             latency_s=decode_step_ms / 1e3),
        Tier("token-staging", TierKind.BURST_BUFFER, host_gbps * GBPS,
             latency_s=10e-6),
    ] + clients
    links = [Link("decode-producer", "token-staging")]
    links += [Link("token-staging", c.name) for c in clients]
    return DrainageBasin(tiers, links)
