"""Burst buffer — the paper's low-jitter staging layer (section 2.1).

The paper generalizes the supercomputing burst buffer into the *decoupling
mechanism* of the whole data path: a fast intermediate tier that "buffers
the stochastic throughput and latency of the non-deterministic source ...
to ensure a deterministic, high-bandwidth supply to the high-speed sink".

Here the buffer is a fixed-slot, thread-safe ring of host objects
(typically numpy batches, checkpoint shards, or decode micro-batches).
Cadence coordination is *decentralized through buffer state* exactly as in
the paper's peer-to-peer zx design (section 2.2): producers block on a free
slot, consumers block on a filled slot; no central scheduler sits in the
data path.

Occupancy statistics make jitter absorption measurable: a well-sized buffer
shows near-zero consumer stall time even when the producer's service time
is erratic (validated in tests/test_burst_buffer.py and
benchmarks/fig2_latency_sweep.py).

**Live resizing** is what makes the buffer a *persistent* decoupling
point: :meth:`BurstBuffer.resize` revises ``capacity`` on the running
buffer — growth takes effect immediately (blocked producers wake into the
new slots), shrinkage applies lazily as consumers free slots (no staged
item is ever dropped), and every statistic keeps accumulating across the
change.  That is the mechanism behind the zero-drain replanning path
(:mod:`repro.core.mover`): a plan revision re-sizes the live buffers in
place instead of draining and rebuilding them, so the data path sustains
the paper's deterministic supply *through* the correction instead of
falling off line rate at every planning boundary.
"""

from __future__ import annotations

import collections
import dataclasses
import threading
import time
from typing import Any, Callable, Generic, Iterable, Iterator, Optional, TypeVar

T = TypeVar("T")


class BufferClosed(Exception):
    """Raised when interacting with a drained, closed buffer."""


@dataclasses.dataclass
class BufferStats:
    """Observed behaviour of one buffer (all times in seconds)."""

    capacity: int
    puts: int = 0
    gets: int = 0
    producer_stall_s: float = 0.0   # time producers spent waiting for a free slot
    consumer_stall_s: float = 0.0   # time consumers spent waiting for an item
    occupancy_sum: float = 0.0      # integral of occupancy over puts+gets (for mean)
    max_occupancy: int = 0
    resizes: int = 0                # live capacity revisions applied

    @property
    def mean_occupancy(self) -> float:
        ops = self.puts + self.gets
        return self.occupancy_sum / ops if ops else 0.0

    @property
    def consumer_stall_per_get_s(self) -> float:
        return self.consumer_stall_s / self.gets if self.gets else 0.0

    @property
    def producer_stall_per_put_s(self) -> float:
        return self.producer_stall_s / self.puts if self.puts else 0.0


class BurstBuffer(Generic[T]):
    """Bounded FIFO staging buffer with backpressure and stall accounting.

    ``capacity`` is the number of slots (items), not bytes: the item
    granularity is chosen by the caller from
    :meth:`repro.core.basin.DrainageBasin.prefetch_depth`.
    """

    def __init__(self, capacity: int, name: str = "burst-buffer",
                 clock: Optional[Callable[[], float]] = None):
        if capacity < 1:
            raise ValueError("capacity must be >= 1")
        self.name = name
        self.capacity = capacity
        self._clock = clock or time.monotonic
        self._items: collections.deque[T] = collections.deque()
        self._lock = threading.Lock()
        self._not_full = threading.Condition(self._lock)
        self._not_empty = threading.Condition(self._lock)
        self._closed = False
        self.stats = BufferStats(capacity=capacity)

    # -- producer side -----------------------------------------------------

    def put(self, item: T, timeout: Optional[float] = None) -> None:
        """Stage one item; blocks (backpressure) while the buffer is full."""
        t0 = self._clock()
        with self._not_full:
            while len(self._items) >= self.capacity and not self._closed:
                if not self._not_full.wait(timeout):
                    raise TimeoutError(f"{self.name}: put timed out after {timeout}s")
            if self._closed:
                raise BufferClosed(f"{self.name} is closed")
            self._items.append(item)
            self.stats.puts += 1
            self.stats.producer_stall_s += self._clock() - t0
            occ = len(self._items)
            self.stats.occupancy_sum += occ
            self.stats.max_occupancy = max(self.stats.max_occupancy, occ)
            self._not_empty.notify()

    def put_many(self, items: Iterable[T],
                 timeout: Optional[float] = None) -> None:
        """Stage every item of ``items`` in one lock round-trip.

        Semantically identical to ``put`` per item (FIFO order, the same
        backpressure, the same per-item stats accounting) but the lock is
        acquired once per *batch* in the uncontended case — the hot-loop
        variant a dispatcher replicating batches down many branch queues
        uses.  Batches larger than ``capacity`` stage in waves as slots
        free.  On close mid-batch, already-staged items stay consumable
        and :class:`BufferClosed` is raised for the remainder."""
        batch = list(items)
        if not batch:
            return
        with self._not_full:
            i = 0
            while i < len(batch):
                # stall accrues per blocking wave (and survives a raise):
                # a dispatcher blocked mid-batch for a whole revision
                # window must show that backpressure IN that window — a
                # single post-batch accrual would zero the intake signal
                # exactly for the branch that is stalling hardest
                t0 = self._clock()
                try:
                    while (len(self._items) >= self.capacity
                           and not self._closed):
                        if not self._not_full.wait(timeout):
                            raise TimeoutError(
                                f"{self.name}: put_many timed out "
                                f"after {timeout}s")
                finally:
                    self.stats.producer_stall_s += self._clock() - t0
                if self._closed:
                    raise BufferClosed(f"{self.name} is closed")
                while i < len(batch) and len(self._items) < self.capacity:
                    self._items.append(batch[i])
                    i += 1
                    self.stats.puts += 1
                    occ = len(self._items)
                    self.stats.occupancy_sum += occ
                    self.stats.max_occupancy = max(self.stats.max_occupancy,
                                                   occ)
                self._not_empty.notify_all()

    # -- consumer side -----------------------------------------------------

    def get(self, timeout: Optional[float] = None) -> T:
        """Take the oldest staged item; blocks while the buffer is empty.

        Raises :class:`BufferClosed` once the buffer is closed *and* drained,
        which is the normal end-of-stream signal.
        """
        t0 = self._clock()
        with self._not_empty:
            while not self._items:
                if self._closed:
                    raise BufferClosed(f"{self.name} is closed and drained")
                if not self._not_empty.wait(timeout):
                    raise TimeoutError(f"{self.name}: get timed out after {timeout}s")
            item = self._items.popleft()
            self.stats.gets += 1
            self.stats.consumer_stall_s += self._clock() - t0
            self.stats.occupancy_sum += len(self._items)
            self._not_full.notify()
            return item

    def get_many(self, max_items: int,
                 timeout: Optional[float] = None) -> list[T]:
        """Take up to ``max_items`` staged items in one lock round-trip.

        Blocks like ``get`` while the buffer is empty, then returns every
        immediately-available item up to the cap (at least one).  Raises
        :class:`BufferClosed` once closed *and* drained.  Stats count one
        get per item returned, so accounting stays comparable with the
        per-item path."""
        if max_items < 1:
            raise ValueError("max_items must be >= 1")
        t0 = self._clock()
        with self._not_empty:
            while not self._items:
                if self._closed:
                    raise BufferClosed(f"{self.name} is closed and drained")
                if not self._not_empty.wait(timeout):
                    raise TimeoutError(
                        f"{self.name}: get_many timed out after {timeout}s")
            n = min(max_items, len(self._items))
            out = [self._items.popleft() for _ in range(n)]
            self.stats.gets += n
            self.stats.consumer_stall_s += self._clock() - t0
            # per-item occupancy integral: after popping the k-th of n the
            # buffer held (start - k) items
            start = len(self._items) + n
            self.stats.occupancy_sum += n * start - n * (n + 1) // 2
            self._not_full.notify_all()
            return out

    def drain(self) -> Iterator[T]:
        """Yield staged items until the buffer closes (end-of-stream)."""
        while True:
            try:
                yield self.get()
            except BufferClosed:
                return

    # -- lifecycle / introspection ------------------------------------------

    def resize(self, capacity: int) -> None:
        """Revise ``capacity`` on the *running* buffer — the live-swap
        primitive behind zero-drain replanning.

        Growth takes effect immediately: producers blocked on a full
        buffer wake into the new slots without a single staged item
        leaving the path.  Shrinkage is lazy: no staged item is dropped —
        occupancy above the new capacity simply blocks producers until
        consumers free slots down to it.  All statistics keep accumulating
        across the change (``stats.capacity`` tracks the current value,
        ``stats.resizes`` counts revisions)."""
        if capacity < 1:
            raise ValueError("capacity must be >= 1")
        with self._lock:
            if capacity == self.capacity:
                return          # no-op: stats.resizes counts real changes
            grew = capacity > self.capacity
            self.capacity = capacity
            self.stats.capacity = capacity
            self.stats.resizes += 1
            if grew:
                self._not_full.notify_all()

    def close(self) -> None:
        """Signal end-of-stream.  Staged items remain consumable."""
        with self._lock:
            self._closed = True
            self._not_empty.notify_all()
            self._not_full.notify_all()

    @property
    def closed(self) -> bool:
        with self._lock:
            return self._closed

    def __len__(self) -> int:
        with self._lock:
            return len(self._items)

    @property
    def occupancy(self) -> float:
        """Fill fraction in [0, 1] - the buffer-state signal that drives
        decentralized cadence (paper section 2.2).  Clamped: right after a
        lazy shrink the staged count may transiently exceed capacity."""
        with self._lock:
            return min(1.0, len(self._items) / self.capacity)

    def feed(self, items: Iterable[T], close_when_done: bool = True) -> None:
        """Stage every item of ``items`` (convenience for tests/benchmarks).

        Closes in a ``finally``: a source iterable that raises
        mid-iteration must still end the stream, or a consumer blocked in
        ``get``/``drain`` waits forever on a buffer nobody will close."""
        try:
            for item in items:
                self.put(item)
        finally:
            if close_when_done:
                self.close()
