"""Burst buffer — the paper's low-jitter staging layer (section 2.1).

The paper generalizes the supercomputing burst buffer into the *decoupling
mechanism* of the whole data path: a fast intermediate tier that "buffers
the stochastic throughput and latency of the non-deterministic source ...
to ensure a deterministic, high-bandwidth supply to the high-speed sink".

Here the buffer is a fixed-slot, thread-safe ring of host objects
(typically numpy batches, checkpoint shards, or decode micro-batches).
Cadence coordination is *decentralized through buffer state* exactly as in
the paper's peer-to-peer zx design (section 2.2): producers block on a free
slot, consumers block on a filled slot; no central scheduler sits in the
data path.

Occupancy statistics make jitter absorption measurable: a well-sized buffer
shows near-zero consumer stall time even when the producer's service time
is erratic (validated in tests/test_burst_buffer.py and
benchmarks/fig2_latency_sweep.py).
"""

from __future__ import annotations

import collections
import dataclasses
import threading
import time
from typing import Any, Callable, Generic, Iterable, Iterator, Optional, TypeVar

T = TypeVar("T")


class BufferClosed(Exception):
    """Raised when interacting with a drained, closed buffer."""


@dataclasses.dataclass
class BufferStats:
    """Observed behaviour of one buffer (all times in seconds)."""

    capacity: int
    puts: int = 0
    gets: int = 0
    producer_stall_s: float = 0.0   # time producers spent waiting for a free slot
    consumer_stall_s: float = 0.0   # time consumers spent waiting for an item
    occupancy_sum: float = 0.0      # integral of occupancy over puts+gets (for mean)
    max_occupancy: int = 0

    @property
    def mean_occupancy(self) -> float:
        ops = self.puts + self.gets
        return self.occupancy_sum / ops if ops else 0.0

    @property
    def consumer_stall_per_get_s(self) -> float:
        return self.consumer_stall_s / self.gets if self.gets else 0.0

    @property
    def producer_stall_per_put_s(self) -> float:
        return self.producer_stall_s / self.puts if self.puts else 0.0


class BurstBuffer(Generic[T]):
    """Bounded FIFO staging buffer with backpressure and stall accounting.

    ``capacity`` is the number of slots (items), not bytes: the item
    granularity is chosen by the caller from
    :meth:`repro.core.basin.DrainageBasin.prefetch_depth`.
    """

    def __init__(self, capacity: int, name: str = "burst-buffer",
                 clock: Optional[Callable[[], float]] = None):
        if capacity < 1:
            raise ValueError("capacity must be >= 1")
        self.name = name
        self.capacity = capacity
        self._clock = clock or time.monotonic
        self._items: collections.deque[T] = collections.deque()
        self._lock = threading.Lock()
        self._not_full = threading.Condition(self._lock)
        self._not_empty = threading.Condition(self._lock)
        self._closed = False
        self.stats = BufferStats(capacity=capacity)

    # -- producer side -----------------------------------------------------

    def put(self, item: T, timeout: Optional[float] = None) -> None:
        """Stage one item; blocks (backpressure) while the buffer is full."""
        t0 = self._clock()
        with self._not_full:
            while len(self._items) >= self.capacity and not self._closed:
                if not self._not_full.wait(timeout):
                    raise TimeoutError(f"{self.name}: put timed out after {timeout}s")
            if self._closed:
                raise BufferClosed(f"{self.name} is closed")
            self._items.append(item)
            self.stats.puts += 1
            self.stats.producer_stall_s += self._clock() - t0
            occ = len(self._items)
            self.stats.occupancy_sum += occ
            self.stats.max_occupancy = max(self.stats.max_occupancy, occ)
            self._not_empty.notify()

    # -- consumer side -----------------------------------------------------

    def get(self, timeout: Optional[float] = None) -> T:
        """Take the oldest staged item; blocks while the buffer is empty.

        Raises :class:`BufferClosed` once the buffer is closed *and* drained,
        which is the normal end-of-stream signal.
        """
        t0 = self._clock()
        with self._not_empty:
            while not self._items:
                if self._closed:
                    raise BufferClosed(f"{self.name} is closed and drained")
                if not self._not_empty.wait(timeout):
                    raise TimeoutError(f"{self.name}: get timed out after {timeout}s")
            item = self._items.popleft()
            self.stats.gets += 1
            self.stats.consumer_stall_s += self._clock() - t0
            self.stats.occupancy_sum += len(self._items)
            self._not_full.notify()
            return item

    def drain(self) -> Iterator[T]:
        """Yield staged items until the buffer closes (end-of-stream)."""
        while True:
            try:
                yield self.get()
            except BufferClosed:
                return

    # -- lifecycle / introspection ------------------------------------------

    def close(self) -> None:
        """Signal end-of-stream.  Staged items remain consumable."""
        with self._lock:
            self._closed = True
            self._not_empty.notify_all()
            self._not_full.notify_all()

    @property
    def closed(self) -> bool:
        with self._lock:
            return self._closed

    def __len__(self) -> int:
        with self._lock:
            return len(self._items)

    @property
    def occupancy(self) -> float:
        """Fill fraction in [0, 1] - the buffer-state signal that drives
        decentralized cadence (paper section 2.2)."""
        with self._lock:
            return len(self._items) / self.capacity

    def feed(self, items: Iterable[T], close_when_done: bool = True) -> None:
        """Stage every item of ``items`` (convenience for tests/benchmarks)."""
        for item in items:
            self.put(item)
        if close_when_done:
            self.close()
