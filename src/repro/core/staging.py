"""Data staging — the coordinating process between mismatched tiers.

Paper section 2.1: "Data staging ... is a critical coordinating process.
This operation must be straightforward, predictable, and highly efficient,
as any delay in staging fundamentally negates the performance benefits of
burst buffering."

A :class:`Stage` is a worker (or pool of workers) that moves items from an
upstream source (an iterator or another stage's burst buffer) into its own
:class:`~repro.core.burst_buffer.BurstBuffer`, optionally applying a
transform (decode, shard, checksum, quantize, host-to-device put).
Chaining stages yields a :class:`StagePipeline` — the executable form of a
drainage-basin path.

Design points lifted from the paper:

* **No central scheduler** — each stage runs free and coordinates only
  through buffer state (backpressure), section 2.2.
* **Concurrency as the latency antidote** — multiple workers per stage
  overlap erratic upstream service times, the host-side mirror of the
  paper's concurrent data mover (section 3.1: latency insensitivity).
* **Measurability** — per-stage stall/throughput stats expose where the
  basin actually chokes, so the fidelity gap can be attributed.
"""

from __future__ import annotations

import dataclasses
import threading
import time
import traceback
from typing import Any, Callable, Generic, Iterable, Iterator, Optional, Sequence, TypeVar

from .burst_buffer import BufferClosed, BurstBuffer

T = TypeVar("T")
U = TypeVar("U")


@dataclasses.dataclass
class StageReport:
    name: str
    items: int
    bytes: int
    elapsed_s: float
    stall_up_s: float      # waiting on upstream (source starvation)
    stall_down_s: float    # waiting on our buffer (downstream backpressure)
    errors: int

    @property
    def throughput_bytes_per_s(self) -> float:
        return self.bytes / self.elapsed_s if self.elapsed_s > 0 else 0.0


class Stage(Generic[T, U]):
    """One staging hop: pull from upstream, transform, stage into a buffer."""

    def __init__(
        self,
        name: str,
        *,
        capacity: int = 4,
        workers: int = 1,
        transform: Optional[Callable[[T], U]] = None,
        sizeof: Optional[Callable[[Any], int]] = None,
    ):
        self.name = name
        self.buffer: BurstBuffer[U] = BurstBuffer(capacity, name=f"{name}.buf")
        self.workers = workers
        self.transform = transform
        self.sizeof = sizeof or _default_sizeof
        self._threads: list[threading.Thread] = []
        self._lock = threading.Lock()
        self._items = 0
        self._bytes = 0
        self._stall_up_s = 0.0
        self._errors = 0
        self._error_tb: Optional[str] = None
        self._finished = 0
        self._t_start: Optional[float] = None
        self._t_end: Optional[float] = None

    # -- execution ----------------------------------------------------------

    def start(self, upstream: Callable[[], Optional[T]]) -> None:
        """Begin staging.  ``upstream()`` returns the next item or ``None``
        at end-of-stream; it must be thread-safe for ``workers > 1``."""
        self._t_start = time.monotonic()

        def run() -> None:
            try:
                while True:
                    t0 = time.monotonic()
                    item = upstream()
                    with self._lock:
                        self._stall_up_s += time.monotonic() - t0
                    if item is None:
                        break
                    out = self.transform(item) if self.transform else item
                    try:
                        self.buffer.put(out)
                    except BufferClosed:
                        break
                    with self._lock:
                        self._items += 1
                        self._bytes += self.sizeof(out)
            except Exception:
                with self._lock:
                    self._errors += 1
                    self._error_tb = traceback.format_exc()
            finally:
                with self._lock:
                    # last worker out closes the buffer (explicit counter:
                    # checking thread liveness races when several workers
                    # exit together and nobody closes)
                    self._finished += 1
                    if self._finished == len(self._threads):
                        self._t_end = time.monotonic()
                        self.buffer.close()

        self._threads = [
            threading.Thread(target=run, name=f"{self.name}-{i}", daemon=True)
            for i in range(self.workers)
        ]
        for t in self._threads:
            t.start()

    def join(self, timeout: Optional[float] = None) -> None:
        for t in self._threads:
            t.join(timeout)
        if self._error_tb:
            raise RuntimeError(f"stage {self.name} failed:\n{self._error_tb}")

    # -- reporting -----------------------------------------------------------

    def report(self) -> StageReport:
        end = self._t_end or time.monotonic()
        start = self._t_start or end
        return StageReport(
            name=self.name,
            items=self._items,
            bytes=self._bytes,
            elapsed_s=end - start,
            stall_up_s=self._stall_up_s,
            stall_down_s=self.buffer.stats.producer_stall_s,
            errors=self._errors,
        )


class StagePipeline:
    """A chain of stages: source iterator -> stage_1 -> ... -> stage_n.

    The caller consumes from ``pipeline.output`` (the last stage's buffer)
    or via iteration.  Every hop runs concurrently; throughput settles at
    the basin bottleneck and each hop's report shows whether it starved
    (upstream too slow) or backpressured (downstream too slow).
    """

    def __init__(self, source: Iterable[Any], stages: Sequence[Stage]):
        if not stages:
            raise ValueError("need at least one stage")
        self.stages = list(stages)
        self._source_iter = iter(source)
        self._source_lock = threading.Lock()
        self._started = False

    def _source_pull(self) -> Optional[Any]:
        with self._source_lock:
            return next(self._source_iter, None)

    @staticmethod
    def _buffer_pull(buf: BurstBuffer) -> Callable[[], Optional[Any]]:
        def pull() -> Optional[Any]:
            try:
                return buf.get()
            except BufferClosed:
                return None
        return pull

    def start(self) -> "StagePipeline":
        if self._started:
            raise RuntimeError("pipeline already started")
        self._started = True
        upstream: Callable[[], Optional[Any]] = self._source_pull
        for stage in self.stages:
            stage.start(upstream)
            upstream = self._buffer_pull(stage.buffer)
        return self

    @property
    def output(self) -> BurstBuffer:
        return self.stages[-1].buffer

    def __iter__(self) -> Iterator[Any]:
        if not self._started:
            self.start()
        return self.output.drain()

    def join(self, timeout: Optional[float] = None) -> None:
        for stage in self.stages:
            stage.join(timeout)

    def reports(self) -> list[StageReport]:
        return [s.report() for s in self.stages]

    def bottleneck(self) -> StageReport:
        """The slowest stage by observed throughput (ties to basin model)."""
        reps = self.reports()
        return min(reps, key=lambda r: r.throughput_bytes_per_s or float("inf"))


def _default_sizeof(x: Any) -> int:
    nbytes = getattr(x, "nbytes", None)
    if nbytes is not None:
        return int(nbytes)
    if isinstance(x, (bytes, bytearray, memoryview)):
        return len(x)
    if isinstance(x, (tuple, list)):
        return sum(_default_sizeof(e) for e in x)
    if isinstance(x, dict):
        return sum(_default_sizeof(v) for v in x.values())
    return 0
