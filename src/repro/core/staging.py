"""Data staging — the coordinating process between mismatched tiers.

Paper section 2.1: "Data staging ... is a critical coordinating process.
This operation must be straightforward, predictable, and highly efficient,
as any delay in staging fundamentally negates the performance benefits of
burst buffering."

A :class:`Stage` is a worker (or pool of workers) that moves items from an
upstream source (an iterator or another stage's burst buffer) into its own
:class:`~repro.core.burst_buffer.BurstBuffer`, optionally applying a
transform (decode, shard, checksum, quantize, host-to-device put).
Chaining stages yields a :class:`StagePipeline` — the executable form of a
drainage-basin path.

Design points lifted from the paper:

* **No central scheduler** — each stage runs free and coordinates only
  through buffer state (backpressure), section 2.2.
* **Concurrency as the latency antidote** — multiple workers per stage
  overlap erratic upstream service times, the host-side mirror of the
  paper's concurrent data mover (section 3.1: latency insensitivity).
* **Measurability** — per-stage stall/throughput stats expose where the
  basin actually chokes, so the fidelity gap can be attributed.

Branching paths (DAG basins) run as a :class:`ParallelBranchPipeline`:
one :class:`StagePipeline` per branch, each with its own source, all
draining into a shared merge buffer as ``(branch_id, item)`` pairs, and
every branch's :class:`StageReport` tagged ``"<branch>/<stage>"`` so the
planner's ``replan`` can attribute a stall to the one degraded branch.

Stages are **live-resizable**: :meth:`Stage.resize` grows or shrinks the
worker pool against the running queues (spawn new workers / lazily retire
surplus ones — no thread-pool teardown) and re-sizes the stage's burst
buffer in place.  Together with :meth:`BurstBuffer.resize
<repro.core.burst_buffer.BurstBuffer.resize>` this is what lets the mover
apply a revised plan to a *running* pipeline (zero-drain replanning)
instead of draining and rebuilding it at every segment boundary;
:func:`delta_report` carves the continuously-running stage's cumulative
counters into per-revision-window evidence for ``replan``.

Windowed (RTT-governed) hops run as a :class:`WindowedStage`: a CHANNEL
hop on a long link is clocked by acknowledgements, not by queue space —
throughput is ``window / RTT`` however much bandwidth is provisioned
(paper §3.1/§3.2, the congestion-window fallacy).  The windowed stage
caps *unacknowledged in-flight bytes* at a plan-assigned ``window_bytes``
and accounts the time workers spend waiting for credit as
``StageReport.stall_window_s`` — a third stall side, distinct from
upstream starvation and downstream backpressure, because its remedy
(raise the window) is distinct from both.

Stages are **batch-admitted**: with ``batch_items > 1`` a worker pulls a
whole slab of items per loop (``upstream_many``), admits the slab's total
wire bytes through the transport-credit seam in one call, transforms it
(a transform exposing a ``.many`` attribute handles the slab in one
invocation), and stages it with one ``put_many`` — one lock round-trip
and one admission check per slab instead of per item.  The paper's host
bottleneck is exactly this per-item coordination cost; collapsing it is
how the staging layer gets out of the basin's way.  Per-slab credit keeps
``WindowedStage`` accounting honest: the ACK ledger carries one entry of
the slab's total bytes, and admission waits still accrue to
``stall_window_s``.  ``batch_items=1`` (the default) is byte-for-byte the
historical per-item path.
"""

from __future__ import annotations

import dataclasses
import heapq
import itertools
import random
import threading
import time
import traceback
from typing import Any, Callable, Generic, Iterable, Iterator, Optional, Sequence, TypeVar

from .burst_buffer import BufferClosed, BurstBuffer

T = TypeVar("T")
U = TypeVar("U")

#: per-side service-time samples kept per stage (bounded: a multi-day
#: transfer must not grow its report without bound)
SERVICE_RESERVOIR = 64


class _Reservoir:
    """Bounded uniform sample of a float stream (Algorithm R).

    The PRNG is seeded per reservoir so a deterministic run produces a
    deterministic report — the property the simulated-basin test harness
    relies on."""

    def __init__(self, k: int = SERVICE_RESERVOIR, seed: int = 0x5EED):
        self._k = k
        self._n = 0
        self._rng = random.Random(seed)
        self.samples: list[float] = []

    def add(self, x: float) -> None:
        self._n += 1
        if len(self.samples) < self._k:
            self.samples.append(x)
        else:
            j = self._rng.randrange(self._n)
            if j < self._k:
                self.samples[j] = x


@dataclasses.dataclass
class StageReport:
    name: str
    items: int
    bytes: int
    elapsed_s: float
    stall_up_s: float      # waiting on upstream (source starvation)
    stall_down_s: float    # waiting on our buffer (downstream backpressure)
    errors: int
    #: waiting for transport credit — in-flight bytes pinned at the hop's
    #: ``window_bytes`` until ACKs return (WindowedStage only; 0.0 on
    #: queue-clocked stages).  Kept apart from the queue stalls because
    #: its remedy is raising the window, not adding workers or buffers.
    stall_window_s: float = 0.0
    #: start -> last completed item: the stage's *active* window.  In a
    #: parallel-branch segment a fast branch finishes early and idles
    #: until the slowest branch drains; rates judged over ``elapsed_s``
    #: would read that idle tail as underdelivery.  0.0 = unknown (treat
    #: as ``elapsed_s``).
    active_s: float = 0.0
    #: bounded reservoir of per-item upstream service times (pull->item);
    #: the regime signature planner.replan diagnoses latency- vs
    #: bandwidth-bound stalls from
    service_up_s: list[float] = dataclasses.field(default_factory=list)
    #: bounded reservoir of per-item downstream delivery times (put->done)
    service_down_s: list[float] = dataclasses.field(default_factory=list)
    #: retransmissions the hop's channel paid in this window (§3.2 loss)
    #: — the evidence behind the planner's **loss-bound** verdict.  0 on
    #: hops without an observable channel.
    retransmits: int = 0
    #: sum and count of observed ACK round-trip times (WindowedStage
    #: only): ``rtt_sum_s / acks`` is the live RTT estimate the planner
    #: revises ``HopPlan.rtt_s`` from — a route change shows up here
    #: *before* it can masquerade as a window-bound stall.
    rtt_sum_s: float = 0.0
    acks: int = 0
    #: transform attempts re-run after a raise, and the backoff the
    #: workers waited before re-running them — first-hand fault evidence
    #: (the planner's **fault-degraded** verdict reads these BEFORE the
    #: stall classifiers, so a flapping hop is priced as faulty rather
    #: than misread as latency-bound).
    retries: int = 0
    retry_wait_s: float = 0.0

    @property
    def throughput_bytes_per_s(self) -> float:
        return self.bytes / self.elapsed_s if self.elapsed_s > 0 else 0.0

    @property
    def rtt_estimate_s(self) -> float:
        """Mean observed ACK round trip (0.0 = no windowed observations)."""
        return self.rtt_sum_s / self.acks if self.acks > 0 else 0.0


#: end-of-stream sentinel for the segment peek (None is a valid item)
_EXHAUSTED = object()


def slab_views(buf: Any, item_bytes: int) -> Iterator[memoryview]:
    """Zero-copy item stream over a contiguous buffer: yields
    ``memoryview`` slices of ``item_bytes`` each (last may be short).

    The slices share the underlying storage — no per-item copy is made
    anywhere in the staging path, which treats ``memoryview`` as a
    first-class item type (``_default_sizeof`` measures it by ``len``)."""
    if item_bytes <= 0:
        raise ValueError(f"item_bytes must be > 0, got {item_bytes}")
    view = memoryview(buf)
    for off in range(0, len(view), item_bytes):
        yield view[off:off + item_bytes]


def iter_segments(source_it: Iterator[Any],
                  items_per_segment: int) -> Iterator[Iterator[Any]]:
    """Split an iterator into consecutive segments of up to
    ``items_per_segment`` items (0 = one segment covering everything).

    This is the online-replanning boundary protocol shared by the mover
    and the input pipeline: each yielded segment must be fully drained
    before the next is requested (a buffer boundary), and the one-item
    peek between segments means an exactly-exhausted source ends the
    loop without a phantom empty segment.  The peeked item is prepended
    to the *next* segment directly — no nested re-wrapping of the source,
    so pull cost stays O(1) however many boundaries a long stream
    crosses."""
    if not items_per_segment:
        yield source_it
        return
    pushback = next(source_it, _EXHAUSTED)
    while pushback is not _EXHAUSTED:
        yield itertools.chain(
            [pushback], itertools.islice(source_it, items_per_segment - 1))
        pushback = next(source_it, _EXHAUSTED)


def merge_reports(chunks: Sequence[Sequence[StageReport]]) -> list[StageReport]:
    """Fold per-chunk stage reports into one report per stage name.

    Online replanning runs one pipeline per chunk, but the transfer is a
    single observable: counters and stall times sum, service-time
    reservoirs concatenate keeping the newest ``SERVICE_RESERVOIR``
    samples (the most recent regime is what the next replan should see)."""
    merged: dict[str, StageReport] = {}
    order: list[str] = []
    for reports in chunks:
        for r in reports:
            m = merged.get(r.name)
            if m is None:
                merged[r.name] = dataclasses.replace(
                    r, service_up_s=list(r.service_up_s),
                    service_down_s=list(r.service_down_s))
                order.append(r.name)
                continue
            m.items += r.items
            m.bytes += r.bytes
            m.elapsed_s += r.elapsed_s
            m.active_s += r.active_s
            m.stall_up_s += r.stall_up_s
            m.stall_down_s += r.stall_down_s
            m.stall_window_s += r.stall_window_s
            m.errors += r.errors
            m.retransmits += r.retransmits
            m.rtt_sum_s += r.rtt_sum_s
            m.acks += r.acks
            m.retries += r.retries
            m.retry_wait_s += r.retry_wait_s
            m.service_up_s = (m.service_up_s
                              + list(r.service_up_s))[-SERVICE_RESERVOIR:]
            m.service_down_s = (m.service_down_s
                                + list(r.service_down_s))[-SERVICE_RESERVOIR:]
    return [merged[n] for n in order]


def delta_report(cur: StageReport,
                 prev: Optional[StageReport]) -> StageReport:
    """The window between two cumulative reports of one *continuously
    running* stage — the zero-drain counterpart of a per-segment report.

    A persistent pipeline's counters accumulate from start; feeding the
    same early stall seconds through ``replan`` at every revision
    checkpoint would re-apply consumed evidence and defeat damping.  This
    subtracts the previously-consumed totals, leaving exactly one
    revision window's evidence.  Service reservoirs do not difference —
    the caller resets them per window (``Stage.reset_service_reservoirs``)
    so ``cur`` already carries only fresh samples, which pass through."""
    if prev is None:
        return cur
    return dataclasses.replace(
        cur,
        items=cur.items - prev.items,
        bytes=cur.bytes - prev.bytes,
        elapsed_s=cur.elapsed_s - prev.elapsed_s,
        active_s=max(0.0, cur.active_s - prev.active_s),
        stall_up_s=cur.stall_up_s - prev.stall_up_s,
        stall_down_s=cur.stall_down_s - prev.stall_down_s,
        stall_window_s=cur.stall_window_s - prev.stall_window_s,
        errors=cur.errors - prev.errors,
        retransmits=cur.retransmits - prev.retransmits,
        rtt_sum_s=max(0.0, cur.rtt_sum_s - prev.rtt_sum_s),
        acks=cur.acks - prev.acks,
        retries=cur.retries - prev.retries,
        retry_wait_s=max(0.0, cur.retry_wait_s - prev.retry_wait_s))


def delta_reports(cur: Sequence[StageReport],
                  prev: Sequence[StageReport]) -> list[StageReport]:
    """Per-stage windows between two cumulative report snapshots (matched
    by name; a stage absent from ``prev`` passes through whole)."""
    by_name = {r.name: r for r in prev}
    out = []
    for r in cur:
        d = delta_report(r, by_name.get(r.name))
        if d.elapsed_s > 0 and d.items > 0:
            out.append(d)
    return out


class Stage(Generic[T, U]):
    """One staging hop: pull from upstream, transform, stage into a buffer."""

    def __init__(
        self,
        name: str,
        *,
        capacity: int = 4,
        workers: int = 1,
        transform: Optional[Callable[[T], U]] = None,
        sizeof: Optional[Callable[[Any], int]] = None,
        clock: Optional[Callable[[], float]] = None,
        batch_items: int = 1,
        retry_budget: int = 0,
        backoff_base_s: float = 0.05,
    ):
        self.name = name
        self._clock = clock or time.monotonic
        self.buffer: BurstBuffer[U] = BurstBuffer(capacity, name=f"{name}.buf",
                                                  clock=self._clock)
        self.workers = workers
        self.transform = transform
        self.sizeof = sizeof or _default_sizeof
        #: slab size: items pulled/admitted/staged per worker loop.  1 =
        #: the per-item path; >1 engages the batched loop when the
        #: upstream supports many-pulls.  Read at each loop head so a
        #: live ``resize(batch_items=...)`` takes effect mid-stream.
        self.batch_items = max(1, int(batch_items))
        #: channel-observability hook: a transform may expose the hop's
        #: underlying channel as ``transform.channel`` (tests/simbasin.py
        #: attaches the SimulatedLink; a production wrapper would expose
        #: its socket stats).  The stage reads the channel's live
        #: ``retransmits`` counter and ``rtt_s`` — the §3.2 evidence that
        #: makes loss and route changes *diagnosable* instead of silent.
        self._channel = getattr(transform, "channel", None)
        #: fault tolerance: a transform raise is retried up to
        #: ``retry_budget`` times with exponential backoff
        #: (``backoff_base_s * 2**attempt``) plus seeded jitter before the
        #: error surfaces.  0 (the default) is the historical fail-fast
        #: path; the planner staffs real budgets per hop
        #: (``HopPlan.retry_budget``).  Retries and backoff waits accrue
        #: to the report as ``retries``/``retry_wait_s`` — fault evidence,
        #: deliberately kept OUT of the service reservoirs so the regime
        #: diagnosis still reads clean service cost.
        self.retry_budget = max(0, int(retry_budget))
        self.backoff_base_s = float(backoff_base_s)
        # seeded from the stage name (stable across runs, unlike hash()):
        # backoff jitter must be a pure function of the script
        self._retry_rng = random.Random(0xFA11 ^ sum(name.encode()))
        self._retries = 0
        self._retry_wait_s = 0.0
        self._retrans_base = 0
        self._rtt_obs_sum = 0.0
        self._rtt_obs_n = 0
        self._threads: list[threading.Thread] = []
        self._lock = threading.Lock()
        self._items = 0
        self._bytes = 0
        self._stall_up_s = 0.0
        self._stall_window_s = 0.0      # WindowedStage accrues; base never
        self._errors = 0
        self._error_tb: Optional[str] = None
        self._upstream: Optional[Callable[[], Optional[T]]] = None
        self._upstream_many: Optional[
            Callable[[int], Optional[list[T]]]] = None
        self._active = 0        # spawned minus exited workers
        self._retire = 0        # pending lazy-retirement requests
        #: items a worker held when its transform failed for good (budget
        #: exhausted) — the branch-failover layer re-routes these onto
        #: surviving branches instead of silently dropping them
        self._salvage: list = []
        self._spawned = 0       # lifetime worker counter (thread names)
        self._t_start: Optional[float] = None
        self._t_end: Optional[float] = None
        self._t_last: Optional[float] = None
        self._service_up = _Reservoir()
        self._service_down = _Reservoir(seed=0xD011)

    # -- execution ----------------------------------------------------------

    def start(self, upstream: Callable[[], Optional[T]],
              upstream_many: Optional[
                  Callable[[int], Optional[list[T]]]] = None) -> None:
        """Begin staging.  ``upstream()`` returns the next item or ``None``
        at end-of-stream; it must be thread-safe for ``workers > 1``.
        ``upstream_many(k)`` (optional) returns up to ``k`` items as a
        list, or ``None``/``[]`` at end-of-stream, in ONE upstream lock
        round-trip — the slab pull the batched worker loop rides.  When
        absent, ``batch_items > 1`` falls back to the per-item loop."""
        self._t_start = self._clock()
        # snapshot the channel's cumulative retransmit counter so this
        # stage reports only ITS OWN window of losses (segmented movers
        # build a fresh stage per segment over one long-lived channel;
        # without the base, merge_reports would multiply-count)
        if self._channel is not None:
            self._retrans_base = int(getattr(self._channel,
                                             "retransmits", 0))
        self._upstream = upstream
        self._upstream_many = upstream_many
        self._spawn(self.workers)

    def _spawn(self, n: int) -> None:
        """Add ``n`` workers against the live upstream/buffer (used at
        start and by live pool growth — no pipeline teardown either way)."""
        if n <= 0:
            return
        # simulation seam: a virtual clock (tests/simbasin.py) anchors the
        # spawned workers' timelines to this instant, so simulated
        # concurrency is deterministic; a real clock has no such hook.
        # Only the FIRST spawn anchors: a live pool growth must not
        # re-anchor at the global frontier — that frontier includes the
        # laggard completions of unrelated slow branches, and charging
        # them to a healthy stage's new workers would be phantom delay.
        spawn_hook = getattr(self._clock, "on_threads_spawn", None)
        if spawn_hook is not None and self._spawned == 0:
            spawn_hook()
        with self._lock:
            threads = [
                threading.Thread(target=self._run_worker,
                                 name=f"{self.name}-{self._spawned + i}",
                                 daemon=True)
                for i in range(n)
            ]
            self._spawned += n
            self._active += n
            # prune exited workers so a long-lived pipeline's grow/retire
            # churn doesn't accumulate dead Thread objects without bound
            self._threads = [t for t in self._threads
                             if t.is_alive()] + threads
        for t in threads:
            t.start()

    # -- transport-credit seam (no-ops here; see WindowedStage) --------------

    def _admit(self, nbytes: int) -> None:
        """Block until the hop may put ``nbytes`` more in flight.  The
        base stage is queue-clocked — admission is free."""

    def _on_sent(self, nbytes: int, t_sent: float) -> None:
        """Record that ``nbytes`` finished transmitting at ``t_sent`` (the
        instant the credit clock starts counting toward their ACK)."""

    # -- fault tolerance ------------------------------------------------------

    def _backoff(self, wait_s: float) -> None:
        """Wait out one retry backoff.  Under the simulated basin's
        virtual clock the waiter's own timeline jumps forward (the same
        per-thread model as windowed admission), so a scripted fault's
        recovery point is deterministic; under a real clock it sleeps."""
        set_thread = getattr(self._clock, "set_thread", None)
        thread_now = getattr(self._clock, "thread_now", None)
        if set_thread is not None and thread_now is not None:
            set_thread(thread_now() + wait_s)
        else:
            time.sleep(wait_s)

    def _run_with_retry(self, attempt_fn: Callable[[], U]) -> U:
        """Run one transform attempt under the hop's retry policy:
        ``retry_budget`` re-runs with exponential backoff and seeded
        jitter.  The final failure re-raises (the worker's error path —
        and, one level up, branch failover — takes over from there)."""
        budget = self.retry_budget
        if budget <= 0:
            return attempt_fn()
        attempt = 0
        while True:
            try:
                return attempt_fn()
            except Exception:
                if attempt >= budget:
                    raise
                # exponential backoff with jitter in [1x, 1.5x): spreads
                # sibling workers' retries so a recovered hop is not
                # re-stormed by a synchronized burst.  Drawn under the
                # stage lock so the jitter sequence is well-defined.
                with self._lock:
                    wait = (self.backoff_base_s * (2 ** attempt)
                            * (1.0 + 0.5 * self._retry_rng.random()))
                    self._retries += 1
                    self._retry_wait_s += wait
                attempt += 1
                self._backoff(wait)

    def _run_worker(self) -> None:
        try:
            while True:
                with self._lock:
                    # lazy retirement: a live pool shrink takes effect at
                    # the worker's next loop head, never mid-item
                    if self._retire > 0:
                        self._retire -= 1
                        return
                # the slab size is re-read each loop so a live
                # resize(batch_items=...) takes effect without a rebuild
                k = self.batch_items
                if k > 1 and self._upstream_many is not None:
                    if not self._step_batch(k):
                        break
                elif not self._step_one():
                    break
        except Exception:
            with self._lock:
                self._errors += 1
                self._error_tb = traceback.format_exc()
        finally:
            with self._lock:
                # last worker out closes the buffer (explicit counter:
                # checking thread liveness races when several workers
                # exit together and nobody closes).  Retired workers only
                # decrement — resize never shrinks the target below one,
                # so the count reaches zero exactly at end-of-stream.
                self._active -= 1
                if self._active == 0 and self._t_end is None:
                    self._t_end = self._clock()
                    self.buffer.close()

    def _step_one(self) -> bool:
        """One per-item loop iteration; False ends the worker (EOS or a
        closed downstream buffer)."""
        t0 = self._clock()
        item = self._upstream()
        dt_up = self._clock() - t0
        with self._lock:
            self._stall_up_s += dt_up
        if item is None:
            return False
        # transport credit is acquired on the PRE-transform size
        # (the bytes handed to the wire) and released on the same
        # figure — admission waits are window stall, kept out of
        # the service samples so the regime diagnosis still reads
        # pure pull+transform cost
        nbytes_wire = self.sizeof(item)
        self._admit(nbytes_wire)
        t_tx0 = self._clock()
        try:
            out = (self._run_with_retry(lambda: self.transform(item))
                   if self.transform else item)
        except BaseException:
            # a failed transmit must still return its credit (via
            # the ACK path, one RTT out) or siblings blocked on
            # the window would wait on an ACK that never comes
            self._on_sent(nbytes_wire, self._clock())
            with self._lock:
                self._salvage.append(item)
            raise
        t1 = self._clock()
        self._on_sent(nbytes_wire, t1)
        with self._lock:
            # upstream service sample = pull + transform: the
            # full cost of acquiring one staged item.  A slow
            # transform (e.g. a storage fetch riding the hop)
            # keeps the worker busy rather than stalled, and
            # only this sample reveals it to the replanner.
            self._service_up.add(dt_up + (t1 - t_tx0))
        try:
            self.buffer.put(out)
        except BufferClosed:
            return False
        dt_down = self._clock() - t1
        with self._lock:
            self._items += 1
            self._bytes += self.sizeof(out)
            self._service_down.add(dt_down)
            self._t_last = self._clock()
        return True

    def _step_batch(self, k: int) -> bool:
        """One slab loop iteration: pull up to ``k`` items in one upstream
        round-trip, admit the slab's total wire bytes in ONE credit check,
        transform, and stage with ONE ``put_many`` — the zero-copy data
        plane's amortized hot path.  Stats parity with ``_step_one``:
        items/bytes count identically, and the service reservoirs record
        the slab's per-item mean so the regime signature stays comparable
        with per-item evidence."""
        t0 = self._clock()
        batch = self._upstream_many(k)
        dt_up = self._clock() - t0
        with self._lock:
            self._stall_up_s += dt_up
        if not batch:
            return False
        sizeof = self.sizeof
        nbytes_wire = sum(sizeof(it) for it in batch)
        # ONE admission for the whole slab: credit is debited per-slab,
        # and the matching _on_sent posts one ACK-ledger entry of the
        # same total, so WindowedStage in-flight accounting balances
        self._admit(nbytes_wire)
        t_tx0 = self._clock()
        transform = self.transform
        try:
            if transform is None:
                out = batch
            else:
                many = getattr(transform, "many", None)
                # the whole slab is one retryable attempt: a mid-slab
                # fault re-runs the slab (simulated tiers charge per
                # serve, so the re-run is paid for honestly)
                out = self._run_with_retry(
                    lambda: list(many(batch)) if many is not None
                    else [transform(it) for it in batch])
        except BaseException:
            self._on_sent(nbytes_wire, self._clock())
            with self._lock:
                self._salvage.extend(batch)
            raise
        t1 = self._clock()
        self._on_sent(nbytes_wire, t1)
        n = len(out)
        with self._lock:
            self._service_up.add((dt_up + (t1 - t_tx0)) / n)
        try:
            self.buffer.put_many(out)
        except BufferClosed:
            return False
        dt_down = self._clock() - t1
        with self._lock:
            self._items += n
            self._bytes += sum(sizeof(o) for o in out)
            self._service_down.add(dt_down / n)
            self._t_last = self._clock()
        return True

    def resize(self, *, capacity: Optional[int] = None,
               workers: Optional[int] = None,
               window_bytes: Optional[float] = None,
               batch_items: Optional[int] = None,
               rtt_s: Optional[float] = None,
               retry_budget: Optional[int] = None,
               backoff_base_s: Optional[float] = None) -> None:
        """Apply revised staging parameters to the *running* stage.

        ``capacity`` re-sizes the stage's burst buffer in place
        (:meth:`BurstBuffer.resize
        <repro.core.burst_buffer.BurstBuffer.resize>`); ``workers`` grows
        the pool by spawning workers against the live queues or shrinks it
        by lazily retiring surplus workers (each exits at its next loop
        head — no thread-pool teardown, no staged item dropped).  Both are
        no-ops when the value is unchanged; the worker target is clamped
        to >= 1 so the stream can always finish.  ``window_bytes`` is
        accepted for call-site uniformity but only a
        :class:`WindowedStage` has a window to revise.  ``batch_items``
        revises the slab size live — each worker reads it at its next
        loop head, so a replan can collapse a misbehaving batched hop to
        per-item (or vice versa) with zero drain.  ``rtt_s`` revises a
        windowed stage's ACK clock (an rtt-revised verdict); ignored on
        queue-clocked stages.  ``retry_budget`` / ``backoff_base_s``
        revise the hop's fault posture live — workers read both at the
        next transform attempt, so a fault-priced budget from telemetry
        priors applies zero-drain."""
        if capacity is not None and capacity != self.buffer.capacity:
            self.buffer.resize(capacity)
        if retry_budget is not None:
            self.retry_budget = max(0, int(retry_budget))
        if backoff_base_s is not None and backoff_base_s > 0:
            self.backoff_base_s = float(backoff_base_s)
        if batch_items is not None:
            self.batch_items = max(1, int(batch_items))
        if workers is None:
            return
        target = max(1, int(workers))
        grow = 0
        with self._lock:
            if self._t_end is not None:
                # stream already ended: record the target for reporting
                # but there is nothing left to staff
                self.workers = target
                return
            current = self._active - self._retire
            self.workers = target
            if target > current:
                grow = target - current
                # growth first cancels pending retirements (cheaper than
                # spawning a thread while another is about to exit)
                cancelled = min(self._retire, grow)
                self._retire -= cancelled
                grow -= cancelled
            elif target < current:
                self._retire += current - target
        if grow > 0 and self._upstream is not None:
            self._spawn(grow)

    @property
    def failed(self) -> bool:
        """True once a worker died on an unretryable (or
        budget-exhausted) error — the dead-branch signal failover acts
        on."""
        with self._lock:
            return self._error_tb is not None

    def take_salvage(self) -> list:
        """Claim (and clear) the items workers held when their transforms
        failed for good, so a failover path can re-route them."""
        with self._lock:
            out, self._salvage = self._salvage, []
            return out

    def error_summary(self) -> str:
        """Last line of the fatal error's traceback ('' while healthy) —
        the one-line obituary failover verdicts carry."""
        with self._lock:
            tb = self._error_tb
        if not tb:
            return ""
        lines = [ln for ln in tb.strip().splitlines() if ln.strip()]
        return lines[-1].strip() if lines else ""

    def wait(self, timeout: Optional[float] = None) -> None:
        """Join worker threads without raising on a recorded error — the
        quiescence barrier failover needs before salvaging (join() is the
        fail-fast form)."""
        with self._lock:
            threads = list(self._threads)
        for t in threads:
            t.join(timeout)

    def join(self, timeout: Optional[float] = None) -> None:
        self.wait(timeout)
        if self._error_tb:
            raise RuntimeError(f"stage {self.name} failed:\n{self._error_tb}")

    # -- reporting -----------------------------------------------------------

    def reset_service_reservoirs(self) -> None:
        """Start fresh per-item service windows.  Online replanning over
        a continuously running stage consumes samples one revision window
        at a time; without a reset, a long-gone regime's samples linger
        in the uniform reservoir and keep polluting every later
        diagnosis."""
        with self._lock:
            self._service_up = _Reservoir()
            self._service_down = _Reservoir(seed=0xD011)

    def report(self) -> StageReport:
        # explicit None checks: a virtual clock legitimately starts at 0.0
        end = self._t_end if self._t_end is not None else self._clock()
        start = self._t_start if self._t_start is not None else end
        with self._lock:
            return StageReport(
                name=self.name,
                items=self._items,
                bytes=self._bytes,
                elapsed_s=end - start,
                active_s=(self._t_last - start
                          if self._t_last is not None else 0.0),
                stall_up_s=self._stall_up_s,
                stall_down_s=self.buffer.stats.producer_stall_s,
                stall_window_s=self._stall_window_s,
                errors=self._errors,
                retransmits=(int(getattr(self._channel, "retransmits", 0))
                             - self._retrans_base
                             if self._channel is not None else 0),
                rtt_sum_s=self._rtt_obs_sum,
                acks=self._rtt_obs_n,
                retries=self._retries,
                retry_wait_s=self._retry_wait_s,
                service_up_s=list(self._service_up.samples),
                service_down_s=list(self._service_down.samples),
            )


class WindowedStage(Stage):
    """A credit/ACK-clocked staging hop — the executable form of the
    paper's §3.1/§3.2 window-governed CHANNEL.

    A long link does not admit bytes because queue space exists; it
    admits them while the *congestion/flow-control window* has credit,
    and credit only returns one round trip after the bytes went out.
    The stage keeps an ACK ledger: transmitting an item occupies
    ``sizeof(item)`` bytes of the window from admission until ``rtt_s``
    after its transmission completes.  A worker that would overfill the
    window waits for the oldest outstanding ACK, and that wait is
    accounted as ``stall_window_s`` — separate from the queue stalls,
    because it caps throughput at ``window_bytes / rtt_s`` no matter how
    much bandwidth is provisioned or how many workers are staffed (the
    evidence behind the planner's **window-bound** verdict).

    The ACK clock is the injectable stage clock: under a real clock the
    waiter sleeps out the remaining round trip; under the simulated
    basin's virtual clock (per-thread timelines present) the waiter's own
    timeline jumps to the ACK instant — the same per-thread latency model
    ``SimulatedTier.serve`` uses — so windowed scenarios stay a pure
    function of the script and never wall-block.

    ``resize(window_bytes=...)`` revises the window on the *running*
    stage: growth wakes credit-blocked workers immediately (the
    zero-drain remedy for a window-bound verdict); shrinkage applies as
    outstanding ACKs return.  An item larger than the whole window is
    admitted alone (the stream must always make progress).

    **Fractional credit** — admission is whole-item, so a window worth
    ``k + f`` items (``0 < f < 1``) would truncate to ``k`` in flight
    and deliver only ``k/(k+f)`` of the grant (severe at small windows —
    an arbitered 10 ms hop granted 2.5 items delivers 80 %).  The stage
    therefore *banks* the stranded fractional credit: each admission
    that blocks on a nearly-full window deposits the unusable leftover
    (capped at one item), and once the bank covers an item's shortfall
    the item is admitted overdrawn.  Long-run average in-flight bytes
    stay ≤ the window; the instantaneous overdraft is bounded by one
    item — the grant is honored in expectation instead of floored.
    """

    def __init__(self, name: str, *, window_bytes: float, rtt_s: float,
                 **kwargs: Any):
        super().__init__(name, **kwargs)
        if window_bytes <= 0:
            raise ValueError(f"stage {name!r}: window_bytes must be > 0")
        if rtt_s < 0:
            raise ValueError(f"stage {name!r}: rtt_s must be >= 0")
        self.window_bytes = float(window_bytes)
        self.rtt_s = float(rtt_s)
        self._win_cond = threading.Condition(threading.Lock())
        self._inflight = 0.0                      # admitted, not yet ACKed
        self._acks: list[tuple[float, int]] = []  # heap of (ack_time, bytes)
        self._win_bank = 0.0    # stranded fractional credit, ≤ one item

    @property
    def inflight_bytes(self) -> float:
        with self._win_cond:
            self._reap(self._clock())
            return self._inflight

    def _reap(self, now: float) -> None:
        """Release credit for every ACK that has matured (win lock held)."""
        while self._acks and self._acks[0][0] <= now + 1e-12:
            _, nb = heapq.heappop(self._acks)
            self._inflight -= nb

    def _locked_try_admit(self, nbytes: int,
                          banked: bool) -> tuple[bool, bool]:
        """One admission attempt (win lock held, credit already reaped).

        Returns ``(admitted, banked)``.  A blocked attempt on a window
        with free-but-insufficient credit deposits that leftover into
        the fractional-credit bank — at most once per admission call
        (``banked`` tracks it), and the bank never exceeds one item —
        then admits overdrawn once bank + leftover cover the item."""
        if (self._inflight <= 0
                or self._inflight + nbytes <= self.window_bytes + 1e-9):
            self._inflight += nbytes
            return True, banked
        leftover = self.window_bytes - self._inflight
        if leftover > 0:
            if self._win_bank + leftover >= nbytes - 1e-9:
                # spend the bank: the overdraft is exactly the credit
                # truncation stranded on earlier admissions
                self._win_bank -= nbytes - leftover
                self._inflight += nbytes
                return True, banked
            if not banked:
                self._win_bank = min(self._win_bank + leftover,
                                     float(nbytes))
                banked = True
        return False, banked

    def _admit(self, nbytes: int) -> None:
        thread_now = getattr(self._clock, "thread_now", None)
        if thread_now is not None:
            self._admit_virtual(nbytes, thread_now)
        else:
            self._admit_wall(nbytes)

    def _admit_virtual(self, nbytes: int,
                       thread_now: Callable[[], float]) -> None:
        """Virtual-clock admission: the waiter's own timeline jumps to the
        oldest outstanding ACK (exactly how :meth:`SimulatedTier.serve`
        models latency), so window pacing stays a per-thread, scripted
        quantity — it neither wall-blocks nor drags the global frontier
        forward under other stages' stall measurements."""
        entry = thread_now()
        t = entry
        banked = False
        with self._win_cond:
            while True:
                self._reap(t)
                admitted, banked = self._locked_try_admit(nbytes, banked)
                if admitted:
                    break
                if self._acks:
                    # the oldest ACK's arrival is when credit next frees
                    t = max(t, self._acks[0][0])
                else:
                    # every in-flight byte belongs to a sibling worker
                    # still mid-transmit; its _on_sent will notify
                    self._win_cond.wait(timeout=0.05)
                    t = max(t, thread_now())
        if t > entry:
            self._clock.set_thread(t)
            with self._lock:
                self._stall_window_s += t - entry

    def _admit_wall(self, nbytes: int) -> None:
        """Real-clock admission: sleep out the remaining round trip of
        the oldest outstanding ACK, re-checking as ACKs mature."""
        t0 = self._clock()
        waited = False
        banked = False
        with self._win_cond:
            while True:
                self._reap(self._clock())
                admitted, banked = self._locked_try_admit(nbytes, banked)
                if admitted:
                    break
                waited = True
                if self._acks:
                    wait_s = max(1e-4, self._acks[0][0] - self._clock())
                    self._win_cond.wait(timeout=wait_s)
                else:
                    self._win_cond.wait(timeout=0.05)
        if waited:
            dt = self._clock() - t0
            with self._lock:
                self._stall_window_s += dt

    def _on_sent(self, nbytes: int, t_sent: float) -> None:
        thread_now = getattr(self._clock, "thread_now", None)
        if thread_now is not None:
            # virtual time: the send completed at this worker's timeline
            # position (its serve's completion), not the global frontier
            t_sent = thread_now()
        # the ACK clock rides the CHANNEL's live round trip when one is
        # observable (a route change physically lengthens every ACK the
        # moment it happens — the ledger must not keep ticking at the
        # planned rtt); the observation accrues to the report so replan
        # can revise HopPlan.rtt_s from the same evidence
        ch_rtt = getattr(self._channel, "rtt_s", None)
        rtt = (float(ch_rtt) if ch_rtt is not None and ch_rtt > 0
               else self.rtt_s)
        with self._win_cond:
            heapq.heappush(self._acks, (t_sent + rtt, nbytes))
            self._rtt_obs_sum += rtt
            self._rtt_obs_n += 1
            self._win_cond.notify_all()

    def resize(self, *, capacity: Optional[int] = None,
               workers: Optional[int] = None,
               window_bytes: Optional[float] = None,
               batch_items: Optional[int] = None,
               rtt_s: Optional[float] = None,
               retry_budget: Optional[int] = None,
               backoff_base_s: Optional[float] = None) -> None:
        if window_bytes is not None and window_bytes > 0 \
                and window_bytes != self.window_bytes:
            with self._win_cond:
                self.window_bytes = float(window_bytes)
                # growth admits credit-blocked workers immediately — the
                # live, zero-drain remedy for a window-bound verdict
                self._win_cond.notify_all()
        if rtt_s is not None and rtt_s > 0 and rtt_s != self.rtt_s:
            # an rtt-revised plan retimes the ACK clock for bytes not yet
            # sent; outstanding ledger entries keep their original ACK
            # instants (those bytes are already in flight on the old path)
            with self._win_cond:
                self.rtt_s = float(rtt_s)
                self._win_cond.notify_all()
        super().resize(capacity=capacity, workers=workers,
                       batch_items=batch_items, retry_budget=retry_budget,
                       backoff_base_s=backoff_base_s)


class StagePipeline:
    """A chain of stages: source iterator -> stage_1 -> ... -> stage_n.

    The caller consumes from ``pipeline.output`` (the last stage's buffer)
    or via iteration.  Every hop runs concurrently; throughput settles at
    the basin bottleneck and each hop's report shows whether it starved
    (upstream too slow) or backpressured (downstream too slow).
    """

    def __init__(self, source: Iterable[Any], stages: Sequence[Stage]):
        if not stages:
            raise ValueError("need at least one stage")
        self.stages = list(stages)
        # a BurstBuffer source (a dispatcher's branch feed) is pulled
        # directly via get/get_many: the intake gets true slab pulls
        # instead of one-item iterator steps under a lock
        if isinstance(source, BurstBuffer):
            self._source_buffer: Optional[BurstBuffer] = source
            self._source_iter = None
        else:
            self._source_buffer = None
            self._source_iter = iter(source)
        self._source_lock = threading.Lock()
        self._started = False
        # failover kill switch: once set, every pull reads end-of-stream,
        # so an aborted branch stops competing with its surviving
        # siblings for shared-intake items (see abort())
        self._aborted = threading.Event()

    def _source_pull(self) -> Optional[Any]:
        if self._aborted.is_set():
            return None
        with self._source_lock:
            return next(self._source_iter, None)

    def _source_pull_many(self, k: int) -> Optional[list[Any]]:
        if self._aborted.is_set():
            return None
        # one lock round-trip covers the whole slab
        with self._source_lock:
            batch = list(itertools.islice(self._source_iter, k))
        return batch or None

    def _buffer_pull(self, buf: BurstBuffer) -> Callable[[], Optional[Any]]:
        def pull() -> Optional[Any]:
            if self._aborted.is_set():
                return None
            try:
                return buf.get()
            except BufferClosed:
                return None
        return pull

    def _buffer_pull_many(self, buf: BurstBuffer
                          ) -> Callable[[int], Optional[list[Any]]]:
        def pull_many(k: int) -> Optional[list[Any]]:
            if self._aborted.is_set():
                return None
            try:
                return buf.get_many(k)
            except BufferClosed:
                return None
        return pull_many

    def abort(self) -> None:
        """Shut the pipeline down without losing staged items: every pull
        starts reading end-of-stream, and every stage buffer is closed so
        workers blocked mid-put unblock (staged items stay consumable by
        the buffer-close contract).  Branch failover calls this on a dead
        branch before salvaging what it stranded; it never touches a
        shared source buffer, which surviving siblings keep draining."""
        self._aborted.set()
        for st in self.stages:
            st.buffer.close()

    def start(self) -> "StagePipeline":
        if self._started:
            raise RuntimeError("pipeline already started")
        self._started = True
        if self._source_buffer is not None:
            upstream = self._buffer_pull(self._source_buffer)
            upstream_many = self._buffer_pull_many(self._source_buffer)
        else:
            upstream = self._source_pull
            upstream_many = self._source_pull_many
        for stage in self.stages:
            stage.start(upstream, upstream_many)
            upstream = self._buffer_pull(stage.buffer)
            upstream_many = self._buffer_pull_many(stage.buffer)
        return self

    @property
    def output(self) -> BurstBuffer:
        return self.stages[-1].buffer

    def __iter__(self) -> Iterator[Any]:
        if not self._started:
            self.start()
        return self.output.drain()

    def join(self, timeout: Optional[float] = None) -> None:
        for stage in self.stages:
            stage.join(timeout)

    def wait(self, timeout: Optional[float] = None) -> None:
        """Join without raising on a failed stage (the failover form)."""
        for stage in self.stages:
            stage.wait(timeout)

    def reports(self) -> list[StageReport]:
        return [s.report() for s in self.stages]

    def bottleneck(self) -> StageReport:
        """The slowest stage by observed throughput (ties to basin model)."""
        reps = self.reports()
        return min(reps, key=lambda r: r.throughput_bytes_per_s or float("inf"))


class ParallelBranchPipeline:
    """Parallel-branch execution: one :class:`StagePipeline` per branch.

    Each branch runs its own stage chain over its own source (a fan-in of
    shard iterators, or the per-branch queues a mover's dispatcher fills
    for fan-out).  Branch outputs drain concurrently into one shared
    merge buffer as ``(branch_id, item)`` pairs — the executable form of
    a fan-in (merge) node — and :meth:`reports` returns every branch's
    stage reports with names tagged ``"<branch>/<stage>"``, the key
    :func:`repro.core.planner.replan` uses for per-branch attribution.
    """

    def __init__(self, branches: Sequence[tuple[str, StagePipeline]], *,
                 merge_capacity: int = 8,
                 clock: Optional[Callable[[], float]] = None,
                 upstreams: Optional[dict[str, BurstBuffer]] = None,
                 shared_upstream: Optional[BurstBuffer] = None):
        if not branches:
            raise ValueError("need at least one branch")
        ids = [bid for bid, _ in branches]
        if len(set(ids)) != len(ids):
            raise ValueError(f"duplicate branch ids: {ids}")
        self.branches = list(branches)
        self._clock = clock or time.monotonic
        self.merge: BurstBuffer[tuple[str, Any]] = BurstBuffer(
            merge_capacity, name="branch-merge", clock=self._clock)
        # per-branch feed buffers to close when that branch exits: on a
        # branch failure this unblocks a dispatcher mid-put instead of
        # deadlocking it against a pipeline that stopped pulling
        self._upstreams = dict(upstreams or {})
        # work-stealing route: every branch pulls one shared intake, which
        # must only close when the LAST branch exits (a lone dead branch
        # leaves its siblings pulling; all dead unblocks the dispatcher)
        self._shared_upstream = shared_upstream
        self._drainers: list[threading.Thread] = []
        self._open_branches = 0
        self._lock = threading.Lock()
        self._started = False
        #: stranded items recovered from branches that died mid-segment,
        #: keyed by branch id — items the dead branch had pulled from its
        #: feed but never delivered to the merge.  Under a shared (steal)
        #: intake they are re-queued onto the survivors automatically; a
        #: per-branch (deal) dispatcher claims them via
        #: :meth:`take_stranded` and re-deals.
        self._stranded: dict[str, list] = {}
        self._dead: set[str] = set()

    def _salvage_branch(self, pipe: StagePipeline) -> list:
        """Everything the dead branch pulled but never delivered: items
        in workers' hands when their transforms failed for good, plus
        items parked in inter-stage buffers.  The branch is aborted and
        quiesced first — its pulls read end-of-stream so it stops
        competing with survivors for shared-intake items, and its closed
        buffers keep staged items consumable.  Items re-enter at the
        branch feed level: any transforms the dead branch already applied
        are re-applied by the surviving branch, which double-pays a hop's
        service rather than ever double-counting or dropping an item."""
        pipe.abort()
        for st in pipe.stages:
            st.wait()
        stranded: list = []
        for st in pipe.stages:
            stranded.extend(st.take_salvage())
        # the LAST stage's buffer feeds the merge drainer, which has
        # already drained it to exhaustion — only inter-stage parking
        # (and the stages' in-hand salvage) can strand items
        for st in pipe.stages[:-1]:
            try:
                while True:
                    stranded.extend(st.buffer.get_many(1 << 10))
            except BufferClosed:
                pass
        return stranded

    def start(self) -> "ParallelBranchPipeline":
        if self._started:
            raise RuntimeError("pipeline already started")
        self._started = True
        self._open_branches = len(self.branches)

        def drain(bid: str, pipe: StagePipeline) -> None:
            try:
                for item in pipe.output.drain():
                    try:
                        self.merge.put((bid, item))
                    except BufferClosed:
                        return
            finally:
                up = self._upstreams.get(bid)
                if up is not None:
                    up.close()
                died = any(st.failed for st in pipe.stages)
                stranded = self._salvage_branch(pipe) if died else []
                with self._lock:
                    # last branch out closes the merge (mirror of the
                    # last-worker-out rule inside Stage)
                    self._open_branches -= 1
                    last = self._open_branches == 0
                    if died:
                        self._dead.add(bid)
                        self._stranded.setdefault(bid, []).extend(stranded)
                if died and not last and stranded \
                        and self._shared_upstream is not None:
                    # steal route: hand the dead branch's stranded items
                    # straight back to the shared intake — the surviving
                    # branches pull them like any other work, so nothing
                    # committed to the intake is ever lost to one death
                    claim = self.take_stranded(bid)
                    try:
                        self._shared_upstream.put_many(claim)
                    except BufferClosed:
                        # intake already closed (death at stream tail):
                        # keep the claim stranded so the mover's final
                        # salvage sweep re-moves it instead of losing it
                        with self._lock:
                            self._stranded.setdefault(bid, []).extend(claim)
                if last:
                    if self._shared_upstream is not None:
                        self._shared_upstream.close()
                    self.merge.close()

        for bid, pipe in self.branches:
            pipe.start()
        self._drainers = [
            threading.Thread(target=drain, args=(bid, pipe),
                             name=f"drain-{bid}", daemon=True)
            for bid, pipe in self.branches
        ]
        for t in self._drainers:
            t.start()
        return self

    @property
    def output(self) -> BurstBuffer:
        """The merge buffer; yields ``(branch_id, item)`` pairs."""
        return self.merge

    def dead_branches(self) -> set[str]:
        """Branch ids that died (a stage exhausted its retry budget) —
        the dispatcher-side failover signal."""
        with self._lock:
            dead = set(self._dead)
        # a branch whose stage has failed but whose drainer has not yet
        # unwound still counts: the dispatcher must stop feeding it NOW
        for bid, pipe in self.branches:
            if bid not in dead and any(st.failed for st in pipe.stages):
                dead.add(bid)
        return dead

    def take_stranded(self, bid: str) -> list:
        """Claim (and clear) the items branch ``bid`` stranded when it
        died; the deal-route dispatcher re-deals them to survivors."""
        with self._lock:
            return self._stranded.pop(bid, [])

    def __iter__(self) -> Iterator[tuple[str, Any]]:
        if not self._started:
            self.start()
        return self.merge.drain()

    def join(self, timeout: Optional[float] = None) -> None:
        for _, pipe in self.branches:
            pipe.join(timeout)
        for t in self._drainers:
            t.join(timeout)

    def wait(self, timeout: Optional[float] = None) -> None:
        """Join without raising on dead branches — the failover form:
        survivors' completion is the success criterion, and the dead
        branches' errors are already recorded in :meth:`dead_branches`
        (and surfaced as ``branch-dead`` verdicts by the mover)."""
        for _, pipe in self.branches:
            pipe.wait(timeout)
        for t in self._drainers:
            t.join(timeout)

    def branch_error(self, bid: str) -> str:
        """First line of the recorded error for a dead branch ('' when
        none) — the obituary text a ``branch-dead(...)`` verdict carries."""
        for b, pipe in self.branches:
            if b != bid:
                continue
            for st in pipe.stages:
                tb = st.error_summary()
                if tb:
                    return tb
        return ""

    def reports(self) -> list[StageReport]:
        """Every branch's stage reports, names tagged ``<branch>/<stage>``."""
        out: list[StageReport] = []
        for bid, pipe in self.branches:
            for r in pipe.reports():
                out.append(dataclasses.replace(r, name=f"{bid}/{r.name}"))
        return out


def _default_sizeof(x: Any) -> int:
    nbytes = getattr(x, "nbytes", None)
    if nbytes is not None:
        return int(nbytes)
    if isinstance(x, (bytes, bytearray, memoryview)):
        return len(x)
    if isinstance(x, (tuple, list)):
        return sum(_default_sizeof(e) for e in x)
    if isinstance(x, dict):
        return sum(_default_sizeof(v) for v in x.values())
    return 0
