"""SSD (Mamba2) scan — Pallas TPU kernel.

Full chunked SSD in one kernel: grid (B, H, nc) with the minor-most chunk
axis sequential, so the recurrent (P, N) state lives in VMEM scratch and
flows across chunks — the inter-chunk recurrence costs zero HBM traffic.
Per chunk the dual quadratic form runs on the MXU:

    y_intra = (tril(exp(cum_i - cum_j)) * dt_j * (C_i . B_j)) @ x
    y_inter = exp(cum_i) * (C_i @ state_in)
    state   = exp(total) * state_in + B^T @ (exp(total - cum) * dt * x)

The pure-jnp oracle is :func:`repro.models.ssm.ssd_chunked`; tests sweep
(B, S, H, P, N, chunk) in interpret mode.

VMEM per step (Q=256, P=64, N<=128): x (Q,P) 64 KiB, B/C (Q,N) 128 KiB,
L/CB (Q,Q) f32 256 KiB each, state (P,N) 32 KiB — well under budget.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _ssd_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, y_ref, state_ref, *,
                Q: int, nc: int):
    ic = pl.program_id(2)

    @pl.when(ic == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    x = x_ref[0, 0, 0].astype(jnp.float32)         # (Q, P)
    dt = dt_ref[0, 0, 0].astype(jnp.float32)       # (Q,)
    A = a_ref[0].astype(jnp.float32)               # scalar (per head)
    Bm = b_ref[0, 0, 0].astype(jnp.float32)        # (Q, N)
    Cm = c_ref[0, 0, 0].astype(jnp.float32)        # (Q, N)

    dA = dt * A                                    # (Q,) negatives
    cum = jnp.cumsum(dA)                           # inclusive
    total = cum[Q - 1]

    li = cum[:, None] - cum[None, :]
    mask = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 0) >= \
        jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 1)
    L = jnp.where(mask, jnp.exp(li), 0.0) * dt[None, :]
    CB = jax.lax.dot_general(Cm, Bm, (((1,), (1,)), ((), ())))   # (Q, Q)
    W = CB * L
    y = jax.lax.dot_general(W, x, (((1,), (0,)), ((), ())))      # (Q, P)

    # inter-chunk: y += exp(cum) * (C @ state_in);  state: (P, N)
    state = state_ref[...]
    y = y + jnp.exp(cum)[:, None] * jax.lax.dot_general(
        Cm, state, (((1,), (1,)), ((), ())))
    # state update
    wdt = jnp.exp(total - cum) * dt                               # (Q,)
    state_ref[...] = jnp.exp(total) * state + jax.lax.dot_general(
        x * wdt[:, None], Bm, (((0,), (0,)), ((), ())))           # (P, N)

    y_ref[0, 0, 0] = y.astype(y_ref.dtype)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan_bhsd(x: jax.Array, dt: jax.Array, A: jax.Array, Bm: jax.Array,
                  Cm: jax.Array, *, chunk: int = 256,
                  interpret: bool = False) -> jax.Array:
    """x: (B, H, S, P); dt: (B, H, S) f32; A: (H,) f32;
    Bm/Cm: (B, G, S, N) (groups broadcast to heads) -> y (B, H, S, P)."""
    B, H, S, P = x.shape
    G, N = Bm.shape[1], Bm.shape[3]
    assert H % G == 0
    rep = H // G
    assert S % chunk == 0
    nc = S // chunk

    xc = x.reshape(B, H, nc, chunk, P)
    dtc = dt.reshape(B, H, nc, chunk)
    Bc = Bm.reshape(B, G, nc, chunk, N)
    Cc = Cm.reshape(B, G, nc, chunk, N)

    kernel = functools.partial(_ssd_kernel, Q=chunk, nc=nc)
    from jax.experimental.pallas import tpu as pltpu
    y = pl.pallas_call(
        kernel,
        grid=(B, H, nc),
        in_specs=[
            pl.BlockSpec((1, 1, 1, chunk, P), lambda b, h, c: (b, h, c, 0, 0)),
            pl.BlockSpec((1, 1, 1, chunk), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((1,), lambda b, h, c: (h,)),
            pl.BlockSpec((1, 1, 1, chunk, N), lambda b, h, c: (b, h // rep, c, 0, 0)),
            pl.BlockSpec((1, 1, 1, chunk, N), lambda b, h, c: (b, h // rep, c, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, 1, chunk, P),
                               lambda b, h, c: (b, h, c, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, nc, chunk, P), x.dtype),
        scratch_shapes=[pltpu.VMEM((P, N), jnp.float32)],
        interpret=interpret,
    )(xc, dtc, A, Bc, Cc)
    return y.reshape(B, H, S, P)
