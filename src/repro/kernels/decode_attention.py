"""Decode attention — single-token flash-decode Pallas kernel.

One new query token per sequence against a long KV cache.  Grid
(B, Hkv, nk): all G query heads of one KV head process together, so the
score block is (G, bk) — MXU-shaped when G >= 8 — and the online-softmax
state (m, l, acc) persists in VMEM scratch across the sequential k-block
axis.  Ring caches and partial fills are handled by an explicit
``k_pos`` operand (absolute position per slot, -1 = empty) and the query
position ``q_pos`` — identical semantics to the model's cache masks.

VMEM per step (G<=16, bk=512, hd<=256): k/v blocks 2*512*256*2B = 512 KiB,
scores G*512*4B <= 32 KiB — small; the kernel is HBM-bandwidth-bound by
design (reads the cache once), which is the roofline-ideal decode.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _decode_kernel(qpos_ref, q_ref, k_ref, v_ref, kpos_ref, o_ref,
                   acc_ref, m_ref, l_ref, *, G: int, bk: int, nk: int,
                   scale: float, window: int):
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0, 0].astype(jnp.float32) * scale           # (G, hd)
    k = k_ref[0, 0].astype(jnp.float32)                   # (bk, hd)
    v = v_ref[0, 0].astype(jnp.float32)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())))  # (G, bk)

    k_pos = kpos_ref[0]                                    # (bk,) i32
    q_pos = qpos_ref[0]                                    # scalar i32
    keep = jnp.logical_and(k_pos >= 0, k_pos <= q_pos)
    if window > 0:
        keep = jnp.logical_and(keep, k_pos > q_pos - window)
    keep = jnp.broadcast_to(keep[None, :], (G, bk))
    s = jnp.where(keep, s, NEG_INF)

    m_prev, l_prev = m_ref[...], l_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.where(keep, jnp.exp(s - m_new[:, None]), 0.0)
    l_new = alpha * l_prev + jnp.sum(p, axis=1)
    acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())))
    m_ref[...] = m_new
    l_ref[...] = l_new

    @pl.when(ik == nk - 1)
    def _finish():
        l = l_ref[...]
        safe = jnp.where(l > 0, l, 1.0)
        o_ref[0, 0] = (acc_ref[...] / safe[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("window", "bk", "interpret"))
def decode_attention_bhd(q: jax.Array, k: jax.Array, v: jax.Array,
                         k_pos: jax.Array, q_pos: jax.Array, *,
                         window: int = 0, bk: int = 512,
                         interpret: bool = False) -> jax.Array:
    """q: (B, Hq, hd); k/v: (B, Hkv, S, hd); k_pos: (B, S) i32;
    q_pos: (B,) i32 -> (B, Hq, hd)."""
    B, Hq, hd = q.shape
    _, Hkv, S, _ = k.shape
    assert Hq % Hkv == 0
    G = Hq // Hkv
    bk = min(bk, S)
    assert S % bk == 0
    nk = S // bk
    scale = hd ** -0.5
    qg = q.reshape(B, Hkv, G, hd)

    kernel = functools.partial(_decode_kernel, G=G, bk=bk, nk=nk,
                               scale=scale, window=window)
    from jax.experimental.pallas import tpu as pltpu
    out = pl.pallas_call(
        kernel,
        grid=(B, Hkv, nk),
        in_specs=[
            pl.BlockSpec((1,), lambda b, h, j: (b,)),
            pl.BlockSpec((1, 1, G, hd), lambda b, h, j: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, bk, hd), lambda b, h, j: (b, h, j, 0)),
            pl.BlockSpec((1, 1, bk, hd), lambda b, h, j: (b, h, j, 0)),
            pl.BlockSpec((1, bk), lambda b, h, j: (b, j)),
        ],
        out_specs=pl.BlockSpec((1, 1, G, hd), lambda b, h, j: (b, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Hkv, G, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((G, hd), jnp.float32),
            pltpu.VMEM((G,), jnp.float32),
            pltpu.VMEM((G,), jnp.float32),
        ],
        interpret=interpret,
    )(q_pos, qg, k, v, k_pos)
    return out.reshape(B, Hq, hd)
