"""Flash attention (fwd) — Pallas TPU kernel with explicit VMEM tiling.

Blocked online-softmax attention: grid (B, Hq, nq, nk); the minor-most
``nk`` axis iterates sequentially on TPU, so the running max / sum /
accumulator live in VMEM scratch across k-blocks and the output block is
written once at the last k-step.  GQA is expressed in the K/V BlockSpec
index maps (q-head h reads kv-head h // G) — no materialized repeat.

Supports causal and sliding-window masking via absolute block positions.
The pure-jnp oracle is :func:`repro.kernels.ref.attention_ref` (which the
model's `_attn_core` also uses); tests sweep shapes/dtypes in
``interpret=True`` mode (this container is CPU-only; TPU is the target).

VMEM budget per grid step (defaults bq=bk=256, hd<=256, f32 scratch):
q/k/v blocks 3*256*256*2B = 384 KiB, scores 256*256*4B = 256 KiB,
acc 256*256*4B = 256 KiB — comfortably under the ~16 MiB/core VMEM.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
                  bq: int, bk: int, nk: int, scale: float, causal: bool,
                  window: int):
    iq = pl.program_id(2)
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0, 0].astype(jnp.float32) * scale          # (bq, hd)
    k = k_ref[0, 0].astype(jnp.float32)                  # (bk, hd)
    v = v_ref[0, 0].astype(jnp.float32)                  # (bk, hd)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())))  # (bq, bk)

    q_pos = iq * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    k_pos = ik * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    keep = jnp.ones((bq, bk), jnp.bool_)
    if causal:
        keep = jnp.logical_and(keep, k_pos <= q_pos)
    if window > 0:
        keep = jnp.logical_and(keep, k_pos > q_pos - window)
    s = jnp.where(keep, s, NEG_INF)

    m_prev = m_ref[...]
    l_prev = l_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new[:, None])
    p = jnp.where(keep, p, 0.0)
    l_new = alpha * l_prev + jnp.sum(p, axis=1)
    acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())))
    m_ref[...] = m_new
    l_ref[...] = l_new

    @pl.when(ik == nk - 1)
    def _finish():
        l = l_ref[...]
        safe = jnp.where(l > 0, l, 1.0)
        o_ref[0, 0] = (acc_ref[...] / safe[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "window", "bq", "bk",
                                             "interpret"))
def flash_attention_bhsd(q: jax.Array, k: jax.Array, v: jax.Array, *,
                         causal: bool = True, window: int = 0,
                         bq: int = 256, bk: int = 256,
                         interpret: bool = False) -> jax.Array:
    """q: (B, Hq, Sq, hd); k/v: (B, Hkv, Sk, hd) -> (B, Hq, Sq, hd)."""
    B, Hq, Sq, hd = q.shape
    _, Hkv, Sk, _ = k.shape
    assert Hq % Hkv == 0
    G = Hq // Hkv
    bq = min(bq, Sq)
    bk = min(bk, Sk)
    assert Sq % bq == 0 and Sk % bk == 0, (Sq, bq, Sk, bk)
    nq, nk = Sq // bq, Sk // bk
    scale = hd ** -0.5

    kernel = functools.partial(_flash_kernel, bq=bq, bk=bk, nk=nk,
                               scale=scale, causal=causal, window=window)
    return pl.pallas_call(
        kernel,
        grid=(B, Hq, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, bq, hd), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, bk, hd), lambda b, h, i, j: (b, h // G, j, 0)),
            pl.BlockSpec((1, 1, bk, hd), lambda b, h, i, j: (b, h // G, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, hd), lambda b, h, i, j: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Hq, Sq, hd), q.dtype),
        scratch_shapes=[
            pl.MemorySpace.ANY if False else _vmem((bq, hd), jnp.float32),
            _vmem((bq,), jnp.float32),
            _vmem((bq,), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)


def _vmem(shape, dtype):
    from jax.experimental.pallas import tpu as pltpu
    return pltpu.VMEM(shape, dtype)
