"""Jitted public wrappers for the Pallas kernels.

Model code calls these through ``ShardCtx.impl == "pallas"``; on this
CPU-only container they execute in interpret mode (kernel bodies run as
Python over numpy — TPU is the compile target, correctness is what's
validated here).  Layout conversions between the model's (B, S, H, hd)
convention and the kernels' (B, H, S, hd) happen here.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .decode_attention import decode_attention_bhd
from .flash_attention import flash_attention_bhsd
from .quantize import dequantize_int8, quantize_int8
from .ssd_scan import ssd_scan_bhsd


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: int = 0) -> jax.Array:
    """(B, S, H, hd) layout in/out."""
    if q.shape[1] % 128 != 0 or k.shape[1] % 128 != 0:
        raise NotImplementedError("flash kernel needs seq % 128 == 0")
    qt = jnp.swapaxes(q, 1, 2)
    kt = jnp.swapaxes(k, 1, 2)
    vt = jnp.swapaxes(v, 1, 2)
    ot = flash_attention_bhsd(qt, kt, vt, causal=causal, window=window,
                              interpret=not on_tpu())
    return jnp.swapaxes(ot, 1, 2)


def decode_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                     k_pos: jax.Array, q_pos: jax.Array, *,
                     window: int = 0) -> jax.Array:
    """q: (B, 1, H, hd); k/v: (B, S, Hkv, hd) caches -> (B, 1, H, hd)."""
    qt = q[:, 0].swapaxes(0, 0)                 # (B, H, hd)
    qt = jnp.swapaxes(q, 1, 2)[:, :, 0]         # (B, H, hd)
    kt = jnp.swapaxes(k, 1, 2)
    vt = jnp.swapaxes(v, 1, 2)
    o = decode_attention_bhd(qt, kt, vt, k_pos, q_pos, window=window,
                             interpret=not on_tpu())
    return o[:, None].swapaxes(1, 1).reshape(q.shape)


def ssd_scan(x: jax.Array, dt: jax.Array, A: jax.Array, Bm: jax.Array,
             Cm: jax.Array, *, chunk: int = 256) -> jax.Array:
    """Model layout: x (B, S, H, P); dt (B, S, H); Bm/Cm (B, S, G, N)."""
    xt = jnp.moveaxis(x, 2, 1)
    dtt = jnp.moveaxis(dt, 2, 1)
    Bt = jnp.moveaxis(Bm, 2, 1)
    Ct = jnp.moveaxis(Cm, 2, 1)
    y = ssd_scan_bhsd(xt, dtt.astype(jnp.float32), A.astype(jnp.float32),
                      Bt, Ct, chunk=chunk, interpret=not on_tpu())
    return jnp.moveaxis(y, 1, 2)


def quantize(x: jax.Array, *, block: int = 256):
    return quantize_int8(x, block=block, interpret=not on_tpu())


def dequantize(q: jax.Array, s: jax.Array, shape: tuple[int, ...]):
    return dequantize_int8(q, s, shape, interpret=not on_tpu())
