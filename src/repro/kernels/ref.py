"""Pure-jnp oracles for every Pallas kernel (the allclose targets)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def attention_ref(q: jax.Array, k: jax.Array, v: jax.Array, *,
                  causal: bool = True, window: int = 0) -> jax.Array:
    """q: (B, Hq, Sq, hd); k/v: (B, Hkv, Sk, hd) -> (B, Hq, Sq, hd)."""
    B, Hq, Sq, hd = q.shape
    _, Hkv, Sk, _ = k.shape
    G = Hq // Hkv
    qg = q.reshape(B, Hkv, G, Sq, hd).astype(jnp.float32)
    kf = k.astype(jnp.float32)
    s = jnp.einsum("bhgqd,bhkd->bhgqk", qg, kf) * hd ** -0.5
    qp = jnp.arange(Sq)[:, None]
    kp = jnp.arange(Sk)[None, :]
    keep = jnp.ones((Sq, Sk), bool)
    if causal:
        keep &= kp <= qp
    if window > 0:
        keep &= kp > qp - window
    s = jnp.where(keep[None, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    p = jnp.where(keep[None, None, None], p, 0.0)
    o = jnp.einsum("bhgqk,bhkd->bhgqd", p, v.astype(jnp.float32))
    return o.reshape(B, Hq, Sq, hd).astype(q.dtype)


def decode_attention_ref(q: jax.Array, k: jax.Array, v: jax.Array,
                         k_pos: jax.Array, q_pos: jax.Array, *,
                         window: int = 0) -> jax.Array:
    """q: (B, Hq, hd); k/v: (B, Hkv, S, hd); k_pos (B,S); q_pos (B,)."""
    B, Hq, hd = q.shape
    _, Hkv, S, _ = k.shape
    G = Hq // Hkv
    qg = q.reshape(B, Hkv, G, hd).astype(jnp.float32)
    s = jnp.einsum("bhgd,bhkd->bhgk", qg, k.astype(jnp.float32)) * hd ** -0.5
    keep = jnp.logical_and(k_pos >= 0, k_pos <= q_pos[:, None])
    if window > 0:
        keep = jnp.logical_and(keep, k_pos > (q_pos[:, None] - window))
    s = jnp.where(keep[:, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    p = jnp.where(keep[:, None, None, :], p, 0.0)
    o = jnp.einsum("bhgk,bhkd->bhgd", p, v.astype(jnp.float32))
    return o.reshape(B, Hq, hd).astype(q.dtype)


def ssd_scan_ref(x: jax.Array, dt: jax.Array, A: jax.Array, Bm: jax.Array,
                 Cm: jax.Array, *, chunk: int) -> jax.Array:
    """Kernel-layout wrapper over models.ssm.ssd_chunked.
    x: (B, H, S, P); dt: (B, H, S); Bm/Cm: (B, G, S, N)."""
    from repro.models.ssm import ssd_chunked
    xs = jnp.moveaxis(x, 1, 2)            # (B, S, H, P)
    dts = jnp.moveaxis(dt, 1, 2)          # (B, S, H)
    Bs = jnp.moveaxis(Bm, 1, 2)           # (B, S, G, N)
    Cs = jnp.moveaxis(Cm, 1, 2)
    y, _ = ssd_chunked(xs, dts.astype(jnp.float32), A.astype(jnp.float32),
                       Bs, Cs, chunk)
    return jnp.moveaxis(y, 2, 1)


def quantize_ref(x: jax.Array, block: int = 256):
    from repro.optim.compression import quantize_int8_blockwise
    return quantize_int8_blockwise(x, block)


def dequantize_ref(q, s, shape):
    from repro.optim.compression import dequantize_int8_blockwise
    return dequantize_int8_blockwise(q, s, shape)
