"""Pallas TPU kernels for the perf-critical compute layers.

<name>.py holds the pl.pallas_call + BlockSpec kernel; ops.py the jitted
wrappers (interpret mode on CPU, compiled on TPU); ref.py the pure-jnp
oracles every kernel is validated against.

Kernels:
* flash_attention — blocked online-softmax GQA attention (train/prefill)
* decode_attention — flash-decode vs long (possibly ring) KV caches
* ssd_scan — full chunked Mamba2/SSD with in-VMEM recurrent state
* quantize — blockwise int8 for the compressed gradient collective
* digest — blockwise lattice digest for accelerator-placed integrity
"""
