"""Blockwise int8 quantize/dequantize — Pallas kernel.

The compute half of the compressed gradient collective
(parallel/collectives.compressed_psum): symmetric per-block int8 with f32
scales.  Tiled so each grid step quantizes a (tile, block) panel from
VMEM; the oracle is optim/compression.quantize_int8_blockwise.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _quant_kernel(x_ref, q_ref, s_ref):
    x = x_ref[...].astype(jnp.float32)            # (tile, block)
    scale = jnp.max(jnp.abs(x), axis=1) / 127.0   # (tile,)
    safe = jnp.where(scale > 0, scale, 1.0)
    q = jnp.clip(jnp.round(x / safe[:, None]), -127, 127)
    q_ref[...] = q.astype(jnp.int8)
    s_ref[...] = scale


def _dequant_kernel(q_ref, s_ref, x_ref):
    x_ref[...] = (q_ref[...].astype(jnp.float32)
                  * s_ref[...][:, None]).astype(x_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block", "tile", "interpret"))
def quantize_int8(x: jax.Array, *, block: int = 256, tile: int = 8,
                  interpret: bool = False) -> tuple[jax.Array, jax.Array]:
    """flat-able x -> (q int8 (nb, block), scales f32 (nb,)); nb padded to
    a multiple of ``tile``."""
    flat = x.reshape(-1).astype(jnp.float32)
    pad = (-flat.size) % (block * tile)
    if pad:
        flat = jnp.pad(flat, (0, pad))
    panels = flat.reshape(-1, block)              # (nb, block)
    nb = panels.shape[0]
    grid = (nb // tile,)
    q, s = pl.pallas_call(
        _quant_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((tile, block), lambda i: (i, 0))],
        out_specs=[pl.BlockSpec((tile, block), lambda i: (i, 0)),
                   pl.BlockSpec((tile,), lambda i: (i,))],
        out_shape=[jax.ShapeDtypeStruct((nb, block), jnp.int8),
                   jax.ShapeDtypeStruct((nb,), jnp.float32)],
        interpret=interpret,
    )(panels)
    return q, s


@functools.partial(jax.jit, static_argnames=("shape", "tile", "interpret"))
def dequantize_int8(q: jax.Array, s: jax.Array, shape: tuple[int, ...], *,
                    tile: int = 8, interpret: bool = False) -> jax.Array:
    nb, block = q.shape
    out = pl.pallas_call(
        _dequant_kernel,
        grid=(nb // tile,),
        in_specs=[pl.BlockSpec((tile, block), lambda i: (i, 0)),
                  pl.BlockSpec((tile,), lambda i: (i,))],
        out_specs=pl.BlockSpec((tile, block), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((nb, block), jnp.float32),
        interpret=interpret,
    )(q, s)
    n = 1
    for d in shape:
        n *= d
    return out.reshape(-1)[:n].reshape(shape)
