"""Blockwise streaming digest — Pallas kernel.

The compute half of accelerator-placed integrity
(:mod:`repro.core.integrity`): the paper budgets checksum/encryption
*inside* the staged data path (§3.4), and "Demystifying the Performance
of Data Transfers" shows the hash pinned to the wrong resource (the host
CPU) dominating end-to-end rates.  This kernel moves the digest onto the
accelerator: each grid step reduces a (tile, block) panel of uint32
words to one 32-bit lattice digest per block row, streaming at memory
bandwidth instead of host hash rate.

The digest is a weighted word sum with position-dependent odd weights
(multiplicative lattice hash): ``d = sum_j x_j * (2j+1) * GOLDEN mod
2^32``.  Odd weights are invertible mod 2^32, so swapping or zeroing a
word changes the digest; the mod-2^32 wraparound is the natural uint32
arithmetic on both the VPU and the jnp oracle, making the kernel's
output bit-identical to :func:`digest_ref` (asserted in
``benchmarks/kernel_bench.py``, interpret mode on CPU).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

#: 2**32 / golden ratio, the classic multiplicative-hash constant; odd,
#: so every derived weight (2j+1)*GOLDEN is odd and invertible mod 2^32
GOLDEN = 0x9E3779B1


def _weights(shape: tuple[int, ...]) -> jax.Array:
    j = jax.lax.broadcasted_iota(jnp.uint32, shape, len(shape) - 1)
    return (jnp.uint32(2) * j + jnp.uint32(1)) * jnp.uint32(GOLDEN)


def _digest_kernel(x_ref, d_ref):
    x = x_ref[...]                                   # (tile, block) uint32
    d_ref[...] = jnp.sum(x * _weights(x.shape), axis=1, dtype=jnp.uint32)


@functools.partial(jax.jit, static_argnames=("tile", "interpret"))
def block_digest(panels: jax.Array, *, tile: int = 8,
                 interpret: bool = False) -> jax.Array:
    """uint32 panels (nb, block) -> one uint32 lattice digest per block.

    ``nb`` must be a multiple of ``tile`` (callers zero-pad; a zero block
    digests to 0, which the item-level fold discards by slicing to the
    real block count)."""
    nb, block = panels.shape
    return pl.pallas_call(
        _digest_kernel,
        grid=(nb // tile,),
        in_specs=[pl.BlockSpec((tile, block), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((tile,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((nb,), jnp.uint32),
        interpret=interpret,
    )(panels)


@jax.jit
def digest_ref(panels: jax.Array) -> jax.Array:
    """jnp oracle for :func:`block_digest` — same lattice hash, pure XLA.

    On CPU this compiled form IS the production accelerator-digest path
    (:class:`repro.core.integrity.StreamDigest` with
    ``placement="accel"``): it stands in for the compiled Pallas kernel
    at real speed, while the interpret-mode kernel is gated on
    bit-exact parity against it."""
    return jnp.sum(panels * _weights(panels.shape), axis=1,
                   dtype=jnp.uint32)
