from .manager import (CheckpointManager, CheckpointMeta, latest_step,
                      load_checkpoint, save_checkpoint, verify_checkpoint)

__all__ = ["CheckpointManager", "CheckpointMeta", "latest_step",
           "load_checkpoint", "save_checkpoint", "verify_checkpoint"]
