"""Checkpointing: staged, checksummed, atomic, elastic.

Checkpoint traffic is a *bulk transfer* in the paper's taxonomy (data at
rest moving device -> storage), so it runs through the same unified-mover
machinery as everything else:

* shards are staged through a burst buffer so the device-side snapshot
  completes immediately and training never blocks on storage (async save),
* every shard carries a SHA-256 (the paper's integrity budget, computed
  inside the staged path where it overlaps transit),
* the manifest commits atomically (tmp dir + rename): a crash mid-save
  can never corrupt the restore point — restart discovers the newest
  *complete* manifest,
* restore is **elastic**: leaves are saved with logical shapes and can be
  re-sharded onto any mesh at load (save on (4,2), restore on (2,2) or a
  single device — tested in tests/test_checkpoint.py).

In a real multi-host deployment each host writes only its addressable
shards; this process-local implementation writes full arrays and notes
the distinction (DESIGN.md §7).
"""

from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import shutil
import threading
import time
from typing import Any, Callable, Optional

import jax
import numpy as np

from repro.core.basin import checkpoint_basin
from repro.core.mover import MoverConfig, UnifiedDataMover
from repro.core.planner import TransferPlan, plan_transfer
from repro.core.telemetry import get_registry


@dataclasses.dataclass
class CheckpointMeta:
    step: int
    leaves: list[dict]            # {path, file, shape, dtype, sha256}
    treedef: str
    wall_time: float
    framework: str = "repro"


def _leaf_path_str(path) -> str:
    return "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)


def _reinterpret_dtype(arr: np.ndarray, dtype_str: str) -> np.ndarray:
    """np.load round-trips ml_dtypes (bfloat16, fp8) as raw void — the
    manifest's dtype string restores the view."""
    if arr.dtype.kind != "V":
        return arr
    import ml_dtypes
    dt = getattr(ml_dtypes, dtype_str, None)
    return arr.view(dt if dt is not None else np.dtype(dtype_str))


def _ckpt_dir(root: str, step: int) -> str:
    return os.path.join(root, f"step_{step:010d}")


def latest_step(root: str) -> Optional[int]:
    """Newest step with a *complete* (committed) manifest."""
    if not os.path.isdir(root):
        return None
    steps = []
    for name in os.listdir(root):
        if name.startswith("step_") and os.path.exists(
                os.path.join(root, name, "manifest.json")):
            try:
                steps.append(int(name[5:]))
            except ValueError:
                continue
    return max(steps) if steps else None


def _leaf_plan(total_bytes: int, n_leaves: int,
               plan: Optional[TransferPlan] = None) -> TransferPlan:
    """Per-shard staging parameters from the checkpoint basin model."""
    if plan is not None:
        return plan
    item_bytes = max(1, total_bytes // max(1, n_leaves))
    return plan_transfer(checkpoint_basin(), item_bytes,
                         stages=("serialize",))


def save_checkpoint(root: str, step: int, tree: Any, *,
                    staged: bool = True,
                    plan: Optional[TransferPlan] = None,
                    mover: Optional[UnifiedDataMover] = None,
                    replan_every_items: int = 0) -> CheckpointMeta:
    """Write one checkpoint atomically; returns its manifest.

    ``replan_every_items > 0`` revises the staging plan online every that
    many shards (a large model's save is a long transfer — a filesystem
    that degrades mid-save is answered mid-save).  Passing a persistent
    ``mover`` lets revisions carry across checkpoints: the mover's plan is
    the live estimate, updated by each save's observed stalls."""
    os.makedirs(root, exist_ok=True)
    final_dir = _ckpt_dir(root, step)
    tmp_dir = final_dir + ".tmp"
    if os.path.exists(tmp_dir):
        shutil.rmtree(tmp_dir)
    os.makedirs(tmp_dir)

    leaves_with_paths, treedef = jax.tree_util.tree_flatten_with_path(tree)
    # device -> host snapshot happens up front (the fast, blocking part);
    # serialization + hashing + disk I/O ride the staged path.
    snapshot = [(i, _leaf_path_str(p), np.asarray(v))
                for i, (p, v) in enumerate(leaves_with_paths)]

    manifest_leaves: list[dict] = [None] * len(snapshot)

    def write_shard(item):
        i, pstr, arr = item
        fname = f"leaf_{i:05d}.npy"
        fpath = os.path.join(tmp_dir, fname)
        np.save(fpath, arr)
        digest = hashlib.sha256(arr.tobytes()).hexdigest()
        manifest_leaves[i] = {
            "path": pstr, "file": fname, "shape": list(arr.shape),
            "dtype": str(arr.dtype), "sha256": digest,
        }
        return arr

    if staged:
        if mover is None:
            mover = UnifiedDataMover(MoverConfig(checksum=False),
                                     telemetry=get_registry(),
                                     layer="checkpoint")
        if plan is not None:
            mover.plan = plan
        elif mover.plan is None:
            mover.plan = _leaf_plan(sum(a.nbytes for _, _, a in snapshot),
                                    len(snapshot), None)
        # plan=None: draw from (and revise) the mover's own plan, so a
        # persistent mover replans across shard batches and across saves
        mover.bulk_transfer(iter(snapshot), sink=lambda _: None,
                            transforms=[("serialize", write_shard)],
                            replan_every_items=replan_every_items)
    else:
        for item in snapshot:
            write_shard(item)

    meta = CheckpointMeta(step=step, leaves=manifest_leaves,
                          treedef=str(treedef), wall_time=time.time())
    with open(os.path.join(tmp_dir, "manifest.json"), "w") as f:
        json.dump(dataclasses.asdict(meta), f)
    if os.path.exists(final_dir):
        shutil.rmtree(final_dir)
    os.replace(tmp_dir, final_dir)       # atomic commit
    return meta


def verify_checkpoint(root: str, step: int) -> bool:
    """Re-hash every shard against the manifest."""
    d = _ckpt_dir(root, step)
    with open(os.path.join(d, "manifest.json")) as f:
        meta = json.load(f)
    for leaf in meta["leaves"]:
        arr = np.load(os.path.join(d, leaf["file"]))
        if hashlib.sha256(arr.tobytes()).hexdigest() != leaf["sha256"]:
            return False
    return True


def load_checkpoint(root: str, step: int, like: Any, *,
                    shardings: Any = None, verify: bool = False,
                    staged: bool = True,
                    replan_every_items: int = 0) -> Any:
    """Restore into the structure of ``like``; optionally re-shard onto a
    new mesh (elastic restore) via per-leaf ``shardings``.

    With ``staged`` (the default) shard files are read through the
    planned mover path — concurrent reads overlap storage latency, and
    assembly is order-independent (leaves are keyed by tree path)."""
    d = _ckpt_dir(root, step)
    if verify and not verify_checkpoint(root, step):
        raise IOError(f"checkpoint {d} failed integrity verification")
    with open(os.path.join(d, "manifest.json")) as f:
        meta = json.load(f)
    by_path = {l["path"]: l for l in meta["leaves"]}

    def read_leaf(leaf: dict) -> tuple[str, np.ndarray]:
        arr = np.load(os.path.join(d, leaf["file"]))
        return leaf["path"], _reinterpret_dtype(arr, leaf["dtype"])

    arrays: dict[str, np.ndarray] = {}
    if staged and meta["leaves"]:
        total = sum(os.path.getsize(os.path.join(d, l["file"]))
                    for l in meta["leaves"])
        plan = _leaf_plan(total, len(meta["leaves"]))
        mover = UnifiedDataMover(MoverConfig(checksum=False), plan=plan,
                                 telemetry=get_registry(), layer="checkpoint")
        mover.bulk_transfer(iter(meta["leaves"]),
                            sink=lambda kv: arrays.__setitem__(*kv),
                            transforms=[("serialize", read_leaf)],
                            plan=plan,
                            replan_every_items=replan_every_items)
    else:
        for leaf in meta["leaves"]:
            k, v = read_leaf(leaf)
            arrays[k] = v

    leaves_with_paths, treedef = jax.tree_util.tree_flatten_with_path(like)
    shard_leaves = (jax.tree.leaves(shardings)
                    if shardings is not None else [None] * len(leaves_with_paths))
    out = []
    for (p, ref), sh in zip(leaves_with_paths, shard_leaves):
        pstr = _leaf_path_str(p)
        if pstr not in by_path:
            raise KeyError(f"checkpoint missing leaf {pstr}")
        arr = arrays[pstr]
        if tuple(arr.shape) != tuple(ref.shape):
            raise ValueError(f"{pstr}: shape {arr.shape} != {ref.shape}")
        arr = arr.astype(ref.dtype)
        out.append(jax.device_put(arr, sh) if sh is not None else
                   jax.device_put(arr))
    return jax.tree.unflatten(treedef, out)


class CheckpointManager:
    """Train-loop-facing manager: periodic async saves, retention,
    restart discovery, failure recovery.

    The manager owns one persistent mover for the save path: the staging
    plan it carries is revised online every ``replan_every_shards`` shards
    *and* survives from one checkpoint to the next, so the estimate of the
    storage tier converges across saves instead of resetting each time."""

    def __init__(self, root: str, *, every_steps: int = 100, keep: int = 3,
                 staged: bool = True, replan_every_shards: int = 16):
        self.root = root
        self.every_steps = every_steps
        self.keep = keep
        self.staged = staged
        self.replan_every_shards = replan_every_shards
        self._mover: Optional[UnifiedDataMover] = None
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    def maybe_save(self, step: int, tree: Any, *, force: bool = False) -> bool:
        if not force and (step == 0 or step % self.every_steps):
            return False
        self.wait()
        # snapshot to host NOW (cheap), write in background (staged)
        host_tree = jax.tree.map(np.asarray, tree)
        if self.staged and self._mover is None:
            self._mover = UnifiedDataMover(MoverConfig(checksum=False),
                                           telemetry=get_registry(),
                                           layer="checkpoint")

        def run():
            try:
                save_checkpoint(self.root, step, host_tree, staged=self.staged,
                                mover=self._mover,
                                replan_every_items=self.replan_every_shards)
                self._gc()
            except BaseException as e:   # surfaced on next wait()
                self._error = e

        self._thread = threading.Thread(target=run, daemon=True)
        self._thread.start()
        return True

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            e, self._error = self._error, None
            raise e

    def restore_latest(self, like: Any, *, shardings: Any = None
                       ) -> tuple[Optional[int], Any]:
        step = latest_step(self.root)
        if step is None:
            return None, like
        return step, load_checkpoint(self.root, step, like,
                                     shardings=shardings)

    def _gc(self) -> None:
        if not os.path.isdir(self.root):
            return
        steps = sorted(
            int(n[5:]) for n in os.listdir(self.root)
            if n.startswith("step_") and not n.endswith(".tmp")
            and os.path.exists(os.path.join(self.root, n, "manifest.json")))
        for s in steps[:-self.keep]:
            shutil.rmtree(_ckpt_dir(self.root, s), ignore_errors=True)
