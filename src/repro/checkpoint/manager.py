"""Checkpointing: staged, checksummed, atomic, elastic.

Checkpoint traffic is a *bulk transfer* in the paper's taxonomy (data at
rest moving device -> storage), so it runs through the same unified-mover
machinery as everything else:

* shards are staged through a burst buffer so the device-side snapshot
  completes immediately and training never blocks on storage (async save),
* every shard carries a SHA-256 (the paper's integrity budget, computed
  inside the staged path where it overlaps transit),
* the manifest commits atomically (tmp dir + rename): a crash mid-save
  can never corrupt the restore point — restart discovers the newest
  *complete* manifest,
* restore is **elastic**: leaves are saved with logical shapes and can be
  re-sharded onto any mesh at load (save on (4,2), restore on (2,2) or a
  single device — tested in tests/test_checkpoint.py),
* saves can **mirror to two storage tiers** (``mirror_root``): shards
  replicate down both branches of a
  :func:`~repro.core.basin.mirrored_checkpoint_basin` plan (local NVMe +
  remote object store) through the mover's parallel-branch mirror mode,
  each branch's stall evidence attributed separately; restore picks
  whichever replica's branch is modeled faster and falls back to the
  other on a missing or corrupt copy.

In a real multi-host deployment each host writes only its addressable
shards; this process-local implementation writes full arrays and notes
the distinction (DESIGN.md §7).
"""

from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import shutil
import threading
import time
from typing import Any, Callable, Optional

import jax
import numpy as np

from repro.core.basin import checkpoint_basin, mirrored_checkpoint_basin
from repro.core.mover import MoverConfig, UnifiedDataMover
from repro.core.planner import TransferPlan, plan_transfer
from repro.core.telemetry import get_registry


@dataclasses.dataclass
class CheckpointMeta:
    step: int
    leaves: list[dict]            # {path, file, shape, dtype, sha256}
    treedef: str
    wall_time: float
    framework: str = "repro"


def _leaf_path_str(path) -> str:
    return "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)


def _reinterpret_dtype(arr: np.ndarray, dtype_str: str) -> np.ndarray:
    """np.load round-trips ml_dtypes (bfloat16, fp8) as raw void — the
    manifest's dtype string restores the view."""
    if arr.dtype.kind != "V":
        return arr
    import ml_dtypes
    dt = getattr(ml_dtypes, dtype_str, None)
    return arr.view(dt if dt is not None else np.dtype(dtype_str))


def _ckpt_dir(root: str, step: int) -> str:
    return os.path.join(root, f"step_{step:010d}")


def complete_steps(root: str) -> list[int]:
    """Every step with a *complete* (committed) manifest, ascending."""
    if not os.path.isdir(root):
        return []
    steps = []
    for name in os.listdir(root):
        if name.startswith("step_") and os.path.exists(
                os.path.join(root, name, "manifest.json")):
            try:
                steps.append(int(name[5:]))
            except ValueError:
                continue
    return sorted(steps)


def latest_step(root: str) -> Optional[int]:
    """Newest step with a complete manifest."""
    steps = complete_steps(root)
    return steps[-1] if steps else None


def _leaf_plan(total_bytes: int, n_leaves: int,
               plan: Optional[TransferPlan] = None) -> TransferPlan:
    """Per-shard staging parameters from the checkpoint basin model."""
    if plan is not None:
        return plan
    item_bytes = max(1, total_bytes // max(1, n_leaves))
    return plan_transfer(checkpoint_basin(), item_bytes,
                         stages=("serialize",), path="auto")


def _prepare_tmp(root: str, step: int) -> tuple[str, str]:
    os.makedirs(root, exist_ok=True)
    final_dir = _ckpt_dir(root, step)
    tmp_dir = final_dir + ".tmp"
    if os.path.exists(tmp_dir):
        shutil.rmtree(tmp_dir)
    os.makedirs(tmp_dir)
    return final_dir, tmp_dir


def _make_writer(tmp_dir: str, manifest_leaves: Optional[list]):
    """Shard writer bound to one destination directory; the primary
    destination's writer also fills the manifest (replicas carry
    byte-identical shards, so one manifest describes both)."""
    def write_shard(item):
        i, pstr, arr = item
        fname = f"leaf_{i:05d}.npy"
        np.save(os.path.join(tmp_dir, fname), arr)
        if manifest_leaves is not None:
            digest = hashlib.sha256(arr.tobytes()).hexdigest()
            manifest_leaves[i] = {
                "path": pstr, "file": fname, "shape": list(arr.shape),
                "dtype": str(arr.dtype), "sha256": digest,
            }
        return arr
    return write_shard


def save_checkpoint(root: str, step: int, tree: Any, *,
                    staged: bool = True,
                    plan: Optional[TransferPlan] = None,
                    mover: Optional[UnifiedDataMover] = None,
                    replan_every_items: int = 0,
                    mirror_root: Optional[str] = None) -> CheckpointMeta:
    """Write one checkpoint atomically; returns its manifest.

    ``replan_every_items > 0`` revises the staging plan online every that
    many shards (a large model's save is a long transfer — a filesystem
    that degrades mid-save is answered mid-save).  Revisions apply
    **zero-drain**: the shard pipeline persists across revision windows
    and re-sizes in place, so a long save never pays a teardown bubble at
    the planning boundary.  Passing a persistent ``mover`` lets revisions
    carry across checkpoints: the mover's plan is the live estimate,
    updated by each save's observed stalls.

    ``mirror_root`` turns the save into a dual-tier mirror: every shard
    replicates down both branches of a mirrored-checkpoint plan (local
    NVMe + remote object store) via the mover's parallel mirror mode —
    one pipeline per branch, stall evidence attributed per branch — and
    both directories commit their (identical) manifest atomically."""
    final_dir, tmp_dir = _prepare_tmp(root, step)
    mirror_dirs: Optional[tuple[str, str]] = None
    if mirror_root is not None:
        mirror_dirs = _prepare_tmp(mirror_root, step)

    leaves_with_paths, treedef = jax.tree_util.tree_flatten_with_path(tree)
    # device -> host snapshot happens up front (the fast, blocking part);
    # serialization + hashing + disk I/O ride the staged path.
    snapshot = [(i, _leaf_path_str(p), np.asarray(v))
                for i, (p, v) in enumerate(leaves_with_paths)]

    manifest_leaves: list[dict] = [None] * len(snapshot)
    write_primary = _make_writer(tmp_dir, manifest_leaves)
    total_bytes = sum(a.nbytes for _, _, a in snapshot)

    if staged:
        if mover is None:
            mover = UnifiedDataMover(MoverConfig(checksum=False),
                                     telemetry=get_registry(),
                                     layer="checkpoint")
        if mirror_dirs is not None:
            if plan is None or not plan.is_multipath:
                item_bytes = max(1, total_bytes // max(1, len(snapshot)))
                plan = plan_transfer(mirrored_checkpoint_basin(), item_bytes,
                                     stages=("serialize",), path="auto")
            primary_id = plan.branches[0].branch_id
            write_mirror = _make_writer(mirror_dirs[1], None)
            transforms = {
                b.branch_id: [("serialize",
                               write_primary if b.branch_id == primary_id
                               else write_mirror)]
                for b in plan.branches
            }
            mover.parallel_transfer(iter(snapshot), sink=lambda _: None,
                                    plan=plan, mode="mirror",
                                    transforms=transforms,
                                    replan_every_items=replan_every_items)
        else:
            if plan is not None:
                mover.plan = plan
            elif mover.plan is None:
                mover.plan = _leaf_plan(total_bytes, len(snapshot), None)
            # plan=None: draw from (and revise) the mover's own plan, so a
            # persistent mover replans across shard batches and across saves
            mover.bulk_transfer(iter(snapshot), sink=lambda _: None,
                                transforms=[("serialize", write_primary)],
                                replan_every_items=replan_every_items)
    else:
        write_mirror = (_make_writer(mirror_dirs[1], None)
                        if mirror_dirs is not None else None)
        for item in snapshot:
            write_primary(item)
            if write_mirror is not None:
                write_mirror(item)

    missing = sum(1 for l in manifest_leaves if l is None)
    if missing:
        # defense in depth: a failed branch surfaces as an exception from
        # the mover's join before this point, but a torn manifest must
        # never commit under any silent-incompleteness path
        raise IOError(f"checkpoint save incomplete: {missing} of "
                      f"{len(manifest_leaves)} shards unwritten")
    meta = CheckpointMeta(step=step, leaves=manifest_leaves,
                          treedef=str(treedef), wall_time=time.time())
    commits = [(final_dir, tmp_dir)]
    if mirror_dirs is not None:
        commits.append(mirror_dirs)
    for fin, tmp in commits:
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(dataclasses.asdict(meta), f)
        if os.path.exists(fin):
            shutil.rmtree(fin)
        os.replace(tmp, fin)       # atomic commit (per replica)
    return meta


def verify_checkpoint(root: str, step: int) -> bool:
    """Re-hash every shard against the manifest."""
    d = _ckpt_dir(root, step)
    with open(os.path.join(d, "manifest.json")) as f:
        meta = json.load(f)
    for leaf in meta["leaves"]:
        arr = np.load(os.path.join(d, leaf["file"]))
        if hashlib.sha256(arr.tobytes()).hexdigest() != leaf["sha256"]:
            return False
    return True


def load_checkpoint(root: str, step: int, like: Any, *,
                    shardings: Any = None, verify: bool = False,
                    staged: bool = True,
                    replan_every_items: int = 0) -> Any:
    """Restore into the structure of ``like``; optionally re-shard onto a
    new mesh (elastic restore) via per-leaf ``shardings``.

    With ``staged`` (the default) shard files are read through the
    planned mover path — concurrent reads overlap storage latency, and
    assembly is order-independent (leaves are keyed by tree path)."""
    d = _ckpt_dir(root, step)
    if verify and not verify_checkpoint(root, step):
        raise IOError(f"checkpoint {d} failed integrity verification")
    with open(os.path.join(d, "manifest.json")) as f:
        meta = json.load(f)
    by_path = {l["path"]: l for l in meta["leaves"]}

    def read_leaf(leaf: dict) -> tuple[str, np.ndarray]:
        arr = np.load(os.path.join(d, leaf["file"]))
        return leaf["path"], _reinterpret_dtype(arr, leaf["dtype"])

    arrays: dict[str, np.ndarray] = {}
    if staged and meta["leaves"]:
        total = sum(os.path.getsize(os.path.join(d, l["file"]))
                    for l in meta["leaves"])
        plan = _leaf_plan(total, len(meta["leaves"]))
        mover = UnifiedDataMover(MoverConfig(checksum=False), plan=plan,
                                 telemetry=get_registry(), layer="checkpoint")
        mover.bulk_transfer(iter(meta["leaves"]),
                            sink=lambda kv: arrays.__setitem__(*kv),
                            transforms=[("serialize", read_leaf)],
                            plan=plan,
                            replan_every_items=replan_every_items)
    else:
        for leaf in meta["leaves"]:
            k, v = read_leaf(leaf)
            arrays[k] = v

    leaves_with_paths, treedef = jax.tree_util.tree_flatten_with_path(like)
    shard_leaves = (jax.tree.leaves(shardings)
                    if shardings is not None else [None] * len(leaves_with_paths))
    out = []
    for (p, ref), sh in zip(leaves_with_paths, shard_leaves):
        pstr = _leaf_path_str(p)
        if pstr not in by_path:
            raise KeyError(f"checkpoint missing leaf {pstr}")
        arr = arrays[pstr]
        if tuple(arr.shape) != tuple(ref.shape):
            raise ValueError(f"{pstr}: shape {arr.shape} != {ref.shape}")
        arr = arr.astype(ref.dtype)
        out.append(jax.device_put(arr, sh) if sh is not None else
                   jax.device_put(arr))
    return jax.tree.unflatten(treedef, out)


class CheckpointManager:
    """Train-loop-facing manager: periodic async saves, retention,
    restart discovery, failure recovery.

    The manager owns one persistent mover for the save path: the staging
    plan it carries is revised online every ``replan_every_shards`` shards
    *and* survives from one checkpoint to the next, so the estimate of the
    storage tier converges across saves instead of resetting each time.

    ``mirror_root`` enables dual-tier mirrored saves (see
    :func:`save_checkpoint`); the mirrored (multipath) plan persists
    across saves the same way, so a degraded replica tier keeps its
    per-branch verdict from one checkpoint to the next.  Restore then
    considers both roots: newest complete step first, the faster-modeled
    replica first within a step, falling back to the sibling replica —
    and then to older complete checkpoints — on any error (a torn,
    missing, or hash-mismatched copy)."""

    def __init__(self, root: str, *, every_steps: int = 100, keep: int = 3,
                 staged: bool = True, replan_every_shards: int = 16,
                 mirror_root: Optional[str] = None):
        self.root = root
        self.mirror_root = mirror_root
        self.every_steps = every_steps
        self.keep = keep
        self.staged = staged
        self.replan_every_shards = replan_every_shards
        self._mover: Optional[UnifiedDataMover] = None
        #: the live multipath estimate for mirrored saves (revised online
        #: and carried across checkpoints, like the mover's linear plan)
        self._mirror_plan: Optional[TransferPlan] = None
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    def maybe_save(self, step: int, tree: Any, *, force: bool = False) -> bool:
        if not force and (step == 0 or step % self.every_steps):
            return False
        self.wait()
        # snapshot to host NOW (cheap), write in background (staged)
        host_tree = jax.tree.map(np.asarray, tree)
        if self.staged and self._mover is None:
            self._mover = UnifiedDataMover(MoverConfig(checksum=False),
                                           telemetry=get_registry(),
                                           layer="checkpoint")

        def run():
            try:
                save_checkpoint(self.root, step, host_tree, staged=self.staged,
                                mover=self._mover,
                                plan=self._mirror_plan,
                                replan_every_items=self.replan_every_shards,
                                mirror_root=self.mirror_root)
                if self.mirror_root and self._mover is not None:
                    self._mirror_plan = self._mover.last_plan
                self._gc()
            except BaseException as e:   # surfaced on next wait()
                self._error = e

        self._thread = threading.Thread(target=run, daemon=True)
        self._thread.start()
        return True

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            e, self._error = self._error, None
            raise e

    def _restore_roots(self) -> list[str]:
        """Candidate roots, fastest modeled replica first."""
        if not self.mirror_root:
            return [self.root]
        plan = self._mirror_plan
        if plan is None or not plan.is_multipath:
            plan = plan_transfer(mirrored_checkpoint_basin(), 1 << 20,
                                 stages=("serialize",))
        # primary root holds the first branch's replica, mirror the second
        rates = [b.rate_bytes_per_s for b in plan.branches[:2]]
        roots = [self.root, self.mirror_root]
        if len(rates) == 2 and rates[1] > rates[0]:
            roots.reverse()
        return roots

    def restore_latest(self, like: Any, *, shardings: Any = None
                       ) -> tuple[Optional[int], Any]:
        if not self.mirror_root:
            # single root: the historical contract — newest complete step
            # or bust.  Silently resuming from an older step would mask a
            # corrupt/unreadable newest checkpoint.
            step = latest_step(self.root)
            if step is None:
                return None, like
            return step, load_checkpoint(self.root, step, like,
                                         shardings=shardings)
        roots = self._restore_roots()
        # every complete (step, replica) pair, newest step first, the
        # faster-modeled replica first within a step: a corrupt newest
        # copy falls back to its sibling, then to older checkpoints
        candidates = [(s, r) for r in roots for s in complete_steps(r)]
        candidates.sort(key=lambda t: (-t[0], roots.index(t[1])))
        if not candidates:
            return None, like
        last_err: Optional[Exception] = None
        for step, r in candidates:
            try:
                # fallback replicas exist, so re-hash shards against the
                # manifest: a silently bit-rotted copy must fail here so
                # the intact mirror (or an older step) gets its turn
                return step, load_checkpoint(r, step, like,
                                             shardings=shardings,
                                             verify=True)
            except Exception as e:       # torn/corrupt replica: try the next
                last_err = e
        raise last_err

    def _gc(self) -> None:
        for root in (self.root, self.mirror_root):
            if not root:
                continue
            for s in complete_steps(root)[:-self.keep]:
                shutil.rmtree(_ckpt_dir(root, s), ignore_errors=True)
