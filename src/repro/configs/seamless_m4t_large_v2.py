"""seamless-m4t-large-v2 — multimodal encoder-decoder backbone.

[arXiv:2308.11596; hf] 24L(enc)+24L(dec) d_model=1024 16H (kv=16)
d_ff=8192 vocab=256206.  The speech frontend is stubbed per the
assignment: ``input_specs`` supplies precomputed frame embeddings to the
encoder.  Decode runs against the self cache plus bulk-staged cross K/V.
Enc-dec full attention -> long_500k skipped.
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-large-v2",
    family="encdec",
    n_layers=24,          # decoder depth
    enc_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    head_dim=64,
    d_ff=8192,
    vocab=256206,
    frontend="frames",
    rope_theta=10000.0,
    max_seq_len=8192,
    source="arXiv:2308.11596",
)
