"""zamba2-1.2b — hybrid: Mamba2 backbone + shared attention block.

[arXiv:2411.15242; hf] 38L d_model=2048 32H (GQA kv=32) d_ff=8192
vocab=32000, ssm_state=64.  One shared transformer block (attn+MLP) is
applied every 6 Mamba2 layers, reusing the same weights at each site
(the Zamba2 parameter-sharing trick).  long_500k runs (SSM state is O(1);
the shared block uses a 4096 ring window at long context).
"""

from repro.models.config import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="zamba2-1.2b",
    family="hybrid",
    n_layers=38,
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,
    head_dim=64,
    d_ff=8192,
    vocab=32000,
    ssm=SSMConfig(d_state=64, head_dim=64, expand=2, n_groups=1,
                  conv_width=4, chunk=256),
    attn_every=6,
    window=4096,     # ring window for the shared attention block
    rope_theta=10000.0,
    max_seq_len=524288,
    source="arXiv:2411.15242",
)
