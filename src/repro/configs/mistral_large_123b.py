"""mistral-large-123b — the largest assigned dense decoder.

[hf:mistralai/Mistral-Large-Instruct-2407; unverified] 88L d_model=12288
96H (GQA kv=8) d_ff=28672 vocab=32768.  FSDP x TP sharding is mandatory
at this size (see launch/sharding defaults).  Pure full attention ->
long_500k skipped.
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="mistral-large-123b",
    family="dense",
    n_layers=88,
    d_model=12288,
    n_heads=96,
    n_kv_heads=8,
    head_dim=128,
    d_ff=28672,
    vocab=32768,
    rope_theta=1_000_000.0,
    max_seq_len=131072,
    source="hf:mistralai/Mistral-Large-Instruct-2407",
)
