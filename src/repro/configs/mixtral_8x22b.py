"""mixtral-8x22b — 8-expert top-2 MoE with sliding-window attention.

[arXiv:2401.04088; hf] 56L d_model=6144 48H (GQA kv=8) d_ff=16384
(per expert) vocab=32768, window 4096 (per assignment).  8 experts do not
divide the 16-wide model axis -> the TP-inside-experts MoE path is used
(DESIGN.md §4).  long_500k runs (SWA ring cache).
"""

from repro.models.config import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="mixtral-8x22b",
    family="moe",
    n_layers=56,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    head_dim=128,
    d_ff=16384,
    vocab=32768,
    window=4096,
    moe=MoEConfig(n_experts=8, top_k=2, d_ff_expert=16384,
                  capacity_factor=1.25),
    rope_theta=1_000_000.0,
    max_seq_len=524288,
    source="arXiv:2401.04088",
)
