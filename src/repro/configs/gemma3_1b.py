"""gemma3-1b — dense decoder with 5:1 local:global attention, 128k ctx.

[hf:google/gemma-3-1b-pt; unverified] 26L d_model=1152 4H (GQA kv=1)
d_ff=6912 vocab=262144, head_dim=256, sliding window 512 on local layers,
every 6th layer global.  long_500k runs: local layers are windowed
(sub-quadratic) and global layers are decode-linear.
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="gemma3-1b",
    family="dense",
    n_layers=26,
    d_model=1152,
    n_heads=4,
    n_kv_heads=1,
    head_dim=256,
    d_ff=6912,
    vocab=262144,
    window=512,
    global_every=6,
    rope_theta=1_000_000.0,
    max_seq_len=131072,
    source="hf:google/gemma-3-1b-pt",
)
