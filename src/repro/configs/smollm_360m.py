"""smollm-360m — llama-arch small dense decoder.

[hf:HuggingFaceTB/SmolLM-135M; hf] 32L d_model=960 15H (GQA kv=5)
d_ff=2560 vocab=49152.  15 heads do not divide the 16-wide model axis:
attention activations stay data-sharded (weights still column-shard);
the chunked-attention path bounds the score workspace.  Pure full
attention -> long_500k skipped.
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="smollm-360m",
    family="dense",
    n_layers=32,
    d_model=960,
    n_heads=15,
    n_kv_heads=5,
    head_dim=64,
    d_ff=2560,
    vocab=49152,
    rope_theta=10000.0,
    max_seq_len=8192,
    source="hf:HuggingFaceTB/SmolLM-360M",
)
