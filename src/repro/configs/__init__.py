"""Architecture registry: ``get_config("<arch-id>")`` / ``--arch <id>``.

Ten assigned architectures (exact published dims, one module each) plus
the framework's own demo config.  ``get_smoke_config`` returns the
reduced same-family variant used by CPU smoke tests.
"""

from __future__ import annotations

import importlib

from repro.models.config import ModelConfig, smoke_variant

# arch-id -> module name
_REGISTRY: dict[str, str] = {
    "phi3-mini-3.8b": "phi3_mini_3_8b",
    "smollm-360m": "smollm_360m",
    "gemma3-1b": "gemma3_1b",
    "mistral-large-123b": "mistral_large_123b",
    "zamba2-1.2b": "zamba2_1_2b",
    "mixtral-8x22b": "mixtral_8x22b",
    "qwen3-moe-30b-a3b": "qwen3_moe_30b_a3b",
    "mamba2-1.3b": "mamba2_1_3b",
    "llava-next-mistral-7b": "llava_next_mistral_7b",
    "seamless-m4t-large-v2": "seamless_m4t_large_v2",
    "repro-100m": "repro_100m",
}

ASSIGNED_ARCHS: tuple[str, ...] = tuple(k for k in _REGISTRY if k != "repro-100m")


def list_archs() -> list[str]:
    return list(_REGISTRY)


def get_config(arch: str) -> ModelConfig:
    if arch not in _REGISTRY:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(_REGISTRY)}")
    mod = importlib.import_module(f"repro.configs.{_REGISTRY[arch]}")
    return mod.CONFIG


def get_smoke_config(arch: str) -> ModelConfig:
    return smoke_variant(get_config(arch))
