"""repro-100m — the framework's own demo config (examples/ end-to-end
driver): a ~100M-parameter llama-style dense decoder sized so a few
hundred training steps complete on modest hardware while exercising the
full data path (basin-staged input pipeline, checkpointing, fidelity
accounting)."""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="repro-100m",
    family="dense",
    n_layers=12,
    d_model=768,
    n_heads=12,
    n_kv_heads=4,
    head_dim=64,
    d_ff=2048,
    vocab=32000,
    rope_theta=10000.0,
    max_seq_len=2048,
    source="repro demo",
)
