"""qwen3-moe-30b-a3b — 128-expert top-8 fine-grained MoE.

[hf:Qwen/Qwen3-30B-A3B; hf] 48L d_model=2048 32H (GQA kv=4) per-expert
d_ff=768 vocab=151936, head_dim=128.  128 experts divide the model axis ->
the expert-parallel shard_map/all-to-all MoE path is used.  Pure full
attention -> long_500k skipped.
"""

from repro.models.config import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="qwen3-moe-30b-a3b",
    family="moe",
    n_layers=48,
    d_model=2048,
    n_heads=32,
    n_kv_heads=4,
    head_dim=128,
    d_ff=768,
    vocab=151936,
    moe=MoEConfig(n_experts=128, top_k=8, d_ff_expert=768,
                  capacity_factor=1.25),
    rope_theta=1_000_000.0,
    max_seq_len=131072,
    source="hf:Qwen/Qwen3-30B-A3B",
)
