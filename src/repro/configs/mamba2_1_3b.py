"""mamba2-1.3b — attention-free SSD (state-space duality) stack.

[arXiv:2405.21060; unverified] 48L d_model=2048 vocab=50280,
ssm_state=128, headdim=64, expand=2 (d_inner=4096, 64 SSD heads).
long_500k runs: decode state is O(1) in sequence length.
"""

from repro.models.config import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="mamba2-1.3b",
    family="ssm",
    n_layers=48,
    d_model=2048,
    n_heads=1,            # attention-free; placeholders
    n_kv_heads=1,
    d_ff=0,
    vocab=50280,
    ssm=SSMConfig(d_state=128, head_dim=64, expand=2, n_groups=1,
                  conv_width=4, chunk=256),
    max_seq_len=1_048_576,
    source="arXiv:2405.21060",
)
