"""llava-next-mistral-7b — VLM: mistral-7b backbone + patch-embed stub.

[hf:llava-hf/llava-v1.6-mistral-7b-hf; unverified] 32L d_model=4096 32H
(GQA kv=8) d_ff=14336 vocab=32000.  The anyres vision tower is stubbed
per the assignment: ``input_specs`` supplies 576 precomputed patch
embeddings which pass through a 2-layer projector and prepend to the text
tokens.  Backbone uses full attention (hf v1.6 config) -> long_500k
skipped.
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="llava-next-mistral-7b",
    family="vlm",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab=32000,
    frontend="patch",
    frontend_len=576,
    rope_theta=1_000_000.0,
    max_seq_len=32768,
    source="hf:llava-hf/llava-v1.6-mistral-7b-hf",
)
