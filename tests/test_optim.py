"""Optimizer + gradient-compression tests."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:          # not installable here - deterministic shim
    from _hypothesis_fallback import given, settings, st

from repro.optim.adamw import (adamw_init, adamw_update, clip_by_global_norm,
                               warmup_cosine)
from repro.optim.compression import (compress_decompress, error_feedback_init,
                                     error_feedback_step,
                                     quantize_int8_blockwise,
                                     dequantize_int8_blockwise)


def test_adamw_converges_on_quadratic():
    params = {"w": jnp.ones((8,), jnp.bfloat16) * 5.0}
    state = adamw_init(params)
    for _ in range(200):
        grads = {"w": state.master["w"]}        # d/dw (w^2/2)
        params, state, m = adamw_update(grads, state, params, lr=0.1,
                                        weight_decay=0.0)
    assert float(jnp.max(jnp.abs(state.master["w"]))) < 0.5


def test_master_weights_are_f32_params_bf16():
    params = {"w": jnp.ones((4,), jnp.bfloat16)}
    state = adamw_init(params)
    assert state.master["w"].dtype == jnp.float32
    params2, state2, _ = adamw_update({"w": jnp.ones((4,))}, state, params,
                                      lr=1e-3)
    assert params2["w"].dtype == jnp.bfloat16
    assert state2.step == 1


def test_clip_by_global_norm():
    g = {"a": jnp.ones((3,)) * 4.0}   # norm ~ 6.93
    clipped, norm = clip_by_global_norm(g, 1.0)
    assert float(norm) == pytest.approx(np.sqrt(48.0))
    got = float(jnp.linalg.norm(clipped["a"]))
    assert got == pytest.approx(1.0, rel=1e-4)


def test_warmup_cosine_shape():
    lr0 = warmup_cosine(jnp.asarray(0), peak_lr=1.0, warmup=10, total=100)
    lr_w = warmup_cosine(jnp.asarray(10), peak_lr=1.0, warmup=10, total=100)
    lr_end = warmup_cosine(jnp.asarray(100), peak_lr=1.0, warmup=10, total=100)
    assert float(lr0) == 0.0
    assert float(lr_w) == pytest.approx(1.0)
    assert float(lr_end) == pytest.approx(0.1, rel=1e-3)


# ---------------------------------------------------------------------------
# compression
# ---------------------------------------------------------------------------


@given(st.integers(min_value=1, max_value=4096),
       st.sampled_from([64, 256]))
@settings(max_examples=40, deadline=None)
def test_property_quantize_roundtrip_bound(n, block):
    x = np.random.default_rng(n).normal(size=n).astype(np.float32) * 2.0
    q, s = quantize_int8_blockwise(jnp.asarray(x), block)
    back = np.asarray(dequantize_int8_blockwise(q, s, (n,)))
    scales = np.repeat(np.asarray(s), block)[:n]
    assert np.all(np.abs(back - x) <= scales * 0.5 + 1e-7)


def test_quantize_zero_tensor():
    q, s = quantize_int8_blockwise(jnp.zeros((100,)), 32)
    assert np.all(np.asarray(q) == 0)
    back = dequantize_int8_blockwise(q, s, (100,))
    assert np.all(np.asarray(back) == 0)


def test_error_feedback_unbiased_over_time():
    """With constant gradients, mean(sent) -> grad: the residual re-injects
    what quantization dropped (1-bit-Adam property)."""
    g = {"w": jnp.asarray(np.random.default_rng(0).normal(size=512),
                          jnp.float32) * 1e-3}
    state = error_feedback_init(g)
    sent_sum = jnp.zeros_like(g["w"])
    n = 50
    for _ in range(n):
        sent, state = error_feedback_step(g, state, block=128)
        sent_sum = sent_sum + sent["w"]
    mean_sent = np.asarray(sent_sum) / n
    err_with_ef = np.abs(mean_sent - np.asarray(g["w"])).max()
    one_shot = np.abs(np.asarray(compress_decompress(g["w"], 128))
                      - np.asarray(g["w"])).max()
    assert err_with_ef <= one_shot * 0.2 + 1e-9


def test_compress_decompress_dtype_preserved():
    x = jnp.ones((64,), jnp.bfloat16)
    y = compress_decompress(x, 32)
    assert y.dtype == jnp.bfloat16
