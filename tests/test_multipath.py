"""DAG-structured basins: topology, multipath planning, parallel-branch
movement, and per-branch replan attribution (the PR-3 tentpole).

The acceptance scenario — a two-branch basin where one branch degrades
mid-transfer — runs on the deterministic simulated-basin harness: replan
must pin the verdict on the degraded branch alone and rebalance traffic
toward the healthy one.
"""

import numpy as np
import pytest

from simbasin import SimHarness

from repro.core.basin import (DrainageBasin, GBPS, Link, MIB, Tier, TierKind,
                              checkpoint_basin, decode_fanout_basin,
                              decode_stream_basin, mirrored_checkpoint_basin,
                              paper_basin, sharded_input_basin,
                              tpu_input_basin)
from repro.core.planner import plan_transfer


def _tiers():
    return [
        Tier("src", TierKind.SOURCE, 40.0 * GBPS, latency_s=1e-5),
        Tier("staging", TierKind.BURST_BUFFER, 40.0 * GBPS, latency_s=1e-5),
        Tier("path-a", TierKind.SINK, 10.0 * GBPS),
        Tier("path-b", TierKind.SINK, 10.0 * GBPS),
    ]


def _fanout_basin(src_gbps=40.0, a_gbps=10.0, b_gbps=10.0):
    src, staging, a, b = _tiers()
    import dataclasses
    src = dataclasses.replace(src, bandwidth_bytes_per_s=src_gbps * GBPS)
    a = dataclasses.replace(a, bandwidth_bytes_per_s=a_gbps * GBPS)
    b = dataclasses.replace(b, bandwidth_bytes_per_s=b_gbps * GBPS)
    return DrainageBasin([src, staging, a, b],
                         [Link("src", "staging"),
                          Link("staging", "path-a"),
                          Link("staging", "path-b")])


# -- topology ----------------------------------------------------------------

def test_linear_basin_is_degenerate_dag():
    b = tpu_input_basin()
    assert b.is_linear
    assert len(b.paths()) == 1
    assert b.paths()[0] == tuple(t.name for t in b.tiers)
    assert b.roots() == ["dataset-store"] and b.sinks() == ["hbm"]
    assert b.split_tiers() == [] and b.merge_tiers() == []


def test_fanout_split_detected():
    b = _fanout_basin()
    assert not b.is_linear
    assert b.split_tiers() == ["staging"]
    assert b.paths() == [("src", "staging", "path-a"),
                         ("src", "staging", "path-b")]


def test_fanin_merge_detected():
    b = sharded_input_basin(3)
    assert b.merge_tiers() == ["host-burst-buffer"]
    assert b.roots() == ["shard-0", "shard-1", "shard-2"]
    assert len(b.paths()) == 3


def test_cycle_rejected():
    t = [Tier("a", TierKind.SOURCE, 1e9), Tier("b", TierKind.CHANNEL, 1e9),
         Tier("c", TierKind.SINK, 1e9)]
    with pytest.raises(ValueError, match="cycle"):
        DrainageBasin(t, [Link("a", "b"), Link("b", "c"), Link("c", "a")])


def test_disconnected_tier_rejected():
    t = [Tier("a", TierKind.SOURCE, 1e9), Tier("b", TierKind.SINK, 1e9),
         Tier("island", TierKind.CHANNEL, 1e9)]
    with pytest.raises(ValueError, match="disconnected"):
        DrainageBasin(t, [Link("a", "b")])


def test_path_basin_is_linear_view():
    b = _fanout_basin()
    sub = b.path_basin(("src", "staging", "path-a"))
    assert sub.is_linear
    assert [t.name for t in sub.tiers] == ["src", "staging", "path-a"]
    # shared Tier objects: the sub-basin sees the same estimates
    assert sub.tiers[0] is b.tier("src")


def test_branch_rates_conserve_shared_tier():
    """Branch rates through a shared tier sum to <= its effective rate."""
    b = _fanout_basin(src_gbps=12.0)        # src is the shared bottleneck
    rates = b.branch_rates()
    assert sum(rates.values()) <= 12.0 * GBPS * (1 + 1e-9)
    # both branches private-capable of 10, squeezed fairly to 6 each
    for r in rates.values():
        assert r == pytest.approx(6.0 * GBPS)


def test_aggregate_throughput_sums_branches():
    b = _fanout_basin()                     # 40 Gbps src, 2 x 10 Gbps sinks
    assert b.achievable_throughput() == pytest.approx(20.0 * GBPS)


def test_replace_tiers_rederives_derived_links():
    import dataclasses
    b = _fanout_basin()
    slow = [dataclasses.replace(t, bandwidth_bytes_per_s=1.0 * GBPS)
            if t.name == "path-a" else t for t in b.tiers]
    revised = b.replace_tiers(slow)
    assert revised.link("staging", "path-a").bandwidth_bytes_per_s \
        == pytest.approx(1.0 * GBPS)
    assert revised.paths() == b.paths()


# -- multipath planning ------------------------------------------------------

def test_multipath_plan_has_branch_per_path():
    plan = plan_transfer(_fanout_basin(), 1 * MIB, stages=("deliver",))
    assert plan.is_multipath
    assert [b.branch_id for b in plan.branches] == ["path-a", "path-b"]
    assert sum(b.weight for b in plan.branches) == pytest.approx(1.0)
    assert plan.planned_bytes_per_s == pytest.approx(
        sum(b.rate_bytes_per_s for b in plan.branches))
    for b in plan.branches:
        assert b.private_tiers == (b.branch_id,)


def test_multipath_weights_follow_capacity():
    plan = plan_transfer(_fanout_basin(a_gbps=15.0, b_gbps=5.0), 1 * MIB,
                         stages=("deliver",))
    by = {b.branch_id: b for b in plan.branches}
    assert by["path-a"].weight > by["path-b"].weight


def test_legacy_basins_plan_as_single_branch():
    """All pre-DAG call sites keep working: one branch mirroring hops."""
    for basin, stages, ordered in [
        (paper_basin(), ("stage",), False),
        (tpu_input_basin(), ("decode", "stage"), True),
        (checkpoint_basin(), ("serialize",), False),
        (decode_stream_basin(), ("token-stream",), True),
    ]:
        plan = plan_transfer(basin, 1 * MIB, stages=stages, ordered=ordered)
        assert not plan.is_multipath
        assert len(plan.branches) == 1
        assert plan.branches[0].hops == plan.hops
        assert plan.branches[0].weight == 1.0
        assert plan.branches[0].rate_bytes_per_s == pytest.approx(
            plan.planned_bytes_per_s)


def test_single_path_dag_equivalent_to_linear():
    """The equivalence acceptance: a chain expressed as an explicit DAG
    (derived links) plans identically to the implicit linear form."""
    tiers = [
        Tier("a", TierKind.SOURCE, 10 * GBPS, latency_s=5e-3,
             jitter_s=20e-3),
        Tier("b", TierKind.BURST_BUFFER, 100 * GBPS, latency_s=1e-5),
        Tier("c", TierKind.SINK, 40 * GBPS, latency_s=1e-4),
    ]
    linear = DrainageBasin(tiers)
    dag = DrainageBasin(tiers, [Link("a", "b"), Link("b", "c")])
    assert dag.is_linear
    for stages in (("move",), ("pull", "push")):
        p_lin = plan_transfer(linear, 4 * MIB, stages=stages, checksum=True)
        p_dag = plan_transfer(dag, 4 * MIB, stages=stages, checksum=True)
        assert p_lin.hops == p_dag.hops
        assert p_lin.planned_bytes_per_s == pytest.approx(
            p_dag.planned_bytes_per_s)
        assert p_lin.checksum_index == p_dag.checksum_index


def test_describe_is_branch_aware():
    plan = plan_transfer(_fanout_basin(), 1 * MIB, stages=("deliver",))
    text = plan.describe()
    assert "2 branches" in text
    assert "path-a" in text and "path-b" in text
    assert "aggregate" in text
    # the linear format is unchanged
    lin = plan_transfer(tpu_input_basin(), 1 * MIB, stages=("decode",
                                                            "stage"))
    assert lin.describe().startswith("TransferPlan(decode[")


def test_prebuilt_dag_basins_plan_cleanly():
    for basin in (sharded_input_basin(4), mirrored_checkpoint_basin(),
                  decode_fanout_basin(3)):
        plan = plan_transfer(basin, 1 * MIB, stages=("s",))
        assert plan.is_multipath
        assert len(plan.branches) == len(basin.paths())
        assert plan.planned_bytes_per_s > 0


# -- parallel-branch movement (deterministic, virtual clock) -----------------

ITEM = 1 * MIB


def test_parallel_split_delivers_everything(simbasin):
    plan = plan_transfer(_fanout_basin(), ITEM, stages=("deliver",))
    tier_a = simbasin.branch_tier("path-a", bandwidth_bytes_per_s=10 * GBPS)
    tier_b = simbasin.branch_tier("path-b", bandwidth_bytes_per_s=10 * GBPS)
    src = simbasin.source(simbasin.tier(bandwidth_bytes_per_s=1000 * GBPS,
                                        wall_pacing_s=0.0), 40, ITEM)
    got = []
    rep = simbasin.mover(plan=plan).parallel_transfer(
        iter(src), got.append,
        transforms={"path-a": [("deliver", simbasin.service(tier_a))],
                    "path-b": [("deliver", simbasin.service(tier_b))]},
        mode="split")
    assert rep.items == 40 and len(got) == 40
    # equal weights deal the stream evenly (deterministic DRR)
    assert tier_a.served == 20 and tier_b.served == 20
    names = {r.name for r in rep.stage_reports}
    assert names == {"path-a/deliver", "path-b/deliver"}


def test_parallel_split_beats_one_branch(simbasin):
    """Two healthy branches move the stream ~2x faster than one: the
    aggregate-rate claim, in virtual time."""
    def run(n_branches):
        h = SimHarness()
        basin = (_fanout_basin() if n_branches == 2 else
                 DrainageBasin(_tiers()[:3]))
        plan = plan_transfer(basin, ITEM, stages=("deliver",))
        tiers = {bid: h.branch_tier(bid, bandwidth_bytes_per_s=10 * GBPS)
                 for bid in ("path-a", "path-b")[:n_branches]}
        src = h.source(h.tier(bandwidth_bytes_per_s=1000 * GBPS,
                              wall_pacing_s=0.0), 60, ITEM)
        tf = {bid: [("deliver", h.service(t))] for bid, t in tiers.items()}
        if n_branches == 1:
            rep = h.mover(plan=plan).bulk_transfer(
                iter(src), lambda _: None, transforms=tf["path-a"])
        else:
            rep = h.mover(plan=plan).parallel_transfer(
                iter(src), lambda _: None, transforms=tf, mode="split")
        return rep.elapsed_s

    assert run(2) < 0.65 * run(1)


def test_parallel_mirror_replicates_to_every_branch(simbasin):
    plan = plan_transfer(mirrored_checkpoint_basin(), ITEM,
                         stages=("serialize",))
    got = {b.branch_id: [] for b in plan.branches}
    sinks = {bid: got[bid].append for bid in got}
    src = simbasin.source(simbasin.tier(bandwidth_bytes_per_s=1000 * GBPS,
                                        wall_pacing_s=0.0), 12, ITEM)
    rep = simbasin.mover(plan=plan).parallel_transfer(
        iter(src), sinks, mode="mirror")
    assert all(len(v) == 12 for v in got.values())
    assert rep.items == 24          # deliveries: every item moved twice


def test_parallel_checksum_hashes_each_source_item_once(simbasin):
    plan = plan_transfer(_fanout_basin(), ITEM, stages=("deliver",))
    payloads = [bytes([i]) * 1024 for i in range(20)]

    def run(mode):
        return simbasin.mover(plan=plan, checksum=True).parallel_transfer(
            iter(payloads), lambda _: None, mode=mode, checksum=True)

    import hashlib
    acc = bytearray(32)
    for p in payloads:
        d = hashlib.sha256(p).digest()
        for i in range(32):
            acc[i] ^= d[i]
    assert run("split").checksum == bytes(acc).hex()
    # mirror replicates deliveries but the stream digest is unchanged
    assert run("mirror").checksum == bytes(acc).hex()


# -- the acceptance scenario: one branch degrades mid-transfer ---------------

def _degrade_scenario(online_chunk, drain_per_segment=False):
    """120 items over two 10 Gbps branches; branch A collapses to 0.5 Gbps
    from its 30th served item — the start of A's third 15-item segment
    share under the equal-weight deal with ``online_chunk=30``, so the
    third segment's samples are purely degraded.  Returns (report, mover,
    starting plan)."""
    h = SimHarness()
    plan = plan_transfer(_fanout_basin(), ITEM, stages=("deliver",))
    tier_a = h.branch_tier("path-a", bandwidth_bytes_per_s=10 * GBPS)
    tier_a.shift_at(30, bandwidth_bytes_per_s=0.5 * GBPS)
    tier_b = h.branch_tier("path-b", bandwidth_bytes_per_s=10 * GBPS)
    src = h.source(h.tier(bandwidth_bytes_per_s=1000 * GBPS,
                          wall_pacing_s=0.0), 120, ITEM)
    mover = h.mover(plan=plan)
    rep = mover.parallel_transfer(
        iter(src), lambda _: None,
        transforms={"path-a": [("deliver", h.service(tier_a))],
                    "path-b": [("deliver", h.service(tier_b))]},
        mode="split", replan_every_items=online_chunk,
        drain_per_segment=drain_per_segment)
    return rep, mover, plan


def test_replan_attributes_degrade_to_one_branch_only():
    """The acceptance criterion, deterministic form: replayed reports of
    a degraded-A segment (A backpressures the split node and
    underdelivers with a tight service signature; B starves in A's
    shadow) must produce a verdict for the degraded branch ONLY, on its
    private tier.  Synthetic replay — no threads, no host-load noise;
    the threaded end-to-end form of the same scenario is asserted with
    load-robust invariants in the two tests below."""
    from repro.core.planner import replan
    from repro.core.staging import StageReport
    plan = plan_transfer(_fanout_basin(), ITEM, stages=("deliver",))
    share = 30 * ITEM
    reports = [
        StageReport(name="path-a/deliver", items=30, bytes=share,
                    elapsed_s=0.5, active_s=0.5, stall_up_s=0.02,
                    stall_down_s=0.0, errors=0,
                    service_up_s=[33.5e-3 + 1e-5 * (i % 3)
                                  for i in range(30)]),
        StageReport(name="path-b/deliver", items=30, bytes=share,
                    elapsed_s=0.5, active_s=0.45, stall_up_s=0.7,
                    stall_down_s=0.0, errors=0,
                    service_up_s=[33.5e-3 + 1e-5 * (i % 3)
                                  for i in range(30)]),
    ]
    revised = replan(plan, reports, damping=1.0,
                     intake_ratio={"path-a": 0.85, "path-b": 0.02})
    assert set(revised.diagnosis) == {"path-a/deliver"}
    assert "path-a" in revised.diagnosis["path-a/deliver"]
    by = {b.branch_id: b for b in revised.branches}
    assert by["path-b"].weight > by["path-a"].weight


def test_threaded_degrade_attributes_degraded_branch():
    """Threaded end-to-end form: the degraded branch must carry a verdict
    naming its own private tier, and the healthy branch must never be
    diagnosed bandwidth-bound (which would wrongly strip its traffic
    share).  A stray latency verdict on the healthy branch under extreme
    host load is tolerated — it is weight-neutral; the strict
    one-branch-only claim is pinned by the deterministic replay above
    and the recorded corpus fixture."""
    rep, mover, _ = _degrade_scenario(online_chunk=30)
    diag = mover.last_plan.diagnosis
    assert any(k.startswith("path-a/") for k in diag), diag
    assert "path-a" in diag["path-a/deliver"]
    assert "bandwidth-bound" not in diag.get("path-b/deliver", ""), diag


def test_replan_rebalances_toward_healthy_branch():
    rep, mover, plan = _degrade_scenario(online_chunk=30)
    start = {b.branch_id: b.weight for b in plan.branches}
    final = {b.branch_id: b.weight for b in mover.last_plan.branches}
    assert start["path-a"] == pytest.approx(start["path-b"])
    assert final["path-b"] > final["path-a"]
    assert rep.replans >= 1


def test_online_rebalance_beats_static_split():
    """Drained segments re-deal the whole next segment at the revised
    weights, so the strict 0.9 margin holds on the drain path (the
    calibration this claim was recorded under).  The zero-drain path's
    dispatcher runs ahead of the revision by the pipeline's depth — a few
    items stay committed to the degraded branch at stale weights — so its
    honest guarantee on this scenario is weaker: it must still beat the
    static split (and the in-segment answer to transient asymmetry is the
    pull-based ``route="steal"``, asserted in test_live_swap.py)."""
    static, _, _ = _degrade_scenario(online_chunk=0)
    drained, _, _ = _degrade_scenario(online_chunk=30,
                                      drain_per_segment=True)
    live, _, _ = _degrade_scenario(online_chunk=30)
    assert static.items == drained.items == live.items == 120
    assert drained.elapsed_s < 0.9 * static.elapsed_s
    assert live.elapsed_s < static.elapsed_s
    assert live.replans >= 1


# -- consumer: mirrored checkpoint save / fastest restore --------------------

def test_mirrored_save_and_fallback_restore(tmp_path):
    from repro.checkpoint.manager import (CheckpointManager, save_checkpoint,
                                          verify_checkpoint)
    tree = {"w": np.arange(24, dtype=np.float32).reshape(4, 6)}
    root, mirror = str(tmp_path / "p"), str(tmp_path / "m")
    save_checkpoint(root, 3, tree, mirror_root=mirror)
    assert verify_checkpoint(root, 3) and verify_checkpoint(mirror, 3)

    mgr = CheckpointManager(root, mirror_root=mirror)
    step, restored = mgr.restore_latest(
        {"w": np.zeros((4, 6), np.float32)})
    assert step == 3
    assert np.allclose(np.asarray(restored["w"]), tree["w"])

    # torn primary: restore falls back to the mirror replica
    import shutil
    shutil.rmtree(str(tmp_path / "p" / "step_0000000003"))
    step, restored = mgr.restore_latest(
        {"w": np.zeros((4, 6), np.float32)})
    assert step == 3
    assert np.allclose(np.asarray(restored["w"]), tree["w"])


def test_mirrored_manager_save_via_mover(tmp_path):
    from repro.checkpoint.manager import CheckpointManager, latest_step
    tree = {"w": np.ones((8, 8), np.float32),
            "b": np.zeros(16, np.float32)}
    mgr = CheckpointManager(str(tmp_path / "p"), every_steps=1,
                            mirror_root=str(tmp_path / "m"))
    assert mgr.maybe_save(1, tree)
    mgr.wait()
    assert latest_step(str(tmp_path / "p")) == 1
    assert latest_step(str(tmp_path / "m")) == 1
    # the mirrored (multipath) plan persisted for the next save
    assert mgr._mirror_plan is not None and mgr._mirror_plan.is_multipath


# -- consumer: input-pipeline shard fan-in -----------------------------------

def test_input_pipeline_shard_fanin():
    from repro.configs import get_smoke_config
    from repro.data.pipeline import (InputPipeline, PipelineConfig,
                                     SyntheticTokenSource)
    cfg = get_smoke_config("repro-100m")
    pc = PipelineConfig(global_batch=4, seq_len=16)
    shards = [SyntheticTokenSource(cfg, pc, n_batches=4) for _ in range(3)]
    pipe = InputPipeline(shards, pc=pc, to_device=False)
    assert pipe.shard_plan is not None and pipe.shard_plan.is_multipath
    assert [b.branch_id for b in pipe.shard_plan.branches] == \
        ["shard-0", "shard-1", "shard-2"]
    batches = list(pipe)
    assert len(batches) == 12
    names = {r.name for r in pipe.reports()}
    assert {"shard-0/pull", "shard-1/pull", "shard-2/pull",
            "decode", "stage"} <= names
    pipe.replan()                   # revises shard plan from tagged reports
    assert pipe.shard_plan.is_multipath


def test_input_pipeline_fanin_rejects_branch_source_mismatch():
    """A basin whose path count differs from the shard-source count must
    fail loudly at construction — a silent zip() would drop shards."""
    from repro.configs import get_smoke_config
    from repro.data.pipeline import (InputPipeline, PipelineConfig,
                                     SyntheticTokenSource)
    cfg = get_smoke_config("repro-100m")
    pc = PipelineConfig(global_batch=4, seq_len=16)
    shards = [SyntheticTokenSource(cfg, pc, n_batches=3) for _ in range(3)]
    with pytest.raises(ValueError, match="shard sources"):
        InputPipeline(shards, basin=tpu_input_basin(), pc=pc,
                      to_device=False)
    with pytest.raises(ValueError, match="shard sources"):
        InputPipeline(shards, basin=sharded_input_basin(2), pc=pc,
                      to_device=False)


def test_input_pipeline_fanin_honours_online_replan_cadence():
    """replan_every_items stays live in fan-in mode: the merged tail runs
    in segments and every batch is still delivered exactly once."""
    from repro.configs import get_smoke_config
    from repro.data.pipeline import (InputPipeline, PipelineConfig,
                                     SyntheticTokenSource)
    cfg = get_smoke_config("repro-100m")
    pc = PipelineConfig(global_batch=4, seq_len=16)
    shards = [SyntheticTokenSource(cfg, pc, n_batches=5) for _ in range(2)]
    pipe = InputPipeline(shards, pc=pc, to_device=False,
                         replan_every_items=4)
    assert pipe.replan_every_items == 4
    batches = list(pipe)
    assert len(batches) == 10
    # cumulative reports still cover everything, shard tags included
    merged = {r.name: r for r in pipe.reports()}
    assert merged["decode"].items == 10
    assert merged["shard-0/pull"].items + merged["shard-1/pull"].items == 10


def test_input_pipeline_fanin_tail_starts_at_merge_tier():
    """Regression (ROADMAP bug): a custom fan-in basin whose shards have
    a private chain DEEPER than one tier used to derive the shared tail
    as ``tiers[1:]`` of branch 0 — planning the merged decode/place path
    over another branch's private cache tier.  The tail must start at
    the merge tier: the first tier common to every root->sink path."""
    from repro.configs import get_smoke_config
    from repro.data.pipeline import (InputPipeline, PipelineConfig,
                                     SyntheticTokenSource)
    n_shards = 2
    shard_tiers = []
    links = []
    for i in range(n_shards):
        shard_tiers += [
            Tier(f"shard-{i}", TierKind.SOURCE, 4.0 * GBPS, latency_s=5e-3),
            Tier(f"cache-{i}", TierKind.BURST_BUFFER, 20.0 * GBPS,
                 latency_s=1e-4),
        ]
        links += [Link(f"shard-{i}", f"cache-{i}"),
                  Link(f"cache-{i}", "host-burst-buffer")]
    tail = [
        Tier("host-burst-buffer", TierKind.BURST_BUFFER, 200.0 * GBPS,
             latency_s=1e-5),
        Tier("pcie", TierKind.CHANNEL, 128.0 * GBPS, latency_s=2e-5),
        Tier("hbm", TierKind.SINK, 819.0 * 8.0 * GBPS, latency_s=1e-6),
    ]
    links += [Link("host-burst-buffer", "pcie"), Link("pcie", "hbm")]
    basin = DrainageBasin(shard_tiers + tail, links)

    cfg = get_smoke_config("repro-100m")
    pc = PipelineConfig(global_batch=4, seq_len=16)
    shards = [SyntheticTokenSource(cfg, pc, n_batches=3)
              for _ in range(n_shards)]
    pipe = InputPipeline(shards, basin=basin, pc=pc, to_device=False)
    # the tail plan's basin begins at the merge tier — no branch-private
    # cache tier leaks into the shared decode/place path
    tail_names = [t.name for t in pipe.plan.basin.tiers]
    assert tail_names == ["host-burst-buffer", "pcie", "hbm"]
    # each shard branch still plans its own 2-deep private chain
    assert len(pipe.shard_plan.branches) == n_shards
    for b in pipe.shard_plan.branches:
        assert set(b.private_tiers) == {b.branch_id,
                                        b.branch_id.replace("shard", "cache")}
    batches = list(pipe)
    assert len(batches) == 3 * n_shards


def test_fanin_promise_bounded_by_shard_aggregate():
    """The input-layer promise must fold in the shard branches' conserved
    aggregate — the fast merge-to-device tail alone would inflate it and
    make every fidelity gap read ~1.0."""
    from repro.configs import get_smoke_config
    from repro.data.pipeline import (InputPipeline, PipelineConfig,
                                     SyntheticTokenSource)
    cfg = get_smoke_config("repro-100m")
    pc = PipelineConfig(global_batch=4, seq_len=16)
    shards = [SyntheticTokenSource(cfg, pc, n_batches=2) for _ in range(2)]
    pipe = InputPipeline(shards, pc=pc, to_device=False)
    assert pipe.plan.planned_bytes_per_s <= \
        pipe.shard_plan.planned_bytes_per_s * (1 + 1e-9)
    pipe.replan()       # the clamp survives plan revision too
    assert pipe.plan.planned_bytes_per_s <= \
        pipe.shard_plan.planned_bytes_per_s * (1 + 1e-9)


def test_mirror_promise_paces_at_slowest_branch(simbasin):
    """Mirror-mode reports promise n x the weakest branch rate, not the
    split-mode aggregate — replication can never beat its slowest copy."""
    plan = plan_transfer(mirrored_checkpoint_basin(), ITEM,
                         stages=("serialize",))
    src = simbasin.source(simbasin.tier(bandwidth_bytes_per_s=1000 * GBPS,
                                        wall_pacing_s=0.0), 8, ITEM)
    rep = simbasin.mover(plan=plan).parallel_transfer(
        iter(src), lambda _: None, mode="mirror")
    rates = [b.rate_bytes_per_s for b in plan.branches]
    assert rep.planned_bytes_per_s == pytest.approx(len(rates) * min(rates))
    assert rep.planned_bytes_per_s < plan.planned_bytes_per_s


def test_mirrored_restore_rejects_bit_rotted_replica(tmp_path):
    """A corrupt shard whose shape/dtype survive np.load must still fail
    the first replica (manifest re-hash) and fall back to the mirror."""
    from repro.checkpoint.manager import CheckpointManager, save_checkpoint
    tree = {"w": np.arange(16, dtype=np.float32)}
    root, mirror = str(tmp_path / "p"), str(tmp_path / "m")
    save_checkpoint(root, 2, tree, mirror_root=mirror)
    # bit-rot the primary's shard in place: same shape, same dtype
    shard = tmp_path / "p" / "step_0000000002" / "leaf_00000.npy"
    arr = np.load(shard)
    arr[3] += 1.0
    np.save(shard, arr)
    mgr = CheckpointManager(root, mirror_root=mirror)
    step, restored = mgr.restore_latest({"w": np.zeros(16, np.float32)})
    assert step == 2
    assert np.allclose(np.asarray(restored["w"]), tree["w"])


def test_shared_tier_revision_sums_branch_shares():
    """Corroborated shared-tier evidence applies ONCE with the branches'
    summed rate — per-share damped updates would collapse a healthy
    shared tier's estimate to ~1/N of its real rate."""
    from repro.core.planner import replan
    from repro.core.staging import StageReport
    basin = sharded_input_basin(4, shard_gbps=40.0, host_staging_gbps=8.0)
    plan = plan_transfer(basin, 1 * MIB, stages=("pull",))
    # every shard starves downstream at the shared host tier, each
    # observing its ~1/4 share of the tier's true 1 GB/s delivery
    share = 8.0 * GBPS / 4
    reports = []
    for b in plan.branches:
        hop = b.hops[0]
        reports.append(StageReport(
            name=f"{b.branch_id}/{hop.name}", items=64,
            bytes=int(share * 2.0), elapsed_s=2.0, active_s=2.0,
            stall_up_s=0.0, stall_down_s=hop.workers * 2.0 * 0.7,
            errors=0,
            service_down_s=[1 * MIB / share + 1e-5 * (i % 2)
                            for i in range(40)]))
    revised = replan(plan, reports, damping=1.0)
    host = revised.basin.tier("host-burst-buffer")
    # aggregate observation = 4 shares = the tier's true rate
    assert host.bandwidth_bytes_per_s == pytest.approx(8.0 * GBPS, rel=0.01)


def test_mirrored_restore_falls_back_to_older_intact_step(tmp_path):
    """When the only replica holding the newest step is corrupt, restore
    must fall back to an older intact checkpoint rather than raise."""
    from repro.checkpoint.manager import CheckpointManager, save_checkpoint
    old = {"w": np.full(8, 1.0, np.float32)}
    new = {"w": np.full(8, 2.0, np.float32)}
    root, mirror = str(tmp_path / "p"), str(tmp_path / "m")
    save_checkpoint(root, 1, old, mirror_root=mirror)
    # step 2 exists only in the primary (crash between the two commits)
    save_checkpoint(root, 2, new)
    # ... and its shard bit-rots
    shard = tmp_path / "p" / "step_0000000002" / "leaf_00000.npy"
    arr = np.load(shard)
    arr[0] += 5.0
    np.save(shard, arr)
    mgr = CheckpointManager(root, mirror_root=mirror)
    step, restored = mgr.restore_latest({"w": np.zeros(8, np.float32)})
    assert step == 1
    assert np.allclose(np.asarray(restored["w"]), old["w"])


def test_untagged_report_not_multiplied_across_branches():
    """A multipath plan driven through one pipeline yields UNTAGGED
    reports; the lookup fallback hands every branch the same report, and
    the shared-tier revision must count it once — not once per branch."""
    from repro.core.planner import replan
    from repro.core.staging import StageReport
    plan = plan_transfer(_fanout_basin(), ITEM, stages=("deliver",))
    observed = 0.105e9          # one pipeline, starved upstream of src
    rep = StageReport(name="deliver", items=64, bytes=int(observed * 2.0),
                      elapsed_s=2.0, active_s=2.0,
                      stall_up_s=plan.hops[0].workers * 2.0 * 0.7,
                      stall_down_s=0.0, errors=0)
    revised = replan(plan, [rep], damping=1.0)
    src = revised.basin.tier("src")
    assert src.bandwidth_bytes_per_s == pytest.approx(observed, rel=0.01)


def test_single_root_restore_keeps_strict_contract(tmp_path):
    """Without a mirror, a failing newest checkpoint raises — it must not
    silently resume from an older step (masking corruption)."""
    from repro.checkpoint.manager import CheckpointManager, save_checkpoint
    mgr = CheckpointManager(str(tmp_path))
    save_checkpoint(str(tmp_path), 1, {"w": np.ones(4, np.float32)})
    save_checkpoint(str(tmp_path), 2, {"w": np.ones(4, np.float32)})
    # tear step 2's shard away entirely
    (tmp_path / "step_0000000002" / "leaf_00000.npy").unlink()
    with pytest.raises(Exception):
        mgr.restore_latest({"w": np.zeros(4, np.float32)})
