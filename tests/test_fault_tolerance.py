"""Fault-tolerant transfers (PR 9): injection, retry/backoff, branch
failover, and resumable ledgers.

The paper's production framing (§2.1 "routine operation") assumes
transfers *finish* — a long transfer's completion is decided by how the
system behaves when an element flakes, flaps, or dies outright.  These
tests pin the survive layer end to end, all in deterministic virtual
time:

* scripted fault injection (``SimulatedTier.fail_at``,
  ``SimulatedLink.outage``) is itself deterministic;
* stage-level retry honors the hop's budget exactly — never one attempt
  more — and charges its backoff to the report, which feeds the
  ``fault-degraded`` replan verdict;
* a branch that exhausts its budget dies WITHOUT killing the transfer:
  the dispatcher fails over, stranded items are salvaged down a
  survivor, and the stream checksum proves item-exactness;
* a killed transfer resumes from its durable ledger with a
  bit-identical stream checksum and no item moved twice.
"""

import dataclasses
import json

import pytest

from simbasin import LinkOutage, SimHarness, SimulatedFault

from repro.core.basin import DrainageBasin, GBPS, Link, MIB, Tier, \
    TierKind, mirrored_checkpoint_basin
from repro.core.integrity import StreamDigest
from repro.core.mover import MoverConfig, UnifiedDataMover
from repro.core.planner import plan_transfer, replan
from repro.core.resume import TransferLedger
from repro.core.staging import BufferClosed, BurstBuffer, Stage, \
    StageReport, WindowedStage

ITEM = 1 * MIB


def _tiers():
    return [
        Tier("src", TierKind.SOURCE, 40.0 * GBPS, latency_s=1e-5),
        Tier("staging", TierKind.BURST_BUFFER, 40.0 * GBPS, latency_s=1e-5),
        Tier("path-a", TierKind.SINK, 10.0 * GBPS),
        Tier("path-b", TierKind.SINK, 10.0 * GBPS),
    ]


def _fanout_basin():
    return DrainageBasin(_tiers(),
                         [Link("src", "staging"),
                          Link("staging", "path-a"),
                          Link("staging", "path-b")])


def _payloads(n, size=1024):
    """Distinct payloads: identical items XOR their SHA-256s away in
    pairs, which would blind the checksum to a lost pair."""
    return [bytes([i % 251 + 1]) * size for i in range(n)]


def _xor_sha(payloads):
    import hashlib
    acc = bytearray(32)
    for p in payloads:
        d = hashlib.sha256(p).digest()
        for i in range(32):
            acc[i] ^= d[i]
    return bytes(acc).hex()


# -- fault injection (tests/simbasin.py) -------------------------------------


def test_transient_fault_fires_once(simbasin):
    t = simbasin.tier(bandwidth_bytes_per_s=1 * GBPS, wall_pacing_s=0.0)
    t.fail_at(2)
    t.serve(1024)
    t.serve(1024)
    with pytest.raises(SimulatedFault):
        t.serve(1024)
    # the retry succeeds: the fault was transient, and the failed
    # attempt charged no transmission
    t.serve(1024)
    assert t.served == 3 and t.faults == 1


def test_permanent_fault_kills_the_tier(simbasin):
    t = simbasin.tier(bandwidth_bytes_per_s=1 * GBPS, wall_pacing_s=0.0)
    t.fail_at(1, permanent=True)
    t.serve(1024)
    for _ in range(3):
        with pytest.raises(SimulatedFault):
            t.serve(1024)
    assert t.served == 1 and t.faults == 3


def test_link_outage_window_is_arrival_gated(simbasin):
    link = simbasin.link(bandwidth_bytes_per_s=1 * GBPS, rtt_s=0.05,
                         wall_pacing_s=0.0)
    link.outage(10.0, 5.0)
    link.serve(1024)                       # arrives ~0s: before the window
    simbasin.clock.set_thread(12.0)
    with pytest.raises(LinkOutage):
        link.serve(1024)                   # arrives mid-blackout
    simbasin.clock.set_thread(15.5)
    link.serve(1024)                       # reconnected after the window
    assert link.faults == 1


def test_injection_is_deterministic():
    def run():
        h = SimHarness()
        t = h.tier(bandwidth_bytes_per_s=1 * GBPS, jitter_s=1e-3, seed=7,
                   wall_pacing_s=0.0)
        t.fail_at(3)
        out = []
        for _ in range(6):
            try:
                out.append(round(t.serve(1024), 9))
            except SimulatedFault:
                out.append("fault")
        return out

    assert run() == run()


# -- stage-level retry/backoff -----------------------------------------------


def _drive_stage(st, items):
    up = BurstBuffer(capacity=max(len(items), 1))
    for it in items:
        up.put(it)
    up.close()

    def pull():
        try:
            return up.get()
        except BufferClosed:
            return None

    st.start(pull)


def test_stage_retries_transient_faults_away():
    calls = {"n": 0}

    def flaky(item):
        calls["n"] += 1
        if calls["n"] in (2, 3):            # one item flakes twice
            raise RuntimeError("flap")
        return item

    st = Stage("hop", capacity=8, workers=1, transform=flaky,
               retry_budget=3, backoff_base_s=1e-4)
    _drive_stage(st, [bytes(64)] * 5)
    st.join(timeout=10.0)
    rep = st.report()
    assert rep.items == 5 and rep.errors == 0
    assert rep.retries == 2
    assert rep.retry_wait_s > 0


@pytest.mark.parametrize("budget", [0, 1, 2, 3])
def test_retry_budget_is_never_exceeded(budget):
    """The property the fault posture promises: budget+1 attempts per
    item, then the error surfaces — never one attempt more."""
    attempts = {"n": 0}

    def doomed(item):
        attempts["n"] += 1
        raise RuntimeError("dead element")

    st = Stage("hop", capacity=4, workers=1, transform=doomed,
               retry_budget=budget, backoff_base_s=1e-4)
    _drive_stage(st, [bytes(64)])
    st.wait(timeout=10.0)
    assert st.failed
    assert attempts["n"] == budget + 1
    assert st.report().retries == budget
    # the in-hand item is salvageable, not lost
    assert st.take_salvage() == [bytes(64)]


def test_default_stage_keeps_fail_fast():
    def doomed(item):
        raise RuntimeError("boom")

    st = Stage("hop", capacity=4, workers=1, transform=doomed)
    _drive_stage(st, [bytes(64)])
    st.wait(timeout=10.0)
    assert st.failed and st.report().retries == 0


def test_backoff_is_seeded_deterministic():
    def run():
        def doomed(item):
            raise RuntimeError("x")
        st = Stage("hop", capacity=4, workers=1, transform=doomed,
                   retry_budget=4, backoff_base_s=1e-4)
        _drive_stage(st, [bytes(64)])
        st.wait(timeout=10.0)
        return st.report().retry_wait_s

    assert run() == pytest.approx(run())


def test_planned_hops_carry_retry_budget():
    plan = plan_transfer(_fanout_basin(), ITEM, stages=("deliver",))
    for b in plan.branches:
        for h in b.hops:
            assert h.retry_budget >= 1
            assert h.backoff_base_s > 0
    assert "retry=" in plan.describe()


# -- the fault-degraded verdict ----------------------------------------------


def _faulted_report(name, hop, *, retry_frac=0.5, rate_frac=0.4,
                    items=30):
    elapsed = items * ITEM / (hop.rate_bytes_per_s * rate_frac)
    return StageReport(
        name=name, items=items, bytes=items * ITEM, elapsed_s=elapsed,
        active_s=elapsed, stall_up_s=0.0, stall_down_s=0.0, errors=0,
        retries=6, retry_wait_s=retry_frac * elapsed * hop.workers)


def test_replan_diagnoses_fault_degraded():
    basin = DrainageBasin(_tiers()[:3], [Link("src", "staging"),
                                         Link("staging", "path-a")])
    plan = plan_transfer(basin, ITEM, stages=("move",))
    hop = plan.hops[0]
    revised = replan(plan, [_faulted_report("move", hop)], damping=1.0)
    assert revised.diagnosis[hop.name].startswith("fault-degraded(")
    # the remedy is an honest re-price, not a staffing change
    assert revised.planned_bytes_per_s < plan.planned_bytes_per_s


def test_fault_degraded_lands_on_the_faulting_branch_only():
    plan = plan_transfer(_fanout_basin(), ITEM, stages=("deliver",))
    by = {b.branch_id: b for b in plan.branches}
    hop_a = by["path-a"].hops[0]
    hop_b = by["path-b"].hops[0]
    share = 30 * ITEM
    healthy = StageReport(
        name="path-b/deliver", items=30, bytes=share,
        elapsed_s=share / hop_b.rate_bytes_per_s,
        active_s=share / hop_b.rate_bytes_per_s,
        stall_up_s=0.0, stall_down_s=0.0, errors=0)
    revised = replan(plan, [_faulted_report("path-a/deliver", hop_a),
                            healthy], damping=1.0)
    assert set(revised.diagnosis) == {"path-a/deliver"}
    assert revised.diagnosis["path-a/deliver"].startswith("fault-degraded(")
    rb = {b.branch_id: b for b in revised.branches}
    assert rb["path-b"].weight > rb["path-a"].weight


def test_retries_without_underdelivery_stay_silent():
    """A hop that retried a couple of flaps but still delivered its
    planned rate earns no verdict — retries alone are not degradation."""
    basin = DrainageBasin(_tiers()[:3], [Link("src", "staging"),
                                        Link("staging", "path-a")])
    plan = plan_transfer(basin, ITEM, stages=("move",))
    hop = plan.hops[0]
    rep = _faulted_report("move", hop, retry_frac=0.02, rate_frac=1.0)
    revised = replan(plan, [rep], damping=1.0)
    assert "fault-degraded" not in str(revised.diagnosis)


# -- branch failover (end to end, virtual time) ------------------------------


def _failover_run(route, n=40, kill_attempt=6, checksum=True):
    h = SimHarness()
    plan = plan_transfer(_fanout_basin(), ITEM, stages=("deliver",))
    tier_a = h.branch_tier("path-a", bandwidth_bytes_per_s=10 * GBPS)
    tier_a.fail_at(kill_attempt, permanent=True)
    tier_b = h.branch_tier("path-b", bandwidth_bytes_per_s=10 * GBPS)
    payloads = _payloads(n, size=ITEM // 256)
    got = []
    mover = h.mover(plan=plan, checksum=checksum)
    rep = mover.parallel_transfer(
        iter(payloads), got.append,
        transforms={"path-a": [("deliver", h.service(tier_a))],
                    "path-b": [("deliver", h.service(tier_b))]},
        mode="split", route=route, checksum=checksum)
    return rep, got, payloads, mover


@pytest.mark.parametrize("route", ["deal", "steal"])
def test_branch_death_does_not_lose_items(route):
    """The tentpole acceptance: a permanent mid-stream tier death on one
    branch; the transfer completes with every item delivered exactly
    once — checksum-verified — and the corpse carries its verdict.
    (route='steal' is the stranded-items regression: a dead thief's
    claimed items must re-enter the shared intake or the tail sweep.)"""
    rep, got, payloads, mover = _failover_run(route)
    assert len(got) == len(payloads)
    assert sorted(got) == sorted(payloads)
    assert rep.checksum == _xor_sha(payloads)
    diag = mover.last_plan.diagnosis
    assert diag.get("path-a", "").startswith("branch-dead")
    assert " dead" in mover.last_plan.describe()


def test_failover_salvages_late_death_on_steal_route():
    """Death at the stream tail: the shared intake may already be closed
    when the corpse's claim is returned — the tail-race path must route
    it through the salvage sweep instead of dropping it."""
    rep, got, payloads, _ = _failover_run("steal", n=24, kill_attempt=11)
    assert sorted(got) == sorted(payloads)
    assert rep.checksum == _xor_sha(payloads)


def test_mirror_survives_replica_death():
    h = SimHarness()
    plan = plan_transfer(mirrored_checkpoint_basin(), ITEM,
                         stages=("serialize",))
    bids = [b.branch_id for b in plan.branches]
    dead_bid, live_bid = bids[0], bids[1]
    tiers = {bid: h.branch_tier(bid, bandwidth_bytes_per_s=10 * GBPS)
             for bid in bids}
    tiers[dead_bid].fail_at(4, permanent=True)
    payloads = _payloads(16, size=ITEM // 256)
    got = {bid: [] for bid in bids}
    mover = h.mover(plan=plan, checksum=True)
    rep = mover.parallel_transfer(
        iter(payloads), {bid: got[bid].append for bid in bids},
        transforms={bid: [("serialize", h.service(t))]
                    for bid, t in tiers.items()},
        mode="mirror", checksum=True)
    # the surviving replica holds the complete stream; the digest is
    # over source items, unaffected by the dead replica
    assert sorted(got[live_bid]) == sorted(payloads)
    assert rep.checksum == _xor_sha(payloads)
    diag = mover.last_plan.diagnosis
    assert diag.get(dead_bid, "").startswith("branch-dead")
    # the mirror promise re-prices to the survivors
    live_rate = {b.branch_id: b.rate_bytes_per_s
                 for b in plan.branches}[live_bid]
    assert rep.planned_bytes_per_s == pytest.approx(live_rate)


def test_all_branches_dead_raises():
    h = SimHarness()
    plan = plan_transfer(_fanout_basin(), ITEM, stages=("deliver",))
    tiers = {bid: h.branch_tier(bid, bandwidth_bytes_per_s=10 * GBPS)
             for bid in ("path-a", "path-b")}
    for t in tiers.values():
        t.fail_at(2, permanent=True)
    with pytest.raises(RuntimeError, match="every branch died"):
        h.mover(plan=plan).parallel_transfer(
            iter(_payloads(20)), lambda _: None,
            transforms={bid: [("deliver", h.service(t))]
                        for bid, t in tiers.items()},
            mode="split")


def test_fleet_member_survives_element_death(simbasin):
    """A fleet member whose basin element dies triggers an arbiter
    rebalance (the corpse's tier derates) instead of a hung grant."""
    h = simbasin
    basin = _fanout_basin()
    arb = h.arbiter(basin)
    adm = arb.admit("xfer", ITEM, qos="bulk", stages=("deliver",))
    assert adm.status == "admitted"
    tiers = {bid: h.branch_tier(bid, bandwidth_bytes_per_s=10 * GBPS)
             for bid in ("path-a", "path-b")}
    tiers["path-a"].fail_at(5, permanent=True)
    payloads = _payloads(30, size=ITEM // 256)
    got = []
    h.mover().parallel_transfer(
        iter(payloads), got.append,
        transforms={bid: [("deliver", h.service(t))]
                    for bid, t in tiers.items()},
        mode="split", fleet=adm)
    assert sorted(got) == sorted(payloads)
    from repro.core.fleet import DEAD_ELEMENT_BYTES_PER_S
    assert arb.basin.tier("path-a").bandwidth_bytes_per_s \
        == pytest.approx(DEAD_ELEMENT_BYTES_PER_S)


# -- windowed fractional-credit bank (the quantization fix) ------------------


def test_window_fractional_credit_banks_and_spends():
    """window = 1.5 items: the stranded half-credit accrues once per
    blocked admission and is spent as a bounded overdraft, so the
    long-run admitted rate follows the window, not floor(window)."""
    st = WindowedStage("wan", capacity=8, workers=1,
                       window_bytes=1536, rtt_s=10.0)
    with st._win_cond:
        ok, banked = st._locked_try_admit(1024, False)
        assert ok and st._inflight == 1024
        # blocked: the stranded half-item leftover banks exactly once
        ok, banked = st._locked_try_admit(1024, banked)
        assert not ok and banked and st._win_bank == 512
        # the banked credit plus the live leftover now cover a full
        # item, so the retry admits as a bounded overdraft...
        ok, banked = st._locked_try_admit(1024, banked)
        assert ok
        assert st._inflight == 2048
        # ...spending the bank down: nothing is minted from thin air
        assert st._win_bank == 0
        # fully overdrawn (inflight > window): no leftover, no banking
        ok, banked = st._locked_try_admit(1024, False)
        assert not ok and not banked and st._win_bank == 0
    assert st._win_bank <= 1024


def test_window_bank_never_exceeds_one_item():
    st = WindowedStage("wan", capacity=8, workers=1,
                       window_bytes=1900, rtt_s=10.0)
    with st._win_cond:
        st._locked_try_admit(1024, False)
        for _ in range(50):
            st._locked_try_admit(1024, False)
        assert st._win_bank <= 1024


def test_fractional_window_raises_long_run_rate():
    """End to end in virtual time: a window of 1.5 items moves a stream
    measurably faster than a window of 1.0 item (the old quantized
    admission delivered identically for both — the half-credit was
    stranded forever)."""
    def run(window_bytes):
        h = SimHarness()
        link = h.link(bandwidth_bytes_per_s=100 * GBPS, rtt_s=0.2)
        st = WindowedStage("wan", capacity=64, workers=4,
                           window_bytes=window_bytes, rtt_s=0.2,
                           transform=h.service(link), clock=h.clock)
        _drive_stage(st, [bytes(1024)] * 24)
        st.join(timeout=30.0)
        rep = st.report()
        assert rep.items == 24
        return rep.elapsed_s

    assert run(1536) < 0.8 * run(1024)


# -- resumable transfer ledger -----------------------------------------------


def test_ledger_records_and_reloads(tmp_path):
    path = str(tmp_path / "ledger.jsonl")
    with TransferLedger(path) as led:
        for p in _payloads(5):
            led.record(p)
        assert led.items_recorded == 5
    led2 = TransferLedger(path)
    assert led2.items_recorded == 5
    assert led2.counts() == TransferLedger(path).counts()
    assert led2.bytes_recorded == 5 * 1024


def test_ledger_tolerates_torn_tail_line(tmp_path):
    path = str(tmp_path / "ledger.jsonl")
    with TransferLedger(path) as led:
        led.record(b"alpha")
        led.record(b"beta")
    with open(path, "a", encoding="utf-8") as fh:
        fh.write('{"sha": "dead')          # mid-write kill
    led2 = TransferLedger(path)
    assert led2.items_recorded == 2        # torn record dropped, not fatal


def test_ledger_is_a_multiset():
    led = TransferLedger()
    led.record(b"dup")
    led.record(b"dup")
    led.record(b"solo")
    digest = StreamDigest(True)
    out = list(led.skip_verified(iter([b"dup"] * 3 + [b"solo"]), digest))
    # exactly two dup occurrences are verified; the third must move
    assert out == [b"dup"]
    assert led.skipped_items == 3


def test_absorb_digest_matches_rehash():
    import hashlib
    items = _payloads(7)
    full = StreamDigest(True)
    for it in items:
        full.add(it)
    mixed = StreamDigest(True)
    for it in items[:3]:
        mixed.absorb_digest(hashlib.sha256(it).hexdigest())
    for it in items[3:]:
        mixed.add(it)
    assert mixed.hexdigest() == full.hexdigest()


def test_absorb_digest_requires_host_placement():
    d = StreamDigest(True, placement="accel")
    with pytest.raises(ValueError, match="host"):
        d.absorb_digest("00" * 32)


def test_resume_is_item_exact_and_digest_identical(tmp_path):
    payloads = _payloads(30, size=2048)
    ref = UnifiedDataMover(MoverConfig(checksum=True)).bulk_transfer(
        iter(payloads), lambda _: None)

    # first attempt dies mid-stream (a killed process, modeled as a
    # sink failure after 11 deliveries)
    path = str(tmp_path / "ledger.jsonl")
    led = TransferLedger(path)
    got1 = []

    def dying_sink(item):
        if len(got1) >= 11:
            raise RuntimeError("power cut")
        got1.append(item)

    with pytest.raises(RuntimeError):
        UnifiedDataMover(MoverConfig(checksum=True)).bulk_transfer(
            iter(payloads), dying_sink, resume=led)
    led.close()
    assert TransferLedger(path).items_recorded == len(got1) == 11

    # the resumed run skips exactly the verified items, moves the rest,
    # and reports the SAME stream checksum as the unbroken reference
    led2 = TransferLedger(path)
    got2 = []
    rep = UnifiedDataMover(MoverConfig(checksum=True)).bulk_transfer(
        iter(payloads), got2.append, resume=led2)
    assert rep.checksum == ref.checksum
    assert led2.skipped_items == 11
    assert sorted(got1 + got2) == sorted(payloads)
    assert led2.items_recorded == len(payloads)
    led2.close()

    # a third pass over a complete ledger moves nothing
    led3 = TransferLedger(path)
    got3 = []
    rep3 = UnifiedDataMover(MoverConfig(checksum=True)).bulk_transfer(
        iter(payloads), got3.append, resume=led3)
    assert got3 == [] and rep3.items == 0
    assert rep3.checksum == ref.checksum
    assert led3.items_recorded == len(payloads)


def test_resume_rejects_accel_checksum():
    plan = dataclasses.replace(
        plan_transfer(DrainageBasin(_tiers()[:3],
                                    [Link("src", "staging"),
                                     Link("staging", "path-a")]),
                      ITEM, stages=("move",)),
        checksum_placement="accel")
    with pytest.raises(ValueError, match="host"):
        UnifiedDataMover(MoverConfig(checksum=True)).bulk_transfer(
            iter(_payloads(3)), lambda _: None, plan=plan,
            resume=TransferLedger())


def test_ledger_survives_repeated_kills(tmp_path):
    """N interruptions: after each resume the ledger is still exactly a
    multiset of delivered items — the union converges to the stream."""
    payloads = _payloads(24, size=1024)
    path = str(tmp_path / "ledger.jsonl")
    delivered = []
    for cut in (5, 9, 6, None):
        led = TransferLedger(path)
        got = []

        def sink(item, _got=got, _cut=cut):
            if _cut is not None and len(_got) >= _cut:
                raise RuntimeError("cut")
            _got.append(item)

        mover = UnifiedDataMover(MoverConfig(checksum=False))
        if cut is None:
            mover.bulk_transfer(iter(payloads), sink, resume=led)
        else:
            with pytest.raises(RuntimeError):
                mover.bulk_transfer(iter(payloads), sink, resume=led)
        delivered.extend(got)
        led.close()
    final = TransferLedger(path)
    assert final.items_recorded == len(payloads)
    assert sorted(delivered) == sorted(payloads)


# -- telemetry surfaces the fault posture ------------------------------------


def test_telemetry_aggregates_retries():
    from repro.core.telemetry import TelemetryRegistry
    reg = TelemetryRegistry()

    flips = {"n": 0}

    def flaky(item):
        flips["n"] += 1
        if flips["n"] == 2:
            raise RuntimeError("flap")
        return item

    mover = UnifiedDataMover(MoverConfig(checksum=False), telemetry=reg,
                             layer="input")
    mover.bulk_transfer(iter(_payloads(6)), lambda _: None,
                        transforms=[("move", flaky)], workers=1,
                        plan=plan_transfer(
                            DrainageBasin(_tiers()[:3],
                                          [Link("src", "staging"),
                                           Link("staging", "path-a")]),
                            ITEM, stages=("move",)))
    s = reg.summary()["input"]
    assert s.retries == 1 and s.retry_wait_s > 0
    assert "retries" in reg.format_summary()
    # the fault counters survive the JSON round trip
    back = TelemetryRegistry.from_json(reg.to_json())
    assert back.summary()["input"].retries == 1
