"""TelemetryRegistry JSON surface: serialization, round-trip, atomic dump
(the dashboard feed written by launch/train.py --telemetry-json)."""

import json
import os

import pytest

from repro.core.mover import TransferReport
from repro.core.telemetry import LayerSummary, TelemetryRegistry


def _report(items=4, nbytes=4096, elapsed=0.5, planned=None):
    return TransferReport(mode="bulk", items=items, bytes=nbytes,
                          elapsed_s=elapsed, stage_reports=[],
                          planned_bytes_per_s=planned)


def _populated():
    reg = TelemetryRegistry()
    reg.record("input", _report(items=8, nbytes=1 << 20, planned=4e6))
    reg.record("input", _report(items=8, nbytes=1 << 20, planned=8e6))
    reg.record("checkpoint", _report(items=3, nbytes=1 << 16))
    reg.record("serve", _report(items=64, nbytes=256, elapsed=0.25,
                                planned=2048.0))
    return reg


def test_to_json_is_valid_and_complete():
    reg = _populated()
    data = json.loads(reg.to_json())
    assert data["version"] == 1
    assert set(data["layers"]) == {"input", "checkpoint", "serve"}
    inp = data["layers"]["input"]
    assert inp["transfers"] == 2
    assert inp["items"] == 16
    assert inp["bytes"] == 2 * (1 << 20)
    # derived throughput rides along for dashboards
    assert inp["throughput_bytes_per_s"] == pytest.approx(
        reg.summary()["input"].throughput_bytes_per_s)
    assert data["worst_fidelity_gap"] == pytest.approx(
        reg.worst_fidelity_gap())


def test_json_round_trip_restores_aggregates():
    reg = _populated()
    clone = TelemetryRegistry.from_json(reg.to_json())
    assert clone.summary() == reg.summary()
    assert clone.worst_fidelity_gap() == pytest.approx(
        reg.worst_fidelity_gap())


def test_round_trip_of_empty_registry():
    reg = TelemetryRegistry()
    clone = TelemetryRegistry.from_json(reg.to_json())
    assert clone.summary() == {}
    assert clone.worst_fidelity_gap() is None
    assert json.loads(reg.to_json())["worst_fidelity_gap"] is None


def test_round_trip_preserves_gapless_layers():
    """Layers that never carried a plan round-trip with gap None, not 0."""
    reg = TelemetryRegistry()
    reg.record("adhoc", _report())
    clone = TelemetryRegistry.from_json(reg.to_json())
    assert clone.summary()["adhoc"].worst_fidelity_gap is None


def test_dump_json_atomic_file_round_trip(tmp_path):
    reg = _populated()
    path = str(tmp_path / "telemetry.json")
    reg.dump_json(path)
    assert not os.path.exists(path + ".tmp")      # tmp renamed away
    with open(path) as f:
        clone = TelemetryRegistry.from_json(f.read())
    assert clone.summary() == reg.summary()
    # a second dump overwrites in place (the polling-dashboard contract)
    reg.record("serve", _report())
    reg.dump_json(path)
    with open(path) as f:
        assert json.loads(f.read())["layers"]["serve"]["transfers"] == 2


def test_summary_equality_is_field_wise():
    a = LayerSummary(layer="x", transfers=1, items=2, bytes=3, elapsed_s=0.5)
    b = LayerSummary(layer="x", transfers=1, items=2, bytes=3, elapsed_s=0.5)
    assert a == b                                  # dataclass semantics


def test_append_jsonl_time_series(tmp_path):
    """Satellite: append mode keeps a history — one snapshot line per
    flush, each a full to_json payload plus a wall-time stamp."""
    reg = TelemetryRegistry()
    path = str(tmp_path / "ts.jsonl")
    for i in range(1, 4):
        reg.record("input", _report(nbytes=i * (1 << 20), planned=4e6))
        reg.append_jsonl(path, timestamp=1000.0 + i)
    lines = [json.loads(l) for l in open(path) if l.strip()]
    assert len(lines) == 3
    assert [l["ts"] for l in lines] == [1001.0, 1002.0, 1003.0]
    # cumulative aggregates: bytes grow monotonically line over line
    totals = [l["layers"]["input"]["bytes"] for l in lines]
    assert totals == sorted(totals) and totals[-1] > totals[0]
    # each line individually round-trips through from_json
    restored = TelemetryRegistry.from_json(json.dumps(lines[-1]))
    assert restored.summary()["input"].transfers == 3


def test_timeseries_example_prints_trends(tmp_path):
    import subprocess
    import sys
    reg = TelemetryRegistry()
    path = str(tmp_path / "ts.jsonl")
    for i in range(1, 4):
        reg.record("input", _report(nbytes=i * (1 << 20), planned=4e6))
        reg.append_jsonl(path, timestamp=1000.0 + i)
    example = os.path.join(os.path.dirname(__file__), "..", "examples",
                           "telemetry_timeseries.py")
    env = dict(os.environ)
    src = os.path.join(os.path.dirname(__file__), "..", "src")
    env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
    out = subprocess.run([sys.executable, example, path], env=env,
                         capture_output=True, text=True, check=True)
    assert "input" in out.stdout
    assert "MB/s" in out.stdout
