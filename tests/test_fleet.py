"""Fleet-scale basin arbitration (the PR 8 tentpole): weighted QoS
shares, admission control, queue promotion, load shedding via basin
revision, the arbiter-capped replan gate, telemetry fleet rows, and the
zero-drain concurrent end-to-end scenario on the simulated basin.

The division of labor with test_fleet_properties.py: that file sweeps
randomized fleets for the conservation/monotonicity invariants; this one
pins exact arithmetic and the stateful paths (promotion takes share,
shedding, rebalance recovery, live transfers)."""

import json
import threading

import pytest

from simbasin import SimHarness

from repro.core.basin import DrainageBasin, GBPS, Link, MIB, Tier, TierKind
from repro.core.fleet import DEFAULT_CLASSES, Admission, FleetArbiter
from repro.core.planner import plan_transfer, replan
from repro.core.staging import StageReport
from repro.core.telemetry import TelemetryRegistry

ITEM = 1 * MIB
L = 100 * GBPS                  # the shared channel's line rate


def _channel_basin(link_bps=L, rtt_s=0.005):
    """Fat endpoints around one shared channel link: the tightest element
    is the link, so every conservation question is about L."""
    return DrainageBasin(
        [Tier("src", TierKind.SOURCE, 2 * link_bps),
         Tier("dst", TierKind.SINK, 2 * link_bps)],
        [Link("src", "dst", link_bps, rtt_s=rtt_s)])


def _admit_fleet(arb, specs):
    """specs: (name, qos[, kwargs]) -> dict of admissions, all asserted in."""
    out = {}
    for spec in specs:
        name, qos, kw = (spec if len(spec) == 3 else (*spec, {}))
        adm = arb.admit(name, ITEM, qos=qos, stages=("move",), **kw)
        assert adm.status == "admitted", (name, adm.status, adm.reason)
        out[name] = adm
    return out


# -- weighted shares ----------------------------------------------------------


def test_one_member_per_class_splits_line_by_weight():
    """Four members, one per default class, saturate one channel: the
    aggregate is exactly L and each grant is exactly its weight's share
    (8:4:2:1), with perfect weighted fairness."""
    arb = FleetArbiter(_channel_basin())
    adms = _admit_fleet(arb, [("a", "interactive"), ("b", "priority"),
                              ("c", "bulk"), ("d", "scavenger")])
    grants = arb.grants()
    total_w = sum(DEFAULT_CLASSES.values())       # 15
    assert sum(grants.values()) == pytest.approx(L)
    for name, qos in (("a", "interactive"), ("b", "priority"),
                      ("c", "bulk"), ("d", "scavenger")):
        assert grants[name] == pytest.approx(
            L * DEFAULT_CLASSES[qos] / total_w)
        assert adms[name].granted_bytes_per_s == grants[name]
    assert arb.weighted_fairness() == pytest.approx(1.0)


def test_single_member_gets_the_whole_line():
    arb = FleetArbiter(_channel_basin())
    (adm,) = _admit_fleet(arb, [("solo", "scavenger")]).values()
    assert adm.granted_bytes_per_s == pytest.approx(L)
    assert adm.plan.planned_bytes_per_s <= L * (1 + 1e-9)


def test_granted_plan_windows_enforce_the_grant():
    """The grant is enforced by the window, not just recorded: a capped
    plan's windowed hop carries exactly grant x RTT of credit — no
    jitter headroom, which on a shared link would overshoot the grant
    and breach conservation on the wire — and describe() names the
    cap."""
    rtt = 0.005
    arb = FleetArbiter(_channel_basin(rtt_s=rtt))
    adms = _admit_fleet(arb, [("a", "interactive"), ("b", "interactive")])
    for adm in adms.values():
        grant = adm.granted_bytes_per_s
        assert grant == pytest.approx(L / 2)
        hop = next(hp for hp in adm.plan.hops if hp.rtt_s > 0)
        assert hop.window_bytes == pytest.approx(grant * rtt)
        assert adm.plan.rate_cap_bytes_per_s == pytest.approx(grant)
        assert "arbiter-capped" in adm.plan.describe()


def test_floor_below_fair_share_never_inflates_the_grant():
    """An admission floor is a guarantee, not a bonus: a scavenger whose
    floor sits below its fair share receives exactly the floorless
    allocation."""
    floorless = FleetArbiter(_channel_basin())
    _admit_fleet(floorless, [("a", "interactive"), ("b", "priority"),
                             ("c", "bulk"), ("d", "scavenger")])
    floored = FleetArbiter(_channel_basin())
    _admit_fleet(floored, [("a", "interactive"), ("b", "priority"),
                           ("c", "bulk"),
                           ("d", "scavenger",
                            {"min_bytes_per_s": 0.05 * L})])
    assert floored.grants() == pytest.approx(floorless.grants())
    assert floored.grants()["d"] == pytest.approx(L / 15)


# -- admission control --------------------------------------------------------


def test_unfittable_min_ask_queues_and_never_perturbs_the_fleet():
    arb = FleetArbiter(_channel_basin())
    _admit_fleet(arb, [("a", "interactive"), ("b", "priority"),
                       ("c", "bulk"), ("d", "scavenger")])
    before = arb.grants()
    greedy = arb.admit("greedy", ITEM, qos="bulk",
                       min_bytes_per_s=0.3 * L, stages=("move",))
    assert greedy.status == "queued"
    assert greedy.reason.startswith("granting min")
    assert greedy.plan is None
    assert arb.grants() == before
    assert arb.stats()["queued"] == 1


def test_queue_false_rejects_instead():
    arb = FleetArbiter(_channel_basin())
    _admit_fleet(arb, [("a", "interactive"), ("b", "priority"),
                       ("c", "bulk"), ("d", "scavenger")])
    before = arb.grants()
    adm = arb.admit("greedy", ITEM, qos="bulk", min_bytes_per_s=0.3 * L,
                    queue=False, stages=("move",))
    assert adm.status == "rejected"
    assert arb.grants() == before
    assert arb.stats()["queued"] == 0


def test_ask_beyond_path_capability_rejected_even_on_empty_fleet():
    arb = FleetArbiter(_channel_basin())
    adm = arb.admit("impossible", ITEM, qos="interactive",
                    min_bytes_per_s=2 * L, stages=("move",))
    assert adm.status == "rejected"
    assert "capability" in adm.reason


def test_duplicate_name_and_unknown_qos_raise():
    arb = FleetArbiter(_channel_basin())
    _admit_fleet(arb, [("a", "bulk")])
    with pytest.raises(ValueError, match="already exists"):
        arb.admit("a", ITEM, qos="bulk", stages=("move",))
    with pytest.raises(ValueError, match="unknown QoS"):
        arb.admit("x", ITEM, qos="platinum", stages=("move",))


def test_promotion_requires_the_fair_share_to_reach_the_floor():
    """A queued ask promotes only when its floorless fair share reaches
    its floor — one release may not be enough.  greedy (bulk, w=2,
    min 0.3L): after releasing a, its share is 2/9 L (< 0.3L, still
    queued); after releasing b too, 2/5 L (>= 0.3L, admitted)."""
    arb = FleetArbiter(_channel_basin())
    adms = _admit_fleet(arb, [("a", "interactive"), ("b", "priority"),
                              ("c", "bulk"), ("d", "scavenger")])
    greedy = arb.admit("greedy", ITEM, qos="bulk",
                       min_bytes_per_s=0.3 * L, stages=("move",))
    assert greedy.status == "queued"

    adms["a"].release()
    assert greedy.status == "queued"          # 2/9 L < 0.3 L
    assert "greedy" not in arb.grants()

    adms["b"].release()                       # 2/5 L >= 0.3 L
    assert greedy.status == "admitted"
    grants = arb.grants()
    assert grants["greedy"] == pytest.approx(0.4 * L)
    assert grants["c"] == pytest.approx(0.4 * L)
    assert grants["d"] == pytest.approx(0.2 * L)
    assert greedy.plan is not None
    assert greedy.plan.rate_cap_bytes_per_s == pytest.approx(0.4 * L)


def test_releasing_a_queued_ask_withdraws_it():
    arb = FleetArbiter(_channel_basin())
    _admit_fleet(arb, [("a", "interactive")])
    greedy = arb.admit("greedy", ITEM, qos="bulk",
                       min_bytes_per_s=0.9 * L, stages=("move",))
    assert greedy.status == "queued"
    greedy.release()
    assert arb.stats()["queued"] == 0
    assert arb.grants() == {"a": pytest.approx(L)}


# -- load shedding via basin revision -----------------------------------------


def test_capacity_loss_sheds_the_lowest_class_floor_first():
    """Admission keeps the floors feasible on the basin they were
    admitted against, so shedding only becomes reachable when the basin
    is revised under the fleet's feet: rebalance(basin=degraded) with
    the channel at half rate leaves the floors oversubscribed, the
    higher class keeps its floor, and the lower class is cut to the
    remainder and marked shed — but stays live."""
    arb = FleetArbiter(_channel_basin())
    adms = _admit_fleet(arb, [
        ("ckpt", "bulk", {"min_bytes_per_s": 0.4 * L}),
        ("scav", "scavenger", {"min_bytes_per_s": 0.3 * L})])
    assert arb.grants() == {"ckpt": pytest.approx(2 * L / 3),
                            "scav": pytest.approx(L / 3)}
    assert arb.stats()["shed"] == []

    arb.rebalance(basin=_channel_basin(link_bps=L / 2))
    grants = arb.grants()
    assert grants["ckpt"] == pytest.approx(0.4 * L)   # floor honored
    assert grants["scav"] == pytest.approx(0.1 * L)   # cut below its floor
    assert adms["scav"].shed and not adms["ckpt"].shed
    assert arb.stats()["shed"] == ["scav"]
    assert adms["scav"].plan.rate_cap_bytes_per_s == pytest.approx(0.1 * L)
    assert "SHED" in arb.describe()

    # capacity comes back: the shed member recovers, the flag clears
    arb.rebalance(basin=_channel_basin())
    assert arb.grants() == {"ckpt": pytest.approx(2 * L / 3),
                            "scav": pytest.approx(L / 3)}
    assert arb.stats()["shed"] == []


def test_rebalance_rejects_a_different_topology():
    arb = FleetArbiter(_channel_basin())
    other = DrainageBasin(
        [Tier("elsewhere", TierKind.SOURCE, L),
         Tier("dst", TierKind.SINK, L)],
        [Link("elsewhere", "dst", L)])
    with pytest.raises(ValueError, match="topology"):
        arb.rebalance(basin=other)


# -- the arbiter-capped replan gate -------------------------------------------


def _capped_report(rate, *, n_items=360):
    bytes_ = n_items * 4 * MIB
    return StageReport(
        name="move", items=n_items, bytes=bytes_,
        elapsed_s=bytes_ / rate, stall_up_s=0.0,
        stall_down_s=0.7 * bytes_ / rate, errors=0,
        # tight sink service samples: the bandwidth-bound signature
        service_down_s=[0.026, 0.02601] * 10)


def test_replan_holds_verdicts_for_a_capped_plan_delivering_its_grant():
    """A fleet member pinned at its grant stalls downstream by
    construction — conservation at work, not degradation.  replan on a
    capped plan delivering the grant returns no verdict and keeps the
    cap; the same evidence on an UNCAPPED plan indicts the pipe."""
    basin = DrainageBasin([
        Tier("src", TierKind.SOURCE, 10 * GBPS),
        Tier("buf", TierKind.BURST_BUFFER, 100 * GBPS, latency_s=1e-5),
        Tier("dst", TierKind.SINK, 40 * GBPS, latency_s=1e-4)])
    cap = 6 * GBPS
    capped = plan_transfer(basin, 4 * MIB, stages=("move",),
                           rate_cap_bytes_per_s=cap)
    assert capped.planned_bytes_per_s == pytest.approx(cap)
    report = _capped_report(1.007 * cap)      # delivering the grant
    revised = replan(capped, [report], damping=1.0)
    assert revised.diagnosis == {}
    assert revised.rate_cap_bytes_per_s == pytest.approx(cap)
    assert revised.planned_bytes_per_s == pytest.approx(cap)

    uncapped = plan_transfer(basin, 4 * MIB, stages=("move",))
    loud = replan(uncapped, [report], damping=1.0)
    assert loud.diagnosis != {}


def test_replan_still_fires_when_a_capped_member_underdelivers():
    """The gate is a grant-awareness filter, not a gag: delivery far
    below the member's OWN grant is a real symptom and diagnoses as
    usual, with the cap carried onto the rebuilt plan."""
    basin = DrainageBasin([
        Tier("src", TierKind.SOURCE, 10 * GBPS),
        Tier("buf", TierKind.BURST_BUFFER, 100 * GBPS, latency_s=1e-5),
        Tier("dst", TierKind.SINK, 40 * GBPS, latency_s=1e-4)])
    cap = 6 * GBPS
    capped = plan_transfer(basin, 4 * MIB, stages=("move",),
                           rate_cap_bytes_per_s=cap)
    revised = replan(capped, [_capped_report(0.18 * cap, n_items=64)],
                     damping=1.0)
    assert revised.diagnosis != {}
    assert revised.rate_cap_bytes_per_s == pytest.approx(cap)


def test_rate_cap_validation():
    basin = _channel_basin()
    with pytest.raises(ValueError, match="rate_cap"):
        plan_transfer(basin, ITEM, stages=("move",),
                      rate_cap_bytes_per_s=0.0)


# -- grant history / time-averaged promise ------------------------------------


def test_mean_granted_integrates_the_grant_step_function():
    """The honest promise for a transfer whose share moved mid-stream is
    the time-average of the grant: solo at L for 1 s, then halved when a
    peer arrives for 1 s -> 0.75 L over the window."""
    h = SimHarness()
    arb = h.arbiter(_channel_basin())
    a = arb.admit("a", ITEM, qos="bulk", stages=("move",))
    h.clock.advance(1.0)
    arb.admit("b", ITEM, qos="bulk", stages=("move",))
    h.clock.advance(1.0)
    assert a.granted_bytes_per_s == pytest.approx(L / 2)
    assert a.mean_granted(0.0, 2.0) == pytest.approx(0.75 * L)
    assert a.mean_granted(1.0, 2.0) == pytest.approx(0.5 * L)


# -- telemetry: the fleet row (satellite 6) -----------------------------------


def test_fleet_stats_ride_the_telemetry_surfaces(tmp_path):
    reg = TelemetryRegistry()
    arb = FleetArbiter(_channel_basin(), telemetry=reg)
    _admit_fleet(arb, [("a", "interactive"), ("b", "scavenger")])

    payload = json.loads(reg.to_json())
    fleet = payload["fleet"]
    assert fleet["live"] == 2
    assert fleet["queued"] == 0
    assert fleet["aggregate_granted_bytes_per_s"] == pytest.approx(L)
    assert fleet["fairness_index"] == pytest.approx(1.0)
    assert fleet["classes"]["interactive"]["granted_bytes_per_s"] == (
        pytest.approx(8 * L / 9))

    # the row survives the round trip and shows on the operator summary
    restored = TelemetryRegistry.from_json(reg.to_json())
    assert json.loads(restored.to_json())["fleet"] == fleet
    assert "fleet" in reg.format_summary()
    assert "2 live" in reg.format_summary()

    path = tmp_path / "trend.jsonl"
    reg.append_jsonl(str(path))
    row = json.loads(path.read_text().splitlines()[-1])
    assert row["fleet"]["live"] == 2

    reg.clear()
    assert "fleet" not in json.loads(reg.to_json())


def test_every_membership_change_publishes_a_fresh_row():
    reg = TelemetryRegistry()
    arb = FleetArbiter(_channel_basin(), telemetry=reg)
    adms = _admit_fleet(arb, [("a", "bulk"), ("b", "bulk")])
    assert json.loads(reg.to_json())["fleet"]["live"] == 2
    adms["a"].release()
    fleet = json.loads(reg.to_json())["fleet"]
    assert fleet["live"] == 1
    assert fleet["aggregate_granted_bytes_per_s"] == pytest.approx(L)


# -- mover integration: the zero-drain concurrent scenario --------------------


def test_mover_refuses_a_non_admitted_fleet_handle():
    arb = FleetArbiter(_channel_basin())
    _admit_fleet(arb, [("a", "interactive")])
    queued = arb.admit("q", ITEM, qos="bulk", min_bytes_per_s=0.9 * L,
                       stages=("move",))
    assert queued.status == "queued"
    h = SimHarness()
    with pytest.raises(ValueError, match="queued"):
        h.mover().bulk_transfer(
            iter([b"\0" * 64]), lambda _: None,
            transforms=[("move", lambda x: x)], fleet=queued)


def test_two_tenants_share_one_channel_zero_drain():
    """The tentpole end to end: tenant A starts alone at the full line,
    tenant B admits mid-stream, the arbiter pushes A's halved grant
    through the zero-drain applier (A's report counts >= 1 replan), both
    meet their TIME-AVERAGED promises on the shared simulated channel,
    and finishing auto-releases every grant."""
    h = SimHarness()
    arb = h.arbiter(_channel_basin())
    # contended-link mode: wall-gate callers into virtual-arrival order
    # so per-flow rates settle in proportion to their granted windows
    link = h.link(bandwidth_bytes_per_s=L, rtt_s=0.005,
                  wall_sync=10.0, wall_pacing_s=0.0)

    adm_a = arb.admit("A", ITEM, qos="interactive", stages=("move",))
    b_go = threading.Event()
    sunk_a = [0]

    def sink_a(item):
        sunk_a[0] += 1
        if sunk_a[0] == 24:
            b_go.set()

    def run_a():
        src = h.source(h.tier(bandwidth_bytes_per_s=1000 * GBPS,
                              wall_pacing_s=0.0), 96, ITEM)
        return h.mover().bulk_transfer(
            iter(src), sink_a,
            transforms=[("move", h.service(link))], fleet=adm_a)

    def run_b():
        b_go.wait(timeout=60)
        adm_b = arb.admit("B", ITEM, qos="bulk", stages=("move",))
        assert adm_b.status == "admitted", adm_b.reason
        src = h.source(h.tier(bandwidth_bytes_per_s=1000 * GBPS,
                              wall_pacing_s=0.0, seed=3), 96, ITEM)
        rep = h.mover().bulk_transfer(
            iter(src), lambda _: None,
            transforms=[("move", h.service(link))], fleet=adm_b)
        return rep, adm_b

    rep_a, (rep_b, adm_b) = h.run_concurrent(run_a, run_b)
    assert rep_a.items == 96 and rep_b.items == 96
    # A's grant moved mid-stream: the rebalance reached the live stage
    assert rep_a.replans >= 1
    # both met their time-averaged promises on the contended channel
    assert abs(rep_a.fidelity_gap) < 0.25, rep_a.fidelity_gap
    assert abs(rep_b.fidelity_gap) < 0.25, rep_b.fidelity_gap
    # completion auto-released both grants
    assert arb.grants() == {}
    assert adm_a.granted_bytes_per_s == 0.0
    assert adm_b.granted_bytes_per_s == 0.0
