"""Per-assigned-architecture smoke tests (reduced same-family configs).

For each of the 10 archs: instantiate the reduced config, run one forward
/ train step on CPU, assert output shapes and no NaNs — per the
assignment's smoke-test rule.  The FULL configs are exercised only via
the dry-run (ShapeDtypeStruct, no allocation).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ASSIGNED_ARCHS, get_config, get_smoke_config
from repro.models import ShardCtx, build

pytestmark = pytest.mark.slow

B, S = 2, 64


def _batch(cfg, rng):
    s_text = S - cfg.frontend_len if cfg.frontend else S
    batch = {
        "tokens": rng.integers(0, cfg.vocab, (B, s_text)).astype(np.int32),
        "labels": rng.integers(0, cfg.vocab, (B, s_text)).astype(np.int32),
    }
    if cfg.family == "encdec":
        batch["tokens"] = rng.integers(0, cfg.vocab, (B, S)).astype(np.int32)
        batch["labels"] = rng.integers(0, cfg.vocab, (B, S)).astype(np.int32)
        batch["frames"] = rng.standard_normal((B, S, cfg.d_model)).astype(np.float32)
    if cfg.frontend and cfg.family != "encdec":
        batch["extra_embeds"] = rng.standard_normal(
            (B, cfg.frontend_len, cfg.d_model)).astype(np.float32)
    return batch


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_smoke_forward_and_grad(arch, rng):
    cfg = get_smoke_config(arch)
    cfg.validate()
    api = build(cfg)
    params = api.init(jax.random.PRNGKey(0))
    batch = _batch(cfg, rng)
    ctx = ShardCtx()

    def loss_fn(p):
        loss, aux = api.loss(p, batch, ctx)
        return loss

    loss, grads = jax.jit(jax.value_and_grad(loss_fn))(params)
    assert np.isfinite(float(loss)), f"{arch}: non-finite loss"
    gnorm = sum(float(jnp.sum(jnp.square(g.astype(jnp.float32))))
                for g in jax.tree.leaves(grads))
    assert np.isfinite(gnorm) and gnorm > 0, f"{arch}: bad grads"


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_smoke_train_step_updates_params(arch, rng):
    from repro.core.codesign import CodesignPlan
    from repro.launch.mesh import make_host_mesh
    from repro.launch import steps as steps_lib
    from repro.optim.adamw import adamw_init

    cfg = get_smoke_config(arch)
    api = build(cfg)
    mesh = make_host_mesh()
    plan = CodesignPlan(sharding="dp", microbatches=1, remat="none",
                        seq_parallel=False)
    step, p_shard, s_shard, ctx = steps_lib.make_train_step(api, mesh, plan)
    params = jax.jit(api.init, out_shardings=p_shard)(jax.random.PRNGKey(0))
    opt = jax.jit(adamw_init, out_shardings=s_shard)(params)
    before = [np.asarray(x, np.float32).copy()
              for x in jax.tree.leaves(opt.master)]
    batch = _batch(cfg, rng)
    params2, opt2, metrics = step(params, opt, batch)
    assert np.isfinite(float(metrics["loss"])), arch
    # one warmup step moves the fp32 master weights (warmup-scale deltas
    # are below bf16/allclose resolution — exact any-leaf comparison)
    after = [np.asarray(x, np.float32) for x in jax.tree.leaves(opt2.master)]
    moved = any(not np.array_equal(a, b) for a, b in zip(before, after))
    assert moved, f"{arch}: no master weight moved"
    assert int(opt2.step) == 1


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_smoke_decode_shapes_and_finiteness(arch, rng):
    cfg = get_smoke_config(arch)
    api = build(cfg)
    params = api.init(jax.random.PRNGKey(0))
    ctx = ShardCtx()
    batch = _batch(cfg, rng)
    logits, cache = jax.jit(
        lambda p, b: api.prefill(p, b, ctx, max_len=S + 8))(params, batch)
    assert logits.shape[0] == B and logits.shape[-1] == cfg.vocab
    tok = jnp.zeros((B, 1), jnp.int32)
    logits2, cache2 = jax.jit(
        lambda p, c, t: api.decode_step(p, c, t, ctx))(params, cache, tok)
    assert logits2.shape == (B, 1, cfg.vocab)
    assert np.all(np.isfinite(np.asarray(logits2, np.float32))), arch
    assert int(cache2["pos"]) == int(cache["pos"]) + 1


def test_full_configs_match_published_sizes():
    expected = {
        "phi3-mini-3.8b": (3.6e9, 4.0e9),
        "mistral-large-123b": (118e9, 126e9),
        "mixtral-8x22b": (135e9, 147e9),
        "qwen3-moe-30b-a3b": (29e9, 32e9),
        "mamba2-1.3b": (1.2e9, 1.5e9),
        "zamba2-1.2b": (1.0e9, 1.3e9),
        "llava-next-mistral-7b": (7.0e9, 7.6e9),
        "smollm-360m": (0.3e9, 0.5e9),
        "gemma3-1b": (1.0e9, 1.4e9),
        "seamless-m4t-large-v2": (1.9e9, 2.4e9),
    }
    for arch, (lo, hi) in expected.items():
        n = get_config(arch).param_count()
        assert lo <= n <= hi, f"{arch}: {n:.3e} outside [{lo:.2e}, {hi:.2e}]"


def test_moe_active_params():
    qwen = get_config("qwen3-moe-30b-a3b")
    assert 2.5e9 <= qwen.active_param_count() <= 4e9   # "A3B"
    mix = get_config("mixtral-8x22b")
    assert 35e9 <= mix.active_param_count() <= 45e9    # ~39B active
