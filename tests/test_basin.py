"""Drainage-basin model: unit + property tests."""

import math

import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:          # not installable here - deterministic shim
    from _hypothesis_fallback import given, settings, st

from repro.core.basin import (ApplianceTier, DrainageBasin, GBPS, Link, Tier,
                              TierKind, daily_volume_bytes, paper_basin,
                              recommend_tier, tpu_input_basin)


def test_paper_basin_bottleneck_is_storage():
    b = paper_basin(link_gbps=100.0, storage_gbps=40.0)
    rep = b.bottleneck()
    assert rep.element == "prod-storage-src"
    assert rep.kind == "tier"
    # fidelity gap vs the fastest element (the burst buffer tier)
    assert 0.5 < rep.fidelity_gap < 0.95


def test_balanced_basin_has_no_storage_gap():
    b = paper_basin(link_gbps=100.0, storage_gbps=200.0)
    rep = b.bottleneck()
    assert rep.element in ("wan", "burst-buffer-src->wan", "wan->burst-buffer-dst")
    assert rep.achievable_bytes_per_s == pytest.approx(100.0 * GBPS)


def test_small_item_latency_penalty():
    """Paper §3.4: small files choke on per-item latency, not bandwidth."""
    b = paper_basin()
    big = b.achievable_throughput(item_bytes=1 << 30)
    small = b.achievable_throughput(item_bytes=1 << 10)
    assert small < big / 100


def test_bdp():
    l = Link("a", "b", 100.0 * GBPS, rtt_s=0.074)
    assert l.bdp_bytes() == pytest.approx(100.0 * GBPS * 0.074)


def test_tier_recommendation_fig3():
    assert recommend_tier(1 * GBPS) == ApplianceTier.MINI
    assert recommend_tier(40 * GBPS) == ApplianceTier.MINI_PLUS
    assert recommend_tier(100 * GBPS) == ApplianceTier.CORE


def test_table5_daily_volumes():
    # Table 5: 1 Gbps ~ 10 TB/day, 10 ~ 100, 100 ~ 1 PB (paper rounds)
    assert daily_volume_bytes(1 * GBPS) == pytest.approx(10.8e12, rel=0.01)
    assert daily_volume_bytes(100 * GBPS) == pytest.approx(1.08e15, rel=0.01)


def test_prefetch_depth_covers_jitter():
    b = tpu_input_basin(dataset_jitter_ms=100.0)
    shallow = tpu_input_basin(dataset_jitter_ms=1.0)
    assert b.prefetch_depth(1 << 20) >= shallow.prefetch_depth(1 << 20)
    assert b.prefetch_depth(1 << 20) >= 2


def test_duplicate_tier_names_rejected():
    t = Tier("x", TierKind.SOURCE, 1.0)
    with pytest.raises(ValueError):
        DrainageBasin([t, t])


# ---------------------------------------------------------------------------
# properties
# ---------------------------------------------------------------------------

bw = st.floats(min_value=1e6, max_value=1e12, allow_nan=False)


@given(bws=st.lists(bw, min_size=2, max_size=6))
@settings(max_examples=50, deadline=None)
def test_throughput_is_min_of_path(bws):
    tiers = [Tier(f"t{i}", TierKind.CHANNEL, b) for i, b in enumerate(bws)]
    basin = DrainageBasin(tiers)
    assert basin.achievable_throughput() == pytest.approx(min(bws))


@given(bws=st.lists(bw, min_size=2, max_size=6), achieved_frac=st.floats(0.01, 1.0))
@settings(max_examples=50, deadline=None)
def test_fidelity_gap_in_unit_interval(bws, achieved_frac):
    tiers = [Tier(f"t{i}", TierKind.CHANNEL, b) for i, b in enumerate(bws)]
    basin = DrainageBasin(tiers)
    achieved = basin.achievable_throughput() * achieved_frac
    gap = basin.fidelity_gap(achieved)
    assert -1e-9 <= gap <= 1.0


@given(bws=st.lists(bw, min_size=2, max_size=6),
       item=st.integers(min_value=1, max_value=1 << 34))
@settings(max_examples=50, deadline=None)
def test_item_amortization_monotone(bws, item):
    """Bigger items never reduce effective throughput (latency amortizes)."""
    tiers = [Tier(f"t{i}", TierKind.CHANNEL, b, latency_s=1e-3)
             for i, b in enumerate(bws)]
    basin = DrainageBasin(tiers)
    assert (basin.achievable_throughput(item_bytes=item * 2)
            >= basin.achievable_throughput(item_bytes=item) * (1 - 1e-9))
