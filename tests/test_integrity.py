"""Integrity of the staged path: staged-vs-direct checksum parity, digest
stability under worker reordering, and plan-placed checksum hops.

The mover's stream digest is the XOR of per-item SHA-256 digests —
commutative and associative, so concurrent staging workers may deliver
items in any order without changing the digest.  That claim is what
these tests pin down.
"""

import hashlib

import numpy as np
import pytest

from repro.core.basin import DrainageBasin, GBPS, Tier, TierKind
from repro.core.mover import MoverConfig, UnifiedDataMover, _as_bytes
from repro.core.planner import plan_transfer


def _items(n=32, size=4 * 1024, seed=0):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, 255, size, dtype=np.uint8) for _ in range(n)]


def _xor_digest(items):
    acc = bytearray(32)
    for it in items:
        d = hashlib.sha256(_as_bytes(it)).digest()
        for i in range(32):
            acc[i] ^= d[i]
    return bytes(acc).hex()


def test_staged_matches_direct_checksum():
    """The staged (buffered, overlapped) path certifies the same stream
    as the serial direct copy — integrity is path-independent."""
    data = _items()
    mover = UnifiedDataMover(MoverConfig(checksum=True))
    staged = mover.bulk_transfer(iter(data), lambda _: None)
    direct = mover.direct_transfer(iter(data), lambda _: None)
    assert staged.checksum == direct.checksum == _xor_digest(data)


def test_digest_stable_under_worker_reordering():
    """workers > 1 may reorder delivery; the XOR-of-SHA256 stream digest
    must not notice.  Runs several times to actually exercise races."""
    data = _items(n=64, size=512)
    expect = _xor_digest(data)
    mover = UnifiedDataMover(MoverConfig(staging_capacity=2,
                                         staging_workers=4, checksum=True))
    for trial in range(5):
        got = []
        rep = mover.bulk_transfer(iter(data), got.append)
        assert rep.checksum == expect
        assert len(got) == len(data)
        # the delivered set is intact even if the order is not
        assert sorted(g.tobytes() for g in got) == \
            sorted(d.tobytes() for d in data)


def test_digest_order_independence_is_real():
    """Sanity: reversing the stream yields the same XOR digest, while a
    corrupted item yields a different one."""
    data = _items(n=16)
    assert _xor_digest(data) == _xor_digest(list(reversed(data)))
    corrupt = [d.copy() for d in data]
    corrupt[7][0] ^= 0xFF
    assert _xor_digest(data) != _xor_digest(corrupt)


def test_plan_placed_checksum_preserves_digest():
    """With a plan, hashing rides the headroom hop mid-path — placement
    must not change what is certified."""
    basin = DrainageBasin([
        Tier("slow-src", TierKind.SOURCE, 2 * GBPS, latency_s=1e-3),
        Tier("fat-buf", TierKind.BURST_BUFFER, 400 * GBPS),
        Tier("sink", TierKind.SINK, 40 * GBPS),
    ])
    plan = plan_transfer(basin, 4 * 1024, stages=["pull", "push"],
                         checksum=True)
    assert plan.checksum_index == 1      # mid-path, not trailing
    data = _items()
    mover = UnifiedDataMover(MoverConfig(checksum=True), plan=plan)
    rep = mover.bulk_transfer(
        iter(data), lambda _: None,
        transforms=[("pull", lambda x: x), ("push", lambda x: x)])
    assert rep.checksum == _xor_digest(data)


def test_checksum_sees_pre_transform_items_when_placed_first():
    """Placement is observable: a checksum hop before a transform
    certifies the source bytes; a trailing one certifies the output."""
    data = _items(n=8)
    negated = [255 - d for d in data]

    basin = DrainageBasin([
        Tier("src", TierKind.SOURCE, 400 * GBPS),
        Tier("buf", TierKind.BURST_BUFFER, 2 * GBPS, latency_s=1e-3),
        Tier("sink", TierKind.SINK, 2 * GBPS, latency_s=1e-3),
    ])
    plan = plan_transfer(basin, 4 * 1024, stages=["negate"], checksum=True)
    assert plan.checksum_index == 0      # headroom is at the source side
    mover = UnifiedDataMover(MoverConfig(checksum=True), plan=plan)
    rep = mover.bulk_transfer(iter(data), lambda _: None,
                              transforms=[("negate", lambda x: 255 - x)])
    assert rep.checksum == _xor_digest(data)
    assert rep.checksum != _xor_digest(negated)

    trailing = UnifiedDataMover(MoverConfig(checksum=True))
    rep2 = trailing.bulk_transfer(iter(data), lambda _: None,
                                  transforms=[("negate", lambda x: 255 - x)])
    assert rep2.checksum == _xor_digest(negated)


def test_streaming_and_bulk_agree_on_checksum():
    data = _items(n=20)
    mover = UnifiedDataMover(MoverConfig(checksum=True))
    bulk = mover.bulk_transfer(iter(data), lambda _: None)
    streaming = mover.streaming_transfer(iter(data), lambda _: None)
    assert bulk.checksum == streaming.checksum == _xor_digest(data)
